"""Chaos-plane benchmark: seeded fault storms, recovery SLOs, and the gate.

Replays a deterministic correlated-fault storm (:mod:`repro.chaos`) —
spatial core bursts with repairs, directed NoC-link failures and
bandwidth stragglers, link repairs — against the multi-tenant cluster
scheduler with recovery armed (:class:`repro.sched.RecoveryConfig`):
training-class tenants killed by faults resume from their last
checkpoint with the resharding transfer charged, serving tenants
re-admit through bounded exponential backoff, and degraded links are
re-costed through the interference model instead of quarantined.

Run:
    PYTHONPATH=src python benchmarks/chaos_sim.py \\
        --trace mixed --policy vnpu,mig,uvm --storm storm

Reports per-policy service availability (admitted / arrived), capacity
availability (1 - core-downtime share), MTTR, fault kills and how they
resolved (checkpoint resumes vs serving retries vs drops), rework and
re-warm cost.

CI gate (merges into ``BENCH_cluster_sim.json``; override with
``--bench-out``):

    PYTHONPATH=src python benchmarks/chaos_sim.py --gate

replays the pinned 6x6 storm twice per policy and fails unless (a) the
fault/repair/migration trajectories are bit-identical run-to-run and
ledger-vs-oracle, (b) vNPU's availability is >= both baselines' under
the same storm, (c) every policy clears its pinned availability floor
and the MTTR ceiling, and (d) the availability counters conserve
(arrived == admitted + rejected).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.chaos import STORMS, make_fault_plan       # noqa: E402
from repro.core import mesh_2d                        # noqa: E402
from repro.core import simulator as S                 # noqa: E402
from repro.obs.registry import (MetricsRegistry,      # noqa: E402
                                collect_cluster)
from repro.obs.trace import Tracer                    # noqa: E402
from repro.sched import (ClusterScheduler, RecoveryConfig,  # noqa: E402
                         TRACES, make_policy, make_trace)

sys.path.insert(0, str(Path(__file__).resolve().parent))

from cluster_sim import BENCH_PATH, _write_bench      # noqa: E402

GATE_MESH = (6, 6)
GATE_HORIZON = 90.0
GATE_SEED = 7
GATE_STORM = "storm"
GATE_POLICIES = ("vnpu", "mig", "uvm")

#: tenants at least this long are training jobs: they checkpoint every
#: ``RecoveryConfig.ckpt_interval_s`` and resume after a fault kill
TRAIN_DURATION_S = 30.0

# pinned SLO floors for the seeded gate storm (measured 0.67 / 0.43 /
# 0.63 at this PR).  MIG's floor is far lower by construction: a core
# death poisons the whole rectangular partition, so the same storm costs
# it multiples of the per-core capacity loss.
GATE_AVAIL_FLOOR = {"vnpu": 0.60, "mig": 0.35, "uvm": 0.55}
GATE_MTTR_CEIL_S = 10.0       # repairs must land (storm repair mean 18 s
                              # clipped by the horizon keeps MTTR below this)


def chaos_trace(name: str = "mixed", seed: int = GATE_SEED,
                horizon_s: float = GATE_HORIZON):
    """The arrival trace with long tenants promoted to training class —
    the population whose fault kills exercise checkpoint resume."""
    trace = make_trace(name, seed=seed, horizon_s=horizon_s)
    return [dataclasses.replace(spec, tenant_class="train")
            if spec.duration_s >= TRAIN_DURATION_S else spec
            for spec in trace]


def run_storm(policy_name, trace, plan, trace_name="mixed",
              rescore="ledger", epoch_s=2.0, tracer=None):
    """One policy through one storm: fresh scheduler, recovery armed,
    fault plan injected up front (the event queue interleaves faults,
    repairs and arrivals deterministically)."""
    policy = make_policy(policy_name, mesh_2d(plan.rows, plan.cols))
    sched = ClusterScheduler(policy, hw=S.SIM_CONFIG, epoch_s=epoch_s,
                             rescore=rescore, recovery=RecoveryConfig(),
                             tracer=tracer)
    t0 = time.perf_counter()
    sched.begin(trace_name=trace_name)
    sched.feed(trace)
    sched.inject_chaos(plan.cluster_events())
    sched.advance_to(None)
    metrics = sched.finish()
    return metrics, time.perf_counter() - t0


def chaos_digest(m):
    """Everything two replays of the same storm must agree on exactly:
    the score trajectory plus every fault/repair/recovery counter."""
    return (
        [(s.t, s.agg_fps, s.utilization, s.n_resident, s.n_queued)
         for s in m.samples],
        dict(m.tenant_iterations),
        m.recovery_summary(),
        (m.n_arrived, m.n_admitted, m.n_rejected, m.n_migrations,
         m.n_failed_cores, m.n_events),
    )


def _bench_entry(policy_name, m, wall_s, storm):
    rec = m.recovery_summary()
    return {
        "trace": "chaos-mixed",
        "mesh": f"{GATE_MESH[0]}x{GATE_MESH[1]}-storm",
        "mode": policy_name,
        "storm": storm,
        "wall_s": round(wall_s, 2),
        "events": m.n_events,
        "service_availability": rec["service_availability"],
        "capacity_availability": rec["capacity_availability"],
        "mttr_s": rec["mttr_s"],
        "fault_kills": rec["fault_kills"],
        "ckpt_resumes": rec["ckpt_resumes"],
        "fault_retries": rec["fault_retries"],
        "fault_drops": rec["fault_drops"],
        "requests_fault_lost": rec["requests_fault_lost"],
        "rework_s": rec["rework_s"],
        "rewarm_cost_s": rec["rewarm_cost_s"],
    }


def run_chaos_gate(json_out: bool, bench_out=BENCH_PATH,
                   trace_out=None, metrics_out=None) -> int:
    """The pinned-storm SLO gate (see the module docstring).  With
    ``--trace-out`` / ``--metrics-out`` the vNPU replay run is traced, so
    the replay bit-identity check doubles as the tracing-purity check."""
    plan = make_fault_plan(*GATE_MESH, GATE_HORIZON, seed=GATE_SEED,
                           profile=GATE_STORM)
    trace = chaos_trace()
    report = {
        "mesh": list(GATE_MESH), "storm": GATE_STORM, "seed": GATE_SEED,
        "horizon_s": GATE_HORIZON, "fault_events": plan.summary(),
        "avail_floors": dict(GATE_AVAIL_FLOOR),
        "mttr_ceiling_s": GATE_MTTR_CEIL_S, "policies": {},
    }
    entries = []
    runs = {}
    ok = True
    observe = bool(trace_out or metrics_out)
    for name in GATE_POLICIES:
        m1, w1 = run_storm(name, trace, plan)
        tracer = None
        if observe and name == "vnpu":
            tracer = Tracer()
            tracer.process_name(
                f"vnpu {GATE_MESH[0]}x{GATE_MESH[1]} {GATE_STORM}")
        m2, _ = run_storm(name, trace, plan, tracer=tracer)
        replay_ok = chaos_digest(m1) == chaos_digest(m2)
        if tracer is not None:
            report["trace_events"] = len(tracer)
            report["trace_dropped"] = tracer.dropped
            if trace_out:
                tracer.write(trace_out)
            if metrics_out:
                reg = MetricsRegistry()
                collect_cluster(reg, m2)
                reg.write_json(metrics_out)
        runs[name] = m1
        rec = m1.recovery_summary()
        conserved = m1.n_arrived == m1.n_admitted + m1.n_rejected
        pol_ok = (replay_ok and conserved
                  and rec["service_availability"] >= GATE_AVAIL_FLOOR[name]
                  and 0.0 < rec["mttr_s"] <= GATE_MTTR_CEIL_S)
        ok = ok and pol_ok
        report["policies"][name] = {
            "replay_identical": replay_ok,
            "counters_conserved": conserved,
            "arrived": m1.n_arrived, "admitted": m1.n_admitted,
            "rejected": m1.n_rejected,
            "policy_ok": pol_ok,
            **rec,
        }
        entries.append(_bench_entry(name, m1, w1, GATE_STORM))

    # the headline SLO claim: under the same storm the fine-grained
    # quarantine + migrate + resume machinery keeps vNPU's availability
    # at or above both baselines'
    avail = {n: runs[n].service_availability for n in GATE_POLICIES}
    order_ok = avail["vnpu"] >= avail["mig"] and avail["vnpu"] >= avail["uvm"]
    # checkpoint resume must actually fire (the storm kills trainers)
    resume_ok = runs["vnpu"].n_ckpt_resumes > 0
    # degraded-link re-costing is mode-independent: the incremental
    # ledger and the oracle recompute replay the storm bit-identically
    oracle, _ = run_storm("vnpu", trace, plan, rescore="oracle")
    modes_ok = chaos_digest(runs["vnpu"]) == chaos_digest(oracle)
    ok = ok and order_ok and resume_ok and modes_ok
    report.update({
        "availability_order_ok": order_ok,
        "ckpt_resume_exercised": resume_ok,
        "ledger_oracle_identical": modes_ok,
        "gate_ok": ok,
    })
    _write_bench("chaos", report, entries, bench_out)
    if json_out:
        print(json.dumps(report, indent=2))
        return 0 if ok else 1
    for name in GATE_POLICIES:
        p = report["policies"][name]
        print(f"{name:>6}: avail={p['service_availability']:.4f} "
              f"(floor {GATE_AVAIL_FLOOR[name]}) "
              f"mttr={p['mttr_s']:.2f}s kills={p['fault_kills']} "
              f"resumes={p['ckpt_resumes']} retries={p['fault_retries']} "
              f"drops={p['fault_drops']} replay="
              f"{'bit-identical' if p['replay_identical'] else 'DIVERGED'} "
              f"-> {'OK' if p['policy_ok'] else 'FAIL'}")
    print(f"vnpu >= baselines: {order_ok}; ledger==oracle: {modes_ok}; "
          f"resumes exercised: {resume_ok} "
          f"-> {'OK' if ok else 'FAIL'}")
    return 0 if ok else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--trace", default="mixed",
                    help="trace name: " + "|".join(sorted(TRACES)))
    ap.add_argument("--policy", default="vnpu,mig,uvm",
                    help="comma-separated: vnpu,mig,uvm")
    ap.add_argument("--mesh", default="6,6", help="physical mesh rows,cols")
    ap.add_argument("--horizon", type=float, default=GATE_HORIZON,
                    help="arrival + fault horizon in seconds")
    ap.add_argument("--seed", type=int, default=GATE_SEED,
                    help="trace and fault-plan seed")
    ap.add_argument("--storm", default=GATE_STORM, choices=sorted(STORMS),
                    help="fault-storm intensity profile")
    ap.add_argument("--gate", action="store_true",
                    help="CI mode: pinned-storm replay/SLO gate")
    ap.add_argument("--bench-out", default=str(BENCH_PATH),
                    help="where --gate merges its BENCH record")
    ap.add_argument("--profile", action="store_true",
                    help="wrap the run in cProfile and print the top-20 "
                         "cumulative hotspots")
    ap.add_argument("--profile-out", default=None, metavar="FILE",
                    help="dump the raw cProfile pstats data to FILE "
                         "(implies --profile)")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="write a Chrome/Perfetto trace-event JSON of the "
                         "run (fault/repair windows as chaos-category "
                         "spans)")
    ap.add_argument("--metrics-out", default=None, metavar="FILE",
                    help="write the unified metrics-registry snapshot "
                         "as JSON")
    ap.add_argument("--json", action="store_true", help="machine output")
    args = ap.parse_args(argv)

    if args.profile or args.profile_out:
        from _profile import run_profiled, strip_profile_flags
        return run_profiled(main, strip_profile_flags(argv),
                            args.profile_out)

    if args.gate:
        return run_chaos_gate(args.json, args.bench_out,
                              args.trace_out, args.metrics_out)

    try:
        rows, cols = (int(x) for x in args.mesh.split(","))
    except ValueError:
        ap.error(f"--mesh wants 'rows,cols' (got {args.mesh!r})")
    policies = [p.strip() for p in args.policy.split(",") if p.strip()]
    try:
        trace = chaos_trace(args.trace, args.seed, args.horizon)
        for name in policies:
            make_policy(name, mesh_2d(1, 1))   # validate names up front
    except KeyError as e:
        ap.error(str(e))
    plan = make_fault_plan(rows, cols, args.horizon, seed=args.seed,
                           profile=args.storm)

    obs_tracer = Tracer() if args.trace_out else Tracer.NULL
    reg = MetricsRegistry() if args.metrics_out else None
    results = []
    for i, name in enumerate(policies):
        tracer = None
        if args.trace_out:
            tracer = Tracer(pid=i)
            tracer.process_name(f"{name} {rows}x{cols} {args.storm}")
        metrics, wall = run_storm(name, trace, plan, trace_name=args.trace,
                                  tracer=tracer)
        results.append((metrics, wall))
        if tracer is not None:
            obs_tracer.absorb(tracer.drain())
        if reg is not None:
            collect_cluster(reg, metrics, prefix=f"cluster_{name}")
    if args.trace_out:
        obs_tracer.write(args.trace_out)
    if reg is not None:
        reg.write_json(args.metrics_out)

    if args.json:
        print(json.dumps({
            "trace": args.trace, "mesh": [rows, cols],
            "storm": args.storm, "fault_events": plan.summary(),
            "policies": [dict(m.summary(), wall_s=round(w, 2))
                         for m, w in results],
        }, indent=2))
        return 0

    print(f"trace={args.trace} tenants={len(trace)} mesh={rows}x{cols} "
          f"storm={args.storm} faults={plan.summary()}")
    print(f"{'policy':>6} {'avail':>7} {'cap_av':>7} {'mttr_s':>7} "
          f"{'kills':>6} {'resume':>7} {'retry':>6} {'drop':>5} "
          f"{'rework_s':>9} {'wall_s':>7}")
    for m, wall in results:
        rec = m.recovery_summary()
        print(f"{m.policy:>6} {rec['service_availability']:>7.4f} "
              f"{rec['capacity_availability']:>7.4f} "
              f"{rec['mttr_s']:>7.2f} {rec['fault_kills']:>6} "
              f"{rec['ckpt_resumes']:>7} {rec['fault_retries']:>6} "
              f"{rec['fault_drops']:>5} {rec['rework_s']:>9.2f} "
              f"{wall:>7.1f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
