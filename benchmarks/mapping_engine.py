"""MappingEngine benchmark: allocation latency at pod scale + TED quality.

Compares the engine (incremental regions + canonical TED cache + vectorized
candidate scoring) against the pre-engine reference path
(``repro.core.mapping.min_topology_edit_distance``, a from-scratch batch
solve per request) on:

1. **Latency** — randomized allocate/release churn on pod meshes (16x16 =
   256 cores, optionally 32x32 = 1024).  Reports the median solve latency
   per allocation event for both paths and the speedup (the PR-2 claim is
   >= 10x at 256+ cores).
2. **Quality** — randomized blocked-set scenarios on the 6x6 paper SIM
   config: the engine's TED must be equal or better than the reference on
   every scenario (the engine scores a superset of the reference candidate
   pool and refines assignments, so it should never lose).

Run:
    PYTHONPATH=src python benchmarks/mapping_engine.py [--big] [--json]

CI gate (allocation-latency smoke):
    PYTHONPATH=src python benchmarks/mapping_engine.py --gate
drives the sched ``mixed`` trace through the engine on a 16x16 mesh and
fails unless the median allocation solve is <= 50 ms/event.

Optimality-gap gate (placement-quality oracle):
    PYTHONPATH=src python benchmarks/mapping_engine.py --gap-gate
sweeps seeded free-region/request corpora on 6x6..16x16 meshes, solves
each scenario with the ``ilp`` mapper (exact MILP, provable-optimality
flag), and records every heuristic mapper's TED gap — and the end-to-end
score gap (simulated iteration-interval regression of its placement) —
against the proven optimum into ``BENCH_cluster_sim.json``.  Fails if any
mapper beats a proven optimum (soundness), or if bipartite/hybrid exceed
their pinned max-TED-gap bounds on proven scenarios.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path
from typing import List, Optional, Tuple

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np                                    # noqa: E402

from repro.core.engine import MappingEngine           # noqa: E402
from repro.core.mapping import min_topology_edit_distance  # noqa: E402
from repro.core.topology import mesh_2d               # noqa: E402

GATE_MEDIAN_S = 0.050     # CI gate: median engine solve on 16x16 mixed trace

REQUEST_SHAPES = ((2, 2), (2, 3), (2, 4), (3, 3), (3, 4), (4, 4))

# ---- optimality-gap gate (--gap-gate) --------------------------------------
#: (mesh, blocked fractions, request shapes) of the seeded gap corpora.
#: Small meshes carry the heavily-fragmented (nonzero-TED) scenarios where
#: the MILP genuinely branches; pod meshes exercise the TED-0 shortcut and
#: the sub-domain path at scale.
GAP_CORPORA = (
    ((6, 6),   (0.15, 0.30, 0.45), ((2, 2), (2, 3), (3, 3), (2, 4), (3, 4))),
    ((8, 8),   (0.20, 0.40),       ((2, 3), (3, 3), (3, 4), (4, 4))),
    ((10, 10), (0.20, 0.40),       ((3, 3), (3, 4), (4, 4))),
    ((12, 12), (0.25,),            ((3, 4), (4, 4))),
    ((16, 16), (0.25,),            ((4, 4),)),
)
#: pinned per-mapper max TED gap vs the proven ILP optimum over the seeded
#: corpora (seed 0).  Everything is deterministic — the engine, HiGHS, the
#: corpora — so these are exact claims, not statistical bounds; a regression
#: in either mapper moves the measured max and fails the gate.
GAP_GATE_BOUNDS = {"hybrid": 5.0, "bipartite": 12.0}
#: heuristic mappers measured against the oracle (rect/partition are
#: recorded but not gated: they trade quality for speed by design)
GAP_MAPPERS = ("hybrid", "bipartite", "rect", "partition")
GAP_WORKLOAD = "bert_base"          # end-to-end score probe workload


def _churn_events(rng: np.random.Generator, n_events: int
                  ) -> List[Tuple[str, Tuple[int, int], float]]:
    """A fully pre-drawn allocate/release schedule.  All randomness —
    including the release-victim draw (a uniform, scaled by the resident
    count at replay time) — is fixed up front, so the engine and legacy
    replays see the exact same schedule even when their allocation
    outcomes (and hence resident counts) diverge."""
    events = []
    for _ in range(n_events):
        shape = REQUEST_SHAPES[int(rng.integers(len(REQUEST_SHAPES)))]
        kind = "alloc" if rng.random() < 0.65 else "release"
        events.append((kind, shape, float(rng.random())))
    return events


def run_latency(rows: int, cols: int, n_events: int, seed: int,
                legacy_cap: Optional[int] = None) -> dict:
    """Replay the same churn schedule through both paths, timing the
    allocation solves.  ``legacy_cap`` bounds how many allocation events the
    (slow) reference path executes."""
    topo = mesh_2d(rows, cols)
    out = {"mesh": [rows, cols], "cores": rows * cols, "events": n_events}

    events = _churn_events(np.random.default_rng(seed), n_events)

    def replay(solve, release, n_alloc_cap):
        residents: List[frozenset] = []
        lats: List[float] = []
        teds: List[float] = []
        for kind, shape, victim_u in events:
            if kind == "release":
                if residents:
                    idx = min(int(victim_u * len(residents)),
                              len(residents) - 1)
                    release(residents.pop(idx))
                continue
            if n_alloc_cap is not None and len(lats) >= n_alloc_cap:
                break
            req = mesh_2d(*shape, base_id=100_000)
            t0 = time.perf_counter()
            result = solve(req)
            lats.append(time.perf_counter() - t0)
            if result is not None:
                teds.append(result.ted)
                residents.append(result.nodes)
        return lats, teds

    # full engine run: telemetry + latency over the whole churn (including
    # the late, fragmented states)
    engine = MappingEngine(topo)
    e_lats, e_teds = replay(
        lambda req: _alloc_engine(engine, req),
        engine.notify_release, None)

    # paired prefix: both paths timed on the SAME first `legacy_cap`
    # allocation events, so the speedup and TED claims compare like with like
    paired_engine = MappingEngine(topo)
    pe_lats, pe_teds = replay(
        lambda req: _alloc_engine(paired_engine, req),
        paired_engine.notify_release, legacy_cap)

    allocated: set = set()

    def legacy_solve(req):
        result = min_topology_edit_distance(topo, allocated, req)
        if result is not None:
            allocated.update(result.nodes)
        return result

    def legacy_release(nodes):
        allocated.difference_update(nodes)

    l_lats, l_teds = replay(legacy_solve, legacy_release, legacy_cap)

    out["engine_median_ms"] = round(float(np.median(e_lats)) * 1e3, 3)
    out["engine_p90_ms"] = round(float(np.percentile(e_lats, 90)) * 1e3, 3)
    out["engine_paired_median_ms"] = round(
        float(np.median(pe_lats)) * 1e3, 3)
    out["legacy_median_ms"] = round(float(np.median(l_lats)) * 1e3, 3)
    out["legacy_alloc_events"] = len(l_lats)
    out["engine_alloc_events"] = len(e_lats)
    out["median_speedup"] = round(
        out["legacy_median_ms"] / max(out["engine_paired_median_ms"], 1e-9),
        1)
    out["engine_mean_ted"] = round(float(np.mean(e_teds)), 3) if e_teds else 0.0
    out["engine_paired_mean_ted"] = round(
        float(np.mean(pe_teds)), 3) if pe_teds else 0.0
    out["legacy_mean_ted"] = round(float(np.mean(l_teds)), 3) if l_teds else 0.0
    out["engine_counters"] = engine.counters()
    return out


def _alloc_engine(engine: MappingEngine, req) -> Optional[object]:
    result = engine.map_request(req)
    if result is not None:
        engine.notify_allocate(result.nodes)
    return result


def run_quality(n_scenarios: int, seed: int) -> dict:
    """Randomized blocked sets on the 6x6 SIM config: engine TED must be
    equal-or-better than the reference on every scenario, on both the
    connected path and the relaxed (fragmented-fallback) path the scheduler
    actually uses (VNPUPolicy defaults require_connected=False)."""
    topo = mesh_2d(6, 6)
    rng = np.random.default_rng(seed)
    nodes = sorted(topo.node_attrs)
    worse = []
    compared = 0
    deltas = []
    for i in range(n_scenarios):
        frac = float(rng.uniform(0.0, 0.75))
        blocked = set(rng.choice(nodes, size=int(frac * len(nodes)),
                                 replace=False).tolist())
        shape = REQUEST_SHAPES[int(rng.integers(len(REQUEST_SHAPES)))]
        if shape[0] * shape[1] > len(nodes) - len(blocked):
            continue
        req = mesh_2d(*shape, base_id=100_000)
        for connected in (True, False):
            legacy = min_topology_edit_distance(
                topo, blocked, req, require_connected=connected)
            engine = MappingEngine(topo)
            engine.notify_allocate(blocked)
            got = engine.map_request(req, require_connected=connected)
            if legacy is None or got is None:
                if (legacy is None) != (got is None):
                    worse.append({
                        "scenario": i, "connected": connected,
                        "blocked": sorted(blocked), "shape": shape,
                        "legacy": None if legacy is None else legacy.ted,
                        "engine": None if got is None else got.ted})
                continue
            compared += 1
            deltas.append(got.ted - legacy.ted)
            if got.ted > legacy.ted + 1e-9:
                worse.append({"scenario": i, "connected": connected,
                              "blocked": sorted(blocked), "shape": shape,
                              "legacy": legacy.ted, "engine": got.ted})
    return {
        "mesh": [6, 6],
        "scenarios_compared": compared,
        "mean_ted_delta": round(float(np.mean(deltas)), 4) if deltas else 0.0,
        "worse_than_legacy": worse,
        "quality_equal_or_better": not worse,
    }


def run_gate(median_budget_s: float = GATE_MEDIAN_S) -> dict:
    """The CI smoke gate: sched 'mixed' trace on 16x16 through the engine."""
    from repro.sched import make_trace
    from repro.sched.policy import best_rect

    topo = mesh_2d(16, 16)
    engine = MappingEngine(topo)
    trace = make_trace("mixed")
    events = []
    for spec in trace:
        events.append((spec.arrival_s, 1, spec))
        events.append((spec.arrival_s + spec.duration_s, 0, spec))
    events.sort(key=lambda e: (e[0], e[1]))
    resident = {}
    lats = []
    for _, kind, spec in events:
        if kind == 0:
            nodes = resident.pop(spec.tid, None)
            if nodes is not None:
                engine.notify_release(nodes)
            continue
        req = mesh_2d(*best_rect(spec.n_cores), base_id=100_000)
        # time solve + allocate notification, matching run_latency's
        # per-allocation-event measure (region split cost included)
        t0 = time.perf_counter()
        result = _alloc_engine(engine, req)
        lats.append(time.perf_counter() - t0)
        if result is not None:
            resident[spec.tid] = result.nodes
    median = float(np.median(lats))
    return {
        "mesh": [16, 16], "trace": "mixed", "alloc_events": len(lats),
        "median_ms": round(median * 1e3, 3),
        "p90_ms": round(float(np.percentile(lats, 90)) * 1e3, 3),
        "budget_ms": median_budget_s * 1e3,
        "engine_counters": engine.counters(),
        "gate_ok": median <= median_budget_s,
    }


def _e2e_interval(topo, result, hw) -> float:
    """End-to-end score of a placement: simulated iteration interval of the
    probe workload on the placed cores (cycles; lower is better)."""
    from repro.core import simulator as S
    from repro.core.workloads import get_workload
    rep = S.simulate(get_workload(GAP_WORKLOAD), sorted(result.nodes),
                     topo, hw)
    return float(rep.interval_cycles)


def run_gap_gate(seed: int, budget_s: float, bench_out: Optional[str]) -> dict:
    """The optimality-gap harness: seeded corpora, one exact (``ilp``)
    solve per scenario, per-mapper TED and end-to-end gaps vs the proven
    optimum.  ``budget_s`` bounds the wall clock — corpora past the budget
    are dropped *loudly* (reported in the summary), never silently."""
    from repro.core import simulator as S

    rng = np.random.default_rng(seed)
    hw = S.SIM_CONFIG
    t_start = time.perf_counter()
    rows = []            # BENCH entries: one per (mesh, mapper)
    violations = []      # mapper beat a proven optimum (soundness failure)
    dropped = []         # corpora skipped on wall budget
    scenarios_total = proven_total = 0
    gaps = {m: [] for m in GAP_MAPPERS}       # proven-scenario TED gaps

    for (r, c), fracs, shapes in GAP_CORPORA:
        if time.perf_counter() - t_start > budget_s:
            dropped.append(f"{r}x{c}")
            continue
        topo = mesh_2d(r, c)
        nodes = sorted(topo.node_attrs)
        per_mapper = {m: {"ted_gaps": [], "e2e_gaps": []}
                      for m in GAP_MAPPERS}
        n_scen = n_proven = 0
        t_mesh = time.perf_counter()
        for frac in fracs:
            blocked = set(rng.choice(
                nodes, size=int(frac * len(nodes)),
                replace=False).tolist())
            free = frozenset(nodes) - blocked
            for shape in shapes:
                if shape[0] * shape[1] > len(free):
                    continue
                req = mesh_2d(*shape, base_id=100_000)
                ilp_eng = MappingEngine(topo, mapper="ilp")
                opt = ilp_eng.map_request(req, require_connected=False,
                                          free_override=free)
                if opt is None:
                    continue
                n_scen += 1
                if not opt.optimal:
                    continue           # gap undefined without a certificate
                n_proven += 1
                e2e_opt = _e2e_interval(topo, opt, hw)
                for m in GAP_MAPPERS:
                    eng = MappingEngine(topo, mapper=m)
                    got = eng.map_request(req, require_connected=False,
                                          free_override=free)
                    if got is None:
                        continue
                    gap = got.ted - opt.ted
                    if gap < -1e-9:
                        violations.append({
                            "mesh": f"{r}x{c}", "shape": list(shape),
                            "mapper": m, "mapper_ted": got.ted,
                            "ilp_ted": opt.ted})
                    per_mapper[m]["ted_gaps"].append(gap)
                    per_mapper[m]["e2e_gaps"].append(
                        (_e2e_interval(topo, got, hw) - e2e_opt)
                        / max(e2e_opt, 1e-9))
        wall = time.perf_counter() - t_mesh
        scenarios_total += n_scen
        proven_total += n_proven
        for m in GAP_MAPPERS:
            tg, eg = per_mapper[m]["ted_gaps"], per_mapper[m]["e2e_gaps"]
            gaps[m].extend(tg)
            rows.append({
                "trace": "gap-corpus", "mesh": f"{r}x{c}-gap",
                "mode": f"gap-{m}", "scenarios": n_scen, "proven": n_proven,
                "max_ted_gap": round(max(tg), 3) if tg else 0.0,
                "mean_ted_gap": round(float(np.mean(tg)), 3) if tg else 0.0,
                "max_e2e_gap": round(max(eg), 4) if eg else 0.0,
                "mean_e2e_gap": round(float(np.mean(eg)), 4) if eg else 0.0,
                "wall_s": round(wall, 2),
            })

    bound_checks = {
        m: {"max_ted_gap": round(max(gaps[m]), 3) if gaps[m] else 0.0,
            "bound": b,
            "ok": (max(gaps[m]) if gaps[m] else 0.0) <= b + 1e-9}
        for m, b in GAP_GATE_BOUNDS.items()}
    report = {
        "seed": seed,
        "scenarios": scenarios_total,
        "proven": proven_total,
        "proven_fraction": round(proven_total / max(scenarios_total, 1), 3),
        "budget_s": budget_s,
        "dropped_corpora": dropped,
        "no_mapper_beats_oracle": not violations,
        "violations": violations,
        "bounds": bound_checks,
        "gate_ok": (not violations and proven_total > 0
                    and all(v["ok"] for v in bound_checks.values())),
    }
    if bench_out:
        sys.path.insert(0, str(Path(__file__).resolve().parent))
        from cluster_sim import _write_bench
        _write_bench("gap-gate", report, rows, bench_out)
    report["entries"] = rows
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--events", type=int, default=160,
                    help="churn events per latency mesh")
    ap.add_argument("--legacy-cap", type=int, default=40,
                    help="max allocation events timed on the legacy path")
    ap.add_argument("--scenarios", type=int, default=40,
                    help="quality scenarios on the 6x6 config")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--big", action="store_true",
                    help="also run the 32x32 (1024-core) latency mesh")
    ap.add_argument("--gate", action="store_true",
                    help="CI mode: only the 16x16 mixed-trace latency gate")
    ap.add_argument("--gap-gate", action="store_true",
                    help="CI mode: optimality-gap sweep vs the ilp oracle; "
                         "merges rows into BENCH_cluster_sim.json")
    ap.add_argument("--gap-budget-s", type=float, default=900.0,
                    help="wall budget for the --gap-gate sweep; corpora "
                         "past it are dropped (and reported)")
    ap.add_argument("--bench-out",
                    default=str(Path(__file__).resolve().parent.parent
                                / "BENCH_cluster_sim.json"),
                    help="BENCH json to merge --gap-gate rows into "
                         "('' to skip writing)")
    ap.add_argument("--json", action="store_true", help="machine output")
    args = ap.parse_args(argv)

    if args.gap_gate:
        rep = run_gap_gate(args.seed, args.gap_budget_s, args.bench_out)
        if args.json:
            print(json.dumps(rep, indent=2))
        else:
            for e in rep["entries"]:
                print(f"{e['mesh']:>10} {e['mode']:<14} "
                      f"proven {e['proven']}/{e['scenarios']}  "
                      f"ted gap max {e['max_ted_gap']} "
                      f"mean {e['mean_ted_gap']}  "
                      f"e2e gap max {e['max_e2e_gap']:.2%}")
            for m, v in rep["bounds"].items():
                print(f"bound {m}: max {v['max_ted_gap']} <= {v['bound']} "
                      f"-> {'OK' if v['ok'] else 'FAIL'}")
            if rep["dropped_corpora"]:
                print(f"DROPPED on wall budget: {rep['dropped_corpora']}")
            print(f"gap-gate: {rep['proven']}/{rep['scenarios']} proven, "
                  f"no_mapper_beats_oracle="
                  f"{rep['no_mapper_beats_oracle']} -> "
                  f"{'OK' if rep['gate_ok'] else 'FAIL'}")
        return 0 if rep["gate_ok"] else 1

    if args.gate:
        gate = run_gate()
        print(json.dumps(gate, indent=2) if args.json else
              f"gate: median={gate['median_ms']}ms "
              f"p90={gate['p90_ms']}ms over {gate['alloc_events']} events "
              f"(budget {gate['budget_ms']:.0f}ms) "
              f"hit_rate={gate['engine_counters']['hit_rate']:.2%} -> "
              f"{'OK' if gate['gate_ok'] else 'FAIL'}")
        return 0 if gate["gate_ok"] else 1

    meshes = [(16, 16)] + ([(32, 32)] if args.big else [])
    latency = [run_latency(r, c, args.events, args.seed,
                           legacy_cap=args.legacy_cap) for r, c in meshes]
    quality = run_quality(args.scenarios, args.seed)
    claims = {
        "median_speedup_geq_10x_at_256": any(
            m["cores"] >= 256 and m["median_speedup"] >= 10.0
            for m in latency),
        "quality_equal_or_better_6x6": quality["quality_equal_or_better"],
    }
    if args.json:
        print(json.dumps({"latency": latency, "quality": quality,
                          "claims": claims}, indent=2))
        return 0 if all(claims.values()) else 1

    for m in latency:
        print(f"{m['mesh'][0]}x{m['mesh'][1]} ({m['cores']} cores): "
              f"engine median {m['engine_median_ms']}ms over full churn "
              f"(p90 {m['engine_p90_ms']}ms, {m['engine_alloc_events']} "
              f"allocs, mean TED {m['engine_mean_ted']}); paired first-"
              f"{m['legacy_alloc_events']} events: engine "
              f"{m['engine_paired_median_ms']}ms / TED "
              f"{m['engine_paired_mean_ted']} vs legacy "
              f"{m['legacy_median_ms']}ms / TED {m['legacy_mean_ted']} "
              f"-> {m['median_speedup']}x speedup")
        ec = m["engine_counters"]
        print(f"   engine: hit_rate={ec['hit_rate']:.2%} "
              f"escalations={ec['exact_escalations']} "
              f"candidates={ec['candidates_evaluated']}")
    print(f"6x6 quality: {quality['scenarios_compared']} scenarios, "
          f"mean TED delta {quality['mean_ted_delta']} "
          f"({'engine never worse' if quality['quality_equal_or_better'] else quality['worse_than_legacy']})")
    print(f"claims: {json.dumps(claims)}")
    return 0 if all(claims.values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
