"""Benchmark harness: one function per paper table/figure + kernel
micro-bench + roofline report.  Prints ``name,us_per_call,derived`` CSV rows
plus per-figure data tables and paper-claim comparisons.
"""
from __future__ import annotations

import json
import os
import sys
import time


def _time_us(fn, *args, reps=3, **kw):
    fn(*args, **kw)  # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    return (time.perf_counter() - t0) / reps * 1e6, out


def run_paper_figures() -> None:
    from . import paper_figures as PF
    print("name,us_per_call,derived")
    for name, fn in PF.ALL_FIGS.items():
        us, (rows, claims) = _time_us(fn, reps=1)
        print(f"{name},{us:.0f},{json.dumps(claims)}")
    print()
    for name, fn in PF.ALL_FIGS.items():
        rows, claims = fn()
        print(f"== {name} ==")
        if rows:
            keys = sorted({k for r in rows for k in r})
            print(",".join(keys))
            for r in rows:
                print(",".join(str(r.get(k, "")) for k in keys))
        print(f"claims: {json.dumps(claims)}")
        print()


def run_kernel_bench() -> None:
    """Wall-time microbench of the jnp oracles (CPU) — the Pallas kernels
    target TPU and are validated in interpret mode by the tests."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ref

    print("== kernels (CPU oracle timings) ==")
    print("name,us_per_call,derived")
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (512, 512), jnp.float32)
    w = jax.random.normal(k, (512, 512), jnp.float32)
    f = jax.jit(ref.matmul_ref)
    us, _ = _time_us(lambda: jax.block_until_ready(f(x, w)))
    print(f"matmul_ref_512,{us:.0f},{{\"gflops\": "
          f"{2 * 512**3 / (us / 1e6) / 1e9:.1f}}}")
    q = jax.random.normal(k, (1, 4, 512, 64), jnp.float32)
    fa = jax.jit(lambda q: ref.flash_attention_ref(q, q, q))
    us, _ = _time_us(lambda: jax.block_until_ready(fa(q)))
    print(f"attention_ref_512,{us:.0f},{{}}")
    print()


def run_roofline_report() -> None:
    """Aggregate the dry-run JSON results into the §Roofline table."""
    results_dir = os.environ.get("DRYRUN_RESULTS",
                                 "/root/repo/results/dryrun")
    if not os.path.isdir(results_dir):
        print("== roofline: no dry-run results yet ==")
        return
    rows = []
    for fn in sorted(os.listdir(results_dir)):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(results_dir, fn)) as f:
            cell = json.load(f)
        if cell.get("status") == "skip":
            rows.append({"arch": cell["arch"], "shape": cell["shape"],
                         "mesh": cell["mesh"], "status": "SKIP",
                         "note": cell["reason"]})
            continue
        r = cell.get("roofline")
        if not r:
            continue
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": "ok",
            "t_compute_ms": round(r["t_compute"] * 1e3, 3),
            "t_memory_ms": round(r["t_memory"] * 1e3, 3),
            "t_collective_ms": round(r["t_collective"] * 1e3, 3),
            "bottleneck": r["bottleneck"],
            "useful_flops_ratio": round(r["useful_flops_ratio"], 3),
            "mfu": round(r["mfu"], 4),
        })
    print("== roofline (from dry-run) ==")
    if rows:
        keys = ["arch", "shape", "mesh", "status", "t_compute_ms",
                "t_memory_ms", "t_collective_ms", "bottleneck",
                "useful_flops_ratio", "mfu", "note"]
        print(",".join(keys))
        for r in rows:
            print(",".join(str(r.get(k, "")) for k in keys))
    print()


def main() -> None:
    run_paper_figures()
    run_kernel_bench()
    run_roofline_report()


if __name__ == "__main__":
    main()
