"""Shared ``--profile`` support for the benchmark CLIs.

Wraps a run in :mod:`cProfile` and prints the top cumulative hotspots, so
perf PRs start from measurements instead of guesses:

    PYTHONPATH=src python benchmarks/serving_sim.py --profile ...
    PYTHONPATH=src python benchmarks/cluster_sim.py --profile ...
    PYTHONPATH=src python benchmarks/fleet_sim.py   --profile ...

The CLIs use the re-entry pattern: parse args, and when ``--profile`` is
set, re-invoke their own ``main`` (flag stripped) inside ``profiled()`` —
every code path of the benchmark is covered without restructuring it.
"""
from __future__ import annotations

import contextlib
import cProfile
import pstats
import sys
from typing import Iterator, List, Optional, Sequence

#: how many cumulative-time rows the report prints
TOP_N = 20


@contextlib.contextmanager
def profiled(top_n: int = TOP_N, stream=None) -> Iterator[cProfile.Profile]:
    """Profile the with-block and print the ``top_n`` hottest functions by
    cumulative time (file/line noise stripped) when it exits."""
    prof = cProfile.Profile()
    prof.enable()
    try:
        yield prof
    finally:
        prof.disable()
        out = stream or sys.stdout
        print(f"\n--- cProfile: top {top_n} by cumulative time ---",
              file=out)
        stats = pstats.Stats(prof, stream=out)
        stats.strip_dirs().sort_stats("cumulative").print_stats(top_n)


def strip_profile_flag(argv: Optional[Sequence[str]]) -> List[str]:
    """The argv to re-enter ``main`` with: ``--profile`` removed."""
    args = list(argv) if argv is not None else sys.argv[1:]
    return [a for a in args if a != "--profile"]
