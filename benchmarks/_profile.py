"""Shared ``--profile`` / ``--profile-out`` support for the benchmark CLIs.

Wraps a run in :mod:`cProfile` and prints the top cumulative hotspots, so
perf PRs start from measurements instead of guesses:

    PYTHONPATH=src python benchmarks/serving_sim.py --profile ...
    PYTHONPATH=src python benchmarks/cluster_sim.py --profile ...
    PYTHONPATH=src python benchmarks/fleet_sim.py   --profile ...
    PYTHONPATH=src python benchmarks/chaos_sim.py   --profile ...

``--profile-out FILE`` additionally dumps the raw :mod:`pstats` data for
offline analysis (``snakeviz FILE`` / ``pstats.Stats(FILE)``).

The CLIs use the re-entry pattern: parse args, and when profiling is
requested, re-invoke their own ``main`` (flags stripped) through
:func:`run_profiled` — every code path of the benchmark is covered
without restructuring it, and the child run's exit code propagates so a
profiled gate still fails CI when the gate fails.
"""
from __future__ import annotations

import contextlib
import cProfile
import pstats
import sys
from typing import Callable, Iterator, List, Optional, Sequence

#: how many cumulative-time rows the report prints
TOP_N = 20


@contextlib.contextmanager
def profiled(top_n: int = TOP_N, stream=None,
             profile_out: Optional[str] = None
             ) -> Iterator[cProfile.Profile]:
    """Profile the with-block and print the ``top_n`` hottest functions by
    cumulative time (file/line noise stripped) when it exits; dump the raw
    pstats data to ``profile_out`` when given."""
    prof = cProfile.Profile()
    prof.enable()
    try:
        yield prof
    finally:
        prof.disable()
        out = stream or sys.stdout
        print(f"\n--- cProfile: top {top_n} by cumulative time ---",
              file=out)
        stats = pstats.Stats(prof, stream=out)
        stats.strip_dirs().sort_stats("cumulative").print_stats(top_n)
        if profile_out:
            prof.dump_stats(profile_out)
            print(f"raw profile written to {profile_out}", file=out)


def run_profiled(main_fn: Callable[[List[str]], Optional[int]],
                 argv: List[str],
                 profile_out: Optional[str] = None) -> int:
    """Re-enter ``main_fn(argv)`` under the profiler and return the child
    run's exit code (``None`` normalized to 0), so profiled gate runs keep
    their pass/fail semantics."""
    with profiled(profile_out=profile_out):
        rc = main_fn(argv)
    return 0 if rc is None else int(rc)


def strip_profile_flags(argv: Optional[Sequence[str]]) -> List[str]:
    """The argv to re-enter ``main`` with: ``--profile`` and
    ``--profile-out FILE`` (either spelling) removed."""
    args = list(argv) if argv is not None else sys.argv[1:]
    out: List[str] = []
    skip = False
    for a in args:
        if skip:
            skip = False
            continue
        if a == "--profile":
            continue
        if a == "--profile-out":
            skip = True
            continue
        if a.startswith("--profile-out="):
            continue
        out.append(a)
    return out


def strip_profile_flag(argv: Optional[Sequence[str]]) -> List[str]:
    """Back-compat alias for :func:`strip_profile_flags`."""
    return strip_profile_flags(argv)
