"""Reproductions of the paper's tables/figures on the analytical simulator.

One function per artifact; each returns rows (list of dicts) and a
`claims` dict comparing our numbers against the paper's headline values.
`benchmarks/run.py` prints all of them as CSV.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.core import (DenseRoutingTable, Hypervisor, InstructionRouter,
                        MIGPartitioner, NoCRouter, RoutingTableDirectory,
                        VNPURequest, mesh_2d, rt_config_cost,
                        min_topology_edit_distance, straightforward_mapping)
from repro.core import simulator as S
from repro.core import workloads as W


# ---------------------------------------------------------------------------
# Fig 11 — routing-table configuration latency
# ---------------------------------------------------------------------------

def fig11_rt_config() -> Tuple[List[Dict], Dict]:
    rows = []
    for n in (4, 8, 16, 32, 64, 128):
        c = rt_config_cost(n)
        rows.append({"bench": "fig11", "cores": n, **c})
    claims = {"total_setup_cycles_under_1000_at_128_cores":
              rows[-1]["total_cycles"] < 1000}
    return rows, claims


# ---------------------------------------------------------------------------
# Fig 12 — instruction dispatch latency (IBUS vs instr-NoC) vs kernel time
# ---------------------------------------------------------------------------

def fig12_dispatch() -> Tuple[List[Dict], Dict]:
    hw = S.FPGA_CONFIG
    topo = hw.topo()
    d = RoutingTableDirectory()
    d.install(DenseRoutingTable(1, {i: i for i in range(8)}))
    rows = []
    for transport in ("ibus", "inoc"):
        ir = InstructionRouter(d, topo, transport=transport)
        for core in range(8):
            ir._last = None
            r = ir.dispatch(1, core)
            rows.append({"bench": "fig12", "transport": transport,
                         "core": core, "cycles": r.cycles})
    # two reference NPU instructions on the FPGA config (16x16 SA)
    conv = W.conv("conv3x3", 56, 56, 64, 64, 3)
    mm = W.fc("matmul", 512, 512, tokens=512)
    t_conv = S.layer_compute_cycles(conv, hw)
    t_mm = S.layer_compute_cycles(mm, hw)
    rows.append({"bench": "fig12", "transport": "exec", "core": -1,
                 "cycles": t_conv, "op": "conv"})
    rows.append({"bench": "fig12", "transport": "exec", "core": -1,
                 "cycles": t_mm, "op": "matmul"})
    worst_dispatch = max(r["cycles"] for r in rows if r["core"] >= 0)
    claims = {"dispatch_2_to_3_orders_below_exec":
              t_conv / worst_dispatch > 100 and t_mm / worst_dispatch > 100}
    return rows, claims


# ---------------------------------------------------------------------------
# Table 3 — NoC virtualization overhead (send/receive vs vSend/vReceive)
# ---------------------------------------------------------------------------

def table3_noc() -> Tuple[List[Dict], Dict]:
    hw = S.FPGA_CONFIG
    topo = hw.topo()
    rt = DenseRoutingTable(1, {i: i for i in range(8)})
    noc = NoCRouter(topo)
    rows = []
    ovhs = []
    for n_packets in (2, 10, 20, 30):
        base_s = base_r = virt_s = virt_r = 0
        for p in range(n_packets):
            b = noc.route(rt, 0, 7, range(8), confined=False,
                          virtualized=False)
            v = noc.route(rt, 0, 7, range(8), confined=False,
                          virtualized=True)
            base_s += b.send_cycles
            base_r += b.recv_cycles
            virt_s += v.send_cycles
            virt_r += v.recv_cycles
        rows.append({"bench": "table3", "packets": n_packets,
                     "send": base_s, "recv": base_r,
                     "vsend": virt_s, "vrecv": virt_r})
        ovhs.append((virt_s - base_s) / base_s)
        ovhs.append((virt_r - base_r) / base_r)
    claims = {"noc_virt_overhead_1_2_percent":
              max(ovhs) <= 0.03, "max_overhead": round(max(ovhs), 4)}
    return rows, claims


# ---------------------------------------------------------------------------
# Fig 13 — broadcast: vRouter vs memory synchronization
# ---------------------------------------------------------------------------

def fig13_broadcast() -> Tuple[List[Dict], Dict]:
    hw = S.SIM_CONFIG
    rows = []
    ratios = []
    kernels = [("matmul", W.fc("mm", 1024, 1024, tokens=1024), 2 << 20),
               ("conv", W.conv("cv", 56, 56, 256, 256, 3), 1 << 20)]
    for name, layer, bytes_out in kernels:
        comp = S.layer_compute_cycles(layer, hw)
        for n in (1, 2, 4):
            v = S.broadcast_cycles_vrouter(bytes_out, n, 3.0, hw)
            m = S.broadcast_cycles_memsync(bytes_out, n, hw,
                                           hbm_concurrency=4)
            rows.append({"bench": "fig13", "kernel": name, "ratio_1_to": n,
                         "comp": comp, "vrouter": v, "memsync": m,
                         "speedup": round(m / v, 2)})
            ratios.append(m / v)
    avg = sum(ratios) / len(ratios)
    claims = {"avg_speedup_vs_paper_4.24x": round(avg, 2),
              "broadcast_overlappable_under_vrouter":
              all(r["vrouter"] < r["comp"] for r in rows)}
    return rows, claims


# ---------------------------------------------------------------------------
# Fig 14 — memory translation: physical vs page(4/32) vs vChunk range(4)
# ---------------------------------------------------------------------------

def fig14_translation() -> Tuple[List[Dict], Dict]:
    hw = S.SIM_CONFIG
    rows = []
    models = ["resnet18", "resnet50", "mobilenet", "alexnet", "bert_base",
              "googlenet"]
    page4, page32, rng4 = [], [], []
    for m in models:
        g = W.get_workload(m)
        per_core = max(g.total_weight_bytes // hw.n_tiles, 1 << 20)
        base = S.simulate_weight_dma(per_core, hw, translation="physical",
                                     bw_share=1 / hw.n_tiles)
        row = {"bench": "fig14", "model": m, "weight_mb":
               round(g.total_weight_bytes / 2**20, 1)}
        for name, tr, ent, acc in (("page4", "page", 4, page4),
                                   ("page32", "page", 32, page32),
                                   ("range4", "range", 4, rng4)):
            r = S.simulate_weight_dma(per_core, hw, translation=tr,
                                      tlb_entries=ent,
                                      bw_share=1 / hw.n_tiles)
            norm = base.total_cycles / r.total_cycles
            row[name + "_normperf"] = round(norm, 4)
            acc.append(1 - norm)
        rows.append(row)
    claims = {
        "page4_overhead_avg(paper ~20%)": round(sum(page4) / len(page4), 3),
        "page32_overhead_avg(paper >=9.2%)":
            round(sum(page32) / len(page32), 3),
        "range4_overhead_avg(paper <=4.3%)": round(sum(rng4) / len(rng4), 4),
        "range_beats_page": max(rng4) < min(page4),
    }
    return rows, claims


# ---------------------------------------------------------------------------
# Fig 15 — vNPU vs UVM-based virtual NPUs (single + multi instance)
# ---------------------------------------------------------------------------

def fig15_uvm() -> Tuple[List[Dict], Dict]:
    hw = S.SIM_CONFIG
    topo = hw.topo()
    rows = []
    cores = [0, 1, 6, 7]
    tra = W.get_workload("transformer")
    res = W.get_workload("resnet50")
    r_t_df = S.simulate(tra, cores, topo, hw)
    r_t_uv = S.simulate(tra, cores, topo, hw, comm="uvm")
    r_r_df = S.simulate(res, cores, topo, hw)
    r_r_uv = S.simulate(res, cores, topo, hw, comm="uvm")
    rows += [{"bench": "fig15", "wl": "transformer", "mode": "vnpu",
              "fps": round(r_t_df.fps, 1)},
             {"bench": "fig15", "wl": "transformer", "mode": "uvm",
              "fps": round(r_t_uv.fps, 1)},
             {"bench": "fig15", "wl": "resnet", "mode": "vnpu",
              "fps": round(r_r_df.fps, 1)},
             {"bench": "fig15", "wl": "resnet", "mode": "uvm",
              "fps": round(r_r_uv.fps, 1)}]
    # multi-instance interference: resnet + transformer concurrently
    r_r_uv2 = S.simulate(res, cores, topo, hw, comm="uvm", hbm_concurrency=2)
    r_t_uv2 = S.simulate(tra, [2, 3, 8, 9], topo, hw, comm="uvm",
                         hbm_concurrency=2)
    r_r_df2 = S.simulate(res, cores, topo, hw)  # vNPU: no HBM contention
    uvm_degr = 1 - (r_r_uv2.fps / r_r_uv.fps +
                    r_t_uv2.fps / r_t_uv.fps) / 2
    rows.append({"bench": "fig15", "wl": "multi", "mode": "uvm_degradation",
                 "fps": round(uvm_degr, 3)})
    claims = {
        "transformer_speedup(paper 2.29x)": round(r_t_df.fps / r_t_uv.fps, 2),
        "resnet_speedup(paper 1.054x)": round(r_r_df.fps / r_r_uv.fps, 3),
        "uvm_multiinstance_degradation(paper ~24%)": round(uvm_degr, 3),
        "vnpu_multiinstance_interference_negligible":
            abs(r_r_df2.fps - r_r_df.fps) / r_r_df.fps < 0.01,
    }
    return rows, claims


# ---------------------------------------------------------------------------
# Fig 16 — vNPU vs MIG (+ bare-metal overhead + warm-up)
# ---------------------------------------------------------------------------

def fig16_mig() -> Tuple[List[Dict], Dict]:
    hw = S.SIM_CONFIG
    topo = hw.topo()
    rows = []
    vs_mig = {}
    # GPT2-small always on vNPU1 (12 cores); the other task varies
    gpt_small_cores = 12
    for wl_name, need in (("gpt2_small", 12), ("gpt2_medium", 24),
                          ("gpt2_large", 36 - gpt_small_cores),
                          ("resnet18", 24), ("resnet34", 24)):
        g = W.get_workload(wl_name)
        free = 36 - gpt_small_cores
        n_v = min(need, free)
        # vNPU: exact core count, arbitrary (similar) topology
        r_v = S.simulate(g, list(range(n_v)), topo, hw,
                         virtualization_overhead=0.005)
        # MIG: fixed partitions (18|18): insufficient cores -> TDM
        part = 18 if need <= 18 else 18
        r_m = S.simulate(g, list(range(need)), topo, hw,
                         tdm_physical=part if need > part else None)
        # bare metal (no virtualization)
        r_b = S.simulate(g, list(range(n_v)), topo, hw)
        rows.append({"bench": "fig16", "wl": wl_name,
                     "vnpu_fps": round(r_v.fps, 2),
                     "mig_fps": round(r_m.fps, 2),
                     "bare_fps": round(r_b.fps, 2),
                     "speedup_vs_mig": round(r_v.fps / r_m.fps, 2),
                     "virt_overhead": round(1 - r_v.fps / r_b.fps, 4),
                     "warmup_ms": round(r_v.warmup_cycles / hw.freq_hz * 1e3,
                                        2)})
        vs_mig[wl_name] = r_v.fps / r_m.fps
    claims = {
        "gpt_speedup_max(paper up to 1.92x)":
            round(max(vs_mig["gpt2_large"], vs_mig["gpt2_medium"]), 2),
        "resnet_speedup(paper avg 1.28x)":
            round((vs_mig["resnet18"] + vs_mig["resnet34"]) / 2, 2),
        "virt_overhead_under_1pct":
            all(r["virt_overhead"] < 0.01 for r in rows),
    }
    return rows, claims


# ---------------------------------------------------------------------------
# Fig 18 — topology mapping strategies (zig-zag vs similar)
# ---------------------------------------------------------------------------

def fig18_mapping() -> Tuple[List[Dict], Dict]:
    # DCRA is a *chiplet* simulator: inter-chiplet links are far narrower
    # than the on-chip NoC, which is what makes mapping locality matter
    import dataclasses as _dc
    hw = _dc.replace(S.SIM_CONFIG, noc_link_bytes_per_cycle=32)
    topo = hw.topo()
    # pre-allocate corners (the paper's 'initial state is not empty')
    blocked = {0, 1, 6, 30, 34, 35}
    rows = []
    gains = {}
    for wl_name, n_cores in (("resnet18", 11), ("resnet18", 28),
                             ("resnet34", 11), ("resnet34", 28),
                             ("gpt2_small", 12), ("gpt2_small", 24)):
        g = W.get_workload(wl_name)
        req = mesh_2d(*_best_rect(n_cores), base_id=1000)
        sim = min_topology_edit_distance(topo, blocked, req)
        zig = straightforward_mapping(topo, blocked, req)
        r_sim = S.simulate(g, sorted(sim.nodes), topo, hw)
        r_zig = S.simulate(g, sorted(zig.nodes), topo, hw)
        gain = r_sim.fps / r_zig.fps
        rows.append({"bench": "fig18", "wl": wl_name, "cores": n_cores,
                     "similar_fps": round(r_sim.fps, 2),
                     "zigzag_fps": round(r_zig.fps, 2),
                     "gain": round(gain, 3),
                     "ted_similar": sim.ted, "ted_zigzag": zig.ted})
        gains[(wl_name, n_cores)] = gain
    claims = {
        # honest divergence notes: (1) our analytic pipeline saturates on the
        # same bottleneck stage at 28 cores, so the paper's 'gain grows with
        # cores' (40% @28c) does not reproduce; (2) with the full-duplex
        # (directional) link model, opposing pipeline flows no longer
        # contend, so the CNN mapping gain shrinks to ~1% while the ring
        # all-reduce — whose serialization scales with avg hop distance —
        # becomes the mapping-sensitive workload.
        "resnet_gain_max(paper up to ~1.4x; ~1.01x under full-duplex links)":
            round(max(gains[(w, c)] for (w, c) in gains
                      if w.startswith("resnet")), 2),
        # note: zigzag TED uses a naive assignment while similar-mapping
        # uses the bipartite-approximate optimum; both are upper bounds, so
        # we report the values and claim only on achieved FPS
        "ted_pairs": [(r["ted_similar"], r["ted_zigzag"]) for r in rows],
        "similar_fps_never_worse":
            all(r["gain"] >= 0.999 for r in rows),
        "mapping_gain_observed_somewhere":
            max(gains.values()) > 1.1,
        "allreduce_hop_sensitive_under_full_duplex":
            max(gains[("gpt2_small", 12)], gains[("gpt2_small", 24)]) >=
            max(gains[(w, c)] for (w, c) in gains if w.startswith("resnet")),
    }
    return rows, claims


def _best_rect(n: int):
    best = (1, n)
    for r in range(1, int(n ** 0.5) + 1):
        if n % r == 0:
            best = (r, n // r)
    return best


# ---------------------------------------------------------------------------
# Fig 19 — hardware cost (LUT/FF) analytical model
# ---------------------------------------------------------------------------

# Cost coefficients per bit of SRAM-resident table state (from Xilinx
# synthesis rules of thumb: 1 FF/bit, LUTs for compare/mux trees).
# whole-SoC baseline for an 8-tile Gemmini Chipyard build on a large FPGA
BASE_NPU_LUT = 450_000
BASE_NPU_FF = 380_000


def fig19_hwcost() -> Tuple[List[Dict], Dict]:
    rows = []
    from repro.core.routing_table import CompactRoutingTable
    from repro.core.vchunk import RTT_ENTRY_BITS
    # vNPU: vRouter (128-entry RT) + vChunk (4-entry range TLB per core)
    rt_bits = 128 * 32
    rtt_bits = 4 * RTT_ENTRY_BITS
    vnpu_ff = rt_bits + 8 * rtt_bits + 512          # regs: hyper-REG etc.
    vnpu_lut = int(0.6 * vnpu_ff)                    # mux/compare trees
    # Kim's (AuRORA): UVM page-TLB + IOMMU walker state
    kim_ff = 8 * 32 * 64 + 2048
    kim_lut = int(0.8 * kim_ff)
    for name, lut, ff in (("vNPU", vnpu_lut, vnpu_ff),
                          ("Kims_UVM", kim_lut, kim_ff)):
        rows.append({"bench": "fig19", "design": name,
                     "extra_lut": lut, "extra_ff": ff,
                     "lut_pct": round(100 * lut / BASE_NPU_LUT, 2),
                     "ff_pct": round(100 * ff / BASE_NPU_FF, 2)})
    vnpu = rows[0]
    claims = {"vnpu_under_~2pct_luts_ffs(paper ~2%)":
              vnpu["lut_pct"] <= 3 and vnpu["ff_pct"] <= 3,
              "vnpu_cheaper_than_kims_uvm":
              vnpu["extra_ff"] <= rows[1]["extra_ff"]}
    return rows, claims


ALL_FIGS = {
    "fig11": fig11_rt_config,
    "fig12": fig12_dispatch,
    "table3": table3_noc,
    "fig13": fig13_broadcast,
    "fig14": fig14_translation,
    "fig15": fig15_uvm,
    "fig16": fig16_mig,
    "fig18": fig18_mapping,
    "fig19": fig19_hwcost,
}
