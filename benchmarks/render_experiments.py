"""Render EXPERIMENTS.md from results/dryrun/*.json + the paper-figure
benchmarks.  Rerun after any dry-run/perf change:

    PYTHONPATH=src python -m benchmarks.render_experiments
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

RESULTS = "/root/repo/results/dryrun"
OUT = "/root/repo/EXPERIMENTS.md"

HEADER = """# EXPERIMENTS — vNPU (ISCA'25) reproduction + multi-pod framework

Three parts: (1) reproduction of the paper's own tables/figures on the
analytical simulator; (2) the multi-pod dry-run over all assigned
(architecture x shape x mesh) cells; (3) the roofline analysis and the
performance-iteration log (paper-faithful baseline vs beyond-paper
recipes, recorded separately).

Hardware model: TPU v5e — 197 TFLOP/s bf16/chip, 819 GB/s HBM,
~50 GB/s/link ICI.  Production mesh 16x16 = 256 chips/pod ("data","model");
multi-pod 2x16x16 = 512 chips ("pod","data","model").

## §Repro — paper-claim scoreboard

Every paper figure/table is reproduced by `benchmarks/paper_figures.py`
(driven by the DCRA-style simulator in `repro/core/simulator.py`; the
translation experiments drive the *real* vChunk/page TLB structures).
`PYTHONPATH=src python -m benchmarks.run` regenerates this.

| paper artifact | paper claim | ours | verdict |
|---|---|---|---|
| Fig 11 RT config | few hundred cycles | 640 cycles @128 cores | ok |
| Fig 12 dispatch | 2-3 orders below kernel exec | >100x below | ok |
| Table 3 NoC virt overhead | 1-2% | <=1.04% max | ok |
| Fig 13 broadcast vRouter vs memsync | 4.24x avg | ~5.0x avg (1:1-1:4, multi-tenant HBM) | ok |
| Fig 14 page-TLB(4) overhead | ~20% | 16.9% avg | ok |
| Fig 14 page-TLB(32) overhead | >=9.2% | 8.6% avg | ok (trend) |
| Fig 14 vChunk range(4) overhead | <=4.3% | ~0.01% | ok (stronger: buddy blocks -> few ranges) |
| Fig 15 transformer vNPU vs UVM | 2.29x | 1.84x | direction ok |
| Fig 15 resnet vNPU vs UVM | 1.054x | 1.11x | ok |
| Fig 15 UVM multi-instance degradation | ~24% | 22.9% | ok |
| Fig 16 GPT vs MIG (TDM) | up to 1.92x | 2.00x | ok |
| Fig 16 resnet vs MIG | 1.28x avg | 1.14x | direction ok |
| Fig 16 virtualization overhead | <1% e2e | <1% (0.5% modeled) | ok |
| Fig 18 similar vs zigzag mapping | up to ~1.4x, grows w/ cores | up to 1.70x @11c; saturates @28c in our analytic pipeline (divergence noted) | partial |
| Fig 19 HW cost | ~2% LUT/FF | <=2.6% | ok |

Simulator-vs-paper deltas are analyzed in DESIGN.md (we replace FireSim/
DCRA with a calibrated analytical model; trends and orders of magnitude are
the reproduction target).

## §Dry-run — multi-pod lower+compile matrix

`launch/dryrun.py` (forces 512 host devices in its first two lines) lowers
and compiles the right step function for every (arch x shape) on BOTH the
16x16 single-pod mesh and the 2x16x16 multi-pod mesh:

* train_4k -> `train_step` (loss + grads + AdamW, sharded optimizer state)
* prefill_32k -> `prefill` ; decode_32k / long_500k -> `decode_step`
  (one token against a seq_len-deep split-KV cache)

**Result: all 80 cells pass** (10 archs x 4 shapes x 2 meshes; 8 cells/mesh
are the documented long_500k full-attention skips — rows retained below).
`memory_analysis()` and `cost_analysis()` per cell live in
`results/dryrun/*.json`; collective bytes are parsed from the compiled
SPMD module with while-trip and call-graph multipliers
(`roofline/analysis.py`).

Accounting notes (full derivation in DESIGN.md):
* FLOPs/bytes: analytic implementation-faithful model
  (`roofline/analytic.py`), validated within ~1% of fully-unrolled XLA
  cost_analysis on dense cells (XLA counts while bodies once, and
  unrolling 48x128-step scans is infeasible on this 1-core container).
* The jnp chunked-attention path evaluates masked causal blocks (2x the
  ideal attention FLOPs) — visible in `useful_flops_ratio`; the Pallas
  flash kernel (kernels/flash_attention.py) skips them on TPU.
"""

PERF = """
## §Perf — hypothesis -> change -> measure log

Paper-faithful baseline recipe (recorded for every cell above): FSDP
(ZeRO-3) over `data` + TP over `model` (fused-head/ff/vocab dims) + EP for
MoE + sequence-sharded attention (legal for any head count) + split-KV
decode.  Three cells hillclimbed per the assignment (worst roofline
fraction; most collective-bound; most representative of the paper's
technique — the EP all-to-all "critical edge").

### Cell 1: llama4-maverick-400b decode_32k (worst MFU, most collective-bound)

| iteration | hypothesis (napkin) | change | t_coll | t_mem | step time | verdict |
|---|---|---|---|---|---|---|
| baseline | — | FSDP+TP | {l4_base_coll:.0f} ms | {l4_base_mem:.1f} ms | {l4_base_step:.0f} ms | collective-bound 257:1 |
| 1 | 99 GB of all-gathers = FSDP weight gathers for ONE token; expert weights 2D-shard (E->model, ff->data) + psum activations instead of gathering weights; non-expert params TP-only (12B/16 = 1.5 GB/chip fits) | `--recipe tp` (+int8 moments) | {l4_tp_coll:.1f} ms | {l4_tp_mem:.1f} ms | {l4_tp_step:.1f} ms | **CONFIRMED — {l4_speedup:.0f}x step-time reduction**; also drops temp memory {l4_base_tmp:.1f} -> {l4_tp_tmp:.1f} GB (now fits 16 GB HBM) |

Post-change bottleneck: memory ({l4_tp_mem:.1f} ms = streaming 17B active
params + caches), which is the physical floor for batch-128 top-1-MoE
decode; next lever is batch growth or weight quantization, both out of
scope for the fixed shapes.

### Cell 2: whisper-large-v3 train_4k (most collective-bound train cell)

| iteration | hypothesis (napkin) | change | t_coll | verdict |
|---|---|---|---|---|
| baseline | — | FSDP+TP+seq-attn | {wh_base_coll:.0f} ms | collective-bound 31:1 |
| 1 | gathers are FSDP params -> drop FSDP | `--recipe tp` | {wh_tp_coll:.0f} ms | **REFUTED** — all-gathers stayed ({wh_tp_ag:.0f} GB): they are the seq-sharded attention K/V gathers (64 layers x small d_model), not FSDP; grad all-reduce over data got added on top |
| 2 | whisper's attention is cheap (d=1280, hd=64) but K/V gathers cost 3 passes x 0.67 GB x 64 layers; replicating the attention core over `model` removes the gathers for ~16x more attention FLOPs (attention is ~13% of step compute -> +{wh_extra_comp:.1f} s compute worst-case vs -{wh_saved:.1f} s collectives) | `--attn-shard replicated` (keep FSDP) | {wh_repl_coll:.0f} ms | **CONFIRMED — step time {wh_base_step:.1f} -> {wh_repl_step:.1f} s (2.3x)**; still collective-bound (TP activation psums at d_model=1280 x 64 layers); mfu {wh_base_mfu:.3f} -> {wh_repl_mfu:.3f} |

Remaining lever (noted, not executed): head-shard over a 4-way model
sub-axis (20 heads % 16 != 0 but % 4 == 0) — requires a (16,4,4) mesh
variant, i.e. a different production mesh than the assigned one.

### Cell 3: deepseek-moe-16b train_4k (paper-representative: EP all-to-all)

| iteration | hypothesis (napkin) | change | t_coll | verdict |
|---|---|---|---|---|
| baseline | — | FSDP+TP+EP+seq-attn | {ds_base_coll:.0f} ms | collective-bound |
| 1 | drop FSDP gathers (as cell 1) | `--recipe tp` | {ds_tp_coll:.0f} ms | **REFUTED** — gathers unchanged (they're attention K/V + optimizer-update gathers, not FSDP); fp32 grad all-reduces over data added 21 GB |
| 2 | deepseek is the ONE arch whose heads divide the mesh (H=KV=16): head-sharded attention deletes the K/V gathers entirely | `--attn-shard heads` (keep FSDP) | {ds_heads_coll:.0f} ms | **REFUTED net** — all-gathers fell 77->59 GB as predicted, but XLA then kept the residual stream replicated over `model` and inserted f32 grad psums (78 GB all-reduce): with seq-sharded attention the partitioner had propagated model-sharding through the whole layer for free |
| 3 | (analysis) the baseline's seq-sharded attention is load-bearing for layout propagation; the remaining 77 GB all-gather = K/V(bf16, 3 passes) + embed/optimizer gathers; the honest lever is gathering K/V once per layer (remat policy saving gathered K/V), trading +0.5 GB/layer memory | — (napkin only; memory headroom is 6.8 GB, policy change left as future work) | — | baseline stands for this cell |

**Net §Perf outcome**: the paper-faithful baseline is already
well-laid-out for MoE training; the beyond-paper wins are decode
({l4_speedup:.0f}x on llama4) and communication-dominated small-d_model
training (2.3x on whisper).  Both optimized recipes are selectable per
tenant (`--recipe`, `--attn-shard`) without model changes — in the vNPU
framing, they are per-tenant virtual-topology policies.

Refuted-hypothesis lessons are kept deliberately: (a) at 256-chip scale
with modest per-device batch, *sequence-sharded attention gathers — not
FSDP — dominate train-step collectives for small/medium models*; (b)
GSPMD's layout propagation interacts with manual shard_map boundaries, so
a locally-better sharding can be globally worse.

### Pallas-kernel deltas (TPU target; structural, from the lowered math)

* flash_attention: skips fully-masked causal blocks -> halves attention
  FLOPs vs the XLA chunked path (useful_flops_ratio for prefill cells
  rises accordingly); scores never round-trip HBM.
* streamed_matmul: K-major grid = vChunk Pattern-2 monotonic weight
  stream; fp32 VMEM accumulator; double-buffered HBM->VMEM via the Pallas
  pipeline.
* ssd_scan: per-(batch,head) SSM state persists in VMEM scratch across the
  chunk grid — the paper's scratchpad-resident dataflow on TPU.
* decode_attention: split-KV streaming with fused masking — the per-shard
  kernel the decode sharding scheme assumes.
"""


def _load_cells(tag: str = "") -> Dict:
    cells = {}
    for fn in os.listdir(RESULTS):
        if not fn.endswith(".json"):
            continue
        base = fn[:-5]
        parts = base.split("--")
        if len(parts) != 3:
            continue
        arch, shape, mesh_tag = parts
        if tag:
            if not mesh_tag.endswith("-" + tag):
                continue
            mesh = mesh_tag[: -len(tag) - 1]
        else:
            if mesh_tag not in ("16x16", "2x16x16"):
                continue
            mesh = mesh_tag
        cells[(arch, shape, mesh)] = json.load(
            open(os.path.join(RESULTS, fn)))
    return cells


def render() -> str:
    from repro.configs import ARCH_IDS, SHAPE_ORDER

    cells = _load_cells()
    lines = [HEADER]

    # --- dry-run table (memory + compile proof) ---
    lines.append("\n### Dry-run matrix (16x16 | 2x16x16): status, per-device"
                 " temp memory\n")
    lines.append("| arch | shape | 16x16 | temp GB | 2x16x16 | temp GB |")
    lines.append("|---|---|---|---|---|---|")
    for a in ARCH_IDS:
        for s in SHAPE_ORDER:
            row = [a, s]
            for mesh in ("16x16", "2x16x16"):
                c = cells.get((a, s, mesh))
                if c is None:
                    row += ["—", ""]
                elif c.get("status") == "skip":
                    row += ["SKIP(full-attn)", ""]
                else:
                    gb = c["memory"]["temp_size_in_bytes"] / 2**30
                    row += ["ok", f"{gb:.1f}"]
            lines.append("| " + " | ".join(str(x) for x in row) + " |")
    lines.append("\n(temp = XLA buffer-assignment temp bytes per device; "
                 "argument/output sizes in the JSONs.  Cells >16 GB note "
                 "where the FSDP baseline exceeds v5e HBM — the tp recipe "
                 "fixes llama4 decode, see §Perf.)\n")

    # --- roofline table ---
    lines.append("\n## §Roofline — single-pod (16x16, 256 chips) baseline\n")
    lines.append("Terms in ms: compute = HLO_FLOPs/(chips*197e12); memory = "
                 "HLO_bytes/(chips*819e9); collective = per-chip collective "
                 "bytes/50e9.  `useful` = MODEL_FLOPS/HLO_FLOPs "
                 "(6*N_active*D convention); `mfu` = MODEL_FLOPS/"
                 "(chips*peak*max-term).\n")
    lines.append("| arch | shape | t_comp | t_mem | t_coll | bottleneck | "
                 "useful | mfu | one-line fix |")
    lines.append("|---|---|---|---|---|---|---|---|---|")
    fixes = {
        "compute": "bigger per-chip batch or flash kernel (halves attn FLOPs)",
        "memory": "weight/KV quantization; fuse fp32 intermediates",
        "collective": "see §Perf: recipe change (tp / attn-shard) per cell",
    }
    for a in ARCH_IDS:
        for s in SHAPE_ORDER:
            c = cells.get((a, s, "16x16"))
            if c is None:
                continue
            if c.get("status") == "skip":
                lines.append(f"| {a} | {s} | — | — | — | SKIP | — | — | "
                             f"{c['reason']} |")
                continue
            r = c["roofline"]
            lines.append(
                f"| {a} | {s} | {r['t_compute']*1e3:.1f} | "
                f"{r['t_memory']*1e3:.1f} | {r['t_collective']*1e3:.1f} | "
                f"{r['bottleneck']} | {r['useful_flops_ratio']:.2f} | "
                f"{r['mfu']:.4f} | {fixes[r['bottleneck']]} |")
    lines.append("""
Reading the table: train/prefill cells are collective-bound at this scale
because per-chip batch is small (a 256-chip pod on 1M tokens/step) — the
dominant streams are sequence-sharded attention K/V gathers and FSDP param
gathers; decode cells are collective/memory-bound by construction (one
token).  The MODEL_FLOPS/HLO ratio < 1 on attention-heavy cells reflects
(a) remat (4x fwd-equivalents per train step, by design) and (b) the
causal-block waste of the jnp attention path that the Pallas kernel
removes on TPU.  SSM/hybrid cells show useful≈0.93-0.97 at prefill — the
SSD path does almost no wasted math.""")

    # --- perf section with numbers ---
    def g(arch, shape, tag):
        c = _load_cells(tag).get((arch, shape, "16x16"))
        return c["roofline"] if c else None

    def step_ms(r):
        return max(r["t_compute"], r["t_memory"], r["t_collective"]) * 1e3

    l4b = cells[("llama4_maverick_400b_a17b", "decode_32k", "16x16")]
    l4t = _load_cells("tp")[("llama4_maverick_400b_a17b", "decode_32k",
                             "16x16")]
    whb = cells[("whisper_large_v3", "train_4k", "16x16")]
    whr = _load_cells("fsdp-repl")[("whisper_large_v3", "train_4k", "16x16")]
    wht = _load_cells("tp")[("whisper_large_v3", "train_4k", "16x16")]
    dsb = cells[("deepseek_moe_16b", "train_4k", "16x16")]
    dst = _load_cells("tp")[("deepseek_moe_16b", "train_4k", "16x16")]
    dsh = _load_cells("fsdp-heads")[("deepseek_moe_16b", "train_4k",
                                     "16x16")]
    kw = dict(
        l4_base_coll=l4b["roofline"]["t_collective"] * 1e3,
        l4_base_mem=l4b["roofline"]["t_memory"] * 1e3,
        l4_base_step=step_ms(l4b["roofline"]),
        l4_base_tmp=l4b["memory"]["temp_size_in_bytes"] / 2**30,
        l4_tp_coll=l4t["roofline"]["t_collective"] * 1e3,
        l4_tp_mem=l4t["roofline"]["t_memory"] * 1e3,
        l4_tp_step=step_ms(l4t["roofline"]),
        l4_tp_tmp=l4t["memory"]["temp_size_in_bytes"] / 2**30,
        l4_speedup=step_ms(l4b["roofline"]) / step_ms(l4t["roofline"]),
        wh_base_coll=whb["roofline"]["t_collective"] * 1e3,
        wh_base_step=step_ms(whb["roofline"]) / 1e3,
        wh_base_mfu=whb["roofline"]["mfu"],
        wh_tp_coll=wht["roofline"]["t_collective"] * 1e3,
        wh_tp_ag=wht["roofline"]["coll_breakdown"]["all-gather"] / 1e9,
        wh_repl_coll=whr["roofline"]["t_collective"] * 1e3,
        wh_repl_step=step_ms(whr["roofline"]) / 1e3,
        wh_repl_mfu=whr["roofline"]["mfu"],
        wh_extra_comp=1.1, wh_saved=4.6,
        ds_base_coll=dsb["roofline"]["t_collective"] * 1e3,
        ds_tp_coll=dst["roofline"]["t_collective"] * 1e3,
        ds_heads_coll=dsh["roofline"]["t_collective"] * 1e3,
    )
    lines.append(PERF.format(**kw))
    return "\n".join(lines) + "\n"


def main():
    md = render()
    with open(OUT, "w") as f:
        f.write(md)
    print(f"wrote {OUT} ({len(md)} bytes)")


if __name__ == "__main__":
    main()
