"""Fleet federation benchmark: N pods, one deterministic router, one
inter-pod switch — serial vs process-parallel execution.

Builds a :class:`~repro.fleet.Fleet` of ``--pods`` pods (each its own
mesh + placement policy + cluster scheduler + serving plane), routes the
``fleet-serving`` arrival stream through the deterministic
:class:`~repro.fleet.FleetRouter`, charges cross-pod evacuations as
checkpoint transfers on the :class:`~repro.fleet.PodSwitch`, and advances
the pods in bounded-lag windows.  ``--workers N`` forks the
process-parallel executor; ``--workers 1`` is the serial reference — the
two produce bit-identical per-pod trajectories and fleet summaries.

Run:
    PYTHONPATH=src python benchmarks/fleet_sim.py --pods 4 --horizon 60
    PYTHONPATH=src python benchmarks/fleet_sim.py --pods 8 --workers 4 \\
        --upgrade 3:120:30 --fail 5:200

CI gate (merges its numbers into ``BENCH_cluster_sim.json``):
    PYTHONPATH=src python benchmarks/fleet_sim.py --gate
first pins the parallel executor bit-identical to the serial reference on
a heterogeneous 3-pod fleet (mixed mesh sizes and ``mem_interface``
layouts, full request logs, a rolling upgrade AND a pod failure
mid-trace), then replays the 8-pod ``fleet-serving`` trace at the
calibrated request-rate scale and fails unless (a) the small-fleet
trajectories and summaries match exactly, (b) >= 10M aggregate requests
arrive inside the wall budget, (c) the big serial and parallel runs agree
on every per-pod digest and the fleet ``serving_summary()``, and (d) on
machines with >= 4 usable cores the parallel executor is >= 3x faster
than serial (on smaller machines the measured speedup is recorded but
not enforced — a fork can't beat the core count).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent))

from cluster_sim import BENCH_PATH, _write_bench          # noqa: E402
from repro.fleet import (Fleet, FleetConfig, PodSpec,     # noqa: E402
                         ROUTING_POLICIES, Scenario, fleet_trace)
from repro.obs.registry import MetricsRegistry, collect_fleet  # noqa: E402
from repro.obs.trace import DEFAULT_CAPACITY              # noqa: E402

GATE_PODS = 8
GATE_MESH = (16, 16)
GATE_TRACE = "fleet-serving"
GATE_RATE = 13.0                 # calibrated: >= 10M aggregate requests
GATE_MIN_REQUESTS = 10_000_000
GATE_WALL_BUDGET_S = 2400.0      # per run (serial and parallel each)
GATE_SPEEDUP_FLOOR = 3.0
GATE_SPEEDUP_MIN_CORES = 4       # floor enforced only with enough cores

#: serving-realistic vNPU config (matches serving_sim.py's baseline): the
#: vectorized bipartite scorer without exact-B&B escalation — placement
#: quality is identical on the serving trace class and stays cheap at
#: fleet request volumes (the exact mapper was 75% of fleet wall time)
POD_POLICY_KWARGS = {"mapper": "bipartite"}

#: the heterogeneous identity fleet: mixed mesh sizes and mem-interface
#: layouts, so the bit-identity check covers per-pod topology divergence
IDENTITY_PODS = [
    PodSpec(pod_id=0, rows=16, cols=16, policy_kwargs=POD_POLICY_KWARGS),
    PodSpec(pod_id=1, rows=12, cols=12, mem_interface_cols=(0, 11),
            policy_kwargs=POD_POLICY_KWARGS),
    PodSpec(pod_id=2, rows=16, cols=16, mem_interface_cols=(0, 15),
            policy_kwargs=POD_POLICY_KWARGS),
]
IDENTITY_HORIZON_S = 40.0
IDENTITY_SCENARIOS = [
    Scenario("upgrade", t_s=15.0, pod_id=1, duration_s=10.0),
    Scenario("pod-failure", t_s=25.0, pod_id=2),
]


def usable_cores() -> int:
    """Cores this process may actually run on (cgroup/affinity aware)."""
    try:
        return len(os.sched_getaffinity(0))
    except AttributeError:                     # non-Linux fallback
        return os.cpu_count() or 1


def build_pods(n, rows, cols):
    return [PodSpec(pod_id=i, rows=rows, cols=cols,
                    policy_kwargs=POD_POLICY_KWARGS) for i in range(n)]


def run_fleet(pods, *, seed=0, window_s=5.0, routing="least-loaded",
              rate_scale=1.0, horizon_s=None, record=False, workers=1,
              scenarios=(), trace_capacity=0):
    """One fleet run: fresh Fleet + trace, returns (FleetMetrics, Fleet).
    ``trace_capacity > 0`` arms the per-pod span tracers; the merged
    Chrome trace is on the returned Fleet's ``tracer``."""
    cfg = FleetConfig(seed=seed, window_s=window_s, routing=routing,
                      trace_name=GATE_TRACE, record_requests=record,
                      rate_scale=rate_scale, trace_capacity=trace_capacity)
    fleet = Fleet(pods, cfg)
    trace = fleet_trace(len(pods), seed=seed, horizon_s=horizon_s)
    return fleet.run(trace, scenarios=scenarios, workers=workers), fleet


def _print_summary(m):
    s = m.summary()
    r, sw = s["router"], s["switch"]
    print(f"pods={s['pods']} windows={s['windows']} workers={s['workers']} "
          f"horizon={s['horizon_s']:.0f}s wall={s['wall_s']:.1f}s")
    print(f"requests={s['requests']} completed={s['completed']} "
          f"goodput={s['sla_goodput_rps']:.2f} rps "
          f"agg={s['agg_req_per_s']:.0f} req/s")
    print(f"ttft p50/p95/p99 = {s['ttft_p50_s']:.3f}/{s['ttft_p95_s']:.3f}/"
          f"{s['ttft_p99_s']:.3f} s   tpot p50/p99 = "
          f"{s['tpot_p50_s']:.4f}/{s['tpot_p99_s']:.4f} s")
    print(f"router: routed={r['routed']} unroutable={r['unroutable']} "
          f"migrations={r['migrations']} affinity_hits={r['affinity_hits']} "
          f"by_pod={r['routed_by_pod']}")
    print(f"switch: transfers={sw['n_transfers']} "
          f"bytes={sw['bytes_total']} busy={sw['busy_s']}s "
          f"queued={sw['queued_s']}s overflows={sw['buffer_overflows']}")


def _bench_entry(mode, m, extra=None):
    s = m.summary()
    entry = {
        "trace": GATE_TRACE,
        "mesh": f"{GATE_PODS}x{GATE_MESH[0]}x{GATE_MESH[1]}-fleet",
        "mode": mode,
        "wall_s": s["wall_s"],
        "workers": s["workers"],
        "windows": s["windows"],
        "requests": s["requests"],
        "completed": s["completed"],
        "agg_req_per_s": s["agg_req_per_s"],
        "sla_goodput_rps": s["sla_goodput_rps"],
        "ttft_p99_s": s["ttft_p99_s"],
        "tpot_p99_s": s["tpot_p99_s"],
        "routed": s["router"]["routed"],
        "unroutable": s["router"]["unroutable"],
        "migrations": s["router"]["migrations"],
        "switch_transfers": s["switch"]["n_transfers"],
    }
    if extra:
        entry.update(extra)
    return entry


def _identity_check(trace_out=None, metrics_out=None):
    """Serial vs parallel on the heterogeneous 3-pod fleet, full request
    logs, an upgrade AND a pod failure mid-trace.  With ``--trace-out`` /
    ``--metrics-out`` the parallel run is traced, so the bit-identity
    check doubles as the tracing-purity check, and the merged
    trace/metrics are written out."""
    observe = bool(trace_out or metrics_out)
    runs = {}
    fleets = {}
    for workers in (1, 2):
        runs[workers], fleets[workers] = run_fleet(
            list(IDENTITY_PODS), seed=7, horizon_s=IDENTITY_HORIZON_S,
            record=True, workers=workers,
            scenarios=list(IDENTITY_SCENARIOS),
            trace_capacity=DEFAULT_CAPACITY if (
                observe and workers == 2) else 0)
    a, b = runs[1], runs[2]
    out = {
        "pods": len(IDENTITY_PODS),
        "digests_identical": a.pod_digests() == b.pod_digests(),
        "summaries_identical": a.serving_summary() == b.serving_summary(),
        "requests": a.requests_arrived,
        "evacuated": a.serving_summary()["evacuated"],
        "migrations": a.serving_summary()["migrations"],
        "switch_transfers": a.serving_summary()["switch"]["n_transfers"],
    }
    if observe:
        out["trace_events"] = len(fleets[2].tracer)
        out["trace_dropped"] = fleets[2].tracer.dropped
        if trace_out:
            fleets[2].tracer.write(trace_out)
        if metrics_out:
            reg = MetricsRegistry()
            collect_fleet(reg, b)
            reg.write_json(metrics_out)
    return out


def run_gate(json_out: bool, bench_out=BENCH_PATH,
             trace_out=None, metrics_out=None) -> int:
    """The fleet gate (see module docstring)."""
    identity = _identity_check(trace_out, metrics_out)
    identity_ok = (identity["digests_identical"]
                   and identity["summaries_identical"])

    cores = usable_cores()
    workers = min(GATE_PODS, max(cores, 2))
    pods = build_pods(GATE_PODS, *GATE_MESH)
    scenarios = [Scenario("upgrade", t_s=120.0, pod_id=3, duration_s=30.0)]

    serial, _ = run_fleet(pods, rate_scale=GATE_RATE, workers=1,
                          scenarios=list(scenarios))
    par, _ = run_fleet(build_pods(GATE_PODS, *GATE_MESH),
                       rate_scale=GATE_RATE, workers=workers,
                       scenarios=list(scenarios))

    scale_identical = (serial.pod_digests() == par.pod_digests()
                       and serial.serving_summary()
                       == par.serving_summary())
    requests = serial.requests_arrived
    volume_ok = requests >= GATE_MIN_REQUESTS
    wall_ok = (serial.wall_s <= GATE_WALL_BUDGET_S
               and par.wall_s <= GATE_WALL_BUDGET_S)
    speedup = serial.wall_s / max(par.wall_s, 1e-9)
    enforce_speedup = cores >= GATE_SPEEDUP_MIN_CORES
    speedup_ok = (not enforce_speedup) or speedup >= GATE_SPEEDUP_FLOOR

    report = {
        "pods": GATE_PODS,
        "mesh": list(GATE_MESH),
        "trace": GATE_TRACE,
        "rate_scale": GATE_RATE,
        "identity": identity,
        "identity_ok": identity_ok,
        "requests": requests,
        "min_requests": GATE_MIN_REQUESTS,
        "volume_ok": volume_ok,
        "scale_identical": scale_identical,
        "serial_wall_s": round(serial.wall_s, 2),
        "parallel_wall_s": round(par.wall_s, 2),
        "wall_budget_s": GATE_WALL_BUDGET_S,
        "wall_ok": wall_ok,
        "usable_cores": cores,
        "workers": par.workers,
        "speedup": round(speedup, 2),
        "speedup_floor": GATE_SPEEDUP_FLOOR,
        "speedup_enforced": enforce_speedup,
        "speedup_ok": speedup_ok,
        "router": serial.router.as_dict(),
        "switch": serial.switch.as_dict(),
        "gate_ok": (identity_ok and volume_ok and scale_identical
                    and wall_ok and speedup_ok),
    }
    entries = [
        _bench_entry("fleet-serial", serial),
        _bench_entry(f"fleet-parallel-w{par.workers}", par,
                     extra={"speedup": round(speedup, 2)}),
    ]
    _write_bench("fleet", report, entries, bench_out)
    if json_out:
        print(json.dumps(report, indent=2))
    else:
        print(f"identity(3-pod hetero)={'OK' if identity_ok else 'DIVERGED'}"
              f" {identity}")
        print(f"requests={requests} (>= {GATE_MIN_REQUESTS}: "
              f"{'OK' if volume_ok else 'FAIL'}) "
              f"scale_identity={'OK' if scale_identical else 'DIVERGED'}")
        print(f"serial={serial.wall_s:.1f}s parallel={par.wall_s:.1f}s "
              f"(budget {GATE_WALL_BUDGET_S:.0f}s: "
              f"{'OK' if wall_ok else 'FAIL'}) "
              f"speedup={speedup:.2f}x on {cores} cores "
              f"(floor {GATE_SPEEDUP_FLOOR}x "
              f"{'enforced' if enforce_speedup else 'not enforced'}: "
              f"{'OK' if speedup_ok else 'FAIL'})")
        print(f"-> {'OK' if report['gate_ok'] else 'FAIL'}")
    return 0 if report["gate_ok"] else 1


def _parse_scenarios(args, ap):
    out = []
    for spec in args.upgrade or ():
        try:
            pod, t, dur = (float(x) for x in spec.split(":"))
        except ValueError:
            ap.error(f"--upgrade wants POD:T:DURATION (got {spec!r})")
        out.append(Scenario("upgrade", t_s=t, pod_id=int(pod),
                            duration_s=dur))
    for spec in args.fail or ():
        try:
            pod, t = (float(x) for x in spec.split(":"))
        except ValueError:
            ap.error(f"--fail wants POD:T (got {spec!r})")
        out.append(Scenario("pod-failure", t_s=t, pod_id=int(pod)))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--pods", type=int, default=4,
                    help="number of pods in the fleet")
    ap.add_argument("--mesh", default="16,16",
                    help="per-pod mesh rows,cols")
    ap.add_argument("--horizon", type=float, default=None,
                    help="arrival horizon in seconds (trace default)")
    ap.add_argument("--window", type=float, default=5.0,
                    help="bounded-lag window length in seconds")
    ap.add_argument("--rate-scale", type=float, default=1.0,
                    help="multiplier on every tenant's request rate")
    ap.add_argument("--workers", type=int, default=1,
                    help="1 = serial reference; N>1 forks the "
                         "process-parallel executor (same trajectories)")
    ap.add_argument("--routing", default="least-loaded",
                    choices=sorted(ROUTING_POLICIES),
                    help="fleet routing policy")
    ap.add_argument("--seed", type=int, default=0,
                    help="fleet seed (per-pod stream seeds are derived)")
    ap.add_argument("--upgrade", action="append", metavar="POD:T:DUR",
                    help="rolling upgrade: drain POD at T for DUR seconds "
                         "(repeatable)")
    ap.add_argument("--fail", action="append", metavar="POD:T",
                    help="permanent pod failure at T (repeatable)")
    ap.add_argument("--record-requests", action="store_true",
                    help="materialize per-request records (identity "
                         "debugging; off = streamed P^2 percentiles)")
    ap.add_argument("--gate", action="store_true",
                    help="CI mode: heterogeneous bit-identity, then the "
                         "8-pod >= 10M-request budgeted run; merges "
                         "BENCH_cluster_sim.json")
    ap.add_argument("--bench-out", default=str(BENCH_PATH),
                    help="where --gate merges the machine-readable "
                         "BENCH record")
    ap.add_argument("--profile", action="store_true",
                    help="wrap the run in cProfile and print the top-20 "
                         "cumulative hotspots")
    ap.add_argument("--profile-out", default=None, metavar="FILE",
                    help="dump the raw cProfile pstats data to FILE "
                         "(implies --profile)")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="write the merged Chrome/Perfetto trace-event "
                         "JSON (pid = pod, 9999 = fleet driver)")
    ap.add_argument("--metrics-out", default=None, metavar="FILE",
                    help="write the unified metrics-registry snapshot "
                         "as JSON")
    ap.add_argument("--json", action="store_true", help="machine output")
    args = ap.parse_args(argv)

    if args.profile or args.profile_out:
        from _profile import run_profiled, strip_profile_flags
        return run_profiled(main, strip_profile_flags(argv),
                            args.profile_out)

    if args.gate:
        return run_gate(args.json, args.bench_out,
                        args.trace_out, args.metrics_out)

    try:
        rows, cols = (int(x) for x in args.mesh.split(","))
    except ValueError:
        ap.error(f"--mesh wants 'rows,cols' (got {args.mesh!r})")
    scenarios = _parse_scenarios(args, ap)
    m, fleet = run_fleet(
        build_pods(args.pods, rows, cols), seed=args.seed,
        window_s=args.window, routing=args.routing,
        rate_scale=args.rate_scale, horizon_s=args.horizon,
        record=args.record_requests, workers=args.workers,
        scenarios=scenarios,
        trace_capacity=DEFAULT_CAPACITY if args.trace_out else 0)
    if args.trace_out:
        fleet.tracer.write(args.trace_out)
    if args.metrics_out:
        reg = MetricsRegistry()
        collect_fleet(reg, m)
        reg.write_json(args.metrics_out)
    if args.json:
        print(json.dumps(m.summary(), indent=2))
    else:
        _print_summary(m)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
