"""Request-level LLM serving simulation: vNPU vs MIG vs UVM on SLA-goodput.

The serving-plane counterpart of ``cluster_sim.py``: the same event-driven
multi-tenant scheduler, but every tenant of the ``serving`` trace carries a
:mod:`repro.serve.requests` profile and serves a prefill/decode-mixed
request stream through the :class:`~repro.serve.plane.ServingPlane` —
continuous batching, KV-cache pressure on a real buddy arena, phase-aware
throughput from the tenant's contention-scored placement, and the
scheduler's elastic vNPU resize (RESIZE events under hysteresis).

Per policy it reports **SLA-goodput** (requests meeting both their TTFT and
TPOT targets, per second), the TTFT/TPOT percentiles, KV pressure events
and the resize trajectory.  Baseline configs are serving-realistic: MIG is
carved into eight 2x4 slices (the A100-style fine slicing that maximizes
its tenancy) and the vNPU policy uses the engine's ``bipartite`` mapper
(the vectorized scorer without exact-B&B escalation — placement quality is
identical on this trace class, and defrag stays cheap).

Run:
    PYTHONPATH=src python benchmarks/serving_sim.py --trace serving

CI gate (merges its numbers into ``BENCH_cluster_sim.json``):
    PYTHONPATH=src python benchmarks/serving_sim.py --gate
replays the ``serving`` trace on the 8x8 mesh through all three policies
(SLA-aware admission) and fails unless (a) two back-to-back vNPU runs
produce bit-identical request-level trajectories, (b) vNPU >= MIG and
>= UVM on SLA-goodput, (c) elastic resize demonstrably fired
(vNPU resize count > 0), and (d) the event loop stays inside the
ms/event budget.

Scale gate (the million-request run, also merged into BENCH):
    PYTHONPATH=src python benchmarks/serving_sim.py --scale-gate
first pins the vectorized plane bit-identical to the retained scalar
engine on the 8x8 ``serving`` trace (request log, samples and resize
trajectory — for the default stream and for the diurnal/doc-heavy one),
then replays the ``pod-serving`` trace on a 32x32 pod with scaled
request streams (``--engine vector --no-request-log``) and fails unless
>= 1M requests arrive inside the wall-time budget.

Exploratory flags: ``--engine scalar`` replays through the segment-exact
scalar plane, ``--arrival diurnal|flash`` / ``--mix doc_heavy`` /
``--rate-scale`` reshape the per-tenant request streams, and
``--no-request-log`` streams percentiles through the P^2 sketches
instead of materializing per-request records.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent))

from cluster_sim import BENCH_PATH, _write_bench          # noqa: E402
from repro.core import mesh_2d                            # noqa: E402
from repro.obs.registry import (MetricsRegistry,          # noqa: E402
                                collect_cluster)
from repro.obs.trace import Tracer                        # noqa: E402
from repro.sched import (ClusterScheduler, ServingConfig,  # noqa: E402
                         TRACES, make_policy, make_trace)
from repro.serve.plane import ServingPlane                # noqa: E402
from repro.serve.requests import (ArrivalProcess,         # noqa: E402
                                  REQUEST_MIXES)

GATE_MESH = (8, 8)
GATE_TRACE = "serving"
GATE_MS_PER_EVENT = 60.0    # absolute event-loop budget (measured ~3 ms)

SCALE_MESH = (32, 32)
SCALE_TRACE = "pod-serving"
SCALE_RATE = 6.0            # per-tenant request-stream multiplier
SCALE_MIN_REQUESTS = 1_000_000
SCALE_WALL_BUDGET_S = 600.0

# serving-realistic baseline configs (see module docstring)
POLICY_KWARGS = {
    "vnpu": {"mapper": "bipartite"},
    "mig": {"partition_shapes": [(2, 4)] * 8},
    "uvm": {},
}


def run_policy(policy_name, trace, mesh, *, trace_name=GATE_TRACE,
               admission="sla", seed=0, epoch_s=2.0, engine="vector",
               record_requests=True, arrival=None, mix="default",
               rate_scale=1.0, tracer=None):
    """One serving run: fresh policy + scheduler + plane."""
    kwargs = dict(POLICY_KWARGS.get(policy_name, {}))
    if policy_name == "mig" and mesh != tuple(GATE_MESH):
        kwargs.pop("partition_shapes", None)   # quadrant default elsewhere
    policy = make_policy(policy_name, mesh_2d(*mesh), **kwargs)
    sched = ClusterScheduler(
        policy, epoch_s=epoch_s,
        serving=ServingConfig(seed=seed, engine=engine,
                              record_requests=record_requests,
                              arrival=arrival, request_mix=mix,
                              rate_scale=rate_scale),
        admission=admission, tracer=tracer)
    t0 = time.perf_counter()
    metrics = sched.run(trace, trace_name=trace_name)
    return metrics, time.perf_counter() - t0


def _request_trajectory(metrics):
    """The request-level outputs two runs must agree on exactly."""
    return (metrics.request_log,
            [(s.t, s.agg_fps, s.utilization, s.n_resident, s.n_queued)
             for s in metrics.samples],
            metrics.n_resizes, metrics.n_resize_attempts)


def _policy_row(metrics, wall_s):
    s = metrics.serving_summary()
    s.update({
        "policy": metrics.policy,
        "admitted": metrics.n_admitted,
        "arrived": metrics.n_arrived,
        "mean_utilization": round(metrics.mean_utilization, 4),
        "p95_wait_s": round(metrics.p95_wait_s, 3),
        "wall_s": round(wall_s, 2),
        "events": metrics.n_events,
    })
    return s


def _print_table(rows):
    hdr = (f"{'policy':>6} {'goodput':>8} {'good':>6} {'compl':>6} "
           f"{'reqs':>6} {'ttft_p95':>9} {'tpot_p95':>9} {'resize':>7} "
           f"{'kv_oom':>7} {'admit':>6} {'util':>6} {'wall_s':>7}")
    print(hdr)
    for r in rows:
        print(f"{r['policy']:>6} {r['sla_goodput_rps']:>8.2f} "
              f"{r['sla_good']:>6} {r['completed']:>6} {r['requests']:>6} "
              f"{r['ttft_p95_s']:>8.3f}s {r['tpot_p95_s']:>8.4f}s "
              f"{r['resizes']:>3}/{r['resize_attempts']:<3} "
              f"{r['kv_preemptions'] + r['kv_admit_oom']:>7} "
              f"{r['admitted']:>3}/{r['arrived']:<3} "
              f"{r['mean_utilization']:>6.3f} {r['wall_s']:>7.1f}")


def _bench_rows(rows, mesh):
    out = []
    for r in rows:
        out.append({
            "trace": GATE_TRACE,
            "mesh": f"{mesh[0]}x{mesh[1]}",
            "mode": f"serving-{r['policy']}",
            "wall_s": r["wall_s"],
            "events": r["events"],
            "ms_per_event": round(r["wall_s"] / max(r["events"], 1) * 1e3,
                                  3),
            "admitted": r["admitted"],
            "sla_goodput_rps": r["sla_goodput_rps"],
            "requests": r["requests"],
            "completed": r["completed"],
            "ttft_p95_s": r["ttft_p95_s"],
            "tpot_p95_s": r["tpot_p95_s"],
            "resizes": r["resizes"],
            "kv_preemptions": r["kv_preemptions"],
        })
    return out


def run_gate(json_out: bool, bench_out=BENCH_PATH,
             trace_out=None, metrics_out=None) -> int:
    """The serving-gate (see module docstring).  With ``--trace-out`` /
    ``--metrics-out`` the determinism replay runs with the span tracer
    armed, so the bit-identity check doubles as the tracing-purity check."""
    trace = make_trace(GATE_TRACE)
    runs = {}
    for name in ("vnpu", "mig", "uvm"):
        runs[name] = run_policy(name, trace, GATE_MESH)
    # determinism: a second vNPU run must replay bit-identically at the
    # request level (every TTFT/TPOT and every resize decision)
    tracer = None
    if trace_out or metrics_out:
        tracer = Tracer()
        tracer.process_name(
            f"vnpu {GATE_MESH[0]}x{GATE_MESH[1]} {GATE_TRACE}")
    vnpu2, _ = run_policy("vnpu", trace, GATE_MESH, tracer=tracer)
    deterministic = (_request_trajectory(runs["vnpu"][0])
                     == _request_trajectory(vnpu2))
    if trace_out:
        tracer.write(trace_out)
    if metrics_out:
        reg = MetricsRegistry()
        collect_cluster(reg, vnpu2)
        reg.write_json(metrics_out)

    rows = [_policy_row(m, w) for m, w in runs.values()]
    by = {r["policy"]: r for r in rows}
    goodput_ok = (by["vnpu"]["sla_goodput_rps"]
                  >= by["mig"]["sla_goodput_rps"] - 1e-9
                  and by["vnpu"]["sla_goodput_rps"]
                  >= by["uvm"]["sla_goodput_rps"] - 1e-9)
    resize_ok = by["vnpu"]["resizes"] > 0
    ms_per_event = max(r["wall_s"] / max(r["events"], 1) * 1e3
                       for r in rows)
    budget_ok = ms_per_event <= GATE_MS_PER_EVENT

    report = {
        "mesh": list(GATE_MESH),
        "trace": GATE_TRACE,
        "tenants": len(trace),
        "deterministic_request_trajectories": deterministic,
        "vnpu_goodput_geq_baselines": goodput_ok,
        "vnpu_resizes": by["vnpu"]["resizes"],
        "resize_fired": resize_ok,
        "max_ms_per_event": round(ms_per_event, 2),
        "ms_per_event_budget": GATE_MS_PER_EVENT,
        "policies": rows,
        "gate_ok": (deterministic and goodput_ok and resize_ok
                    and budget_ok),
    }
    if tracer is not None:
        report["trace_events"] = len(tracer)
        report["trace_dropped"] = tracer.dropped
    _write_bench("serving", report, _bench_rows(rows, GATE_MESH), bench_out)
    if json_out:
        print(json.dumps(report, indent=2))
    else:
        _print_table(rows)
        print(f"deterministic={'OK' if deterministic else 'DIVERGED'} "
              f"vnpu>=baselines={'OK' if goodput_ok else 'FAIL'} "
              f"resize_fired={'OK' if resize_ok else 'FAIL'} "
              f"({by['vnpu']['resizes']} resizes) "
              f"budget={ms_per_event:.1f}ms/event "
              f"(<= {GATE_MS_PER_EVENT}) -> "
              f"{'OK' if report['gate_ok'] else 'FAIL'}")
    return 0 if report["gate_ok"] else 1


def _identity_pair(arrival, mix):
    """Vector vs scalar engine over the 8x8 serving trace: bit-identical
    request trajectories AND identical streamed summaries?"""
    trace = make_trace(GATE_TRACE)
    runs = {}
    for engine in ServingPlane.ENGINES:
        m, _ = run_policy("vnpu", trace, GATE_MESH, engine=engine,
                          arrival=arrival, mix=mix)
        runs[engine] = m
    vec, sca = runs["vector"], runs["scalar"]
    return (_request_trajectory(vec) == _request_trajectory(sca)
            and vec.serving_summary() == sca.serving_summary())


def run_scale_gate(json_out: bool, bench_out=BENCH_PATH) -> int:
    """The million-request scale gate (see module docstring): pin the
    vectorized plane bit-identical to the scalar engine on the 8x8 gate
    trace, then push >= 1M requests through a 32x32 pod inside the
    wall-time budget, streaming percentiles instead of request records."""
    identity = {
        "default": _identity_pair(None, "default"),
        "diurnal_doc_heavy": _identity_pair(
            ArrivalProcess(kind="diurnal"), "doc_heavy"),
    }
    identity_ok = all(identity.values())

    trace = make_trace(SCALE_TRACE)
    metrics, wall = run_policy(
        "vnpu", trace, SCALE_MESH, trace_name=SCALE_TRACE,
        engine="vector", record_requests=False, rate_scale=SCALE_RATE)
    s = metrics.serving_summary()
    volume_ok = s["requests"] >= SCALE_MIN_REQUESTS
    wall_ok = wall <= SCALE_WALL_BUDGET_S

    row = {
        "trace": SCALE_TRACE,
        "mesh": "32x32-pod-serving",     # namespaced: the cluster pod
                                         # gate owns the plain "32x32" rows
        "mode": "serving-scale-vnpu",
        "wall_s": round(wall, 2),
        "events": metrics.n_events,
        "requests": s["requests"],
        "req_per_s": round(s["requests"] / max(wall, 1e-9), 1),
        "completed": s["completed"],
        "sla_goodput_rps": s["sla_goodput_rps"],
        "ttft_p50_s": s["ttft_p50_s"],
        "ttft_p99_s": s["ttft_p99_s"],
        "tpot_p50_s": s["tpot_p50_s"],
        "tpot_p99_s": s["tpot_p99_s"],
        "resizes": s["resizes"],
        "kv_preemptions": s["kv_preemptions"],
        "peak_live_records": metrics.peak_live_records,
    }
    report = {
        "mesh": list(SCALE_MESH),
        "trace": SCALE_TRACE,
        "tenants": len(trace),
        "rate_scale": SCALE_RATE,
        "scalar_vector_identity": identity,
        "requests": s["requests"],
        "min_requests": SCALE_MIN_REQUESTS,
        "wall_s": round(wall, 2),
        "wall_budget_s": SCALE_WALL_BUDGET_S,
        "req_per_s": row["req_per_s"],
        "peak_live_records": metrics.peak_live_records,
        "summary": s,
        "gate_ok": identity_ok and volume_ok and wall_ok,
    }
    _write_bench("serving_scale", report, [row], bench_out)
    if json_out:
        print(json.dumps(report, indent=2))
    else:
        print(f"identity={'OK' if identity_ok else 'DIVERGED'} "
              f"{identity} "
              f"requests={s['requests']} (>= {SCALE_MIN_REQUESTS}: "
              f"{'OK' if volume_ok else 'FAIL'}) "
              f"wall={wall:.1f}s (<= {SCALE_WALL_BUDGET_S:.0f}s: "
              f"{'OK' if wall_ok else 'FAIL'}) "
              f"{row['req_per_s']:.0f} req/s "
              f"ttft_p99={s['ttft_p99_s']:.3f}s "
              f"tpot_p99={s['tpot_p99_s']:.4f}s "
              f"goodput={s['sla_goodput_rps']:.2f} rps -> "
              f"{'OK' if report['gate_ok'] else 'FAIL'}")
    return 0 if report["gate_ok"] else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--trace", default="serving",
                    help="trace name: " + "|".join(sorted(TRACES)))
    ap.add_argument("--policy", default="vnpu,mig,uvm",
                    help="comma-separated: vnpu,mig,uvm")
    ap.add_argument("--mesh", default="8,8", help="physical mesh rows,cols")
    ap.add_argument("--seed", type=int, default=None,
                    help="trace seed (also seeds the request streams)")
    ap.add_argument("--horizon", type=float, default=None,
                    help="arrival horizon in seconds (trace default)")
    ap.add_argument("--admission", default="sla", choices=("fifo", "sla"),
                    help="queue drain order: FIFO or SLA-aware "
                         "(EDF with TTFT-predictive deadlines)")
    ap.add_argument("--engine", default="vector",
                    choices=ServingPlane.ENGINES,
                    help="serving-plane engine: vectorized lockstep or "
                         "the segment-exact scalar reference")
    ap.add_argument("--no-request-log", action="store_true",
                    help="stream percentiles (P^2 sketches) instead of "
                         "materializing per-request records")
    ap.add_argument("--arrival", default="poisson",
                    choices=ArrivalProcess.KINDS,
                    help="request-arrival shape within each tenant stream")
    ap.add_argument("--mix", default="default",
                    choices=sorted(REQUEST_MIXES),
                    help="request mix: profile default or the heavy-tail "
                         "doc_heavy (Pareto long-prefill) mix")
    ap.add_argument("--rate-scale", type=float, default=1.0,
                    help="multiplier on every tenant's request rate")
    ap.add_argument("--gate", action="store_true",
                    help="CI mode: deterministic request trajectories, "
                         "vNPU >= MIG/UVM on SLA-goodput, resize fires, "
                         "ms/event budget; merges BENCH_cluster_sim.json")
    ap.add_argument("--scale-gate", action="store_true",
                    help="CI mode: scalar-vs-vector bit-identity on the "
                         "8x8 gate trace, then >= 1M requests on a 32x32 "
                         "pod inside the wall budget; merges "
                         "BENCH_cluster_sim.json")
    ap.add_argument("--bench-out", default=str(BENCH_PATH),
                    help="where --gate merges the machine-readable "
                         "BENCH record")
    ap.add_argument("--profile", action="store_true",
                    help="wrap the run in cProfile and print the top-20 "
                         "cumulative hotspots")
    ap.add_argument("--profile-out", default=None, metavar="FILE",
                    help="dump the raw cProfile pstats data to FILE "
                         "(implies --profile)")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="write a Chrome/Perfetto trace-event JSON of the "
                         "run (sim-time request/tenant spans)")
    ap.add_argument("--metrics-out", default=None, metavar="FILE",
                    help="write the unified metrics-registry snapshot "
                         "as JSON")
    ap.add_argument("--json", action="store_true", help="machine output")
    args = ap.parse_args(argv)

    if args.profile or args.profile_out:
        from _profile import run_profiled, strip_profile_flags
        return run_profiled(main, strip_profile_flags(argv),
                            args.profile_out)

    if args.gate:
        return run_gate(args.json, args.bench_out,
                        args.trace_out, args.metrics_out)
    if args.scale_gate:
        return run_scale_gate(args.json, args.bench_out)

    try:
        rows_cols = tuple(int(x) for x in args.mesh.split(","))
        assert len(rows_cols) == 2
    except (ValueError, AssertionError):
        ap.error(f"--mesh wants 'rows,cols' (got {args.mesh!r})")
    try:
        trace = make_trace(args.trace, seed=args.seed,
                           horizon_s=args.horizon)
    except KeyError as e:
        ap.error(str(e))

    arrival = (None if args.arrival == "poisson"
               else ArrivalProcess(kind=args.arrival))
    obs_tracer = Tracer() if args.trace_out else Tracer.NULL
    reg = MetricsRegistry() if args.metrics_out else None
    rows = []
    for i, name in enumerate(
            p.strip() for p in args.policy.split(",") if p.strip()):
        tracer = None
        if args.trace_out:
            tracer = Tracer(pid=i)
            tracer.process_name(
                f"{name} {rows_cols[0]}x{rows_cols[1]} {args.trace}")
        metrics, wall = run_policy(name, trace, rows_cols,
                                   trace_name=args.trace,
                                   admission=args.admission,
                                   seed=args.seed or 0,
                                   engine=args.engine,
                                   record_requests=not args.no_request_log,
                                   arrival=arrival, mix=args.mix,
                                   rate_scale=args.rate_scale,
                                   tracer=tracer)
        rows.append(_policy_row(metrics, wall))
        if tracer is not None:
            obs_tracer.absorb(tracer.drain())
        if reg is not None:
            collect_cluster(reg, metrics, prefix=f"cluster_{name}")
    if args.trace_out:
        obs_tracer.write(args.trace_out)
    if reg is not None:
        reg.write_json(args.metrics_out)
    if args.json:
        print(json.dumps({"trace": args.trace, "mesh": list(rows_cols),
                          "admission": args.admission, "policies": rows},
                         indent=2))
    else:
        print(f"trace={args.trace} tenants={len(trace)} "
              f"mesh={rows_cols[0]}x{rows_cols[1]} "
              f"admission={args.admission}")
        _print_table(rows)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
