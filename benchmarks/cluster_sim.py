"""Multi-tenant cluster simulation: vNPU vs MIG vs UVM over one trace.

The dynamic counterpart of Figs. 15–18: tenants arrive (Poisson), queue,
run, depart; each policy places them on the same mesh (6x6 SIM config by
default, ``--mesh 16,16`` / ``--mesh 32,32`` for pods) and the analytic
simulator scores every epoch with cross-tenant interference wired from the
actual co-residents — incrementally via the InterferenceLedger by default,
or with the O(residents^2 x flows) reference recompute (``--rescore
oracle``).

Run:
    PYTHONPATH=src python benchmarks/cluster_sim.py \\
        --trace mixed --policy vnpu,mig,uvm

Reports per-policy mean utilization, p50/p95/p99 tenant queueing latency,
admission counts, mean per-tenant throughput and the median epoch-scoring
pass cost, plus the headline claim (vNPU >= both baselines on utilization
— the paper's Fig-15 trend).

CI gate (epoch-rescoring ledger):
    PYTHONPATH=src python benchmarks/cluster_sim.py --gate
replays the ``mixed`` and ``pod-mixed`` traces on a 16x16 mesh through the
vNPU policy under both rescore modes and fails unless (a) the scores are
bit-identical and (b) the ledger's median scoring pass is >= 5x cheaper.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import mesh_2d                       # noqa: E402
from repro.core import simulator as S                # noqa: E402
from repro.sched import (ClusterScheduler, TRACES, make_policy,  # noqa: E402
                         make_trace)

GATE_MESH = (16, 16)
GATE_SPEEDUP = 5.0        # ledger vs oracle median epoch-scoring pass cost
GATE_TRACES = (("mixed", None), ("pod-mixed", 25.0))   # (name, horizon_s)


def _trajectory(metrics):
    """The score-bearing outputs two rescore modes must agree on exactly."""
    return ([(s.t, s.agg_fps, s.utilization, s.n_resident, s.n_queued)
             for s in metrics.samples],
            dict(metrics.tenant_iterations))


def run_gate(json_out: bool) -> int:
    """Ledger-vs-oracle gate: bit-identical scores, >= 5x cheaper passes."""
    report = {"mesh": list(GATE_MESH), "speedup_floor": GATE_SPEEDUP,
              "traces": []}
    ok = True
    for trace_name, horizon in GATE_TRACES:
        trace = make_trace(trace_name, horizon_s=horizon)
        runs = {}
        for mode in ("ledger", "oracle"):
            policy = make_policy("vnpu", mesh_2d(*GATE_MESH))
            sched = ClusterScheduler(policy, hw=S.SIM_CONFIG, epoch_s=2.0,
                                     rescore=mode)
            t0 = time.perf_counter()
            metrics = sched.run(trace, trace_name=trace_name)
            runs[mode] = (metrics, time.perf_counter() - t0)
        ledger, oracle = runs["ledger"][0], runs["oracle"][0]
        identical = _trajectory(ledger) == _trajectory(oracle)
        speedup = oracle.median_scoring_ms / max(ledger.median_scoring_ms,
                                                 1e-9)
        entry = {
            "trace": trace_name,
            "tenants": len(trace),
            "identical_scores": identical,
            "ledger_median_scoring_ms": round(ledger.median_scoring_ms, 3),
            "oracle_median_scoring_ms": round(oracle.median_scoring_ms, 3),
            "ledger_scoring_passes": len(ledger.scoring_pass_s),
            "oracle_scoring_passes": len(oracle.scoring_pass_s),
            "median_pass_speedup": round(speedup, 1),
            "ledger_wall_s": round(runs["ledger"][1], 1),
            "oracle_wall_s": round(runs["oracle"][1], 1),
            "ledger_counters": ledger.ledger_counters,
            "gate_ok": identical and speedup >= GATE_SPEEDUP,
        }
        ok = ok and entry["gate_ok"]
        report["traces"].append(entry)
    report["gate_ok"] = ok
    if json_out:
        print(json.dumps(report, indent=2))
    else:
        for e in report["traces"]:
            print(f"{e['trace']}: ledger {e['ledger_median_scoring_ms']}ms "
                  f"vs oracle {e['oracle_median_scoring_ms']}ms per pass "
                  f"-> {e['median_pass_speedup']}x "
                  f"(floor {GATE_SPEEDUP}x), scores "
                  f"{'bit-identical' if e['identical_scores'] else 'DIVERGED'}"
                  f" over {e['tenants']} tenants "
                  f"-> {'OK' if e['gate_ok'] else 'FAIL'}")
    return 0 if ok else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--trace", default="mixed",
                    help="trace name: " + "|".join(sorted(TRACES)))
    ap.add_argument("--policy", default="vnpu,mig,uvm",
                    help="comma-separated: vnpu,mig,uvm")
    ap.add_argument("--seed", type=int, default=None)
    ap.add_argument("--horizon", type=float, default=None,
                    help="arrival horizon in seconds (trace default if unset)")
    ap.add_argument("--epoch", type=float, default=2.0,
                    help="scoring epoch in seconds")
    ap.add_argument("--mesh", default="6,6", help="physical mesh rows,cols")
    ap.add_argument("--rescore", default="ledger",
                    choices=("ledger", "oracle"),
                    help="epoch scoring: incremental ledger (default) or "
                         "the O(R^2 x flows) reference oracle")
    ap.add_argument("--no-defrag", action="store_true",
                    help="disable defragmenting migration")
    ap.add_argument("--gate", action="store_true",
                    help="CI mode: ledger-vs-oracle scoring gate at 16x16 "
                         "on the mixed and pod-mixed traces")
    ap.add_argument("--json", action="store_true", help="machine output")
    args = ap.parse_args(argv)

    if args.gate:
        return run_gate(args.json)

    try:
        rows, cols = (int(x) for x in args.mesh.split(","))
    except ValueError:
        ap.error(f"--mesh wants 'rows,cols' (got {args.mesh!r})")
    policies = [p.strip() for p in args.policy.split(",") if p.strip()]
    try:
        trace = make_trace(args.trace, seed=args.seed, horizon_s=args.horizon)
        for name in policies:
            make_policy(name, mesh_2d(1, 1))   # validate names up front
    except KeyError as e:
        ap.error(str(e))

    results = []
    for name in policies:
        policy = make_policy(name, mesh_2d(rows, cols))
        sched = ClusterScheduler(policy, hw=S.SIM_CONFIG,
                                 epoch_s=args.epoch,
                                 defrag=not args.no_defrag,
                                 rescore=args.rescore)
        t0 = time.perf_counter()
        metrics = sched.run(trace, trace_name=args.trace)
        wall = time.perf_counter() - t0
        results.append((metrics, wall))

    by_name = {m.policy: m for m, _ in results}
    claims = {}
    if "vnpu" in by_name:
        v = by_name["vnpu"].mean_utilization
        # vNPU and UVM admit the same tenants on utilization-bound traces
        # (both allocate exact core counts), so equality is structural, not
        # coincidental — compare with a small tolerance so the CI gate does
        # not flake on simulation-noise-level perturbations of a tie
        claims["vnpu_utilization_geq_baselines"] = all(
            v >= by_name[o].mean_utilization - 5e-3
            for o in ("mig", "uvm") if o in by_name)
        claims["vnpu_mean_utilization"] = round(v, 4)

    # nonzero exit when a headline claim fails, so the CI smoke step gates
    # on the Fig-15 trend instead of only catching crashes
    ok = all(v for v in claims.values() if isinstance(v, bool))

    if args.json:
        print(json.dumps({
            "trace": args.trace, "n_tenants": len(trace),
            "mesh": [rows, cols], "rescore": args.rescore,
            "policies": [m.summary() for m, _ in results],
            "claims": claims,
        }, indent=2))
        return 0 if ok else 1

    print(f"trace={args.trace} tenants={len(trace)} mesh={rows}x{cols} "
          f"epoch={args.epoch}s defrag={not args.no_defrag} "
          f"rescore={args.rescore}")
    hdr = (f"{'policy':>6} {'util':>7} {'p50_wait':>9} {'p95_wait':>9} "
           f"{'p99_wait':>9} {'admit':>6} {'reject':>7} {'migr':>5} "
           f"{'fps/tenant':>11} {'score_ms':>9} {'wall_s':>7}")
    print(hdr)
    for m, wall in results:
        s = m.summary()
        print(f"{s['policy']:>6} {s['mean_utilization']:>7.4f} "
              f"{s['p50_wait_s']:>8.2f}s {s['p95_wait_s']:>8.2f}s "
              f"{s['p99_wait_s']:>8.2f}s "
              f"{s['admitted']:>6} {s['rejected']:>7} {s['migrations']:>5} "
              f"{s['mean_tenant_fps']:>11.1f} "
              f"{s['median_scoring_ms']:>9.3f} {wall:>7.1f}")
    print(f"claims: {json.dumps(claims)}")

    # mapping-engine telemetry (vNPU policy): cache effectiveness of the
    # placement engine across admission probes, allocations and migrations
    for m, _ in results:
        ec = m.engine_counters
        if ec:
            cacheable = ec["cache_hits"] + ec["cache_misses"]
            print(f"\n{m.policy} mapping engine: "
                  f"hit_rate={ec['hit_rate']:.2%} of "
                  f"{cacheable} cacheable component lookups "
                  f"(hits={ec['cache_hits']} misses={ec['cache_misses']}; "
                  f"+{ec['uncacheable']} uncacheable) "
                  f"map_calls={ec['map_calls']} "
                  f"escalations={ec['exact_escalations']} "
                  f"region_ops={ec['region_ops']}")

    # interference-ledger telemetry: how much epoch scoring the
    # incremental occupancy bookkeeping avoided
    for m, _ in results:
        lc = m.ledger_counters
        if lc:
            print(f"{m.policy} interference ledger: "
                  f"reuse_rate={lc['reuse_rate']:.2%} "
                  f"(rescored={lc['rescored']} reused={lc['reused']}) "
                  f"dirtied={lc['tenants_dirtied']} "
                  f"global_invalidations={lc['global_invalidations']} "
                  f"events={lc['adds']}+{lc['removes']}+{lc['updates']} "
                  f"(add/remove/migrate)")

    # short trajectory excerpt: utilization over time per policy
    print("\ntrajectory (utilization @ epoch):")
    for m, _ in results:
        pts = m.samples[:: max(len(m.samples) // 12, 1)]
        line = " ".join(f"{p.t:>5.0f}s:{p.utilization:.2f}" for p in pts)
        print(f"  {m.policy:>6}  {line}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
