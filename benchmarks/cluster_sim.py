"""Multi-tenant cluster simulation: vNPU vs MIG vs UVM over one trace.

The dynamic counterpart of Figs. 15–18: tenants arrive (Poisson), queue,
run, depart; each policy places them on the same mesh (6x6 SIM config by
default, ``--mesh 16,16`` / ``--mesh 32,32`` for pods) and the analytic
simulator scores every epoch with cross-tenant interference wired from the
actual co-residents — incrementally via the InterferenceLedger by default,
or with the O(residents^2 x flows) reference recompute (``--rescore
oracle``, which also disables the drain-queue probe memo and the
split-RunReport skeleton cache so the whole fast path is gated at once).

Run:
    PYTHONPATH=src python benchmarks/cluster_sim.py \\
        --trace mixed --policy vnpu,mig,uvm

Reports per-policy mean utilization, p50/p95/p99 tenant queueing latency,
admission counts, mean per-tenant throughput and the median epoch-scoring
pass cost, plus the headline claim (vNPU >= both baselines on utilization
— the paper's Fig-15 trend).

``--failure-rate R`` injects a Poisson process of single-core FAILURE
events (R expected dead cores per second over the arrival horizon, seeded
with the trace) and reports availability (admitted / arrived) next to
utilization per policy — the fault-tolerance study from the ROADMAP.

CI gates (both write ``BENCH_cluster_sim.json`` so the perf trajectory is
tracked across PRs; override the path with ``--bench-out``):

    PYTHONPATH=src python benchmarks/cluster_sim.py --gate
replays the ``mixed`` and ``pod-mixed`` traces on a 16x16 mesh through the
vNPU policy under both rescore modes and fails unless (a) the
placement/score trajectories are bit-identical and (b) the fast path's
median scoring pass is >= 5x cheaper.

    PYTHONPATH=src python benchmarks/cluster_sim.py --gate --mesh 32,32
is the budgeted pod-scale gate: ``pod-mixed`` on a 1024-core mesh (one
policy, one trace — not the full three-policy benchmark), asserting
bit-identical trajectories between the fast path (ledger + probe memo +
split-RunReport + symmetry cache) and the oracle path, an end-to-end
event-loop wall-time speedup floor over the oracle, and an absolute
ms/event budget.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import mesh_2d                       # noqa: E402
from repro.core import simulator as S                # noqa: E402
from repro.obs.registry import (MetricsRegistry,     # noqa: E402
                                collect_cluster)
from repro.obs.trace import Tracer                   # noqa: E402
from repro.sched import (ClusterScheduler, TRACES, make_policy,  # noqa: E402
                         make_trace)
from repro.sched.defrag import DEFRAG_PLANNERS       # noqa: E402

GATE_MESH = (16, 16)
GATE_SPEEDUP = 5.0        # ledger vs oracle median epoch-scoring pass cost
GATE_TRACES = (("mixed", None), ("pod-mixed", 25.0))   # (name, horizon_s)

POD_GATE_MESH = (32, 32)
POD_GATE_TRACE = "pod-mixed"
POD_GATE_HORIZON = 90.0   # the full pod trace: the deep-queue tail is
                          # exactly the regime the fast path exists for
# The oracle path shares the optimized placement machinery (symmetry
# cache, delta 2-opt, lazy candidates), so the in-code end-to-end gap is
# far smaller than the vs-base-commit headline (~22x at this PR): the
# floor pins ledger + probe memo + split-RunReport against regression.
POD_GATE_SPEEDUP = 1.25   # fast-path vs oracle end-to-end wall-time floor
POD_GATE_MS_PER_EVENT = 250.0   # absolute event-loop budget (CI machines
                                # vary; this PR measures ~54 ms/event)
# tracing is a pure observer: the traced replay must stay bit-identical
# and cost at most this factor of the untraced fast path's wall time
TRACE_OVERHEAD_MAX = 1.15

BENCH_PATH = Path(__file__).resolve().parent.parent / "BENCH_cluster_sim.json"


def _trajectory(metrics):
    """The score-bearing outputs two rescore modes must agree on exactly."""
    return ([(s.t, s.agg_fps, s.utilization, s.n_resident, s.n_queued)
             for s in metrics.samples],
            dict(metrics.tenant_iterations))


def synthesize_failures(rate_per_s, horizon_s, n_cores, seed=0):
    """Poisson single-core failure events: ``rate_per_s`` expected dead
    cores per second over ``[0, horizon_s)``; cores are sampled without
    replacement so each FAILURE kills a distinct physical core.
    Deterministic per seed — every policy sees the same fault sequence."""
    rng = np.random.default_rng(seed + 0xFA11)
    out = []
    t = 0.0
    dead = set()
    while True:
        t += float(rng.exponential(1.0 / max(rate_per_s, 1e-9)))
        if t >= horizon_s or len(dead) >= n_cores:
            return out
        alive = [c for c in range(n_cores) if c not in dead]
        core = int(rng.choice(alive))
        dead.add(core)
        out.append((t, (core,)))


def _bench_entry(trace_name, mesh, mode, metrics, wall_s):
    """One BENCH_cluster_sim.json row: wall time, per-event and scoring
    costs, and the fast-path telemetry (cache hit rates, probe skips)."""
    entry = {
        "trace": trace_name,
        "mesh": f"{mesh[0]}x{mesh[1]}",
        "mode": mode,
        "wall_s": round(wall_s, 2),
        "events": metrics.n_events,
        "ms_per_event": round(wall_s / max(metrics.n_events, 1) * 1e3, 3),
        "median_scoring_ms": round(metrics.median_scoring_ms, 3),
        "admitted": metrics.n_admitted,
        "probe_skips": metrics.n_probe_skips,
    }
    ec = metrics.engine_counters
    if ec:
        entry["engine_hit_rate"] = ec.get("hit_rate", 0.0)
        entry["sym_decoded_hits"] = ec.get("sym_decoded_hits", 0)
        entry["cache_misses"] = ec.get("cache_misses", 0)
    lc = metrics.ledger_counters
    if lc:
        entry["ledger_reuse_rate"] = lc.get("reuse_rate", 0.0)
    return entry


def _write_bench(gate_name, report, entries, bench_out, extra=None):
    """Persist the machine-readable perf record (tracked in-repo so the
    trajectory across PRs is diffable).  Each gate (16x16, 32x32,
    serving) owns one ``gates`` slot and its mesh's ``entries`` rows;
    records from the other gates are preserved so running any one
    refreshes only its half.  ``extra`` merges additional top-level
    sections (the failure-sweep frontier)."""
    path = Path(bench_out)
    payload = {"benchmark": "cluster_sim", "gates": {}, "entries": []}
    if path.exists():
        try:
            old = json.loads(path.read_text())
            payload.update({k: v for k, v in old.items()
                            if k not in ("benchmark",)})
            payload["gates"] = dict(old.get("gates", {}))
            payload["entries"] = list(old.get("entries", []))
        except (json.JSONDecodeError, AttributeError):
            pass
    if gate_name is not None:
        payload["gates"][gate_name] = report
    fresh_meshes = {e["mesh"] for e in entries}
    payload["entries"] = sorted(
        [e for e in payload["entries"] if e.get("mesh") not in fresh_meshes]
        + entries,
        key=lambda e: (e.get("mesh", ""), e.get("trace", ""),
                       e.get("mode", "")))
    if extra:
        payload.update(extra)
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")


def _gate_pair(trace, trace_name, mesh):
    """Run the fast path and the oracle path over one trace; returns
    {mode: (metrics, wall_s)}.  Fresh policy+scheduler per mode — the
    oracle disables the ledger, the probe memo and the skeleton cache."""
    runs = {}
    for mode in ("ledger", "oracle"):
        policy = make_policy("vnpu", mesh_2d(*mesh))
        sched = ClusterScheduler(policy, hw=S.SIM_CONFIG, epoch_s=2.0,
                                 rescore=mode)
        t0 = time.perf_counter()
        metrics = sched.run(trace, trace_name=trace_name)
        runs[mode] = (metrics, time.perf_counter() - t0)
    return runs


def _traced_run(trace, trace_name, mesh):
    """One extra fast-path run with the span tracer armed (pure observer:
    the trajectory must match the untraced run exactly)."""
    tracer = Tracer()
    tracer.process_name(f"vnpu {mesh[0]}x{mesh[1]} {trace_name}")
    policy = make_policy("vnpu", mesh_2d(*mesh))
    sched = ClusterScheduler(policy, hw=S.SIM_CONFIG, epoch_s=2.0,
                             rescore="ledger", tracer=tracer)
    t0 = time.perf_counter()
    metrics = sched.run(trace, trace_name=trace_name)
    return tracer, metrics, time.perf_counter() - t0


def run_gate(json_out: bool, bench_out=BENCH_PATH,
             trace_out=None, metrics_out=None) -> int:
    """16x16 ledger-vs-oracle gate: bit-identical scores, >= 5x cheaper
    scoring passes; writes the BENCH record.  ``--trace-out`` adds a
    traced replay of the first gate trace (the obs-gate: its trajectory
    must stay bit-identical with tracing on) and writes the Chrome
    trace-event JSON; ``--metrics-out`` writes the registry snapshot."""
    report = {"mesh": list(GATE_MESH), "speedup_floor": GATE_SPEEDUP,
              "traces": []}
    bench_entries = []
    ok = True
    first = None           # (trace, ledger metrics) of the first gate trace
    for trace_name, horizon in GATE_TRACES:
        trace = make_trace(trace_name, horizon_s=horizon)
        runs = _gate_pair(trace, trace_name, GATE_MESH)
        ledger, oracle = runs["ledger"][0], runs["oracle"][0]
        if first is None:
            first = (trace, ledger)
        identical = _trajectory(ledger) == _trajectory(oracle)
        speedup = oracle.median_scoring_ms / max(ledger.median_scoring_ms,
                                                 1e-9)
        entry = {
            "trace": trace_name,
            "tenants": len(trace),
            "identical_scores": identical,
            "ledger_median_scoring_ms": round(ledger.median_scoring_ms, 3),
            "oracle_median_scoring_ms": round(oracle.median_scoring_ms, 3),
            "ledger_scoring_passes": len(ledger.scoring_pass_s),
            "oracle_scoring_passes": len(oracle.scoring_pass_s),
            "median_pass_speedup": round(speedup, 1),
            "ledger_wall_s": round(runs["ledger"][1], 1),
            "oracle_wall_s": round(runs["oracle"][1], 1),
            "probe_skips": ledger.n_probe_skips,
            "ledger_counters": ledger.ledger_counters,
            "gate_ok": identical and speedup >= GATE_SPEEDUP,
        }
        ok = ok and entry["gate_ok"]
        report["traces"].append(entry)
        for mode in ("ledger", "oracle"):
            bench_entries.append(_bench_entry(
                trace_name, GATE_MESH, mode, *runs[mode]))
    if trace_out or metrics_out:
        trace_name = GATE_TRACES[0][0]
        tracer, t_metrics, t_wall = _traced_run(first[0], trace_name,
                                                GATE_MESH)
        identical = _trajectory(t_metrics) == _trajectory(first[1])
        report["observability"] = {
            "trace": trace_name,
            "trace_identical": identical,
            "trace_events": len(tracer),
            "trace_dropped": tracer.dropped,
            "traced_wall_s": round(t_wall, 2),
        }
        ok = ok and identical
        if trace_out:
            tracer.write(trace_out)
        if metrics_out:
            reg = MetricsRegistry()
            collect_cluster(reg, t_metrics)
            reg.write_json(metrics_out)
    report["gate_ok"] = ok
    _write_bench("16x16", report, bench_entries, bench_out)
    if json_out:
        print(json.dumps(report, indent=2))
    else:
        if "observability" in report:
            o = report["observability"]
            print(f"obs: traced replay of {o['trace']} "
                  f"{'bit-identical' if o['trace_identical'] else 'DIVERGED'}"
                  f" ({o['trace_events']} events, "
                  f"{o['trace_dropped']} dropped)")
        for e in report["traces"]:
            print(f"{e['trace']}: ledger {e['ledger_median_scoring_ms']}ms "
                  f"vs oracle {e['oracle_median_scoring_ms']}ms per pass "
                  f"-> {e['median_pass_speedup']}x "
                  f"(floor {GATE_SPEEDUP}x), scores "
                  f"{'bit-identical' if e['identical_scores'] else 'DIVERGED'}"
                  f" over {e['tenants']} tenants "
                  f"-> {'OK' if e['gate_ok'] else 'FAIL'}")
    return 0 if ok else 1


def run_pod_gate(json_out: bool, bench_out=BENCH_PATH,
                 trace_out=None, metrics_out=None) -> int:
    """Budgeted 32x32 gate: the full fast path (ledger + probe memo +
    split-RunReport + symmetry cache) must replay ``pod-mixed`` with a
    trajectory bit-identical to the oracle path's and an end-to-end
    event-loop wall time >= POD_GATE_SPEEDUP x cheaper.  A third run with
    the span tracer armed must stay bit-identical and inside the
    TRACE_OVERHEAD_MAX wall-time ratio (recorded in BENCH)."""
    trace = make_trace(POD_GATE_TRACE, horizon_s=POD_GATE_HORIZON)
    runs = _gate_pair(trace, POD_GATE_TRACE, POD_GATE_MESH)
    fast, oracle = runs["ledger"], runs["oracle"]
    identical = _trajectory(fast[0]) == _trajectory(oracle[0])
    speedup = oracle[1] / max(fast[1], 1e-9)
    ms_per_event = fast[1] / max(fast[0].n_events, 1) * 1e3
    tracer, t_metrics, t_wall = _traced_run(trace, POD_GATE_TRACE,
                                            POD_GATE_MESH)
    trace_identical = _trajectory(t_metrics) == _trajectory(fast[0])
    trace_overhead = t_wall / max(fast[1], 1e-9)
    reg = MetricsRegistry()
    collect_cluster(reg, t_metrics)
    report = {
        "mesh": list(POD_GATE_MESH),
        "trace": POD_GATE_TRACE,
        "horizon_s": POD_GATE_HORIZON,
        "tenants": len(trace),
        "identical_trajectories": identical,
        "fast_wall_s": round(fast[1], 2),
        "oracle_wall_s": round(oracle[1], 2),
        "end_to_end_speedup": round(speedup, 2),
        "speedup_floor": POD_GATE_SPEEDUP,
        "fast_ms_per_event": round(ms_per_event, 1),
        "ms_per_event_budget": POD_GATE_MS_PER_EVENT,
        "probe_skips": fast[0].n_probe_skips,
        "engine": fast[0].engine_counters,
        "traced_wall_s": round(t_wall, 2),
        "trace_overhead_ratio": round(trace_overhead, 3),
        "trace_overhead_max": TRACE_OVERHEAD_MAX,
        "trace_identical": trace_identical,
        "trace_events": len(tracer),
        "trace_dropped": tracer.dropped,
        "gate_ok": (identical and speedup >= POD_GATE_SPEEDUP
                    and ms_per_event <= POD_GATE_MS_PER_EVENT
                    and trace_identical
                    and trace_overhead <= TRACE_OVERHEAD_MAX),
    }
    if trace_out:
        tracer.write(trace_out)
    if metrics_out:
        reg.write_json(metrics_out)
    traced_entry = _bench_entry(POD_GATE_TRACE, POD_GATE_MESH,
                                "ledger-traced", t_metrics, t_wall)
    traced_entry["trace_overhead_ratio"] = round(trace_overhead, 3)
    traced_entry["trace_events"] = len(tracer)
    # the unified registry snapshot rides along in the BENCH record
    # (tools/check_bench.py lints it: unique names, finite values)
    traced_entry["metrics"] = reg.snapshot()
    _write_bench("32x32", report, [
        _bench_entry(POD_GATE_TRACE, POD_GATE_MESH, m, *runs[m])
        for m in ("ledger", "oracle")] + [traced_entry], bench_out)
    if json_out:
        print(json.dumps(report, indent=2))
    else:
        print(f"pod gate {POD_GATE_MESH[0]}x{POD_GATE_MESH[1]} "
              f"{POD_GATE_TRACE}@{POD_GATE_HORIZON}s: fast "
              f"{report['fast_wall_s']}s vs oracle "
              f"{report['oracle_wall_s']}s -> "
              f"{report['end_to_end_speedup']}x "
              f"(floor {POD_GATE_SPEEDUP}x), "
              f"{report['fast_ms_per_event']}ms/event "
              f"(budget {POD_GATE_MS_PER_EVENT}), trajectories "
              f"{'bit-identical' if identical else 'DIVERGED'}, traced "
              f"{report['traced_wall_s']}s = "
              f"{report['trace_overhead_ratio']}x "
              f"(max {TRACE_OVERHEAD_MAX}x, "
              f"{'bit-identical' if trace_identical else 'DIVERGED'}, "
              f"{report['trace_events']} events) -> "
              f"{'OK' if report['gate_ok'] else 'FAIL'}")
    return 0 if report["gate_ok"] else 1


def run_failure_sweep(rates, trace_name, policies, mesh, horizon, seed,
                      epoch_s, json_out, bench_out) -> int:
    """Sweep a failure-rate grid and report the availability/utilization
    frontier per policy (the ROADMAP fault-tolerance study): each rate
    synthesizes its own seeded Poisson single-core death sequence, every
    policy replays the same trace against it.  MIG loses a whole partition
    per death (no finer quarantine), so its frontier collapses first;
    vNPU/UVM quarantine per core and migrate residents away.  The
    frontier is merged into ``BENCH_cluster_sim.json`` under
    ``failure_frontier``."""
    trace = make_trace(trace_name, seed=seed, horizon_s=horizon)
    eff_horizon = horizon if horizon is not None \
        else TRACES[trace_name].horizon_s
    eff_seed = seed if seed is not None else TRACES[trace_name].seed
    frontier = {p: [] for p in policies}
    for rate in rates:
        failures = synthesize_failures(rate, eff_horizon, mesh[0] * mesh[1],
                                       seed=eff_seed) if rate > 0 else []
        for name in policies:
            policy = make_policy(name, mesh_2d(*mesh))
            sched = ClusterScheduler(policy, hw=S.SIM_CONFIG,
                                     epoch_s=epoch_s)
            m = sched.run(trace, trace_name=trace_name, failures=failures)
            frontier[name].append({
                "rate_per_s": rate,
                "availability": round(m.n_admitted / max(m.n_arrived, 1), 4),
                "utilization": round(m.mean_utilization, 4),
                "failed_cores": m.n_failed_cores,
                "migrations": m.n_migrations,
            })
    record = {"trace": trace_name, "mesh": f"{mesh[0]}x{mesh[1]}",
              "rates": list(rates), "frontier": frontier}
    _write_bench(None, None, [], bench_out,
                 extra={"failure_frontier": record})
    if json_out:
        print(json.dumps(record, indent=2))
        return 0
    print(f"failure sweep: trace={trace_name} mesh={mesh[0]}x{mesh[1]} "
          f"rates={list(rates)}")
    print(f"{'policy':>6} {'rate':>6} {'avail':>7} {'util':>7} "
          f"{'dead':>5} {'migr':>5}")
    for name in policies:
        for row in frontier[name]:
            print(f"{name:>6} {row['rate_per_s']:>6.3f} "
                  f"{row['availability']:>7.4f} {row['utilization']:>7.4f} "
                  f"{row['failed_cores']:>5} {row['migrations']:>5}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--trace", default="mixed",
                    help="trace name: " + "|".join(sorted(TRACES)))
    ap.add_argument("--policy", default="vnpu,mig,uvm",
                    help="comma-separated: vnpu,mig,uvm")
    ap.add_argument("--seed", type=int, default=None)
    ap.add_argument("--horizon", type=float, default=None,
                    help="arrival horizon in seconds (trace default if unset)")
    ap.add_argument("--epoch", type=float, default=2.0,
                    help="scoring epoch in seconds")
    ap.add_argument("--mesh", default="6,6", help="physical mesh rows,cols")
    ap.add_argument("--rescore", default="ledger",
                    choices=("ledger", "oracle"),
                    help="epoch scoring: incremental ledger (default) or "
                         "the O(R^2 x flows) reference oracle (also turns "
                         "off the probe memo and skeleton cache)")
    ap.add_argument("--failure-rate", type=float, default=0.0,
                    help="expected core failures per second over the "
                         "arrival horizon (Poisson, seeded); reports "
                         "availability vs utilization per policy")
    ap.add_argument("--failure-sweep", default=None, metavar="R0,R1,...",
                    help="sweep a comma-separated failure-rate grid and "
                         "emit the availability/utilization frontier per "
                         "policy into BENCH_cluster_sim.json "
                         "(e.g. 0,0.05,0.1,0.2)")
    ap.add_argument("--no-defrag", action="store_true",
                    help="disable defragmenting migration")
    ap.add_argument("--defrag-planner", default="greedy",
                    choices=sorted(DEFRAG_PLANNERS),
                    help="defrag strategy: greedy most-scattered-first, or "
                         "ilp = exact minimum-pause migration subsets "
                         "(MILP; vNPU policy only, falls back to greedy)")
    ap.add_argument("--gate", action="store_true",
                    help="CI mode: fast-path-vs-oracle gate — 16x16 "
                         "mixed/pod-mixed by default, the budgeted "
                         "pod-scale variant with --mesh 32,32")
    ap.add_argument("--bench-out", default=str(BENCH_PATH),
                    help="where --gate writes the machine-readable "
                         "BENCH_cluster_sim.json perf record")
    ap.add_argument("--heat-aware", action="store_true",
                    help="link-heatmap-aware vNPU admission: equal-TED "
                         "placements prefer regions whose boundary links "
                         "are cold in the interference ledger (off = "
                         "historical placement, bit-identical)")
    ap.add_argument("--profile", action="store_true",
                    help="cProfile the run and print the top-20 "
                         "cumulative hotspots")
    ap.add_argument("--profile-out", default=None, metavar="FILE",
                    help="dump the raw cProfile pstats data to FILE "
                         "(implies --profile)")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="write a Chrome/Perfetto trace-event JSON of the "
                         "run (sim-time spans; pure observer — "
                         "trajectories are unchanged)")
    ap.add_argument("--metrics-out", default=None, metavar="FILE",
                    help="write the unified metrics-registry snapshot "
                         "as JSON")
    ap.add_argument("--json", action="store_true", help="machine output")
    args = ap.parse_args(argv)

    if args.profile or args.profile_out:
        sys.path.insert(0, str(Path(__file__).resolve().parent))
        from _profile import run_profiled, strip_profile_flags
        return run_profiled(main, strip_profile_flags(argv),
                            args.profile_out)

    try:
        rows, cols = (int(x) for x in args.mesh.split(","))
    except ValueError:
        ap.error(f"--mesh wants 'rows,cols' (got {args.mesh!r})")

    if args.gate:
        if (rows, cols) == tuple(POD_GATE_MESH):
            return run_pod_gate(args.json, args.bench_out,
                                args.trace_out, args.metrics_out)
        if (rows, cols) not in ((6, 6), tuple(GATE_MESH)):
            ap.error(f"--gate runs fixed configurations: the 16x16 gate "
                     f"(default; --mesh 16,16) or the pod gate "
                     f"(--mesh 32,32) — got --mesh {args.mesh!r}")
        return run_gate(args.json, args.bench_out,
                        args.trace_out, args.metrics_out)

    policies = [p.strip() for p in args.policy.split(",") if p.strip()]
    try:
        trace = make_trace(args.trace, seed=args.seed, horizon_s=args.horizon)
        for name in policies:
            make_policy(name, mesh_2d(1, 1))   # validate names up front
    except KeyError as e:
        ap.error(str(e))

    if args.failure_sweep is not None:
        try:
            rates = [float(x) for x in args.failure_sweep.split(",") if x]
        except ValueError:
            ap.error(f"--failure-sweep wants comma-separated rates "
                     f"(got {args.failure_sweep!r})")
        return run_failure_sweep(rates, args.trace, policies, (rows, cols),
                                 args.horizon, args.seed, args.epoch,
                                 args.json, args.bench_out)

    failures = []
    if args.failure_rate > 0:
        horizon = (args.horizon if args.horizon is not None
                   else TRACES[args.trace].horizon_s)
        failures = synthesize_failures(
            args.failure_rate, horizon, rows * cols,
            seed=args.seed if args.seed is not None else TRACES[args.trace].seed)

    # one tracer per policy run (pid = policy index) merged into one file
    obs_tracer = Tracer() if args.trace_out else Tracer.NULL
    results = []
    for i, name in enumerate(policies):
        kwargs = {"heat_aware": True} if (
            name == "vnpu" and args.heat_aware) else {}
        policy = make_policy(name, mesh_2d(rows, cols), **kwargs)
        tracer = None
        if args.trace_out:
            tracer = Tracer(pid=i)
            tracer.process_name(f"{name} {rows}x{cols}")
        sched = ClusterScheduler(policy, hw=S.SIM_CONFIG,
                                 epoch_s=args.epoch,
                                 defrag=not args.no_defrag,
                                 defrag_planner=args.defrag_planner,
                                 rescore=args.rescore,
                                 tracer=tracer)
        t0 = time.perf_counter()
        metrics = sched.run(trace, trace_name=args.trace, failures=failures)
        wall = time.perf_counter() - t0
        results.append((metrics, wall))
        if tracer is not None:
            obs_tracer.absorb(tracer.drain())

    if args.trace_out:
        obs_tracer.write(args.trace_out)
    if args.metrics_out:
        reg = MetricsRegistry()
        for m, _ in results:
            collect_cluster(reg, m, prefix=f"cluster_{m.policy}")
        reg.write_json(args.metrics_out)

    by_name = {m.policy: m for m, _ in results}
    claims = {}
    if "vnpu" in by_name:
        v = by_name["vnpu"].mean_utilization
        # vNPU and UVM admit the same tenants on utilization-bound traces
        # (both allocate exact core counts), so equality is structural, not
        # coincidental — compare with a small tolerance so the CI gate does
        # not flake on simulation-noise-level perturbations of a tie
        claims["vnpu_utilization_geq_baselines"] = all(
            v >= by_name[o].mean_utilization - 5e-3
            for o in ("mig", "uvm") if o in by_name)
        claims["vnpu_mean_utilization"] = round(v, 4)

    # nonzero exit when a headline claim fails, so the CI smoke step gates
    # on the Fig-15 trend instead of only catching crashes
    ok = all(v for v in claims.values() if isinstance(v, bool))

    def availability(m):
        """Fraction of arrived tenants that were eventually admitted —
        the service-availability axis of the failure study."""
        return m.n_admitted / m.n_arrived if m.n_arrived else 0.0

    if args.json:
        out = {
            "trace": args.trace, "n_tenants": len(trace),
            "mesh": [rows, cols], "rescore": args.rescore,
            "policies": [m.summary() for m, _ in results],
            "claims": claims,
        }
        if failures:
            out["failure_rate_per_s"] = args.failure_rate
            out["n_failure_events"] = len(failures)
            out["availability"] = {
                m.policy: round(availability(m), 4) for m, _ in results}
        print(json.dumps(out, indent=2))
        return 0 if ok else 1

    print(f"trace={args.trace} tenants={len(trace)} mesh={rows}x{cols} "
          f"epoch={args.epoch}s defrag={not args.no_defrag} "
          f"rescore={args.rescore}")
    hdr = (f"{'policy':>6} {'util':>7} {'p50_wait':>9} {'p95_wait':>9} "
           f"{'p99_wait':>9} {'admit':>6} {'reject':>7} {'migr':>5} "
           f"{'fps/tenant':>11} {'score_ms':>9} {'wall_s':>7}")
    print(hdr)
    for m, wall in results:
        s = m.summary()
        print(f"{s['policy']:>6} {s['mean_utilization']:>7.4f} "
              f"{s['p50_wait_s']:>8.2f}s {s['p95_wait_s']:>8.2f}s "
              f"{s['p99_wait_s']:>8.2f}s "
              f"{s['admitted']:>6} {s['rejected']:>7} {s['migrations']:>5} "
              f"{s['mean_tenant_fps']:>11.1f} "
              f"{s['median_scoring_ms']:>9.3f} {wall:>7.1f}")
    print(f"claims: {json.dumps(claims)}")

    if failures:
        # availability vs utilization: how each policy degrades when cores
        # die (quarantine + evacuation migrations vs lost capacity)
        print(f"\nfailure study: rate={args.failure_rate}/s, "
              f"{len(failures)} core deaths injected")
        for m, _ in results:
            print(f"  {m.policy:>6}  availability={availability(m):.4f} "
                  f"utilization={m.mean_utilization:.4f} "
                  f"failed_cores={m.n_failed_cores} "
                  f"migrations={m.n_migrations}")

    # mapping-engine telemetry (vNPU policy): cache effectiveness of the
    # placement engine across admission probes, allocations and migrations
    for m, _ in results:
        ec = m.engine_counters
        if ec:
            cacheable = ec["cache_hits"] + ec["cache_misses"]
            print(f"\n{m.policy} mapping engine: "
                  f"hit_rate={ec['hit_rate']:.2%} of "
                  f"{cacheable} cacheable component lookups "
                  f"(hits={ec['cache_hits']} misses={ec['cache_misses']}; "
                  f"+{ec['uncacheable']} uncacheable; "
                  f"{ec['sym_decoded_hits']} via D4 symmetry) "
                  f"map_calls={ec['map_calls']} "
                  f"escalations={ec['exact_escalations']} "
                  f"region_ops={ec['region_ops']}")

    # interference-ledger telemetry: how much epoch scoring the
    # incremental occupancy bookkeeping avoided
    for m, _ in results:
        lc = m.ledger_counters
        if lc:
            print(f"{m.policy} interference ledger: "
                  f"reuse_rate={lc['reuse_rate']:.2%} "
                  f"(rescored={lc['rescored']} reused={lc['reused']}) "
                  f"dirtied={lc['tenants_dirtied']} "
                  f"global_invalidations={lc['global_invalidations']} "
                  f"events={lc['adds']}+{lc['removes']}+{lc['updates']} "
                  f"(add/remove/migrate) "
                  f"probe_skips={m.n_probe_skips}")

    # short trajectory excerpt: utilization over time per policy
    print("\ntrajectory (utilization @ epoch):")
    for m, _ in results:
        pts = m.samples[:: max(len(m.samples) // 12, 1)]
        line = " ".join(f"{p.t:>5.0f}s:{p.utilization:.2f}" for p in pts)
        print(f"  {m.policy:>6}  {line}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
