"""Multi-tenant cluster simulation: vNPU vs MIG vs UVM over one trace.

The dynamic counterpart of Figs. 15–18: tenants arrive (Poisson), queue,
run, depart; each policy places them on the same 6x6 SIM-config mesh and
the analytic simulator scores every epoch with cross-tenant interference
wired from the actual co-residents.

Run:
    PYTHONPATH=src python benchmarks/cluster_sim.py \\
        --trace mixed --policy vnpu,mig,uvm

Reports per-policy mean utilization, p50/p95 tenant queueing latency,
admission counts and mean per-tenant throughput, plus the headline claim
(vNPU >= both baselines on utilization — the paper's Fig-15 trend).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import mesh_2d                       # noqa: E402
from repro.core import simulator as S                # noqa: E402
from repro.sched import (ClusterScheduler, make_policy,  # noqa: E402
                         make_trace)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--trace", default="mixed",
                    help="trace name: mixed|small|large|bursty")
    ap.add_argument("--policy", default="vnpu,mig,uvm",
                    help="comma-separated: vnpu,mig,uvm")
    ap.add_argument("--seed", type=int, default=None)
    ap.add_argument("--horizon", type=float, default=None,
                    help="arrival horizon in seconds (trace default if unset)")
    ap.add_argument("--epoch", type=float, default=2.0,
                    help="scoring epoch in seconds")
    ap.add_argument("--mesh", default="6,6", help="physical mesh rows,cols")
    ap.add_argument("--no-defrag", action="store_true",
                    help="disable defragmenting migration")
    ap.add_argument("--json", action="store_true", help="machine output")
    args = ap.parse_args(argv)

    try:
        rows, cols = (int(x) for x in args.mesh.split(","))
    except ValueError:
        ap.error(f"--mesh wants 'rows,cols' (got {args.mesh!r})")
    policies = [p.strip() for p in args.policy.split(",") if p.strip()]
    try:
        trace = make_trace(args.trace, seed=args.seed, horizon_s=args.horizon)
        for name in policies:
            make_policy(name, mesh_2d(1, 1))   # validate names up front
    except KeyError as e:
        ap.error(str(e))

    results = []
    for name in policies:
        policy = make_policy(name, mesh_2d(rows, cols))
        sched = ClusterScheduler(policy, hw=S.SIM_CONFIG,
                                 epoch_s=args.epoch,
                                 defrag=not args.no_defrag)
        t0 = time.perf_counter()
        metrics = sched.run(trace, trace_name=args.trace)
        wall = time.perf_counter() - t0
        results.append((metrics, wall))

    by_name = {m.policy: m for m, _ in results}
    claims = {}
    if "vnpu" in by_name:
        v = by_name["vnpu"].mean_utilization
        # vNPU and UVM admit the same tenants on utilization-bound traces
        # (both allocate exact core counts), so equality is structural, not
        # coincidental — compare with a small tolerance so the CI gate does
        # not flake on simulation-noise-level perturbations of a tie
        claims["vnpu_utilization_geq_baselines"] = all(
            v >= by_name[o].mean_utilization - 5e-3
            for o in ("mig", "uvm") if o in by_name)
        claims["vnpu_mean_utilization"] = round(v, 4)

    # nonzero exit when a headline claim fails, so the CI smoke step gates
    # on the Fig-15 trend instead of only catching crashes
    ok = all(v for v in claims.values() if isinstance(v, bool))

    if args.json:
        print(json.dumps({
            "trace": args.trace, "n_tenants": len(trace),
            "mesh": [rows, cols],
            "policies": [m.summary() for m, _ in results],
            "claims": claims,
        }, indent=2))
        return 0 if ok else 1

    print(f"trace={args.trace} tenants={len(trace)} mesh={rows}x{cols} "
          f"epoch={args.epoch}s defrag={not args.no_defrag}")
    hdr = (f"{'policy':>6} {'util':>7} {'p50_wait':>9} {'p95_wait':>9} "
           f"{'admit':>6} {'reject':>7} {'migr':>5} {'fps/tenant':>11} "
           f"{'wall_s':>7}")
    print(hdr)
    for m, wall in results:
        s = m.summary()
        print(f"{s['policy']:>6} {s['mean_utilization']:>7.4f} "
              f"{s['p50_wait_s']:>8.2f}s {s['p95_wait_s']:>8.2f}s "
              f"{s['admitted']:>6} {s['rejected']:>7} {s['migrations']:>5} "
              f"{s['mean_tenant_fps']:>11.1f} {wall:>7.1f}")
    print(f"claims: {json.dumps(claims)}")

    # mapping-engine telemetry (vNPU policy): cache effectiveness of the
    # placement engine across admission probes, allocations and migrations
    for m, _ in results:
        ec = m.engine_counters
        if ec:
            cacheable = ec["cache_hits"] + ec["cache_misses"]
            print(f"\n{m.policy} mapping engine: "
                  f"hit_rate={ec['hit_rate']:.2%} of "
                  f"{cacheable} cacheable component lookups "
                  f"(hits={ec['cache_hits']} misses={ec['cache_misses']}; "
                  f"+{ec['uncacheable']} uncacheable) "
                  f"map_calls={ec['map_calls']} "
                  f"escalations={ec['exact_escalations']} "
                  f"region_ops={ec['region_ops']}")

    # short trajectory excerpt: utilization over time per policy
    print("\ntrajectory (utilization @ epoch):")
    for m, _ in results:
        pts = m.samples[:: max(len(m.samples) // 12, 1)]
        line = " ".join(f"{p.t:>5.0f}s:{p.utilization:.2f}" for p in pts)
        print(f"  {m.policy:>6}  {line}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
