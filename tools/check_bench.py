"""Bench-record lint: BENCH_cluster_sim.json must stay machine-checkable.

The benchmark scripts (``benchmarks/cluster_sim.py``, ``serving_sim.py``,
``fleet_sim.py``, ``chaos_sim.py`` and ``mapping_engine.py --gap-gate``)
all merge their results into one ledger file via ``_write_bench``.  CI and the docs quote
numbers straight out of that file, so a malformed merge (NaN wall-times,
a gate slot without a verdict, an entry that lost its mesh key) silently
poisons every downstream claim.  This lint validates the record:

* top-level shape: ``benchmark == "cluster_sim"``, ``entries`` a list,
  ``gates`` a dict;
* every gate record carries a boolean ``gate_ok``;
* every entry names a known ``trace``, a ``mesh`` matching
  ``ROWSxCOLS`` (with an optional suffix such as ``8x16x16-fleet`` or
  ``6x6-gap``) and a non-empty ``mode``;
* every numeric field in every entry and gate is finite (no NaN/inf);
* no duplicate ``(mesh, trace, mode)`` rows — ``_write_bench`` keys its
  replacement on those, so duplicates mean the merge logic regressed;
* embedded metrics-registry snapshots (an entry's ``metrics`` list, from
  ``repro.obs.registry``) are lists of well-formed metric objects:
  Prometheus-legal unique names, known kinds, finite values.

Run:  python tools/check_bench.py
(the CI gap-gate job; ``tests/test_bench_record.py`` runs the same checks
in tier-1).  Exits non-zero listing every violation.
"""
from __future__ import annotations

import json
import math
import sys
from pathlib import Path
from typing import Any, Dict, List

ROOT = Path(__file__).resolve().parent.parent
BENCH_PATH = ROOT / "BENCH_cluster_sim.json"

# mesh labels: "16x16", "8x16x16-fleet" (pods), "6x6-gap", "32x32-pod-serving"
MESH_RE = r"^\d+x\d+(x\d+)?(-[a-z][a-z-]*)?$"

# traces written by the benchmark scripts; "gap-corpus" is the synthetic
# corpus label used by mapping_engine.py --gap-gate, "chaos-mixed" the
# train-marked mixed trace chaos_sim.py replays under its fault storm
KNOWN_TRACES = frozenset({
    "bursty", "fleet-serving", "large", "mixed", "pod-mixed",
    "pod-serving", "serving", "small", "gap-corpus", "chaos-mixed",
})


#: legal metric names (Prometheus exposition charset)
METRIC_NAME_RE = r"^[a-zA-Z_:][a-zA-Z0-9_:]*$"
METRIC_KINDS = frozenset({"counter", "gauge", "histogram"})


def _check_metrics(prefix: str, metrics: Any, out: List[str]) -> None:
    """Lint one embedded metrics-registry snapshot (``snapshot()`` shape:
    a list of {name, kind, value|count/sum/quantiles} objects)."""
    import re
    if not isinstance(metrics, list):
        out.append(f"{prefix}: metrics is {type(metrics).__name__}, "
                   "expected list")
        return
    name_re = re.compile(METRIC_NAME_RE)
    seen: Dict[str, int] = {}
    for i, m in enumerate(metrics):
        where = f"{prefix}[{i}]"
        if not isinstance(m, dict):
            out.append(f"{where}: not a dict")
            continue
        name, kind = m.get("name"), m.get("kind")
        if not (isinstance(name, str) and name_re.match(name)):
            out.append(f"{where}.name {name!r} does not match "
                       f"{METRIC_NAME_RE}")
        elif name in seen:
            out.append(f"{where} duplicates metric name {name!r} "
                       f"({prefix}[{seen[name]}])")
        else:
            seen[name] = i
        if kind not in METRIC_KINDS:
            out.append(f"{where}.kind {kind!r} not in "
                       f"{sorted(METRIC_KINDS)}")
            continue
        if kind in ("counter", "gauge"):
            v = m.get("value")
            if isinstance(v, bool) or not isinstance(v, (int, float)) \
                    or not math.isfinite(v):
                out.append(f"{where}.value {v!r} is not a finite number")
        else:   # histogram
            for field in ("count", "sum", "min", "max"):
                v = m.get(field)
                if isinstance(v, bool) or not isinstance(v, (int, float)) \
                        or not math.isfinite(v):
                    out.append(f"{where}.{field} {v!r} is not a "
                               "finite number")
            if not isinstance(m.get("quantiles"), dict):
                out.append(f"{where}.quantiles is not a dict")


def _finite_violations(prefix: str, obj: Any, out: List[str]) -> None:
    """Walk nested dicts/lists and flag every non-finite float."""
    if isinstance(obj, dict):
        for k, v in sorted(obj.items()):
            _finite_violations(f"{prefix}.{k}", v, out)
    elif isinstance(obj, (list, tuple)):
        for i, v in enumerate(obj):
            _finite_violations(f"{prefix}[{i}]", v, out)
    elif isinstance(obj, float) and not math.isfinite(obj):
        out.append(f"{prefix}: non-finite value {obj!r}")


def check_record(record: Dict[str, Any]) -> List[str]:
    import re
    violations: List[str] = []
    if record.get("benchmark") != "cluster_sim":
        violations.append(
            f"benchmark field is {record.get('benchmark')!r}, "
            "expected 'cluster_sim'")

    gates = record.get("gates")
    if not isinstance(gates, dict):
        violations.append(f"gates is {type(gates).__name__}, expected dict")
        gates = {}
    for name, gate in sorted(gates.items()):
        if not isinstance(gate, dict):
            violations.append(f"gates[{name!r}] is not a dict")
            continue
        if not isinstance(gate.get("gate_ok"), bool):
            violations.append(f"gates[{name!r}] missing boolean gate_ok")
        _finite_violations(f"gates[{name!r}]", gate, violations)

    entries = record.get("entries")
    if not isinstance(entries, list):
        violations.append(
            f"entries is {type(entries).__name__}, expected list")
        entries = []
    mesh_re = re.compile(MESH_RE)
    seen: Dict[tuple, int] = {}
    for i, e in enumerate(entries):
        if not isinstance(e, dict):
            violations.append(f"entries[{i}] is not a dict")
            continue
        mesh, trace, mode = e.get("mesh"), e.get("trace"), e.get("mode")
        if not (isinstance(mesh, str) and mesh_re.match(mesh)):
            violations.append(
                f"entries[{i}].mesh {mesh!r} does not match {MESH_RE}")
        if trace not in KNOWN_TRACES:
            violations.append(
                f"entries[{i}].trace {trace!r} not a known trace")
        if not (isinstance(mode, str) and mode):
            violations.append(f"entries[{i}].mode {mode!r} is empty")
        key = (mesh, trace, mode)
        if key in seen:
            violations.append(
                f"entries[{i}] duplicates entries[{seen[key]}] "
                f"(mesh={mesh!r}, trace={trace!r}, mode={mode!r})")
        else:
            seen[key] = i
        if "metrics" in e:
            _check_metrics(f"entries[{i}].metrics", e["metrics"],
                           violations)
        _finite_violations(f"entries[{i}]", e, violations)
    return violations


def check_file(path: Path = BENCH_PATH) -> List[str]:
    if not path.exists():
        return [f"{path.name}: missing"]
    try:
        record = json.loads(path.read_text())
    except json.JSONDecodeError as exc:
        return [f"{path.name}: invalid JSON ({exc})"]
    return [f"{path.name}: {v}" for v in check_record(record)]


def main() -> int:
    violations = check_file()
    if violations:
        print(f"check_bench: {len(violations)} violation(s)")
        for v in violations:
            print(f"  - {v}")
        return 1
    record = json.loads(BENCH_PATH.read_text())
    print(f"check_bench: OK ({len(record['entries'])} entries, "
          f"{len(record['gates'])} gates)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
