"""Docs lint: documented commands must not rot.

Extracts fenced ``bash`` code blocks from README.md, docs/architecture.md,
DESIGN.md and docs/observability.md, finds every ``python ...`` invocation,
and checks that

* the referenced script / module file exists in the repo;
* for argparse-based benchmark scripts, every ``--flag`` used in the
  documented command appears in the script's ``--help`` output (the help
  text is fetched once per script via a subprocess);
* ``--trace`` / ``--policy`` values name real entries in the
  ``repro.sched`` registries, and ``--mesh`` values parse as ``rows,cols``;
* relative markdown links in the scanned files resolve to real paths.

Run:  PYTHONPATH=src python tools/check_docs.py
(the CI ``docs`` job; ``tests/test_docs.py`` runs the same checks in
tier-1).  Exits non-zero listing every violation.
"""
from __future__ import annotations

import re
import subprocess
import sys
from pathlib import Path
from typing import Dict, List

ROOT = Path(__file__).resolve().parent.parent

DOC_FILES = ("README.md", "docs/architecture.md", "DESIGN.md",
             "docs/observability.md")

# scripts whose documented flags are validated against their --help output
# (examples/ scripts take no arguments and are only checked for existence)
ARGPARSE_SCRIPTS = ("benchmarks/cluster_sim.py", "benchmarks/mapping_engine.py",
                    "benchmarks/serving_sim.py", "benchmarks/fleet_sim.py",
                    "benchmarks/chaos_sim.py", "tools/trace_report.py")

# non-repo executables we do not try to resolve
SKIP_MODULES = ("pytest", "pip", "doctest", "venv")

_FENCE_RE = re.compile(r"```(?:bash|sh|console)\n(.*?)```", re.DOTALL)
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)#]+)\)")


def extract_commands(text: str) -> List[str]:
    """Command lines (continuations joined, comments stripped) from every
    fenced bash block."""
    out: List[str] = []
    for block in _FENCE_RE.findall(text):
        pending = ""
        for raw in block.splitlines():
            line = raw.strip()
            if not line or line.startswith("#"):
                continue
            line = line.split("#", 1)[0].rstrip()
            if line.endswith("\\"):
                pending += line[:-1] + " "
                continue
            out.append(" ".join((pending + line).split()))
            pending = ""
        if pending:
            out.append(pending.strip())
    return [c for c in out if "python" in c.split()[0] or " python" in c
            or c.startswith("python")]


def parse_python_command(cmd: str):
    """(target, flags, values) of one documented ``python`` invocation.

    ``target`` is a script path or ``-m <module>``; ``flags`` are the
    ``--options`` used; ``values`` maps a flag to its value when given as
    the next token or ``--flag=value``.
    """
    tokens = cmd.split()
    # drop env assignments (PYTHONPATH=src) and the interpreter
    while tokens and ("=" in tokens[0] and not tokens[0].startswith("-")):
        tokens.pop(0)
    if not tokens or not tokens[0].startswith("python"):
        return None
    tokens.pop(0)
    if not tokens:
        return None
    if tokens[0] == "-m":
        target = f"-m {tokens[1]}"
        rest = tokens[2:]
    else:
        target = tokens[0]
        rest = tokens[1:]
    flags: List[str] = []
    values: Dict[str, str] = {}
    i = 0
    while i < len(rest):
        tok = rest[i]
        if tok.startswith("--"):
            if "=" in tok:
                flag, val = tok.split("=", 1)
                flags.append(flag)
                values[flag] = val
            else:
                flags.append(tok)
                if i + 1 < len(rest) and not rest[i + 1].startswith("-"):
                    values[tok] = rest[i + 1]
                    i += 1
        i += 1
    return target, flags, values


def module_path(module: str) -> Path:
    p = ROOT / (module.replace(".", "/") + ".py")
    if p.exists():
        return p
    return ROOT / module.replace(".", "/") / "__main__.py"


class DocChecker:
    def __init__(self) -> None:
        self.errors: List[str] = []
        self._help_cache: Dict[str, str] = {}
        self._registries = None

    # -- helpers -----------------------------------------------------------
    def _help_text(self, script: str) -> str:
        text = self._help_cache.get(script)
        if text is None:
            import os
            env = dict(os.environ)
            env["PYTHONPATH"] = str(ROOT / "src") + (
                os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH")
                else "")
            proc = subprocess.run(
                [sys.executable, script, "--help"], cwd=ROOT, env=env,
                capture_output=True, text=True, timeout=120)
            text = proc.stdout + proc.stderr
            if proc.returncode != 0:
                self.errors.append(f"{script} --help exited "
                                   f"{proc.returncode}: {text[-300:]}")
            self._help_cache[script] = text
        return text

    def _registry(self):
        if self._registries is None:
            sys.path.insert(0, str(ROOT / "src"))
            from repro.sched.policy import POLICIES
            from repro.sched.traces import TRACES
            self._registries = (set(TRACES), set(POLICIES))
        return self._registries

    # -- checks ------------------------------------------------------------
    def check_command(self, doc: str, cmd: str) -> None:
        parsed = parse_python_command(cmd)
        if parsed is None:
            return
        target, flags, values = parsed
        if target.startswith("-m "):
            module = target[3:]
            if module.split(".")[0] in SKIP_MODULES:
                return
            if not module_path(module).exists():
                self.errors.append(
                    f"{doc}: `{cmd}` references missing module {module}")
            return
        script = target
        if not (ROOT / script).exists():
            self.errors.append(
                f"{doc}: `{cmd}` references missing file {script}")
            return
        if script not in ARGPARSE_SCRIPTS:
            return
        help_text = self._help_text(script)
        for flag in flags:
            if flag not in help_text:
                self.errors.append(
                    f"{doc}: `{cmd}` uses {flag}, absent from "
                    f"{script} --help")
        traces, policies = self._registry()
        if "--trace" in values and values["--trace"] not in traces:
            self.errors.append(
                f"{doc}: `{cmd}` names unknown trace "
                f"{values['--trace']!r} (have {sorted(traces)})")
        if "--policy" in values:
            for p in values["--policy"].split(","):
                if p and p not in policies:
                    self.errors.append(
                        f"{doc}: `{cmd}` names unknown policy {p!r}")
        if "--mesh" in values:
            parts = values["--mesh"].split(",")
            if len(parts) != 2 or not all(x.isdigit() for x in parts):
                self.errors.append(
                    f"{doc}: `{cmd}` has malformed --mesh "
                    f"{values['--mesh']!r} (want rows,cols)")

    def check_links(self, doc: str, text: str) -> None:
        base = (ROOT / doc).parent
        for link in _LINK_RE.findall(text):
            link = link.strip()
            if link.startswith(("http://", "https://", "mailto:")):
                continue
            if not (base / link).exists() and not (ROOT / link).exists():
                self.errors.append(f"{doc}: broken link -> {link}")

    def run(self) -> int:
        for doc in DOC_FILES:
            path = ROOT / doc
            if not path.exists():
                self.errors.append(f"missing doc file: {doc}")
                continue
            text = path.read_text()
            self.check_links(doc, text)
            for cmd in extract_commands(text):
                self.check_command(doc, cmd)
        if self.errors:
            print(f"check_docs: {len(self.errors)} problem(s)")
            for e in self.errors:
                print(f"  - {e}")
            return 1
        print(f"check_docs: OK ({', '.join(DOC_FILES)})")
        return 0


def main(argv=None) -> int:
    return DocChecker().run()


if __name__ == "__main__":
    raise SystemExit(main())
