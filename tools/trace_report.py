"""Trace report: summarize + schema-validate Chrome trace-event JSON.

The benchmark CLIs' ``--trace-out`` writes sim-time span traces in the
Chrome trace-event format (viewable at https://ui.perfetto.dev).  This
tool works on those files without a browser:

    python tools/trace_report.py trace.json              # summary
    python tools/trace_report.py trace.json --validate   # CI schema gate
    python tools/trace_report.py trace.json --json       # machine output

The summary reports the top span classes by total sim-time, the
busiest tenants (queued vs executing breakdown — the per-tenant critical
path), instant-event counts and the counter tracks present.

``--validate`` checks every event against the trace-event schema the
:mod:`repro.obs.trace` Tracer emits — required keys per phase, finite
microsecond timestamps, non-negative durations, counter samples with
numeric values — and exits non-zero listing every violation, so the CI
obs-gate catches a malformed emitter before a human ever loads the file.
"""
from __future__ import annotations

import argparse
import json
import math
import sys
from collections import defaultdict
from typing import Any, Dict, List

#: event phases the Tracer emits (complete span, instant, counter, meta)
KNOWN_PHASES = frozenset({"X", "i", "C", "M"})
INSTANT_SCOPES = frozenset({"t", "p", "g"})
META_NAMES = frozenset({"process_name", "thread_name"})


def _finite(v: Any) -> bool:
    return (isinstance(v, (int, float)) and not isinstance(v, bool)
            and math.isfinite(v))


def validate(doc: Any) -> List[str]:
    """Schema violations in a loaded trace document (empty list = valid)."""
    out: List[str] = []
    if isinstance(doc, dict):
        events = doc.get("traceEvents")
        if not isinstance(events, list):
            return ["traceEvents missing or not a list"]
    elif isinstance(doc, list):    # the bare-array spelling is also legal
        events = doc
    else:
        return [f"top level is {type(doc).__name__}, expected dict or list"]

    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            out.append(f"{where}: not a dict")
            continue
        ph = ev.get("ph")
        if ph not in KNOWN_PHASES:
            out.append(f"{where}: unknown phase {ph!r}")
            continue
        if not (isinstance(ev.get("name"), str) and ev["name"]):
            out.append(f"{where}: missing/empty name")
        if not isinstance(ev.get("pid"), int):
            out.append(f"{where}: pid {ev.get('pid')!r} is not an int")
        if not isinstance(ev.get("tid"), int):
            out.append(f"{where}: tid {ev.get('tid')!r} is not an int")
        if ph == "M":
            if ev.get("name") not in META_NAMES:
                out.append(f"{where}: metadata name {ev.get('name')!r} "
                           f"not in {sorted(META_NAMES)}")
            args = ev.get("args")
            if not (isinstance(args, dict)
                    and isinstance(args.get("name"), str)):
                out.append(f"{where}: metadata args.name missing")
            continue
        if not _finite(ev.get("ts")):
            out.append(f"{where}: ts {ev.get('ts')!r} is not finite")
        if ph == "X":
            if not _finite(ev.get("dur")) or ev.get("dur", -1) < 0:
                out.append(f"{where}: dur {ev.get('dur')!r} is not a "
                           "non-negative number")
        elif ph == "i":
            if ev.get("s", "t") not in INSTANT_SCOPES:
                out.append(f"{where}: instant scope {ev.get('s')!r} "
                           f"not in {sorted(INSTANT_SCOPES)}")
        elif ph == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not args:
                out.append(f"{where}: counter args missing")
            else:
                for k, v in args.items():
                    if not _finite(v):
                        out.append(f"{where}: counter series {k!r} value "
                                   f"{v!r} is not finite")
    return out


def summarize(doc: Any, top: int = 12) -> Dict[str, Any]:
    """Aggregate view of one trace (see the module docstring)."""
    events = doc.get("traceEvents", []) if isinstance(doc, dict) else doc
    span_classes: Dict[tuple, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "total_us": 0.0, "max_us": 0.0})
    tenants: Dict[tuple, Dict[str, float]] = defaultdict(
        lambda: {"queued_us": 0.0, "exec_us": 0.0, "spans": 0})
    instants: Dict[str, int] = defaultdict(int)
    counters: Dict[str, int] = defaultdict(int)
    names: Dict[int, str] = {}
    t_min, t_max = math.inf, -math.inf
    n_spans = 0
    for ev in events:
        ph = ev.get("ph")
        if ph == "M":
            if ev.get("name") == "process_name":
                names[ev["pid"]] = ev["args"]["name"]
            continue
        ts = ev.get("ts", 0.0)
        t_min = min(t_min, ts)
        t_max = max(t_max, ts + ev.get("dur", 0.0))
        if ph == "X":
            n_spans += 1
            dur = ev.get("dur", 0.0)
            c = span_classes[(ev.get("cat", ""), ev.get("name", ""))]
            c["count"] += 1
            c["total_us"] += dur
            c["max_us"] = max(c["max_us"], dur)
            tid = ev.get("tid", 0)
            if tid:
                t = tenants[(ev.get("pid", 0), tid)]
                t["spans"] += 1
                key = "queued_us" if ev.get("name") == "queued" \
                    else "exec_us"
                t[key] += dur
        elif ph == "i":
            instants[ev.get("name", "")] += 1
        elif ph == "C":
            counters[ev.get("name", "")] += 1

    classes = sorted(span_classes.items(),
                     key=lambda kv: -kv[1]["total_us"])[:top]
    busiest = sorted(tenants.items(),
                     key=lambda kv: -(kv[1]["queued_us"]
                                      + kv[1]["exec_us"]))[:top]
    return {
        "events": len(events),
        "spans": n_spans,
        "sim_range_s": [round(t_min / 1e6, 6), round(t_max / 1e6, 6)]
        if n_spans or instants or counters else [0.0, 0.0],
        "processes": {str(pid): name for pid, name in sorted(names.items())},
        "span_classes": [
            {"cat": cat, "name": name, "count": int(c["count"]),
             "total_s": round(c["total_us"] / 1e6, 6),
             "max_s": round(c["max_us"] / 1e6, 6)}
            for (cat, name), c in classes],
        "busiest_tenants": [
            {"pid": pid, "tid": tid, "spans": int(t["spans"]),
             "queued_s": round(t["queued_us"] / 1e6, 6),
             "exec_s": round(t["exec_us"] / 1e6, 6)}
            for (pid, tid), t in busiest],
        "instants": dict(sorted(instants.items(),
                                key=lambda kv: -kv[1])),
        "counter_tracks": dict(sorted(counters.items())),
    }


def _print_summary(s: Dict[str, Any]) -> None:
    lo, hi = s["sim_range_s"]
    print(f"{s['events']} events ({s['spans']} spans) over sim "
          f"[{lo:.1f}s, {hi:.1f}s]")
    if s["processes"]:
        procs = ", ".join(f"{pid}={name}"
                          for pid, name in s["processes"].items())
        print(f"processes: {procs}")
    if s["span_classes"]:
        print(f"\ntop span classes by total sim-time:")
        print(f"{'cat':>9} {'name':>12} {'count':>8} {'total_s':>10} "
              f"{'max_s':>9}")
        for c in s["span_classes"]:
            print(f"{c['cat']:>9} {c['name']:>12} {c['count']:>8} "
                  f"{c['total_s']:>10.3f} {c['max_s']:>9.3f}")
    if s["busiest_tenants"]:
        print(f"\nbusiest tenants (critical path = queued + exec):")
        print(f"{'pid':>5} {'tid':>6} {'spans':>6} {'queued_s':>9} "
              f"{'exec_s':>9}")
        for t in s["busiest_tenants"]:
            print(f"{t['pid']:>5} {t['tid']:>6} {t['spans']:>6} "
                  f"{t['queued_s']:>9.3f} {t['exec_s']:>9.3f}")
    if s["instants"]:
        print(f"\ninstants: " + ", ".join(
            f"{k}={v}" for k, v in s["instants"].items()))
    if s["counter_tracks"]:
        print(f"counter tracks: " + ", ".join(
            f"{k}({v} samples)" for k, v in s["counter_tracks"].items()))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome trace-event JSON file "
                                  "(a CLI's --trace-out output)")
    ap.add_argument("--validate", action="store_true",
                    help="schema-check every event; non-zero exit on any "
                         "violation (the CI obs-gate)")
    ap.add_argument("--top", type=int, default=12,
                    help="rows per summary table")
    ap.add_argument("--json", action="store_true", help="machine output")
    args = ap.parse_args(argv)

    try:
        with open(args.trace) as fh:
            doc = json.load(fh)
    except (OSError, json.JSONDecodeError) as exc:
        print(f"trace_report: cannot load {args.trace}: {exc}",
              file=sys.stderr)
        return 1

    violations = validate(doc)
    if args.validate:
        if violations:
            print(f"trace_report: {len(violations)} schema violation(s) "
                  f"in {args.trace}")
            for v in violations[:50]:
                print(f"  - {v}")
            if len(violations) > 50:
                print(f"  ... and {len(violations) - 50} more")
            return 1
        n = len(doc.get("traceEvents", doc) if isinstance(doc, dict)
                else doc)
        print(f"trace_report: OK ({n} events, schema-valid)")

    summary = summarize(doc, top=args.top)
    if violations and not args.validate:
        summary["schema_violations"] = len(violations)
    if args.json:
        print(json.dumps(summary, indent=2))
    else:
        _print_summary(summary)
        if violations and not args.validate:
            print(f"\nWARNING: {len(violations)} schema violation(s) — "
                  f"run with --validate for details")
    return 0


if __name__ == "__main__":
    sys.exit(main())
