"""InterferenceLedger: incremental occupancy == oracle recompute across
random allocate/release/migrate/fail sequences, and scheduler-level
bit-identity of ledger-based epoch scoring vs the O(R^2 x flows) oracle."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # property tests degrade, unit tests still run
    from _hypothesis_fallback import given, settings, st

from repro.core import mesh_2d
from repro.core import simulator as S
from repro.core import workloads as W
from repro.core.simulator import Flow, flow_link_loads, flow_paths, \
    link_contention
from repro.sched import (ClusterScheduler, InterferenceLedger, TenantSpec,
                         make_policy, make_trace)
from repro.sched.traces import TRACES


def _spec(tid=1, model="resnet18", n_cores=4, arrival=0.0, duration=10.0,
          **kw):
    return TenantSpec(tid=tid, model=model, n_cores=n_cores,
                      arrival_s=arrival, duration_s=duration, **kw)


# ---------------------------------------------------------------------------
# simulator: the pre-aggregated external-loads fast path
# ---------------------------------------------------------------------------

class TestExternalLinkLoads:
    def test_flow_link_loads_aggregates_directed_edges(self):
        topo = mesh_2d(1, 3)
        loads = flow_link_loads(topo, [
            Flow(src=0, dst=2, bytes_per_iter=100),
            Flow(src=1, dst=2, bytes_per_iter=50),
            Flow(src=2, dst=0, bytes_per_iter=7),    # opposite direction
            Flow(src=1, dst=1, bytes_per_iter=9),    # no edges
            Flow(src=0, dst=1, bytes_per_iter=0),    # zero bytes: pruned
        ])
        assert loads == {(0, 1): 100.0, (1, 2): 150.0, (2, 1): 7.0,
                         (1, 0): 7.0}

    def test_link_contention_external_loads_equals_flow_list(self):
        """Seeding link_contention with aggregated loads must match listing
        the external flows explicitly — exactly, not approximately."""
        topo = mesh_2d(4, 4)
        rng = np.random.default_rng(0)
        nodes = sorted(topo.node_attrs)
        for _ in range(20):
            own = [Flow(int(rng.choice(nodes)), int(rng.choice(nodes)),
                        int(rng.integers(0, 1 << 20)), owner=1)
                   for _ in range(4)]
            ext = [Flow(int(rng.choice(nodes)), int(rng.choice(nodes)),
                        int(rng.integers(0, 1 << 20)), owner=2)
                   for _ in range(6)]
            all_flows = own + ext
            ref = link_contention(flow_paths(topo, all_flows),
                                  all_flows)[:len(own)]
            fast = link_contention(flow_paths(topo, own), own,
                                   external_loads=flow_link_loads(topo, ext))
            assert fast == ref

    @pytest.mark.parametrize("model,cores", [
        ("resnet18", [0, 1, 2, 3]),            # pipeline
        ("gpt2_small", [0, 1, 6, 7]),          # tensor-parallel ring
    ])
    def test_simulate_external_link_loads_bit_identical(self, model, cores):
        topo = mesh_2d(6, 6)
        hw = S.SIM_CONFIG
        g = W.get_workload(model)
        ext = S.tenant_flows(W.get_workload("transformer"), [14, 15, 20, 21],
                             topo, hw, owner=9)
        ref = S.simulate(g, cores, topo, hw, external_flows=ext)
        fast = S.simulate(g, cores, topo, hw,
                          external_link_loads=flow_link_loads(topo, ext))
        assert fast.interval_cycles == ref.interval_cycles
        assert fast.fps == ref.fps
        assert fast.latency_cycles == ref.latency_cycles

    def test_empty_loads_dict_keeps_ring_self_contention(self):
        """external_link_loads={} must mean 'external flows exist but load
        none of my links' (ring self-contention computed), while omitting it
        means 'no external flows' (contention skipped) — the oracle's
        flow-list truthiness semantics."""
        topo = mesh_2d(6, 6)
        hw = S.SIM_CONFIG
        g = W.get_workload("gpt2_small")
        cores = [0, 1, 6, 7]
        quiet = S.simulate(g, cores, topo, hw)
        # a co-located TDM flow has src == dst: a real external flow with no
        # link footprint
        ext = [Flow(src=30, dst=30, bytes_per_iter=1 << 20, owner=2)]
        ref = S.simulate(g, cores, topo, hw, external_flows=ext)
        fast = S.simulate(g, cores, topo, hw, external_link_loads={})
        assert fast.interval_cycles == ref.interval_cycles
        # the switch matters: the ring contends with itself on this layout
        assert ref.interval_cycles >= quiet.interval_cycles


# ---------------------------------------------------------------------------
# the ledger property: incremental occupancy == oracle recompute
# ---------------------------------------------------------------------------

def _random_flows(rng, nodes, tid, max_flows=6):
    n = int(rng.integers(0, max_flows + 1))
    return [Flow(src=int(rng.choice(nodes)), dst=int(rng.choice(nodes)),
                 bytes_per_iter=int(rng.integers(0, 1 << 22)), owner=tid)
            for _ in range(n)]


class TestLedgerOccupancyProperty:
    @staticmethod
    def _churn_check(seed):
        """Random allocate/release/migrate/fail churn: the incrementally-
        maintained link occupancy must always equal a from-scratch
        aggregation of the current residents' flows — exactly."""
        rng = np.random.default_rng(seed)
        topo = mesh_2d(5, 5)
        nodes = sorted(topo.node_attrs)
        led = InterferenceLedger(topo)
        flows_by_tid = {}
        next_tid = 1
        for _ in range(40):
            u = rng.random()
            if flows_by_tid and u < 0.3:                    # release
                tid = int(rng.choice(sorted(flows_by_tid)))
                led.remove(tid)
                del flows_by_tid[tid]
            elif flows_by_tid and u < 0.55:                 # migrate / fail
                tid = int(rng.choice(sorted(flows_by_tid)))
                flows = _random_flows(rng, nodes, tid)
                led.update(tid, flows, hbm_client=bool(rng.random() < 0.2))
                flows_by_tid[tid] = flows
            else:                                           # allocate
                tid = next_tid
                next_tid += 1
                flows = _random_flows(rng, nodes, tid)
                led.add(tid, flows, hbm_client=bool(rng.random() < 0.2))
                flows_by_tid[tid] = flows
            led.check_invariants()
            assert led.link_loads == led.oracle_link_loads(flows_by_tid)
            for tid in flows_by_tid:
                others = {t: f for t, f in flows_by_tid.items() if t != tid}
                assert led.external_loads(tid) == \
                    led.oracle_link_loads(others)
                assert led.has_external(tid) == \
                    any(f for f in others.values())

    @given(st.integers(0, 10_000))
    @settings(max_examples=15, deadline=None)
    def test_ledger_matches_oracle(self, seed):
        self._churn_check(seed)

    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_ledger_matches_oracle_seeded(self, seed):
        # deterministic variant that runs even without hypothesis
        self._churn_check(seed)

    def test_double_add_rejected(self):
        led = InterferenceLedger(mesh_2d(3, 3))
        led.add(1, [])
        with pytest.raises(ValueError):
            led.add(1, [])

    def test_remove_unknown_is_noop(self):
        led = InterferenceLedger(mesh_2d(3, 3))
        led.remove(99)
        assert led.link_loads == {} and not led.dirty


class TestLedgerDirtySet:
    def test_disjoint_tenants_do_not_dirty_each_other(self):
        """Two tenants in opposite mesh corners share no links: placing and
        removing one must not invalidate the other once both are scored."""
        topo = mesh_2d(6, 6)
        led = InterferenceLedger(topo)
        far = [Flow(src=28, dst=35, bytes_per_iter=1000, owner=2)]
        near = [Flow(src=0, dst=7, bytes_per_iter=1000, owner=1)]
        led.add(1, near)
        led.add(2, far)           # crosses the 0/1 external boundary
        led.take_dirty()
        led.add(3, [Flow(src=30, dst=31, bytes_per_iter=10, owner=3)])
        # tenant 1's links (top-left) are untouched by tenant 3 (bottom row)
        assert 1 not in led.dirty and 3 in led.dirty
        led.take_dirty()
        led.remove(3)
        assert 1 not in led.dirty

    def test_overlapping_footprints_dirty(self):
        topo = mesh_2d(6, 6)
        led = InterferenceLedger(topo)
        led.add(1, [Flow(src=0, dst=2, bytes_per_iter=1000, owner=1)])
        led.take_dirty()
        led.add(2, [Flow(src=1, dst=3, bytes_per_iter=1000, owner=2)])
        assert {1, 2} <= led.dirty   # share the (1, 2) directed link

    def test_lone_flow_tenant_flips_on_boundary(self):
        """The tensor model computes ring self-contention only when external
        flows exist — so the 0<->1 co-resident-with-flows boundary must
        dirty the lone flow tenant even with disjoint links."""
        topo = mesh_2d(6, 6)
        led = InterferenceLedger(topo)
        led.add(1, [Flow(src=0, dst=1, bytes_per_iter=10, owner=1)])
        led.take_dirty()
        led.add(2, [Flow(src=34, dst=35, bytes_per_iter=10, owner=2)])
        assert 1 in led.dirty         # gained external traffic
        led.take_dirty()
        led.remove(2)
        assert 1 in led.dirty         # lost all external traffic

    def test_hbm_client_dirties_everyone(self):
        topo = mesh_2d(6, 6)
        led = InterferenceLedger(topo)
        led.add(1, [Flow(src=0, dst=1, bytes_per_iter=10, owner=1)])
        led.add(2, [])
        led.take_dirty()
        led.add(3, [], hbm_client=True)
        assert {1, 2, 3} <= led.dirty
        assert led.hbm_clients == 1
        led.take_dirty()
        led.remove(3)
        assert {1, 2} <= led.dirty and led.hbm_clients == 0


# ---------------------------------------------------------------------------
# scheduler-level: ledger scoring bit-identical to the oracle
# ---------------------------------------------------------------------------

def _run_both(policy_name, trace, mesh=(6, 6), failures=(), **kw):
    out = {}
    for mode in ("ledger", "oracle"):
        policy = make_policy(policy_name, mesh_2d(*mesh))
        sched = ClusterScheduler(policy, epoch_s=2.0, rescore=mode, **kw)
        out[mode] = sched.run(trace, trace_name="t", failures=failures)
    return out["ledger"], out["oracle"]


def _trajectory(m):
    return ([(s.t, s.agg_fps, s.utilization, s.n_resident, s.n_queued)
             for s in m.samples], m.tenant_iterations, m.tenant_active_s,
            m.n_admitted, m.n_rejected, m.n_migrations)


class TestSchedulerLedgerEqualsOracle:
    @pytest.mark.parametrize("policy", ["vnpu", "mig", "uvm"])
    def test_mixed_trace_bit_identical(self, policy):
        trace = make_trace("mixed", seed=7, horizon_s=35.0)
        ledger, oracle = _run_both(policy, trace)
        assert _trajectory(ledger) == _trajectory(oracle)
        assert ledger.ledger_counters and not oracle.ledger_counters

    def test_pod_mixed_trace_bit_identical(self):
        # the full-size pod-mixed identity check runs in the CI gate
        # (cluster_sim --gate, 16x16); here keep tier-1 fast by dropping
        # the asks that dwarf an 8x8 mesh and would only exercise the
        # engine's (already-gated) fragmented fallback over and over
        trace = [t for t in make_trace("pod-mixed", seed=3, horizon_s=8.0)
                 if t.n_cores <= 16]
        assert trace
        ledger, oracle = _run_both("vnpu", trace, mesh=(8, 8))
        assert _trajectory(ledger) == _trajectory(oracle)

    @pytest.mark.parametrize("policy", ["vnpu", "mig", "uvm"])
    def test_bit_identical_under_failures(self, policy):
        """allocate/release/migrate/fail all maintain the ledger: inject
        core failures mid-trace and require identical trajectories."""
        trace = make_trace("mixed", seed=11, horizon_s=30.0)
        failures = [(8.0, (0, 1)), (18.0, (22,))]
        ledger, oracle = _run_both(policy, trace, failures=failures)
        assert _trajectory(ledger) == _trajectory(oracle)
        assert ledger.n_failed_cores == 3

    def test_repeated_failure_of_same_core_counted_once(self):
        pol = make_policy("uvm", mesh_2d(3, 3))
        sched = ClusterScheduler(pol, epoch_s=1.0)
        m = sched.run([_spec(tid=1, n_cores=2, duration=10.0)],
                      failures=[(2.0, (8,)), (4.0, (8, 7))])
        assert m.n_failed_cores == 2          # core 8 died once, not twice

    @pytest.mark.parametrize("policy", ["mig", "uvm"])
    def test_baseline_policies_quarantine_and_evacuate(self, policy):
        """Failure injection is meaningful for the baselines too: dead
        cores leave the free pool and the resident is moved off them."""
        pol = make_policy(policy, mesh_2d(4, 4))
        sched = ClusterScheduler(pol, epoch_s=1.0)
        spec = _spec(tid=1, model="resnet18", n_cores=4, duration=20.0)
        m = sched.run([spec], failures=[(5.0, (0,))])
        assert m.n_admitted == 1
        assert m.n_failed_cores == 1
        assert m.n_migrations >= 1
        assert 0 not in pol.free_cores()
        # quarantine persists after the tenant departs
        assert pol.utilization() == 0.0

    def test_uvm_defrag_migration_still_pointless(self):
        pol = make_policy("uvm", mesh_2d(3, 3))
        p = pol.allocate(_spec(tid=1, n_cores=3))
        assert pol.migrate(p) == (p, False)   # no avoid overlap: no move

    def test_failure_quarantines_and_migrates(self):
        pol = make_policy("vnpu", mesh_2d(4, 4))
        sched = ClusterScheduler(pol, epoch_s=1.0)
        spec = _spec(tid=1, model="resnet18", n_cores=4, duration=20.0)
        m = sched.run([spec], failures=[(5.0, (0,))])
        assert m.n_admitted == 1
        assert m.n_failed_cores == 1
        assert m.n_migrations >= 1       # resident moved off the dead core
        assert 0 not in pol.free_cores() # quarantined, never freed

    def test_ledger_reuses_scores(self):
        """The point of the tentpole: a run must *reuse* some cached tenant
        scores (the oracle recomputes everything every pass)."""
        trace = make_trace("mixed", seed=7, horizon_s=35.0)
        ledger, _ = _run_both("vnpu", trace)
        lc = ledger.ledger_counters
        assert lc["reused"] > 0
        assert lc["reuse_rate"] > 0.0

    def test_invalid_rescore_mode_rejected(self):
        with pytest.raises(ValueError):
            ClusterScheduler(make_policy("uvm", mesh_2d(3, 3)),
                             rescore="nope")


# ---------------------------------------------------------------------------
# pod-mixed trace family
# ---------------------------------------------------------------------------

class TestPodMixedTrace:
    def test_registered_with_pod_rates(self):
        cfg = TRACES["pod-mixed"]
        assert cfg.intended_mesh == "16x16-32x32"
        trace = make_trace("pod-mixed", seed=1, horizon_s=20.0)
        assert trace
        assert max(t.n_cores for t in trace) > 9     # beyond 6x6 asks
        # arrival rate matched to pods: ~2.2/s vs mixed's 0.45/s
        assert len(trace) > len(make_trace("mixed", seed=1, horizon_s=20.0))

    def test_deterministic(self):
        a = make_trace("pod-mixed", seed=5, horizon_s=15.0)
        b = make_trace("pod-mixed", seed=5, horizon_s=15.0)
        assert [(t.tid, t.arrival_s, t.n_cores) for t in a] == \
            [(t.tid, t.arrival_s, t.n_cores) for t in b]
