"""End-to-end behaviour tests: training convergence, checkpoint/restart,
serving, data determinism, gradient compression — system-level invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import reduce_for_smoke
from repro.data import DataConfig, make_batch
from repro.models import build
from repro.train import (AdamWConfig, TrainConfig, init_state,
                         make_train_step, train_loop)


def _bundle(arch="llama3_2_1b"):
    cfg = reduce_for_smoke(get_config(arch))
    return build(cfg), cfg


class TestTraining:
    def test_loss_decreases(self):
        bundle, cfg = _bundle()
        dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                          global_batch=8)
        tcfg = TrainConfig(opt=AdamWConfig(lr=3e-3, warmup_steps=2))

        def it():
            s = 0
            while True:
                yield {k: jnp.asarray(v)
                       for k, v in make_batch(dcfg, s).items()}
                s += 1

        state, hist = train_loop(bundle, tcfg, it(), n_steps=30,
                                 key=jax.random.PRNGKey(0), log_every=1)
        assert hist[-1]["loss"] < hist[0]["loss"] * 0.8
        assert np.isfinite(hist[-1]["loss"])

    def test_grad_accum_close_to_full_batch(self):
        bundle, cfg = _bundle()
        params = bundle.init(jax.random.PRNGKey(0))
        dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                          global_batch=8)
        batch = {k: jnp.asarray(v) for k, v in make_batch(dcfg, 0).items()}
        s1 = init_state(params, AdamWConfig(lr=1e-3))
        s2 = init_state(params, AdamWConfig(lr=1e-3))
        step1 = jax.jit(make_train_step(bundle.loss,
                                        TrainConfig(opt=AdamWConfig(lr=1e-3))))
        step2 = jax.jit(make_train_step(
            bundle.loss, TrainConfig(opt=AdamWConfig(lr=1e-3), grad_accum=2)))
        s1, _ = step1(s1, batch)
        s2, _ = step2(s2, batch)
        d = [float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                   b.astype(jnp.float32))))
             for a, b in zip(jax.tree.leaves(s1["params"]),
                             jax.tree.leaves(s2["params"]))]
        assert max(d) < 2e-2

    def test_int8_moments_close_to_fp32(self):
        bundle, cfg = _bundle("qwen2_0_5b")
        params = bundle.init(jax.random.PRNGKey(0))
        dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                          global_batch=4)
        batch = {k: jnp.asarray(v) for k, v in make_batch(dcfg, 0).items()}
        outs = {}
        for md in ("float32", "int8"):
            tcfg = TrainConfig(opt=AdamWConfig(lr=1e-3, moment_dtype=md))
            step = jax.jit(make_train_step(bundle.loss, tcfg))
            st = init_state(params, tcfg.opt)
            for _ in range(3):
                st, m = step(st, batch)
            outs[md] = float(m["loss"])
        assert abs(outs["int8"] - outs["float32"]) < 0.2


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        from repro.checkpoint import (latest_step, restore_checkpoint,
                                      save_checkpoint)
        bundle, _ = _bundle()
        params = bundle.init(jax.random.PRNGKey(0))
        opt = AdamWConfig(lr=1e-3)
        state = init_state(params, opt)
        save_checkpoint(str(tmp_path), state, step=7)
        assert latest_step(str(tmp_path)) == 7
        like = jax.eval_shape(lambda: init_state(
            bundle.init(jax.random.PRNGKey(0)), opt))
        restored, step = restore_checkpoint(str(tmp_path), like)
        assert step == 7
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

    def test_resume_continues_training(self, tmp_path):
        from repro.checkpoint import restore_checkpoint
        bundle, cfg = _bundle()
        dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                          global_batch=4)
        tcfg = TrainConfig(opt=AdamWConfig(lr=1e-3, warmup_steps=1))

        def it(start=0):
            s = start
            while True:
                yield {k: jnp.asarray(v)
                       for k, v in make_batch(dcfg, s).items()}
                s += 1

        state, _ = train_loop(bundle, tcfg, it(), n_steps=4,
                              key=jax.random.PRNGKey(0),
                              checkpoint_dir=str(tmp_path),
                              checkpoint_every=4)
        like = jax.eval_shape(lambda: init_state(
            bundle.init(jax.random.PRNGKey(0)), tcfg.opt))
        restored, step = restore_checkpoint(str(tmp_path), like)
        assert step == 4
        state2, hist = train_loop(bundle, tcfg, it(4), n_steps=2,
                                  state=restored)
        assert int(state2["step"]) == 6


class TestData:
    def test_determinism_and_host_sharding(self):
        d0 = DataConfig(vocab_size=1000, seq_len=64, global_batch=8,
                        host_index=0, host_count=2)
        d1 = dataclasses.replace(d0, host_index=1)
        a = make_batch(d0, 5)["tokens"]
        b = make_batch(d0, 5)["tokens"]
        c = make_batch(d1, 5)["tokens"]
        np.testing.assert_array_equal(a, b)
        assert not np.array_equal(a, c)
        assert a.shape == (4, 64)


class TestCompression:
    def test_quantize_roundtrip_error_bounded(self):
        from repro.train.optimizer import dequantize_q8, quantize_q8
        x = jax.random.normal(jax.random.PRNGKey(0), (64, 384)) * 3.0
        q = quantize_q8(x)
        r = dequantize_q8(q, 384)
        err = jnp.max(jnp.abs(r - x))
        assert float(err) <= float(jnp.max(jnp.abs(x))) / 127 + 1e-6

    def test_error_feedback_preserves_mean_gradient(self):
        from repro.parallel import make_error_feedback_compressor
        compress, init = make_error_feedback_compressor()
        g = {"w": jnp.full((4, 256), 1e-3)}
        r = init(g)
        total = jnp.zeros((4, 256))
        for _ in range(8):
            gq, r = compress(g, r)
            total = total + gq["w"]
        np.testing.assert_allclose(np.asarray(total / 8),
                                   np.asarray(g["w"]), atol=3e-4)

    def test_wire_ratio_near_4x(self):
        from repro.parallel import compression_ratio
        g = {"a": jnp.zeros((1024, 1024)), "b": jnp.zeros((512, 512))}
        assert 3.5 < compression_ratio(g) <= 4.0


class TestServing:
    def test_engine_end_to_end(self):
        from repro.serve import EngineConfig, ServeEngine
        bundle, cfg = _bundle()
        params = bundle.init(jax.random.PRNGKey(0))
        eng = ServeEngine(bundle, params,
                          EngineConfig(batch_size=2, max_seq=64))
        rng = np.random.default_rng(0)
        for _ in range(2):
            eng.submit(rng.integers(0, cfg.vocab_size - 1, size=8
                                    ).astype(np.int32), max_new_tokens=4)
        reqs = eng.run()
        assert all(r.done and len(r.out_tokens) == 4 for r in reqs)
        assert all(0 <= t < cfg.vocab_size
                   for r in reqs for t in r.out_tokens)
