"""Bench-record lint in tier-1: BENCH_cluster_sim.json must stay
machine-checkable (the same checks the CI gap-gate job runs via
tools/check_bench.py)."""
import importlib.util
import json
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

_spec = importlib.util.spec_from_file_location(
    "check_bench", ROOT / "tools" / "check_bench.py")
check_bench = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_bench)


def _minimal_record():
    return {
        "benchmark": "cluster_sim",
        "gates": {"serving": {"gate_ok": True, "budget": 60.0}},
        "entries": [
            {"mesh": "8x8", "trace": "mixed", "mode": "ledger",
             "wall_s": 0.5},
        ],
    }


class TestCheckRecord:
    def test_minimal_record_is_clean(self):
        assert check_bench.check_record(_minimal_record()) == []

    def test_wrong_benchmark_name(self):
        rec = _minimal_record()
        rec["benchmark"] = "other"
        assert any("benchmark" in v
                   for v in check_bench.check_record(rec))

    def test_gate_without_verdict(self):
        rec = _minimal_record()
        del rec["gates"]["serving"]["gate_ok"]
        assert any("gate_ok" in v for v in check_bench.check_record(rec))

    def test_nan_is_flagged(self):
        rec = _minimal_record()
        rec["entries"][0]["wall_s"] = float("nan")
        assert any("non-finite" in v
                   for v in check_bench.check_record(rec))

    def test_bad_mesh_label(self):
        rec = _minimal_record()
        rec["entries"][0]["mesh"] = "not-a-mesh"
        assert any(".mesh" in v for v in check_bench.check_record(rec))

    def test_unknown_trace(self):
        rec = _minimal_record()
        rec["entries"][0]["trace"] = "made-up"
        assert any(".trace" in v for v in check_bench.check_record(rec))

    def test_gap_suffixed_mesh_accepted(self):
        rec = _minimal_record()
        rec["entries"][0].update(mesh="6x6-gap", trace="gap-corpus",
                                 mode="gap-hybrid")
        assert check_bench.check_record(rec) == []

    def test_pod_mesh_accepted(self):
        rec = _minimal_record()
        rec["entries"][0].update(mesh="8x16x16-fleet", trace="fleet-serving",
                                 mode="fleet")
        assert check_bench.check_record(rec) == []

    def test_duplicate_rows_flagged(self):
        rec = _minimal_record()
        rec["entries"].append(dict(rec["entries"][0]))
        assert any("duplicates" in v
                   for v in check_bench.check_record(rec))

    def test_embedded_metrics_snapshot_clean(self):
        rec = _minimal_record()
        rec["entries"][0]["metrics"] = [
            {"name": "cluster_admitted_total", "kind": "counter",
             "value": 12},
            {"name": "cluster_ttft_seconds", "kind": "histogram",
             "count": 2, "sum": 1.0, "min": 0.4, "max": 0.6,
             "quantiles": {"0.5": 0.5}},
        ]
        assert check_bench.check_record(rec) == []

    def test_embedded_metrics_violations_flagged(self):
        rec = _minimal_record()
        rec["entries"][0]["metrics"] = [
            {"name": "bad name", "kind": "counter", "value": 1},
            {"name": "dup_total", "kind": "counter", "value": 1},
            {"name": "dup_total", "kind": "gauge",
             "value": float("inf")},
            {"name": "h", "kind": "histogram", "count": float("nan"),
             "sum": 0.0, "min": 0.0, "max": 0.0, "quantiles": {}},
        ]
        out = check_bench.check_record(rec)
        assert any("does not match" in v for v in out)
        assert any("duplicates metric name" in v for v in out)
        assert any(".value" in v and "finite" in v for v in out)
        assert any(".count" in v and "finite" in v for v in out)

    def test_metrics_not_a_list_flagged(self):
        rec = _minimal_record()
        rec["entries"][0]["metrics"] = {"name": "x"}
        assert any("expected list" in v
                   for v in check_bench.check_record(rec))


class TestRepoRecord:
    def test_checked_in_record_is_clean(self):
        assert check_bench.check_file() == []

    def test_gap_gate_recorded_and_passing(self):
        record = json.loads(check_bench.BENCH_PATH.read_text())
        gate = record["gates"]["gap-gate"]
        assert gate["gate_ok"] is True
        assert gate["no_mapper_beats_oracle"] is True
        # the pinned bounds in benchmarks/mapping_engine.py are what CI
        # enforces; the checked-in record must agree with them
        for mapper, b in gate["bounds"].items():
            assert b["ok"] is True
            assert b["max_ted_gap"] <= b["bound"]

    def test_gap_entries_present_for_all_corpora(self):
        record = json.loads(check_bench.BENCH_PATH.read_text())
        gap_meshes = {e["mesh"] for e in record["entries"]
                      if e["trace"] == "gap-corpus"}
        assert {"6x6-gap", "8x8-gap", "10x10-gap",
                "12x12-gap", "16x16-gap"} <= gap_meshes
