"""Meshed tests (8 host devices, 2x4): sharded train/forward equivalence,
sequence-parallel SSD exactness, vNPU->Mesh integration, elastic remap,
simulator sanity, roofline parsing."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, SHAPES
from repro.configs.base import reduce_for_smoke
from repro.launch.mesh import make_test_mesh
from repro.models import build
from repro.models.common import (clear_mesh_context, set_activation_rules,
                                 set_mesh_context)
from repro.parallel import seq_parallel_ssd, sharding as shd

pytestmark = pytest.mark.skipif(len(jax.devices()) < 8,
                                reason="needs 8 host devices")


def _mesh():
    return make_test_mesh((2, 4), ("data", "model"))


class TestShardedEquivalence:
    @pytest.mark.parametrize("arch", ["llama3_2_1b", "hymba_1_5b"])
    def test_meshed_forward_matches_local(self, arch):
        mesh = _mesh()
        cfg = dataclasses.replace(reduce_for_smoke(get_config(arch)),
                                  d_model=64, vocab_size=256,
                                  param_dtype="float32")
        bundle = build(cfg)
        key = jax.random.PRNGKey(0)
        clear_mesh_context()
        params = bundle.init(key)
        batch = {"tokens": jax.random.randint(key, (4, 64), 0, 255)}
        ref = np.asarray(bundle.forward(params, batch), np.float32)

        set_mesh_context(mesh, shd.batch_axes(mesh))
        set_activation_rules(shd.activation_rules(mesh))
        pshard = shd.named_shardings(
            mesh, shd.param_specs(bundle.param_logical_axes(),
                                  shd.param_rules(mesh)))
        bshard = shd.named_shardings(mesh, shd.batch_specs(batch, mesh))
        with mesh:
            out = jax.jit(bundle.forward, in_shardings=(pshard, bshard))(
                jax.device_put(params, pshard),
                jax.device_put(batch, bshard))
        np.testing.assert_allclose(ref, np.asarray(out, np.float32),
                                   rtol=2e-3, atol=2e-3)

    def test_moe_ep_matches_local_when_no_drops(self):
        from repro.models.moe import moe_forward, moe_init
        mesh = _mesh()
        cfg = dataclasses.replace(reduce_for_smoke(get_config(
            "deepseek_moe_16b")), d_model=64, capacity_factor=16.0)
        p, _ = moe_init(cfg, jax.random.PRNGKey(1), jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(2), (4, 16, 64), jnp.float32)
        y_ref, _ = moe_forward(cfg, p, x, mesh=None)
        specs = {"router": P(), "wg": P("model", None, None),
                 "wu": P("model", None, None), "wd": P("model", None, None),
                 "shared_wg": P(None, "model"), "shared_wu": P(None, "model"),
                 "shared_wd": P("model", None)}
        pm = {k: jax.device_put(v, NamedSharding(mesh, specs[k]))
              for k, v in p.items()}
        xm = jax.device_put(x, NamedSharding(mesh, P(("data",), None, None)))
        with mesh:
            y, _ = jax.jit(lambda pp, xx: moe_forward(cfg, pp, xx, mesh=mesh)
                           )(pm, xm)
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y),
                                   rtol=1e-4, atol=1e-4)


class TestSeqParallel:
    def test_sp_ssd_matches_serial(self):
        from repro.models.ssd import ssd_scan_ref
        mesh = _mesh()
        b, S, H, Pd, N = 1, 128, 4, 8, 16
        k = jax.random.PRNGKey(0)
        x = jax.random.normal(k, (b, S, H, Pd)) * 0.5
        dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1),
                                               (b, S, H)))
        A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (H,)) * 0.3)
        B = jax.random.normal(jax.random.PRNGKey(3), (b, S, 1, N)) * 0.5
        C = jax.random.normal(jax.random.PRNGKey(4), (b, S, 1, N)) * 0.5
        ref = ssd_scan_ref(x, dt, A, B, C, 16)
        with mesh:
            out = seq_parallel_ssd(x, dt, A, B, C, chunk=16, mesh=mesh,
                                   axis="data")
        np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                                   rtol=2e-4, atol=2e-4)


class TestVMesh:
    def test_tenant_mesh_and_elastic_remap(self):
        from repro.core import (DeviceTopology, Hypervisor, allocate_tenant,
                                elastic_remap, mesh_2d)
        devs = jax.devices()[:8]
        dt = DeviceTopology.from_devices(devs, (2, 4))
        hyp = Hypervisor(dt.topo, hbm_bytes=1 << 30)
        tenant = allocate_tenant(hyp, dt, mesh_2d(2, 2, base_id=100))
        assert tenant.mesh.devices.shape == (2, 2)
        # run a tiny sharded computation on the tenant mesh
        x = jnp.arange(8.0).reshape(4, 2)
        y = jax.jit(lambda a: a * 2,
                    in_shardings=NamedSharding(tenant.mesh,
                                               P("data", "model")),
                    )(x)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x) * 2)
        # kill one allocated node; remap must avoid it
        dead = next(iter(tenant.vnpu.p_cores))
        t2 = elastic_remap(hyp, dt, tenant, [dead])
        assert dead not in t2.vnpu.p_cores
        assert t2.mesh.devices.shape == (2, 2)

    def test_tenants_get_disjoint_devices(self):
        from repro.core import DeviceTopology, Hypervisor, allocate_tenant, \
            mesh_2d
        devs = jax.devices()[:8]
        dt = DeviceTopology.from_devices(devs, (2, 4))
        hyp = Hypervisor(dt.topo, hbm_bytes=1 << 30)
        t1 = allocate_tenant(hyp, dt, mesh_2d(1, 4, base_id=50))
        t2 = allocate_tenant(hyp, dt, mesh_2d(1, 4, base_id=60))
        d1 = {d.id for d in t1.mesh.devices.flat}
        d2 = {d.id for d in t2.mesh.devices.flat}
        assert not (d1 & d2)


class TestRooflineParsing:
    def test_collective_regex(self):
        from repro.roofline import collective_bytes
        hlo = """
  %ag = bf16[2,1024,128]{2,1,0} all-gather(%x), replica_groups={}
  %ar = f32[512]{0} all-reduce(%y), to_apply=%add
  %cp = bf16[4,4]{1,0} collective-permute(%z)
"""
        out = collective_bytes(hlo)
        assert out["all-gather"] == 2 * 1024 * 128 * 2
        assert out["all-reduce"] == 512 * 4
        assert out["collective-permute"] == 32

    def test_while_aware_multiplies_trip_count(self):
        from repro.roofline import collective_bytes_while_aware
        hlo = """
%cond.1 (a: s32[]) -> pred[] {
  %c = s32[] constant(24)
  ROOT %cmp = pred[] compare(%a, %c), direction=LT
}

%body.1 (a: s32[]) -> s32[] {
  %ar = f32[128]{0} all-reduce(%p), to_apply=%add
  ROOT %n = s32[] add(%a, %one)
}

ENTRY %main (p: f32[128]) -> f32[128] {
  %w = s32[] while(s32[] %i), condition=%cond.1, body=%body.1
  %ag = f32[64]{0} all-gather(%p)
  ROOT %r = f32[128] %p
}
"""
        out = collective_bytes_while_aware(hlo)
        assert out["all-reduce"] == 24 * 128 * 4
        assert out["all-gather"] == 64 * 4

    def test_analytic_flops_match_xla_on_dense(self):
        """Analytic model vs unrolled XLA cost analysis (small dense cell)."""
        from repro.roofline.analytic import step_flops
        from repro.models.common import set_scan_unroll
        cfg = dataclasses.replace(
            reduce_for_smoke(get_config("llama3_2_1b")),
            d_model=64, vocab_size=256, n_layers=2)
        shape = dataclasses.replace(SHAPES["train_4k"], seq_len=128,
                                    global_batch=4)
        analytic = step_flops(cfg, shape)
        bundle = build(cfg)
        from repro.train import AdamWConfig, TrainConfig, init_state, \
            make_train_step
        tcfg = TrainConfig(opt=AdamWConfig())
        step = make_train_step(bundle.loss, tcfg)
        state = jax.eval_shape(lambda: init_state(
            bundle.init(jax.random.PRNGKey(0)), tcfg.opt))
        batch = {"tokens": jax.ShapeDtypeStruct((4, 128), jnp.int32)}
        set_scan_unroll(True)
        try:
            c = jax.jit(step).lower(state, batch).compile()
        finally:
            set_scan_unroll(False)
        from repro.roofline import cost_analysis_dict
        xla = float(cost_analysis_dict(c).get("flops", 0))
        assert xla > 0
        assert 0.5 < analytic / xla < 2.0


class TestSimulatorSanity:
    def test_paper_trends_hold(self):
        """The headline directions of §6 must hold in the simulator."""
        from repro.core import simulator as S, workloads as W
        hw = S.SIM_CONFIG
        topo = hw.topo()
        tra = W.get_workload("transformer")
        r_df = S.simulate(tra, [0, 1, 6, 7], topo, hw)
        r_uv = S.simulate(tra, [0, 1, 6, 7], topo, hw, comm="uvm")
        assert r_df.fps / r_uv.fps > 1.5          # Fig 15 direction
        g = W.get_workload("gpt2_large")
        r_v = S.simulate(g, list(range(36)), topo, hw)
        r_m = S.simulate(g, list(range(36)), topo, hw, tdm_physical=24)
        assert 1.5 < r_v.fps / r_m.fps < 2.5      # Fig 16 (paper 1.92x)
        d_page = S.simulate_weight_dma(256 << 20, hw, translation="page",
                                       tlb_entries=4, bw_share=1 / 36)
        d_rng = S.simulate_weight_dma(256 << 20, hw, translation="range",
                                      tlb_entries=4, bw_share=1 / 36)
        assert d_page.overhead > 0.1              # Fig 14: page ~20%
        assert d_rng.overhead < 0.043             # Fig 14: range <= 4.3%

    def test_trace_driven_matches_pattern_claims(self):
        """Real vchunk TLB structures driven by a Pattern-1/2/3 trace."""
        from repro.core import simulator as S
        hw = S.SIM_CONFIG
        # 7 MB blob -> 3 buddy ranges (4+2+1); 2-entry TLB misses on the
        # wrap-around so Pattern-3's last_v actually fires
        r = S.simulate_weight_dma(7 << 20, hw, translation="range",
                                  tlb_entries=2, n_iterations=3,
                                  trace_driven=True)
        assert r.stats is not None
        # iteration-periodic trace: last_v learned after iteration 1
        assert r.stats.last_v_hits >= 1
        assert r.overhead < 0.01
