"""Degrade gracefully when ``hypothesis`` is not installed.

Property-based tests decorate with ``@given(...)``; where hypothesis is
absent those tests must *skip* (not error at collection) so the rest of the
suite still runs — see ISSUE/pyproject: hypothesis is a test extra, not a
hard requirement.

Usage in a test module::

    try:
        from hypothesis import given, settings, strategies as st
    except ModuleNotFoundError:
        from _hypothesis_fallback import given, settings, st
"""
import pytest


class _AnyStrategy:
    """Stand-in for ``hypothesis.strategies``: every attribute is a callable
    returning an opaque placeholder (the decorated test never runs)."""

    def __getattr__(self, name):
        def strategy(*args, **kwargs):
            return None

        return strategy


st = _AnyStrategy()


def given(*args, **kwargs):
    def decorate(fn):
        # Deliberately not functools.wraps: pytest must see the (*a, **k)
        # signature, not the original one, or it would demand fixtures for
        # the hypothesis-provided arguments.
        def skipper(*a, **k):
            pytest.skip("hypothesis not installed")

        skipper.__name__ = fn.__name__
        skipper.__doc__ = fn.__doc__
        return skipper

    return decorate


def settings(*args, **kwargs):
    def decorate(fn):
        return fn

    return decorate
