"""MappingEngine: incremental free regions, canonical TED cache, vectorized
candidate scoring, mapper strategies, hypervisor integration, pod scale."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # property tests degrade, unit tests still run
    from _hypothesis_fallback import given, settings, st

from repro.core import (Hypervisor, MappingEngine, VNPURequest, mesh_2d)
from repro.core.engine import FreeRegions, component_signature
from repro.core.engine.cache import TEDCache, region_part
from repro.core.engine.regions import scan_components
from repro.core.mapping import (default_edge_match, default_node_match,
                                induced_edit_cost, mem_dist_node_match,
                                min_topology_edit_distance)
from repro.core.topology import line


# ---------------------------------------------------------------------------
# incremental free regions
# ---------------------------------------------------------------------------

class TestFreeRegions:
    @staticmethod
    def _churn_check(seed):
        """Random allocate/release churn: the incrementally-maintained
        components must always equal a from-scratch scan of the free set."""
        rng = np.random.default_rng(seed)
        topo = mesh_2d(5, 5)
        fr = FreeRegions(topo)
        nodes = sorted(topo.node_attrs)
        for _ in range(20):
            subset = set(rng.choice(nodes, size=int(rng.integers(1, 7)),
                                    replace=False).tolist())
            if rng.random() < 0.5:
                fr.allocate(subset)
            else:
                fr.release(subset)
            fr.check_invariants()
            scratch = scan_components(fr.free, fr.adj)
            assert [c for _, c in fr.components()] == scratch

    @given(st.integers(0, 10_000))
    @settings(max_examples=25, deadline=None)
    def test_incremental_matches_scratch(self, seed):
        self._churn_check(seed)

    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_incremental_matches_scratch_seeded(self, seed):
        # deterministic variant that runs even without hypothesis
        self._churn_check(seed)

    def test_signature_translation_invariance(self):
        topo = mesh_2d(4, 4)
        adj = {n: tuple(ms) for n, ms in topo._adj().items()}
        row0 = component_signature(topo, {0, 1, 2}, adj)
        row1 = component_signature(topo, {4, 5, 6}, adj)   # same cols, row+1
        assert row0.key == row1.key
        assert row0.order == (0, 1, 2) and row1.order == (4, 5, 6)
        # shifting by a column changes mem_dist — a match fn reads it, so
        # the canonical key must separate
        shifted = component_signature(topo, {1, 2, 3}, adj)
        assert shifted.key != row0.key

    def test_signature_separates_structure(self):
        topo = mesh_2d(4, 4)
        adj = {n: tuple(ms) for n, ms in topo._adj().items()}
        path = component_signature(topo, {0, 1, 2, 3}, adj)
        star = component_signature(topo, {5, 1, 4, 6}, adj)
        assert path.key != star.key


# ---------------------------------------------------------------------------
# cache correctness (the PR-2 property test)
# ---------------------------------------------------------------------------

class TestCacheBitIdentical:
    @staticmethod
    def _churn_check(seed):
        """Across a randomized allocate/release sequence, a (possibly
        cached) engine answer must be bit-identical — nodes, TED and the
        full assignment — to a cold engine solving the same free set.

        Pinned with ``symmetry=False``: the translation-only cache is
        exactly equivariant (the candidate generators commute with id
        shifts), so warm==cold holds bit-for-bit.  A D4-decoded hit is
        TED-identical but may pick a different equal-cost node set than a
        fresh heuristic solve — that relaxed property has its own tests
        (``TestSymmetryCache``)."""
        rng = np.random.default_rng(seed)
        topo = mesh_2d(6, 6)
        eng = MappingEngine(topo, symmetry=False)
        req = mesh_2d(2, 3, base_id=500)
        residents = []
        for _ in range(10):
            if residents and rng.random() < 0.45:
                eng.notify_release(residents.pop(
                    int(rng.integers(len(residents)))))
            else:
                r = eng.map_request(req)
                if r is not None:
                    eng.notify_allocate(r.nodes)
                    residents.append(r.nodes)
            warm = eng.map_request(req)          # served from cache when hot
            cold_engine = MappingEngine(topo, symmetry=False)
            cold_engine.reset(eng.regions.free)
            cold = cold_engine.map_request(req)
            if warm is None:
                assert cold is None
            else:
                assert cold is not None
                assert cold.ted == warm.ted
                assert cold.nodes == warm.nodes
                assert cold.assignment == warm.assignment

    @given(st.integers(0, 10_000))
    @settings(max_examples=8, deadline=None)
    def test_cached_equals_fresh_across_churn(self, seed):
        self._churn_check(seed)

    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_cached_equals_fresh_seeded(self, seed):
        # deterministic variant that runs even without hypothesis
        self._churn_check(seed)

    def test_repeat_query_is_cache_hit(self):
        eng = MappingEngine(mesh_2d(6, 6))
        req = mesh_2d(3, 3, base_id=100)
        first = eng.map_request(req)
        h0 = eng.stats.hits
        second = eng.map_request(req)
        assert eng.stats.hits == h0 + 1
        assert second.nodes == first.nodes
        assert second.assignment == first.assignment

    def test_translated_region_hits_cache(self):
        """The canonical (translation-normalized) key serves a request in a
        region that is a shifted copy of an already-solved one.  mem_dist
        depends on the column only, so two row bands at the same columns
        are exact translations (attribute patterns included)."""
        topo = mesh_2d(6, 4)
        eng = MappingEngine(topo)
        req = mesh_2d(2, 2, base_id=100)
        # carve two identical 2x4 free bands: rows 0-1 and rows 3-4
        wall = [n for n in topo.node_attrs if topo.coords[n][0] in (2, 5)]
        eng.notify_allocate(wall)
        r1 = eng.map_request(req)                      # solves band 1
        assert all(topo.coords[n][0] <= 1 for n in r1.nodes)
        band1 = [n for n in topo.node_attrs if topo.coords[n][0] <= 1]
        eng.notify_allocate(band1)                     # band 2 remains
        misses = eng.stats.misses
        r2 = eng.map_request(req)
        assert r2 is not None
        assert eng.stats.misses == misses              # translated hit
        assert all(3 <= topo.coords[n][0] <= 4 for n in r2.nodes)
        assert r2.ted == r1.ted

    def test_unregistered_match_fn_is_uncacheable_but_correct(self):
        eng = MappingEngine(mesh_2d(5, 5))
        req = mesh_2d(2, 2, base_id=100)
        nm = lambda a, b: default_node_match(a, b)   # no match_id
        r1 = eng.map_request(req, node_match=nm)
        r2 = eng.map_request(req, node_match=nm)
        assert eng.stats.hits == 0 and eng.stats.uncacheable >= 2
        assert r1.nodes == r2.nodes and r1.ted == r2.ted


# ---------------------------------------------------------------------------
# eviction churn: live-shape pinning keeps answers capacity-independent
# ---------------------------------------------------------------------------

class TestEvictionChurn:
    def test_pinned_entries_survive_overflow(self):
        live = {"A"}
        c = TEDCache(max_entries=2, pinned=lambda: live)
        c.put(("A", "q1"), None)
        c.put(("B", "q1"), None)
        c.put(("C", "q1"), None)        # overflow: B (oldest unpinned) goes
        assert c.get(("A", "q1"))[0]
        assert not c.get(("B", "q1"))[0]
        assert c.get(("C", "q1"))[0]
        assert c.evictions == 1

    def test_unpinning_makes_entry_evictable(self):
        live = {"A"}
        c = TEDCache(max_entries=1, pinned=lambda: live)
        c.put(("A", "q1"), None)
        live.clear()                     # shape died: tracker mutated it away
        c.put(("B", "q1"), None)
        assert not c.get(("A", "q1"))[0]
        assert c.get(("B", "q1"))[0]

    def test_soft_capacity_when_all_pinned(self):
        live = {"A", "B", "C"}
        c = TEDCache(max_entries=1, pinned=lambda: live)
        for k in ("A", "B", "C"):
            c.put((k, "q1"), None)
        assert len(c) == 3 and c.evictions == 0   # bound goes soft

    def test_zz_key_region_part(self):
        fs = (0, 1, 2, 3)
        assert region_part(("zz", fs, "rk", "nm", "em")) == fs
        assert region_part(("rk", "qk", "nm", "em", "hybrid", 512)) == "rk"

    def test_live_shape_hits_despite_tiny_cache(self):
        """Churning one free band must not evict entries for the *other*,
        untouched band: its shape stays live, so a re-query hits the cache
        even through a 1-entry capacity bound."""
        topo = mesh_2d(6, 6)
        eng = MappingEngine(topo, cache_entries=1)
        # wall row 2: band A (rows 0-1) and band B (rows 3-5), disconnected
        wall = [n for n in topo.node_attrs if topo.coords[n][0] == 2]
        eng.notify_allocate(wall)
        req = mesh_2d(2, 6, base_id=500)     # only band A can host 2x6
        assert eng.map_request(req) is not None
        for _ in range(4):                   # churn band B only
            r = eng.map_request(mesh_2d(3, 3, base_id=600))  # needs 3 rows
            assert r is not None
            assert all(topo.coords[n][0] >= 3 for n in r.nodes)
            eng.notify_allocate(r.nodes)     # mutates band B: old keys die
            eng.map_request(line(3, base_id=700))
            eng.notify_release(r.nodes)
        assert eng.cache.evictions > 0       # dead band-B entries churned
        h0 = eng.stats.hits
        assert eng.map_request(req) is not None
        assert eng.stats.hits > h0           # band A entry survived it all

    @staticmethod
    def _capacity_independence_check(seed, symmetry):
        """A 4-entry cache under heavy churn must answer every query with
        the same TED as a 4096-entry cache fed the identical op sequence —
        and bit-identically (nodes + assignment) for ``symmetry=False``,
        where translation-equivariance makes a re-solve reproduce an
        evicted entry exactly.  (Under D4 keys a dead shape recurring in a
        rotated frame may legally resolve an equal-cost tie differently,
        so there the guarantee is cost-level; live shapes never re-solve
        at all thanks to pinning.)"""
        rng = np.random.default_rng(seed)
        topo = mesh_2d(6, 6)
        engines = [MappingEngine(topo, cache_entries=4, symmetry=symmetry),
                   MappingEngine(topo, cache_entries=4096, symmetry=symmetry)]
        reqs = [mesh_2d(2, 3, base_id=500), mesh_2d(2, 2, base_id=600),
                line(3, base_id=700), line(5, base_id=800)]
        residents = []
        for _ in range(30):
            op = rng.random()
            if residents and op < 0.35:
                nodes = residents.pop(int(rng.integers(len(residents))))
                for eng in engines:
                    eng.notify_release(nodes)
            else:
                req = reqs[int(rng.integers(len(reqs)))]
                results = [eng.map_request(req) for eng in engines]
                small, big = results
                if small is None or big is None:
                    assert small is None and big is None
                    continue
                assert small.ted == big.ted
                if not symmetry:
                    assert small.nodes == big.nodes
                    assert small.assignment == big.assignment
                if op < 0.75:            # keep some placements resident
                    # allocate one node set in BOTH engines so the free
                    # sets stay in lockstep even where D4 ties may differ
                    for eng in engines:
                        eng.notify_allocate(big.nodes)
                    residents.append(big.nodes)
        assert engines[0].cache.evictions > 0     # churn actually evicted

    @given(st.integers(0, 10_000))
    @settings(max_examples=6, deadline=None)
    def test_capacity_independent_answers(self, seed):
        self._capacity_independence_check(seed, symmetry=False)

    @pytest.mark.parametrize("seed", [0, 1, 2])
    @pytest.mark.parametrize("symmetry", [False, True])
    def test_capacity_independent_answers_seeded(self, seed, symmetry):
        self._capacity_independence_check(seed, symmetry)


# ---------------------------------------------------------------------------
# D4 symmetry-normalized cache keys
# ---------------------------------------------------------------------------

# the eight (row, col) lattice transforms, keyed like regions.D4_TRANSFORMS
_D4_FNS = {
    "identity": lambda r, c, R, C: (r, c),
    "rot90": lambda r, c, R, C: (c, R - 1 - r),
    "rot180": lambda r, c, R, C: (R - 1 - r, C - 1 - c),
    "rot270": lambda r, c, R, C: (C - 1 - c, r),
    "flip_rows": lambda r, c, R, C: (R - 1 - r, c),
    "flip_cols": lambda r, c, R, C: (r, C - 1 - c),
    "transpose": lambda r, c, R, C: (c, r),
    "anti_transpose": lambda r, c, R, C: (C - 1 - c, R - 1 - r),
}


def _uniform_mesh(rows, cols):
    """A mesh whose node attrs are D4-symmetric (constant mem_dist), so
    every group element is attr-preserving."""
    topo = mesh_2d(rows, cols)
    for n in topo.node_attrs:
        topo.node_attrs[n]["mem_dist"] = 0
    return topo


def _uniform_request(rows, cols):
    req = mesh_2d(rows, cols, base_id=500)
    for n in req.node_attrs:
        req.node_attrs[n]["mem_dist"] = 0
    return req


def _random_blob(rng, rows, cols, size):
    """A random connected coordinate set on a rows x cols lattice."""
    start = (int(rng.integers(rows)), int(rng.integers(cols)))
    blob = {start}
    while len(blob) < size:
        r, c = list(blob)[int(rng.integers(len(blob)))]
        nbrs = [(r + dr, c + dc) for dr, dc in ((0, 1), (1, 0), (0, -1),
                                                (-1, 0))
                if 0 <= r + dr < rows and 0 <= c + dc < cols]
        nbrs = [p for p in nbrs if p not in blob]
        if nbrs:
            blob.add(nbrs[int(rng.integers(len(nbrs)))])
    return blob


class TestSymmetryCache:
    def _decode_check(self, topo, req, result, free):
        """The decoded mapping must be a valid assignment onto the
        transformed region whose induced cost equals the reported TED."""
        assert result is not None
        assert result.nodes <= free
        assert set(result.assignment.values()) == set(result.nodes)
        sub = topo.subgraph(result.nodes)
        ref = induced_edit_cost(req, sub, result.assignment,
                                default_node_match, default_edge_match)
        assert result.ted == pytest.approx(ref, abs=1e-12)

    def _transform_check(self, seed):
        """Property: for a random free blob and every D4 element, a
        transformed copy of (region, request) is answered soundly — a
        cache HIT decodes to a valid assignment on the transformed mesh
        with TED identical to the original solve's, and when the original
        solve was perfect (TED 0, provably orientation-independent) every
        transform MUST hit.  A suboptimal original may instead re-solve
        fresh (heuristic quality is not D4-invariant — serving it across
        orientations would let a lucky orientation poison the others);
        then the fresh result must simply be valid."""
        rng = np.random.default_rng(seed)
        R = C = 9
        topo = _uniform_mesh(R, C)
        req = _uniform_request(2, 3)
        blob = _random_blob(rng, R, C, int(rng.integers(7, 14)))
        by_coord = {v: k for k, v in topo.coords.items()}
        all_nodes = set(topo.node_attrs)
        for name, fn in _D4_FNS.items():
            eng = MappingEngine(topo)        # fresh engine per element
            keep = {by_coord[p] for p in blob}
            eng.notify_allocate(all_nodes - keep)
            base = eng.map_request(req)
            assert base is not None
            m0 = eng.stats.misses
            tkeep = {by_coord[fn(r, c, R, C)] for r, c in blob}
            eng.notify_release(all_nodes - keep)
            eng.notify_allocate(all_nodes - tkeep)
            r2 = eng.map_request(req)
            hit = eng.stats.misses == m0
            self._decode_check(topo, req, r2, tkeep)
            if base.ted == 0.0:
                assert hit, f"perfect solve: transform {name} must hit"
            if hit:
                assert r2.ted == base.ted, f"transform {name} changed TED"

    def test_perfect_region_hits_all_transforms(self):
        """Deterministic anchor for the property: a 3x4 free rectangle
        hosts the 2x3 request perfectly (TED 0), so all eight transformed
        copies are cache hits with valid decodes."""
        R = C = 9
        topo = _uniform_mesh(R, C)
        req = _uniform_request(2, 3)
        by_coord = {v: k for k, v in topo.coords.items()}
        all_nodes = set(topo.node_attrs)
        rect = {(r, c) for r in range(3) for c in range(4)}
        eng = MappingEngine(topo)
        keep = {by_coord[p] for p in rect}
        eng.notify_allocate(all_nodes - keep)
        base = eng.map_request(req)
        assert base.ted == 0.0
        m0 = eng.stats.misses
        prev = keep
        for name, fn in _D4_FNS.items():
            tkeep = {by_coord[fn(r, c, R, C)] for r, c in rect}
            eng.notify_release(all_nodes - prev)
            eng.notify_allocate(all_nodes - tkeep)
            prev = tkeep
            r2 = eng.map_request(req)
            assert eng.stats.misses == m0, f"transform {name} missed"
            self._decode_check(topo, req, r2, tkeep)
            assert r2.ted == 0.0
        assert eng.stats.sym_decoded_hits >= 2   # rotations are not shifts

    @given(st.integers(0, 10_000))
    @settings(max_examples=10, deadline=None)
    def test_all_transforms_hit_property(self, seed):
        self._transform_check(seed)

    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_all_transforms_hit_seeded(self, seed):
        # deterministic variant that runs even without hypothesis
        self._transform_check(seed)

    def test_vertical_reflection_hits_with_mem_dist(self):
        """On the default layout (mem_interface_cols=(0,)) mem_dist is a
        function of the column alone, so the row mirror is attr-preserving
        and must be cache-unified — with the real heterogeneous attrs."""
        topo = mesh_2d(7, 5)                 # default mem_interface_cols=(0,)
        coords = topo.coords
        by_coord = {v: k for k, v in coords.items()}
        shape = {(0, 0), (0, 1), (1, 0), (1, 1), (2, 0), (1, 2)}
        eng = MappingEngine(topo)
        keep = {by_coord[p] for p in shape}
        eng.notify_allocate(set(topo.node_attrs) - keep)
        req = mesh_2d(2, 2, base_id=500)
        r1 = eng.map_request(req)
        misses0 = eng.stats.misses
        # row mirror of the shape (columns, hence mem_dist, unchanged)
        mirrored = {(6 - r, c) for r, c in shape}
        mkeep = {by_coord[p] for p in mirrored}
        eng.notify_release(set(topo.node_attrs) - keep)
        eng.notify_allocate(set(topo.node_attrs) - mkeep)
        r2 = eng.map_request(req)
        assert eng.stats.misses == misses0          # D4 hit, no re-solve
        assert eng.stats.sym_decoded_hits >= 1
        self._decode_check(topo, req, r2, mkeep)
        assert r2.ted == r1.ted

    def test_mem_dist_asymmetry_is_not_unified(self):
        """The column mirror *changes* mem_dist on the default layout, so
        it must NOT be cache-unified even though the bare shapes match:
        symmetry only applies when it preserves every attribute a match
        function may read."""
        topo = mesh_2d(5, 7)                 # mem_dist = col (interface col 0)
        coords = topo.coords
        by_coord = {v: k for k, v in coords.items()}
        shape = {(0, 0), (0, 1), (0, 2), (1, 0), (2, 0), (1, 1)}
        eng = MappingEngine(topo)
        keep = {by_coord[p] for p in shape}
        eng.notify_allocate(set(topo.node_attrs) - keep)
        req = mesh_2d(2, 2, base_id=500)
        eng.map_request(req)
        misses0 = eng.stats.misses
        # column mirror: same silhouette, different mem_dist pattern
        mirrored = {(r, 6 - c) for r, c in shape}
        mkeep = {by_coord[p] for p in mirrored}
        eng.notify_release(set(topo.node_attrs) - keep)
        eng.notify_allocate(set(topo.node_attrs) - mkeep)
        r2 = eng.map_request(req)
        assert eng.stats.misses == misses0 + 1      # fresh solve, no false hit
        assert r2 is not None
        # and the canonical keys really differ
        adj = {n: tuple(ms) for n, ms in topo._adj().items()}
        k1 = component_signature(topo, keep, adj).key
        k2 = component_signature(topo, mkeep, adj).key
        assert k1 != k2

    def test_transform_recorded_and_order_consistent(self):
        topo = _uniform_mesh(6, 6)
        adj = {n: tuple(ms) for n, ms in topo._adj().items()}
        # an L-tromino and its rotation must share a key; at least one of
        # the two signatures decodes through a non-identity element
        a = {0, 1, 6}            # (0,0),(0,1),(1,0)
        b = {1, 7, 6}            # (0,1),(1,1),(1,0) — rot90 of the L
        sa = component_signature(topo, a, adj)
        sb = component_signature(topo, b, adj)
        assert sa.key == sb.key
        assert len(sa.order) == len(sb.order) == 3
        assert {"identity"} != {sa.transform, sb.transform}
        # symmetry off: translation-only keys separate the orientations
        sa0 = component_signature(topo, a, adj, symmetry=False)
        sb0 = component_signature(topo, b, adj, symmetry=False)
        assert sa0.key != sb0.key
        assert sa0.transform == sb0.transform == "identity"

    def test_orientation_sensitive_mapper_not_poisoned_by_d4_twin(self):
        """The rect first-fit mapper only finds an exact-shape window in
        one orientation of a strip; D4-unifying its entries would let the
        unlucky orientation (zig-zag fallback, TED > 0) poison the lucky
        one.  ``d4_stable = False`` keys it by orientation: the rotated
        twin re-solves fresh and finds the perfect rectangle."""
        topo = _uniform_mesh(9, 9)
        by_coord = {v: k for k, v in topo.coords.items()}
        req = _uniform_request(2, 3)
        eng = MappingEngine(topo, mapper="rect")
        # solve the 3x2 strip first: no 2x3 window exists in it
        strip_v = {by_coord[(r, c)] for r in range(3) for c in range(2)}
        eng.notify_allocate(set(topo.node_attrs) - strip_v)
        bad = eng.map_request(req)
        assert bad is not None and bad.ted > 0.0
        # now its rot90 twin: a fresh solve must find the exact window
        strip_h = {by_coord[(r, c)] for r in range(2) for c in range(3)}
        eng.notify_release(set(topo.node_attrs) - strip_v)
        eng.notify_allocate(set(topo.node_attrs) - strip_h)
        good = eng.map_request(req)
        assert good is not None and good.ted == 0.0
        assert eng.stats.sym_decoded_hits == 0

    def test_free_key_canonical_across_equivalent_pools(self):
        """FreeRegions.free_key / MappingEngine.free_state_id unify
        equivalent pools (the probe memo's cross-state hits) and separate
        different shapes."""
        topo = _uniform_mesh(6, 6)
        eng = MappingEngine(topo)
        by_coord = {v: k for k, v in topo.coords.items()}
        sq = {by_coord[(r, c)] for r in (0, 1) for c in (0, 1)}
        eng.notify_allocate(sq)
        id1 = eng.free_state_id()
        eng.notify_release(sq)
        sq2 = {by_coord[(r, c)] for r in (4, 5) for c in (4, 5)}
        eng.notify_allocate(sq2)            # the rot180 image of that pool
        assert eng.free_state_id() == id1
        eng.notify_release(sq2)
        line3 = {by_coord[(0, c)] for c in range(3)}
        eng.notify_allocate(line3)          # different hole shape
        assert eng.free_state_id() != id1


# ---------------------------------------------------------------------------
# quality vs the reference implementation
# ---------------------------------------------------------------------------

class TestEngineQuality:
    def _engine_for(self, topo, blocked):
        eng = MappingEngine(topo)
        eng.notify_allocate(blocked)
        return eng

    @pytest.mark.parametrize("blocked,shape", [
        (set(), (3, 3)),
        ({0, 1, 6, 7, 28, 29, 34, 35}, (3, 4)),       # corners taken
        ({0, 1, 2, 6, 7, 8, 12, 13, 14}, (3, 3)),     # 3x3 taken, ask again
        ({1, 4, 9, 16, 21, 30}, (2, 4)),              # scattered
    ])
    def test_ted_equal_or_better_than_legacy_6x6(self, blocked, shape):
        topo = mesh_2d(6, 6)
        req = mesh_2d(*shape, base_id=100)
        legacy = min_topology_edit_distance(topo, blocked, req)
        got = self._engine_for(topo, blocked).map_request(req)
        assert (got is None) == (legacy is None)
        if got is not None:
            assert got.ted <= legacy.ted + 1e-9

    def test_returned_ted_is_true_induced_cost(self):
        """The engine's TED must be the actual induced edit cost of the
        assignment it returns (vectorized path == reference arithmetic)."""
        topo = mesh_2d(6, 6)
        req = mesh_2d(2, 3, base_id=100)
        for blocked in (set(), {0, 1, 2, 6, 7, 8}, {7, 8, 9, 13, 14, 15}):
            got = self._engine_for(topo, blocked).map_request(req)
            sub = topo.subgraph(got.nodes)
            ref = induced_edit_cost(req, sub, got.assignment,
                                    default_node_match, default_edge_match)
            assert got.ted == pytest.approx(ref)

    def test_heterogeneous_mem_dist_objective(self):
        topo = mesh_2d(4, 4, mem_interface_cols=(0,))
        eng = MappingEngine(topo)
        got = eng.map_request(mesh_2d(2, 2, base_id=100),
                              node_match=mem_dist_node_match(0.5))
        cols = {topo.coords[n][1] for n in got.nodes}
        assert min(cols) == 0          # hugs the memory-interface column

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_relaxed_ted_equal_or_better_than_legacy(self, seed):
        """The fragmented (require_connected=False) path the scheduler uses
        must also never lose to the reference — the zig-zag fallback is
        escalated (2-opt + exact B&B) just like a connected candidate."""
        rng = np.random.default_rng(seed)
        topo = mesh_2d(6, 6)
        nodes = sorted(topo.node_attrs)
        for _ in range(12):
            n_blocked = int(rng.integers(12, 30))
            blocked = set(rng.choice(nodes, size=n_blocked,
                                     replace=False).tolist())
            shape = [(2, 2), (2, 3), (2, 4)][int(rng.integers(3))]
            if shape[0] * shape[1] > 36 - n_blocked:
                continue
            req = mesh_2d(*shape, base_id=100)
            legacy = min_topology_edit_distance(
                topo, blocked, req, require_connected=False)
            got = self._engine_for(topo, blocked).map_request(
                req, require_connected=False)
            assert (got is None) == (legacy is None)
            if got is not None:
                assert got.ted <= legacy.ted + 1e-9

    def test_fragmented_fallback_when_disconnected(self):
        topo = mesh_2d(3, 3)
        eng = MappingEngine(topo)
        eng.notify_allocate({1, 4, 7})           # split into two columns
        req = line(4, base_id=100)
        assert eng.map_request(req) is None      # no connected 4-set
        relaxed = eng.map_request(req, require_connected=False)
        assert relaxed is not None and len(relaxed.nodes) == 4
        assert relaxed.ted > 0


# ---------------------------------------------------------------------------
# mapper strategies
# ---------------------------------------------------------------------------

class TestMapperStrategies:
    def test_all_strategies_produce_valid_mappings(self):
        topo = mesh_2d(6, 6)
        blocked = {0, 1, 6, 7, 28, 29, 34, 35}
        req = mesh_2d(2, 3, base_id=100)
        teds = {}
        for name in ("exact", "hybrid", "bipartite", "rect"):
            eng = MappingEngine(topo, mapper=name)
            eng.notify_allocate(blocked)
            got = eng.map_request(req)
            assert got is not None
            assert len(got.nodes) == 6
            assert not (got.nodes & blocked)
            assert topo.is_connected(got.nodes)
            assert set(got.assignment.values()) == set(got.nodes)
            teds[name] = got.ted
        assert teds["exact"] <= teds["hybrid"] + 1e-9
        assert teds["hybrid"] <= teds["bipartite"] + 1e-9
        assert teds["hybrid"] <= teds["rect"] + 1e-9

    def test_unknown_mapper_rejected(self):
        with pytest.raises(KeyError):
            MappingEngine(mesh_2d(3, 3), mapper="nope")
        eng = MappingEngine(mesh_2d(3, 3))
        with pytest.raises(KeyError):
            eng.map_request(mesh_2d(2, 2, base_id=50), mapper="nope")


# ---------------------------------------------------------------------------
# hypervisor integration
# ---------------------------------------------------------------------------

def _expected_free(hyp):
    """Ground truth reconstructed independently of the engine's tracker
    (hyp.free_cores() is engine-derived, so the sync assertions must not
    read it back)."""
    used = {p for v in hyp.vnpus.values() for p in v.p_cores}
    return set(hyp.topo.node_attrs) - used - hyp.quarantined


class TestHypervisorIntegration:
    def test_lifecycle_keeps_engine_in_sync(self):
        rng = np.random.default_rng(7)
        hyp = Hypervisor(mesh_2d(6, 6), hbm_bytes=1 << 32)
        live = []
        for _ in range(20):
            if live and rng.random() < 0.4:
                hyp.destroy_vnpu(live.pop(int(rng.integers(len(live)))))
            else:
                shape = [(2, 2), (2, 3), (3, 3)][int(rng.integers(3))]
                try:
                    v = hyp.create_vnpu(VNPURequest(
                        topology=mesh_2d(*shape, base_id=100),
                        require_connected=False))
                    live.append(v.vmid)
                except Exception:
                    pass
            assert hyp.engine.regions.free == _expected_free(hyp)
            hyp.engine.regions.check_invariants()

    def test_probe_then_allocate_is_cache_hit(self):
        hyp = Hypervisor(mesh_2d(6, 6))
        req = VNPURequest(topology=mesh_2d(3, 3, base_id=100))
        assert hyp.can_allocate(req)
        h0 = hyp.engine.stats.hits
        hyp.create_vnpu(req)
        assert hyp.engine.stats.hits > h0

    def test_remap_keeps_engine_in_sync(self):
        hyp = Hypervisor(mesh_2d(6, 6))
        v = hyp.create_vnpu(VNPURequest(topology=mesh_2d(2, 2, base_id=100)))
        dead = next(iter(v.p_cores))
        v2 = hyp.remap_vnpu(v.vmid, [dead])
        assert dead not in v2.p_cores
        assert hyp.engine.regions.free == _expected_free(hyp)

    def test_failed_core_never_reallocated(self):
        """remap_vnpu quarantines dead cores: nothing may be placed on them
        afterwards, across allocations, destroys and further remaps."""
        hyp = Hypervisor(mesh_2d(4, 4))
        v = hyp.create_vnpu(VNPURequest(topology=mesh_2d(2, 2, base_id=100)))
        dead = next(iter(v.p_cores))
        hyp.remap_vnpu(v.vmid, [dead])
        assert dead in hyp.quarantined
        assert dead not in hyp.free_cores()
        assert dead not in hyp.engine.regions.free
        placed = [hyp.create_vnpu(VNPURequest(
            topology=mesh_2d(2, 2, base_id=200), require_connected=False))
            for _ in range(2)]
        assert all(dead not in p.p_cores for p in placed)
        # the straightforward (zig-zag) strategy must honor quarantine too
        zz = hyp.create_vnpu(VNPURequest(
            topology=mesh_2d(1, 2, base_id=300), strategy="straightforward"))
        assert dead not in zz.p_cores
        hyp.destroy_vnpu(zz.vmid)
        for p in placed:
            hyp.destroy_vnpu(p.vmid)
        # destroying the remapped tenant must not free the dead core either
        hyp.destroy_vnpu(v.vmid)
        assert dead not in hyp.free_cores()
        assert hyp.engine.regions.free == _expected_free(hyp)

    def test_utilization_bounded_with_quarantined_resident(self):
        """Between mark_failed and the tenant's migration, the dead core is
        both quarantined and owned — utilization must stay <= 1."""
        hyp = Hypervisor(mesh_2d(2, 2))
        v = hyp.create_vnpu(VNPURequest(topology=mesh_2d(2, 2, base_id=100)))
        assert hyp.utilization() == 1.0
        dead = next(iter(v.p_cores))
        hyp.mark_failed([dead])
        assert hyp.utilization() == 1.0          # 3 useful / 3 healthy
        hyp.destroy_vnpu(v.vmid)
        assert hyp.utilization() == 0.0

    def test_defrag_migrate_does_not_quarantine(self):
        hyp = Hypervisor(mesh_2d(6, 6))
        v = hyp.create_vnpu(VNPURequest(topology=mesh_2d(2, 2, base_id=100)))
        avoid = next(iter(v.p_cores))
        hyp.migrate_vnpu(v.vmid, avoid=[avoid])
        assert not hyp.quarantined          # advisory avoid, not dead HW
        assert hyp.engine.regions.free == _expected_free(hyp)

    def test_failed_memory_alloc_leaves_engine_untouched(self):
        hyp = Hypervisor(mesh_2d(4, 4), hbm_bytes=1 << 26)
        free0 = set(hyp.engine.regions.free)
        with pytest.raises(Exception):
            hyp.create_vnpu(VNPURequest(topology=mesh_2d(2, 2, base_id=100),
                                        memory_bytes=1 << 30))
        assert hyp.engine.regions.free == free0 == _expected_free(hyp)


# ---------------------------------------------------------------------------
# pod scale
# ---------------------------------------------------------------------------

class TestPodScale:
    def test_propose_candidates_16x16(self):
        eng = MappingEngine(mesh_2d(16, 16))
        cands = eng.propose_candidates(9)
        assert 0 < len(cands) <= eng.max_candidates
        topo = eng.topo
        for cand in cands[:50]:
            assert len(set(cand)) == 9
            assert topo.is_connected(cand)

    def test_event_loop_smoke_16x16(self):
        """The satellite smoke: the cluster event loop drives the engine's
        candidate proposal on a 256-core mesh within a sane time budget."""
        import time

        from repro.sched import ClusterScheduler, make_policy, make_trace

        policy = make_policy("vnpu", mesh_2d(16, 16))
        trace = make_trace("mixed", horizon_s=25.0)
        sched = ClusterScheduler(policy, epoch_s=5.0)
        t0 = time.perf_counter()
        metrics = sched.run(trace, trace_name="mixed-pod")
        wall = time.perf_counter() - t0
        assert metrics.n_admitted > 0
        assert metrics.n_rejected == 0          # 256 cores swallow the mix
        ec = metrics.engine_counters
        assert ec and ec["map_calls"] > 0
        # generous bound (CI machines vary); the real perf gate lives in
        # benchmarks/mapping_engine.py --gate
        assert wall < 120.0
