"""Mapper conformance suite + placement-quality-oracle differential checks.

Every entry in ``MAPPERS`` — whatever its speed/accuracy trade — must obey
the same contract: placements land inside the free set, no core is
double-assigned, the reported TED is exactly the cost the assignment
induces, and cache decodes (translation and D4) preserve all of that.  The
``ilp`` strategy additionally *certifies* optimality (``result.optimal``),
which makes it the differential oracle: no mapper may ever report a TED
below a proven optimum.
"""
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent))

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_fallback import given, settings, st

from repro.core.engine import MAPPERS, MappingEngine
from repro.core.engine.cache import decode_result, encode_result
from repro.core.engine.ilp import (HAVE_MILP, placement_milp_size,
                                   solve_placement_milp)
from repro.core.mapping import (MappingResult, default_edge_match,
                                default_node_match, induced_edit_cost)
from repro.core.topology import mesh_2d

ALL_MAPPERS = sorted(MAPPERS)

# a 6x6 blocking pattern with no free 3x3/3x4 rectangle: every mapper is
# forced off the TED-0 fast path for the larger shapes
FRAGMENTED_6X6 = frozenset({2, 4, 8, 9, 14, 16, 20, 22, 26, 28, 32, 33})


def _free(topo, blocked):
    return frozenset(topo.node_attrs) - set(blocked)


def _check_contract(topo, req, free, result):
    """The conformance contract every mapper shares."""
    assert result.nodes <= free
    vals = list(result.assignment.values())
    assert len(vals) == len(set(vals)) == req.num_nodes
    assert set(vals) == set(result.nodes)
    ref = induced_edit_cost(req, topo.subgraph(result.nodes),
                            result.assignment,
                            default_node_match, default_edge_match)
    assert result.ted == pytest.approx(ref, abs=1e-12)


class TestMapperConformance:
    @pytest.mark.parametrize("shape", [(2, 2), (2, 3), (3, 3)])
    @pytest.mark.parametrize("name", ALL_MAPPERS)
    def test_contract_on_seeded_corpus(self, name, shape):
        topo = mesh_2d(6, 6)
        rng = np.random.default_rng(7)
        blocked = set(rng.choice(sorted(topo.node_attrs), size=10,
                                 replace=False).tolist())
        free = _free(topo, blocked)
        eng = MappingEngine(topo, mapper=name)
        req = mesh_2d(*shape, base_id=10_000)
        res = eng.map_request(req, require_connected=False,
                              free_override=free)
        assert res is not None
        _check_contract(topo, req, free, res)

    @pytest.mark.parametrize("name", ALL_MAPPERS)
    def test_contract_on_fragmented_corpus(self, name):
        topo = mesh_2d(6, 6)
        free = _free(topo, FRAGMENTED_6X6)
        eng = MappingEngine(topo, mapper=name)
        req = mesh_2d(2, 3, base_id=10_000)
        res = eng.map_request(req, require_connected=False,
                              free_override=free)
        assert res is not None
        _check_contract(topo, req, free, res)

    @pytest.mark.parametrize("name", ALL_MAPPERS)
    def test_translation_decode_preserves_contract(self, name):
        """Solve with a free 3x3 blob in one corner, then translate the
        blob: the (likely cached) second answer must still satisfy the
        contract on the *new* coordinates."""
        topo = mesh_2d(6, 6)
        by_coord = {v: k for k, v in topo.coords.items()}
        all_nodes = set(topo.node_attrs)
        req = mesh_2d(2, 2, base_id=10_000)
        eng = MappingEngine(topo, mapper=name)
        for origin in ((0, 0), (3, 3), (1, 2)):
            keep = {by_coord[(origin[0] + r, origin[1] + c)]
                    for r in range(3) for c in range(3)}
            eng.notify_allocate(all_nodes - keep)
            res = eng.map_request(req)
            assert res is not None
            _check_contract(topo, req, frozenset(keep), res)
            eng.notify_release(all_nodes - keep)

    def test_unknown_mapper_name_rejected(self):
        with pytest.raises((KeyError, ValueError)):
            MappingEngine(mesh_2d(4, 4), mapper="definitely-not-a-mapper")


# the eight lattice transforms, matching regions.D4_TRANSFORMS
D4_FNS = {
    "identity": lambda r, c, R, C: (r, c),
    "rot90": lambda r, c, R, C: (c, R - 1 - r),
    "rot180": lambda r, c, R, C: (R - 1 - r, C - 1 - c),
    "rot270": lambda r, c, R, C: (C - 1 - c, r),
    "flip_rows": lambda r, c, R, C: (R - 1 - r, c),
    "flip_cols": lambda r, c, R, C: (r, C - 1 - c),
    "transpose": lambda r, c, R, C: (c, r),
    "anti_transpose": lambda r, c, R, C: (C - 1 - c, R - 1 - r),
}


def _uniform(topo):
    for n in topo.node_attrs:
        topo.node_attrs[n]["mem_dist"] = 0
    return topo


class TestD4Decode:
    @pytest.mark.parametrize("name", ALL_MAPPERS)
    def test_all_orientations_valid(self, name):
        R = C = 7
        topo = _uniform(mesh_2d(R, C))
        req = _uniform(mesh_2d(2, 3, base_id=10_000))
        by_coord = {v: k for k, v in topo.coords.items()}
        all_nodes = set(topo.node_attrs)
        blob = {(0, 0), (0, 1), (0, 2), (1, 0), (1, 1), (1, 2), (2, 0)}
        eng = MappingEngine(topo, mapper=name)
        prev: set = set()
        base_ted = None
        for tname, fn in D4_FNS.items():
            keep = {by_coord[fn(r, c, R, C)] for r, c in blob}
            if prev:
                eng.notify_release(all_nodes - prev)
            eng.notify_allocate(all_nodes - keep)
            prev = keep
            res = eng.map_request(req)
            assert res is not None, (name, tname)
            _check_contract(topo, req, frozenset(keep), res)
            if base_ted is None:
                base_ted = res.ted
            elif res.ted == 0.0 or base_ted == 0.0:
                assert res.ted == base_ted, (name, tname)


class TestOracleDifferential:
    """No mapper beats a proven ILP optimum — the oracle property the
    gap-gate harness enforces at benchmark scale."""

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_no_mapper_beats_ilp_small_mesh(self, seed):
        topo = mesh_2d(5, 5)
        rng = np.random.default_rng(seed)
        blocked = set(rng.choice(sorted(topo.node_attrs), size=9,
                                 replace=False).tolist())
        free = _free(topo, blocked)
        for shape in ((2, 2), (2, 3)):
            req = mesh_2d(*shape, base_id=10_000)
            opt = MappingEngine(topo, mapper="ilp").map_request(
                req, require_connected=False, free_override=free)
            if opt is None:
                continue
            assert opt.optimal, "5x5 components must be MILP-provable"
            for name in ALL_MAPPERS:
                got = MappingEngine(topo, mapper=name).map_request(
                    req, require_connected=False, free_override=free)
                if got is not None:
                    assert got.ted >= opt.ted - 1e-9, (name, shape, seed)

    @pytest.mark.slow
    def test_ilp_matches_exact_branch_and_bound(self):
        """On the fragmented 6x6 corpus where the budgeted exact B&B
        terminates, the MILP certificate agrees with it exactly."""
        topo = mesh_2d(6, 6)
        free = _free(topo, FRAGMENTED_6X6)
        req = mesh_2d(3, 3, base_id=10_000)
        opt = MappingEngine(topo, mapper="ilp").map_request(
            req, require_connected=False, free_override=free)
        exact = MappingEngine(topo, mapper="exact").map_request(
            req, require_connected=False, free_override=free)
        assert opt is not None and exact is not None
        assert opt.optimal
        assert opt.ted == pytest.approx(exact.ted)

    @pytest.mark.slow
    def test_ilp_proves_nonzero_ted_within_budget(self):
        """The directed MILP formulation proves a k=12 nonzero-TED optimum
        on the fragmented mesh (the case the naive linearization cannot
        close within any reasonable budget)."""
        topo = mesh_2d(6, 6)
        free = _free(topo, FRAGMENTED_6X6)
        req = mesh_2d(3, 4, base_id=10_000)
        opt = MappingEngine(topo, mapper="ilp").map_request(
            req, require_connected=False, free_override=free)
        assert opt is not None
        assert opt.optimal
        assert opt.ted > 0.0


class TestMilpFormulation:
    @pytest.mark.skipif(not HAVE_MILP, reason="scipy.milp unavailable")
    def test_square_case_matches_hand_count(self):
        """2-node path request into a 2-node path candidate: perfect
        embedding, objective recovers TED 0 slots."""
        A = np.array([[0, 1], [1, 0]], bool)
        W = np.ones((2, 2))
        C = np.zeros((2, 2))
        sol = solve_placement_milp(A, W, C, A, W, time_limit=5.0)
        assert sol is not None and sol.proven
        assert sorted(sol.slots.tolist()) == [0, 1]

    @pytest.mark.skipif(not HAVE_MILP, reason="scipy.milp unavailable")
    def test_rectangular_selection_avoids_spurious(self):
        """Placing a 2-node *edgeless* request into a triangle (all edges
        spurious) vs a path-plus-isolate: the optimum uses the isolated
        node to dodge one spurious edge."""
        req_A = np.zeros((2, 2), bool)
        req_W = np.zeros((2, 2))
        # candidate: nodes 0-1 adjacent, node 2 isolated
        cand_A = np.zeros((3, 3), bool)
        cand_A[0, 1] = cand_A[1, 0] = True
        cand_W = np.ones((3, 3))
        C = np.zeros((2, 3))
        sol = solve_placement_milp(req_A, req_W, C, cand_A, cand_W,
                                   time_limit=5.0)
        assert sol is not None and sol.proven
        assert 2 in sol.slots.tolist()      # the isolate is used
        assert sol.objective == pytest.approx(0.0)

    def test_size_gate_formula(self):
        # k*m assignment vars + 2 directed arcs per (req edge, cand edge)
        # + one spurious var per candidate edge
        assert placement_milp_size(2, 3, 1, 2) == 2 * 3 + 2 * 1 * 2 + 2


class TestOptimalFlagProtocol:
    def test_cache_roundtrip_preserves_optimal(self):
        res = MappingResult(nodes=frozenset({5, 6}), ted=1.5,
                            assignment={100: 5, 101: 6}, exact=True,
                            candidates_evaluated=3, optimal=True)
        enc = encode_result(res, [5, 6, 7], [100, 101])
        assert enc.optimal
        dec = decode_result(enc, [5, 6, 7], [100, 101])
        assert dec.optimal and dec.ted == res.ted

    def test_heuristic_results_not_marked_optimal(self):
        topo = mesh_2d(6, 6)
        free = _free(topo, FRAGMENTED_6X6)
        req = mesh_2d(2, 3, base_id=10_000)
        for name in ("hybrid", "bipartite", "rect", "partition"):
            res = MappingEngine(topo, mapper=name).map_request(
                req, require_connected=False, free_override=free)
            assert res is not None
            assert not res.optimal

    def test_ilp_marks_optimal_on_perfect_fit(self):
        topo = mesh_2d(4, 4)
        req = mesh_2d(2, 2, base_id=10_000)
        res = MappingEngine(topo, mapper="ilp").map_request(req)
        assert res is not None
        assert res.ted == 0.0 and res.optimal

    @given(st.integers(0, 10_000))
    @settings(max_examples=10, deadline=None)
    def test_ilp_contract_property(self, seed):
        """Property: on random 5x5 blockings the ilp mapper's result obeys
        the conformance contract and its certificate is never set on a
        result that another mapper improves upon."""
        topo = mesh_2d(5, 5)
        rng = np.random.default_rng(seed)
        n_blocked = int(rng.integers(0, 14))
        blocked = set(rng.choice(sorted(topo.node_attrs), size=n_blocked,
                                 replace=False).tolist())
        free = _free(topo, blocked)
        req = mesh_2d(2, 2, base_id=10_000)
        if len(free) < 4:
            return
        res = MappingEngine(topo, mapper="ilp").map_request(
            req, require_connected=False, free_override=free)
        if res is None:
            return
        _check_contract(topo, req, free, res)
        if res.optimal:
            hyb = MappingEngine(topo, mapper="hybrid").map_request(
                req, require_connected=False, free_override=free)
            if hyb is not None:
                assert hyb.ted >= res.ted - 1e-9


class TestPartitionMapper:
    def test_perfect_fit_on_empty_mesh(self):
        """The compact-blob pre-trim must carve an exact rectangle out of
        an untouched mesh — TED 0, no search involved."""
        topo = mesh_2d(6, 6)
        req = mesh_2d(2, 2, base_id=10_000)
        res = MappingEngine(topo, mapper="partition").map_request(req)
        assert res is not None
        assert res.ted == 0.0

    def test_single_candidate_evaluated(self):
        """partition is O(1) in pool terms: exactly one candidate scored."""
        topo = mesh_2d(6, 6)
        req = mesh_2d(2, 3, base_id=10_000)
        res = MappingEngine(topo, mapper="partition").map_request(req)
        assert res is not None
        assert res.candidates_evaluated == 1
