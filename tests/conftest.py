import os

# Tests that need a multi-device mesh spawn their own env; the default test
# process keeps a SMALL forced device count (8) so meshed unit tests can run
# without touching the dry-run's 512-device setting (per instructions, 512
# is set ONLY in launch/dryrun.py).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402  (initialize after the flag)

import pytest


@pytest.fixture(autouse=True)
def _clear_mesh_context():
    """Keep the module-level mesh context from leaking across tests."""
    yield
    from repro.models.common import clear_mesh_context, set_scan_unroll
    clear_mesh_context()
    set_scan_unroll(False)
