"""Observability plane: tracer purity, ring-buffer determinism, the
metrics registry, timelines and LatencyStats snapshot round-trips.

The load-bearing invariant is that tracing is a *pure observer*: a run
with a Tracer attached must produce bit-identical trajectories, digests
and summaries to the same run without one — across the cluster
scheduler, the serving plane (both engines), the fleet executors and a
chaos storm.  The CI obs-gate re-checks this on the 16x16 gate; these
tests pin it at tier-1 scale, plus the flight-recorder semantics
(count-based deterministic eviction), the Chrome trace-event schema
(via ``tools/trace_report.validate``) and the registry rules
(Prometheus charset, duplicate rejection, snapshot lint).
"""
import importlib.util
import math
from pathlib import Path

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # property tests skip, unit tests still run
    from _hypothesis_fallback import given, settings, st

from repro.chaos import make_fault_plan
from repro.core import mesh_2d
from repro.fleet import Fleet, FleetConfig, PodSpec, Scenario, fleet_trace
from repro.obs.registry import (MetricsRegistry, collect_cluster,
                                collect_fleet)
from repro.obs.timeline import TimelineSampler
from repro.obs.trace import FLEET_PID, Tracer
from repro.sched import (ClusterScheduler, RecoveryConfig, ServingConfig,
                         make_policy, make_trace)
from repro.serve.stats import LatencyStats

ROOT = Path(__file__).resolve().parent.parent

_spec = importlib.util.spec_from_file_location(
    "trace_report", ROOT / "tools" / "trace_report.py")
trace_report = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(trace_report)


# ---------------------------------------------------------------------------
# Tracer mechanics
# ---------------------------------------------------------------------------

class TestTracer:
    def test_export_is_schema_valid(self):
        tr = Tracer(pid=3)
        tr.process_name("pod3 8x8 vnpu")
        tr.thread_name(7, "tenant 7")
        tr.span("queued", "tenant", 1.0, 0.5, tid=7)
        tr.instant("admitted", "tenant", 1.5, tid=7, args={"n_cores": 4})
        tr.counter("cores", 2.0, {"busy": 12, "free": 52})
        doc = tr.export()
        assert trace_report.validate(doc) == []
        assert doc["otherData"] == {"clock": "sim", "emitted": 3,
                                    "dropped": 0}
        # metadata first, sim-seconds exported as microseconds
        assert [e["ph"] for e in doc["traceEvents"]] == \
            ["M", "M", "X", "i", "C"]
        span = doc["traceEvents"][2]
        assert span["ts"] == 1e6 and span["dur"] == 0.5e6
        assert span["pid"] == 3 and span["tid"] == 7

    def test_null_tracer_is_inert(self):
        n0 = Tracer.NULL.n_emitted
        Tracer.NULL.span("x", "c", 0.0, 1.0)
        Tracer.NULL.instant("y", "c", 0.0)
        Tracer.NULL.counter("z", 0.0, {"v": 1})
        Tracer.NULL.process_name("nope")
        assert not Tracer.NULL.enabled
        assert len(Tracer.NULL) == 0
        assert Tracer.NULL.n_emitted == n0
        assert Tracer.NULL.export()["traceEvents"] == []

    def test_ring_overflow_evicts_oldest_by_count(self):
        tr = Tracer(capacity=10)
        for i in range(100):
            tr.span(f"s{i}", "t", float(i), 0.5)
        assert len(tr) == 10
        assert tr.dropped == 90
        names = [e["name"] for e in tr.export()["traceEvents"]]
        assert names == [f"s{i}" for i in range(90, 100)]
        assert tr.export()["otherData"]["dropped"] == 90

    def test_overflow_is_deterministic(self):
        def run():
            tr = Tracer(capacity=7)
            tr.process_name("p")
            for i in range(50):
                tr.span(f"s{i}", "t", float(i), 0.25, tid=i % 3)
            return tr.export()
        assert run() == run()

    def test_drain_absorb_round_trip(self):
        pod = Tracer(capacity=5, pid=2)
        pod.process_name("pod2")
        pod.thread_name(9, "tenant 9")
        for i in range(8):                  # overflows: 3 dropped
            pod.span(f"s{i}", "t", float(i), 0.1, tid=9)
        payload = pod.drain()
        assert len(payload["events"]) == 5
        assert payload["dropped"] == 3
        assert payload["meta"] == {"2": "pod2", "2|9": "tenant 9"}
        assert len(pod) == 0
        # counters restart per window: a clean drain reports 0 dropped
        pod.span("s8", "t", 8.0, 0.1, tid=9)
        assert pod.drain()["dropped"] == 0

        merged = Tracer(pid=FLEET_PID)
        merged.absorb(payload)
        doc = merged.export()
        assert trace_report.validate(doc) == []
        assert {e["pid"] for e in doc["traceEvents"]} == {2}
        assert doc["traceEvents"][0] == {
            "name": "process_name", "ph": "M", "pid": 2, "tid": 0,
            "args": {"name": "pod2"}}

    def test_timeline_sampler_counter_tracks(self):
        tr = Tracer()
        tl = TimelineSampler(tr)
        tl.sample(1.0, n_total=36, n_free=20, n_failed=2,
                  link_loads={(0, 1): 3.0, (1, 0): 1.0})
        doc = tr.export()
        assert trace_report.validate(doc) == []
        by_name = {e["name"]: e["args"] for e in doc["traceEvents"]}
        assert by_name["cores"] == {"busy": 14, "free": 20, "failed": 2}
        assert by_name["link_heat"]["total"] == 4.0
        assert by_name["link_heat"]["max"] == 3.0
        assert by_name["link_heat"]["active_links"] == 2


# ---------------------------------------------------------------------------
# MetricsRegistry
# ---------------------------------------------------------------------------

_check_bench_spec = importlib.util.spec_from_file_location(
    "check_bench", ROOT / "tools" / "check_bench.py")
check_bench = importlib.util.module_from_spec(_check_bench_spec)
_check_bench_spec.loader.exec_module(check_bench)


class TestRegistry:
    def test_counter_gauge_histogram_snapshot(self):
        reg = MetricsRegistry()
        reg.counter("cluster_admitted_total", 41, help="admissions")
        reg.gauge("cluster_utilization_frac", 0.62)
        reg.histogram("cluster_ttft_seconds",
                      {"count": 3, "total": 1.5, "mean": 0.5, "min": 0.1,
                       "max": 0.9, "quantiles": {"0.5": 0.5}})
        snap = reg.snapshot()
        assert [m["name"] for m in snap] == [
            "cluster_admitted_total", "cluster_utilization_frac",
            "cluster_ttft_seconds"]
        assert snap[0]["kind"] == "counter" and snap[0]["value"] == 41
        assert snap[2]["kind"] == "histogram" and snap[2]["count"] == 3
        # the snapshot must pass the same lint check_bench applies to
        # snapshots embedded in BENCH entries
        out = []
        check_bench._check_metrics("m", snap, out)
        assert out == []

    def test_duplicate_and_illegal_names_raise(self):
        reg = MetricsRegistry()
        reg.counter("ok_total", 1)
        with pytest.raises(ValueError):
            reg.counter("ok_total", 2)
        with pytest.raises(ValueError):
            reg.gauge("bad-name", 1.0)
        with pytest.raises(ValueError):
            reg.counter("9starts_with_digit", 1)

    def test_prometheus_text_exposition(self):
        reg = MetricsRegistry()
        reg.counter("x_total", 2, help="a counter")
        reg.gauge("y_s", 0.25)
        reg.histogram("z_seconds",
                      {"count": 2, "total": 3.0, "mean": 1.5, "min": 1.0,
                       "max": 2.0, "quantiles": {"0.5": 1.5}})
        text = reg.prometheus_text()
        assert "# TYPE x_total counter" in text
        assert "x_total 2" in text
        assert "# TYPE z_seconds summary" in text
        assert 'z_seconds{quantile="0.5"} 1.5' in text
        assert "z_seconds_count 2" in text

    def test_collect_cluster_surfaces_every_counter(self):
        """Every ``n_*`` counter on ClusterMetrics lands in the registry —
        the guard against the summary()-drops-a-counter bug class."""
        import dataclasses
        pol = make_policy("vnpu", mesh_2d(6, 6))
        sched = ClusterScheduler(pol, epoch_s=2.0)
        m = sched.run(make_trace("mixed", seed=3, horizon_s=15.0),
                      trace_name="mixed")
        reg = MetricsRegistry()
        collect_cluster(reg, m, prefix="c")
        names = {s["name"] for s in reg.snapshot()}
        for f in dataclasses.fields(m):
            if f.name.startswith("n_"):
                assert f"c_{f.name[2:]}_total" in names, f.name


# ---------------------------------------------------------------------------
# tracer purity: traced == untraced, bit for bit
# ---------------------------------------------------------------------------

def _cluster_digest(m):
    return ([(s.t, s.agg_fps, s.utilization, s.n_resident, s.n_queued)
             for s in m.samples], dict(m.tenant_iterations),
            (m.n_arrived, m.n_admitted, m.n_rejected, m.n_events),
            m.recovery_summary())


class TestTracerPurity:
    def _mixed_run(self, tracer):
        pol = make_policy("vnpu", mesh_2d(6, 6))
        sched = ClusterScheduler(pol, epoch_s=2.0, tracer=tracer)
        return sched.run(make_trace("mixed", seed=5, horizon_s=20.0),
                         trace_name="mixed")

    def test_cluster_6x6_mixed(self):
        base = self._mixed_run(None)
        tr = Tracer()
        traced = self._mixed_run(tr)
        assert _cluster_digest(base) == _cluster_digest(traced)
        assert len(tr) > 0
        assert trace_report.validate(tr.export()) == []

    @pytest.mark.parametrize("engine", ["scalar", "vector"])
    def test_serving_8x8(self, engine):
        def run(tracer):
            pol = make_policy("vnpu", mesh_2d(8, 8), mapper="bipartite")
            sched = ClusterScheduler(
                pol, serving=ServingConfig(engine=engine),
                admission="sla", tracer=tracer)
            return sched.run(make_trace("serving", horizon_s=30.0),
                             trace_name="serving")
        base = run(None)
        tr = Tracer()
        traced = run(tr)
        assert base.request_log == traced.request_log
        assert base.serving_summary() == traced.serving_summary()
        assert _cluster_digest(base) == _cluster_digest(traced)
        names = {e["name"] for e in tr.export()["traceEvents"]}
        assert {"prefill", "decode", "queued"} <= names
        assert trace_report.validate(tr.export()) == []

    def test_chaos_6x6_storm(self):
        plan = make_fault_plan(6, 6, 40.0, seed=7)
        trace = make_trace("mixed", seed=7, horizon_s=40.0)

        def run(tracer):
            pol = make_policy("vnpu", mesh_2d(6, 6))
            sched = ClusterScheduler(pol, epoch_s=2.0,
                                     recovery=RecoveryConfig(),
                                     tracer=tracer)
            sched.begin()
            sched.feed(trace)
            sched.inject_chaos(plan.cluster_events())
            sched.advance_to(None)
            return sched.finish()
        base = run(None)
        tr = Tracer()
        traced = run(tr)
        assert _cluster_digest(base) == _cluster_digest(traced)
        cats = {e.get("cat") for e in tr.export()["traceEvents"]}
        assert "chaos" in cats
        assert trace_report.validate(tr.export()) == []

    def _fleet_run(self, workers, trace_capacity):
        pods = [PodSpec(pod_id=0, rows=8, cols=8),
                PodSpec(pod_id=1, rows=8, cols=8,
                        mem_interface_cols=(0, 7))]
        cfg = FleetConfig(seed=11, window_s=2.0, record_requests=True,
                          trace_capacity=trace_capacity)
        fleet = Fleet(pods, cfg)
        trace = fleet_trace(2, seed=11, horizon_s=8.0)
        scenarios = [Scenario("upgrade", t_s=4.0, pod_id=1, duration_s=4.0)]
        m = fleet.run(trace, scenarios=scenarios, workers=workers,
                      end_s=24.0)
        return m, fleet

    def test_hetero_fleet_traced_matches_untraced(self):
        base, _ = self._fleet_run(1, 0)
        traced, fleet = self._fleet_run(1, 100_000)
        assert base.pod_digests() == traced.pod_digests()
        assert base.serving_summary() == traced.serving_summary()
        doc = fleet.tracer.export()
        assert trace_report.validate(doc) == []
        pids = {e["pid"] for e in doc["traceEvents"]}
        assert {0, 1, FLEET_PID} <= pids

    def test_fleet_serial_and_parallel_traces_identical(self):
        s_m, s_fleet = self._fleet_run(1, 100_000)
        p_m, p_fleet = self._fleet_run(2, 100_000)
        assert s_m.pod_digests() == p_m.pod_digests()
        exp_s, exp_p = s_fleet.tracer.export(), p_fleet.tracer.export()
        assert exp_s["traceEvents"] == exp_p["traceEvents"]

        reg_s, reg_p = MetricsRegistry(), MetricsRegistry()
        collect_fleet(reg_s, s_m)
        collect_fleet(reg_p, p_m)
        assert reg_s.snapshot() == reg_p.snapshot()


# ---------------------------------------------------------------------------
# LatencyStats snapshot / merge round trips
# ---------------------------------------------------------------------------

def _stats_from(xs):
    s = LatencyStats()
    for x in xs:
        s.add(x)
    return s


_samples = st.lists(st.floats(min_value=1e-4, max_value=100.0,
                              allow_nan=False, allow_infinity=False),
                    min_size=0, max_size=200)


class TestLatencyStatsSnapshot:
    @pytest.mark.parametrize("n", [0, 1, 50, 200])
    def test_round_trip_fixed_series(self, n):
        xs = [((i * 29) % 97) / 13.0 + 0.01 for i in range(n)]
        a = _stats_from(xs)
        b = LatencyStats.from_snapshot(a.snapshot())
        assert (b.count, b.total, b.mean) == (a.count, a.total, a.mean)
        if n:
            for q in (50.0, 95.0, 99.0):
                assert b.percentile(q) == a.percentile(q)
        assert b.snapshot() == a.snapshot()
        # a restored instance keeps streaming identically
        a.add(42.0)
        b.add(42.0)
        assert b.snapshot() == a.snapshot()

    @given(_samples)
    @settings(max_examples=60, deadline=None)
    def test_snapshot_round_trip_answers_identically(self, xs):
        a = _stats_from(xs)
        b = LatencyStats.from_snapshot(a.snapshot())
        assert b.count == a.count
        assert b.total == a.total
        assert b.mean == a.mean
        if a.count:
            for q in (50.0, 95.0, 99.0):
                assert b.percentile(q) == a.percentile(q)
        assert b.snapshot() == a.snapshot()

    def test_merged_mode_round_trip(self):
        parts = [_stats_from([float(i) for i in range(100)]),
                 _stats_from([5.0, 7.0, 9.0])]
        m = LatencyStats.merge(parts)
        m2 = LatencyStats.from_snapshot(m.snapshot())
        assert m2.count == m.count
        for q in (10.0, 50.0, 95.0):
            assert m2.percentile(q) == m.percentile(q)
        with pytest.raises(RuntimeError):
            m2.add(1.0)

    def test_unknown_mode_rejected(self):
        with pytest.raises(ValueError):
            LatencyStats.from_snapshot({"mode": "wat", "count": 1,
                                        "total": 1.0, "min": 1.0,
                                        "max": 1.0})

    @staticmethod
    def _assert_order_independent(parts):
        """snapshot -> from_snapshot -> merge must not depend on part
        order: exact while every part is raw and the total stays under
        CUTOVER; to float tolerance once any part sketched (the
        mixture-CDF inversion sums per-part contributions in input
        order).  All-raw totals beyond CUTOVER replay into a P² sketch,
        which is an order-sensitive stream by design — only the exact
        counters are order-free there."""
        rebuilt = [LatencyStats.from_snapshot(p.snapshot()) for p in parts]
        a = LatencyStats.merge(parts)
        b = LatencyStats.merge(list(reversed(rebuilt)))
        assert b.count == a.count
        assert math.isclose(b.total, a.total, rel_tol=1e-12, abs_tol=1e-12)
        if a.count == 0:
            return
        assert b.vmin == a.vmin and b.vmax == a.vmax
        all_raw = all(p._sketches is None and p._cdf is None
                      for p in parts)
        if all_raw and a.count > LatencyStats.CUTOVER:
            return
        for q in (50.0, 95.0, 99.0):
            pa, pb = a.percentile(q), b.percentile(q)
            if all_raw:
                assert pa == pb
            else:
                assert math.isclose(pa, pb, rel_tol=1e-6, abs_tol=1e-6)

    def test_merge_order_independent_exact_parts(self):
        self._assert_order_independent(
            [_stats_from([1.0, 5.0, 2.0]), _stats_from([9.0]),
             _stats_from([0.5, 0.25])])

    def test_merge_order_independent_sketched_parts(self):
        big = _stats_from([((i * 37) % 101) / 7.0 for i in range(300)])
        small = _stats_from([3.0, 1.0, 4.0])
        assert big._sketches is not None    # really sketched
        self._assert_order_independent([big, small])
        self._assert_order_independent(
            [big, _stats_from([((i * 17) % 89) / 5.0
                               for i in range(200)])])

    @given(st.lists(_samples, min_size=2, max_size=4))
    @settings(max_examples=40, deadline=None)
    def test_merge_is_order_independent(self, parts_xs):
        self._assert_order_independent([_stats_from(xs)
                                        for xs in parts_xs])


# ---------------------------------------------------------------------------
# embedded metrics snapshots in BENCH records (check_bench lint)
# ---------------------------------------------------------------------------

class TestBenchMetricsLint:
    def _record_with(self, metrics):
        return {"benchmark": "cluster_sim", "gates": {},
                "entries": [{"mesh": "6x6", "trace": "mixed",
                             "mode": "ledger", "metrics": metrics}]}

    def test_valid_snapshot_is_clean(self):
        reg = MetricsRegistry()
        reg.counter("a_total", 1)
        reg.gauge("b_s", 2.0)
        rec = self._record_with(reg.snapshot())
        assert check_bench.check_record(rec) == []

    def test_violations_flagged(self):
        bad = [{"name": "bad name", "kind": "counter", "value": 1},
               {"name": "dup_total", "kind": "counter", "value": 1},
               {"name": "dup_total", "kind": "counter", "value": 2},
               {"name": "nan_g", "kind": "gauge", "value": float("nan")},
               {"name": "wat", "kind": "timer", "value": 1},
               {"name": "h", "kind": "histogram", "count": 1, "sum": 1.0,
                "min": 1.0, "max": 1.0, "quantiles": []}]
        out = check_bench.check_record(self._record_with(bad))
        assert any("does not match" in v for v in out)
        assert any("duplicates metric name" in v for v in out)
        assert any("not a finite number" in v for v in out)
        assert any("timer" in v for v in out)
        assert any("quantiles" in v for v in out)
