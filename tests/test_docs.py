"""Docs lint in tier-1: documented commands and links must resolve (the
same checks the CI docs job runs via tools/check_docs.py)."""
import importlib.util
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

_spec = importlib.util.spec_from_file_location(
    "check_docs", ROOT / "tools" / "check_docs.py")
check_docs = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_docs)


class TestCommandParsing:
    def test_extract_commands_joins_continuations(self):
        text = ("intro\n```bash\n# comment\npip install -e .\n"
                "PYTHONPATH=src python benchmarks/cluster_sim.py \\\n"
                "    --trace mixed --policy vnpu\n```\n")
        cmds = check_docs.extract_commands(text)
        assert cmds == ["PYTHONPATH=src python benchmarks/cluster_sim.py "
                        "--trace mixed --policy vnpu"]

    def test_parse_python_command(self):
        target, flags, values = check_docs.parse_python_command(
            "PYTHONPATH=src python benchmarks/cluster_sim.py "
            "--trace pod-mixed --mesh 32,32 --json")
        assert target == "benchmarks/cluster_sim.py"
        assert flags == ["--trace", "--mesh", "--json"]
        assert values == {"--trace": "pod-mixed", "--mesh": "32,32"}

    def test_parse_module_invocation(self):
        target, flags, _ = check_docs.parse_python_command(
            "PYTHONPATH=src python -m benchmarks.run")
        assert target == "-m benchmarks.run"
        assert flags == []


class TestDocChecker:
    def test_repo_docs_are_clean(self):
        """The real README / architecture / DESIGN commands all validate."""
        assert check_docs.DocChecker().run() == 0

    def test_detects_unknown_flag_and_trace(self):
        checker = check_docs.DocChecker()
        checker.check_command(
            "fake.md", "PYTHONPATH=src python benchmarks/cluster_sim.py "
            "--no-such-flag --trace not-a-trace")
        msgs = "\n".join(checker.errors)
        assert "--no-such-flag" in msgs
        assert "not-a-trace" in msgs

    def test_detects_missing_script(self):
        checker = check_docs.DocChecker()
        checker.check_command("fake.md", "python benchmarks/gone.py --json")
        assert any("missing file" in e for e in checker.errors)

    def test_detects_broken_link(self):
        checker = check_docs.DocChecker()
        checker.check_links("README.md", "see [x](docs/absent.md)")
        assert any("broken link" in e for e in checker.errors)

    def test_architecture_doc_linked_from_readme(self):
        readme = (ROOT / "README.md").read_text()
        assert "docs/architecture.md" in readme
        assert (ROOT / "docs" / "architecture.md").exists()
