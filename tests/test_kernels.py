"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp oracle,
sweeping shapes/dtypes + hypothesis property sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # property tests skip, unit tests still run
    from _hypothesis_fallback import given, settings, st

from repro.kernels import ops, ref


def _allclose(a, b, dtype):
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# streamed matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(64, 128, 64), (128, 384, 256),
                                   (100, 60, 40)])
def test_streamed_matmul_shapes(shape, dtype):
    M, K, N = shape
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (M, K), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N), dtype)
    out = ops.matmul(x, w, block_m=64, block_n=64, block_k=64)
    _allclose(out, ref.matmul_ref(x, w), dtype)


@given(st.integers(1, 5), st.integers(1, 5), st.integers(1, 5))
@settings(max_examples=8, deadline=None)
def test_streamed_matmul_property(mi, ki, ni):
    M, K, N = 32 * mi, 32 * ki, 32 * ni
    x = jax.random.normal(jax.random.PRNGKey(mi), (M, K), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(ki), (K, N), jnp.float32)
    out = ops.matmul(x, w, block_m=32, block_n=32, block_k=32)
    _allclose(out, ref.matmul_ref(x, w), jnp.float32)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("S,hd", [(128, 64), (256, 128)])
def test_flash_attention(S, hd, causal, dtype):
    k = jax.random.PRNGKey(0)
    shape = (2, 3, S, hd)
    q = jax.random.normal(k, shape, dtype)
    kk = jax.random.normal(jax.random.PRNGKey(1), shape, dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), shape, dtype)
    out = ops.flash_attention(q, kk, v, causal=causal, block_q=64, block_k=64)
    _allclose(out, ref.flash_attention_ref(q, kk, v, causal=causal), dtype)


def test_flash_blocks_dont_change_result():
    k = jax.random.PRNGKey(3)
    q = jax.random.normal(k, (1, 2, 256, 64))
    kk = jax.random.normal(jax.random.PRNGKey(4), (1, 2, 256, 64))
    v = jax.random.normal(jax.random.PRNGKey(5), (1, 2, 256, 64))
    a = ops.flash_attention(q, kk, v, block_q=64, block_k=128)
    b = ops.flash_attention(q, kk, v, block_q=128, block_k=64)
    _allclose(a, b, jnp.float32)


# ---------------------------------------------------------------------------
# SSD chunk scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("S,chunk", [(64, 16), (128, 32), (96, 32)])
def test_ssd_scan(S, chunk, dtype):
    b, H, P, N = 2, 4, 16, 32
    k = jax.random.PRNGKey(0)
    x = (jax.random.normal(k, (b, S, H, P)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (b, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (H,)) * 0.3)
    B = (jax.random.normal(jax.random.PRNGKey(3), (b, S, N)) * 0.5).astype(dtype)
    C = (jax.random.normal(jax.random.PRNGKey(4), (b, S, N)) * 0.5).astype(dtype)
    out = ops.ssd_scan(x, dt, A, B, C, chunk=chunk)
    r = ref.ssd_scan_kernel_ref(x, dt, A, B, C, chunk)
    scale = float(jnp.abs(r.astype(jnp.float32)).max()) + 1e-6
    err = float(jnp.abs(out.astype(jnp.float32) - r.astype(jnp.float32)).max())
    assert err / scale < (5e-2 if dtype == jnp.bfloat16 else 1e-4)


def test_ssd_chunking_invariance():
    """Same result for different chunk sizes (associativity of the scan)."""
    b, S, H, P, N = 1, 64, 2, 8, 16
    k = jax.random.PRNGKey(7)
    x = jax.random.normal(k, (b, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(8), (b, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(9), (H,)) * 0.3)
    B = jax.random.normal(jax.random.PRNGKey(10), (b, S, N)) * 0.5
    C = jax.random.normal(jax.random.PRNGKey(11), (b, S, N)) * 0.5
    a = ops.ssd_scan(x, dt, A, B, C, chunk=16)
    bb = ops.ssd_scan(x, dt, A, B, C, chunk=64)
    _allclose(a, bb, jnp.float32)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("S,length", [(256, 100), (512, 512), (512, 1)])
def test_decode_attention(S, length, dtype):
    B, H, hd = 2, 4, 64
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (B, H, hd), dtype)
    kc = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, hd), dtype)
    vc = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, hd), dtype)
    out = ops.decode_attention(q, kc, vc, length=length, block_s=128)
    _allclose(out, ref.decode_attention_ref(q, kc, vc, length), dtype)
