"""Core vNPU layer: topology, routing tables, vRouter, vChunk, buddy,
mapping, hypervisor — unit + property tests (hypothesis)."""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # property tests skip, unit tests still run
    from _hypothesis_fallback import given, settings, st

from repro.core import (AccessCounter, AllocationError, BuddyAllocator,
                        CompactRoutingTable, DenseRoutingTable, Hypervisor,
                        InstructionRouter, MIGPartitioner, NoCRouter,
                        PageTable, PageTLB, RangeTLB, RangeTranslationTable,
                        RoutingError, RoutingTableDirectory, RTTEntry,
                        Topology, TranslationFault, UVMAllocator,
                        VNPURequest, confined_path, dor_path,
                        enumerate_connected_subsets, line, mesh_2d,
                        min_topology_edit_distance, ring,
                        straightforward_mapping, topology_edit_distance)
from repro.core.mapping import induced_edit_cost, hungarian, mem_dist_node_match
import numpy as np


# ---------------------------------------------------------------------------
# topology
# ---------------------------------------------------------------------------

class TestTopology:
    def test_mesh_structure(self):
        t = mesh_2d(4, 4)
        assert t.num_nodes == 16
        assert t.num_edges == 2 * 4 * 3
        assert t.is_rect_mesh() == (4, 4)
        assert t.is_connected()
        assert t.degree(0) == 2 and t.degree(5) == 4

    def test_subgraph_rect_detection(self):
        t = mesh_2d(5, 5)
        sub = t.subgraph([6, 7, 8, 11, 12, 13])
        assert sub.is_rect_mesh() == (2, 3)
        ragged = t.subgraph([0, 1, 2, 5])
        assert ragged.is_rect_mesh() is None

    def test_connectivity(self):
        t = mesh_2d(3, 3)
        assert t.is_connected([0, 1, 2])
        assert not t.is_connected([0, 2])
        assert t.bfs_hops(0, 8) == 4
        assert t.bfs_hops(0, 8, allowed=[0, 1, 2, 5, 8]) == 4

    def test_canonical_key_isomorphism(self):
        t = mesh_2d(4, 4)
        # two paths of 4 at different positions are isomorphic
        a = t.subgraph([0, 1, 2, 3]).canonical_key()
        b = t.subgraph([12, 13, 14, 15]).canonical_key()
        assert a == b
        # a star (center 5 with leaves 1, 4, 6) is NOT a path
        c = t.subgraph([5, 1, 4, 6]).canonical_key()
        assert c != a

    @given(st.integers(2, 4), st.integers(2, 4), st.integers(2, 5))
    @settings(max_examples=20, deadline=None)
    def test_enumerate_connected_subsets_property(self, r, c, k):
        t = mesh_2d(r, c)
        seen = set()
        for s in enumerate_connected_subsets(t, k, max_results=500):
            assert len(s) == k
            assert t.is_connected(s)
            assert s not in seen  # uniqueness
            seen.add(s)


# ---------------------------------------------------------------------------
# routing tables + vRouter
# ---------------------------------------------------------------------------

class TestRouting:
    def test_dense_lookup_and_isolation(self):
        rt = DenseRoutingTable(1, {0: 5, 1: 6, 2: 9})
        assert rt.lookup(0) == 5
        with pytest.raises(RoutingError):
            rt.lookup(7)
        with pytest.raises(ValueError):
            DenseRoutingTable(2, {0: 5, 1: 5})  # duplicate physical

    def test_compact_matches_dense(self):
        # 2x3 virtual mesh at p_start=6 on a 5-wide physical mesh
        c = CompactRoutingTable(1, v_start=0, p_start=6, shape=(2, 3),
                                phys_cols=5)
        assert c.as_dict() == {0: 6, 1: 7, 2: 8, 3: 11, 4: 12, 5: 13}
        assert c.storage_bits() < DenseRoutingTable(1, c.as_dict()).storage_bits()

    def test_directory_vmid_isolation(self):
        d = RoutingTableDirectory()
        d.install(DenseRoutingTable(1, {0: 0}))
        d.install(DenseRoutingTable(2, {0: 8}))
        assert d.translate(1, 0) == 0
        assert d.translate(2, 0) == 8
        with pytest.raises(RoutingError):
            d.translate(3, 0)

    def test_instruction_router_lookup_cache(self):
        topo = mesh_2d(4, 4)
        d = RoutingTableDirectory()
        d.install(DenseRoutingTable(1, {i: i for i in range(16)}))
        ir = InstructionRouter(d, topo)
        r1 = ir.dispatch(1, 15)
        r2 = ir.dispatch(1, 15)   # consecutive same core -> no RT lookup
        assert r1.rt_lookup and not r2.rt_lookup
        assert r2.cycles < r1.cycles

    def test_dor_path(self):
        path = dor_path((0, 0), (2, 3))
        assert path[0] == (0, 0) and path[-1] == (2, 3)
        # X first, then Y
        assert path[1] == (0, 1) and path[4] == (1, 3)

    def test_noc_interference_detection(self):
        # Fig 5 scenario: vNPU2 = {5,6,7,9,11} (physical); 5->9 via DOR
        # passes through a foreign core
        topo = mesh_2d(4, 4)
        rt = DenseRoutingTable(2, {0: 5, 1: 6, 2: 7, 3: 9, 4: 11})
        noc = NoCRouter(topo)
        owned = set(rt.as_dict().values())
        tr = noc.route(rt, 2, 3, owned, confined=False)  # p7 -> p9
        assert tr.interference_nodes - owned == tr.interference_nodes
        tr_conf = noc.route(rt, 2, 3, owned, confined=True)
        assert not tr_conf.interference_nodes
        assert set(tr_conf.path) <= owned

    def test_virtualization_overhead_small(self):
        # Table 3: vSend/vReceive within a few % of bare-metal
        topo = mesh_2d(4, 4)
        rt = DenseRoutingTable(1, {i: i for i in range(16)})
        noc = NoCRouter(topo)
        v = noc.route(rt, 0, 3, range(16), confined=False, virtualized=True)
        b = noc.route(rt, 0, 3, range(16), confined=False, virtualized=False)
        ovh = (v.send_cycles - b.send_cycles) / b.send_cycles
        assert 0 <= ovh < 0.05


# ---------------------------------------------------------------------------
# vChunk
# ---------------------------------------------------------------------------

class TestVChunk:
    def _rtt(self, n=8, size=1 << 20):
        return RangeTranslationTable(
            [RTTEntry(vaddr=i * size, paddr=(n - i) * size, size=size)
             for i in range(n)])

    def test_translate_and_fault(self):
        rtt = self._rtt()
        assert rtt.translate(0) == 8 << 20
        assert rtt.translate((1 << 20) + 5) == (7 << 20) + 5
        with pytest.raises(TranslationFault):
            rtt.translate(9 << 20)

    def test_overlap_rejected(self):
        rtt = self._rtt(2)
        with pytest.raises(ValueError):
            rtt.insert(RTTEntry(vaddr=100, paddr=0, size=1 << 20))

    def test_pattern2_monotonic_single_walk_step(self):
        """Monotonic stream: every miss resolves in one cursor step."""
        rtt = self._rtt(8)
        tlb = RangeTLB(rtt, n_entries=4)
        for va in range(0, 8 << 20, 1 << 18):
            tlb.translate(va)
        assert tlb.stats.misses == 8
        # cursor walk: <=2 table reads per miss (check cur, advance once) —
        # O(1), vs O(n) for an un-cursored scan
        assert tlb.stats.walk_steps <= 2 * tlb.stats.misses

    def test_pattern3_last_v_jump_back(self):
        """Iteration 2+ jumps straight back to the start via last_v."""
        rtt = self._rtt(8)
        tlb = RangeTLB(rtt, n_entries=4)
        for _ in range(3):
            for va in range(0, 8 << 20, 1 << 19):
                tlb.translate(va)
        # without last_v, each wrap-around would scan ~n entries
        assert tlb.stats.last_v_hits >= 1
        per_iter = tlb.stats.walk_steps / 3
        assert per_iter <= 2.5 * 8  # O(1) table reads per miss

    def test_page_tlb_lru(self):
        pt = PageTable(4096)
        pt.map_range(0, 1 << 30, 1 << 20)
        tlb = PageTLB(pt, n_entries=2)
        for va in (0, 4096, 8192, 0):
            tlb.translate(va)
        assert tlb.stats.misses == 4  # 0 was evicted by LRU

    def test_access_counter_throttles(self):
        ac = AccessCounter(max_bytes_per_window=1000, window_cycles=100)
        assert ac.record(0, 800)
        assert not ac.record(10, 300)
        assert ac.record(150, 300)  # new window


# ---------------------------------------------------------------------------
# buddy allocator
# ---------------------------------------------------------------------------

class TestBuddy:
    def test_alloc_free_coalesce(self):
        b = BuddyAllocator(1 << 30, min_block=1 << 20)
        a1, s1 = b.alloc(3 << 20)
        assert s1 == 4 << 20
        a2, _ = b.alloc(1 << 20)
        b.free_block(a1)
        b.free_block(a2)
        assert b.free_bytes() == 1 << 30
        a3, s3 = b.alloc(1 << 30)
        assert s3 == 1 << 30

    @given(st.lists(st.integers(1, 64), min_size=1, max_size=24))
    @settings(max_examples=25, deadline=None)
    def test_invariants_property(self, sizes):
        b = BuddyAllocator(1 << 28, min_block=1 << 20)
        held = []
        for i, mb in enumerate(sizes):
            try:
                addr, _ = b.alloc(mb << 20)
                held.append(addr)
            except Exception:
                pass
            if i % 3 == 2 and held:
                b.free_block(held.pop(0))
            b.check_invariants()
        for a in held:
            b.free_block(a)
        b.check_invariants()
        assert b.free_bytes() == 1 << 28


# ---------------------------------------------------------------------------
# topology mapping (Algorithm 1)
# ---------------------------------------------------------------------------

class TestMapping:
    def test_hungarian_simple(self):
        cost = np.array([[4, 1, 3], [2, 0, 5], [3, 2, 2]], float)
        assign = hungarian(cost)
        total = sum(cost[i, j] for i, j in enumerate(assign))
        assert total == 5.0  # optimal

    def test_ted_identical_zero(self):
        t = mesh_2d(3, 3)
        d, m = topology_edit_distance(t, mesh_2d(3, 3, base_id=100))
        assert d == 0.0
        assert len(m) == 9

    def test_ted_line_vs_ring(self):
        d, _ = topology_edit_distance(line(5), ring(5, base_id=50))
        assert d == 1.0  # one extra edge

    def test_induced_cost_consistency(self):
        t1, t2 = line(4), ring(4, base_id=9)
        d, m = topology_edit_distance(t1, t2)
        assert induced_edit_cost(t1, t2, m,
                                 lambda a, b: 0.0,
                                 lambda e1, e2: 1.0) == pytest.approx(d)

    def test_paper_lock_in_scenario(self):
        """Two 3x3 requests on a 5x5 mesh: exact + similar (TED small)."""
        t = mesh_2d(5, 5)
        r1 = min_topology_edit_distance(t, [], mesh_2d(3, 3, base_id=100))
        assert r1 is not None and r1.exact and r1.ted == 0.0
        r2 = min_topology_edit_distance(t, r1.nodes, mesh_2d(3, 3, base_id=100))
        assert r2 is not None and not r2.exact
        assert 0 < r2.ted <= 8
        assert t.is_connected(r2.nodes)
        assert not (r1.nodes & r2.nodes)

    def test_similar_beats_straightforward(self):
        t = mesh_2d(6, 6)
        blocked = {0, 1, 6, 7, 28, 29, 34, 35}  # corners taken
        req = mesh_2d(3, 4, base_id=100)
        sim = min_topology_edit_distance(t, blocked, req)
        zig = straightforward_mapping(t, blocked, req)
        assert sim.ted <= zig.ted

    def test_heterogeneous_mem_dist_penalty(self):
        t = mesh_2d(4, 4, mem_interface_cols=(0,))
        req = mesh_2d(2, 2, base_id=100, mem_interface_cols=(0,))
        near = min_topology_edit_distance(
            t, [], req, node_match=mem_dist_node_match(0.5))
        # best allocation should hug the memory-interface column
        cols = {t.coords[n][1] for n in near.nodes}
        assert min(cols) == 0

    @given(st.integers(3, 5), st.integers(3, 5),
           st.integers(2, 6), st.integers(0, 6))
    @settings(max_examples=15, deadline=None)
    def test_mapping_respects_allocation(self, r, c, k, nblocked):
        t = mesh_2d(r, c)
        blocked = set(list(t.nodes())[:nblocked])
        if k > t.num_nodes - len(blocked):
            return
        req = line(k, base_id=200)
        res = min_topology_edit_distance(t, blocked, req)
        if res is not None:
            assert len(res.nodes) == k          # R-1
            assert not (res.nodes & blocked)     # no poaching
            assert t.is_connected(res.nodes)     # R-3
            assert set(res.assignment.values()) == set(res.nodes)


# ---------------------------------------------------------------------------
# hypervisor
# ---------------------------------------------------------------------------

class TestHypervisor:
    def _hyp(self):
        return Hypervisor(mesh_2d(6, 6), hbm_bytes=1 << 32)

    def test_create_destroy_lifecycle(self):
        hyp = self._hyp()
        v = hyp.create_vnpu(VNPURequest(topology=mesh_2d(2, 3),
                                        memory_bytes=64 << 20))
        assert v.n_cores == 6
        assert len(v.rtt) >= 1
        assert v.rtt.translate(0) is not None
        assert hyp.utilization() == 6 / 36
        hyp.destroy_vnpu(v.vmid)
        assert hyp.utilization() == 0.0
        assert hyp.buddy.free_bytes() == 1 << 32

    def test_memory_exhaustion_rolls_back(self):
        hyp = Hypervisor(mesh_2d(4, 4), hbm_bytes=1 << 26)  # 64 MB
        with pytest.raises(AllocationError):
            hyp.create_vnpu(VNPURequest(topology=mesh_2d(2, 2),
                                        memory_bytes=1 << 30))
        assert hyp.utilization() == 0.0
        assert hyp.buddy.free_bytes() == 1 << 26

    def test_many_tenants_beat_mig_utilization(self):
        """The paper's core utilization claim: flexible topology fits more."""
        hyp = self._hyp()
        for _ in range(4):
            hyp.create_vnpu(VNPURequest(topology=mesh_2d(3, 3)))
        assert hyp.utilization() == 1.0
        mig = MIGPartitioner(mesh_2d(6, 6), [(3, 6), (3, 6)])
        parts = 0
        try:
            for _ in range(4):
                mig.allocate(9)
                parts += 1
        except AllocationError:
            pass
        assert parts == 2  # MIG fits only 2 nine-core tenants

    def test_mig_tdm_when_oversubscribed(self):
        mig = MIGPartitioner(mesh_2d(6, 6), [(4, 6), (2, 6)])
        part, share = mig.allocate(30)
        assert share < 1.0

    def test_remap_after_failure(self):
        hyp = self._hyp()
        v = hyp.create_vnpu(VNPURequest(topology=mesh_2d(2, 2)))
        dead = next(iter(v.p_cores))
        v2 = hyp.remap_vnpu(v.vmid, [dead])
        assert dead not in v2.p_cores
        assert len(v2.p_cores) == 4

    def test_uvm_allocator(self):
        uvm = UVMAllocator(mesh_2d(4, 4))
        got = uvm.allocate(5)
        assert len(got) == 5
        uvm.release(got)
        assert uvm.allocate(16)
