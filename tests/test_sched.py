"""Cluster scheduler layer: placement policies, event loop, defragmenting
migration, cross-tenant simulator wiring — plus the allocation-path coverage
the refactor demanded (remap_vnpu details, MIG TDM oversubscription,
directional link contention)."""
import math

import pytest

from repro.core import (AllocationError, Hypervisor, MIGPartitioner,
                        UVMAllocator, VNPURequest, mesh_2d)
from repro.core import simulator as S
from repro.core import workloads as W
from repro.core.simulator import Flow, flow_paths, link_contention
from repro.sched import (ClusterScheduler, EventQueue, MIGPolicy, TenantSpec,
                         UVMPolicy, VNPUPolicy, compare_policies, make_policy,
                         make_trace, poisson_trace)
from repro.sched.events import ARRIVAL, DEPARTURE
from repro.sched.traces import TraceConfig, get_serving_workload


def _spec(tid=1, model="resnet18", n_cores=4, arrival=0.0, duration=10.0,
          **kw):
    return TenantSpec(tid=tid, model=model, n_cores=n_cores,
                      arrival_s=arrival, duration_s=duration, **kw)


# ---------------------------------------------------------------------------
# simulator: directional link contention (bugfix regression)
# ---------------------------------------------------------------------------

class TestDirectionalContention:
    def test_opposing_flows_do_not_contend(self):
        """Full-duplex mesh link: A->B and B->A ride separate wires."""
        topo = mesh_2d(1, 2)
        flows = [Flow(src=0, dst=1, bytes_per_iter=1000),
                 Flow(src=1, dst=0, bytes_per_iter=1000)]
        factors = link_contention(flow_paths(topo, flows), flows)
        assert factors == [1.0, 1.0]

    def test_same_direction_flows_contend(self):
        topo = mesh_2d(1, 3)
        flows = [Flow(src=0, dst=2, bytes_per_iter=1000),
                 Flow(src=1, dst=2, bytes_per_iter=1000)]
        factors = link_contention(flow_paths(topo, flows), flows)
        assert factors[0] == 2.0 and factors[1] == 2.0

    def test_tenant_flows_pipeline_and_tensor(self):
        topo = mesh_2d(6, 6)
        hw = S.SIM_CONFIG
        cnn = S.tenant_flows(W.get_workload("resnet18"), [0, 1, 2, 3],
                             topo, hw, owner=7)
        assert cnn and all(f.owner == 7 for f in cnn)
        llm = S.tenant_flows(W.get_workload("gpt2_small"), [0, 1, 6, 7],
                             topo, hw, owner=9)
        assert len(llm) == 4  # ring over 4 cores
        assert all(f.bytes_per_iter > 0 for f in llm)

    def test_external_flows_slow_tensor_allreduce(self):
        topo = mesh_2d(6, 6)
        hw = S.SIM_CONFIG
        g = W.get_workload("transformer")
        quiet = S.simulate(g, [0, 1, 6, 7], topo, hw)
        noisy = S.simulate(g, [0, 1, 6, 7], topo, hw,
                           external_flows=S.tenant_flows(
                               g, [2, 3, 8, 9], topo, hw, owner=2) * 4)
        assert noisy.interval_cycles >= quiet.interval_cycles


# ---------------------------------------------------------------------------
# refactored allocation paths: remap + MIG TDM
# ---------------------------------------------------------------------------

class TestRemapVNPU:
    def test_remap_reinstalls_routing_preserves_rtt_releases_cores(self):
        hyp = Hypervisor(mesh_2d(6, 6), hbm_bytes=1 << 32)
        v = hyp.create_vnpu(VNPURequest(topology=mesh_2d(2, 2),
                                        memory_bytes=32 << 20))
        old_cores = set(v.p_cores)
        rtt_before = [(e.vaddr, e.paddr, e.size) for e in v.rtt.entries]
        dead = next(iter(v.p_cores))

        v2 = hyp.remap_vnpu(v.vmid, [dead])

        # old cores released; the dead one is quarantined, not freed
        assert dead not in v2.p_cores
        assert hyp.allocated_cores() == set(v2.p_cores)
        assert dead in hyp.quarantined
        # routing table reinstalled: directory translates to the new cores
        for vcore, pcore in v2.assignment.items():
            assert hyp.directory.translate(v.vmid, vcore) == pcore
        assert set(v2.assignment.values()) == set(v2.p_cores)
        # RTT preserved: global-memory contents survive the migration
        rtt_after = [(e.vaddr, e.paddr, e.size) for e in v2.rtt.entries]
        assert rtt_after == rtt_before
        # vacated healthy cores can be reallocated; the dead one cannot
        free = hyp.free_cores()
        assert (old_cores - set(v2.p_cores)) - {dead} <= free
        assert dead not in free

    def test_migrate_vnpu_compacts_or_stays(self):
        hyp = Hypervisor(mesh_2d(6, 6), hbm_bytes=1 << 32)
        v = hyp.create_vnpu(VNPURequest(topology=mesh_2d(2, 2)))
        v2, moved = hyp.migrate_vnpu(v.vmid)
        assert len(v2.p_cores) == 4
        if not moved:
            assert set(v2.p_cores) == set(v.p_cores)


class TestMIGTDM:
    def test_oversubscribed_time_share(self):
        mig = MIGPartitioner(mesh_2d(6, 6), [(4, 6), (2, 6)])
        part, share = mig.allocate(30)
        assert share == pytest.approx(24 / 30)
        assert share < 1.0
        # TDM tenant still only uses its partition's physical cores
        assert len(part.cores) == 24

    def test_utilization_counts_useful_cores_only(self):
        mig = MIGPartitioner(mesh_2d(6, 6), [(3, 6), (3, 6)])
        p1, s1 = mig.allocate(4)       # 4 useful of an 18-core partition
        assert s1 == 1.0
        assert mig.utilization() == pytest.approx(4 / 36)
        p2, s2 = mig.allocate(30)      # oversubscribed: caps at partition
        assert s2 < 1.0
        assert mig.utilization() == pytest.approx((4 + 18) / 36)
        mig.release(p1.pid)
        mig.release(p2.pid)
        assert mig.utilization() == 0.0
        assert mig.free_cores() == set(range(36))

    def test_mig_policy_tdm_placement(self):
        pol = MIGPolicy(mesh_2d(6, 6), partition_shapes=[(3, 6), (3, 6)])
        p = pol.allocate(_spec(n_cores=24))
        assert p.time_share < 1.0
        assert p.tdm_physical == 18
        assert len(p.cores) == 24          # virtual cores, cycled
        assert p.n_cores == 18             # distinct physical cores
        pol.release(p)
        assert pol.utilization() == 0.0


# ---------------------------------------------------------------------------
# placement policies behind one protocol
# ---------------------------------------------------------------------------

class TestPolicies:
    @pytest.mark.parametrize("name", ["vnpu", "mig", "uvm"])
    def test_allocate_release_utilization(self, name):
        pol = make_policy(name, mesh_2d(6, 6))
        p = pol.allocate(_spec(n_cores=4))
        assert p.n_cores >= 1
        assert 0.0 < pol.utilization() <= 1.0
        pol.release(p)
        assert pol.utilization() == 0.0
        assert pol.free_cores() == set(range(36))

    def test_vnpu_exact_cores_mig_holds_partition(self):
        vn = VNPUPolicy(mesh_2d(6, 6))
        mg = MIGPolicy(mesh_2d(6, 6))
        pv = vn.allocate(_spec(n_cores=4))
        pm = mg.allocate(_spec(tid=2, n_cores=4))
        assert vn.utilization() == pytest.approx(4 / 36)
        # MIG reports useful cores, but physically holds the partition
        assert mg.utilization() == pytest.approx(4 / 36)
        assert len(mg.free_cores()) < 32
        assert len(vn.free_cores()) == 32
        assert pv.vnpu is not None and pm.vnpu is None

    def test_uvm_comm_mode_and_hbm_flag(self):
        pol = UVMPolicy(mesh_2d(6, 6))
        p = pol.allocate(_spec(n_cores=5))
        assert p.comm == "uvm" and p.hbm_client

    def test_vnpu_migrate_avoids_core(self):
        pol = VNPUPolicy(mesh_2d(6, 6))
        p = pol.allocate(_spec(n_cores=4))
        dead = p.cores[0]
        p2, moved = pol.migrate(p, avoid=[dead])
        assert moved and dead not in p2.cores

    def test_exhaustion_raises(self):
        pol = UVMPolicy(mesh_2d(2, 2))
        pol.allocate(_spec(n_cores=3))
        with pytest.raises(AllocationError):
            pol.allocate(_spec(tid=2, n_cores=2))


# ---------------------------------------------------------------------------
# events + traces
# ---------------------------------------------------------------------------

class TestEventsAndTraces:
    def test_event_queue_time_then_insertion_order(self):
        q = EventQueue()
        q.push(5.0, ARRIVAL, tid=1)
        q.push(1.0, DEPARTURE, tid=2)
        q.push(1.0, ARRIVAL, tid=3)
        got = [(e.time, e.kind, e.tid) for e in q.drain()]
        assert got == [(1.0, DEPARTURE, 2), (1.0, ARRIVAL, 3),
                       (5.0, ARRIVAL, 1)]

    def test_same_instant_departure_frees_cores_before_arrival(self):
        q = EventQueue()
        q.push(5.0, ARRIVAL, tid=1)      # pushed first, lower seq
        q.push(5.0, DEPARTURE, tid=2)
        got = [(e.kind, e.tid) for e in q.drain()]
        assert got == [(DEPARTURE, 2), (ARRIVAL, 1)]

    def test_equal_timestamp_kind_priority_total_order(self):
        """At one instant: departure < failure < epoch < arrival < resize,
        whatever order they were pushed in — the scheduler's same-tick
        semantics (free cores, quarantine, observe, admit, grow) depend
        on exactly this order."""
        from repro.sched.events import EPOCH, FAILURE, RESIZE
        q = EventQueue()
        for kind in (RESIZE, ARRIVAL, EPOCH, FAILURE, DEPARTURE):
            q.push(3.0, kind, tid=1)
        got = [e.kind for e in q.drain()]
        assert got == [DEPARTURE, FAILURE, EPOCH, ARRIVAL, RESIZE]

    def test_equal_time_and_kind_preserves_insertion_order(self):
        """Ties within one (time, kind) bucket break by insertion seq —
        the heap is fully deterministic, never Python-object-id ordered."""
        q = EventQueue()
        for tid in (7, 3, 9, 1):
            q.push(2.0, ARRIVAL, tid=tid)
        assert [e.tid for e in q.drain()] == [7, 3, 9, 1]

    def test_interleaved_pushes_replay_identically(self):
        """Two queues fed the same push/pop script emit the same event
        stream (heap order is a pure function of the script, not of heap
        internals), and a full drain honors (time, kind, insertion)."""
        from repro.sched.events import EPOCH, FAILURE, RESIZE
        script = [(5.0, ARRIVAL, 1), (5.0, RESIZE, 2), (1.0, EPOCH, 3),
                  (5.0, DEPARTURE, 4), (1.0, ARRIVAL, 5), (0.5, FAILURE, 6),
                  (5.0, FAILURE, 7), (1.0, DEPARTURE, 8)]

        def run():
            q = EventQueue()
            out = []
            for i, (t, kind, tid) in enumerate(script):
                q.push(t, kind, tid=tid)
                if i % 3 == 2:
                    e = q.pop()
                    out.append((e.time, e.kind, e.tid))
            out.extend((e.time, e.kind, e.tid) for e in q.drain())
            return out

        assert run() == run()
        full = EventQueue()
        for t, kind, tid in script:
            full.push(t, kind, tid=tid)
        got = [(e.time, e.kind, e.tid) for e in full.drain()]
        assert got == [(0.5, FAILURE, 6), (1.0, DEPARTURE, 8),
                       (1.0, EPOCH, 3), (1.0, ARRIVAL, 5),
                       (5.0, DEPARTURE, 4), (5.0, FAILURE, 7),
                       (5.0, ARRIVAL, 1), (5.0, RESIZE, 2)]

    def test_peek_matches_pop(self):
        q = EventQueue()
        q.push(2.0, ARRIVAL, tid=1)
        q.push(2.0, DEPARTURE, tid=2)
        p = q.peek()
        assert (p.kind, p.tid) == (DEPARTURE, 2)
        assert q.pop() is p
        assert len(q) == 1 and bool(q)

    def test_poisson_trace_deterministic_and_in_horizon(self):
        cfg = TraceConfig(seed=42, horizon_s=50.0)
        a = poisson_trace(cfg)
        b = poisson_trace(cfg)
        assert [t.tid for t in a] == [t.tid for t in b]
        assert [t.arrival_s for t in a] == [t.arrival_s for t in b]
        assert all(0 <= t.arrival_s < 50.0 for t in a)
        assert all(t.duration_s > 0 and t.n_cores >= 1 for t in a)

    def test_named_traces_exist(self):
        for name in ("mixed", "small", "large", "bursty"):
            trace = make_trace(name, seed=1, horizon_s=20.0)
            assert trace, name
        with pytest.raises(KeyError):
            make_trace("nope")

    def test_config_proxy_workloads(self):
        g = get_serving_workload("llama3_2_1b")
        assert g.name.startswith("transformer")   # tensor-parallel dispatch
        assert g.total_weight_bytes > 0
        # registry models pass through
        assert get_serving_workload("resnet18").name == "resnet18"


# ---------------------------------------------------------------------------
# the event loop
# ---------------------------------------------------------------------------

class TestClusterScheduler:
    def test_admit_run_depart(self):
        pol = make_policy("uvm", mesh_2d(6, 6))
        sched = ClusterScheduler(pol, epoch_s=5.0)
        trace = [_spec(tid=1, n_cores=6, arrival=0.0, duration=10.0),
                 _spec(tid=2, n_cores=6, arrival=1.0, duration=10.0)]
        m = sched.run(trace)
        assert m.n_admitted == 2 and m.n_rejected == 0
        assert m.queue_waits_s == [0.0, 0.0]
        assert 0.0 < m.mean_utilization < 1.0
        assert m.tenant_iterations[1] > 0
        assert pol.utilization() == 0.0   # everyone departed

    def test_queueing_and_wait_metrics(self):
        pol = make_policy("uvm", mesh_2d(2, 2))
        sched = ClusterScheduler(pol, epoch_s=2.0)
        trace = [_spec(tid=1, n_cores=4, arrival=0.0, duration=10.0),
                 _spec(tid=2, n_cores=4, arrival=1.0, duration=5.0,
                       sla_wait_s=100.0)]
        m = sched.run(trace)
        assert m.n_admitted == 2
        # tenant 2 waited until tenant 1 departed at t=10
        assert m.wait_percentile(100) == pytest.approx(9.0, abs=1e-6)
        assert 0.0 < m.p95_wait_s <= 9.0

    def test_sla_abandonment_rejects_and_censors_wait(self):
        pol = make_policy("uvm", mesh_2d(2, 2))
        sched = ClusterScheduler(pol, epoch_s=1.0)
        trace = [_spec(tid=1, n_cores=4, arrival=0.0, duration=50.0),
                 _spec(tid=2, n_cores=4, arrival=1.0, duration=5.0,
                       sla_wait_s=3.0)]
        m = sched.run(trace)
        assert m.n_admitted == 1 and m.n_rejected == 1
        # the abandoned tenant's wait is censored into the distribution at
        # its SLA — rejecting must not make the latency metrics look better
        assert sorted(m.queue_waits_s) == [0.0, 3.0]

    def test_strict_first_prefers_connected_placement(self):
        pol = VNPUPolicy(mesh_2d(3, 3))
        # count-feasible but connectivity matters: strict succeeds only on
        # a connected region
        p = pol.allocate(_spec(n_cores=4), strict=True)
        sub = pol.topo.subgraph(p.cores)
        assert sub.is_connected()
        assert pol.can_place(_spec(tid=2, n_cores=4), strict=True)
        pol.release(p)

    def test_can_place_probe_has_no_side_effects(self):
        pol = VNPUPolicy(mesh_2d(3, 3))
        assert pol.can_place(_spec(n_cores=4), strict=True)
        assert pol.utilization() == 0.0
        assert not pol.can_place(_spec(n_cores=10))          # count probe
        assert not pol.can_place(_spec(n_cores=10), strict=True)
        assert pol.utilization() == 0.0

    def test_defrag_migration_unblocks_queued_tenant(self):
        """Two scattered 2-core tenants block a 4-core connected request;
        compaction via live migration must admit it."""
        pol = VNPUPolicy(mesh_2d(3, 3), require_connected=True)
        sched = ClusterScheduler(pol, epoch_s=1.0, defrag=True)
        trace = [_spec(tid=1, model="yolo_lite", n_cores=3, arrival=0.0,
                       duration=30.0),
                 _spec(tid=2, model="yolo_lite", n_cores=2, arrival=0.0,
                       duration=30.0),
                 _spec(tid=3, model="resnet18", n_cores=4, arrival=1.0,
                       duration=10.0, sla_wait_s=50.0)]
        m = sched.run(trace)
        assert m.n_admitted >= 2   # the big request should eventually land

    def test_compare_policies_same_trace_fig15_trend(self):
        trace = make_trace("mixed", seed=3, horizon_s=30.0)
        ms = compare_policies(
            [make_policy(p, mesh_2d(6, 6)) for p in ("vnpu", "mig", "uvm")],
            trace, epoch_s=5.0)
        by = {m.policy: m for m in ms}
        assert by["vnpu"].mean_utilization >= by["mig"].mean_utilization - 1e-9
        assert by["vnpu"].mean_utilization >= by["uvm"].mean_utilization - 1e-9
        for m in ms:
            assert m.horizon_s > 0
            assert all(0.0 <= s.utilization <= 1.0 for s in m.samples)

    def test_migration_charged_as_pause(self):
        pol = VNPUPolicy(mesh_2d(4, 4))
        sched = ClusterScheduler(pol, epoch_s=1.0)
        spec = _spec(tid=1, model="gpt2_small", n_cores=4, duration=10.0)
        p = pol.allocate(spec)
        cyc = pol.migration_cycles(p, 100 << 20,
                                   S.SIM_CONFIG.hbm_bytes_per_cycle)
        assert cyc > 0
        # warm-up dominated: ~100MB / 720 B/cyc
        assert cyc == pytest.approx(100 * 2**20 / 720, rel=0.1)
