"""Pod-scale fast path: split-RunReport rescoring, drain-queue probe
memoization, buddy state digests — each pinned against its exact oracle.

The symmetry-normalized TED cache has its own tests in
``tests/test_engine.py::TestSymmetryCache``; the end-to-end 32x32 gate
lives in ``benchmarks/cluster_sim.py --gate --mesh 32,32``.
"""
import random

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # property tests degrade, unit tests still run
    from _hypothesis_fallback import given, settings, st

from repro.core import mesh_2d
from repro.core import simulator as S
from repro.core import workloads as W
from repro.core.buddy import BuddyAllocator, OutOfMemory
from repro.sched import ClusterScheduler, make_policy, make_trace
from repro.sched.events import TenantSpec


# ---------------------------------------------------------------------------
# split RunReport: skeleton + rescore_contention == simulate, bit for bit
# ---------------------------------------------------------------------------

_MODELS = ["resnet18", "mobilenet", "yolo_lite", "gpt2_small", "transformer"]


class TestSplitRunReport:
    def _random_case(self, rng, topo):
        g = W.get_workload(rng.choice(_MODELS))
        k = rng.choice([2, 3, 4, 6, 8])
        cores = rng.sample(sorted(topo.node_attrs), k)
        kw = dict(comm=rng.choice(["dataflow", "uvm"]),
                  owner=rng.randrange(1, 99),
                  tdm_physical=rng.choice([None, max(1, k - 1)]))
        hbm = rng.choice([1, 2, 5])
        ext_loads = None
        if rng.random() < 0.6:
            ext_loads = {}
            for _ in range(rng.randint(0, 10)):
                a, b = rng.sample(sorted(topo.node_attrs), 2)
                ext_loads[(a, b)] = float(rng.randint(1, 1 << 20))
        return g, cores, kw, hbm, ext_loads

    @staticmethod
    def _check(seed):
        """simulate(...) and rescore_contention(make_skeleton(...)) are the
        same two function calls — every field of the RunReport must match
        exactly, for any contention/HBM context applied to one skeleton."""
        rng = random.Random(seed)
        topo = mesh_2d(8, 8)
        hw = S.SIM_CONFIG
        self = TestSplitRunReport()
        for _ in range(20):
            g, cores, kw, hbm, ext = self._random_case(rng, topo)
            full = S.simulate(g, cores, topo, hw, hbm_concurrency=hbm,
                              external_link_loads=ext, **kw)
            sk = S.make_skeleton(g, cores, topo, hw, **kw)
            fast = S.rescore_contention(sk, external_link_loads=ext,
                                        hbm_concurrency=hbm)
            assert full == fast
            # the same skeleton recombines under a *different* context too
            full2 = S.simulate(g, cores, topo, hw, hbm_concurrency=hbm + 1,
                               **kw)
            assert full2 == S.rescore_contention(sk,
                                                 hbm_concurrency=hbm + 1)

    @given(st.integers(0, 10_000))
    @settings(max_examples=10, deadline=None)
    def test_rescore_equals_simulate_property(self, seed):
        self._check(seed)

    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_rescore_equals_simulate_seeded(self, seed):
        # deterministic variant that runs even without hypothesis
        self._check(seed)

    def test_external_flows_variant(self):
        """The slow branch (re-path external flow lists) recombines
        identically as well."""
        rng = random.Random(5)
        topo = mesh_2d(8, 8)
        hw = S.SIM_CONFIG
        flows = [S.Flow(src=a, dst=b, bytes_per_iter=rng.randint(1, 1 << 18),
                        owner=9)
                 for a, b in [tuple(rng.sample(sorted(topo.node_attrs), 2))
                              for _ in range(4)]]
        for model in ("resnet18", "gpt2_small"):
            g = W.get_workload(model)
            cores = [0, 1, 8, 9]
            full = S.simulate(g, cores, topo, hw, external_flows=flows)
            sk = S.make_skeleton(g, cores, topo, hw)
            assert full == S.rescore_contention(sk, external_flows=flows)

    def test_skeleton_noc_flows_match_tenant_flows(self):
        """The ledger consumes skeleton.noc_flows — it must equal the
        reference tenant_flows for both execution styles."""
        topo = mesh_2d(8, 8)
        hw = S.SIM_CONFIG
        for model in ("resnet18", "gpt2_small"):
            g = W.get_workload(model)
            cores = [0, 1, 8, 9, 16, 17]
            ref = S.tenant_flows(g, cores, topo, hw, owner=42)
            sk = S.make_skeleton(g, cores, topo, hw, owner=42)
            assert sk.noc_flows == ref

    def test_avg_pairwise_hops_matches_reference(self):
        topo = mesh_2d(9, 9)
        rng = random.Random(0)
        coord = topo.coords
        for _ in range(50):
            cs = rng.sample(sorted(topo.node_attrs), rng.randint(1, 12))
            tot = n = 0
            for i in range(len(cs)):
                for j in range(i + 1, len(cs)):
                    a, b = coord[cs[i]], coord[cs[j]]
                    tot += abs(a[0] - b[0]) + abs(a[1] - b[1])
                    n += 1
            ref = tot / n if n else 0.0
            assert S.avg_pairwise_hops(topo, cs) == ref


# ---------------------------------------------------------------------------
# drain-queue probe memoization
# ---------------------------------------------------------------------------

def _run(policy_name, trace, mesh=(6, 6), failures=(), **kw):
    sched = ClusterScheduler(make_policy(policy_name, mesh_2d(*mesh)),
                             hw=S.SIM_CONFIG, epoch_s=2.0, **kw)
    metrics = sched.run(trace, trace_name="t", failures=list(failures))
    return sched, metrics


def _trajectory(metrics):
    return ([(s.t, s.agg_fps, s.utilization, s.n_resident, s.n_queued)
             for s in metrics.samples],
            dict(metrics.tenant_iterations),
            metrics.n_admitted, metrics.n_rejected,
            [round(w, 12) for w in metrics.queue_waits_s])


class TestProbeMemo:
    @pytest.mark.parametrize("policy", ["vnpu", "mig", "uvm"])
    def test_memo_never_changes_the_schedule(self, policy):
        """Exactness oracle: ledger runs with the memo forced on vs forced
        off must produce identical trajectories, admissions and waits —
        skipping a probe only ever replaces provably-failing work."""
        trace = make_trace("mixed", seed=7, horizon_s=35.0)
        _, on = _run(policy, trace, probe_memo=True)
        _, off = _run(policy, trace, probe_memo=False)
        assert _trajectory(on) == _trajectory(off)
        assert on.n_probe_skips > 0          # the congested mix queues
        assert off.n_probe_skips == 0

    def test_memo_exact_under_failures(self):
        trace = make_trace("mixed", seed=11, horizon_s=30.0)
        failures = [(8.0, (0, 1)), (18.0, (22,))]
        _, on = _run("vnpu", trace, failures=failures, probe_memo=True)
        _, off = _run("vnpu", trace, failures=failures, probe_memo=False)
        assert _trajectory(on) == _trajectory(off)

    def test_unchanged_pool_drain_is_solver_free(self):
        """The headline property: once a spec's size class has failed
        against a pool, an epoch-triggered drain over the *unchanged* pool
        performs zero additional engine map calls for it."""
        # one resident fills a 4x4 mesh for the whole run; a second tenant
        # wants 8 cores and can never fit while the first is resident
        big = TenantSpec(tid=1, model="resnet18", n_cores=16, arrival_s=0.0,
                         duration_s=60.0, sla_wait_s=1e9)
        small = TenantSpec(tid=2, model="yolo_lite", n_cores=8, arrival_s=1.0,
                           duration_s=5.0, sla_wait_s=1e9)
        sched = ClusterScheduler(make_policy("vnpu", mesh_2d(4, 4)),
                                 hw=S.SIM_CONFIG, epoch_s=2.0, defrag=True)
        metrics = sched.run([big, small], trace_name="t")
        eng = sched.policy.hyp.engine
        # tenant 2 waits through ~30 epochs of an unchanged pool; without
        # the memo every drain would re-probe it (strict + relaxed).  With
        # it, the solver runs a bounded number of times (arrival + the
        # post-departure retry), far below one per epoch.
        assert metrics.n_probe_skips >= 20
        assert eng.stats.map_calls <= 6
        assert metrics.n_admitted == 2       # tenant 2 admitted at departure

    def test_oracle_mode_disables_memo_by_default(self):
        trace = make_trace("mixed", seed=7, horizon_s=20.0)
        _, oracle = _run("vnpu", trace, rescore="oracle")
        assert oracle.n_probe_skips == 0
        sched, ledger = _run("vnpu", trace)
        assert sched.probe_memo


class TestRequestShapeMemoKey:
    """ROADMAP fast-path follow-up: vNPU's probe-memo key carries the
    *request canonical shape*, not just the (n_cores, mem, bw) size class,
    so heterogeneous-topology asks with colliding size classes can never
    alias a memo entry."""

    def test_vnpu_key_is_canonical_shape(self):
        pol = make_policy("vnpu", mesh_2d(6, 6))
        mk = lambda n, mem=64 << 20: TenantSpec(
            tid=0, model="resnet18", n_cores=n, arrival_s=0.0,
            duration_s=1.0, memory_bytes=mem)
        k4, k4b = pol.request_key(mk(4)), pol.request_key(mk(4))
        assert k4 == k4b                       # stable per shape
        k6, k8 = pol.request_key(mk(6)), pol.request_key(mk(8))
        # different best_rect shapes mint different shape keys even though
        # memory and bandwidth agree
        assert len({k4[0], k6[0], k8[0]}) == 3
        # shape equal but memory differing still splits the key
        assert k4 != pol.request_key(mk(4, mem=128 << 20))
        # and the shape component is the engine's canonical signature key
        # (translation-normalized), not the raw core count
        assert k4[0] != 4

    def test_default_policies_keep_size_class(self):
        for name in ("mig", "uvm"):
            pol = make_policy(name, mesh_2d(6, 6))
            spec = TenantSpec(tid=0, model="resnet18", n_cores=6,
                              arrival_s=0.0, duration_s=1.0,
                              memory_bytes=1 << 20, bandwidth_cap=None)
            assert pol.request_key(spec) == (6, 1 << 20, None)

    def test_shape_keyed_memo_bit_identity(self):
        """The refined key must not change any schedule: memo on vs off
        stays bit-identical on a congested trace (same oracle as
        TestProbeMemo, pinned separately for the shape-keyed path)."""
        trace = make_trace("mixed", seed=13, horizon_s=30.0)
        sched_on, on = _run("vnpu", trace, probe_memo=True)
        _, off = _run("vnpu", trace, probe_memo=False)
        assert _trajectory(on) == _trajectory(off)
        assert on.n_probe_skips > 0
        # the live memo is keyed by canonical shape tuples
        assert all(isinstance(k[0], tuple)
                   for k in sched_on._probe_memo)


# ---------------------------------------------------------------------------
# buddy state digests (the memory half of the probe-memo token)
# ---------------------------------------------------------------------------

class TestBuddyStateKey:
    def test_rollback_restores_key(self):
        """The OOM path allocates then frees in reverse — the state key
        must return to its pre-attempt value, or memory-infeasible probes
        would thrash the memo instead of hitting it."""
        b = BuddyAllocator(1 << 30, min_block=1 << 20)
        k0 = b.state_key()
        addrs = [b.alloc(100 << 20)[0] for _ in range(3)]
        assert b.state_key() != k0
        for a in addrs:
            b.free_block(a)
        assert b.state_key() == k0

    def test_key_decides_alloc_feasibility(self):
        """Equal keys, equal success/failure for the same request."""
        rng = random.Random(3)
        for _ in range(20):
            b1 = BuddyAllocator(1 << 28, min_block=1 << 20)
            b2 = BuddyAllocator(1 << 28, min_block=1 << 20)
            # drive both to the same multiset through different addresses
            a1 = [b1.alloc(1 << 22)[0] for _ in range(8)]
            a2 = [b2.alloc(1 << 22)[0] for _ in range(8)]
            rng.shuffle(a1)
            for a in a1[:4]:
                b1.free_block(a)
            for a in a2[:4]:
                b2.free_block(a)
            if b1.state_key() != b2.state_key():
                continue      # coalescing differed: keys differ, no claim
            size = rng.choice([1 << 21, 1 << 24, 1 << 27, 1 << 28])
            try:
                b1.alloc(size)
                ok1 = True
            except OutOfMemory:
                ok1 = False
            try:
                b2.alloc(size)
                ok2 = True
            except OutOfMemory:
                ok2 = False
            assert ok1 == ok2


# ---------------------------------------------------------------------------
# the fast path end to end (small scale; 32x32 is the CI gate)
# ---------------------------------------------------------------------------

class TestFastPathEndToEnd:
    def test_ledger_vs_oracle_pod_16x16_short(self):
        """Everything on vs everything off: ledger + skeleton + memo vs
        the oracle recompute — bit-identical trajectories on a pod trace
        slice at 16x16 (the cheap cousin of the 32x32 CI gate)."""
        trace = make_trace("pod-mixed", seed=5, horizon_s=10.0)
        _, fast = _run("vnpu", trace, mesh=(16, 16))
        _, oracle = _run("vnpu", trace, mesh=(16, 16), rescore="oracle")
        assert _trajectory(fast) == _trajectory(oracle)
