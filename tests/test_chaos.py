"""Chaos plane: fault plans, core repair, degraded links, checkpoint
recovery, retry queues — determinism and conservation properties.

Covers the chaos subsystem end to end: seeded :class:`FaultPlan`
generation, ``mark_repaired`` across all three policies (property-tested
for no-leak / no-double-own), the scheduler's REPAIR / LINK_* event
handling with MTTR + availability accounting, train-class checkpoint
resume vs serve-class retry/drop, fleet-level retry + switch brownout,
and the bit-identity guarantees the chaos gate relies on (storm replay,
ledger vs oracle, no-fault off-path).
"""
import dataclasses
import math

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # property tests skip, unit tests still run
    from _hypothesis_fallback import given, settings, st

from repro.chaos import FaultEvent, STORMS, make_fault_plan
from repro.core import Hypervisor, MIGPartitioner, UVMAllocator, \
    VNPURequest, mesh_2d
from repro.sched import (ClusterScheduler, RecoveryConfig, TenantSpec,
                         make_policy)
from repro.sched.events import (ARRIVAL, DEPARTURE, EventQueue, FAILURE,
                                LINK_FAIL, LINK_REPAIR, REPAIR)
from repro.fleet import (Fleet, FleetConfig, PodSpec, Scenario, fleet_trace)
from repro.fleet.switch import PodSwitch, SwitchConfig


def _spec(tid=1, model="resnet18", n_cores=4, arrival=0.0, duration=10.0,
          **kw):
    return TenantSpec(tid=tid, model=model, n_cores=n_cores,
                      arrival_s=arrival, duration_s=duration, **kw)


def _storm_run(policy_name, trace, plan, rescore="ledger", epoch_s=2.0):
    policy = make_policy(policy_name, mesh_2d(plan.rows, plan.cols))
    sched = ClusterScheduler(policy, epoch_s=epoch_s, rescore=rescore,
                             recovery=RecoveryConfig())
    sched.begin()
    sched.feed(trace)
    sched.inject_chaos(plan.cluster_events())
    sched.advance_to(None)
    return sched.finish()


def _digest(m):
    return ([(s.t, s.agg_fps, s.utilization, s.n_resident, s.n_queued)
             for s in m.samples],
            dict(m.tenant_iterations), m.recovery_summary(),
            (m.n_arrived, m.n_admitted, m.n_rejected, m.n_events))


# ---------------------------------------------------------------------------
# fault plans
# ---------------------------------------------------------------------------

class TestFaultPlan:
    def test_same_seed_bit_identical(self):
        a = make_fault_plan(6, 6, 90.0, seed=7)
        b = make_fault_plan(6, 6, 90.0, seed=7)
        assert a.events == b.events and a.summary() == b.summary()

    def test_different_seeds_diverge(self):
        a = make_fault_plan(6, 6, 90.0, seed=7)
        b = make_fault_plan(6, 6, 90.0, seed=8)
        assert a.events != b.events

    def test_events_sorted_and_inside_horizon(self):
        plan = make_fault_plan(8, 8, 60.0, seed=3)
        times = [e.t_s for e in plan.events]
        assert times == sorted(times)
        assert all(0.0 <= t < 60.0 for t in times)

    def test_burst_cores_are_a_spatial_neighborhood(self):
        plan = make_fault_plan(8, 8, 120.0, seed=1)
        bursts = [e for e in plan.cluster_events() if e.kind == "core-fail"
                  and len(e.cores) > 1]
        assert bursts, "storm profile should produce multi-core bursts"
        for e in bursts:
            # a Manhattan-ball neighborhood: pairwise distance stays far
            # below what independent uniform sampling would produce
            dists = [abs(a // 8 - b // 8) + abs(a % 8 - b % 8)
                     for a in e.cores for b in e.cores]
            assert max(dists) <= max(2, len(e.cores))

    def test_links_are_mesh_edges(self):
        plan = make_fault_plan(6, 6, 90.0, seed=7)
        topo = mesh_2d(6, 6)
        edges = {(u, v) for u, v in topo.edges()} \
            | {(v, u) for u, v in topo.edges()}
        for e in plan.cluster_events():
            if e.link is not None:
                assert tuple(e.link) in edges

    def test_profiles_registered(self):
        assert "storm" in STORMS and "drizzle" in STORMS
        with pytest.raises(KeyError):
            make_fault_plan(4, 4, 10.0, profile="hurricane")

    def test_fleet_scope_split(self):
        plan = make_fault_plan(6, 6, 200.0, seed=5, n_pods=4)
        fleet = plan.fleet_events()
        assert all(e.kind in ("pod-fail", "switch-brownout") for e in fleet)
        assert all(e.kind not in ("pod-fail", "switch-brownout")
                   for e in plan.cluster_events())


# ---------------------------------------------------------------------------
# repair: hypervisor / MIG / UVM (policy API)
# ---------------------------------------------------------------------------

class TestMarkRepaired:
    def test_hypervisor_round_trip(self):
        hyp = Hypervisor(mesh_2d(4, 4), hbm_bytes=1 << 32)
        hyp.mark_failed([5, 6])
        assert {5, 6} <= hyp.quarantined
        assert {5, 6} & hyp.free_cores() == set()
        hyp.mark_repaired([5, 6])
        assert hyp.quarantined == set()
        assert {5, 6} <= hyp.free_cores()

    def test_hypervisor_repair_of_owned_core_defers_to_release(self):
        hyp = Hypervisor(mesh_2d(4, 4), hbm_bytes=1 << 32)
        v = hyp.create_vnpu(VNPURequest(topology=mesh_2d(2, 2)))
        owned = set(v.p_cores)
        dead = next(iter(owned))
        hyp.mark_failed([dead])
        hyp.mark_repaired([dead])   # still owned: no double-add to free pool
        assert dead not in hyp.free_cores()
        hyp.destroy_vnpu(v.vmid)
        assert dead in hyp.free_cores()

    @pytest.mark.parametrize("name", ["vnpu", "mig", "uvm"])
    def test_policy_repair_restores_allocability(self, name):
        pol = make_policy(name, mesh_2d(4, 4))
        pol.mark_failed(list(range(16)))
        with pytest.raises(Exception):
            pol.allocate(_spec(n_cores=4))
        pol.mark_repaired(list(range(16)))
        pl = pol.allocate(_spec(n_cores=4))
        assert len(pl.cores) == 4
        pol.release(pl)

    def test_mig_partition_unpoisons_only_when_fully_healthy(self):
        mig = MIGPartitioner(mesh_2d(4, 4), [(2, 4), (2, 4)])
        part = next(p for p in mig.partitions if {0, 1} <= p.cores)
        mig.mark_failed([0, 1])
        assert part.failed
        mig.mark_repaired([0])
        assert part.failed          # core 1 still dead
        mig.mark_repaired([1])
        assert not part.failed

    def test_uvm_round_trip(self):
        uvm = UVMAllocator(mesh_2d(4, 4))
        uvm.mark_failed([3])
        assert 3 in uvm.quarantined
        uvm.mark_repaired([3])
        assert 3 not in uvm.quarantined

    @settings(max_examples=40, deadline=None)
    @given(st.lists(st.tuples(st.booleans(),
                              st.sets(st.integers(0, 15), max_size=5)),
                    max_size=12))
    def test_hypervisor_fail_repair_no_leak_no_double_own(self, steps):
        """Any interleaving of quarantines and repairs conserves the core
        census: free, allocated and quarantined partition the mesh (an
        owned quarantined core is only withheld, never double-counted)."""
        hyp = Hypervisor(mesh_2d(4, 4), hbm_bytes=1 << 32)
        v = hyp.create_vnpu(VNPURequest(topology=mesh_2d(2, 2)))
        owned = set(v.p_cores)
        for fail, cores in steps:
            if fail:
                hyp.mark_failed(cores)
            else:
                hyp.mark_repaired(cores)
            free = hyp.free_cores()
            assert free & hyp.quarantined == set()
            assert free & owned == set()
            assert free | owned | hyp.quarantined == set(range(16))
        hyp.mark_repaired(range(16))
        hyp.destroy_vnpu(v.vmid)
        assert hyp.free_cores() == set(range(16))

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.tuples(st.booleans(),
                              st.sets(st.integers(0, 15), max_size=4)),
                    max_size=10))
    def test_uvm_fail_repair_census(self, steps):
        uvm = UVMAllocator(mesh_2d(4, 4))
        alive = set()
        for fail, cores in steps:
            if fail:
                uvm.mark_failed(cores)
                alive -= set(cores)
            else:
                uvm.mark_repaired(cores)
            assert uvm.quarantined <= set(range(16))
            free = set(range(16)) - uvm.quarantined - uvm.allocated_cores()
            assert free & uvm.quarantined == set()


# ---------------------------------------------------------------------------
# scheduler: repair events, MTTR, recovery accounting
# ---------------------------------------------------------------------------

class TestSchedulerRecovery:
    def test_repair_restores_capacity_and_books_mttr(self):
        pol = make_policy("vnpu", mesh_2d(2, 2))
        sched = ClusterScheduler(pol, epoch_s=5.0,
                                 recovery=RecoveryConfig())
        # tenant 2 needs the whole mesh: only admissible after the repair
        trace = [_spec(tid=2, n_cores=4, arrival=6.0, duration=5.0,
                       sla_wait_s=60.0)]
        sched.begin()
        sched.feed(trace)
        sched.inject_chaos([
            FaultEvent(t_s=1.0, kind="core-fail", cores=(0, 1)),
            FaultEvent(t_s=9.0, kind="core-repair", cores=(0, 1)),
        ])
        sched.advance_to(None)
        m = sched.finish()
        assert m.n_failed_cores == 2 and m.n_repaired_cores == 2
        assert m.n_repairs == 2
        assert m.mttr_s == pytest.approx(8.0)
        assert m.core_downtime_s == pytest.approx(16.0)
        assert m.n_admitted == 1    # admitted once capacity returned
        assert m.queue_waits_s[0] == pytest.approx(3.0)

    def test_unrepaired_downtime_closed_at_horizon(self):
        pol = make_policy("vnpu", mesh_2d(2, 2))
        sched = ClusterScheduler(pol, epoch_s=5.0,
                                 recovery=RecoveryConfig())
        sched.begin()
        sched.feed([_spec(tid=1, n_cores=2, arrival=0.0, duration=8.0)])
        sched.inject_chaos(
            [FaultEvent(t_s=2.0, kind="core-fail", cores=(3,))])
        sched.advance_to(None)
        m = sched.finish()
        assert m.n_repairs == 0 and m.mttr_s == 0.0
        assert m.core_downtime_s == pytest.approx(m.horizon_s - 2.0)
        assert m.n_cores_total == 4
        assert 0.0 < m.capacity_availability < 1.0

    def test_train_tenant_resumes_from_checkpoint(self):
        pol = make_policy("vnpu", mesh_2d(2, 2))
        sched = ClusterScheduler(pol, epoch_s=5.0,
                                 recovery=RecoveryConfig())
        spec = _spec(tid=1, n_cores=4, arrival=0.0, duration=40.0,
                     sla_wait_s=120.0, tenant_class="train")
        sched.begin()
        sched.feed([spec])
        sched.inject_chaos([
            FaultEvent(t_s=13.0, kind="core-fail", cores=(0,)),
            FaultEvent(t_s=20.0, kind="core-repair", cores=(0,)),
        ])
        sched.advance_to(None)
        m = sched.finish()
        assert m.n_fault_kills == 1 and m.n_ckpt_resumes == 1
        # killed at 13 with ckpt_interval 10: 3 s since the last boundary
        assert m.rework_s == pytest.approx(math.fmod(13.0, 10.0))
        assert m.rewarm_cost_s > 0.0
        # the resumed stint re-arrives and is admitted after the repair
        assert m.n_arrived == 2 and m.n_admitted == 2
        assert m.n_fault_kills == \
            m.n_ckpt_resumes + m.n_fault_retries + m.n_fault_drops

    def test_serve_tenant_retries_with_backoff(self):
        pol = make_policy("vnpu", mesh_2d(2, 2))
        sched = ClusterScheduler(pol, epoch_s=5.0,
                                 recovery=RecoveryConfig(retry_base_s=0.5))
        spec = _spec(tid=1, n_cores=4, arrival=0.0, duration=20.0,
                     sla_wait_s=60.0)
        sched.begin()
        sched.feed([spec])
        sched.inject_chaos([
            FaultEvent(t_s=5.0, kind="core-fail", cores=(0,)),
            FaultEvent(t_s=8.0, kind="core-repair", cores=(0,)),
        ])
        sched.advance_to(None)
        m = sched.finish()
        assert m.n_fault_kills == 1 and m.n_fault_retries == 1
        assert m.n_fault_drops == 0 and m.n_ckpt_resumes == 0
        assert m.n_admitted == 2    # original + retried re-admission

    def test_serve_retry_budget_zero_drops(self):
        pol = make_policy("vnpu", mesh_2d(2, 2))
        sched = ClusterScheduler(pol, epoch_s=5.0,
                                 recovery=RecoveryConfig(retry_max=0))
        sched.begin()
        sched.feed([_spec(tid=1, n_cores=4, arrival=0.0, duration=20.0)])
        sched.inject_chaos(
            [FaultEvent(t_s=5.0, kind="core-fail", cores=(0,))])
        sched.advance_to(None)
        m = sched.finish()
        assert m.n_fault_kills == 1 and m.n_fault_drops == 1
        assert m.n_fault_retries == 0

    def test_link_degrade_slows_scores_and_repair_restores(self):
        def run(events):
            pol = make_policy("vnpu", mesh_2d(2, 2))
            sched = ClusterScheduler(pol, epoch_s=2.0,
                                     recovery=RecoveryConfig(
                                         migrate_on_link_fail=False))
            sched.begin()
            # the transformer workload is NoC-bandwidth-bound: its score
            # actually moves when its links slow down (resnet18 would be
            # compute-bound and mask the degradation)
            sched.feed([_spec(tid=1, model="transformer", n_cores=4,
                              arrival=0.0, duration=30.0)])
            sched.inject_chaos(events)
            sched.advance_to(None)
            return sched.finish()

        base = run([])
        # degrade every directed mesh link: whatever the tenant's flows
        # use, its contention context worsens by 8x until the repair
        topo = mesh_2d(2, 2)
        links = [(u, v) for u, v in topo.edges()] \
            + [(v, u) for u, v in topo.edges()]
        hit = run(
            [FaultEvent(t_s=5.0, kind="link-degrade", link=e, factor=8.0)
             for e in links]
            + [FaultEvent(t_s=15.0, kind="link-repair", link=e)
               for e in links])
        assert hit.n_link_faults == len(links)
        assert hit.n_link_repairs == len(links)
        by_t_base = {s.t: s.agg_fps for s in base.samples}
        by_t_hit = {s.t: s.agg_fps for s in hit.samples}
        degraded = [t for t in by_t_hit if 5.0 < t <= 15.0]
        assert degraded
        assert all(by_t_hit[t] < by_t_base[t] for t in degraded)
        healthy = [t for t in by_t_hit if t > 15.0 or t <= 5.0]
        assert all(by_t_hit[t] == by_t_base[t] for t in healthy)

    def test_no_fault_trajectory_bit_identical_to_plain_run(self):
        trace = [_spec(tid=i, n_cores=4, arrival=i * 1.5,
                       duration=8.0 + i) for i in range(1, 7)]
        pol = make_policy("vnpu", mesh_2d(4, 4))
        plain = ClusterScheduler(pol, epoch_s=2.0).run(trace)
        pol2 = make_policy("vnpu", mesh_2d(4, 4))
        armed = ClusterScheduler(pol2, epoch_s=2.0,
                                 recovery=RecoveryConfig())
        armed.begin()
        armed.feed(trace)
        armed.advance_to(None)
        m = armed.finish()
        assert _digest(m)[:2] == _digest(plain)[:2]
        assert m.n_events == plain.n_events


# ---------------------------------------------------------------------------
# storm replay determinism + conservation (the gate's core properties)
# ---------------------------------------------------------------------------

class TestStormDeterminism:
    @pytest.fixture(scope="class")
    def storm(self):
        plan = make_fault_plan(4, 4, 40.0, seed=11, profile="storm")
        trace = [
            dataclasses.replace(s, tenant_class="train")
            if s.duration_s >= 15.0 else s
            for s in (_spec(tid=i, n_cores=2 + 2 * (i % 2),
                            arrival=i * 2.0, duration=6.0 + 3.0 * i,
                            sla_wait_s=30.0) for i in range(1, 9))]
        return plan, trace

    @pytest.mark.parametrize("name", ["vnpu", "mig", "uvm"])
    def test_replay_bit_identical(self, storm, name):
        plan, trace = storm
        assert _digest(_storm_run(name, trace, plan)) \
            == _digest(_storm_run(name, trace, plan))

    def test_ledger_matches_oracle_under_storm(self, storm):
        plan, trace = storm
        assert _digest(_storm_run("vnpu", trace, plan)) \
            == _digest(_storm_run("vnpu", trace, plan, rescore="oracle"))

    @pytest.mark.parametrize("name", ["vnpu", "mig", "uvm"])
    def test_availability_counters_conserve(self, storm, name):
        plan, trace = storm
        m = _storm_run(name, trace, plan)
        assert m.n_arrived == m.n_admitted + m.n_rejected
        assert m.n_fault_kills == \
            m.n_ckpt_resumes + m.n_fault_retries + m.n_fault_drops
        assert 0.0 <= m.service_availability <= 1.0
        assert 0.0 <= m.capacity_availability <= 1.0


# ---------------------------------------------------------------------------
# event-queue ordering
# ---------------------------------------------------------------------------

class TestEventPriorities:
    def test_same_instant_repair_before_failure_before_arrival(self):
        q = EventQueue()
        q.push(5.0, ARRIVAL, spec=_spec(tid=1))
        q.push(5.0, FAILURE, cores=(0,))
        q.push(5.0, REPAIR, cores=(1,))
        q.push(5.0, DEPARTURE, tid=9)
        kinds = [q.pop().kind for _ in range(4)]
        assert kinds == [DEPARTURE, REPAIR, FAILURE, ARRIVAL]

    def test_link_events_order_between_failure_and_arrival(self):
        q = EventQueue()
        q.push(2.0, ARRIVAL, spec=_spec(tid=1))
        q.push(2.0, LINK_FAIL, link=(0, 1))
        q.push(2.0, LINK_REPAIR, link=(0, 1))
        q.push(2.0, FAILURE, cores=(0,))
        kinds = [q.pop().kind for _ in range(4)]
        assert kinds == [FAILURE, LINK_REPAIR, LINK_FAIL, ARRIVAL]


# ---------------------------------------------------------------------------
# fleet: retry queue + switch brownout
# ---------------------------------------------------------------------------

class TestFleetChaos:
    def test_brownout_divides_bandwidth_until_restored(self):
        sw = PodSwitch(SwitchConfig(latency_s=0.0,
                                    bandwidth_bytes_per_s=100.0))
        base = sw.transfer(0, 1, 200, 0.0)
        assert base == pytest.approx(2.0)
        sw.set_degradation(4.0)
        slow = sw.transfer(0, 2, 200, 10.0)
        assert slow - 10.0 == pytest.approx(8.0)
        sw.set_degradation(1.0)
        fast = sw.transfer(0, 3, 200, 100.0)
        assert fast - 100.0 == pytest.approx(2.0)
        assert sw.stats.n_brownouts == 1
        with pytest.raises(ValueError):
            sw.set_degradation(0.5)

    def test_fleet_brownout_scenario_slows_migrations_deterministically(self):
        pods = [PodSpec(pod_id=0, rows=8, cols=8),
                PodSpec(pod_id=1, rows=8, cols=8)]
        trace = fleet_trace(2, seed=3, horizon_s=30.0)
        scn = [Scenario("switch-brownout", 2.0, 0, duration_s=10.0,
                        factor=8.0),
               Scenario("pod-failure", 6.0, 1)]
        m1 = Fleet(pods, FleetConfig(seed=3)).run(trace, scn, workers=1)
        m2 = Fleet(pods, FleetConfig(seed=3)).run(trace, scn, workers=2)
        assert m1.serving_summary() == m2.serving_summary()
        assert m1.pod_digests() == m2.pod_digests()
        assert m1.switch.n_brownouts == 1

    def test_unroutable_arrivals_retry_after_undrain(self):
        pods = [PodSpec(pod_id=0, rows=8, cols=8),
                PodSpec(pod_id=1, rows=8, cols=8)]
        trace = fleet_trace(2, seed=5, horizon_s=20.0)
        # both pods drain over the arrival window: arrivals are
        # unroutable until the undrain barriers, then the retry queue
        # re-routes them instead of losing them
        scn = [Scenario("upgrade", 0.0, 0, duration_s=25.0),
               Scenario("upgrade", 0.0, 1, duration_s=25.0)]
        fleet = Fleet(pods, FleetConfig(seed=5, retry_base_s=2.0,
                                        retry_max=8))
        m = fleet.run(trace, scn, workers=1)
        assert m.n_retried > 0
        assert m.requests_completed > 0     # retried tenants served
        summary = m.serving_summary()
        assert summary["n_retried"] == m.n_retried
        assert summary["n_dropped"] == m.n_dropped

    def test_exhausted_retries_drop(self):
        pods = [PodSpec(pod_id=0, rows=4, cols=4)]
        trace = fleet_trace(1, seed=2, horizon_s=10.0)
        scn = [Scenario("pod-failure", 0.0, 0)]
        fleet = Fleet(pods, FleetConfig(seed=2, retry_base_s=1.0,
                                        retry_max=1, drain_tail_s=30.0))
        m = fleet.run(trace, scn, workers=1)
        assert m.n_retried > 0 and m.n_dropped > 0
        assert m.requests_completed == 0

    def test_unknown_scenario_still_rejected(self):
        fleet = Fleet([PodSpec(pod_id=0, rows=4, cols=4)])
        with pytest.raises(ValueError):
            fleet.run([], scenarios=[Scenario("meteor", 1.0, 0)])
