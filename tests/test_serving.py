"""Request-level serving plane: request sampling, KV arena, phase model,
continuous batching, elastic vNPU resize, and the scheduler integration —
plus the ServeEngine cross-check closing the ROADMAP item (simulated
decode tokens/s vs a real CPU-backend run, pinned by a recorded
calibration factor).
"""
import math
import random

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # property tests degrade, unit tests still run
    from _hypothesis_fallback import given, settings, st

from repro.core import mesh_2d
from repro.core import simulator as S
from repro.core.baselines import AllocationError
from repro.core.hypervisor import Hypervisor, VNPURequest
from repro.core.vchunk import RangeTLB
from repro.sched import (ClusterScheduler, ServingConfig, make_policy,
                         make_trace)
from repro.sched.events import TenantSpec
from repro.sched.policy import best_rect
from repro.serve.kv import TenantKV
from repro.serve.plane import ServingPlane, TenantServer
from repro.serve.requests import (SERVE_PROFILES, get_profile,
                                  sample_requests)


# ---------------------------------------------------------------------------
# request sampling
# ---------------------------------------------------------------------------

class TestRequestSampling:
    def test_deterministic_per_seed(self):
        prof = SERVE_PROFILES["qwen2_0_5b"]
        a = sample_requests(prof, 30.0, seed=42)
        b = sample_requests(prof, 30.0, seed=42)
        assert a == b
        c = sample_requests(prof, 30.0, seed=43)
        assert a != c

    def test_stream_shape(self):
        prof = SERVE_PROFILES["llama3_2_1b"]
        reqs = sample_requests(prof, 60.0, seed=0)
        assert all(0 <= r.t_s < 60.0 for r in reqs)
        assert all(r.t_s <= s.t_s for r, s in zip(reqs, reqs[1:]))
        assert all(r.prompt_tokens >= 8 and r.max_new_tokens >= 2
                   for r in reqs)
        assert {r.cls for r in reqs} <= {"chat", "doc"}
        # Poisson count in the right ballpark (rate * horizon)
        expect = prof.rate_per_s * 60.0
        assert 0.5 * expect < len(reqs) < 1.7 * expect


# ---------------------------------------------------------------------------
# KV arena over the real buddy allocator
# ---------------------------------------------------------------------------

class TestTenantKV:
    def _kv(self, arena=32 << 20, block=1 << 20, bpt=16 << 10):
        return TenantKV(arena, block, bpt)

    def test_admit_release_roundtrip(self):
        kv = self._kv()
        free0 = kv.buddy.free_bytes()
        assert kv.try_admit(1, 100)        # 100 tokens @16K = 2 blocks
        assert kv.n_ranges(1) == 2
        assert kv.capacity_tokens(1) >= 100
        kv.buddy.check_invariants()
        kv.release(1)
        assert kv.buddy.free_bytes() == free0
        kv.buddy.check_invariants()

    def test_grow_and_oom_rollback(self):
        kv = self._kv(arena=4 << 20)       # 4 blocks
        assert kv.try_admit(1, 60)         # 1 block
        assert kv.try_grow(1, 200)         # -> 4 blocks total? 200*16K=3.2M
        assert kv.n_ranges(1) == 4
        free_before = kv.buddy.free_bytes()
        assert not kv.try_grow(1, 1000)    # would need far more than arena
        assert kv.buddy.free_bytes() == free_before   # all-or-nothing
        assert kv.stats.grow_oom == 1
        kv.buddy.check_invariants()

    def test_admit_oom_leaves_arena_untouched(self):
        kv = self._kv(arena=2 << 20)
        assert not kv.try_admit(1, 1000)
        assert kv.stats.admit_oom == 1
        assert kv.buddy.free_bytes() == kv.buddy.total
        assert kv.occupancy() == 0.0

    def test_rtt_walk_matches_analytic_stall_count(self):
        """The phase model charges ``n_ranges`` RTT reads per decode step
        (Pattern 2: the RTT_CUR cursor makes each miss a short walk).
        Driving the *real* RangeTLB over the request's materialized RTT
        must agree: one miss per range per sequential pass."""
        kv = self._kv(arena=64 << 20, block=1 << 20, bpt=16 << 10)
        assert kv.try_admit(7, 500)        # 500 tokens -> 8 x 1MiB ranges
        n_ranges = kv.n_ranges(7)
        assert n_ranges == 8
        rtt = kv.rtt_for(7)
        assert len(rtt.entries) == n_ranges
        tlb = RangeTLB(rtt, n_entries=4)   # fewer entries than ranges
        burst = 512
        span = n_ranges << 20
        for _ in range(2):                 # two decode passes over the KV
            for va in range(0, span, burst << 4):
                tlb.translate(va)
        assert tlb.stats.misses == 2 * n_ranges
        assert kv.stall_ranges([7]) == n_ranges

    def test_release_all(self):
        kv = self._kv()
        for rid in range(4):
            assert kv.try_admit(rid, 50)
        kv.release_all()
        assert kv.buddy.free_bytes() == kv.buddy.total


# ---------------------------------------------------------------------------
# phase model (simulator side)
# ---------------------------------------------------------------------------

class TestPhaseModel:
    def _model(self, model, k, clients=1, topo=None):
        from repro.sched.traces import get_serving_workload
        topo = topo or mesh_2d(8, 8)
        g = get_serving_workload(model)
        sk = S.tensor_skeleton(g, list(range(k)), topo, S.SIM_CONFIG)
        prof = get_profile(model)
        return S.derive_phase_model(sk, S.finish_tensor(sk),
                                    proxy_seq=prof.proxy_seq,
                                    decode_hbm_clients=clients)

    def test_prefill_is_fps_times_seq(self):
        from repro.sched.traces import get_serving_workload
        topo = mesh_2d(8, 8)
        g = get_serving_workload("qwen2_0_5b")
        sk = S.tensor_skeleton(g, [0, 1, 8, 9], topo, S.SIM_CONFIG)
        rep = S.finish_tensor(sk)
        pm = S.derive_phase_model(sk, rep, proxy_seq=512)
        assert pm.prefill_tokens_per_s == pytest.approx(rep.fps * 512)

    def test_weights_residency_speeds_decode(self):
        """transformer's ~98 MB of shards fit in aggregate scratchpad at 7
        cores but not at 4 — the structural payoff of elastic growth."""
        small = self._model("transformer", 4)
        big = self._model("transformer", 8)
        assert not small.weights_resident and big.weights_resident
        kv, ranges = 8 << 20, 10
        assert big.decode_step_s(kv, ranges) < \
            0.25 * small.decode_step_s(kv, ranges)

    def test_hbm_sharing_slows_decode(self):
        one = self._model("qwen2_0_5b", 6, clients=1)
        four = self._model("qwen2_0_5b", 6, clients=4)
        s1 = one.decode_step_s(1 << 20, 4)
        s4 = four.decode_step_s(1 << 20, 4)
        assert 3.0 < s4 / s1 < 4.5       # streaming is the dominant term

    def test_rejects_pipeline_skeletons(self):
        from repro.core import workloads as W
        g = W.get_workload("resnet18")
        sk = S.pipeline_skeleton(g, [0, 1], mesh_2d(6, 6), S.SIM_CONFIG)
        with pytest.raises(TypeError):
            S.derive_phase_model(sk, S.finish_pipeline(sk), proxy_seq=64)


# ---------------------------------------------------------------------------
# continuous batching (TenantServer micro-sim)
# ---------------------------------------------------------------------------

def _flat_phase(prefill=10_000.0, step_cycles=5e5, freq=500e6):
    """A simple constant-rate phase model for unit tests."""
    return S.PhaseModel(prefill_tokens_per_s=prefill,
                        step_base_cycles=step_cycles,
                        hbm_bytes_per_cycle=1e18,    # KV i/o negligible
                        stall_cycles_per_range=0,
                        freq_hz=freq)


class TestTenantServer:
    def _server(self, stream, profile_name="qwen2_0_5b", admit=0.0,
                arrival=0.0, depart=1e9):
        prof = SERVE_PROFILES[profile_name]
        return TenantServer(1, prof, stream, arrival, admit, depart)

    def test_serves_to_completion_and_ttft_ordering(self):
        from repro.serve.requests import RequestSpec
        stream = [RequestSpec(rid=i, t_s=0.1 * i, prompt_tokens=100,
                              max_new_tokens=10, cls="chat")
                  for i in range(6)]
        srv = self._server(stream)
        srv.advance(0.0, 60.0, _flat_phase())
        recs = sorted(srv.records, key=lambda r: r.rid)
        assert len(recs) == 6 and all(r.completed for r in recs)
        assert all(r.tokens_out == 10 for r in recs)
        # first tokens come out in arrival order; TTFT ~ prefill time
        firsts = [r.first_token_s for r in recs]
        assert firsts == sorted(firsts)
        assert all(r.ttft_s > 0 and math.isfinite(r.tpot_s) for r in recs)

    def test_backlogged_requests_pay_admission_wait(self):
        """Anchoring streams at tenant *arrival* makes queue latency
        surface as TTFT for the backlog."""
        from repro.serve.requests import RequestSpec
        stream = [RequestSpec(rid=0, t_s=0.5, prompt_tokens=64,
                              max_new_tokens=4, cls="chat")]
        srv = self._server(stream, arrival=0.0, admit=5.0)
        srv.advance(5.0, 20.0, _flat_phase())
        (rec,) = srv.records
        assert rec.completed
        assert rec.ttft_s > 4.4          # waited ~4.5 s before admission

    def test_kv_pressure_preempts_and_recovers(self):
        """A tiny arena forces mid-decode OOM: the youngest slot is
        preempted (free-and-recompute) and everything still completes."""
        import dataclasses
        from repro.serve.requests import RequestSpec
        prof = dataclasses.replace(
            SERVE_PROFILES["qwen2_0_5b"], kv_arena_bytes=4 << 20,
            kv_block_bytes=1 << 20, max_batch=4)
        stream = [RequestSpec(rid=i, t_s=0.0, prompt_tokens=60,
                              max_new_tokens=60, cls="chat")
                  for i in range(4)]
        srv = TenantServer(1, prof, stream, 0.0, 0.0, 1e9)
        srv.advance(0.0, 300.0, _flat_phase())
        assert srv.kv.stats.grow_oom > 0
        recs = sorted(srv.records, key=lambda r: r.rid)
        assert len(recs) == 4 and all(r.completed for r in recs)
        assert any(r.preempts > 0 for r in recs)
        assert srv.kv.buddy.free_bytes() == srv.kv.buddy.total

    def test_unserveable_request_dropped_not_livelocked(self):
        """A request whose *total* context (prompt + all output tokens)
        can never fit the arena must be dropped up front — admitting it
        would cycle admit -> grow-OOM -> self-preempt forever."""
        import dataclasses
        from repro.serve.requests import RequestSpec
        prof = dataclasses.replace(
            SERVE_PROFILES["qwen2_0_5b"], kv_arena_bytes=2 << 20,
            kv_block_bytes=1 << 20, max_batch=4)   # capacity ~170 tokens
        stream = [
            RequestSpec(rid=0, t_s=0.0, prompt_tokens=100,
                        max_new_tokens=200, cls="doc"),   # total 300: never
            RequestSpec(rid=1, t_s=0.0, prompt_tokens=50,
                        max_new_tokens=50, cls="chat"),   # total 100: fits
        ]
        srv = TenantServer(1, prof, stream, 0.0, 0.0, 1e9)
        srv.advance(0.0, 120.0, _flat_phase())
        assert srv.n_dropped == 1
        recs = {r.rid: r for r in srv.records}
        assert not recs[0].completed and recs[0].first_token_s is None
        assert recs[1].completed and recs[1].tokens_out == 50

    def test_deterministic_replay(self):
        prof = SERVE_PROFILES["transformer"]
        stream = sample_requests(prof, 20.0, seed=5)
        outs = []
        for _ in range(2):
            srv = TenantServer(1, prof, list(stream), 0.0, 0.0, 1e9)
            t = 0.0
            while t < 40.0:              # advance in irregular windows
                srv.advance(t, t + 1.7, _flat_phase(prefill=50_000.0,
                                                    step_cycles=2e5))
                t += 1.7
            outs.append([(r.rid, r.ttft_s, r.tpot_s, r.tokens_out)
                         for r in sorted(srv.records, key=lambda r: r.rid)])
        assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# elastic vNPU resize (Hypervisor.resize_vnpu): churn property test
# ---------------------------------------------------------------------------

def _check_hypervisor_invariants(hyp: Hypervisor) -> None:
    """No core double-owned or leaked, engine free view exact, buddy arena
    covered — the invariants grow/shrink churn must preserve."""
    owned = set()
    for v in hyp.vnpus.values():
        cores = set(v.p_cores)
        assert not (cores & owned), "core owned by two vNPUs"
        owned |= cores
        assert v.request.topology.num_nodes == len(cores)
        assert set(v.assignment.values()) == cores
        assert hyp.directory.get(v.vmid) is v.routing_table
    expect_free = set(hyp.topo.node_attrs) - owned - hyp.quarantined
    assert hyp.free_cores() == expect_free
    assert set(hyp.engine.regions.free) == expect_free
    hyp.buddy.check_invariants()


def _request(n, memory=8 << 20):
    return VNPURequest(topology=mesh_2d(*best_rect(n), base_id=10_000),
                       memory_bytes=memory, require_connected=False)


class TestResizeVNPU:
    def test_grow_shrink_grow_preserves_memory_and_tables(self):
        hyp = Hypervisor(mesh_2d(6, 6), hbm_bytes=1 << 32)
        v = hyp.create_vnpu(_request(4, memory=32 << 20))
        rtt_before = list(v.rtt.entries)
        blocks_before = list(v.mem_blocks)
        for target in (9, 4, 12, 6):
            v = hyp.resize_vnpu(v.vmid,
                                mesh_2d(*best_rect(target), base_id=10_000))
            assert v.n_cores == target
            assert v.rtt.entries == rtt_before       # memory untouched
            assert v.mem_blocks == blocks_before
            _check_hypervisor_invariants(hyp)
        hyp.destroy_vnpu(v.vmid)
        _check_hypervisor_invariants(hyp)
        assert hyp.buddy.free_bytes() == hyp.buddy.total

    def test_resize_is_transactional_on_failure(self):
        hyp = Hypervisor(mesh_2d(4, 4), hbm_bytes=1 << 30)
        v = hyp.create_vnpu(_request(6))
        hyp.create_vnpu(_request(8))
        with pytest.raises(AllocationError):
            hyp.resize_vnpu(v.vmid, mesh_2d(4, 4, base_id=10_000))  # 16 > free
        assert v.n_cores == 6
        _check_hypervisor_invariants(hyp)

    def test_resize_avoids_quarantined_cores(self):
        hyp = Hypervisor(mesh_2d(4, 4), hbm_bytes=1 << 30)
        v = hyp.create_vnpu(_request(4))
        hyp.mark_failed([0, 1, 2])
        v = hyp.resize_vnpu(v.vmid, mesh_2d(2, 4, base_id=10_000))
        assert not (set(v.p_cores) & {0, 1, 2})
        _check_hypervisor_invariants(hyp)

    @staticmethod
    def _churn(seed):
        rng = random.Random(seed)
        hyp = Hypervisor(mesh_2d(6, 6), hbm_bytes=1 << 32)
        live = []
        for _ in range(30):
            op = rng.choice(["create", "create", "resize", "resize",
                             "resize", "destroy", "fail"])
            try:
                if op == "create" or not live:
                    v = hyp.create_vnpu(_request(rng.choice([2, 4, 6, 9]),
                                                 memory=rng.choice(
                                                     [0, 8 << 20, 32 << 20])))
                    live.append(v.vmid)
                elif op == "resize":
                    vmid = rng.choice(live)
                    hyp.resize_vnpu(vmid, mesh_2d(
                        *best_rect(rng.choice([2, 4, 6, 9, 12])),
                        base_id=10_000))
                elif op == "destroy":
                    vmid = live.pop(rng.randrange(len(live)))
                    hyp.destroy_vnpu(vmid)
                elif op == "fail" and len(hyp.quarantined) < 4:
                    hyp.mark_failed([rng.randrange(36)])
            except AllocationError:
                pass                      # full mesh is a legal outcome
            _check_hypervisor_invariants(hyp)
        for vmid in live:
            hyp.destroy_vnpu(vmid)
        _check_hypervisor_invariants(hyp)
        assert hyp.buddy.free_bytes() == hyp.buddy.total

    @given(st.integers(0, 10_000))
    @settings(max_examples=15, deadline=None)
    def test_churn_property(self, seed):
        self._churn(seed)

    @pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
    def test_churn_seeded(self, seed):
        # deterministic variant that runs even without hypothesis
        self._churn(seed)


# ---------------------------------------------------------------------------
# scheduler integration (plane + RESIZE events + SLA admission)
# ---------------------------------------------------------------------------

def _serving_run(policy_name, horizon=60.0, mesh=(8, 8), admission="sla",
                 **pol_kw):
    trace = make_trace("serving", horizon_s=horizon)
    policy = make_policy(policy_name, mesh_2d(*mesh), **pol_kw)
    sched = ClusterScheduler(policy, serving=ServingConfig(),
                             admission=admission)
    return sched, sched.run(trace, trace_name="serving")


class TestServingScheduler:
    def test_vnpu_end_to_end(self):
        sched, m = _serving_run("vnpu", mapper="bipartite")
        assert m.requests_arrived > 500
        assert m.requests_completed > 0.7 * m.requests_arrived
        assert m.requests_sla_good > 0
        assert len(m.request_log) == m.requests_arrived
        assert m.tokens_generated > 0
        # the pressure controller fired and the hypervisor resized live
        # tenants (the serving trace is tuned to overload transiently)
        assert m.n_resize_attempts > 0
        assert m.n_resizes > 0 and m.n_grows > 0
        assert m.n_resizes == m.n_grows + m.n_shrinks
        # ledger occupancancy stayed exact through resize churn
        sched.ledger.check_invariants()
        s = m.summary()
        assert "serving" in s and s["serving"]["requests"] > 0

    def test_request_level_determinism(self):
        _, a = _serving_run("vnpu", horizon=45.0, mapper="bipartite")
        _, b = _serving_run("vnpu", horizon=45.0, mapper="bipartite")
        assert a.request_log == b.request_log
        assert a.n_resizes == b.n_resizes
        assert a.serving_summary() == b.serving_summary()

    @pytest.mark.parametrize("policy", ["mig", "uvm"])
    def test_baselines_run_clean(self, policy):
        _, m = _serving_run(policy, horizon=40.0)
        assert m.requests_arrived > 0
        assert m.requests_completed > 0
        if policy == "mig":
            assert m.n_resizes == 0       # partitions cannot resize

    def test_uvm_resize_grows_and_shrinks(self):
        topo = mesh_2d(4, 4)
        pol = make_policy("uvm", topo)
        spec = TenantSpec(tid=1, model="qwen2_0_5b", n_cores=4,
                          arrival_s=0.0, duration_s=10.0)
        p = pol.allocate(spec)
        p2, ok = pol.resize(p, 8)
        assert ok and len(p2.cores) == 8
        p3, ok = pol.resize(p2, 3)
        assert ok and len(p3.cores) == 3
        assert len(pol.free_cores()) == 13

    def test_serving_off_keeps_legacy_metrics(self):
        trace = make_trace("mixed", seed=3, horizon_s=20.0)
        sched = ClusterScheduler(make_policy("vnpu", mesh_2d(6, 6)))
        m = sched.run(trace, trace_name="mixed")
        assert m.requests_arrived == 0 and not m.request_log
        assert "serving" not in m.summary()

    def test_sla_admission_orders_by_deadline(self):
        sched = ClusterScheduler(make_policy("vnpu", mesh_2d(6, 6)),
                                 serving=ServingConfig(), admission="sla")
        tight = TenantSpec(tid=1, model="qwen2_0_5b", n_cores=4,
                           arrival_s=0.0, duration_s=10.0, sla_wait_s=5.0)
        slack = TenantSpec(tid=2, model="qwen2_0_5b", n_cores=4,
                           arrival_s=0.0, duration_s=10.0, sla_wait_s=50.0)
        sched._waiting = [(slack, 0.0), (tight, 0.0)]
        order = [s.tid for s, _ in sched._admission_order()]
        assert order == [1, 2]            # EDF: tight deadline first
        sched.admission = "fifo"
        assert [s.tid for s, _ in sched._admission_order()] == [2, 1]


# ---------------------------------------------------------------------------
# cross-check: analytic decode rate vs a real ServeEngine run (ROADMAP)
# ---------------------------------------------------------------------------

class TestServeEngineCrossCheck:
    # Analytic decode tokens/s (full qwen2_0_5b on the SIM NPU config)
    # divided by the measured CPU-backend tokens/s of the smoke-reduced
    # model in the reference container.  The NPU model and the reduced CPU
    # run differ by architecture, size and backend, so the ratio is a
    # *calibration constant*, not 1.0; the test pins that the two stay
    # within a band of it (CI machines vary in CPU speed, hence the wide
    # tolerance — what matters is that the analytic model cannot silently
    # drift by orders of magnitude).
    CALIBRATION = 0.41
    TOLERANCE = 8.0

    def test_analytic_decode_rate_matches_engine(self):
        import jax

        from repro.configs import get_config
        from repro.configs.base import reduce_for_smoke
        from repro.models import build
        from repro.serve import EngineConfig, ServeEngine

        cfg = reduce_for_smoke(get_config("qwen2_0_5b"))
        bundle = build(cfg)
        params = bundle.init(jax.random.PRNGKey(0))
        eng = ServeEngine(bundle, params,
                          EngineConfig(batch_size=4, max_seq=64))
        rng = np.random.default_rng(0)

        def submit(n_new):
            for _ in range(4):
                eng.submit(rng.integers(0, cfg.vocab_size - 1, size=16
                                        ).astype(np.int32),
                           max_new_tokens=n_new)

        submit(4)
        eng.run()                          # warm-up: compile prefill+decode
        submit(24)
        tokens0 = eng.stats["tokens_out"]
        import time
        t0 = time.perf_counter()
        eng.run(max_ticks=64)
        dt = time.perf_counter() - t0
        measured = (eng.stats["tokens_out"] - tokens0) / dt
        assert measured > 0

        # analytic: the same model served on 4 cores of the SIM config,
        # single tenant, mid-decode batch of 4 at ~300 tokens context
        from repro.sched.traces import get_serving_workload
        prof = get_profile("qwen2_0_5b")
        g = get_serving_workload("qwen2_0_5b")
        sk = S.tensor_skeleton(g, [0, 1, 6, 7], mesh_2d(6, 6), S.SIM_CONFIG)
        pm = S.derive_phase_model(sk, S.finish_tensor(sk),
                                  proxy_seq=prof.proxy_seq)
        step = pm.decode_step_s(4 * 300 * prof.kv_bytes_per_token, 4 * 3)
        analytic = 4 / step

        ratio = analytic / measured
        assert self.CALIBRATION / self.TOLERANCE < ratio \
            < self.CALIBRATION * self.TOLERANCE, (
                f"analytic {analytic:.0f} tok/s vs measured "
                f"{measured:.0f} tok/s: ratio {ratio:.3f} left the "
                f"calibration band")
