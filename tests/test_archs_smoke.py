"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs (assignment
requirement (f)); plus a prefill->decode consistency check per family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import reduce_for_smoke
from repro.models import build
from repro.train import AdamWConfig, TrainConfig, init_state, make_train_step


def _batch_for(cfg, B=2, S=32, key=jax.random.PRNGKey(0)):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size - 1)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.enc_seq, cfg.frontend_dim), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["tokens"] = batch["tokens"][:, : S - cfg.frontend_seq]
        batch["patch_embeds"] = jax.random.normal(
            key, (B, cfg.frontend_seq, cfg.frontend_dim), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = reduce_for_smoke(get_config(arch))
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)

    logits = bundle.forward(params, batch)
    S_total = batch["tokens"].shape[1] + (cfg.frontend_seq
                                          if cfg.family == "vlm" else 0)
    assert logits.shape == (2, S_total, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    tcfg = TrainConfig(opt=AdamWConfig(lr=1e-3, warmup_steps=1))
    step = jax.jit(make_train_step(bundle.loss, tcfg))
    state = init_state(params, tcfg.opt)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state["step"]) == 1
    # a second step must also be finite (optimizer state exercised)
    state, metrics2 = step(state, batch)
    assert np.isfinite(float(metrics2["loss"]))


@pytest.mark.parametrize("arch", ["llama3_2_1b", "qwen3_4b", "mamba2_1_3b",
                                  "hymba_1_5b", "whisper_large_v3",
                                  "deepseek_moe_16b"])
def test_prefill_decode_consistency(arch):
    """Next-token logits from (prefill -> decode_step) must match the full
    forward at the same position — the KV-cache/state plumbing invariant."""
    cfg = reduce_for_smoke(get_config(arch))
    # fp32 params keep the comparison tight; high capacity factor removes
    # MoE token drops (capacity-based dropping is context-length dependent,
    # so exact prefill/full equivalence needs the no-drop regime)
    cfg = dataclasses.replace(cfg, param_dtype="float32",
                              compute_dtype="float32",
                              capacity_factor=16.0)
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch_for(cfg, B=B, S=S + 1)
    toks = batch["tokens"]

    # full forward logits at position S-1 predict token S
    full_batch = dict(batch)
    full_batch["tokens"] = toks[:, : S + 1]
    logits_full = bundle.forward(params, full_batch)

    # prefill on first S tokens, then one decode step
    pre_batch = dict(batch)
    pre_batch["tokens"] = toks[:, :S]
    last_logits, caches = bundle.prefill(params, pre_batch)

    n_front = cfg.frontend_seq if cfg.family == "vlm" else 0
    np.testing.assert_allclose(
        np.asarray(last_logits[:, 0, : cfg.vocab_size], np.float32),
        np.asarray(logits_full[:, n_front + S - 1, : cfg.vocab_size],
                   np.float32),
        rtol=2e-3, atol=2e-3)

    # seed a bigger decode cache so position S has a slot
    from repro.serve import seed_decode_cache
    caches = seed_decode_cache(bundle, caches, B, n_front + S + 8)
    dec_logits, _ = bundle.decode(params, caches, toks[:, S:S + 1],
                                  jnp.int32(n_front + S))
    np.testing.assert_allclose(
        np.asarray(dec_logits[:, 0, : cfg.vocab_size], np.float32),
        np.asarray(logits_full[:, n_front + S, : cfg.vocab_size], np.float32),
        rtol=5e-3, atol=5e-3)


def test_param_counts_match_published():
    expected = {
        "qwen2_7b": 7.6e9, "llama3_2_1b": 1.24e9, "qwen2_0_5b": 0.49e9,
        "qwen3_4b": 4.4e9, "mamba2_1_3b": 1.34e9,
        "deepseek_moe_16b": 16.4e9, "llama4_maverick_400b_a17b": 398e9,
    }
    for arch, want in expected.items():
        got = get_config(arch).param_count()
        assert abs(got - want) / want < 0.12, (arch, got, want)
