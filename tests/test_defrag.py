"""ILP defrag planner: validity, never-worse-than-greedy, determinism.

The planner promises three things (see ``repro.sched.defrag``):

1. **Validity** — every planned move lands on cores that are actually
   available at its turn (free + the mover's own, never quarantined,
   never the goal's reservation), each migrant keeps its own
   ``require_connected`` contract, and applying the plan really unlocks
   the goal placement;
2. **Floor** — the returned plan never pauses longer than the simulated
   greedy pass (by construction: the cheaper of the two is returned);
3. **Determinism** — identical cluster states produce bit-identical
   plans (HiGHS, the engine and all iteration orders are deterministic).
"""
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent))

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_fallback import given, settings, st

from repro.core import simulator as S
from repro.core.topology import Topology, mesh_2d
from repro.sched.cluster import ClusterScheduler, ResidentTenant
from repro.sched.defrag import ILPDefragPlanner
from repro.sched.events import TenantSpec
from repro.sched.policy import VNPUPolicy


def _spec(tid, n_cores, model="bert_base"):
    return TenantSpec(tid=tid, model=model, arrival_s=0.0,
                      duration_s=100.0, n_cores=n_cores)


def _fragmented_cluster(seed, rows=6, cols=6, require_connected=True):
    """Admit a seeded batch of tenants, then release every other one —
    the classic fragmentation pattern that defeats strict placement of a
    larger request."""
    from repro.core.workloads import get_workload
    rng = np.random.default_rng(seed)
    policy = VNPUPolicy(mesh_2d(rows, cols),
                        require_connected=require_connected)
    residents = {}
    tid = 0
    placed = []
    while True:
        n = int(rng.choice([2, 3, 4]))
        spec = _spec(tid, n)
        try:
            placement = policy.allocate(spec, strict=True)
        except Exception:
            break
        rt = ResidentTenant(spec=spec, placement=placement,
                            graph=get_workload("bert_base"),
                            admit_s=0.0, depart_s=100.0)
        residents[tid] = rt
        placed.append(tid)
        tid += 1
    # free alternating tenants to scatter holes
    for t in placed[::2]:
        policy.release(residents.pop(t).placement)
    return policy, residents


def _plan_key(plan):
    """Canonical identity of a plan, for bit-identical comparison."""
    if plan is None:
        return None
    return tuple((m.tid, m.vmid, tuple(sorted(m.result.nodes)),
                  tuple(sorted(m.result.assignment.items())),
                  m.pause_s) for m in plan.moves) + (plan.total_pause_s,
                                                     plan.source)


def _check_plan_validity(policy, residents, plan, goal_spec):
    hyp = policy.hyp
    free_now = set(hyp.free_cores())
    cores_now = {t: set(r.placement.cores) for t, r in residents.items()}
    for mv in plan.moves:
        dest = set(mv.result.nodes)
        assert not dest & hyp.quarantined
        # available at this move's turn: free pool + the mover's own cores
        assert dest <= free_now | cores_now[mv.tid]
        # no other still-resident tenant's cores
        for t, cs in cores_now.items():
            if t != mv.tid:
                assert not dest & cs
        # connectivity contract of the mover itself
        rt = residents[mv.tid]
        if rt.placement.vnpu.request.require_connected:
            assert policy.topo.subgraph(mv.result.nodes).is_connected()
        free_now = (free_now | cores_now[mv.tid]) - dest
        cores_now[mv.tid] = dest
    # applying the moves must unlock a strict placement for the goal
    eng = hyp.engine
    goal = policy._request(goal_spec, strict=True)
    assert eng.map_request(goal.topology, require_connected=True,
                           mapper=goal.mapper,
                           free_override=frozenset(free_now)) is not None


def _first_blocked_spec(policy, start_n=6):
    """Smallest request that strict placement rejects but capacity admits."""
    for n in range(start_n, 17):
        spec = _spec(999, n)
        if (len(policy.hyp.free_cores()) >= n
                and not policy.can_place(spec, strict=True)):
            return spec
    return None


class TestPlannerProperties:
    def _property(self, seed):
        policy, residents = _fragmented_cluster(seed)
        spec = _first_blocked_spec(policy, start_n=4)
        if spec is None:
            return                      # state not fragmented enough
        planner = ILPDefragPlanner(policy, S.SIM_CONFIG, max_migrations=2)
        plan = planner.plan_admission(spec, residents)
        if plan is None:
            return                      # no bounded set unlocks the goal
        assert plan.moves, "a plan must contain at least one move"
        _check_plan_validity(policy, residents, plan, spec)
        # floor: never pauses longer than the simulated greedy pass
        goal = policy._request(spec, strict=True)
        greedy = planner._simulate_greedy(
            goal.topology, planner._movers(residents),
            goal_mapper=goal.mapper)
        if greedy is not None:
            assert plan.total_pause_s <= greedy.total_pause_s + 1e-12
        # determinism: bit-identical on a replay of the same state
        again = planner.plan_admission(spec, residents)
        assert _plan_key(plan) == _plan_key(again)

    @pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
    def test_seeded(self, seed):
        self._property(seed)

    @given(st.integers(0, 10_000))
    @settings(max_examples=8, deadline=None)
    def test_property(self, seed):
        self._property(seed)

    def test_cross_instance_determinism(self):
        """Two independently-constructed identical clusters produce
        bit-identical plans."""
        keys = []
        for _ in range(2):
            policy, residents = _fragmented_cluster(3)
            spec = _first_blocked_spec(policy, start_n=4)
            if spec is None:
                pytest.skip("seed 3 no longer fragments this mesh")
            planner = ILPDefragPlanner(policy, S.SIM_CONFIG)
            keys.append(_plan_key(planner.plan_admission(spec, residents)))
        assert keys[0] == keys[1]


class TestSchedulerIntegration:
    def test_planner_requires_vnpu(self):
        from repro.sched.policy import UVMPolicy
        sched = ClusterScheduler(UVMPolicy(mesh_2d(4, 4)),
                                 defrag_planner="ilp")
        assert sched._planner is None      # silent greedy fallback

    def test_unknown_planner_rejected(self):
        with pytest.raises(ValueError):
            ClusterScheduler(VNPUPolicy(mesh_2d(4, 4)),
                             defrag_planner="simulated-annealing")

    def test_greedy_default_has_no_planner(self):
        sched = ClusterScheduler(VNPUPolicy(mesh_2d(4, 4)))
        assert sched.defrag_planner == "greedy"
        assert sched._planner is None

    @pytest.mark.slow
    def test_ilp_run_matches_greedy_admissions(self):
        """On the mixed trace the ILP planner must never admit fewer
        tenants than greedy (it only ever replaces a greedy pass with a
        provably-sufficient cheaper one, or falls back to greedy)."""
        from repro.sched.traces import make_trace
        results = {}
        for planner in ("greedy", "ilp"):
            policy = VNPUPolicy(mesh_2d(6, 6), require_connected=True)
            sched = ClusterScheduler(policy, defrag_planner=planner)
            m = sched.run(make_trace("mixed", seed=0))
            results[planner] = m
        assert results["ilp"].n_admitted >= results["greedy"].n_admitted
        assert results["ilp"].n_migrations <= results["greedy"].n_migrations
        assert results["ilp"].n_defrag_plans >= 1

    def test_apply_mapping_rejects_stale_plan(self):
        """A plan computed against one state must fail loudly if the
        destination cores were allocated in the meantime."""
        from repro.core.baselines import AllocationError
        policy, residents = _fragmented_cluster(0)
        hyp = policy.hyp
        vmid = next(iter(residents.values())).placement.handle
        vnpu = hyp.vnpus[vmid]
        taken = sorted(set(hyp.free_cores()))[: vnpu.request.topology.num_nodes]
        if len(taken) < vnpu.request.topology.num_nodes:
            pytest.skip("not enough free cores for the stale-plan probe")
        hyp.engine.notify_allocate(taken)   # someone else grabbed them
        from repro.core.mapping import MappingResult
        stale = MappingResult(
            nodes=frozenset(taken), ted=0.0,
            assignment={v: p for v, p in
                        zip(sorted(vnpu.request.topology.node_attrs),
                            taken)},
            exact=True)
        with pytest.raises(AllocationError):
            hyp.apply_mapping(vmid, stale)
