"""Fleet federation layer: the inter-pod switch, the deterministic global
router, pod seed derivation, evacuation semantics, latency-sketch merging,
serial-vs-parallel bit-identity, and the link-heat placement tie-break."""
import dataclasses

import pytest

from repro.core import MappingEngine, mesh_2d
from repro.fleet import (Fleet, FleetConfig, FleetPodParams, PodSpec,
                         PodSwitch, PodView, RouterStats, Scenario,
                         SwitchConfig, derive_pod_seed, fleet_trace,
                         make_routing_policy)
from repro.fleet.pod import PodHost
from repro.sched import ClusterScheduler, TenantSpec, VNPUPolicy
from repro.serve.stats import LatencyStats


def _spec(tid=1, model="resnet18", n_cores=4, arrival=0.0, duration=10.0,
          **kw):
    return TenantSpec(tid=tid, model=model, n_cores=n_cores,
                      arrival_s=arrival, duration_s=duration, **kw)


def _view(pod_id, healthy=256, resident_cores=0, queued_cores=0,
          models=None, **kw):
    return PodView(pod_id=pod_id, total_cores=256, healthy_cores=healthy,
                   free_cores=healthy - resident_cores,
                   n_resident=0, n_queued=0,
                   resident_cores=resident_cores, queued_cores=queued_cores,
                   utilization=resident_cores / max(healthy, 1),
                   models=models or {}, **kw)


# ---------------------------------------------------------------------------
# inter-pod switch
# ---------------------------------------------------------------------------

class TestPodSwitch:
    CFG = SwitchConfig(latency_s=1e-3, bandwidth_bytes_per_s=1e9,
                       buffer_bytes=1 << 20)

    def test_single_transfer_latency_plus_serialization(self):
        sw = PodSwitch(self.CFG)
        done = sw.transfer(0, 1, 500_000_000, now=2.0)
        assert done == pytest.approx(2.0 + 1e-3 + 0.5)
        assert sw.stats.n_transfers == 1
        assert sw.stats.bytes_total == 500_000_000
        assert sw.stats.queued_s == 0.0

    def test_same_link_serializes(self):
        sw = PodSwitch(self.CFG)
        first = sw.transfer(0, 1, 1_000_000_000, now=0.0)   # 1 s on the wire
        second = sw.transfer(0, 1, 1_000_000_000, now=0.0)
        assert first == pytest.approx(1.001)
        # the second queues behind the first's serialization (not its
        # latency), then pays its own latency + serialization
        assert second == pytest.approx(1.0 + 1e-3 + 1.0)
        assert sw.stats.queued_s == pytest.approx(1.0)

    def test_distinct_links_do_not_serialize(self):
        sw = PodSwitch(self.CFG)
        a = sw.transfer(0, 1, 1_000_000_000, now=0.0)
        b = sw.transfer(1, 0, 1_000_000_000, now=0.0)   # reverse direction
        c = sw.transfer(0, 2, 1_000_000_000, now=0.0)   # different dst
        assert a == b == c == pytest.approx(1.001)

    def test_buffer_overflow_counted_not_dropped(self):
        sw = PodSwitch(self.CFG)
        for _ in range(4):
            done = sw.transfer(0, 1, 2 << 20, now=0.0)   # 2 MiB vs 1 MiB buf
        assert sw.stats.buffer_overflows >= 2
        assert sw.stats.n_transfers == 4          # lossless: all complete
        assert done > 0.0
        assert sw.stats.max_backlog_bytes >= 4 * (2 << 20)

    def test_backlog_drains_at_bandwidth(self):
        sw = PodSwitch(self.CFG)
        sw.transfer(0, 1, 2 << 20, now=0.0)
        # ~2 MiB takes ~2.1 ms at 1 GB/s; after 10 ms the backlog is gone
        sw.transfer(0, 1, 2 << 20, now=10.0)
        assert sw.stats.buffer_overflows == 0


# ---------------------------------------------------------------------------
# routing policies + router
# ---------------------------------------------------------------------------

class TestRoutingPolicies:
    def test_least_loaded_picks_lowest_pressure_tie_by_pod_id(self):
        pol = make_routing_policy("least-loaded")
        views = [_view(0, resident_cores=64), _view(1, resident_cores=32),
                 _view(2, resident_cores=32)]
        assert pol.choose(_spec(), views, {}) == 1   # tie 1 vs 2 -> lower id

    def test_committed_cores_spread_a_burst(self):
        pol = make_routing_policy("least-loaded")
        views = [_view(0), _view(1)]
        assert pol.choose(_spec(), views, {}) == 0
        # after committing a big ask to pod 0, the next choice moves on
        assert pol.choose(_spec(), views, {0: 128}) == 1

    def test_draining_and_failed_pods_ineligible(self):
        pol = make_routing_policy("least-loaded")
        views = [_view(0, draining=True), _view(1, failed=True), _view(2)]
        assert pol.choose(_spec(), views, {}) == 2

    def test_unroutable_when_ask_exceeds_every_healthy_pod(self):
        pol = make_routing_policy("least-loaded")
        views = [_view(0, healthy=8), _view(1, healthy=8)]
        assert pol.choose(_spec(n_cores=16), views, {}) is None

    def test_affinity_prefers_warm_pod_until_overloaded(self):
        pol = make_routing_policy("affinity")
        views = [_view(0), _view(1, resident_cores=64,
                                 models={"resnet18": 2})]
        assert pol.choose(_spec(model="resnet18"), views, {}) == 1
        # a cold model falls back to least-loaded
        assert pol.choose(_spec(model="gpt2_small"), views, {}) == 0
        # overload cap: the warm pod past the cap stops attracting
        hot = [_view(0), _view(1, resident_cores=255 + 256,
                               models={"resnet18": 9})]
        assert pol.choose(_spec(model="resnet18"), hot, {}) == 0

    def test_round_robin_rotates_over_eligible(self):
        pol = make_routing_policy("round-robin")
        views = [_view(0), _view(1, draining=True), _view(2)]
        got = [pol.choose(_spec(), views, {}) for _ in range(4)]
        assert got == [0, 2, 0, 2]

    def test_make_routing_policy_unknown_raises(self):
        with pytest.raises(KeyError):
            make_routing_policy("nope")

    def test_router_stats_and_commit_tracking(self):
        from repro.fleet import FleetRouter
        router = FleetRouter(make_routing_policy("least-loaded"))
        views = [_view(0), _view(1)]
        router.new_window()
        a = router.route(_spec(tid=1, n_cores=128), views)
        b = router.route(_spec(tid=2, n_cores=4), views)
        assert (a, b) == (0, 1)                  # commitment pushed tid 2 off
        assert router.route(_spec(tid=3, n_cores=512), views) is None
        d = router.stats.as_dict()
        assert d["routed"] == 2 and d["unroutable"] == 1
        assert d["routed_by_pod"] == {"0": 1, "1": 1}
        router.new_window()                      # commitments reset
        assert router.route(_spec(tid=4), views) == 0


# ---------------------------------------------------------------------------
# pod seeds + evacuation semantics
# ---------------------------------------------------------------------------

class TestPodSeedsAndEvacuation:
    def test_derived_seeds_deterministic_and_decorrelated(self):
        seeds = [derive_pod_seed(42, pid) for pid in range(16)]
        assert seeds == [derive_pod_seed(42, pid) for pid in range(16)]
        assert len(set(seeds)) == 16
        assert seeds != [42 + pid for pid in range(16)]
        assert derive_pod_seed(43, 0) != derive_pod_seed(42, 0)

    def test_evacuate_restamps_residents_keeps_queued_verbatim(self):
        host = PodHost(PodSpec(pod_id=0, rows=4, cols=4),
                       FleetPodParams(serving=False))
        resident = _spec(tid=1, n_cores=4, arrival=0.0, duration=50.0)
        # asks for the whole mesh while tid 1 holds cores -> stays queued
        queued = _spec(tid=2, n_cores=16, arrival=0.0, duration=5.0,
                       sla_wait_s=1e9)
        host.feed([resident, queued])
        host.advance_to(10.0)
        host.drain()
        res, que = host.evacuate(10.0)
        assert [s.tid for s in res] == [1]
        assert res[0].arrival_s == 10.0
        assert res[0].duration_s == pytest.approx(40.0)
        assert que == [queued]                   # verbatim, SLA clock runs
        assert host.snapshot().n_resident == 0

    def test_fleet_trace_scales_rate_with_pod_count(self):
        small = fleet_trace(2, seed=0, horizon_s=50.0)
        big = fleet_trace(8, seed=0, horizon_s=50.0)
        assert len(big) > 2 * len(small)
        assert all(0 <= s.arrival_s < 50.0 for s in big)


# ---------------------------------------------------------------------------
# latency-sketch merging
# ---------------------------------------------------------------------------

class TestLatencyStatsMerge:
    def test_exact_merge_replays_buffers(self):
        import numpy as np
        a, b = LatencyStats(), LatencyStats()
        xs, ys = [1.0, 5.0, 3.0], [2.0, 4.0]
        for x in xs:
            a.add(x)
        for y in ys:
            b.add(y)
        m = LatencyStats.merge([a, b])
        assert m.count == 5 and m.total == pytest.approx(15.0)
        assert m.percentile(50) == pytest.approx(
            float(np.percentile(xs + ys, 50)))

    def test_sketched_merge_approximates_pooled_percentiles(self):
        import numpy as np
        rng = np.random.default_rng(0)
        parts, pooled = [], []
        for i in range(4):
            st = LatencyStats()
            vals = rng.gamma(2.0, 0.5, size=500) + i * 0.1
            for v in vals:
                st.add(float(v))
            pooled.extend(float(v) for v in vals)
            parts.append(st)
        m = LatencyStats.merge(parts)
        assert m.count == 2000
        for q in (50, 95, 99):
            exact = float(np.percentile(pooled, q))
            got = m.percentile(q)
            assert abs(got - exact) <= max(0.15 * exact, 0.05), (q, got,
                                                                 exact)
        # merged percentiles are independent of part order
        rev = LatencyStats.merge(list(reversed(parts)))
        assert rev.percentile(95) == pytest.approx(m.percentile(95))

    def test_merged_is_read_only_and_empty_parts_drop(self):
        a = LatencyStats()
        for v in range(100):
            a.add(float(v))
        m = LatencyStats.merge([LatencyStats(), a])
        assert m.count == 100
        with pytest.raises(RuntimeError):
            m.add(1.0)
        empty = LatencyStats.merge([])
        assert empty.count == 0 and empty.percentile(50) == 0.0


# ---------------------------------------------------------------------------
# serial vs parallel bit-identity (the tentpole invariant)
# ---------------------------------------------------------------------------

class TestFleetBitIdentity:
    def _run(self, workers):
        pods = [PodSpec(pod_id=0, rows=8, cols=8),
                PodSpec(pod_id=1, rows=8, cols=8,
                        mem_interface_cols=(0, 7))]
        cfg = FleetConfig(seed=11, window_s=2.0, record_requests=True)
        fleet = Fleet(pods, cfg)
        trace = fleet_trace(2, seed=11, horizon_s=8.0)
        scenarios = [Scenario("upgrade", t_s=4.0, pod_id=1, duration_s=4.0)]
        return fleet.run(trace, scenarios=scenarios, workers=workers,
                         end_s=24.0)

    def test_parallel_matches_serial_bit_for_bit(self):
        serial = self._run(1)
        par = self._run(2)
        assert par.workers == 2
        assert serial.pod_digests() == par.pod_digests()
        assert serial.serving_summary() == par.serving_summary()
        assert serial.requests_arrived > 0
        assert serial.router.routed > 0

    def test_pod_failure_evacuates_through_router(self):
        pods = [PodSpec(pod_id=0, rows=8, cols=8),
                PodSpec(pod_id=1, rows=8, cols=8)]
        fleet = Fleet(pods, FleetConfig(seed=3, window_s=2.0))
        trace = fleet_trace(2, seed=3, horizon_s=6.0)
        m = fleet.run(trace, scenarios=[Scenario("pod-failure", t_s=4.0,
                                                 pod_id=0)],
                      workers=1, end_s=20.0)
        s = m.serving_summary()
        assert s["evacuated"] > 0
        assert s["router"]["migrations"] > 0
        # everything after the failure lands on the surviving pod
        assert m.pods[0].n_events > 0
        assert s["switch"]["n_transfers"] == s["router"]["migrations"] \
            or s["switch"]["n_transfers"] <= s["router"]["migrations"]

    def test_duplicate_pod_ids_rejected(self):
        with pytest.raises(ValueError):
            Fleet([PodSpec(pod_id=0), PodSpec(pod_id=0)])

    def test_unknown_scenario_kind_rejected(self):
        fleet = Fleet([PodSpec(pod_id=0, rows=4, cols=4)])
        with pytest.raises(ValueError):
            fleet.run([], scenarios=[Scenario("reboot", 1.0, 0)])


# ---------------------------------------------------------------------------
# link-heat-aware admission (satellite: cold-boundary tie-break)
# ---------------------------------------------------------------------------

class TestHeatAwarePlacement:
    def test_heat_fn_none_is_the_default_path(self):
        eng = MappingEngine(mesh_2d(6, 6))
        assert eng.heat_fn is None
        base = eng.map_request(mesh_2d(2, 2, base_id=100))
        assert base is not None and base.ted == 0.0

    def test_hot_boundary_steers_equal_ted_choice(self):
        """Two equal-TED free regions (a wall splits the mesh): the engine
        prefers the one whose boundary links are cold."""
        req = mesh_2d(2, 2, base_id=100)
        wall = {n for n in range(36) if n % 6 in (2, 3)}   # cols 2-3 of 6x6

        cold_eng = MappingEngine(mesh_2d(6, 6))
        cold_eng.notify_allocate(wall)
        baseline = cold_eng.map_request(req)
        assert baseline.ted == 0.0

        hot_eng = MappingEngine(mesh_2d(6, 6))
        hot_eng.notify_allocate(wall)
        # roast every directed link crossing the baseline choice's boundary
        loads = {}
        for n in baseline.nodes:
            for m in hot_eng.adj[n]:
                if m not in baseline.nodes:
                    loads[(n, m)] = 100.0
                    loads[(m, n)] = 100.0
        hot_eng.heat_fn = lambda: loads
        steered = hot_eng.map_request(req)
        assert steered is not None and steered.ted == 0.0
        assert set(steered.nodes) != set(baseline.nodes)
        assert hot_eng._boundary_heat(steered.nodes, loads) \
            < hot_eng._boundary_heat(baseline.nodes, loads)
        # the two choices live in the two disjoint halves of the mesh
        assert set(steered.nodes).isdisjoint(set(baseline.nodes))

    def test_vnpu_policy_binds_ledger_heat(self):
        policy = VNPUPolicy(mesh_2d(6, 6), heat_aware=True)
        assert policy.heat_aware
        sched = ClusterScheduler(policy, rescore="ledger")
        assert policy.hyp.engine.heat_fn is not None
        sched.begin(driven=True)
        sched.feed([_spec(tid=1, n_cores=4, duration=20.0)])
        sched.advance_to(5.0)
        assert policy.hyp.engine.heat_fn() is not None
        sched.finish()

    def test_heat_off_policy_has_no_heat_fn(self):
        policy = VNPUPolicy(mesh_2d(6, 6))
        ClusterScheduler(policy, rescore="ledger")
        assert policy.hyp.engine.heat_fn is None
