"""The vectorized serving plane at scale: scalar-vs-vector bit-identity,
streaming P^2 percentile sketches, the new arrival processes (diurnal /
flash-crowd thinning, heavy-tail prompts), byte-weighted decode HBM
sharing, batched KV-arena queries, and the memory audit behind the
million-request gate (no O(requests) state after detach).
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.core import mesh_2d
from repro.core import simulator as S
from repro.sched import (ClusterScheduler, ServingConfig, make_policy,
                         make_trace)
from repro.sched.cluster import HBM_BYTE_WEIGHT
from repro.serve.kv import TenantKV
from repro.serve.plane import ServingPlane
from repro.serve.requests import (REQUEST_MIXES, SERVE_PROFILES,
                                  ArrivalProcess, sample_requests)
from repro.serve.stats import TRACKED_QUANTILES, LatencyStats, P2Quantile


# ---------------------------------------------------------------------------
# arrival processes: determinism, thinning, heavy tails
# ---------------------------------------------------------------------------

class TestArrivalProcesses:
    def test_deterministic_per_seed(self):
        prof = SERVE_PROFILES["qwen2_0_5b"]
        for arrival, mix, scale in (
                (ArrivalProcess(kind="diurnal"), "default", 1.0),
                (ArrivalProcess(kind="flash"), "doc_heavy", 2.0)):
            a = sample_requests(prof, 120.0, seed=7, arrival=arrival,
                                rate_scale=scale, mix=mix)
            b = sample_requests(prof, 120.0, seed=7, arrival=arrival,
                                rate_scale=scale, mix=mix)
            assert a == b
            c = sample_requests(prof, 120.0, seed=8, arrival=arrival,
                                rate_scale=scale, mix=mix)
            assert a != c

    def test_explicit_poisson_routes_through_legacy_loop(self):
        """A homogeneous unscaled default-mix stream must be byte-identical
        to the historical sampler whether ``arrival`` is omitted or an
        explicit poisson process — the gates pin trajectories on it."""
        prof = SERVE_PROFILES["transformer"]
        assert sample_requests(prof, 60.0, seed=3) == sample_requests(
            prof, 60.0, seed=3, arrival=ArrivalProcess())

    def test_rate_scale_scales_volume(self):
        prof = SERVE_PROFILES["transformer"]          # 15 req/s base
        n2 = len(sample_requests(prof, 400.0, seed=1, rate_scale=2.0))
        n4 = len(sample_requests(prof, 400.0, seed=1, rate_scale=4.0))
        assert n4 / n2 == pytest.approx(2.0, rel=0.10)

    def test_diurnal_thinning_tracks_rate(self):
        """Bin arrivals into the sine's rising and falling half-periods:
        the count ratio must match the analytic intensity integral
        (pi + 2a) / (pi - 2a) — the thinning acceptance test."""
        prof = SERVE_PROFILES["qwen2_0_5b"]           # 8 req/s base
        arr = ArrivalProcess(kind="diurnal", period_s=240.0, amplitude=0.6)
        reqs = sample_requests(prof, 960.0, seed=11, arrival=arr)
        ts = np.array([r.t_s for r in reqs])
        phase = (ts % arr.period_s) / arr.period_s
        peak = int(np.sum(phase < 0.5))               # sin >= 0 half
        trough = int(np.sum(phase >= 0.5))
        expect = (math.pi + 2 * arr.amplitude) / (math.pi
                                                  - 2 * arr.amplitude)
        assert peak / max(trough, 1) == pytest.approx(expect, rel=0.15)

    def test_flash_crowd_burst(self):
        prof = SERVE_PROFILES["qwen2_0_5b"]
        arr = ArrivalProcess(kind="flash", flash_t_s=45.0, flash_dur_s=25.0,
                             flash_mult=4.0)
        reqs = sample_requests(prof, 120.0, seed=5, arrival=arr)
        ts = np.array([r.t_s for r in reqs])
        in_burst = int(np.sum((ts >= 45.0) & (ts < 70.0)))
        baseline = int(np.sum((ts >= 90.0) & (ts < 115.0)))   # same width
        assert in_burst / max(baseline, 1) == pytest.approx(4.0, rel=0.35)

    def test_heavy_tail_prompt_moments(self):
        """doc_heavy docs draw Pareto-I (alpha 2.1) prompts: the sample
        mean sits near the class mean, and the tail is qualitatively
        heavier than the default lognormal docs (clip-rail mass at
        prompt_max, larger p99/p50 spread)."""
        prof = SERVE_PROFILES["qwen2_0_5b"]
        heavy = [r.prompt_tokens for r in sample_requests(
            prof, 2000.0, seed=2, arrival=ArrivalProcess(), mix="doc_heavy",
            rate_scale=2.0) if r.cls == "doc"]
        cls = next(c for c in REQUEST_MIXES["doc_heavy"] if c.name == "doc")
        assert len(heavy) > 2000
        heavy = np.array(heavy, dtype=float)
        # mean: Pareto mean 900 minus the mass clipped at prompt_max
        assert 700.0 < heavy.mean() < 950.0
        assert heavy.max() == cls.prompt_max          # tail hits the clip
        light = np.array([r.prompt_tokens for r in sample_requests(
            prof, 2000.0, seed=2, arrival=ArrivalProcess(),
            rate_scale=2.0) if r.cls == "doc"], dtype=float)
        spread_h = np.percentile(heavy, 99) / np.percentile(heavy, 50)
        spread_l = np.percentile(light, 99) / np.percentile(light, 50)
        assert spread_h > 1.5 * spread_l


# ---------------------------------------------------------------------------
# streaming percentile sketches (P^2)
# ---------------------------------------------------------------------------

class TestLatencyStats:
    def test_exact_below_cutover(self):
        rng = np.random.default_rng(0)
        xs = rng.lognormal(0.0, 1.0, size=40)
        st = LatencyStats()
        for x in xs:
            st.add(float(x))
        for q in (50, 95, 99):
            assert st.percentile(q) == pytest.approx(
                float(np.percentile(xs, q)))

    def test_sketch_tracks_numpy_percentiles(self):
        rng = np.random.default_rng(1)
        xs = rng.lognormal(0.0, 1.0, size=20_000)
        st = LatencyStats()
        for x in xs:
            st.add(float(x))
        assert st.count == 20_000
        assert st.mean == pytest.approx(float(xs.mean()))
        for q, tol in ((50, 0.05), (95, 0.05), (99, 0.10)):
            assert st.percentile(q) == pytest.approx(
                float(np.percentile(xs, q)), rel=tol)

    def test_untracked_percentile_raises_after_cutover(self):
        st = LatencyStats()
        for i in range(200):
            st.add(float(i))
        with pytest.raises(ValueError):
            st.percentile(90)
        assert st.percentile(95) > 0.0

    def test_deterministic_for_identical_feeds(self):
        rng = np.random.default_rng(4)
        xs = [float(x) for x in rng.exponential(1.0, size=5000)]
        outs = []
        for _ in range(2):
            st = LatencyStats()
            for x in xs:
                st.add(x)
            outs.append(tuple(st.percentile(100 * q)
                              for q in TRACKED_QUANTILES))
        assert outs[0] == outs[1]

    def test_p2_exact_on_tiny_samples(self):
        q = P2Quantile(0.50)
        for x in (5.0, 1.0, 3.0):
            q.add(x)
        assert q.value() == pytest.approx(3.0)


# ---------------------------------------------------------------------------
# scalar-vs-vector bit-identity at the plane level
# ---------------------------------------------------------------------------

def _phase(prefill, step_cycles, hbm=720.0, stall=18.0, freq=500e6):
    return S.PhaseModel(prefill_tokens_per_s=prefill,
                        step_base_cycles=step_cycles,
                        hbm_bytes_per_cycle=hbm,
                        stall_cycles_per_range=stall, freq_hz=freq)


_PLANE_TENANTS = (("transformer", 1), ("qwen2_0_5b", 2), ("llama3_2_1b", 3))
_PLANE_PHASES = {1: _phase(60_000.0, 2e5), 2: _phase(25_000.0, 6e5),
                 3: _phase(9_000.0, 1.6e6)}


def _drive_plane(engine, arrival=None, mix="default", rate_scale=1.0,
                 record=True):
    """Attach three tenants, advance irregular windows with a mid-run
    departure, and capture everything observable: the streamed sink feed,
    per-window pressure signals, and the departure folds."""
    emitted = []
    plane = ServingPlane(seed=3, engine=engine, record_requests=record,
                         arrival=arrival, rate_scale=rate_scale, mix=mix,
                         sink=lambda *a: emitted.append(a))
    for model, tid in _PLANE_TENANTS:
        # depart_s bounds the sampled stream — keep it just past the
        # driven windows (14 x 1.3 s)
        assert plane.attach(tid, model, 0.0, 0.0, 25.0)
    folds, pressures = {}, []
    t = 0.0
    for i in range(14):
        t2 = t + 1.3
        entries = [(tid, t, _PLANE_PHASES[tid]) for _, tid in _PLANE_TENANTS
                   if plane.is_attached(tid)]
        plane.advance_all(entries, t2)
        pressures.extend(plane.pressure(tid) for _, tid in _PLANE_TENANTS
                         if plane.is_attached(tid))
        t = t2
        if i == 7:
            folds[2] = plane.detach(2)           # mid-run departure
    for _, tid in _PLANE_TENANTS:
        if plane.is_attached(tid):
            folds[tid] = plane.detach(tid)
    return emitted, pressures, folds, plane.peak_live_records


class TestVectorScalarIdentity:
    @pytest.mark.parametrize("arrival,mix,scale", [
        (None, "default", 1.0),
        (ArrivalProcess(kind="diurnal"), "doc_heavy", 1.0),
        (ArrivalProcess(kind="flash"), "default", 2.0),
    ])
    def test_plane_identity(self, arrival, mix, scale):
        vec = _drive_plane("vector", arrival, mix, scale)
        sca = _drive_plane("scalar", arrival, mix, scale)
        assert vec[0] == sca[0]                  # streamed completions
        assert vec[1] == sca[1]                  # pressure signals
        assert vec[2] == sca[2]                  # departure folds
        assert sum(len(f.records) for f in vec[2].values()) > 0

    def test_scheduler_identity_short_horizon(self):
        outs = {}
        for engine in ServingPlane.ENGINES:
            trace = make_trace("serving", horizon_s=40.0)
            policy = make_policy("vnpu", mesh_2d(8, 8))
            sch = ClusterScheduler(policy, admission="sla",
                                   serving=ServingConfig(engine=engine))
            m = sch.run(trace, trace_name="serving")
            outs[engine] = (m.request_log, m.n_resizes, m.serving_summary())
        assert outs["vector"] == outs["scalar"]

    def test_unknown_engine_rejected(self):
        with pytest.raises(ValueError):
            ServingPlane(engine="warp")


# ---------------------------------------------------------------------------
# memory audit: nothing O(requests) survives a detach
# ---------------------------------------------------------------------------

def _serving_run(horizon, **cfg_kw):
    trace = make_trace("serving", horizon_s=horizon)
    policy = make_policy("vnpu", mesh_2d(8, 8))
    sch = ClusterScheduler(policy, admission="sla",
                           serving=ServingConfig(**cfg_kw))
    return sch.run(trace, trace_name="serving")


class TestMemoryAudit:
    def test_streaming_mode_keeps_no_records(self):
        m = _serving_run(60.0, record_requests=False)
        assert m.request_log == []
        assert m.peak_live_records == 0          # no records materialized
        s = m.serving_summary()
        assert s["requests"] > 500 and s["completed"] > 0
        assert s["ttft_p99_s"] > 0.0 and s["tpot_p99_s"] > 0.0

    def test_record_mode_peak_is_bounded_by_churn(self):
        """With records on, detach folds each tenant's records out of the
        plane — the high-water mark stays well under the total request
        volume on a trace with tenant churn (O(attached backlog), not
        O(all requests ever))."""
        m = _serving_run(90.0, record_requests=True)
        assert 0 < m.peak_live_records < 0.7 * m.requests_arrived

    def test_streaming_and_record_modes_agree(self):
        a = _serving_run(45.0, record_requests=True)
        b = _serving_run(45.0, record_requests=False)
        assert a.serving_summary() == b.serving_summary()
        assert len(a.request_log) > 0 and b.request_log == []


# ---------------------------------------------------------------------------
# byte-weighted decode HBM sharing (pinned regression)
# ---------------------------------------------------------------------------

def _skeleton(model, k):
    from repro.sched.traces import get_serving_workload
    g = get_serving_workload(model)
    return S.tensor_skeleton(g, list(range(k)), mesh_2d(8, 8), S.SIM_CONFIG)


class TestByteWeightedHBM:
    def test_equal_split_share_is_legacy_identical(self):
        """share = 1/clients must reproduce the legacy equal-split model
        bit-for-bit (0.25 * B and B / 4 are the same float)."""
        sk = _skeleton("qwen2_0_5b", 6)
        prof = SERVE_PROFILES["qwen2_0_5b"]
        rep = S.finish_tensor(sk)
        legacy = S.derive_phase_model(sk, rep, proxy_seq=prof.proxy_seq,
                                      decode_hbm_clients=4)
        shared = S.derive_phase_model(sk, rep, proxy_seq=prof.proxy_seq,
                                      decode_hbm_clients=4, hbm_share=0.25)
        assert shared == legacy

    def test_share_scales_streamed_bytes_pinned(self):
        """The weighted share is charged to the streamed decode bytes:
        halving the share adds exactly weights/(B*s) worth of cycles, and
        the exported KV bandwidth is exactly B*s."""
        sk = _skeleton("qwen2_0_5b", 6)
        prof = SERVE_PROFILES["qwen2_0_5b"]
        rep = S.finish_tensor(sk)
        B = S.SIM_CONFIG.hbm_bytes_per_cycle
        wbytes = sk.graph.total_weight_bytes
        hi = S.derive_phase_model(sk, rep, proxy_seq=prof.proxy_seq,
                                  decode_hbm_clients=4, hbm_share=0.5)
        lo = S.derive_phase_model(sk, rep, proxy_seq=prof.proxy_seq,
                                  decode_hbm_clients=4, hbm_share=0.25)
        assert not hi.weights_resident               # it streams
        assert hi.hbm_bytes_per_cycle == pytest.approx(B * 0.5)
        assert lo.hbm_bytes_per_cycle == pytest.approx(B * 0.25)
        extra_s = (wbytes / (B * 0.25) - wbytes / (B * 0.5)) / hi.freq_hz
        assert lo.decode_step_s(0, 0) - hi.decode_step_s(0, 0) == \
            pytest.approx(extra_s, rel=1e-9)
        assert lo.decode_step_s(0, 0) > hi.decode_step_s(0, 0)

    def test_blend_constant_pinned(self):
        """The scheduler's share blend (see cluster._hbm_share_keys) is a
        calibrated constant: the serving gate's goodput ordering
        (vNPU >= MIG/UVM) was validated at this value."""
        assert HBM_BYTE_WEIGHT == 0.25

    def test_blend_conserves_port(self):
        """Convex-blend shares over any busy census sum to 1."""
        demands = [11_683 << 20, 1_034 << 20, 64 << 20, 128 << 20]
        total = sum(demands)
        n = len(demands)
        shares = [(1.0 - HBM_BYTE_WEIGHT) / n + HBM_BYTE_WEIGHT * d / total
                  for d in demands]
        assert sum(shares) == pytest.approx(1.0)
        assert all(s >= (1.0 - HBM_BYTE_WEIGHT) / n for s in shares)
        assert shares[0] == max(shares)              # 7B earns the most


# ---------------------------------------------------------------------------
# batched KV-arena queries
# ---------------------------------------------------------------------------

class TestKVBatchedQueries:
    def _kv(self):
        return TenantKV(arena_bytes=32 << 20, block_bytes=1 << 20,
                        kv_bytes_per_token=16 << 10)

    def test_block_counts_matches_n_ranges(self):
        kv = self._kv()
        for rid, tokens in ((1, 10), (2, 100), (3, 300)):
            assert kv.try_admit(rid, tokens)
        rids = [3, 1, 2, 99]
        counts = kv.block_counts(rids)
        assert counts.dtype == np.int64
        assert counts.tolist() == [kv.n_ranges(r) for r in rids]
        assert counts[3] == 0                        # unknown rid

    def test_capacity_limit_is_exact_growth_inverse(self):
        """tokens <= capacity_limit_tokens(rid) iff try_grow allocates
        nothing — the vectorized plane's O(1) precheck must agree with
        the real allocator on every boundary."""
        kv = self._kv()
        assert kv.try_admit(7, 100)
        cap = kv.capacity_limit_tokens(7)
        blocks = kv.n_ranges(7)
        assert cap == blocks * (1 << 20) // (16 << 10)
        assert kv.try_grow(7, cap)
        assert kv.n_ranges(7) == blocks              # no-op at the limit
        assert kv.try_grow(7, cap + 1)
        assert kv.n_ranges(7) == blocks + 1          # one step past: alloc
