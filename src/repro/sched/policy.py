"""One placement API for vNPU / MIG / UVM.

``PlacementPolicy`` is the protocol the cluster scheduler drives:
``allocate`` / ``release`` / ``migrate`` / ``utilization``.  The three
implementations adapt the core allocators:

* :class:`VNPUPolicy` — the paper's hypervisor: similar-topology mapping
  with fragmented fallback, dataflow (NoC) communication, live migration
  for defragmentation (``Hypervisor.migrate_vnpu``);
* :class:`MIGPolicy` — fixed rectangular partitions, TDM when a request
  exceeds every free partition; no defragmentation (a partition is a
  partition), but failed-partition evacuation moves a tenant to another
  free partition;
* :class:`UVMPolicy` — any free cores, all inter-core traffic through
  global memory (the HBM-contended baseline); defragmentation is
  pointless (no topology), but dead cores are swapped for free ones.

All three implement ``mark_failed`` / ``mark_repaired`` (quarantine and
recovery: vNPU per-core via the hypervisor, MIG per-partition, UVM
per-core), so failure injection *and* repair in the cluster loop are
meaningful for every policy.

``utilization()`` is comparable across policies: fraction of physical
cores doing *useful* work.  For vNPU/UVM this equals allocated/total
(allocations are exact); for MIG an occupied partition contributes only
the cores its tenant requested — the remainder is internal fragmentation.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Optional, Sequence, Set, Tuple

from ..core.baselines import AllocationError, MIGPartitioner, UVMAllocator
from ..core.hypervisor import Hypervisor, VirtualNPU, VNPURequest
from ..core.mapping import mem_dist_node_match
from ..core.topology import Topology, mesh_2d
from ..core.vrouter import rt_config_cost
from .events import TenantSpec


def best_rect(n: int) -> Tuple[int, int]:
    """Most-square factorization of ``n`` (a line when ``n`` is prime)."""
    best = (1, n)
    for r in range(1, int(n ** 0.5) + 1):
        if n % r == 0:
            best = (r, n // r)
    return best


@dataclasses.dataclass
class Placement:
    """A tenant's admitted footprint, in simulator terms.

    ``cores`` is what the simulator runs the workload on; ``time_share`` /
    ``tdm_physical`` carry the MIG oversubscription; ``comm`` selects the
    NoC-vs-global-memory communication style; ``hbm_client`` marks the
    tenant as a shared-HBM-bandwidth consumer (UVM sync traffic).
    ``vnpu`` is set by :class:`VNPUPolicy` only — it is the handle the JAX
    mesh integration (:func:`repro.core.vmesh.virtual_mesh`) consumes.
    """
    tid: int
    cores: Tuple[int, ...]
    time_share: float = 1.0
    comm: str = "dataflow"            # simulator comm mode
    tdm_physical: Optional[int] = None
    hbm_client: bool = False
    handle: object = None             # policy-private
    vnpu: Optional[VirtualNPU] = None

    @property
    def n_cores(self) -> int:
        return len(set(self.cores))


class PlacementPolicy:
    """Protocol + shared plumbing for cluster placement policies."""

    name: str = "abstract"

    def __init__(self, topo: Topology):
        self.topo = topo
        self.placements: Dict[int, Placement] = {}

    # -- protocol ----------------------------------------------------------
    def allocate(self, spec: TenantSpec, strict: bool = False) -> Placement:
        """Place a tenant or raise :class:`AllocationError`.

        ``strict`` asks for the high-quality placement only (for vNPU: a
        *connected* sub-topology, no fragmented fallback) — the scheduler
        tries strict first, defragments, and only then relaxes.  Policies
        without a quality distinction ignore the flag.
        """
        raise NotImplementedError

    def can_place(self, spec: TenantSpec, strict: bool = False) -> bool:
        """Side-effect-free feasibility probe for ``allocate``."""
        return len(self.free_cores()) >= spec.n_cores

    def release(self, placement: Placement) -> None:
        raise NotImplementedError

    def migrate(self, placement: Placement,
                avoid: Sequence[int] = ()) -> Tuple[Placement, bool]:
        """Best-effort move to a better spot.  Default: cannot move."""
        return placement, False

    def mark_failed(self, cores: Sequence[int]) -> None:
        """Dead hardware: quarantine the cores so nothing is placed on them
        again.  Policies without that notion ignore the report; callers
        should still ``migrate(placement, avoid=cores)`` affected tenants."""

    def mark_repaired(self, cores: Sequence[int]) -> None:
        """Repaired hardware: lift the quarantine so the cores are
        allocatable again.  Policies without a quarantine notion ignore the
        report; callers must invalidate any placement-feasibility memos
        they hold (repair grows the free pool)."""

    def resize(self, placement: Placement,
               new_n_cores: int) -> Tuple[Placement, bool]:
        """Elastic resize: grow or shrink a *live* tenant to
        ``new_n_cores`` cores, preserving its memory contents.  Returns
        ``(placement, resized)``; the default (and MIG, whose partitions
        are fixed) cannot resize.  Callers charge the scratchpad re-warm
        pause like a migration."""
        return placement, False

    def request_key(self, spec: TenantSpec) -> Tuple:
        """Hashable identity of what ``allocate`` reads from a spec — the
        scheduler's negative-probe memo key.  Default: the size class
        ``(n_cores, memory_bytes, bandwidth_cap)``.  Policies that map a
        *topology* (vNPU) refine this with the request's canonical shape
        key, so two asks that build different topologies never share a
        memo entry even if their size classes collide."""
        return (spec.n_cores, spec.memory_bytes, spec.bandwidth_cap)

    def free_state_token(self):
        """Hashable token that is equal between two policy states iff
        ``allocate`` is guaranteed to give the same success/failure for the
        same spec in both — what the scheduler's negative-probe memo
        compares.  ``None`` (the default) tells the scheduler to fall back
        to its own placement-mutation counter (exact but never matches
        across state changes); policies with canonical state (vNPU's
        symmetry-normalized free-region key) override this to also match
        across *equivalent* pools."""
        return None

    def utilization(self) -> float:
        raise NotImplementedError

    # -- shared ------------------------------------------------------------
    def free_cores(self) -> Set[int]:
        raise NotImplementedError

    def migration_cycles(self, placement: Placement,
                         weight_bytes: int, hbm_bytes_per_cycle: float) -> int:
        """Pause charged for one live migration: scratchpad re-warm from HBM
        (the RTT — global-memory contents — is preserved, so no data copy)
        plus routing-table reconfiguration (Fig. 11 model)."""
        warm = int(weight_bytes / max(hbm_bytes_per_cycle, 1e-9))
        return warm + rt_config_cost(placement.n_cores)["total_cycles"]

    def _register(self, p: Placement) -> Placement:
        self.placements[p.tid] = p
        return p

    def _unregister(self, p: Placement) -> None:
        self.placements.pop(p.tid, None)


class VNPUPolicy(PlacementPolicy):
    """The paper's hypervisor behind the placement protocol.

    Placement runs through the hypervisor's
    :class:`~repro.core.engine.MappingEngine`; ``mapper`` selects the
    speed/accuracy strategy (hybrid default, or exact / bipartite / rect),
    and ``engine_counters`` exposes the engine's cache hit/miss telemetry
    to the scheduler metrics.
    """

    name = "vnpu"

    def __init__(self, topo: Topology, hbm_bytes: int = 1 << 36,
                 hypervisor: Optional[Hypervisor] = None,
                 require_connected: bool = False,
                 mapper: Optional[str] = None,
                 heat_aware: bool = False):
        super().__init__(topo)
        self.hyp = hypervisor or Hypervisor(topo, hbm_bytes=hbm_bytes)
        self.require_connected = require_connected
        self.mapper = mapper
        # link-heatmap-aware admission (opt in): the scheduler binds the
        # InterferenceLedger so equal-TED placements prefer cold-boundary
        # regions; with the flag off nothing is bound and placement is
        # bit-identical to the historical behavior
        self.heat_aware = heat_aware
        self._shape_keys: Dict[int, Tuple] = {}   # n_cores -> canonical key

    def bind_link_heat(self, ledger) -> None:
        """Feed the MappingEngine live per-directed-link occupancy (called
        by the scheduler when ``heat_aware`` is set and a ledger exists).
        The engine snapshots the dict per ``map_request``; the ledger
        mutates it in place, so a bound method closing over the ledger
        stays current with zero copying."""
        self.hyp.engine.heat_fn = lambda: ledger.link_loads

    def _request(self, spec: TenantSpec, strict: bool) -> VNPURequest:
        """Translate a tenant spec into the hypervisor's request form (the
        most-square mesh of ``n_cores``; connectivity required iff strict)."""
        return VNPURequest(
            topology=mesh_2d(*best_rect(spec.n_cores), base_id=10_000),
            memory_bytes=spec.memory_bytes,
            bandwidth_cap=spec.bandwidth_cap,
            require_connected=strict or self.require_connected,
            mapper=self.mapper)

    def allocate(self, spec: TenantSpec, strict: bool = False) -> Placement:
        """Place through the MappingEngine (cached minTopologyEditDistance
        over the free components — typically a cache hit after a
        ``can_place`` probe); raises :class:`AllocationError` when no
        candidate of the right size exists."""
        vnpu = self.hyp.create_vnpu(self._request(spec, strict))
        return self._register(Placement(
            tid=spec.tid, cores=tuple(sorted(vnpu.p_cores)),
            comm="dataflow", handle=vnpu.vmid, vnpu=vnpu))

    def can_place(self, spec: TenantSpec, strict: bool = False) -> bool:
        if len(self.hyp.free_cores()) < spec.n_cores:
            return False
        if not (strict or self.require_connected):
            return True
        # probe through the engine — the solve is cached, so the allocate
        # that typically follows a successful probe is a cache hit
        return self.hyp.can_allocate(self._request(spec, strict))

    def mark_failed(self, cores: Sequence[int]) -> None:
        """Quarantine dead cores in the hypervisor: they leave the free
        pool until repaired, even after their tenant migrates away or is
        destroyed."""
        self.hyp.mark_failed(cores)

    def mark_repaired(self, cores: Sequence[int]) -> None:
        """Un-quarantine repaired cores (unowned ones rejoin the engine's
        free regions immediately; owned ones at their tenant's release)."""
        self.hyp.mark_repaired(cores)

    def engine_counters(self) -> Dict[str, float]:
        """MappingEngine telemetry snapshot (cache hits/misses, escalations,
        region ops) — surfaced into :class:`ClusterMetrics`."""
        return self.hyp.engine.counters()

    def free_state_token(self):
        """(canonical free-shape id, buddy free-size multiset): equal
        tokens guarantee identical ``allocate`` success/failure — mapping
        feasibility is a function of the free-region shapes (strict:
        a big-enough component exists; relaxed: enough free cores) and
        memory feasibility of the buddy's free-size multiset alone."""
        return (self.hyp.engine.free_state_id(), self.hyp.buddy.state_key())

    def request_key(self, spec: TenantSpec) -> Tuple:
        """Probe-memo key refined with the *request canonical shape*: the
        translation-normalized signature of the topology ``allocate``
        would build (the same ``req_sig.key`` the engine's TED cache
        addresses by).  For today's ``best_rect`` requests this is a
        function of ``n_cores``, but a future heterogeneous-topology
        request with an equal size class would mint a distinct key instead
        of aliasing the memo (ROADMAP fast-path follow-up)."""
        shape = self._shape_keys.get(spec.n_cores)
        if shape is None:
            from ..core.engine.regions import component_signature
            t = mesh_2d(*best_rect(spec.n_cores), base_id=10_000)
            shape = component_signature(t, t.node_attrs, t._adj(),
                                        symmetry=False).key
            self._shape_keys[spec.n_cores] = shape
        return (shape, spec.memory_bytes, spec.bandwidth_cap)

    def resize(self, placement: Placement,
               new_n_cores: int) -> Tuple[Placement, bool]:
        """Elastic grow/shrink through ``Hypervisor.resize_vnpu`` (the
        remap machinery with the tenant's own cores counted free); memory
        (RTT) is preserved.  ``moved=False`` when no sub-topology of the
        new size exists — the tenant keeps running unchanged."""
        if new_n_cores == placement.n_cores:
            return placement, False
        topo_req = mesh_2d(*best_rect(new_n_cores), base_id=10_000)
        try:
            vnpu = self.hyp.resize_vnpu(
                placement.handle, topo_req,
                node_match=mem_dist_node_match(0.5))
        except AllocationError:
            return placement, False
        new = dataclasses.replace(
            placement, cores=tuple(sorted(vnpu.p_cores)), vnpu=vnpu)
        return self._register(new), True

    def release(self, placement: Placement) -> None:
        """Destroy the vNPU: cores rejoin the free set (O(component) region
        merge in the engine), routing-table entries are removed."""
        self.hyp.destroy_vnpu(placement.handle)
        self._unregister(placement)

    def migrate(self, placement: Placement,
                avoid: Sequence[int] = ()) -> Tuple[Placement, bool]:
        """Live migration via ``Hypervisor.migrate_vnpu`` (remap with the
        tenant's own cores counted free, ``avoid`` advisory — see
        ``mark_failed`` for dead hardware).  Returns ``(placement, moved)``;
        never raises on an unplaceable move — reports ``moved=False``."""
        try:
            vnpu, moved = self.hyp.migrate_vnpu(
                placement.handle, node_match=mem_dist_node_match(0.5),
                avoid=avoid)
        except AllocationError:
            return placement, False
        if not moved:
            return placement, False
        new = dataclasses.replace(
            placement, cores=tuple(sorted(vnpu.p_cores)), vnpu=vnpu)
        return self._register(new), True

    def utilization(self) -> float:
        """Allocated / healthy (non-quarantined) cores, in [0, 1]."""
        return self.hyp.utilization()

    def free_cores(self) -> Set[int]:
        """Currently allocatable physical core ids (engine-derived)."""
        return self.hyp.free_cores()


class MIGPolicy(PlacementPolicy):
    """Fixed partitions; the whole partition is held whatever the request."""

    name = "mig"

    def __init__(self, topo: Topology,
                 partition_shapes: Sequence[Tuple[int, int]] = ()):
        super().__init__(topo)
        if not partition_shapes:
            shape = topo.is_rect_mesh()
            if shape is None:
                raise ValueError("MIG policy requires a rectangular mesh")
            r, c = shape
            # default carve: quadrants (the finest square MIG slicing)
            partition_shapes = [(r - r // 2, c - c // 2), (r - r // 2, c // 2),
                                (r // 2, c - c // 2), (r // 2, c // 2)]
            partition_shapes = [(a, b) for a, b in partition_shapes
                                if a > 0 and b > 0]
        self.mig = MIGPartitioner(topo, partition_shapes)

    def allocate(self, spec: TenantSpec, strict: bool = False) -> Placement:
        """Claim the best-fitting free partition (O(partitions)); when the
        request exceeds every free partition, the largest one is
        time-shared (TDM): ``time_share < 1`` and ``tdm_physical`` carry
        the oversubscription to the simulator."""
        part, share = self.mig.allocate(spec.n_cores)
        pcores = sorted(part.cores)
        if share >= 1.0:
            cores = tuple(pcores[: spec.n_cores])
            tdm = None
        else:
            # oversubscribed: spec.n_cores virtual cores time-share the
            # partition's physical cores round-robin
            cores = tuple(itertools.islice(itertools.cycle(pcores),
                                           spec.n_cores))
            tdm = len(pcores)
        return self._register(Placement(
            tid=spec.tid, cores=cores, time_share=share, comm="dataflow",
            tdm_physical=tdm, handle=part.pid))

    def can_place(self, spec: TenantSpec, strict: bool = False) -> bool:
        # TDM makes any free healthy partition admissible, whatever the
        # request
        return any(p.occupied_by is None and not p.failed
                   for p in self.mig.partitions)

    def mark_failed(self, cores: Sequence[int]) -> None:
        """Dead cores poison their whole partition (MIG has no finer
        quarantine granularity): it is not allocated again until every
        dead core inside it is repaired."""
        self.mig.mark_failed(cores)

    def mark_repaired(self, cores: Sequence[int]) -> None:
        """Un-poison partitions whose dead cores have all come back."""
        self.mig.mark_repaired(cores)

    def migrate(self, placement: Placement,
                avoid: Sequence[int] = ()) -> Tuple[Placement, bool]:
        """MIG cannot defragment (a partition is a partition), but it *can*
        evacuate: when ``avoid`` overlaps the tenant's cores (the failure
        path), re-allocate the same virtual-core count on another free
        healthy partition.  Returns ``moved=False`` when none exists."""
        if not set(avoid) & set(placement.cores):
            return placement, False
        probe = TenantSpec(tid=placement.tid, model="", arrival_s=0.0,
                           duration_s=0.0, n_cores=len(placement.cores))
        try:
            new = self.allocate(probe)
        except AllocationError:
            return placement, False
        self.mig.release(placement.handle)
        return new, True

    def release(self, placement: Placement) -> None:
        """Return the whole partition (MIG holds it regardless of how many
        cores the tenant actually used)."""
        self.mig.release(placement.handle)
        self._unregister(placement)

    def utilization(self) -> float:
        """*Useful* cores / total: an occupied partition contributes only
        the cores its tenant requested — internal fragmentation shows."""
        return self.mig.utilization()

    def free_cores(self) -> Set[int]:
        """Cores of currently unoccupied partitions."""
        return self.mig.free_cores()


class UVMPolicy(PlacementPolicy):
    """Topology-blind allocation; all cross-core traffic rides shared HBM."""

    name = "uvm"

    def __init__(self, topo: Topology):
        super().__init__(topo)
        self.uvm = UVMAllocator(topo)

    def allocate(self, spec: TenantSpec, strict: bool = False) -> Placement:
        """Any ``n_cores`` free cores, topology ignored (O(free set)); all
        inter-core traffic is marked as shared-HBM (``hbm_client``)."""
        cores = self.uvm.allocate(spec.n_cores)
        return self._register(Placement(
            tid=spec.tid, cores=tuple(sorted(cores)), comm="uvm",
            hbm_client=True, handle=cores))

    def release(self, placement: Placement) -> None:
        """Free the exact allocated cores."""
        self.uvm.release(placement.handle)
        self._unregister(placement)

    def mark_failed(self, cores: Sequence[int]) -> None:
        """Quarantine dead cores until repaired."""
        self.uvm.mark_failed(cores)

    def mark_repaired(self, cores: Sequence[int]) -> None:
        """Lift the quarantine: repaired unowned cores are free again."""
        self.uvm.mark_repaired(cores)

    def migrate(self, placement: Placement,
                avoid: Sequence[int] = ()) -> Tuple[Placement, bool]:
        """Topology-blind, so defragmentation is pointless (``avoid``
        disjoint from the tenant: not moved) — but evacuation is not: cores
        in ``avoid`` that the tenant owns are swapped for free ones when
        available (callers on the failure path ``mark_failed`` first, which
        keeps the dead cores out of the replacement pick)."""
        bad = set(avoid) & set(placement.cores)
        if not bad:
            return placement, False
        try:
            repl = self.uvm.allocate(len(bad))
        except AllocationError:
            return placement, False
        self.uvm.release(bad)
        cores = frozenset(set(placement.cores) - bad) | repl
        new = dataclasses.replace(placement, cores=tuple(sorted(cores)),
                                  handle=cores)
        return self._register(new), True

    def resize(self, placement: Placement,
               new_n_cores: int) -> Tuple[Placement, bool]:
        """Topology-blind elastic resize: grow takes any free cores,
        shrink releases the highest-numbered ones (allocations are exact
        sets, so either direction is O(delta))."""
        cur = set(placement.cores)
        delta = new_n_cores - len(cur)
        if delta == 0:
            return placement, False
        if delta > 0:
            try:
                extra = self.uvm.allocate(delta)
            except AllocationError:
                return placement, False
            cores = frozenset(cur | set(extra))
        else:
            if new_n_cores < 1:
                return placement, False
            drop = set(sorted(cur)[new_n_cores:])
            self.uvm.release(drop)
            cores = frozenset(cur - drop)
        new = dataclasses.replace(placement, cores=tuple(sorted(cores)),
                                  handle=cores)
        return self._register(new), True

    def utilization(self) -> float:
        """Allocated / total cores, in [0, 1] (allocations are exact)."""
        return self.uvm.utilization()

    def free_cores(self) -> Set[int]:
        """Currently unallocated physical core ids."""
        return self.uvm.free_cores()


POLICIES = {
    "vnpu": VNPUPolicy,
    "mig": MIGPolicy,
    "uvm": UVMPolicy,
}


def make_policy(name: str, topo: Topology, **kwargs) -> PlacementPolicy:
    """Instantiate a registered policy (``vnpu`` / ``mig`` / ``uvm``) over
    ``topo``; extra kwargs go to the policy constructor."""
    try:
        cls = POLICIES[name]
    except KeyError:
        raise KeyError(f"unknown policy {name!r}; have {sorted(POLICIES)}")
    return cls(topo, **kwargs)
