"""The interference ledger: incremental cross-tenant occupancy accounting.

The paper's multi-tenant results (§6.3) score each vNPU against the NoC and
HBM traffic of its *actual* co-residents.  The reference implementation
(:meth:`~repro.sched.cluster.ClusterScheduler._rescore`) re-derives that
context from scratch — every resident re-lists every other resident's flows
and re-paths them, O(residents^2 x flows) per scoring pass — which
ROADMAP.md identified as the pod-scale wall-time bottleneck once PR 2 made
placement itself cheap.

:class:`InterferenceLedger` replaces the recompute with bookkeeping that is
maintained *incrementally* on every tenant lifecycle event
(allocate / release / migrate / fail):

* **link occupancy** — the aggregate bytes/iteration each *directed* NoC
  link carries, summed over all resident tenants' flows
  (:func:`repro.core.simulator.flow_link_loads`).  Loads are integer-valued
  floats, so addition and subtraction are exact and order-independent —
  the ledger's totals are bit-identical to a from-scratch aggregation.
* **per-tenant footprints** — which links each tenant's flows touch and
  with how many bytes.  A tenant's *external* load on a link is simply
  ``total - own`` (exact), which is what the simulator's
  ``external_link_loads`` fast path consumes.
* **HBM clients** — how many residents synchronize through global memory
  (``Placement.hbm_client``); the simulator's ``hbm_concurrency`` input.

On each mutation the ledger computes the **dirty set**: the tenants whose
score could have changed.  A tenant is dirtied when

1. its own placement changed (it is the subject of the event);
2. the occupancy of a link in its footprint changed (another tenant's
   flows appeared on / disappeared from a link it uses);
3. the number of co-residents *with flows* crossed the 0/1 boundary from
   its perspective — the tensor-parallel model only computes ring
   (self-)contention when external traffic exists, so that boolean flip
   changes scores even across disjoint links;
4. the HBM-client count changed — ``hbm_concurrency`` feeds every
   simulator call (conservatively dirties everyone).

Everything else keeps its cached :class:`~repro.core.simulator.RunReport`.
The scheduler re-simulates only the dirty set, making an epoch scoring
pass O(dirty x own flows) instead of O(residents^2 x flows) — measured by
``benchmarks/cluster_sim.py --gate`` and pinned bit-identical to the
oracle by ``tests/test_ledger.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Sequence, Set, Tuple

from ..core import simulator as S
from ..core.simulator import Flow
from ..core.topology import Topology

Edge = Tuple[int, int]            # directed NoC link (src core id, dst core id)


@dataclasses.dataclass
class LedgerCounters:
    """Telemetry for one scheduler run (all counts are event/tenant counts,
    not times; the scheduler records pass wall-times separately)."""
    adds: int = 0                 # tenants added (admissions)
    removes: int = 0              # tenants removed (departures)
    updates: int = 0              # in-place footprint swaps (migrations)
    tenants_dirtied: int = 0      # dirty-set insertions, cumulative
    global_invalidations: int = 0  # dirty-all events (HBM / 0-1 boundary)
    rescored: int = 0             # tenants re-simulated by scoring passes
    reused: int = 0               # tenant scores served from cache

    @property
    def reuse_rate(self) -> float:
        total = self.rescored + self.reused
        return self.reused / total if total else 0.0

    def as_dict(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        d["reuse_rate"] = round(self.reuse_rate, 4)
        return d


class InterferenceLedger:
    """Per-link / per-HBM-port occupancy, maintained incrementally.

    All mutators are O(footprint links) plus the dirty bookkeeping; queries
    are O(links currently loaded).  The ledger never calls the simulator —
    it only decides *who* must be re-simulated and supplies the aggregated
    ``external_link_loads`` input.
    """

    def __init__(self, topo: Topology):
        self.topo = topo
        #: aggregate bytes/iteration per directed link, all tenants summed
        self.link_loads: Dict[Edge, float] = {}
        self._footprints: Dict[int, Dict[Edge, float]] = {}
        self._edge_tenants: Dict[Edge, Set[int]] = {}
        #: tenants whose flow *list* is non-empty (not "has link edges":
        #: a TDM flow between co-located virtual cores has no edges but
        #: still flips the tensor model's external-traffic switch)
        self._flow_tenants: Set[int] = set()
        self._hbm: Set[int] = set()
        self.dirty: Set[int] = set()
        self.counters = LedgerCounters()

    # -- introspection -------------------------------------------------------
    @property
    def hbm_clients(self) -> int:
        """Resident tenants synchronizing through global memory — the
        simulator's ``hbm_concurrency`` (a count, not a bandwidth)."""
        return len(self._hbm)

    def tenants(self) -> Set[int]:
        return set(self._footprints)

    def footprint(self, tid: int) -> Dict[Edge, float]:
        """The tenant's own per-link loads (bytes/iteration), as recorded."""
        return dict(self._footprints.get(tid, {}))

    def has_external(self, tid: int) -> bool:
        """Does any *other* resident inject NoC flows?  Mirrors the oracle's
        ``external_flows`` list truthiness — the tensor model's contention
        switch — so the ledger path stays bit-identical."""
        other = self._flow_tenants - {tid}
        return bool(other)

    def external_loads(self, tid: int) -> Dict[Edge, float]:
        """Per-link loads every tenant but ``tid`` injects (bytes/iter).

        Exact ``total - own`` per link (integer-valued floats), pruned of
        zero entries; O(loaded links).
        """
        own = self._footprints.get(tid, {})
        out: Dict[Edge, float] = {}
        for e, total in self.link_loads.items():
            ext = total - own.get(e, 0.0)
            if ext:
                out[e] = ext
        return out

    # -- lifecycle mutators --------------------------------------------------
    def add(self, tid: int, flows: Sequence[Flow],
            hbm_client: bool = False) -> None:
        """A tenant was placed (admission): record its footprint, dirty it
        and every resident whose links it loads."""
        if tid in self._footprints:
            raise ValueError(f"tenant {tid} already in ledger")
        self.counters.adds += 1
        fp = S.flow_link_loads(self.topo, flows)
        # boundary flip: the previously-lone flow tenant gains external
        # traffic (rule 3 in the module docstring)
        if flows and len(self._flow_tenants) == 1:
            self._mark_dirty(self._flow_tenants)
        for e, v in fp.items():
            self._mark_dirty(self._edge_tenants.get(e, ()))
            self.link_loads[e] = self.link_loads.get(e, 0.0) + v
            self._edge_tenants.setdefault(e, set()).add(tid)
        self._footprints[tid] = fp
        if flows:
            self._flow_tenants.add(tid)
        self._mark_dirty((tid,))
        if hbm_client:
            self._hbm.add(tid)
            self._dirty_all()     # hbm_concurrency feeds every score

    def remove(self, tid: int) -> None:
        """A tenant departed: subtract its footprint, dirty the residents
        that shared its links, forget it."""
        fp = self._footprints.pop(tid, None)
        if fp is None:
            return
        self.counters.removes += 1
        had_flows = tid in self._flow_tenants
        for e, v in fp.items():
            remaining = self.link_loads[e] - v       # exact (integer floats)
            if remaining:
                self.link_loads[e] = remaining
            else:
                del self.link_loads[e]
            owners = self._edge_tenants.get(e)
            if owners is not None:
                owners.discard(tid)
                if not owners:
                    del self._edge_tenants[e]
                else:
                    self._mark_dirty(owners)
        self._flow_tenants.discard(tid)
        self.dirty.discard(tid)
        # boundary flip: the now-lone flow tenant loses all external
        # traffic — only possible if the departed tenant *had* flows
        if had_flows and len(self._flow_tenants) == 1:
            self._mark_dirty(self._flow_tenants)
        if tid in self._hbm:
            self._hbm.discard(tid)
            self._dirty_all()

    def update(self, tid: int, flows: Sequence[Flow],
               hbm_client: bool = False) -> None:
        """A tenant moved (defrag migration / failure remap): swap its
        footprint.  Composed remove+add, so both the vacated and the newly
        loaded links dirty their tenants.  Raises for an unknown tenant
        (mirroring :meth:`add` on a duplicate)."""
        if tid not in self._footprints:
            raise ValueError(f"tenant {tid} not in ledger")
        self.remove(tid)
        self.add(tid, flows, hbm_client=hbm_client)
        self.counters.updates += 1
        self.counters.adds -= 1
        self.counters.removes -= 1

    # -- dirty-set protocol --------------------------------------------------
    def take_dirty(self) -> List[int]:
        """Drain the dirty set (sorted for deterministic replay)."""
        out = sorted(self.dirty)
        self.dirty.clear()
        return out

    def _mark_dirty(self, tids: Iterable[int]) -> None:
        for t in tids:
            if t not in self.dirty:
                self.dirty.add(t)
                self.counters.tenants_dirtied += 1

    def _dirty_all(self) -> None:
        self.counters.global_invalidations += 1
        self._mark_dirty(self._footprints)

    def invalidate_all(self) -> None:
        """External context change the ledger cannot see link-by-link (a
        NoC link failed, degraded or was repaired): every resident's
        contention context is stale, so mark them all for re-simulation."""
        self._dirty_all()

    # -- verification (tests / --gate) ---------------------------------------
    def oracle_link_loads(self, flows_by_tid: Dict[int, Sequence[Flow]]
                          ) -> Dict[Edge, float]:
        """From-scratch aggregate of the given per-tenant flows — what
        ``link_loads`` must equal after any event sequence (exactly: loads
        are integer-valued, so no tolerance is needed)."""
        return S.flow_link_loads(
            self.topo, [f for flows in flows_by_tid.values() for f in flows])

    def check_invariants(self) -> None:
        """Test hook: totals equal the sum of footprints; edge index and
        flow-tenant set are consistent."""
        totals: Dict[Edge, float] = {}
        for tid, fp in self._footprints.items():
            for e, v in fp.items():
                totals[e] = totals.get(e, 0.0) + v
                assert tid in self._edge_tenants.get(e, set())
        totals = {e: v for e, v in totals.items() if v}
        assert totals == self.link_loads, "ledger totals drifted"
        for e, owners in self._edge_tenants.items():
            assert owners, f"empty owner set for link {e}"
            for t in owners:
                assert e in self._footprints.get(t, {}), (e, t)
        assert self._flow_tenants <= set(self._footprints)
        assert self._hbm <= set(self._footprints)
