"""Event-driven multi-tenant cluster scheduling over one NPU mesh.

The paper's §6.3 claims are about *dynamics* — utilization and per-tenant
throughput as vNPUs arrive, depart and fragment the mesh.  This package
turns the static allocators of :mod:`repro.core` into a schedulable system:

* :mod:`repro.sched.events`  — tenant specs, the time-ordered event queue;
* :mod:`repro.sched.policy`  — the ``PlacementPolicy`` protocol and its
  three implementations (vNPU / MIG / UVM) over the core allocators;
* :mod:`repro.sched.traces`  — Poisson / named arrival traces drawn from
  the workload registry and the model-config catalog;
* :mod:`repro.sched.ledger`  — the :class:`InterferenceLedger`: per-link /
  per-HBM-port occupancy maintained incrementally across tenant lifecycle
  events, so epoch scoring re-simulates only the tenants whose
  interference context changed;
* :mod:`repro.sched.cluster` — the event loop: admission control with
  queueing, best-effort defragmentation via live migration, failure
  injection, and per-epoch scoring through :mod:`repro.core.simulator`
  with cross-tenant interference wired from the actual co-residents
  (through the ledger by default; ``rescore="oracle"`` selects the
  reference recompute).

See ``docs/architecture.md`` for the end-to-end tour of this stack.
"""
from .events import Event, EventQueue, TenantSpec
from .ledger import InterferenceLedger, LedgerCounters
from .policy import (MIGPolicy, Placement, PlacementPolicy, UVMPolicy,
                     VNPUPolicy, make_policy)
from .traces import TraceConfig, make_trace, poisson_trace, TRACES
from .cluster import (ClusterMetrics, ClusterScheduler, EpochSample,
                      RecoveryConfig, ServingConfig, compare_policies)

__all__ = [
    "Event", "EventQueue", "TenantSpec",
    "InterferenceLedger", "LedgerCounters",
    "Placement", "PlacementPolicy", "VNPUPolicy", "MIGPolicy", "UVMPolicy",
    "make_policy",
    "TraceConfig", "make_trace", "poisson_trace", "TRACES",
    "ClusterMetrics", "ClusterScheduler", "EpochSample", "RecoveryConfig",
    "ServingConfig", "compare_policies",
]
