"""Tenant specs and the time-ordered event queue for the cluster loop.

Time is wall-clock seconds (floats); the simulator converts per-iteration
cycles to throughput at ``HWConfig.freq_hz``.  Events at equal timestamps
are ordered departure < epoch < arrival (then insertion order), so a
departure at the same instant as an arrival frees its cores first — the
scheduler relies on this for back-to-back core reuse.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from typing import Iterator, Optional

ARRIVAL = "arrival"
DEPARTURE = "departure"
EPOCH = "epoch"
FAILURE = "failure"               # dead cores: quarantine + migrate residents
RESIZE = "resize"                 # elastic vNPU grow/shrink (serving plane)

# same-timestamp processing order: free cores, then fail hardware, then
# observe, then admit, then resize — a departure at the same instant as a
# failure frees its cores before the quarantine, an arrival sees the
# post-failure mesh, and a RESIZE pushed by an epoch's pressure check runs
# after that instant's admissions so growth never races a same-tick
# arrival for cores
_KIND_PRIORITY = {DEPARTURE: 0, FAILURE: 1, EPOCH: 2, ARRIVAL: 3, RESIZE: 4}


@dataclasses.dataclass
class TenantSpec:
    """What one tenant asks of the cluster: a model, cores, and an SLA.

    ``model`` names a workload graph (``repro.core.workloads.REGISTRY`` or
    a config-derived serving model from :mod:`repro.sched.traces`).
    ``sla_wait_s`` is the admission SLA: the tenant abandons the queue (a
    rejected request) if not placed within that long of arriving.
    """
    tid: int
    model: str
    n_cores: int
    arrival_s: float
    duration_s: float
    memory_bytes: int = 64 << 20
    bandwidth_cap: Optional[int] = None
    sla_wait_s: float = math.inf


@dataclasses.dataclass(order=True)
class Event:
    """One scheduled occurrence.  ``time`` is wall-clock seconds; the
    payload fields per kind: ``spec`` (arrival), ``tid`` (departure),
    ``cores`` (failure — the physical core ids that died) or
    ``tid`` + ``n_cores`` (resize — the elastic target size)."""
    time: float
    priority: int
    seq: int
    kind: str = dataclasses.field(compare=False)
    spec: Optional[TenantSpec] = dataclasses.field(compare=False, default=None)
    tid: Optional[int] = dataclasses.field(compare=False, default=None)
    cores: Optional[tuple] = dataclasses.field(compare=False, default=None)
    n_cores: Optional[int] = dataclasses.field(compare=False, default=None)


class EventQueue:
    """A heap of events ordered by (time, kind priority, insertion seq).
    ``push``/``pop`` are O(log n); ``peek`` is O(1)."""

    def __init__(self) -> None:
        self._heap: list[Event] = []
        self._seq = itertools.count()

    def push(self, time: float, kind: str,
             spec: Optional[TenantSpec] = None,
             tid: Optional[int] = None,
             cores: Optional[tuple] = None,
             n_cores: Optional[int] = None) -> Event:
        """Schedule ``kind`` at ``time`` (seconds) with its payload."""
        ev = Event(time=time, priority=_KIND_PRIORITY.get(kind, 9),
                   seq=next(self._seq), kind=kind, spec=spec, tid=tid,
                   cores=cores, n_cores=n_cores)
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> Event:
        """Remove and return the earliest event."""
        return heapq.heappop(self._heap)

    def peek(self) -> Optional[Event]:
        """The earliest event without removing it (None when empty)."""
        return self._heap[0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)

    def drain(self) -> Iterator[Event]:
        """Pop every event in time order (consumes the queue)."""
        while self._heap:
            yield self.pop()
