"""Tenant specs and the time-ordered event queue for the cluster loop.

Time is wall-clock seconds (floats); the simulator converts per-iteration
cycles to throughput at ``HWConfig.freq_hz``.  Events at equal timestamps
are ordered departure < epoch < arrival (then insertion order), so a
departure at the same instant as an arrival frees its cores first — the
scheduler relies on this for back-to-back core reuse.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from typing import Iterator, Optional

ARRIVAL = "arrival"
DEPARTURE = "departure"
EPOCH = "epoch"
FAILURE = "failure"               # dead cores: quarantine + migrate residents
REPAIR = "repair"                 # repaired cores rejoin the free pool
LINK_FAIL = "link-fail"           # directed NoC link outage (re-costed)
LINK_DEGRADE = "link-degrade"     # directed NoC link straggler (bandwidth x1/f)
LINK_REPAIR = "link-repair"       # degraded/failed link back to full speed
RESIZE = "resize"                 # elastic vNPU grow/shrink (serving plane)

# same-timestamp processing order: free cores, then repair hardware, then
# fail hardware, then settle links, then observe, then admit, then resize —
# a departure at the same instant as a failure frees its cores before the
# quarantine, a repair returns capacity before a same-tick arrival asks for
# it, an arrival sees the post-failure mesh, and a RESIZE pushed by an
# epoch's pressure check runs after that instant's admissions so growth
# never races a same-tick arrival for cores.  Only the *relative* order of
# kinds matters (priority breaks same-timestamp ties), so inserting the
# chaos kinds leaves every fault-free trajectory bit-identical.
_KIND_PRIORITY = {DEPARTURE: 0, REPAIR: 1, FAILURE: 2, LINK_REPAIR: 3,
                  LINK_FAIL: 4, LINK_DEGRADE: 5, EPOCH: 6, ARRIVAL: 7,
                  RESIZE: 8}


@dataclasses.dataclass
class TenantSpec:
    """What one tenant asks of the cluster: a model, cores, and an SLA.

    ``model`` names a workload graph (``repro.core.workloads.REGISTRY`` or
    a config-derived serving model from :mod:`repro.sched.traces`).
    ``sla_wait_s`` is the admission SLA: the tenant abandons the queue (a
    rejected request) if not placed within that long of arriving.
    ``tenant_class`` selects the fault-recovery path: ``"train"`` tenants
    killed by a fault resume from their last periodic checkpoint (restore
    pause charged), anything else re-admits through the bounded-backoff
    retry queue.
    """
    tid: int
    model: str
    n_cores: int
    arrival_s: float
    duration_s: float
    memory_bytes: int = 64 << 20
    bandwidth_cap: Optional[int] = None
    sla_wait_s: float = math.inf
    tenant_class: str = "serve"


@dataclasses.dataclass(order=True)
class Event:
    """One scheduled occurrence.  ``time`` is wall-clock seconds; the
    payload fields per kind: ``spec`` (arrival), ``tid`` (departure),
    ``cores`` (failure/repair — the physical core ids that died or came
    back), ``tid`` + ``n_cores`` (resize — the elastic target size) or
    ``link`` + ``factor`` (link fault — a directed NoC edge and its
    bandwidth-degradation factor)."""
    time: float
    priority: int
    seq: int
    kind: str = dataclasses.field(compare=False)
    spec: Optional[TenantSpec] = dataclasses.field(compare=False, default=None)
    tid: Optional[int] = dataclasses.field(compare=False, default=None)
    cores: Optional[tuple] = dataclasses.field(compare=False, default=None)
    n_cores: Optional[int] = dataclasses.field(compare=False, default=None)
    link: Optional[tuple] = dataclasses.field(compare=False, default=None)
    factor: Optional[float] = dataclasses.field(compare=False, default=None)


class EventQueue:
    """A heap of events ordered by (time, kind priority, insertion seq).
    ``push``/``pop`` are O(log n); ``peek`` is O(1)."""

    def __init__(self) -> None:
        self._heap: list[Event] = []
        self._seq = itertools.count()

    def push(self, time: float, kind: str,
             spec: Optional[TenantSpec] = None,
             tid: Optional[int] = None,
             cores: Optional[tuple] = None,
             n_cores: Optional[int] = None,
             link: Optional[tuple] = None,
             factor: Optional[float] = None) -> Event:
        """Schedule ``kind`` at ``time`` (seconds) with its payload."""
        ev = Event(time=time, priority=_KIND_PRIORITY.get(kind, 99),
                   seq=next(self._seq), kind=kind, spec=spec, tid=tid,
                   cores=cores, n_cores=n_cores, link=link, factor=factor)
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> Event:
        """Remove and return the earliest event."""
        return heapq.heappop(self._heap)

    def peek(self) -> Optional[Event]:
        """The earliest event without removing it (None when empty)."""
        return self._heap[0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)

    def drain(self) -> Iterator[Event]:
        """Pop every event in time order (consumes the queue)."""
        while self._heap:
            yield self.pop()
