"""Tenant arrival traces: Poisson and named mixes.

Each trace is a list of :class:`TenantSpec` — model + core count + SLA —
drawn from a catalog that combines the simulator's workload registry
(:mod:`repro.core.workloads`) with serving-model proxies derived from the
real model configs under :mod:`repro.configs` (a config's depth/width/vocab
become a tensor-parallel transformer graph the simulator can score).

Named families (``TRACES``): ``mixed`` / ``small`` / ``large`` /
``bursty`` target the paper's 6x6 SIM config; ``pod-mixed`` carries
pod-matched arrival rates and 2–48-core asks for 16x16–32x32 meshes (the
README table lists rates and intended ``--mesh`` sizes); ``serving`` is
the LLM-only mix for the request-level serving plane (every tenant has a
:mod:`repro.serve.requests` profile and a KV-arena memory grant; intended
mesh 8x8), with ``pod-serving`` the same mix scaled to a 32x32 pod for
the million-request scale gate.  All times are seconds; traces are
deterministic per seed.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import workloads as W
from .events import TenantSpec

# ---------------------------------------------------------------------------
# serving-model proxies from repro.configs
# ---------------------------------------------------------------------------

# arch id -> decode sequence length for the serving proxy graph
_CONFIG_PROXIES: Dict[str, int] = {
    "llama3_2_1b": 512,
    "qwen2_0_5b": 512,
    "qwen2_7b": 256,
}

_GRAPH_CACHE: Dict[str, W.WorkloadGraph] = {}


def _config_graph(arch: str, seq: int) -> W.WorkloadGraph:
    """Build a tensor-parallel transformer graph from a ModelConfig's
    published dimensions.  The ``transformer_`` name prefix routes it to the
    simulator's tensor-parallel execution model."""
    from ..configs import get_config

    cfg = get_config(arch)
    d_ff_mult = max(1, round(cfg.d_ff / cfg.d_model))
    return W._transformer(f"transformer_{arch}", cfg.n_layers, cfg.d_model,
                          seq, d_ff_mult=d_ff_mult, vocab=cfg.vocab_size)


def get_serving_workload(name: str) -> W.WorkloadGraph:
    """Workload registry + config proxies, cached (graphs are immutable
    inputs to the analytic simulator)."""
    g = _GRAPH_CACHE.get(name)
    if g is None:
        if name in _CONFIG_PROXIES:
            g = _config_graph(name, _CONFIG_PROXIES[name])
        else:
            g = W.get_workload(name)
        _GRAPH_CACHE[name] = g
    return g


# ---------------------------------------------------------------------------
# catalog + trace config
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CatalogEntry:
    """One tenant class: which model, how many cores it may ask for, its
    admission SLA, and its sampling weight in the mix.
    ``extra_memory_bytes`` is added on top of the model's weight footprint
    (the serving catalog grants each LLM tenant its KV arena this way)."""
    model: str
    cores: Tuple[int, ...]
    sla_wait_s: float = 30.0
    weight: float = 1.0
    extra_memory_bytes: int = 0


# The mixed cloud catalog: small CNN inference, mid-size detection,
# LLM serving from the config registry, and big batch transformers.
MIXED_CATALOG: Tuple[CatalogEntry, ...] = (
    CatalogEntry("yolo_lite", (2, 3), sla_wait_s=10.0, weight=2.0),
    CatalogEntry("mobilenet", (2, 4), sla_wait_s=10.0, weight=2.0),
    CatalogEntry("resnet18", (4, 6), sla_wait_s=15.0, weight=2.0),
    CatalogEntry("resnet50", (6, 8), sla_wait_s=20.0, weight=1.5),
    CatalogEntry("qwen2_0_5b", (4, 6), sla_wait_s=20.0, weight=1.5),
    CatalogEntry("llama3_2_1b", (8, 9), sla_wait_s=30.0, weight=1.0),
    CatalogEntry("transformer", (6, 8), sla_wait_s=30.0, weight=1.0),
    CatalogEntry("gpt2_small", (12,), sla_wait_s=45.0, weight=0.75),
    CatalogEntry("qwen2_7b", (16,), sla_wait_s=60.0, weight=0.25),
)

SMALL_CATALOG: Tuple[CatalogEntry, ...] = (
    CatalogEntry("yolo_lite", (2,), sla_wait_s=8.0, weight=2.0),
    CatalogEntry("mobilenet", (2, 4), sla_wait_s=8.0, weight=2.0),
    CatalogEntry("resnet18", (4,), sla_wait_s=10.0, weight=1.0),
    CatalogEntry("qwen2_0_5b", (4,), sla_wait_s=12.0, weight=1.0),
)

LARGE_CATALOG: Tuple[CatalogEntry, ...] = (
    CatalogEntry("gpt2_small", (12,), sla_wait_s=60.0, weight=1.0),
    CatalogEntry("gpt2_medium", (18,), sla_wait_s=90.0, weight=0.5),
    CatalogEntry("llama3_2_1b", (9, 12), sla_wait_s=45.0, weight=1.0),
    CatalogEntry("qwen2_7b", (16, 24), sla_wait_s=90.0, weight=0.5),
    CatalogEntry("resnet50", (8, 12), sla_wait_s=30.0, weight=1.0),
)

# Pod-scale mix (256–1024 cores, i.e. --mesh 16,16 to 32,32): the same
# service classes as MIXED but with core asks and an arrival rate matched
# to pods — mean demand ~8.5 cores x 30 s at 2.2 arrivals/s is ~560
# occupied cores in steady state (55% of a 32x32 mesh; an overload/queueing
# stress at 16x16).  This is the trace the ledger's epoch-scoring gate and
# the ROADMAP pod-scale items measure against.
POD_CATALOG: Tuple[CatalogEntry, ...] = (
    CatalogEntry("yolo_lite", (2, 3), sla_wait_s=10.0, weight=2.0),
    CatalogEntry("mobilenet", (2, 4), sla_wait_s=10.0, weight=2.0),
    CatalogEntry("resnet18", (4, 6), sla_wait_s=15.0, weight=2.0),
    CatalogEntry("resnet50", (8, 12), sla_wait_s=20.0, weight=1.5),
    CatalogEntry("qwen2_0_5b", (4, 8), sla_wait_s=20.0, weight=1.5),
    CatalogEntry("llama3_2_1b", (9, 16), sla_wait_s=30.0, weight=1.0),
    CatalogEntry("gpt2_small", (16, 25), sla_wait_s=45.0, weight=0.75),
    CatalogEntry("gpt2_medium", (24, 36), sla_wait_s=60.0, weight=0.5),
    CatalogEntry("qwen2_7b", (32, 48), sla_wait_s=90.0, weight=0.25),
)


def _kv_arena(model: str) -> int:
    """The model's serving KV-arena grant (see repro.serve.requests)."""
    from ..serve.requests import get_profile
    profile = get_profile(model)
    return profile.kv_arena_bytes if profile else 0


# LLM-serving mix for the request-level serving plane (benchmarks/
# serving_sim.py): every tenant has a ServeProfile, asks for its weights
# plus a KV arena, and serves a prefill/decode-mixed request stream
# (chat-style decode-heavy + doc-style prefill-heavy, see
# repro.serve.requests).  Small models dominate the mix (the realistic
# serving population — and the regime where MIG's fixed slices waste
# cores while vNPU packs).  Rates target an 8x8 mesh: mean demand
# ~0.4/s x ~6.5 cores x 35 s ~= 90 demanded cores against 64 — a heavy
# multi-tenant overload (~14 concurrent tenants wanted) that exercises
# queueing, elastic resize, KV pressure, and the regime where a fixed
# 8-slice MIG carve caps concurrency while vNPU keeps packing.
SERVING_CATALOG: Tuple[CatalogEntry, ...] = (
    CatalogEntry("transformer", (2, 3), sla_wait_s=6.0, weight=3.0,
                 extra_memory_bytes=_kv_arena("transformer")),
    CatalogEntry("qwen2_0_5b", (4, 6), sla_wait_s=8.0, weight=3.0,
                 extra_memory_bytes=_kv_arena("qwen2_0_5b")),
    CatalogEntry("llama3_2_1b", (6, 9), sla_wait_s=12.0, weight=1.0,
                 extra_memory_bytes=_kv_arena("llama3_2_1b")),
    CatalogEntry("gpt2_small", (8, 12), sla_wait_s=15.0, weight=0.75,
                 extra_memory_bytes=_kv_arena("gpt2_small")),
    CatalogEntry("gpt2_medium", (12, 16), sla_wait_s=20.0, weight=0.4,
                 extra_memory_bytes=_kv_arena("gpt2_medium")),
    CatalogEntry("qwen2_7b", (16,), sla_wait_s=30.0, weight=0.2,
                 extra_memory_bytes=_kv_arena("qwen2_7b")),
)


@dataclasses.dataclass
class TraceConfig:
    """One named arrival process: a catalog plus Poisson parameters.

    ``horizon_s``/``service_mean_s`` are seconds, ``rate_per_s`` is
    arrivals/second; ``intended_mesh`` documents the physical mesh sizes
    the rates were tuned for (``cluster_sim.py --mesh``).
    """
    name: str = "mixed"
    seed: int = 0
    horizon_s: float = 120.0          # arrivals stop here; departures run on
    rate_per_s: float = 0.45
    service_mean_s: float = 25.0
    catalog: Sequence[CatalogEntry] = MIXED_CATALOG
    # bursty traffic: cycle of (phase_length_s, rate_per_s) overriding
    # rate_per_s when set
    rate_phases: Optional[Sequence[Tuple[float, float]]] = None
    intended_mesh: str = "6x6"        # documentation: mesh the rates target


def poisson_trace(cfg: TraceConfig) -> List[TenantSpec]:
    """Sample a Poisson (or phase-modulated Poisson) arrival process over
    the catalog.  Deterministic for a given seed — every policy in a
    comparison consumes the *same* tenant sequence."""
    rng = np.random.default_rng(cfg.seed)
    weights = np.array([e.weight for e in cfg.catalog], float)
    weights /= weights.sum()

    def rate_at(t: float) -> float:
        if not cfg.rate_phases:
            return cfg.rate_per_s
        cycle = sum(p for p, _ in cfg.rate_phases)
        u = t % cycle
        for phase_len, rate in cfg.rate_phases:
            if u < phase_len:
                return rate
            u -= phase_len
        return cfg.rate_phases[-1][1]

    def next_arrival(t: float) -> float:
        if not cfg.rate_phases:
            return t + float(rng.exponential(1.0 / max(cfg.rate_per_s, 1e-9)))
        # inhomogeneous Poisson via thinning: drawing one gap at the
        # current phase's rate would overrun phase boundaries (a gap drawn
        # in a lull skips the start of the next burst); instead propose at
        # the max phase rate and accept with probability rate(t)/max_rate
        max_rate = max(r for _, r in cfg.rate_phases)
        while True:
            t += float(rng.exponential(1.0 / max(max_rate, 1e-9)))
            if t >= cfg.horizon_s:
                return t
            if rng.random() * max_rate <= rate_at(t):
                return t

    specs: List[TenantSpec] = []
    t = 0.0
    tid = 1
    while True:
        t = next_arrival(t)
        if t >= cfg.horizon_s:
            break
        entry = cfg.catalog[int(rng.choice(len(cfg.catalog), p=weights))]
        n_cores = int(rng.choice(entry.cores))
        duration = float(np.clip(rng.exponential(cfg.service_mean_s),
                                 cfg.service_mean_s * 0.2,
                                 cfg.service_mean_s * 4.0))
        graph = get_serving_workload(entry.model)
        specs.append(TenantSpec(
            tid=tid, model=entry.model, n_cores=n_cores, arrival_s=t,
            duration_s=duration,
            memory_bytes=max(graph.total_weight_bytes, 1 << 20)
            + entry.extra_memory_bytes,
            sla_wait_s=entry.sla_wait_s))
        tid += 1
    return specs


TRACES: Dict[str, TraceConfig] = {
    "mixed": TraceConfig(name="mixed"),
    "small": TraceConfig(name="small", catalog=SMALL_CATALOG,
                         rate_per_s=0.9, service_mean_s=15.0),
    "large": TraceConfig(name="large", catalog=LARGE_CATALOG,
                         rate_per_s=0.15, service_mean_s=40.0),
    "bursty": TraceConfig(name="bursty",
                          rate_phases=((20.0, 1.2), (20.0, 0.1))),
    "pod-mixed": TraceConfig(name="pod-mixed", catalog=POD_CATALOG,
                             rate_per_s=2.2, service_mean_s=30.0,
                             horizon_s=90.0,
                             intended_mesh="16x16-32x32"),
    "serving": TraceConfig(name="serving", catalog=SERVING_CATALOG,
                           rate_per_s=0.4, service_mean_s=35.0,
                           horizon_s=120.0, intended_mesh="8x8"),
    # The million-request pod trace: the serving mix scaled to a 32x32
    # pod (1024 cores) at the same ~140% core-demand overload as the 8x8
    # gate (6.4/s x ~6.5 cores x 35 s ~= 1456 demanded).  With the
    # request streams scaled up (ServingConfig.rate_scale, see
    # benchmarks/serving_sim.py --scale-gate) this drives >1M requests
    # through the vectorized plane inside the CI wall budget.
    "pod-serving": TraceConfig(name="pod-serving", catalog=SERVING_CATALOG,
                               rate_per_s=6.4, service_mean_s=35.0,
                               horizon_s=300.0, intended_mesh="32x32"),
    # The fleet arrival stream: one global serving-mix Poisson process the
    # FleetRouter splits across pods.  The registered rate is tuned for
    # 8 x 16x16 pods at the pod-serving overload density (1.6/s per 256
    # cores); ``repro.fleet.fleet_trace`` rescales it for other fleet
    # sizes.  benchmarks/fleet_sim.py --gate drives >= 10M aggregate
    # requests through it with the request streams scaled up.
    "fleet-serving": TraceConfig(name="fleet-serving",
                                 catalog=SERVING_CATALOG,
                                 rate_per_s=12.8, service_mean_s=35.0,
                                 horizon_s=300.0,
                                 intended_mesh="8x(16x16)"),
}


def make_trace(name: str, seed: Optional[int] = None,
               horizon_s: Optional[float] = None) -> List[TenantSpec]:
    """Materialize a named trace (optionally overriding seed/horizon).
    O(rate x horizon) tenants; deterministic per seed."""
    try:
        cfg = TRACES[name]
    except KeyError:
        raise KeyError(f"unknown trace {name!r}; have {sorted(TRACES)}")
    if seed is not None or horizon_s is not None:
        cfg = dataclasses.replace(
            cfg,
            seed=cfg.seed if seed is None else seed,
            horizon_s=cfg.horizon_s if horizon_s is None else horizon_s)
    return poisson_trace(cfg)
