"""Exact defragmentation planning (the ILP defrag planner).

The scheduler's historical defragmentation is greedy: migrate the
most-scattered residents one at a time (compaction objective) until a
strict placement for the blocked request appears, bounded by
``max_migrations_per_event``.  Greedy picks *which* tenants to move by a
scatter heuristic, so it can pay a large-model migration pause where
moving one small tenant would have unlocked the same placement.

:class:`ILPDefragPlanner` instead asks "which migration *set* minimizes
total pause?" as a MILP over the residents (HiGHS via
``scipy.optimize.milp``, the same backend as the engine's ``ilp`` mapper):

* one binary per resident (move it or not), objective = its migration
  pause in seconds (plus an epsilon tie-break on tid order, so equal-pause
  optima are deterministic);
* cardinality cap ``max_migrations``;
* feasibility — "after the selected tenants vacate, the goal placement
  fits strictly and every selected tenant can itself be re-placed" — is
  geometric, so it is enforced by *iterative no-good cuts*: solve, trial
  the selected subset against the real MappingEngine (side-effect-free
  ``free_override`` solves), and on failure forbid exactly that subset and
  re-solve.  With the default cap of 2 the loop terminates in a handful of
  trials.

Every plan is compared against a *simulated* run of the greedy pass
(identical arithmetic to ``ClusterScheduler._defrag_for``, no state
mutated) and the cheaper of the two is returned — the planner is
never-worse-than-greedy **by construction**, not by hope.  All inputs are
deterministic (HiGHS, the engine, sorted iteration), so a plan is
bit-identical across runs for identical cluster states.

The planner is vNPU-only: it speaks the hypervisor's re-mapping protocol
(``Hypervisor.apply_mapping``) and reads the engine through the policy.
Schedulers configured with ``defrag_planner="ilp"`` over MIG/UVM silently
keep the greedy path.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

import numpy as np

from ..core.engine.ilp import HAVE_MILP
from ..core.mapping import MappingResult, mem_dist_node_match
from ..core.simulator import HWConfig, avg_pairwise_hops
from ..core.topology import Topology, mesh_2d
from .events import TenantSpec
from .policy import best_rect

#: deterministic tie-break between equal-pause migration sets: prefer the
#: lexicographically-smallest tid subset.  Small enough to never flip a
#: genuine pause difference (pauses are >= microseconds).
_EPSILON = 1e-12


@dataclasses.dataclass(frozen=True)
class DefragMove:
    """One planned live migration: install ``result`` onto vNPU ``vmid``
    (tenant ``tid``) via :meth:`Hypervisor.apply_mapping`."""
    tid: int
    vmid: int
    result: MappingResult
    pause_s: float


@dataclasses.dataclass(frozen=True)
class DefragPlan:
    """An ordered migration set that provably unlocks the goal placement.

    ``moves`` apply front-to-back (each destination uses only cores free
    at its turn).  ``proven`` is True when the subset came from a HiGHS
    status-0 solve — the minimum-pause certificate; the simulated-greedy
    fallback plan carries ``proven=False``.
    """
    moves: Tuple[DefragMove, ...]
    total_pause_s: float
    proven: bool
    source: str                        # "ilp" | "greedy"


class ILPDefragPlanner:
    """Minimum-pause migration planning over a vNPU policy's residents.

    ``residents`` arguments are the scheduler's ``tid -> ResidentTenant``
    map (the planner reads ``spec``, ``placement`` and
    ``graph.total_weight_bytes`` — the pause model's inputs).  Planning is
    side-effect-free: all placement solves go through the engine's
    ``free_override`` path; nothing is committed until the scheduler
    applies the returned plan.
    """

    def __init__(self, policy, hw: HWConfig,
                 max_migrations: int = 2,
                 time_budget_s: float = 5.0,
                 max_trials: int = 16):
        self.policy = policy
        self.hw = hw
        self.max_migrations = max_migrations
        self.time_budget_s = time_budget_s
        self.max_trials = max_trials

    # -- public entry points -------------------------------------------------
    def plan_admission(self, spec: TenantSpec,
                       residents: Dict[int, object]
                       ) -> Optional[DefragPlan]:
        """Cheapest migration set that unlocks a *strict* (connected)
        placement for ``spec``; None when no bounded set does."""
        goal = self.policy._request(spec, strict=True)
        movers = self._movers(residents)
        ilp = self._plan(goal.topology, frozenset(), movers,
                         goal_mapper=goal.mapper)
        greedy = self._simulate_greedy(goal.topology, movers,
                                       goal_mapper=goal.mapper)
        return self._cheaper(ilp, greedy)

    def plan_resize(self, rt, new_n_cores: int,
                    residents: Dict[int, object]) -> Optional[DefragPlan]:
        """Cheapest migration set that unlocks growing resident ``rt`` to
        ``new_n_cores`` (its own cores count as free for the goal solve,
        exactly like ``Hypervisor.resize_vnpu``); the tenant itself never
        moves.  There is no greedy baseline here — the greedy pass only
        ever ran for admissions — so the ILP plan stands alone."""
        vnpu = rt.placement.vnpu
        if vnpu is None:
            return None
        goal = mesh_2d(*best_rect(new_n_cores), base_id=10_000)
        movers = self._movers(residents, exclude=rt.spec.tid)
        return self._plan(goal, frozenset(rt.placement.cores), movers,
                          goal_mapper=vnpu.request.mapper,
                          goal_connected=vnpu.request.require_connected)

    # -- shared machinery ----------------------------------------------------
    def _movers(self, residents: Dict[int, object],
                exclude: Optional[int] = None) -> List[object]:
        return [rt for tid, rt in sorted(residents.items())
                if tid != exclude and rt.placement.vnpu is not None]

    def _pause_s(self, rt) -> float:
        cycles = self.policy.migration_cycles(
            rt.placement, rt.graph.total_weight_bytes,
            self.hw.hbm_bytes_per_cycle)
        return cycles / self.hw.freq_hz

    def _plan(self, goal_topo: Topology, extra_free: FrozenSet[int],
              movers: Sequence[object], *, goal_mapper: Optional[str],
              goal_connected: bool = True) -> Optional[DefragPlan]:
        if not HAVE_MILP or not movers:  # pragma: no cover - scipy baked in
            return None
        pauses = [self._pause_s(rt) for rt in movers]
        # the empty set is known infeasible: callers only plan after a
        # failed can_place/resize on the unchanged free pool
        cuts: List[FrozenSet[int]] = [frozenset()]
        for _ in range(self.max_trials):
            sel = self._select(pauses, cuts)
            if sel is None:
                return None
            subset = [movers[i] for i in sorted(sel)]
            trial = self._trial(goal_topo, extra_free, subset,
                                goal_mapper=goal_mapper,
                                goal_connected=goal_connected)
            if trial is None:
                cuts.append(sel)
                continue
            moves = tuple(trial)
            return DefragPlan(
                moves=moves,
                total_pause_s=sum(m.pause_s for m in moves),
                proven=True, source="ilp")
        return None

    def _select(self, pauses: Sequence[float],
                cuts: Sequence[FrozenSet[int]]) -> Optional[FrozenSet[int]]:
        """Minimum-pause subset of <= ``max_migrations`` residents avoiding
        every forbidden (previously-trialed-infeasible) subset."""
        from scipy.optimize import Bounds, LinearConstraint, milp

        n = len(pauses)
        c = np.array([p + _EPSILON * (i + 1) for i, p in enumerate(pauses)])
        A: List[List[float]] = [[1.0] * n]        # cardinality cap
        lb: List[float] = [1.0]                   # and at least one move
        ub: List[float] = [float(self.max_migrations)]
        for s in cuts:
            if not s:
                continue                          # empty cut == lb >= 1 above
            row = [1.0 if i in s else -1.0 for i in range(n)]
            A.append(row)
            lb.append(-np.inf)
            ub.append(float(len(s) - 1))
        res = milp(c=c, constraints=LinearConstraint(np.array(A), lb, ub),
                   integrality=np.ones(n),
                   bounds=Bounds(np.zeros(n), np.ones(n)),
                   options={"time_limit": float(self.time_budget_s)})
        if res.x is None or res.status != 0:
            return None
        return frozenset(i for i in range(n) if res.x[i] > 0.5)

    def _trial(self, goal_topo: Topology, extra_free: FrozenSet[int],
               subset: Sequence[object], *, goal_mapper: Optional[str],
               goal_connected: bool) -> Optional[List[DefragMove]]:
        """Feasibility of one migration subset, against the real engine but
        side-effect-free.  The goal solves over (free + the subset's cores
        + ``extra_free``); each migrant then re-places sequentially into
        what is *actually* free at its turn (never another still-resident
        tenant's cores, never the goal's reservation), so the returned
        move list is safe to apply front-to-back."""
        hyp = self.policy.hyp
        eng = hyp.engine
        free0 = set(hyp.free_cores())
        free_trial = free0 | set(extra_free)
        for rt in subset:
            free_trial |= set(rt.placement.cores)
        goal_res = eng.map_request(
            goal_topo, require_connected=goal_connected,
            mapper=goal_mapper, free_override=free_trial)
        if goal_res is None:
            return None
        goal_nodes = set(goal_res.nodes)
        remainder = free0 - goal_nodes
        moves: List[DefragMove] = []
        for rt in subset:                          # tid order (sorted movers)
            req = rt.placement.vnpu.request
            old = set(rt.placement.cores)
            avail = (remainder | old) - goal_nodes
            res = eng.map_request(
                req.topology, node_match=mem_dist_node_match(0.5),
                require_connected=req.require_connected,
                mapper=req.mapper, free_override=avail)
            if res is None:
                return None
            if set(res.nodes) == old:
                continue                           # never blocked the goal
            moves.append(DefragMove(
                tid=rt.spec.tid, vmid=rt.placement.handle, result=res,
                pause_s=self._pause_s(rt)))
            remainder = (remainder | old) - set(res.nodes)
        return moves

    def _simulate_greedy(self, goal_topo: Topology,
                         movers: Sequence[object], *,
                         goal_mapper: Optional[str]
                         ) -> Optional[DefragPlan]:
        """Replay ``ClusterScheduler._defrag_for``'s greedy pass without
        mutating anything: same order (most-scattered first), same per-move
        solve, same stop condition.  Returns a plan only when greedy would
        actually unlock the goal — a greedy pass that moves tenants and
        *still* fails is not a usable floor."""
        hyp = self.policy.hyp
        eng = hyp.engine
        topo = self.policy.topo
        free_sim = set(hyp.free_cores())
        cores_now = {rt.spec.tid: set(rt.placement.cores) for rt in movers}
        order = sorted(
            movers,
            key=lambda r: avg_pairwise_hops(topo, r.placement.cores),
            reverse=True)
        moves: List[DefragMove] = []
        for rt in order:
            if len(moves) >= self.max_migrations:
                break
            req = rt.placement.vnpu.request
            old = cores_now[rt.spec.tid]
            res = eng.map_request(
                req.topology, node_match=mem_dist_node_match(0.5),
                require_connected=req.require_connected,
                mapper=req.mapper, free_override=free_sim | old)
            if res is None or set(res.nodes) == old:
                continue
            moves.append(DefragMove(
                tid=rt.spec.tid, vmid=rt.placement.handle, result=res,
                pause_s=self._pause_s(rt)))
            free_sim = (free_sim | old) - set(res.nodes)
            cores_now[rt.spec.tid] = set(res.nodes)
            if eng.map_request(goal_topo, require_connected=True,
                               mapper=goal_mapper,
                               free_override=free_sim) is not None:
                return DefragPlan(
                    moves=tuple(moves),
                    total_pause_s=sum(m.pause_s for m in moves),
                    proven=False, source="greedy")
        return None

    @staticmethod
    def _cheaper(ilp: Optional[DefragPlan],
                 greedy: Optional[DefragPlan]) -> Optional[DefragPlan]:
        """min by total pause (ties: fewer moves, then the proven plan) —
        the never-worse-than-greedy guarantee."""
        if ilp is None:
            return greedy
        if greedy is None:
            return ilp
        ki = (ilp.total_pause_s, len(ilp.moves), 0)
        kg = (greedy.total_pause_s, len(greedy.moves), 1)
        return ilp if ki <= kg else greedy


#: scheduler-facing registry: ``defrag_planner=`` values
DEFRAG_PLANNERS = ("greedy", "ilp")
