"""The event-driven cluster scheduler.

One loop drives any :class:`~repro.sched.policy.PlacementPolicy` through a
tenant trace:

* **admission control with queueing** — arrivals that don't fit wait in a
  FIFO queue (with backfill: a small tenant behind a blocked big one may
  still be admitted) and abandon after their SLA wait;
* **defragmentation via live migration** — admission tries a *connected*
  (strict) placement first; when fragmentation prevents one, resident
  tenants are migrated (most-scattered first, compaction objective) to
  consolidate free cores before falling back to a fragmented placement;
  each move is charged the warmup/RTT-model pause (scratchpad re-warm +
  routing-table reconfig);
* **epoch scoring** — between events the resident set is scored with
  :mod:`repro.core.simulator`; a tenant's ``external_flows`` are the NoC
  flows its *actual co-residents* inject, and ``hbm_concurrency`` is the
  number of resident tenants synchronizing through global memory — nothing
  is hand-set.

The output is a :class:`ClusterMetrics`: time-weighted mean utilization,
queue-latency percentiles, per-tenant throughput, per-epoch trajectory
samples (the paper's Figs. 15–18 axes under dynamic arrivals) and — for
the vNPU policy — the MappingEngine's cache hit/miss telemetry.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import simulator as S
from ..core.baselines import AllocationError
from ..core.simulator import Flow, HWConfig, RunReport
from ..core.workloads import WorkloadGraph
from .events import ARRIVAL, DEPARTURE, EPOCH, EventQueue, TenantSpec
from .policy import Placement, PlacementPolicy
from .traces import get_serving_workload


@dataclasses.dataclass
class ResidentTenant:
    spec: TenantSpec
    placement: Placement
    graph: WorkloadGraph
    admit_s: float
    depart_s: float
    pause_until_s: float = 0.0        # migrating: no throughput until then
    served_iterations: float = 0.0
    migrations: int = 0


@dataclasses.dataclass
class EpochSample:
    t: float
    utilization: float
    n_resident: int
    n_queued: int
    agg_fps: float                     # sum of effective per-tenant fps


@dataclasses.dataclass
class ClusterMetrics:
    policy: str
    trace: str = ""
    samples: List[EpochSample] = dataclasses.field(default_factory=list)
    queue_waits_s: List[float] = dataclasses.field(default_factory=list)
    n_arrived: int = 0
    n_admitted: int = 0
    n_rejected: int = 0
    n_migrations: int = 0
    util_integral: float = 0.0        # ∫ utilization dt
    horizon_s: float = 0.0
    tenant_iterations: Dict[int, float] = dataclasses.field(
        default_factory=dict)
    tenant_active_s: Dict[int, float] = dataclasses.field(
        default_factory=dict)
    # mapping-engine telemetry (vNPU policy only): cache hits/misses,
    # candidates evaluated, region ops — see MappingEngine.counters()
    engine_counters: Dict[str, float] = dataclasses.field(
        default_factory=dict)

    @property
    def mean_utilization(self) -> float:
        return self.util_integral / self.horizon_s if self.horizon_s else 0.0

    def wait_percentile(self, q: float) -> float:
        if not self.queue_waits_s:
            return 0.0
        return float(np.percentile(np.array(self.queue_waits_s), q))

    @property
    def p50_wait_s(self) -> float:
        return self.wait_percentile(50)

    @property
    def p95_wait_s(self) -> float:
        return self.wait_percentile(95)

    @property
    def mean_tenant_fps(self) -> float:
        rates = [it / act for it, act in
                 ((self.tenant_iterations[t], self.tenant_active_s[t])
                  for t in self.tenant_iterations) if act > 0]
        return float(np.mean(rates)) if rates else 0.0

    def summary(self) -> Dict[str, float]:
        out = {
            "policy": self.policy,
            "trace": self.trace,
            "mean_utilization": round(self.mean_utilization, 4),
            "p50_wait_s": round(self.p50_wait_s, 3),
            "p95_wait_s": round(self.p95_wait_s, 3),
            "admitted": self.n_admitted,
            "rejected": self.n_rejected,
            "migrations": self.n_migrations,
            "mean_tenant_fps": round(self.mean_tenant_fps, 2),
        }
        if self.engine_counters:
            out["engine"] = dict(self.engine_counters)
        return out


class ClusterScheduler:
    """Event loop binding a placement policy to the analytic simulator."""

    def __init__(self, policy: PlacementPolicy,
                 hw: Optional[HWConfig] = None,
                 epoch_s: float = 2.0,
                 defrag: bool = True,
                 max_migrations_per_event: int = 2):
        self.policy = policy
        self.hw = hw or S.SIM_CONFIG
        self.topo = policy.topo
        self.epoch_s = epoch_s
        self.defrag = defrag
        self.max_migrations_per_event = max_migrations_per_event

        self._residents: Dict[int, ResidentTenant] = {}
        self._waiting: List[Tuple[TenantSpec, float]] = []
        self._scores: Dict[int, RunReport] = {}
        self._flows: Dict[int, List[Flow]] = {}
        self._dirty = True
        self._last_t = 0.0
        self.metrics = ClusterMetrics(policy=policy.name)

    # -- scoring -----------------------------------------------------------
    def _tenant_flows(self, rt: ResidentTenant) -> List[Flow]:
        flows = self._flows.get(rt.spec.tid)
        if flows is None:
            if rt.placement.comm == "dataflow":
                flows = S.tenant_flows(rt.graph, rt.placement.cores,
                                       self.topo, self.hw,
                                       owner=rt.spec.tid)
            else:
                flows = []   # UVM traffic rides HBM, not the NoC
            self._flows[rt.spec.tid] = flows
        return flows

    def _rescore(self) -> None:
        """Score every resident against its actual co-residents."""
        hbm_clients = sum(1 for r in self._residents.values()
                          if r.placement.hbm_client)
        self._scores = {}
        for tid, rt in self._residents.items():
            p = rt.placement
            kwargs = dict(comm=p.comm, owner=tid,
                          tdm_physical=p.tdm_physical,
                          hbm_concurrency=max(hbm_clients, 1))
            if p.comm == "dataflow":
                external = [f for other, r2 in self._residents.items()
                            if other != tid for f in self._tenant_flows(r2)]
                kwargs["external_flows"] = external
            self._scores[tid] = S.simulate(
                rt.graph, list(p.cores), self.topo, self.hw, **kwargs)
        self._dirty = False

    def _fps(self, tid: int) -> float:
        if self._dirty:
            self._rescore()
        report = self._scores.get(tid)
        return report.fps if report else 0.0

    # -- time accounting ---------------------------------------------------
    def _advance(self, now: float) -> None:
        dt = now - self._last_t
        if dt <= 0:
            return
        self.metrics.util_integral += self.policy.utilization() * dt
        for tid, rt in self._residents.items():
            active = dt
            if rt.pause_until_s > self._last_t:
                active -= min(rt.pause_until_s, now) - self._last_t
            if active > 0:
                rt.served_iterations += self._fps(tid) * active
        self._last_t = now

    # -- admission ---------------------------------------------------------
    def _try_place(self, spec: TenantSpec, now: float,
                   evq: EventQueue, strict: bool = False) -> bool:
        try:
            placement = self.policy.allocate(spec, strict=strict)
        except AllocationError:
            return False
        rt = ResidentTenant(
            spec=spec, placement=placement,
            graph=get_serving_workload(spec.model),
            admit_s=now, depart_s=now + spec.duration_s)
        self._residents[spec.tid] = rt
        self._dirty = True
        evq.push(rt.depart_s, DEPARTURE, tid=spec.tid)
        self.metrics.n_admitted += 1
        self.metrics.queue_waits_s.append(now - spec.arrival_s)
        return True

    def _defrag_for(self, spec: TenantSpec, now: float) -> bool:
        """Migrate residents (most-scattered first, compaction objective)
        until a *connected* placement for the pending request exists.
        Returns True if any tenant moved."""
        if self.policy.can_place(spec, strict=True):
            return False   # nothing to defragment
        order = sorted(
            self._residents.values(),
            key=lambda r: S.avg_pairwise_hops(self.topo, r.placement.cores),
            reverse=True)
        moved_any = False
        migrations = 0
        for rt in order:
            if migrations >= self.max_migrations_per_event:
                break
            new_p, moved = self.policy.migrate(rt.placement)
            if not moved:
                continue
            migrations += 1
            moved_any = True
            rt.placement = new_p
            rt.migrations += 1
            self.metrics.n_migrations += 1
            pause_cycles = self.policy.migration_cycles(
                new_p, rt.graph.total_weight_bytes,
                self.hw.hbm_bytes_per_cycle)
            rt.pause_until_s = max(rt.pause_until_s,
                                   now + pause_cycles / self.hw.freq_hz)
            self._flows.pop(rt.spec.tid, None)
            self._dirty = True
            if self.policy.can_place(spec, strict=True):
                break
        return moved_any

    def _reject(self, spec: TenantSpec, wait_s: float) -> None:
        """A tenant that gave up: censor its wait into the latency metrics
        (otherwise policies that reject more would *look* faster)."""
        self.metrics.n_rejected += 1
        self.metrics.queue_waits_s.append(wait_s)

    def _expire_waiting(self, now: float) -> None:
        kept = []
        for spec, enq in self._waiting:
            if now - spec.arrival_s > spec.sla_wait_s:
                self._reject(spec, spec.sla_wait_s)
            else:
                kept.append((spec, enq))
        self._waiting = kept

    def _drain_queue(self, now: float, evq: EventQueue) -> None:
        self._expire_waiting(now)
        still: List[Tuple[TenantSpec, float]] = []
        for i, (spec, enq) in enumerate(self._waiting):
            if self._try_place(spec, now, evq, strict=True):
                continue
            if i == 0 and self.defrag:
                # one defrag attempt on behalf of the queue head
                if self._defrag_for(spec, now) and \
                        self._try_place(spec, now, evq, strict=True):
                    continue
            if self._try_place(spec, now, evq):   # relaxed (fragmented ok)
                continue
            still.append((spec, enq))
        self._waiting = still

    # -- main loop ---------------------------------------------------------
    def run(self, trace: Sequence[TenantSpec],
            trace_name: str = "") -> ClusterMetrics:
        if self._residents or self._waiting or self._last_t > 0.0:
            raise RuntimeError(
                "ClusterScheduler.run() is one-shot: the policy's placement "
                "state survives a run, so reuse would mix tenants across "
                "traces — build a fresh scheduler+policy per run (as "
                "compare_policies does)")
        self.metrics = ClusterMetrics(policy=self.policy.name,
                                      trace=trace_name)
        evq = EventQueue()
        for spec in trace:
            evq.push(spec.arrival_s, ARRIVAL, spec=spec)
        if self.epoch_s > 0:
            evq.push(self.epoch_s, EPOCH)

        while evq:
            ev = evq.pop()
            now = ev.time
            self._advance(now)
            if ev.kind == ARRIVAL:
                self.metrics.n_arrived += 1
                spec = ev.spec
                # strict (connected) first; defragment; only then accept a
                # fragmented placement — locality is worth one defrag pass
                placed = self._try_place(spec, now, evq, strict=True)
                if not placed and self.defrag and not self._waiting:
                    if self._defrag_for(spec, now):
                        placed = self._try_place(spec, now, evq, strict=True)
                if not placed:
                    placed = self._try_place(spec, now, evq)
                if not placed:
                    self._waiting.append((spec, now))
            elif ev.kind == DEPARTURE:
                rt = self._residents.pop(ev.tid, None)
                if rt is not None:
                    self.policy.release(rt.placement)
                    self._flows.pop(ev.tid, None)
                    self._dirty = True
                    self.metrics.tenant_iterations[ev.tid] = \
                        rt.served_iterations
                    self.metrics.tenant_active_s[ev.tid] = \
                        max(rt.depart_s - rt.admit_s, 0.0)
                self._drain_queue(now, evq)
            elif ev.kind == EPOCH:
                self._drain_queue(now, evq)
                if self._dirty:
                    self._rescore()
                self.metrics.samples.append(EpochSample(
                    t=now,
                    utilization=self.policy.utilization(),
                    n_resident=len(self._residents),
                    n_queued=len(self._waiting),
                    agg_fps=sum(self._fps(t) for t in self._residents)))
                # re-arm while the system still has work in flight
                if evq:
                    evq.push(now + self.epoch_s, EPOCH)

        # tenants still waiting when the trace ends count as rejected;
        # censor their wait at what they actually endured (or their SLA)
        for spec, enq in self._waiting:
            self._reject(spec, min(max(self._last_t - spec.arrival_s, 0.0),
                                   spec.sla_wait_s))
        self._waiting = []
        self.metrics.horizon_s = self._last_t
        counters = getattr(self.policy, "engine_counters", None)
        if callable(counters):
            self.metrics.engine_counters = counters()
        return self.metrics


def compare_policies(policies: Sequence[PlacementPolicy],
                     trace: Sequence[TenantSpec],
                     hw: Optional[HWConfig] = None,
                     trace_name: str = "",
                     **sched_kwargs) -> List[ClusterMetrics]:
    """Run the same trace through several policies (fresh scheduler each)."""
    out = []
    for policy in policies:
        sched = ClusterScheduler(policy, hw=hw, **sched_kwargs)
        out.append(sched.run(trace, trace_name=trace_name))
    return out
