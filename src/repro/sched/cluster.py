"""The event-driven cluster scheduler.

One loop drives any :class:`~repro.sched.policy.PlacementPolicy` through a
tenant trace:

* **admission control with queueing** — arrivals that don't fit wait in a
  FIFO queue (with backfill: a small tenant behind a blocked big one may
  still be admitted) and abandon after their SLA wait;
* **defragmentation via live migration** — admission tries a *connected*
  (strict) placement first; when fragmentation prevents one, resident
  tenants are migrated (most-scattered first, compaction objective) to
  consolidate free cores before falling back to a fragmented placement;
  each move is charged the warmup/RTT-model pause (scratchpad re-warm +
  routing-table reconfig);
* **failure injection** — ``run(..., failures=...)`` kills physical cores
  mid-trace: the policy quarantines them (`mark_failed`) and every resident
  touching a dead core is live-migrated away, charged like a defrag move;
* **epoch scoring** — between events the resident set is scored with
  :mod:`repro.core.simulator`; a tenant's cross-tenant interference is the
  NoC traffic its *actual co-residents* inject and the number of resident
  HBM clients — nothing is hand-set.

Scoring has two implementations, selected by ``rescore=``:

* ``"ledger"`` (default) — the :class:`~repro.sched.ledger.InterferenceLedger`
  maintains per-directed-link occupancy incrementally across
  allocate/release/migrate/fail and re-simulates only the tenants whose
  links' occupancy (or HBM context) actually changed: O(dirty x own flows)
  per pass.
* ``"oracle"`` — the reference recompute: every resident re-lists and
  re-paths every co-resident's flows, O(residents^2 x flows) per pass.
  Kept as the ground truth; ``benchmarks/cluster_sim.py --gate`` pins the
  ledger bit-identical to it and >= 5x cheaper at 16x16.

The output is a :class:`ClusterMetrics`: time-weighted mean utilization,
queue-latency percentiles (p50/p95/p99), per-tenant throughput, per-epoch
trajectory samples (the paper's Figs. 15–18 axes under dynamic arrivals),
scoring-pass costs, and — for the vNPU policy — the MappingEngine's cache
telemetry next to the ledger's hit/recompute counters.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import simulator as S
from ..core.baselines import AllocationError
from ..core.simulator import Flow, HWConfig, PhaseModel, RunReport
from ..core.workloads import WorkloadGraph
from ..obs.timeline import TimelineSampler
from ..obs.trace import Tracer
from ..serve.plane import ServingPlane
from ..serve.requests import ArrivalProcess, get_profile
from ..serve.stats import LatencyStats
from .defrag import DEFRAG_PLANNERS, DefragPlan, ILPDefragPlanner
from .events import (ARRIVAL, DEPARTURE, EPOCH, FAILURE, LINK_DEGRADE,
                     LINK_FAIL, LINK_REPAIR, REPAIR, RESIZE, EventQueue,
                     TenantSpec)
from .ledger import InterferenceLedger
from .policy import Placement, PlacementPolicy
from .traces import get_serving_workload

RESCORE_MODES = ("ledger", "oracle")
ADMISSION_MODES = ("fifo", "sla")

# Byte-weighting strength of the decode HBM-share blend (see
# ``_hbm_share_keys``): a busy client's port share is
# ``(1-w)/streamers + w*own_bytes/total_bytes``.  w=0 is the legacy
# equal split; w=1 is pure demand-proportional service (which starves
# small co-residents behind a 7B shard stream).
HBM_BYTE_WEIGHT = 0.25


@dataclasses.dataclass
class ServingConfig:
    """Turns the request-level serving plane on and parameterizes the
    elastic-resize controller.

    The scheduler samples each admitted LLM tenant's request stream with
    ``seed`` (deterministic per tenant id), advances its continuous-batching
    server between events, and at every epoch reads the tenant's pressure
    signals: growth fires when the decode queue is ``grow_queue_depth``
    deep, the KV arena is ``grow_kv_occupancy`` full, or an admission was
    KV-blocked; shrink fires after ``shrink_epochs`` consecutive idle
    epochs (empty queue, batch under ``shrink_batch_fill``).  Both
    directions respect a per-tenant ``cooldown_s`` hysteresis and the
    ``grow_limit`` cap (a multiple of the original core ask); shrink never
    goes below the original ask.

    ``engine`` selects the serving-plane implementation (``"vector"``, the
    numpy struct-of-arrays pool, or ``"scalar"``, the per-tenant reference
    loop — bit-identical trajectories, pinned by the scale gate).
    ``record_requests=False`` streams completions through the metrics
    sketches instead of materializing per-request records (mandatory at
    million-request scale; ``request_log`` stays empty).  ``arrival`` /
    ``rate_scale`` / ``request_mix`` shape every tenant's request stream
    (see :mod:`repro.serve.requests`).
    """
    seed: int = 0
    grow_queue_depth: int = 3
    grow_kv_occupancy: float = 0.85
    shrink_batch_fill: float = 0.25
    shrink_epochs: int = 3
    cooldown_s: float = 6.0
    grow_limit: float = 3.0
    engine: str = "vector"
    record_requests: bool = True
    arrival: Optional[ArrivalProcess] = None
    rate_scale: float = 1.0
    request_mix: str = "default"


@dataclasses.dataclass
class RecoveryConfig:
    """Arms the chaos-plane recovery semantics (``recovery=`` kwarg).

    With a config bound, a resident whose placement is destroyed by a
    fault *and* cannot be migrated is killed and recovered instead of
    left running degraded: training-class tenants
    (``TenantSpec.tenant_class == "train"``) resume from their last
    periodic checkpoint — the work since that boundary is redone and the
    restore (scratchpad re-warm + routing-table resharding, the same
    Fig.-11 arithmetic as a migration) delays re-entry; every other
    tenant re-arrives through a bounded exponential-backoff retry queue
    (``retry_base_s * 2**attempt``, dropped after ``retry_max``
    attempts).  ``migrate_on_link_fail`` additionally evacuates residents
    off a hard-failed NoC link's endpoints.  Without a config (the
    default) fault handling is bit-identical to the historical behavior.
    """
    ckpt_interval_s: float = 10.0
    retry_base_s: float = 0.5
    retry_max: int = 5
    migrate_on_link_fail: bool = True


@dataclasses.dataclass
class _ResizeState:
    """Per-tenant hysteresis bookkeeping for the resize controller."""
    orig_n_cores: int
    last_resize_s: float = -math.inf
    idle_epochs: int = 0
    # growth cannot extend the KV arena (it is fixed at attach), only
    # drain contexts faster — so KV-only pressure buys one growth attempt
    # per pressure episode instead of marching to the cap
    kv_grow_tried: bool = False


@dataclasses.dataclass
class ResidentTenant:
    """A placed tenant's run state.  Times are wall-clock seconds;
    ``served_iterations`` integrates fps x active time."""
    spec: TenantSpec
    placement: Placement
    graph: WorkloadGraph
    admit_s: float
    depart_s: float
    pause_until_s: float = 0.0        # migrating: no throughput until then
    served_iterations: float = 0.0
    migrations: int = 0


@dataclasses.dataclass
class EpochSample:
    """One trajectory point (taken at every epoch event)."""
    t: float                           # seconds
    utilization: float                 # fraction of useful physical cores
    n_resident: int
    n_queued: int
    agg_fps: float                     # sum of effective per-tenant fps


@dataclasses.dataclass
class ClusterMetrics:
    """Everything one scheduler run reports.

    Units: waits and the horizon are seconds; fps is iterations/second at
    ``HWConfig.freq_hz``; ``scoring_pass_s`` holds the wall-time of each
    epoch-scoring pass (the quantity the ledger tentpole optimizes).
    """
    policy: str
    trace: str = ""
    rescore_mode: str = "ledger"
    samples: List[EpochSample] = dataclasses.field(default_factory=list)
    queue_waits_s: List[float] = dataclasses.field(default_factory=list)
    n_arrived: int = 0
    n_admitted: int = 0
    n_rejected: int = 0
    n_migrations: int = 0
    n_failed_cores: int = 0
    # exact-defrag telemetry (defrag_planner="ilp" only): plans applied,
    # moves those plans contained, and grows unlocked by a planned defrag
    n_defrag_plans: int = 0
    n_planned_moves: int = 0
    n_resize_defrags: int = 0
    # residents handed back to a fleet router by ``evacuate()`` (pod drain
    # or pod failure) — they depart this pod but are not rejections
    n_evacuated: int = 0
    # placement attempts skipped because the spec's size class last failed
    # against an identical free pool (drain-queue probe memoization)
    n_probe_skips: int = 0
    n_events: int = 0                 # events processed by the run loop
    util_integral: float = 0.0        # ∫ utilization dt
    horizon_s: float = 0.0
    # ---- chaos-plane recovery SLOs (fault/repair runs only) ----
    n_repaired_cores: int = 0         # cores returned to service
    n_repairs: int = 0                # closed fail->repair intervals
    mttr_sum_s: float = 0.0           # Σ (repair - fail) over closed intervals
    core_downtime_s: float = 0.0      # ∫ dead-core count dt (core-seconds)
    n_cores_total: int = 0            # mesh size, stamped at finish()
    n_link_faults: int = 0            # link-fail + link-degrade events
    n_link_repairs: int = 0
    n_link_migrations: int = 0        # residents moved off a failed link
    n_fault_kills: int = 0            # residents killed by core faults
    n_ckpt_resumes: int = 0           # train tenants resumed from checkpoint
    rework_s: float = 0.0             # work redone since the last checkpoint
    rewarm_cost_s: float = 0.0        # restore/re-shard pauses charged
    n_fault_retries: int = 0          # serve tenants queued for re-admission
    n_fault_drops: int = 0            # retry budget exhausted: tenant lost
    requests_fault_lost: int = 0      # in-flight requests lost at fault kills
    tenant_iterations: Dict[int, float] = dataclasses.field(
        default_factory=dict)
    tenant_active_s: Dict[int, float] = dataclasses.field(
        default_factory=dict)
    # wall-time of every scoring pass (oracle: full recompute; ledger:
    # dirty-set re-simulation) — cluster_sim's --gate compares the medians
    scoring_pass_s: List[float] = dataclasses.field(default_factory=list)
    # mapping-engine telemetry (vNPU policy only): cache hits/misses,
    # candidates evaluated, region ops — see MappingEngine.counters()
    engine_counters: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    # interference-ledger telemetry (rescore="ledger" only): tenants
    # rescored vs reused, dirty marks, global invalidations — see
    # LedgerCounters.as_dict()
    ledger_counters: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    # ---- request-level serving metrics (ServingConfig runs only) ----
    n_resize_attempts: int = 0        # RESIZE events processed
    n_resizes: int = 0                # resizes the policy actually performed
    n_grows: int = 0
    n_shrinks: int = 0
    requests_arrived: int = 0
    requests_completed: int = 0
    requests_sla_good: int = 0        # met both TTFT and TPOT targets
    tokens_generated: int = 0
    kv_preemptions: int = 0           # mid-decode KV OOM evictions
    kv_admit_oom: int = 0             # admissions deferred on KV pressure
    requests_dropped: int = 0         # prompts larger than the whole arena
    # high-water mark of per-request records resident in the plane at any
    # instant (the memory-audit telemetry: O(active tenants x backlog)
    # with record_requests off, never O(total requests))
    peak_live_records: int = 0
    # streaming latency summaries: exact counters + P² percentile sketches
    # fed one completion at a time (O(1) memory at any request volume)
    ttft_stats: LatencyStats = dataclasses.field(default_factory=LatencyStats)
    tpot_stats: LatencyStats = dataclasses.field(default_factory=LatencyStats)
    # compact per-request trajectory for determinism gates:
    # (tid, rid, ttft, tpot, tokens_out, preempts), completed-or-censored
    # — only populated when ServingConfig.record_requests is on
    request_log: List[Tuple] = dataclasses.field(default_factory=list)

    @property
    def mean_utilization(self) -> float:
        """Time-weighted mean fraction of useful cores (dimensionless)."""
        return self.util_integral / self.horizon_s if self.horizon_s else 0.0

    def wait_percentile(self, q: float) -> float:
        """q-th percentile of admission waits in seconds (rejected tenants
        are censored in at the wait they endured)."""
        if not self.queue_waits_s:
            return 0.0
        return float(np.percentile(np.array(self.queue_waits_s), q))

    @property
    def p50_wait_s(self) -> float:
        return self.wait_percentile(50)

    @property
    def p95_wait_s(self) -> float:
        return self.wait_percentile(95)

    @property
    def p99_wait_s(self) -> float:
        return self.wait_percentile(99)

    @property
    def median_scoring_ms(self) -> float:
        """Median wall-time of one epoch-scoring pass, in milliseconds."""
        if not self.scoring_pass_s:
            return 0.0
        return float(np.median(np.array(self.scoring_pass_s))) * 1e3

    @property
    def mttr_s(self) -> float:
        """Mean time to repair: average seconds a dead core stayed down,
        over the fail->repair intervals that closed inside the run."""
        return self.mttr_sum_s / self.n_repairs if self.n_repairs else 0.0

    @property
    def capacity_availability(self) -> float:
        """1 − mean fraction of physical cores dead over the horizon —
        a pure function of the storm, identical across policies."""
        denom = self.n_cores_total * self.horizon_s
        return 1.0 - self.core_downtime_s / denom if denom else 1.0

    @property
    def service_availability(self) -> float:
        """Admitted / arrived tenants — the fraction of service asks the
        cluster actually carried under the storm.  Unlike capacity
        availability this separates policies: how much of the surviving
        hardware a policy can still *shape into placements* (vNPU remaps
        around holes, MIG loses whole partitions)."""
        return self.n_admitted / self.n_arrived if self.n_arrived else 1.0

    def recovery_summary(self) -> Dict[str, float]:
        """Flat digest of the chaos-plane recovery SLOs."""
        return {
            "mttr_s": round(self.mttr_s, 4),
            "capacity_availability": round(self.capacity_availability, 6),
            "service_availability": round(self.service_availability, 4),
            "repaired_cores": self.n_repaired_cores,
            "core_downtime_s": round(self.core_downtime_s, 4),
            "link_faults": self.n_link_faults,
            "link_repairs": self.n_link_repairs,
            "link_migrations": self.n_link_migrations,
            "fault_kills": self.n_fault_kills,
            "ckpt_resumes": self.n_ckpt_resumes,
            "rework_s": round(self.rework_s, 4),
            "rewarm_cost_s": round(self.rewarm_cost_s, 4),
            "fault_retries": self.n_fault_retries,
            "fault_drops": self.n_fault_drops,
            "requests_fault_lost": self.requests_fault_lost,
        }

    @property
    def sla_goodput_rps(self) -> float:
        """Requests meeting both TTFT and TPOT targets, per second of the
        run horizon — the serving plane's headline axis."""
        return self.requests_sla_good / self.horizon_s if self.horizon_s \
            else 0.0

    def observe_request(self, ttft_s: float, tpot_s: float, tokens: int,
                        good: bool) -> None:
        """Streaming completion sink: the serving plane calls this the
        moment a request finishes (identical order for both engines), so
        completed-request accounting never needs the per-request records."""
        self.requests_completed += 1
        self.tokens_generated += tokens
        if good:
            self.requests_sla_good += 1
        self.ttft_stats.add(ttft_s)
        self.tpot_stats.add(tpot_s)

    def serving_summary(self) -> Dict[str, float]:
        """Flat digest of the request-level serving run."""
        return {
            "requests": self.requests_arrived,
            "completed": self.requests_completed,
            "sla_good": self.requests_sla_good,
            "sla_goodput_rps": round(self.sla_goodput_rps, 4),
            "tokens_generated": self.tokens_generated,
            "ttft_p50_s": round(self.ttft_stats.percentile(50), 4),
            "ttft_p95_s": round(self.ttft_stats.percentile(95), 4),
            "ttft_p99_s": round(self.ttft_stats.percentile(99), 4),
            "tpot_p50_s": round(self.tpot_stats.percentile(50), 5),
            "tpot_p95_s": round(self.tpot_stats.percentile(95), 5),
            "tpot_p99_s": round(self.tpot_stats.percentile(99), 5),
            "kv_preemptions": self.kv_preemptions,
            "kv_admit_oom": self.kv_admit_oom,
            "requests_dropped": self.requests_dropped,
            "resizes": self.n_resizes,
            "grows": self.n_grows,
            "shrinks": self.n_shrinks,
            "resize_attempts": self.n_resize_attempts,
        }

    @property
    def mean_tenant_fps(self) -> float:
        rates = [it / act for it, act in
                 ((self.tenant_iterations[t], self.tenant_active_s[t])
                  for t in self.tenant_iterations) if act > 0]
        return float(np.mean(rates)) if rates else 0.0

    def summary(self) -> Dict[str, float]:
        """Flat scalar digest (what ``cluster_sim.py`` prints/serializes)."""
        out = {
            "policy": self.policy,
            "trace": self.trace,
            "rescore": self.rescore_mode,
            "mean_utilization": round(self.mean_utilization, 4),
            "p50_wait_s": round(self.p50_wait_s, 3),
            "p95_wait_s": round(self.p95_wait_s, 3),
            "p99_wait_s": round(self.p99_wait_s, 3),
            "admitted": self.n_admitted,
            "rejected": self.n_rejected,
            "migrations": self.n_migrations,
            "mean_tenant_fps": round(self.mean_tenant_fps, 2),
            "median_scoring_ms": round(self.median_scoring_ms, 3),
        }
        if self.n_failed_cores:
            out["failed_cores"] = self.n_failed_cores
        if self.n_repaired_cores or self.n_link_faults or self.n_fault_kills:
            out["recovery"] = self.recovery_summary()
        # unconditional: these were once gated on being non-zero, which
        # silently dropped them from printed tables (and hid regressions
        # where a counter unexpectedly *stayed* zero)
        out["evacuated"] = self.n_evacuated
        out["probe_skips"] = self.n_probe_skips
        if self.engine_counters:
            out["engine"] = dict(self.engine_counters)
        if self.ledger_counters:
            out["ledger"] = dict(self.ledger_counters)
        if self.requests_arrived:
            out["serving"] = self.serving_summary()
        return out


class ClusterScheduler:
    """Event loop binding a placement policy to the analytic simulator.

    ``rescore`` selects the epoch-scoring implementation: ``"ledger"``
    (incremental, the default) or ``"oracle"`` (the O(residents^2 x flows)
    reference recompute) — scores are bit-identical either way.
    """

    def __init__(self, policy: PlacementPolicy,
                 hw: Optional[HWConfig] = None,
                 epoch_s: float = 2.0,
                 defrag: bool = True,
                 max_migrations_per_event: int = 2,
                 rescore: str = "ledger",
                 probe_memo: Optional[bool] = None,
                 serving: Optional[ServingConfig] = None,
                 admission: str = "fifo",
                 defrag_planner: str = "greedy",
                 recovery: Optional[RecoveryConfig] = None,
                 tracer: Optional[Tracer] = None):
        if rescore not in RESCORE_MODES:
            raise ValueError(
                f"rescore must be one of {RESCORE_MODES}, got {rescore!r}")
        if admission not in ADMISSION_MODES:
            raise ValueError(
                f"admission must be one of {ADMISSION_MODES}, "
                f"got {admission!r}")
        if defrag_planner not in DEFRAG_PLANNERS:
            raise ValueError(
                f"defrag_planner must be one of {DEFRAG_PLANNERS}, "
                f"got {defrag_planner!r}")
        self.policy = policy
        self.hw = hw or S.SIM_CONFIG
        self.topo = policy.topo
        # observability plane: a pure observer — every emission is guarded
        # by ``tracer.enabled`` and only records values the sim computed
        # anyway, so trajectories are bit-identical with tracing on or off
        self.tracer = tracer if tracer is not None else Tracer.NULL
        self.timeline = TimelineSampler(self.tracer)
        self.epoch_s = epoch_s
        self.defrag = defrag
        self.max_migrations_per_event = max_migrations_per_event
        # exact (minimum-pause) defragmentation planning — vNPU only; the
        # greedy default preserves every pinned trajectory bit-for-bit
        self.defrag_planner = defrag_planner
        self._planner: Optional[ILPDefragPlanner] = (
            ILPDefragPlanner(policy, self.hw,
                             max_migrations=max_migrations_per_event)
            if defrag_planner == "ilp" and hasattr(policy, "hyp") else None)
        self.rescore_mode = rescore
        # negative-probe memoization rides the fast path; the oracle mode
        # re-probes everything so the CI gate pins the memo's exactness
        # (trajectories must stay bit-identical between the two)
        self.probe_memo = (rescore == "ledger") if probe_memo is None \
            else probe_memo
        self.ledger: Optional[InterferenceLedger] = (
            InterferenceLedger(self.topo) if rescore == "ledger" else None)
        # link-heatmap-aware admission: bind the ledger's per-directed-link
        # occupancy into the policy's MappingEngine (vNPU opt-in flag; see
        # VNPUPolicy.bind_link_heat — no ledger, no heat)
        if self.ledger is not None and getattr(policy, "heat_aware", False):
            policy.bind_link_heat(self.ledger)
        # request-level serving plane (opt in): continuous batching per
        # resident LLM tenant + the elastic-resize pressure controller
        self.serving = serving
        self.admission = admission
        self.plane: Optional[ServingPlane] = (
            ServingPlane(seed=serving.seed, engine=serving.engine,
                         record_requests=serving.record_requests,
                         arrival=serving.arrival,
                         rate_scale=serving.rate_scale,
                         mix=serving.request_mix)
            if serving is not None else None)
        if self.plane is not None:
            self.plane.tracer = self.tracer
        self._resize_state: Dict[int, _ResizeState] = {}
        # tid -> {(own bytes, total bytes) HBM-share key -> phase model}:
        # the byte-weighted share oscillates as servers go busy/idle, so
        # keep one model per share instead of thrashing a single slot
        self._phase_cache: Dict[int, Dict[Tuple[int, int], PhaseModel]] = {}
        # tid -> isolated (no-external-load) interval of the cached
        # skeleton — pure function of the placement, invalidated with it
        self._iso_cache: Dict[int, int] = {}

        # chaos plane: recovery semantics (None keeps the historical
        # fault handling), live link-degradation overlay, per-core
        # downtime clocks and the serving retry ledger
        self.recovery = recovery
        self._degraded_links: Dict[Tuple[int, int], float] = {}
        self._core_down_since: Dict[int, float] = {}
        self._retry_attempts: Dict[int, int] = {}

        self._residents: Dict[int, ResidentTenant] = {}
        self._failed_cores: set = set()
        self._waiting: List[Tuple[TenantSpec, float]] = []
        self._scores: Dict[int, RunReport] = {}
        self._flows: Dict[int, List[Flow]] = {}
        # split-RunReport cache (ledger mode): per-tenant placement skeleton
        # (compute, DMA, own-flow paths), invalidated when the placement
        # changes; a dirty rescore recombines only the contention/HBM terms
        self._skeletons: Dict[int, object] = {}
        # negative-probe memo: size-class key -> (free-state token, defrag
        # attempted, placement version at failure) of the last full failure
        self._probe_memo: Dict[Tuple, Tuple] = {}
        self._placement_version = 0
        self._free_token_cache: Optional[Tuple[int, Tuple]] = None
        self._dirty = True                # oracle-mode recompute flag
        self._last_t = 0.0
        # incremental-drive state (the fleet layer's pod protocol): begin()
        # arms the loop, feed()/advance_to() drive it, finish() closes it.
        # run() is a thin wrapper, bit-identical to the historical one-shot.
        self._began = False
        self._evq: Optional[EventQueue] = None
        self._driven = False              # fleet-driven: epochs never die
        self.draining = False             # router hint; set by drain()
        self.metrics = ClusterMetrics(policy=policy.name,
                                      rescore_mode=rescore)

    # -- scoring -----------------------------------------------------------
    def _skeleton(self, rt: ResidentTenant):
        """The tenant's placement skeleton (ledger mode only): the
        compute/DMA/own-flow-path half of a simulation, built once per
        placement and recombined with fresh contention context per scoring
        pass (:func:`repro.core.simulator.rescore_contention`)."""
        sk = self._skeletons.get(rt.spec.tid)
        if sk is None:
            p = rt.placement
            sk = S.make_skeleton(rt.graph, list(p.cores), self.topo, self.hw,
                                 comm=p.comm, owner=rt.spec.tid,
                                 tdm_physical=p.tdm_physical)
            self._skeletons[rt.spec.tid] = sk
        return sk

    def _tenant_flows(self, rt: ResidentTenant) -> List[Flow]:
        """The NoC flows this tenant injects per iteration (cached until
        the placement changes).  O(workload layers) on a miss; in ledger
        mode the flows come from the placement skeleton (same arithmetic
        as :func:`repro.core.simulator.tenant_flows`, computed once)."""
        flows = self._flows.get(rt.spec.tid)
        if flows is None:
            if rt.placement.comm == "dataflow":
                if self.ledger is not None:
                    flows = list(self._skeleton(rt).noc_flows)
                else:
                    flows = S.tenant_flows(rt.graph, rt.placement.cores,
                                           self.topo, self.hw,
                                           owner=rt.spec.tid)
            else:
                flows = []   # UVM traffic rides HBM, not the NoC
            self._flows[rt.spec.tid] = flows
        return flows

    def _score_tenant(self, rt: ResidentTenant,
                      hbm_clients: int) -> RunReport:
        """One simulator call for one resident.  The interference context
        comes either from the ledger (pre-aggregated per-link loads,
        O(own flows)) or — oracle mode — from re-listing every
        co-resident's flows (O(residents x flows)).  In ledger mode the
        placement-dependent skeleton is cached on the resident, so only
        the contention/HBM recombination is paid here — bit-identical to
        the full simulation (one shared arithmetic path)."""
        p = rt.placement
        tid = rt.spec.tid
        kwargs = dict(hbm_concurrency=max(hbm_clients, 1))
        if self.ledger is None:
            if p.comm == "dataflow":
                ext_flows = [
                    f for other, r2 in self._residents.items()
                    if other != tid for f in self._tenant_flows(r2)]
                if self._degraded_links:
                    # degraded mode: fold the link-degradation overlay
                    # into pre-aggregated loads (a solo tenant must feel
                    # a slow link too, so always take the loads path)
                    base = S.flow_link_loads(self.topo, ext_flows)
                    own = S.flow_link_loads(self.topo,
                                            self._tenant_flows(rt))
                    kwargs["external_link_loads"] = \
                        self._degraded_loads(base, own)
                else:
                    kwargs["external_flows"] = ext_flows
            return S.simulate(rt.graph, list(p.cores), self.topo, self.hw,
                              comm=p.comm, owner=tid,
                              tdm_physical=p.tdm_physical, **kwargs)
        if p.comm == "dataflow" and (self._degraded_links
                                     or self.ledger.has_external(tid)):
            # pass the (possibly empty) aggregate exactly when the
            # oracle's flow list would be non-empty — the tensor
            # model's contention switch keys on that, not on loads
            ext = self.ledger.external_loads(tid)
            if self._degraded_links:
                ext = self._degraded_loads(ext, None)
            kwargs["external_link_loads"] = ext
        return S.rescore_contention(self._skeleton(rt), **kwargs)

    def _degraded_loads(self, base: Dict[Tuple[int, int], float],
                        own: Optional[Dict[Tuple[int, int], float]]
                        ) -> Dict[Tuple[int, int], float]:
        """Re-cost degraded links into a tenant's external-load context: a
        directed edge at degradation factor ``d`` behaves as if it carried
        ``d x`` its actual bytes, so we add ``(d-1) x total_edge_bytes`` of
        phantom external load — inside :func:`~repro.core.simulator.
        link_contention` the edge then totals exactly ``d x (ext + own)``,
        the scaled-capacity semantics.  ``own`` is the tenant's own
        footprint (oracle mode); in ledger mode the ledger's ``link_loads``
        already hold the all-resident total.  Loads are integer-valued
        floats, so both derivations are exact and bit-identical."""
        out = dict(base)
        for e, d in sorted(self._degraded_links.items()):
            if own is None:
                total = self.ledger.link_loads.get(e, 0.0)
            else:
                total = base.get(e, 0.0) + own.get(e, 0.0)
            extra = (d - 1.0) * total
            if extra > 0.0:
                out[e] = out.get(e, 0.0) + extra
        return out

    def _rescore(self) -> None:
        """Reference oracle: score every resident against every other —
        O(residents^2 x flows) per pass."""
        hbm_clients = sum(1 for r in self._residents.values()
                          if r.placement.hbm_client)
        self._scores = {tid: self._score_tenant(rt, hbm_clients)
                        for tid, rt in self._residents.items()}
        self._phase_cache.clear()
        self._dirty = False

    def _rescore_dirty(self) -> None:
        """Ledger path: re-simulate only the tenants whose interference
        context changed — O(dirty x own flows) per pass."""
        led = self.ledger
        live = [t for t in led.take_dirty() if t in self._residents]
        for tid in live:
            self._scores[tid] = self._score_tenant(
                self._residents[tid], led.hbm_clients)
            self._phase_cache.pop(tid, None)
        led.counters.rescored += len(live)
        led.counters.reused += len(self._residents) - len(live)

    def _ensure_scores(self) -> None:
        """Bring ``_scores`` up to date, timing the pass for the metrics."""
        if self.ledger is None:
            if not self._dirty:
                return
            t0 = time.perf_counter()
            self._rescore()
        else:
            if not self.ledger.dirty:
                return
            t0 = time.perf_counter()
            self._rescore_dirty()
        self.metrics.scoring_pass_s.append(time.perf_counter() - t0)

    def _fps(self, tid: int) -> float:
        """Current effective throughput of a resident (iterations/s)."""
        self._ensure_scores()
        report = self._scores.get(tid)
        return report.fps if report else 0.0

    # -- lifecycle hooks (ledger/oracle invalidation) ----------------------
    def _tenant_admitted(self, rt: ResidentTenant) -> None:
        self._placement_version += 1
        if self.ledger is not None:
            self.ledger.add(rt.spec.tid, self._tenant_flows(rt),
                            hbm_client=rt.placement.hbm_client)
        else:
            self._dirty = True

    def _tenant_departed(self, tid: int) -> None:
        self._placement_version += 1
        self._flows.pop(tid, None)
        self._scores.pop(tid, None)
        self._skeletons.pop(tid, None)
        self._phase_cache.clear()      # decode HBM-client count changed
        self._iso_cache.pop(tid, None)
        if self.ledger is not None:
            self.ledger.remove(tid)
        else:
            self._dirty = True

    def _tenant_moved(self, rt: ResidentTenant) -> None:
        """Placement changed in place (defrag / failure migration / elastic
        resize): refresh the flow and skeleton caches and swap the ledger
        footprint."""
        self._placement_version += 1
        self._flows.pop(rt.spec.tid, None)
        self._skeletons.pop(rt.spec.tid, None)
        self._phase_cache.pop(rt.spec.tid, None)
        self._iso_cache.pop(rt.spec.tid, None)
        if self.ledger is not None:
            self.ledger.update(rt.spec.tid, self._tenant_flows(rt),
                               hbm_client=rt.placement.hbm_client)
        else:
            self._dirty = True

    # -- negative-probe memoization -----------------------------------------
    def _spec_key(self, spec: TenantSpec) -> Tuple:
        """The identity of a placement attempt — everything ``allocate``
        reads from a spec (model identity is throughput-, not
        placement-relevant).  Delegated to the policy: the default is the
        ``(n_cores, memory_bytes, bandwidth_cap)`` size class; vNPU refines
        it with the request topology's canonical shape key so
        heterogeneous asks with colliding size classes never share a memo
        entry (``PlacementPolicy.request_key``)."""
        return self.policy.request_key(spec)

    def _free_token(self):
        """Current free-pool identity for the probe memo: the policy's
        canonical token (vNPU: free-region shape + buddy multiset) or the
        scheduler's own placement-mutation counter as the exact fallback.

        Cached per placement version — every mutation that could change
        the policy token flows through this scheduler and bumps the
        version — so a drain pass over an unchanged pool costs one token
        derivation total, not one per queued spec."""
        cached = self._free_token_cache
        if cached is not None and cached[0] == self._placement_version:
            return cached[1]
        tok = self.policy.free_state_token()
        if tok is None:
            tok = ("v", self._placement_version)
        self._free_token_cache = (self._placement_version, tok)
        return tok

    def _probe_skip(self, spec: TenantSpec, defrag_now: bool) -> bool:
        """True when ``spec``'s size class is recorded as failing against
        the *current* pool, so re-attempting is provably pointless.

        A failure recorded with a defrag attempt covers plain retries too
        (its attempt set is a superset); a plain failure never excuses a
        defrag-eligible attempt — defragmentation depends on the resident
        arrangement, so those skips additionally require the placement
        version to be unchanged."""
        entry = self._probe_memo.get(self._spec_key(spec))
        if entry is None or entry[0] != self._free_token():
            return False
        if not defrag_now:
            return True
        return entry[1] and entry[2] == self._placement_version

    def _record_probe_failure(self, spec: TenantSpec,
                              defrag_covered: bool) -> None:
        """Record a fully-failed placement attempt (post-attempt state:
        a failed defrag may still have migrated residents, so the token is
        read *after* the attempts).

        ``defrag_covered`` must only be True when the defrag attempt made
        *no* moves: a defrag that migrated residents and still failed has
        made progress (it is bounded per event), and the next head retry
        could migrate further and succeed — suppressing it would diverge
        from the memo-less schedule."""
        self._probe_memo[self._spec_key(spec)] = (
            self._free_token(), defrag_covered, self._placement_version)

    # -- serving plane -----------------------------------------------------
    def _weights_resident(self, rt: ResidentTenant) -> bool:
        """Do this tenant's tensor-partitioned weight shards fit in its
        allocation's aggregate scratchpad?  Placement-only (no circular
        dependence on the HBM-client count); the same
        :func:`repro.core.simulator.weights_resident` formula the phase
        model applies, so the streamer census and the model agree."""
        p = rt.placement
        physical = p.tdm_physical or len(set(p.cores))
        return S.weights_resident(rt.graph.total_weight_bytes, physical,
                                  self.hw)

    def _hbm_share_keys(self) -> Dict[int, Tuple[int, int, int]]:
        """Byte-weighted decode HBM shares, snapshotted once per
        integration window: each attached tenant's ``(own, total,
        streamers)`` demand key, where demand is the bytes its decode step
        actually streams (weight shards unless they fit in aggregate
        scratchpad, plus its KV arena), ``total`` sums the demands of
        every tenant with work in flight, and ``streamers`` counts the
        busy weight-streaming tenants.  :meth:`_phase_model` turns the
        key into a port share via the convex blend ``(1-w)/streamers +
        w*own/total`` (``w = HBM_BYTE_WEIGHT``): a saturated FR-FCFS
        controller arbitrates between per-client round-robin slots (the
        equal-split term, which also guarantees a small client is never
        starved by a giant co-resident) and row-hit-first service that
        tracks offered load (the demand term: a 7B shard set earns
        proportionally more of the port than an embedding-sized
        co-resident).  Unlike a floored ``max(own/total, 1/streamers)``,
        the blend *conserves* the port: shares sum to one over the busy
        clients, so byte-weighting redistributes bandwidth instead of
        minting it.  An idle tenant is keyed as if it joined the pool:
        the rates it would see the moment work arrives.  A tenant grown
        past its weights-residency threshold drops its weight bytes from
        every total, which speeds *everyone's* decode — the cluster-wide
        payoff of elastic growth."""
        demands: Dict[int, Tuple[int, bool, bool]] = {}
        busy_total = 0
        n_streamers = 0
        for tid, rt in self._residents.items():
            if not self.plane.is_attached(tid):
                continue
            streams = not self._weights_resident(rt)
            d = self.plane.profile(tid).kv_arena_bytes
            if streams:
                d += rt.graph.total_weight_bytes
            busy = self.plane.busy(tid)
            demands[tid] = (d, busy, streams)
            if busy:
                busy_total += d
                if streams:
                    n_streamers += 1
        out = {}
        for tid, (d, busy, streams) in demands.items():
            if busy:
                total, nstr = busy_total, n_streamers
            else:   # as if it joined the pool right now
                total = busy_total + d
                nstr = n_streamers + (1 if streams else 0)
            out[tid] = (d, total, max(nstr, 1))
        return out

    def _phase_model(self, rt: ResidentTenant,
                     share: Tuple[int, int, int]) -> PhaseModel:
        """The tenant's current phase-aware serving rates, derived from its
        cached placement skeleton and contention-aware epoch score (cached
        per byte-weighted HBM-share key until the score or placement
        changes)."""
        tid = rt.spec.tid
        # scores first: a dirty pass clears/pops _phase_cache, so taking
        # the per-tid slot before it would store into an orphaned dict
        self._ensure_scores()
        per_tid = self._phase_cache.setdefault(tid, {})
        pm = per_tid.get(share)
        if pm is not None:
            return pm
        sk = self._skeleton(rt)
        report = self._scores.get(tid)
        if report is None:               # first window before any epoch
            report = S.rescore_contention(sk)
        iso = self._iso_cache.get(tid)
        if iso is None:
            iso = S.finish_tensor(sk).interval_cycles
            self._iso_cache[tid] = iso
        own, total, nstr = share
        pm = S.derive_phase_model(
            sk, report,
            proxy_seq=self.plane.profile(tid).proxy_seq,
            hbm_share=((1.0 - HBM_BYTE_WEIGHT) / nstr
                       + HBM_BYTE_WEIGHT * own / max(total, 1)),
            decode_hbm_clients=nstr, isolated_interval=iso)
        per_tid[share] = pm
        return pm

    def _fold_records(self, fold) -> None:
        """Aggregate a departed tenant's :class:`~repro.serve.plane.
        ServerFold` into the metrics.  Completed requests were already
        streamed through ``observe_request`` at finalize time; this books
        the arrival census, censored decode tokens, KV telemetry and — in
        record mode — the determinism gates' ``request_log``."""
        m = self.metrics
        if fold.records is not None:
            for rec in fold.records:
                m.requests_arrived += 1
                if not rec.completed:
                    m.tokens_generated += rec.tokens_out
                m.request_log.append(
                    (rec.tid, rec.rid, round(rec.ttft_s, 9),
                     round(rec.tpot_s, 9), rec.tokens_out, rec.preempts))
        else:
            m.requests_arrived += fold.n_requests
            m.tokens_generated += fold.censored_tokens
        m.kv_preemptions += fold.kv_stats.grow_oom
        m.kv_admit_oom += fold.kv_stats.admit_oom
        m.requests_dropped += fold.n_dropped

    def _check_pressure(self, now: float, evq: EventQueue) -> None:
        """Epoch hook of the elastic-resize controller: read each serving
        tenant's pressure signals and schedule RESIZE events under
        hysteresis (see :class:`ServingConfig`).

        Admission outranks elasticity: while tenants wait in the cluster
        queue, growth is suppressed — a resident scaling up would take the
        very cores a queued tenant needs (and the queued tenant's whole
        stream is worth more goodput than a resident's marginal speedup).
        Shrinks are always allowed; they feed the queue."""
        cfg = self.serving
        may_grow = not self._waiting
        for tid, rt in self._residents.items():
            if not self.plane.is_attached(tid):
                continue
            st = self._resize_state[tid]
            if now - st.last_resize_s < cfg.cooldown_s:
                continue
            sig = self.plane.pressure(tid)
            cur = rt.spec.n_cores
            queue_pressure = sig.queue_depth >= cfg.grow_queue_depth
            kv_pressure = (sig.kv_occupancy >= cfg.grow_kv_occupancy
                           or sig.kv_blocked)
            if not kv_pressure:
                st.kv_grow_tried = False      # pressure episode ended
            grow = may_grow and (queue_pressure
                                 or (kv_pressure and not st.kv_grow_tried))
            idle = (sig.queue_depth == 0 and not sig.kv_blocked
                    and sig.batch_fill <= cfg.shrink_batch_fill)
            if grow:
                st.idle_epochs = 0
                cap = max(int(st.orig_n_cores * cfg.grow_limit),
                          st.orig_n_cores)
                new = min(cap, cur + max(2, cur // 2))
                if new > cur:
                    evq.push(now, RESIZE, tid=tid, n_cores=new)
                    st.last_resize_s = now   # cooldown even if resize fails
                    if kv_pressure and not queue_pressure:
                        st.kv_grow_tried = True
            elif idle:
                st.idle_epochs += 1
                if st.idle_epochs >= cfg.shrink_epochs \
                        and cur > st.orig_n_cores:
                    new = max(st.orig_n_cores, cur - max(2, cur // 2))
                    evq.push(now, RESIZE, tid=tid, n_cores=new)
                    st.last_resize_s = now
                    st.idle_epochs = 0
            else:
                st.idle_epochs = 0

    def _do_resize(self, ev, now: float) -> None:
        """RESIZE event: drive the policy's elastic resize and charge the
        scratchpad re-warm pause like a migration (the vNPU's memory — RTT
        contents, KV arena — survives; only the cores change)."""
        rt = self._residents.get(ev.tid)
        if rt is None or not (self.plane and self.plane.is_attached(ev.tid)):
            return                     # departed while the event was queued
        self.metrics.n_resize_attempts += 1
        old_n = rt.spec.n_cores
        new_p, resized = self.policy.resize(rt.placement, ev.n_cores)
        if not resized and self._planner is not None \
                and ev.n_cores > old_n:
            # fragmentation-blocked grow: ask the exact planner for the
            # minimum-pause migration set that frees a big-enough
            # sub-topology next to the tenant, then retry once
            plan = self._planner.plan_resize(rt, ev.n_cores,
                                             self._residents)
            if plan is not None and self._apply_plan(plan, now):
                new_p, resized = self.policy.resize(rt.placement,
                                                    ev.n_cores)
                if resized:
                    self.metrics.n_resize_defrags += 1
        if not resized:
            return
        rt.placement = new_p
        # the spec objects in a trace are shared across policy runs —
        # replace, never mutate in place
        rt.spec = dataclasses.replace(rt.spec, n_cores=len(set(new_p.cores)))
        self.metrics.n_resizes += 1
        if rt.spec.n_cores > old_n:
            self.metrics.n_grows += 1
        else:
            self.metrics.n_shrinks += 1
        if self.tracer.enabled:
            self.tracer.instant("resized", "tenant", now, tid=ev.tid,
                                args={"old_n": old_n,
                                      "new_n": rt.spec.n_cores})
        rt.migrations += 1
        pause_cycles = self.policy.migration_cycles(
            rt.placement, rt.graph.total_weight_bytes,
            self.hw.hbm_bytes_per_cycle)
        rt.pause_until_s = max(rt.pause_until_s,
                               now + pause_cycles / self.hw.freq_hz)
        self._tenant_moved(rt)

    # -- time accounting ---------------------------------------------------
    def _advance(self, now: float) -> None:
        """Integrate utilization and per-tenant served iterations from the
        last event to ``now`` (seconds), and advance every serving tenant's
        continuous-batching server through its active window.  O(residents)
        plus at most one scoring pass plus the serving segments."""
        dt = now - self._last_t
        if dt <= 0:
            return
        self.metrics.util_integral += self.policy.utilization() * dt
        shares = self._hbm_share_keys() if self.plane is not None else {}
        entries = []
        for tid, rt in self._residents.items():
            active = dt
            if rt.pause_until_s > self._last_t:
                active -= min(rt.pause_until_s, now) - self._last_t
            if active > 0:
                rt.served_iterations += self._fps(tid) * active
            if self.plane is not None and self.plane.is_attached(tid):
                w0 = max(self._last_t, min(rt.pause_until_s, now))
                if now > w0:
                    entries.append((tid, w0,
                                    self._phase_model(rt, shares[tid])))
        if entries:
            # one batched call: the vector engine advances every tenant's
            # window in a single struct-of-arrays lockstep loop
            self.plane.advance_all(entries, now)
        self._last_t = now

    # -- admission ---------------------------------------------------------
    def _try_place(self, spec: TenantSpec, now: float,
                   evq: EventQueue, strict: bool = False) -> bool:
        """Attempt one placement through the policy (the MappingEngine, for
        vNPU); on success the tenant becomes resident and its departure is
        scheduled.  Returns False when the policy cannot place it."""
        try:
            placement = self.policy.allocate(spec, strict=strict)
        except AllocationError:
            return False
        rt = ResidentTenant(
            spec=spec, placement=placement,
            graph=get_serving_workload(spec.model),
            admit_s=now, depart_s=now + spec.duration_s)
        self._residents[spec.tid] = rt
        self._tenant_admitted(rt)
        if self.plane is not None and self.plane.attach(
                spec.tid, spec.model, spec.arrival_s, now, rt.depart_s):
            self._resize_state[spec.tid] = _ResizeState(
                orig_n_cores=spec.n_cores)
            self._phase_cache.clear()    # decode HBM-share totals changed
        evq.push(rt.depart_s, DEPARTURE, tid=spec.tid)
        self.metrics.n_admitted += 1
        self.metrics.queue_waits_s.append(now - spec.arrival_s)
        tr = self.tracer
        if tr.enabled:
            if now > spec.arrival_s:
                tr.span("queued", "tenant", spec.arrival_s,
                        now - spec.arrival_s, tid=spec.tid)
            tr.instant("admitted", "tenant", now, tid=spec.tid,
                       args={"model": spec.model,
                             "n_cores": spec.n_cores,
                             "strict": strict})
        return True

    def _charge_migration(self, rt: ResidentTenant, now: float) -> None:
        """Book one live migration: count it and pause the tenant for the
        scratchpad re-warm + routing-table reconfig (cycles -> seconds at
        ``hw.freq_hz``)."""
        rt.migrations += 1
        self.metrics.n_migrations += 1
        pause_cycles = self.policy.migration_cycles(
            rt.placement, rt.graph.total_weight_bytes,
            self.hw.hbm_bytes_per_cycle)
        rt.pause_until_s = max(rt.pause_until_s,
                               now + pause_cycles / self.hw.freq_hz)
        if self.tracer.enabled:
            self.tracer.instant(
                "migrated", "tenant", now, tid=rt.spec.tid,
                args={"pause_s": pause_cycles / self.hw.freq_hz,
                      "migrations": rt.migrations})
        self._tenant_moved(rt)

    def _defrag_for(self, spec: TenantSpec, now: float) -> bool:
        """Migrate residents (most-scattered first, compaction objective)
        until a *connected* placement for the pending request exists.
        Bounded by ``max_migrations_per_event``; returns True if any tenant
        moved."""
        if self.policy.can_place(spec, strict=True):
            return False   # nothing to defragment
        if self._planner is not None:
            plan = self._planner.plan_admission(spec, self._residents)
            if plan is not None:
                return self._apply_plan(plan, now)
            # no certified plan within bounds — fall through to greedy
        order = sorted(
            self._residents.values(),
            key=lambda r: S.avg_pairwise_hops(self.topo, r.placement.cores),
            reverse=True)
        moved_any = False
        migrations = 0
        for rt in order:
            if migrations >= self.max_migrations_per_event:
                break
            new_p, moved = self.policy.migrate(rt.placement)
            if not moved:
                continue
            migrations += 1
            moved_any = True
            rt.placement = new_p
            self._charge_migration(rt, now)
            if self.policy.can_place(spec, strict=True):
                break
        return moved_any

    def _apply_plan(self, plan: DefragPlan, now: float) -> bool:
        """Commit a defrag planner's migration set: install each planned
        mapping through the hypervisor and charge the usual migration
        pause.  Returns True iff any tenant moved."""
        moved = False
        for mv in plan.moves:
            rt = self._residents.get(mv.tid)
            if rt is None:              # pragma: no cover - defensive
                continue
            vnpu = self.policy.hyp.apply_mapping(mv.vmid, mv.result)
            rt.placement = dataclasses.replace(
                rt.placement, cores=tuple(sorted(vnpu.p_cores)), vnpu=vnpu)
            self.policy._register(rt.placement)
            self._charge_migration(rt, now)
            self.metrics.n_planned_moves += 1
            moved = True
        if moved:
            self.metrics.n_defrag_plans += 1
            if self.tracer.enabled:
                self.tracer.instant("defrag_plan", "defrag", now,
                                    args={"moves": len(plan.moves)})
        return moved

    def _fail_cores(self, cores: Sequence[int], now: float,
                    evq: Optional[EventQueue] = None) -> None:
        """Dead hardware: quarantine the cores through the policy, then
        live-migrate every resident touching them (``avoid=`` the dead
        set), charging the usual migration pause.  A tenant the policy
        cannot move keeps running degraded on its old cores — the model's
        stand-in for a stranded tenant awaiting operator action — unless a
        :class:`RecoveryConfig` is bound, in which case it is killed and
        recovered (checkpoint resume / retry queue, see
        :meth:`_fault_kill`)."""
        cores = tuple(int(c) for c in cores)
        self.policy.mark_failed(cores)
        self._placement_version += 1   # quarantine changes what can place
        # count each physical core's death once, however many failure
        # events name it (the policy's quarantine is idempotent too)
        newly_dead = set(cores) - self._failed_cores
        self._failed_cores |= newly_dead
        self.metrics.n_failed_cores += len(newly_dead)
        for c in sorted(newly_dead):
            self._core_down_since[c] = now    # MTTR clock starts
        if newly_dead and self.tracer.enabled:
            self.tracer.instant("core_fail", "chaos", now,
                                args={"cores": sorted(newly_dead)})
        dead = set(cores)
        for rt in list(self._residents.values()):
            if not dead & set(rt.placement.cores):
                continue
            new_p, moved = self.policy.migrate(rt.placement, avoid=cores)
            if moved:
                rt.placement = new_p
                self._charge_migration(rt, now)
            elif self.recovery is not None and evq is not None:
                self._fault_kill(rt, now, evq)

    def _repair_cores(self, cores: Sequence[int], now: float) -> None:
        """REPAIR event: return quarantined cores to service through the
        policy and close their MTTR intervals.  The placement-version bump
        invalidates the negative-probe memo (repair grows the free pool;
        for vNPU the canonical free-state token changes with the engine's
        regions, so stale negative entries can never mask the new
        capacity)."""
        back = {int(c) for c in cores} & self._failed_cores
        if not back:
            return
        self.policy.mark_repaired(sorted(back))
        self._placement_version += 1
        self._failed_cores -= back
        self.metrics.n_repaired_cores += len(back)
        for c in sorted(back):
            t0 = self._core_down_since.pop(c, None)
            if t0 is not None:
                self.metrics.mttr_sum_s += now - t0
                self.metrics.core_downtime_s += now - t0
                self.metrics.n_repairs += 1
                if self.tracer.enabled:
                    # one span per closed fail->repair window
                    self.tracer.span("core_down", "chaos", t0, now - t0,
                                     args={"core": c})

    def _fault_kill(self, rt: ResidentTenant, now: float,
                    evq: EventQueue) -> None:
        """A fault destroyed this tenant's placement and no migration
        target exists: release it and route it through recovery.  Training
        tenants re-arrive after the checkpoint-restore pause with the work
        since their last checkpoint boundary re-added; serving tenants
        re-arrive through the bounded exponential-backoff retry queue (or
        are dropped once the budget is exhausted).  Any in-flight requests
        are lost with the placement and counted."""
        tid = rt.spec.tid
        self._residents.pop(tid, None)
        requests_lost = 0
        if self.plane is not None and self.plane.is_attached(tid):
            fold = self.plane.detach(tid)
            self._fold_records(fold)
            requests_lost = fold.n_incomplete
            self._resize_state.pop(tid, None)
            self._phase_cache.clear()
        self.policy.release(rt.placement)
        self._tenant_departed(tid)
        self.metrics.tenant_iterations[tid] = rt.served_iterations
        self.metrics.tenant_active_s[tid] = max(now - rt.admit_s, 0.0)
        self.metrics.n_fault_kills += 1
        self.metrics.requests_fault_lost += requests_lost
        if self.tracer.enabled:
            self.tracer.span("resident", "tenant", rt.admit_s,
                             max(now - rt.admit_s, 0.0), tid=tid,
                             args={"end": "fault_kill",
                                   "migrations": rt.migrations})
            self.tracer.instant("fault_kill", "chaos", now, tid=tid,
                                args={"requests_lost": requests_lost})
        rc = self.recovery
        remaining = max(rt.depart_s - now, 0.0)
        if rt.spec.tenant_class == "train":
            # resume from the last periodic checkpoint: the work since
            # that boundary is redone, and the restore (scratchpad
            # re-warm + routing-table resharding — the same Fig.-11
            # arithmetic a migration pays) delays re-entry
            lost = math.fmod(max(now - rt.admit_s, 0.0),
                             rc.ckpt_interval_s)
            restore_s = self.policy.migration_cycles(
                rt.placement, rt.graph.total_weight_bytes,
                self.hw.hbm_bytes_per_cycle) / self.hw.freq_hz
            self.metrics.rework_s += lost
            self.metrics.rewarm_cost_s += restore_s
            self.metrics.n_ckpt_resumes += 1
            back = now + restore_s
            evq.push(back, ARRIVAL, spec=dataclasses.replace(
                rt.spec, arrival_s=back, duration_s=remaining + lost))
        else:
            attempt = self._retry_attempts.get(tid, 0)
            if attempt >= rc.retry_max or remaining <= 0.0:
                self.metrics.n_fault_drops += 1
                return
            self._retry_attempts[tid] = attempt + 1
            back = now + rc.retry_base_s * (2.0 ** attempt)
            self.metrics.n_fault_retries += 1
            evq.push(back, ARRIVAL, spec=dataclasses.replace(
                rt.spec, arrival_s=back, duration_s=remaining))

    # -- NoC-link degraded mode --------------------------------------------
    def _invalidate_scores(self) -> None:
        """Link state changed: every resident's contention context is
        stale (degradation is an overlay on the shared link loads), so
        force a full rescore whichever scoring mode is active."""
        self._phase_cache.clear()
        if self.ledger is not None:
            self.ledger.invalidate_all()
        else:
            self._dirty = True

    def _tenants_on_link(self, link: Tuple[int, int]) -> List[int]:
        """Resident tids whose own flows cross the directed edge, in tid
        order — identical in ledger and oracle mode (the ledger's
        footprints are :func:`~repro.core.simulator.flow_link_loads` of
        the same cached flows)."""
        out = []
        for tid in sorted(self._residents):
            fp = S.flow_link_loads(
                self.topo, self._tenant_flows(self._residents[tid]))
            if fp.get(link):
                out.append(tid)
        return out

    def _link_fault(self, ev, now: float) -> None:
        """LINK_FAIL / LINK_DEGRADE event: install (or worsen) the edge's
        degradation factor and re-score everyone.  For hard failures with
        recovery armed, residents whose own traffic crosses the edge are
        migrated off it (``avoid=`` its endpoints) — re-costing handles
        the ones that cannot move."""
        link = (int(ev.link[0]), int(ev.link[1]))
        factor = float(ev.factor) if ev.factor else 2.0
        self._degraded_links[link] = max(
            self._degraded_links.get(link, 1.0), factor)
        self.metrics.n_link_faults += 1
        if self.tracer.enabled:
            self.tracer.instant(
                "link_fail" if ev.kind == LINK_FAIL else "link_degrade",
                "chaos", now, args={"link": list(link), "factor": factor})
        self._invalidate_scores()
        if ev.kind != LINK_FAIL or self.recovery is None \
                or not self.recovery.migrate_on_link_fail:
            return
        for tid in self._tenants_on_link(link):
            rt = self._residents.get(tid)
            if rt is None:
                continue
            new_p, moved = self.policy.migrate(rt.placement, avoid=link)
            if moved:
                rt.placement = new_p
                self._charge_migration(rt, now)
                self.metrics.n_link_migrations += 1

    def _link_repair(self, ev, now: float) -> None:
        """LINK_REPAIR event: the edge is back at full bandwidth."""
        link = (int(ev.link[0]), int(ev.link[1]))
        if self._degraded_links.pop(link, None) is not None:
            self.metrics.n_link_repairs += 1
            if self.tracer.enabled:
                self.tracer.instant("link_repair", "chaos", now,
                                    args={"link": list(link)})
            self._invalidate_scores()

    def _reject(self, spec: TenantSpec, wait_s: float) -> None:
        """A tenant that gave up: censor its wait into the latency metrics
        (otherwise policies that reject more would *look* faster)."""
        self.metrics.n_rejected += 1
        self.metrics.queue_waits_s.append(wait_s)
        if self.tracer.enabled:
            self.tracer.span("queued", "tenant", spec.arrival_s, wait_s,
                             tid=spec.tid, args={"end": "rejected"})

    def _expire_waiting(self, now: float) -> None:
        kept = []
        for spec, enq in self._waiting:
            if now - spec.arrival_s > spec.sla_wait_s:
                self._reject(spec, spec.sla_wait_s)
            else:
                kept.append((spec, enq))
        self._waiting = kept

    def _admission_order(self) -> List[Tuple[TenantSpec, float]]:
        """The queue in drain order.  ``admission="fifo"`` keeps arrival
        order (with backfill); ``admission="sla"`` drains earliest-deadline
        first, where a serving tenant's deadline is tightened by its
        *predicted TTFT at current load* — the plane's observed prefill
        rate applied to the profile's mean prompt — so tenants whose first
        request would otherwise blow its TTFT target are placed (and
        defragmented for) ahead of slack-rich ones."""
        if self.admission != "sla":
            return self._waiting
        def deadline(item):
            spec, _ = item
            d = spec.arrival_s + spec.sla_wait_s
            if self.plane is not None:
                profile = get_profile(spec.model)
                if profile is not None:
                    d -= self.plane.predicted_prefill_s(profile)
            return (d, spec.arrival_s, spec.tid)
        return sorted(self._waiting, key=deadline)

    def _drain_queue(self, now: float, evq: EventQueue) -> None:
        """Admit as many waiting tenants as now fit (FIFO with backfill);
        one defrag attempt on behalf of the queue head.

        With ``probe_memo`` on, a queued spec whose size class last failed
        against an identical free pool is skipped outright — a drain pass
        over an unchanged pool costs O(queue) token comparisons instead of
        O(queue) mapping solves, with identical admissions (negative
        probes are pure functions of the pool, pinned by the CI gate)."""
        self._expire_waiting(now)
        still: List[Tuple[TenantSpec, float]] = []
        for i, (spec, enq) in enumerate(self._admission_order()):
            defrag_now = i == 0 and self.defrag
            if self.probe_memo and self._probe_skip(spec, defrag_now):
                self.metrics.n_probe_skips += 1
                still.append((spec, enq))
                continue
            v0 = self._placement_version
            if self._try_place(spec, now, evq, strict=True):
                continue
            if defrag_now:
                # one defrag attempt on behalf of the queue head
                if self._defrag_for(spec, now) and \
                        self._try_place(spec, now, evq, strict=True):
                    continue
            if self._try_place(spec, now, evq):   # relaxed (fragmented ok)
                continue
            if self.probe_memo:
                self._record_probe_failure(
                    spec, defrag_now and self._placement_version == v0)
            still.append((spec, enq))
        self._waiting = still

    # -- incremental drive (the fleet pod protocol) ------------------------
    def begin(self, trace_name: str = "", driven: bool = False) -> None:
        """Arm the event loop for incremental driving.  ``driven=True`` is
        fleet mode: the epoch chain re-arms even over an empty queue (more
        arrivals keep coming from the router), so ``advance_to`` must be
        given explicit barrier times.

        One-shot like :meth:`run`: the policy's placement state survives,
        so reuse would mix tenants across traces."""
        if self._began or self._residents or self._waiting \
                or self._last_t > 0.0:
            raise RuntimeError(
                "ClusterScheduler is one-shot: the policy's placement "
                "state survives a run, so reuse would mix tenants across "
                "traces — build a fresh scheduler+policy per run (as "
                "compare_policies does)")
        self._began = True
        self._driven = driven
        self.metrics = ClusterMetrics(policy=self.policy.name,
                                      trace=trace_name,
                                      rescore_mode=self.rescore_mode)
        if self.plane is not None:
            # completions stream straight into the run's metrics the
            # moment they finalize (exact counters + percentile sketches)
            self.plane.sink = self.metrics.observe_request
        self._evq = EventQueue()
        if self.epoch_s > 0:
            self._evq.push(self.epoch_s, EPOCH)

    def feed(self, specs: Sequence[TenantSpec]) -> None:
        """Queue tenant arrivals (any time, including before ``_last_t`` —
        a migrant whose checkpoint-transfer completed mid-window is
        processed deterministically at its own timestamp)."""
        for spec in specs:
            self._evq.push(spec.arrival_s, ARRIVAL, spec=spec)

    def inject_failures(
            self, failures: Sequence[Tuple[float, Sequence[int]]]) -> None:
        """Queue ``(time_s, dead core ids)`` FAILURE events."""
        for fail_t, dead in failures:
            self._evq.push(fail_t, FAILURE, cores=tuple(dead))

    def inject_chaos(self, events) -> None:
        """Queue a fault plan's cluster-scope events (core bursts with
        their repairs, directed-link failures/stragglers with theirs).

        Duck-typed on ``kind`` / ``t_s`` / ``cores`` / ``link`` /
        ``factor`` — see :class:`repro.chaos.plan.FaultEvent`; the kind
        strings are matched literally so :mod:`repro.chaos` never needs
        to import the scheduler (and vice versa)."""
        for fe in events:
            kind = fe.kind
            if kind == "core-fail":
                self._evq.push(fe.t_s, FAILURE, cores=tuple(fe.cores))
            elif kind == "core-repair":
                self._evq.push(fe.t_s, REPAIR, cores=tuple(fe.cores))
            elif kind == "link-fail":
                self._evq.push(fe.t_s, LINK_FAIL, link=tuple(fe.link),
                               factor=float(fe.factor))
            elif kind == "link-degrade":
                self._evq.push(fe.t_s, LINK_DEGRADE, link=tuple(fe.link),
                               factor=float(fe.factor))
            elif kind == "link-repair":
                self._evq.push(fe.t_s, LINK_REPAIR, link=tuple(fe.link))
            else:
                raise ValueError(
                    f"unknown chaos event kind {kind!r} (fleet-scope "
                    f"events belong to the fleet driver, not the "
                    f"scheduler)")

    def resident_specs(self) -> Dict[int, TenantSpec]:
        """Current residents' specs (router-facing snapshot input)."""
        return {tid: rt.spec for tid, rt in self._residents.items()}

    def drain(self) -> None:
        """Mark the pod as draining (rolling upgrade / decommission): a
        router hint — the loop itself keeps processing whatever is already
        queued; pair with :meth:`evacuate` to hand residents back."""
        self.draining = True

    def undrain(self) -> None:
        """Return the pod to service after a completed drain."""
        self.draining = False

    def evacuate(self, now: Optional[float] = None) -> List[TenantSpec]:
        """Hand every resident and queued tenant back to the caller (the
        fleet router) as re-admittable specs, releasing their placements.

        Residents return with ``duration_s`` clamped to their remaining
        service time (their serving folds are booked here, like a
        departure); queued tenants return verbatim — their SLA clock keeps
        running from the original arrival.  Deterministic order (residents
        by tid, then the queue in its drain order).  The stale DEPARTURE
        events left in the queue are tolerated by the loop."""
        now = self._last_t if now is None else now
        out: List[TenantSpec] = []
        for tid in sorted(self._residents):
            rt = self._residents.pop(tid)
            if self.plane is not None and self.plane.is_attached(tid):
                self._fold_records(self.plane.detach(tid))
                self._resize_state.pop(tid, None)
                self._phase_cache.clear()
            self.policy.release(rt.placement)
            self._tenant_departed(tid)
            self.metrics.tenant_iterations[tid] = rt.served_iterations
            self.metrics.tenant_active_s[tid] = max(now - rt.admit_s, 0.0)
            self.metrics.n_evacuated += 1
            if self.tracer.enabled:
                self.tracer.span("resident", "tenant", rt.admit_s,
                                 max(now - rt.admit_s, 0.0), tid=tid,
                                 args={"end": "evacuated",
                                       "migrations": rt.migrations})
            remaining = max(rt.depart_s - now, 0.0)
            out.append(dataclasses.replace(rt.spec, arrival_s=now,
                                           duration_s=remaining))
        for spec, _enq in self._waiting:
            out.append(spec)
        self._waiting = []
        return out

    def advance_to(self, t: Optional[float] = None) -> None:
        """Process every queued event with ``time <= t`` (all of them when
        ``t`` is None — the classic run-to-completion), then integrate
        utilization and the serving plane up to ``t`` exactly, so a
        barrier snapshot reflects the barrier instant."""
        if t is None and self._driven:
            raise ValueError("driven mode needs explicit barrier times "
                             "(the epoch chain re-arms forever)")
        evq = self._evq
        while evq and (t is None or evq.peek().time <= t):
            ev = evq.pop()
            now = ev.time
            self.metrics.n_events += 1
            self._advance(now)
            if ev.kind == ARRIVAL:
                self.metrics.n_arrived += 1
                spec = ev.spec
                # strict (connected) first; defragment; only then accept a
                # fragmented placement — locality is worth one defrag pass.
                # The probe memo short-circuits the whole cascade when this
                # size class is recorded as failing against this very pool
                # (common once a big ask is queued and more keep arriving).
                defrag_now = self.defrag and not self._waiting
                if self.probe_memo and self._probe_skip(spec, defrag_now):
                    self.metrics.n_probe_skips += 1
                    self._waiting.append((spec, now))
                else:
                    v0 = self._placement_version
                    placed = self._try_place(spec, now, evq, strict=True)
                    if not placed and defrag_now:
                        if self._defrag_for(spec, now):
                            placed = self._try_place(spec, now, evq,
                                                     strict=True)
                    if not placed:
                        placed = self._try_place(spec, now, evq)
                    if not placed:
                        if self.probe_memo:
                            self._record_probe_failure(
                                spec,
                                defrag_now
                                and self._placement_version == v0)
                        self._waiting.append((spec, now))
            elif ev.kind == DEPARTURE:
                rt = self._residents.get(ev.tid)
                # a fault-killed-and-recovered tenant re-enters under its
                # own tid with a *later* departure — the stale DEPARTURE
                # from its first life must not clip the resumed one (for
                # live residents ev.time is exactly rt.depart_s, the very
                # float this event was pushed with)
                if rt is not None and rt.depart_s == now:
                    self._residents.pop(ev.tid)
                    if self.plane is not None and \
                            self.plane.is_attached(ev.tid):
                        self._fold_records(self.plane.detach(ev.tid))
                        self._resize_state.pop(ev.tid, None)
                        self._phase_cache.clear()
                    self.policy.release(rt.placement)
                    self._tenant_departed(ev.tid)
                    self.metrics.tenant_iterations[ev.tid] = \
                        rt.served_iterations
                    self.metrics.tenant_active_s[ev.tid] = \
                        max(rt.depart_s - rt.admit_s, 0.0)
                    if self.tracer.enabled:
                        self.tracer.span(
                            "resident", "tenant", rt.admit_s,
                            max(rt.depart_s - rt.admit_s, 0.0), tid=ev.tid,
                            args={"end": "departed",
                                  "migrations": rt.migrations})
                self._drain_queue(now, evq)
            elif ev.kind == FAILURE:
                self._fail_cores(ev.cores, now, evq)
                self._drain_queue(now, evq)
            elif ev.kind == REPAIR:
                self._repair_cores(ev.cores, now)
                self._drain_queue(now, evq)   # repaired capacity admits
            elif ev.kind in (LINK_FAIL, LINK_DEGRADE):
                self._link_fault(ev, now)
            elif ev.kind == LINK_REPAIR:
                self._link_repair(ev, now)
            elif ev.kind == RESIZE:
                self._do_resize(ev, now)
                self._drain_queue(now, evq)   # a shrink freed cores
            elif ev.kind == EPOCH:
                self._drain_queue(now, evq)
                self._ensure_scores()
                self.metrics.samples.append(EpochSample(
                    t=now,
                    utilization=self.policy.utilization(),
                    n_resident=len(self._residents),
                    n_queued=len(self._waiting),
                    agg_fps=sum(self._fps(t) for t in self._residents)))
                if self.tracer.enabled:
                    self._trace_epoch(now)
                if self.plane is not None:
                    self._check_pressure(now, evq)
                # re-arm while the system still has work in flight (in
                # driven mode always: the router keeps feeding arrivals)
                if evq or self._driven:
                    evq.push(now + self.epoch_s, EPOCH)
        if t is not None and t > self._last_t:
            # integrate to the barrier instant so the snapshot the router
            # reads (utilization, queue depths, serving pressure) is at t
            self._advance(t)

    def _trace_epoch(self, now: float) -> None:
        """Epoch-boundary observability: occupancy/link-heat timelines
        (:class:`~repro.obs.timeline.TimelineSampler`), the tenant census,
        and the MappingEngine's cumulative cache telemetry as counter
        tracks.  Every input is a pure read of state the epoch scoring
        just computed — the mapping engine has no sim-time access of its
        own, so its hit/miss/escalation counters surface here."""
        sample = self.metrics.samples[-1]
        self.timeline.sample(
            now, n_total=self.topo.num_nodes,
            n_free=len(self.policy.free_cores()),
            n_failed=len(self._failed_cores),
            link_loads=self.ledger.link_loads
            if self.ledger is not None else None)
        self.tracer.counter("tenants", now,
                            {"resident": sample.n_resident,
                             "queued": sample.n_queued})
        counters = getattr(self.policy, "engine_counters", None)
        if callable(counters):
            ec = counters()
            self.tracer.counter(
                "engine_cache", now,
                {"hits": ec.get("cache_hits", 0),
                 "misses": ec.get("cache_misses", 0),
                 "escalations": ec.get("exact_escalations", 0)})

    def finish(self) -> ClusterMetrics:
        """Close the run: censor leftover queued tenants as rejected, stamp
        the horizon, collect engine/ledger telemetry."""
        # tenants still waiting when the trace ends count as rejected;
        # censor their wait at what they actually endured (or their SLA)
        for spec, enq in self._waiting:
            self._reject(spec, min(max(self._last_t - spec.arrival_s, 0.0),
                                   spec.sla_wait_s))
        self._waiting = []
        self.metrics.horizon_s = self._last_t
        # close still-open core-downtime intervals at the horizon (their
        # MTTR interval never closed, so only downtime is booked)
        for c in sorted(self._core_down_since):
            self.metrics.core_downtime_s += max(
                self._last_t - self._core_down_since[c], 0.0)
            if self.tracer.enabled:
                self.tracer.span(
                    "core_down", "chaos", self._core_down_since[c],
                    max(self._last_t - self._core_down_since[c], 0.0),
                    args={"core": c, "open": True})
        self._core_down_since = {}
        self.metrics.n_cores_total = self.topo.num_nodes
        if self.plane is not None:
            self.metrics.peak_live_records = self.plane.peak_live_records
        counters = getattr(self.policy, "engine_counters", None)
        if callable(counters):
            self.metrics.engine_counters = counters()
        if self.ledger is not None:
            self.metrics.ledger_counters = self.ledger.counters.as_dict()
        return self.metrics

    # -- main loop ---------------------------------------------------------
    def run(self, trace: Sequence[TenantSpec],
            trace_name: str = "",
            failures: Sequence[Tuple[float, Sequence[int]]] = ()
            ) -> ClusterMetrics:
        """Replay ``trace`` (plus optional ``failures``: ``(time_s, dead
        core ids)`` pairs) to completion and return the metrics.

        One-shot: the policy's placement state survives a run, so reuse
        would mix tenants across traces — build a fresh scheduler+policy
        per run (as :func:`compare_policies` does).  Composed from the
        incremental-drive protocol (begin / feed / advance_to / finish)
        with a single run-to-completion advance — event order, and so the
        whole trajectory, is identical to the historical one-shot loop.
        """
        self.begin(trace_name=trace_name)
        self.feed(trace)
        self.inject_failures(failures)
        self.advance_to(None)
        return self.finish()


def compare_policies(policies: Sequence[PlacementPolicy],
                     trace: Sequence[TenantSpec],
                     hw: Optional[HWConfig] = None,
                     trace_name: str = "",
                     **sched_kwargs) -> List[ClusterMetrics]:
    """Run the same trace through several policies (fresh scheduler each)."""
    out = []
    for policy in policies:
        sched = ClusterScheduler(policy, hw=hw, **sched_kwargs)
        out.append(sched.run(trace, trace_name=trace_name))
    return out
