"""GPipe-style pipeline parallelism over the ``pod`` axis.

For multi-pod training an alternative to pure DP-across-pods: pods hold
disjoint layer ranges and microbatches stream through a
`collective_permute` pipeline.  Implemented as a generic combinator over a
per-stage function; the scan over (microbatches + bubble steps) gives the
classic (P-1)/(P-1+m) bubble fraction.

This is an opt-in recipe (examples + §Perf candidates), not the default
mesh layout — the dry-run's baseline keeps pods data-parallel.
"""
from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_forward(stage_fn: Callable, x_micro: jnp.ndarray, *,
                     mesh: Mesh, axis: str = "pod",
                     stage_params=None) -> jnp.ndarray:
    """Run ``stage_fn(params_local, x)`` as a P-stage pipeline.

    x_micro: (n_micro, micro_batch, ...) — microbatches stream in sequence.
    stage_params: pytree whose leading dim is the stage count (sharded over
    ``axis``).  Returns the pipeline output microbatches (same shape),
    valid after the (P-1)-step fill.
    """
    Pn = mesh.shape[axis]
    n_micro = x_micro.shape[0]

    def body(params_l, xm):
        sidx = jax.lax.axis_index(axis)
        total = n_micro + Pn - 1
        perm = [(i, i + 1) for i in range(Pn - 1)]

        def step(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (others use the permuted buffer)
            feed = jnp.where(t < n_micro, t, n_micro - 1)
            x_in = jnp.where(sidx == 0, xm[feed], buf)
            y = stage_fn(jax.tree.map(lambda a: a[0], params_l), x_in)
            buf_next = jax.lax.ppermute(y, axis, perm)
            # last stage emits after the fill
            emit = t - (Pn - 1)
            emit_ok = (emit >= 0) & (sidx == Pn - 1)
            outs = jax.lax.cond(
                emit_ok,
                lambda o: o.at[jnp.maximum(emit, 0)].set(y),
                lambda o: o, outs)
            return (buf_next, outs), None

        buf0 = jnp.zeros_like(xm[0])
        outs0 = jnp.zeros_like(xm)
        (_, outs), _ = jax.lax.scan(step, (buf0, outs0),
                                    jnp.arange(total))
        # broadcast final outputs from the last stage to all pods (masked sum)
        outs = jnp.where(sidx == Pn - 1, outs, jnp.zeros_like(outs))
        outs = jax.lax.psum(outs, axis)
        return outs

    return shard_map(body, mesh=mesh,
                     in_specs=(P(axis), P()),
                     out_specs=P(),
                     check_rep=False)(stage_params, x_micro)


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    """Classic GPipe bubble: (P-1) / (P-1+m)."""
    return (n_stages - 1) / (n_stages - 1 + n_micro)
