"""Gradient compression: int8 block-quantized gradients with error feedback.

Distributed-optimization trick for the multi-pod mesh: quantizing gradients
to int8 before the data-parallel reduction cuts cross-pod (DCN/ICI) gradient
bytes 4x.  Error feedback (Seide et al.; EF21-style) accumulates the
quantization residual locally and re-injects it next step, preserving
convergence.

Under GSPMD we express this as quantize -> dequantize around the gradient
tree: XLA performs the all-reduce on the *reconstructed* tensors, so the
numerics are exactly what a real int8 collective would produce, while the
wire-format claim (4x) is validated by the unit tests on the quantizer
itself.  A shard_map psum of the int8 payload is provided for meshes where
the collective should be explicit.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..train.optimizer import QBLOCK, dequantize_q8, quantize_q8


def compress_tree(grads):
    """Quantize every leaf; returns (quantized_tree, recon_tree)."""
    q = jax.tree.map(quantize_q8, grads)
    recon = jax.tree.map(
        lambda qt, g: dequantize_q8(qt, g.shape[-1] if g.ndim else 1
                                    ).reshape(g.shape).astype(g.dtype),
        q, grads)
    return q, recon


def make_error_feedback_compressor():
    """Returns (compress(grads, residual) -> (grads', residual'), init_fn).

    grads' = Q(grads + residual); residual' = (grads + residual) - grads'.
    """

    def init(grads):
        return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def compress(grads, residual):
        def one(g, r):
            x = g.astype(jnp.float32) + r
            qt = quantize_q8(x)
            recon = dequantize_q8(qt, x.shape[-1] if x.ndim else 1)
            recon = recon.reshape(x.shape)
            return recon.astype(g.dtype), x - recon
        flat = jax.tree.map(one, grads, residual)
        new_g = jax.tree.map(lambda t: t[0], flat,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_r = jax.tree.map(lambda t: t[1], flat,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_g, new_r

    return compress, init


def compression_ratio(grads) -> float:
    """Wire bytes: int8 payload + fp32 scales vs fp32 gradients."""
    fp = sum(x.size * 4 for x in jax.tree.leaves(grads))
    q = 0
    for x in jax.tree.leaves(grads):
        n = x.shape[-1] if x.ndim else 1
        blocks = -(-n // QBLOCK)
        q += x.size // max(n, 1) * blocks * (QBLOCK * 1 + 4)
    return fp / max(q, 1)
