from . import sharding
from .compression import (compress_tree, make_error_feedback_compressor,
                          compression_ratio)
from .seqparallel import seq_parallel_ssd
from .pipeline import pipeline_forward, bubble_fraction
