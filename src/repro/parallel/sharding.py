"""Logical-axis -> mesh-axis sharding rules (MaxText-style indirection).

One model definition, any mesh.  Params carry logical axis names (see
models/*.py ``*_init``); this module maps them to PartitionSpecs for a given
mesh and parallelism recipe.

Baseline recipe (paper-faithful tenant layout; §Perf iterates on it):
  * vocab / fused-head / ff / expert dims  -> "model"   (TP / EP)
  * d_model (param) dim                    -> "data"    (FSDP / ZeRO-3)
  * batch                                  -> ("pod", "data") when multi-pod
  * attention q-sequence + split-KV cache  -> "model"   (inside shard_map /
                                               decode constraints)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def is_multi_pod(mesh: Mesh) -> bool:
    return "pod" in mesh.axis_names


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if is_multi_pod(mesh) else ("data",)


def param_rules(mesh: Mesh, *, fsdp: bool = True) -> Dict[str, Any]:
    """fsdp=True: ZeRO-3 baseline (d_model dim sharded over data; per-layer
    all-gathers).  fsdp=False: TP/EP-only recipe — params replicated over
    data except expert hidden dims, which shard over data with activation
    psums (no weight gathers at all)."""
    return {
        "vocab": "model",
        "embed": "data" if fsdp else None,
        "heads": "model",
        "kv_heads": "model",
        "ff": "model",
        "moe_ff": None if fsdp else "data",
        "expert": "model",
        "layers": None,
        None: None,
    }


def activation_rules(mesh: Mesh) -> Dict[str, Any]:
    return {
        "batch": batch_axes(mesh),
        "seq": "model",
        "vocab_act": "model",
        "heads_act": "model",
    }


def logical_to_spec(axes: Tuple, rules: Dict[str, Any]) -> P:
    return P(*[rules.get(a) for a in axes])


def param_specs(logical_axes, rules: Dict[str, Any]):
    """Map a pytree of logical-axis tuples to a pytree of PartitionSpecs."""
    return jax.tree.map(lambda t: logical_to_spec(t, rules), logical_axes,
                        is_leaf=lambda t: isinstance(t, tuple))


def named_shardings(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


# ---------------------------------------------------------------------------
# cache / batch specs (decode)
# ---------------------------------------------------------------------------

def _axes_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fit(mesh: Mesh, axes, dim_size: int):
    """Use ``axes`` for a dim only if the dim is divisible by their size
    (long_500k has global_batch=1 — unshardable over 16-way data)."""
    return axes if dim_size % _axes_size(mesh, axes) == 0 else None


def cache_spec_for(leaf_path: str, shape, mesh: Mesh) -> P:
    """Sharding for decode-cache leaves.

    KV caches (L, B, S, KV, hd): batch over data axes, *sequence over model*
    (split-KV).  SSM states (L, B, H, P, N): heads over model.  Conv tails
    and cross-attention caches: batch only.  Leading dim = stacked layers
    (unsharded).  Dims that don't divide the mesh axes stay replicated.
    """
    ba = batch_axes(mesh)
    ndim = len(shape)
    if leaf_path in ("k", "v"):
        return P(None, _fit(mesh, ba, shape[1]),
                 _fit(mesh, "model", shape[2]), None, None)
    if leaf_path == "state":
        return P(None, _fit(mesh, ba, shape[1]),
                 _fit(mesh, "model", shape[2]), None, None)
    if leaf_path in ("cross_k", "cross_v", "conv_x", "conv_BC"):
        return P(None, _fit(mesh, ba, shape[1]), *([None] * (ndim - 2)))
    return P(*([None] * ndim))


def cache_specs(cache_shapes, mesh: Mesh):
    """Build PartitionSpecs for the (stacked) decode cache pytree."""
    def one(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        return cache_spec_for(name, leaf.shape, mesh)
    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def batch_specs(batch_shapes, mesh: Mesh):
    """Input batches: shard the leading (batch) dim over (pod, data)."""
    ba = batch_axes(mesh)

    def one(leaf):
        if leaf.ndim == 0:
            return P()
        return P(_fit(mesh, ba, leaf.shape[0]), *([None] * (leaf.ndim - 1)))
    return jax.tree.map(one, batch_shapes)
