"""Sequence-parallel SSD scan: shard the 524k-token sequence across mesh
devices and chain SSM states through `collective_permute` (SP for the
long_500k shape).

Two-pass formulation (linear-recurrence prefix over devices):

  pass 1: each device runs its local chunk scan from a zero state,
          producing its local final state S_i and total decay D_i.
  chain:  an M-step ppermute pipeline forms the exclusive prefix
          state_in_i = sum_{j<i} S_j * prod_{j<k<i} D_k.
  pass 2: re-run the local scan seeded with state_in_i.

Pass 2 recomputes the local work (the classic parallel-scan 2x trade), so
wall-clock = 2x local + M p2p hops instead of 1x serial over the whole
sequence — a 8x win at M=16 shards.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..models.ssd import ssd_scan_ref


def _local_decay(dt: jnp.ndarray, A: jnp.ndarray) -> jnp.ndarray:
    """Total per-head decay of a local sequence shard: exp(sum_t dt_t * A)."""
    return jnp.exp(jnp.einsum("bsh,h->bh", dt, A))


def seq_parallel_ssd(x, dt, A, B, C, *, chunk: int, mesh: Mesh,
                     axis: str = "data") -> jnp.ndarray:
    """x: (b,S,H,P); dt: (b,S,H); B/C: (b,S,G,N).  S sharded over ``axis``.

    Returns y: (b,S,H,P) (same sharding).  Exact: matches the single-device
    ssd_scan_ref (tests/test_seqparallel.py).
    """
    M = mesh.shape[axis]

    def body(x_l, dt_l, A_r, B_l, C_l):
        # pass 1: local state from zero init
        _, s_local = ssd_scan_ref(x_l, dt_l, A_r, B_l, C_l, chunk,
                                  return_state=True)
        d_local = _local_decay(dt_l, A_r)                   # (b,H)

        # exclusive prefix chain: state_in_i = S_{i-1} + D_{i-1}*state_in_{i-1}
        # as an (M-1)-hop ppermute pipeline (device 0 receives zeros).
        perm = [(i, i + 1) for i in range(M - 1)]
        carry = jnp.zeros_like(s_local)
        for _ in range(M - 1):
            send = s_local + carry * d_local[..., None, None]
            carry = jax.lax.ppermute(send, axis, perm)
        state_in = carry

        # pass 2: seeded local scan (the 2x recompute of parallel scan)
        y, _ = ssd_scan_ref(x_l, dt_l, A_r, B_l, C_l, chunk,
                            init_state=state_in, return_state=True)
        return y

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(None, axis, None, None), P(None, axis, None),
                  P(), P(None, axis, None, None), P(None, axis, None, None)),
        out_specs=P(None, axis, None, None),
        check_rep=False,
    )(x, dt, A, B, C)
