from .ops import matmul, flash_attention, ssd_scan, decode_attention
from . import ref
