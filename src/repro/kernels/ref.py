"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth
that tests/test_kernels.py sweeps shapes/dtypes against).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """(M,K) @ (K,N) with fp32 accumulation, output in x.dtype."""
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)
                   ).astype(x.dtype)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True) -> jnp.ndarray:
    """q,k,v: (B,H,S,hd) -> (B,H,S,hd); plain softmax attention in fp32."""
    B, H, S, hd = q.shape
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def ssd_scan_kernel_ref(x, dt, A, B, C, chunk: int):
    """Single-group SSD oracle; x (b,S,H,P), dt (b,S,H), A (H), B/C (b,S,N).

    Thin wrapper over models.ssd.ssd_scan_ref (the model-level reference).
    """
    from ..models.ssd import ssd_scan_ref
    return ssd_scan_ref(x, dt, A, B[:, :, None, :], C[:, :, None, :], chunk)


def decode_attention_ref(q, k, v, length: int) -> jnp.ndarray:
    """q: (B,H,hd); k,v: (B,S,H,hd); attend to positions < length."""
    B, S, H, hd = k.shape
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    valid = jnp.arange(S) < length
    s = jnp.where(valid[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
