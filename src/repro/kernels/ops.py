"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True on non-TPU backends (this container is CPU:
the kernel bodies execute in Python via the Pallas interpreter, which is
how tests validate them); on TPU they lower to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .decode_attention import decode_attention as _decode
from .flash_attention import flash_attention as _flash
from .ssd_scan import ssd_scan as _ssd
from .streamed_matmul import streamed_matmul as _matmul


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def matmul(x, w, *, block_m=256, block_n=256, block_k=512, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _matmul(x, w, block_m=block_m, block_n=block_n, block_k=block_k,
                   interpret=interpret)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal=True, block_q=256, block_k=256,
                    interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _flash(q, k, v, causal=causal, block_q=block_q, block_k=block_k,
                  interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, *, chunk=256, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _ssd(x, dt, A, B, C, chunk=chunk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("length", "block_s",
                                             "interpret"))
def decode_attention(q, k, v, length, *, block_s=512, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _decode(q, k, v, length, block_s=block_s, interpret=interpret)
