"""Mamba-2 SSD chunk-scan Pallas kernel (TPU target).

One grid step = one (batch, head, chunk) tile.  The chunk axis is the
innermost, *sequential* grid dimension: the running SSM state (P x N) lives
in VMEM scratch and persists across chunk iterations of the same (b, h) —
the TPU-native equivalent of the paper's scratchpad-resident data flow
(state never round-trips HBM between chunks).

Intra-chunk math matches models.ssd.ssd_scan_ref for n_groups=1, with the
(q x q) decay matrix built in VMEM; the MXU sees three (q x q) / (q x P) /
(q x N) matmuls per tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import tpu_compiler_params


def _ssd_kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, y_ref, state_ref, *,
                chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)          # (q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (q,)
    A = A_ref[0]                                  # ()
    Bm = B_ref[0, 0].astype(jnp.float32)         # (q, N)
    Cm = C_ref[0, 0].astype(jnp.float32)         # (q, N)

    dA = dt * A                                   # (q,)
    cum = jnp.cumsum(dA)                          # (q,)
    xdt = x * dt[:, None]

    # intra-chunk: L[i,j] = exp(cum_i - cum_j) for i >= j
    diff = cum[:, None] - cum[None, :]
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(iota_i >= iota_j, jnp.exp(diff), 0.0)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))  # (q,q)
    y = jax.lax.dot_general(scores * L, xdt, (((1,), (0,)), ((), ())))

    # inter-chunk: contribution of the carried state
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, state_ref[...], (((1,), (1,)), ((), ())))     # (q,N)x(P,N)->(q,P)

    # state update: S' = S * exp(sum dA) + sum_j exp(cum_last - cum_j) xdt_j B_j
    dec = jnp.exp(cum[-1] - cum)                  # (q,)
    contrib = jax.lax.dot_general(xdt * dec[:, None], Bm,
                                  (((0,), (0,)), ((), ())))  # (P, N)
    state_ref[...] = state_ref[...] * jnp.exp(cum[-1]) + contrib
    y_ref[0, 0] = y.astype(y_ref.dtype)


def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
             B: jnp.ndarray, C: jnp.ndarray, *, chunk: int = 256,
             interpret: bool = False) -> jnp.ndarray:
    """x: (b,S,H,P); dt: (b,S,H); A: (H,); B/C: (b,S,N) (n_groups=1).

    Returns y: (b,S,H,P) matching ref.ssd_scan_kernel_ref.
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    q = min(chunk, S)
    while S % q:
        q -= 1
    nc = S // q

    xg = x.transpose(0, 2, 1, 3).reshape(b, H, nc, q, P)
    dtg = dt.transpose(0, 2, 1).reshape(b, H, nc, q)
    Bg = B.reshape(b, nc, q, N)
    Cg = C.reshape(b, nc, q, N)

    out = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=q),
        grid=(b * H, 1, nc),
        in_specs=[
            pl.BlockSpec((1, 1, q, P), lambda bh, _, c: (bh, c, 0, 0)),
            pl.BlockSpec((1, 1, q), lambda bh, _, c: (bh, c, 0)),
            pl.BlockSpec((1,), lambda bh, _, c: (bh,)),
            pl.BlockSpec((1, 1, q, N), lambda bh, _, c: (bh, c, 0, 0)),
            pl.BlockSpec((1, 1, q, N), lambda bh, _, c: (bh, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q, P), lambda bh, _, c: (bh, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * H, nc, q, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(xg.reshape(b * H, nc, q, P),
      dtg.reshape(b * H, nc, q),
      jnp.tile(A, b),  # flat (b*H,): index bh -> A[bh % H]
      jnp.repeat(Bg[:, None], H, axis=1).reshape(b * H, nc, q, N),
      jnp.repeat(Cg[:, None], H, axis=1).reshape(b * H, nc, q, N))
    return out.reshape(b, H, nc, q, P).reshape(b, H, S, P).transpose(0, 2, 1, 3)
