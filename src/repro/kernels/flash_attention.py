"""Causal flash attention Pallas kernel (TPU target).

Blockwise online softmax with running (max, sum, acc) held in VMEM scratch.
Unlike the jnp reference path (which must evaluate every (q, kv) block and
mask), the kernel *skips* fully-masked blocks via the grid index map — on
TPU the causal triangle costs ~S^2/2, recovering the 2x the XLA path wastes
(this is the compute-term optimization for prefill cells; see §Perf).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, block_q: int, block_k: int, causal: bool,
                  n_k: int):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: block row qi only needs kv blocks with start <= q block end
    run = (not causal) or (ki * block_k <= qi * block_q + block_q - 1)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)                 # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                 # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_blk = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_ref[...], m_blk)
        p = jnp.exp(s - m_new[:, None])
        r_old = jnp.exp(m_ref[...] - m_new)
        l_new = l_ref[...] * r_old + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * r_old[:, None] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ki == n_k - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, block_q: int = 256,
                    block_k: int = 256,
                    interpret: bool = False) -> jnp.ndarray:
    """q,k,v: (B,H,S,hd) -> (B,H,S,hd).  GQA callers broadcast KV heads in
    the ops wrapper; hd should be a multiple of 128 for MXU alignment (64
    also lowers, at half MXU occupancy)."""
    B, H, S, hd = q.shape
    assert k.shape == v.shape == (B, H, S, hd)
    bq = min(block_q, S)
    while S % bq:
        bq -= 1
    bk = min(block_k, S)
    while S % bk:
        bk -= 1
    n_k = S // bk
    grid = (B * H, S // bq, n_k)
    scale = 1.0 / math.sqrt(hd)

    qf = q.reshape(B * H, S, hd)
    kf = k.reshape(B * H, S, hd)
    vf = v.reshape(B * H, S, hd)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, block_q=bq,
                          block_k=bk, causal=causal, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, hd)
