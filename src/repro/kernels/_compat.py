"""Version compatibility shims for the Pallas TPU API.

jax renamed ``pltpu.TPUCompilerParams`` (<= 0.4.x / early 0.5.x) to
``pltpu.CompilerParams`` (newer releases).  The kernels target the new
name; this shim resolves whichever the installed jax provides.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams(**kwargs)`` on any supported jax version."""
    return _COMPILER_PARAMS_CLS(**kwargs)
