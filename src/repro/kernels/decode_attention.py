"""Split-KV decode attention Pallas kernel (TPU target).

Decode is memory-bound: the whole job is streaming the KV cache HBM->VMEM
once and doing one dot per block.  The grid walks cache blocks sequentially
per (batch*head); partial (max, sum, acc) live in VMEM scratch — the
single-token analogue of flash attention, and the kernel the split-KV
sharding scheme expects per shard.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import tpu_compiler_params

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, scale: float, block_s: int, n_s: int):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                 # (1, hd)
    k = k_ref[0].astype(jnp.float32)                 # (bs, hd)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))[0] * scale  # (bs,)
    pos = si * block_s + jax.lax.broadcasted_iota(jnp.int32, (block_s,), 0)
    s = jnp.where(pos < len_ref[0], s, NEG_INF)
    m_new = jnp.maximum(m_ref[0], jnp.max(s))
    p = jnp.exp(s - m_new)
    r = jnp.exp(m_ref[0] - m_new)
    l_ref[0] = l_ref[0] * r + jnp.sum(p)
    acc_ref[...] = acc_ref[...] * r + \
        jax.lax.dot_general(p[None], v, (((1,), (0,)), ((), ())))
    m_ref[0] = m_new

    @pl.when(si == n_s - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[0], 1e-30)
                    ).astype(o_ref.dtype)


def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     length: int, *, block_s: int = 512,
                     interpret: bool = False) -> jnp.ndarray:
    """q: (B,H,hd); k,v: (B,S,H,hd); attends to cache positions < length.

    Matches ref.decode_attention_ref.
    """
    B, S, H, hd = k.shape
    bs = min(block_s, S)
    while S % bs:
        bs -= 1
    n_s = S // bs
    scale = 1.0 / math.sqrt(hd)

    qf = q.reshape(B * H, 1, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    lens = jnp.full((B * H,), length, jnp.int32)

    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, block_s=bs, n_s=n_s),
        grid=(B * H, n_s),
        in_specs=[
            pl.BlockSpec((1,), lambda b, s: (b,)),
            pl.BlockSpec((1, 1, hd), lambda b, s: (b, 0, 0)),
            pl.BlockSpec((1, bs, hd), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1, bs, hd), lambda b, s: (b, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda b, s: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, 1, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lens, qf, kf, vf)
    return out.reshape(B, H, hd)
