"""vChunk-style streamed matmul Pallas kernel (TPU target).

The paper's vChunk insight — NPU DMA moves model weights HBM->SRAM in large
monotonically-advancing chunks (Patterns 1/2), re-walked per iteration
(Pattern 3) — maps onto the TPU memory hierarchy as a *grid-pipelined
weight stream*: the K-major grid walks the weight matrix range by range,
`pl.pallas_call`'s automatic pipelining double-buffers the HBM->VMEM DMAs
(the range-TLB-friendly sequential stream), and a VMEM fp32 accumulator
plays the scratchpad.  Block shapes are MXU-aligned (multiples of 128 on
the contracting/lane dims).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import tpu_compiler_params


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _fit_block(dim: int, block: int) -> int:
    b = min(block, dim)
    while dim % b:
        b -= 1
    return max(b, 1)


def streamed_matmul(x: jnp.ndarray, w: jnp.ndarray, *,
                    block_m: int = 256, block_n: int = 256,
                    block_k: int = 512,
                    interpret: bool = False) -> jnp.ndarray:
    """x: (M,K) @ w: (K,N) -> (M,N) in x.dtype, fp32 VMEM accumulation.

    Weight traffic: each (k, n) weight block is streamed HBM->VMEM exactly
    M/block_m times; K-major ordering keeps the address walk monotonic per
    output tile (the vChunk Pattern-2 stream), and the grid restart per
    output row-band is Pattern-3's iteration loop.
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    bm, bn, bk = _fit_block(M, block_m), _fit_block(N, block_n), \
        _fit_block(K, block_k)
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)

    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w)
