"""Fleet federation: parallel multi-pod execution with a deterministic
global router.

* :mod:`~repro.fleet.pod` — one pod (mesh + policy + scheduler + serving
  plane) behind the barrier protocol, with fleet-seed derivation;
* :mod:`~repro.fleet.router` — the pluggable routing-policy API and the
  load/affinity/drain-aware :class:`FleetRouter`;
* :mod:`~repro.fleet.switch` — the inter-pod latency/bandwidth/buffering
  switch charging cross-pod migration as checkpoint-transfer time;
* :mod:`~repro.fleet.executor` — the serial reference and the fork-based
  process-parallel executor (bit-identical trajectories);
* :mod:`~repro.fleet.fleet` — the bounded-lag window driver with
  rolling-upgrade / pod-failure scenario hooks.
"""
from .executor import ParallelExecutor, SerialExecutor, make_executor
from .fleet import (FLEET_PER_POD_RATE, Fleet, FleetConfig, FleetMetrics,
                    Scenario, fleet_trace)
from .pod import FleetPodParams, PodHost, PodSpec, derive_pod_seed
from .router import (ROUTING_POLICIES, AffinityRouting, FleetRouter,
                     LeastLoadedRouting, PodView, RoundRobinRouting,
                     RouterStats, RoutingPolicy, make_routing_policy)
from .switch import PodSwitch, SwitchConfig, SwitchStats

__all__ = [
    "FLEET_PER_POD_RATE", "Fleet", "FleetConfig", "FleetMetrics",
    "Scenario", "fleet_trace",
    "FleetPodParams", "PodHost", "PodSpec", "derive_pod_seed",
    "ROUTING_POLICIES", "AffinityRouting", "FleetRouter",
    "LeastLoadedRouting", "PodView", "RoundRobinRouting", "RouterStats",
    "RoutingPolicy", "make_routing_policy",
    "ParallelExecutor", "SerialExecutor", "make_executor",
    "PodSwitch", "SwitchConfig", "SwitchStats",
]
