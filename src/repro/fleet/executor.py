"""Pod executors: the serial reference and the process-parallel engine.

Both present the same interface to the fleet driver — snapshots / feeds /
drains / evacuations / a barrier-synchronized ``advance_all`` — and both
return results in **pod-id submission order**, so the driver's view of the
fleet is byte-identical whichever executor runs underneath:

* :class:`SerialExecutor` owns every :class:`~repro.fleet.pod.PodHost`
  in-process and advances them one after another (the reference).
* :class:`ParallelExecutor` forks ``workers`` persistent processes, pins
  pods to workers round-robin, and drives them over pipes.  Pods are
  share-nothing between barriers, every host is built from the same
  picklable recipe, and all cross-pod state (router, switch) lives in the
  driver process — so the only difference is which OS process executes a
  pod's (deterministic) event loop, and per-pod trajectories match the
  serial executor bit for bit.

``advance_all`` is the parallel section: one command per worker, each
worker advancing its pods back-to-back, the driver blocking until every
worker acks — the bounded-lag window barrier.
"""
from __future__ import annotations

import multiprocessing as mp
from typing import Dict, List, Sequence, Tuple

from ..sched.cluster import ClusterMetrics
from ..sched.events import TenantSpec
from .pod import FleetPodParams, PodHost, PodSpec
from .router import PodView


class SerialExecutor:
    """All pods in the driver process, advanced in pod order."""

    workers = 1

    def __init__(self, pod_specs: Sequence[PodSpec],
                 params: FleetPodParams):
        self.order = [ps.pod_id for ps in pod_specs]
        self._hosts: Dict[int, PodHost] = {
            ps.pod_id: PodHost(ps, params) for ps in pod_specs}

    def snapshots(self) -> List[PodView]:
        return [self._hosts[pid].snapshot() for pid in self.order]

    def feed_many(self, batches: Dict[int, List[TenantSpec]]) -> None:
        for pid in sorted(batches):
            self._hosts[pid].feed(batches[pid])

    def advance_all(self, t: float) -> None:
        for pid in self.order:
            self._hosts[pid].advance_to(t)

    def drain(self, pod_id: int) -> None:
        self._hosts[pod_id].drain()

    def undrain(self, pod_id: int) -> None:
        self._hosts[pod_id].undrain()

    def fail(self, pod_id: int) -> None:
        self._hosts[pod_id].fail()

    def evacuate(self, pod_id: int, now: float
                 ) -> Tuple[List[TenantSpec], List[TenantSpec]]:
        return self._hosts[pod_id].evacuate(now)

    def drain_traces(self) -> List[Tuple[int, dict]]:
        return [(pid, self._hosts[pid].drain_trace()) for pid in self.order]

    def finish_all(self) -> List[ClusterMetrics]:
        return [self._hosts[pid].finish() for pid in self.order]

    def close(self) -> None:
        self._hosts.clear()


def _worker_main(conn, pod_specs: List[PodSpec],
                 params: FleetPodParams) -> None:
    """One worker process: build the pinned hosts, serve commands until
    ``close``.  Any exception is shipped back as ``("err", repr)`` so the
    driver fails loudly instead of deadlocking on a dead pipe."""
    hosts = {ps.pod_id: PodHost(ps, params)
             for ps in sorted(pod_specs, key=lambda p: p.pod_id)}
    order = sorted(hosts)
    while True:
        try:
            msg = conn.recv()
        except EOFError:
            break
        cmd, args = msg[0], msg[1:]
        try:
            if cmd == "snapshots":
                out = [hosts[pid].snapshot() for pid in order]
            elif cmd == "feed_many":
                for pid, specs in args[0]:
                    hosts[pid].feed(specs)
                out = None
            elif cmd == "advance_all":
                for pid in order:
                    hosts[pid].advance_to(args[0])
                out = None
            elif cmd in ("drain", "undrain", "fail"):
                getattr(hosts[args[0]], cmd)()
                out = None
            elif cmd == "evacuate":
                out = hosts[args[0]].evacuate(args[1])
            elif cmd == "drain_traces":
                out = [(pid, hosts[pid].drain_trace()) for pid in order]
            elif cmd == "finish_all":
                out = [(pid, hosts[pid].finish()) for pid in order]
            elif cmd == "close":
                conn.send(("ok", None))
                break
            else:
                raise ValueError(f"unknown executor command {cmd!r}")
            conn.send(("ok", out))
        except Exception as exc:                     # pragma: no cover
            import traceback
            conn.send(("err", f"{exc!r}\n{traceback.format_exc()}"))
    conn.close()


class ParallelExecutor:
    """``workers`` forked processes, pods pinned round-robin.

    Fork keeps startup cheap (the parent's imports are inherited) and is
    the start method this codebase's numpy state tolerates — hosts are
    still built *inside* the workers from picklable recipes, never
    shipped across, so the fork point carries no pod state.
    """

    def __init__(self, pod_specs: Sequence[PodSpec],
                 params: FleetPodParams, workers: int):
        if workers < 2:
            raise ValueError("ParallelExecutor needs workers >= 2 "
                             "(use SerialExecutor for workers=1)")
        self.order = [ps.pod_id for ps in pod_specs]
        self.workers = min(workers, len(pod_specs))
        ctx = mp.get_context("fork")
        assign: List[List[PodSpec]] = [[] for _ in range(self.workers)]
        self._owner: Dict[int, int] = {}
        for i, ps in enumerate(pod_specs):
            assign[i % self.workers].append(ps)
            self._owner[ps.pod_id] = i % self.workers
        self._procs = []
        self._conns = []
        for w in range(self.workers):
            parent, child = ctx.Pipe()
            proc = ctx.Process(target=_worker_main,
                               args=(child, assign[w], params), daemon=True)
            proc.start()
            child.close()
            self._procs.append(proc)
            self._conns.append(parent)

    # -- plumbing ----------------------------------------------------------
    @staticmethod
    def _recv(conn):
        status, payload = conn.recv()
        if status != "ok":
            raise RuntimeError(f"fleet worker failed:\n{payload}")
        return payload

    def _call_all(self, *msg) -> List:
        """Fan a command out to every worker, then collect every ack —
        the workers run the command concurrently."""
        for conn in self._conns:
            conn.send(msg)
        return [self._recv(conn) for conn in self._conns]

    def _call_owner(self, pod_id: int, *msg):
        conn = self._conns[self._owner[pod_id]]
        conn.send(msg)
        return self._recv(conn)

    # -- interface ---------------------------------------------------------
    def snapshots(self) -> List[PodView]:
        views: Dict[int, PodView] = {}
        for worker_views in self._call_all("snapshots"):
            for v in worker_views:
                views[v.pod_id] = v
        return [views[pid] for pid in self.order]

    def feed_many(self, batches: Dict[int, List[TenantSpec]]) -> None:
        per_worker: List[List[Tuple[int, List[TenantSpec]]]] = [
            [] for _ in range(self.workers)]
        for pid in sorted(batches):
            per_worker[self._owner[pid]].append((pid, batches[pid]))
        for w, items in enumerate(per_worker):
            if items:
                self._conns[w].send(("feed_many", items))
        for w, items in enumerate(per_worker):
            if items:
                self._recv(self._conns[w])

    def advance_all(self, t: float) -> None:
        self._call_all("advance_all", t)

    def drain(self, pod_id: int) -> None:
        self._call_owner(pod_id, "drain", pod_id)

    def undrain(self, pod_id: int) -> None:
        self._call_owner(pod_id, "undrain", pod_id)

    def fail(self, pod_id: int) -> None:
        self._call_owner(pod_id, "fail", pod_id)

    def evacuate(self, pod_id: int, now: float
                 ) -> Tuple[List[TenantSpec], List[TenantSpec]]:
        return self._call_owner(pod_id, "evacuate", pod_id, now)

    def drain_traces(self) -> List[Tuple[int, dict]]:
        payloads: Dict[int, dict] = {}
        for worker_out in self._call_all("drain_traces"):
            for pid, payload in worker_out:
                payloads[pid] = payload
        return [(pid, payloads[pid]) for pid in self.order]

    def finish_all(self) -> List[ClusterMetrics]:
        metrics: Dict[int, ClusterMetrics] = {}
        for worker_out in self._call_all("finish_all"):
            for pid, m in worker_out:
                metrics[pid] = m
        return [metrics[pid] for pid in self.order]

    def close(self) -> None:
        for conn in self._conns:
            try:
                conn.send(("close",))
                conn.recv()
            except (BrokenPipeError, EOFError):
                pass
            conn.close()
        for proc in self._procs:
            proc.join(timeout=10)
            if proc.is_alive():                      # pragma: no cover
                proc.terminate()


def make_executor(pod_specs: Sequence[PodSpec], params: FleetPodParams,
                  workers: int):
    """workers=1 -> the serial reference; >1 -> the forked engine."""
    if workers <= 1:
        return SerialExecutor(pod_specs, params)
    return ParallelExecutor(pod_specs, params, workers)
