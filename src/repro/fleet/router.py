"""The deterministic global router: tenant -> pod admission decisions.

The fleet-level analog of :class:`~repro.sched.policy.PlacementPolicy`:
:class:`RoutingPolicy` is a pluggable strategy (``make_routing_policy``
mirrors ``make_policy``) that picks a pod for each arriving tenant from
:class:`PodView` snapshots — the bounded-lag state the executors publish at
every barrier — plus the router's own *within-window commitments* (cores it
already routed since the last barrier, which the snapshots cannot know
about yet).

Routing is load-, affinity- and drain-aware:

* **load** — committed cores (resident + queued + routed-this-window)
  relative to healthy capacity;
* **affinity** — pods already serving the same model are preferred
  (weights are resident, the migration/warmup story is cheapest there);
* **drain** — draining or failed pods are never eligible; a tenant whose
  ask exceeds every eligible pod's healthy capacity is unroutable
  (counted, not crashed).

Every decision is a pure function of (spec, ordered views, commitments),
so the serial and process-parallel executors — which present identical
snapshots in pod-id order — route identically, which is what makes the
whole fleet bit-reproducible across worker counts.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from ..sched.events import TenantSpec


@dataclasses.dataclass
class PodView:
    """One pod's barrier snapshot, as the router sees it.

    ``resident_cores``/``queued_cores`` are summed tenant asks (virtual
    cores), ``healthy_cores`` excludes quarantined ones; ``models`` maps
    model name -> resident tenant count (the affinity signal).
    """
    pod_id: int
    total_cores: int
    healthy_cores: int
    free_cores: int
    n_resident: int
    n_queued: int
    resident_cores: int
    queued_cores: int
    utilization: float
    models: Dict[str, int] = dataclasses.field(default_factory=dict)
    draining: bool = False
    failed: bool = False

    @property
    def eligible(self) -> bool:
        return not (self.draining or self.failed)


class RoutingPolicy:
    """Strategy protocol: order the eligible pods for one tenant.

    ``choose`` returns the selected pod id or ``None`` (unroutable).
    ``committed`` maps pod id -> cores routed since the pods' snapshots
    were taken (the router maintains it; policies fold it into load).
    """

    name = "abstract"

    def choose(self, spec: TenantSpec, views: Sequence[PodView],
               committed: Dict[int, int]) -> Optional[int]:
        raise NotImplementedError

    @staticmethod
    def _fits(spec: TenantSpec, v: PodView) -> bool:
        """A pod can ever host the ask: healthy capacity covers it."""
        return v.eligible and v.healthy_cores >= spec.n_cores

    @staticmethod
    def _load(v: PodView, committed: Dict[int, int]) -> float:
        """Committed-core pressure in [0, inf): resident + queued + routed
        this window, over healthy capacity."""
        used = v.resident_cores + v.queued_cores + committed.get(v.pod_id, 0)
        return used / max(v.healthy_cores, 1)


class LeastLoadedRouting(RoutingPolicy):
    """Pick the eligible pod with the lowest committed-core pressure
    (ties: lower pod id — total order, no hash iteration)."""

    name = "least-loaded"

    def choose(self, spec: TenantSpec, views: Sequence[PodView],
               committed: Dict[int, int]) -> Optional[int]:
        best = min(
            (v for v in views if self._fits(spec, v)),
            key=lambda v: (self._load(v, committed), v.pod_id),
            default=None)
        return best.pod_id if best is not None else None


class AffinityRouting(RoutingPolicy):
    """Prefer pods already serving the tenant's model (weights resident,
    cheapest future migration), then least pressure; fall back to plain
    least-loaded when no pod has the model.  A pod more than
    ``overload_cap`` committed stops attracting affinity traffic — a hot
    model must spill to cold pods instead of melting one."""

    name = "affinity"

    def __init__(self, overload_cap: float = 1.25):
        self.overload_cap = overload_cap

    def choose(self, spec: TenantSpec, views: Sequence[PodView],
               committed: Dict[int, int]) -> Optional[int]:
        fits = [v for v in views if self._fits(spec, v)]
        warm = [v for v in fits
                if v.models.get(spec.model, 0) > 0
                and self._load(v, committed) <= self.overload_cap]
        pool = warm or fits
        best = min(pool, key=lambda v: (self._load(v, committed), v.pod_id),
                   default=None)
        return best.pod_id if best is not None else None


class RoundRobinRouting(RoutingPolicy):
    """Rotate over eligible pods regardless of load (the control
    baseline; still capacity- and drain-aware)."""

    name = "round-robin"

    def __init__(self):
        self._next = 0

    def choose(self, spec: TenantSpec, views: Sequence[PodView],
               committed: Dict[int, int]) -> Optional[int]:
        fits = [v for v in views if self._fits(spec, v)]
        if not fits:
            return None
        v = fits[self._next % len(fits)]
        self._next += 1
        return v.pod_id


ROUTING_POLICIES = {
    "least-loaded": LeastLoadedRouting,
    "affinity": AffinityRouting,
    "round-robin": RoundRobinRouting,
}


def make_routing_policy(name: str, **kwargs) -> RoutingPolicy:
    """Instantiate a registered routing policy (mirrors
    :func:`repro.sched.policy.make_policy`)."""
    try:
        cls = ROUTING_POLICIES[name]
    except KeyError:
        raise KeyError(f"unknown routing policy {name!r}; "
                       f"have {sorted(ROUTING_POLICIES)}")
    return cls(**kwargs)


@dataclasses.dataclass
class RouterStats:
    """One fleet run's routing telemetry."""
    routed: int = 0                   # tenants admitted to some pod
    unroutable: int = 0               # no eligible pod could ever fit
    migrations: int = 0               # evacuation re-admissions routed
    routed_by_pod: Dict[int, int] = dataclasses.field(default_factory=dict)
    affinity_hits: int = 0            # routed to a pod already serving model

    def as_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        d["routed_by_pod"] = {str(k): v
                              for k, v in sorted(self.routed_by_pod.items())}
        return d


class FleetRouter:
    """Admission front-end over the pods: applies a :class:`RoutingPolicy`
    to each arrival, tracking within-window commitments so a burst between
    two barriers spreads instead of dog-piling the pod that *was* coldest
    at the last snapshot."""

    def __init__(self, policy: RoutingPolicy):
        self.policy = policy
        self.stats = RouterStats()
        self._committed: Dict[int, int] = {}

    def new_window(self) -> None:
        """Fresh barrier snapshots arrived: drop the within-window
        commitment estimates (the snapshots now carry the truth)."""
        self._committed = {}

    def route(self, spec: TenantSpec, views: Sequence[PodView],
              migration: bool = False) -> Optional[int]:
        """Pick a pod for one tenant (or None: unroutable).  ``migration``
        marks an evacuation re-admission for the stats."""
        pod_id = self.policy.choose(spec, views, self._committed)
        if pod_id is None:
            self.stats.unroutable += 1
            return None
        self._committed[pod_id] = (self._committed.get(pod_id, 0)
                                   + spec.n_cores)
        self.stats.routed += 1
        self.stats.routed_by_pod[pod_id] = \
            self.stats.routed_by_pod.get(pod_id, 0) + 1
        if migration:
            self.stats.migrations += 1
        by_view = {v.pod_id: v for v in views}
        if by_view.get(pod_id) is not None \
                and by_view[pod_id].models.get(spec.model, 0) > 0:
            self.stats.affinity_hits += 1
        return pod_id
