"""The inter-pod network: a latency/bandwidth/buffering switch model.

Pods are whole NPU meshes; the only traffic between them is tenant
migration — a checkpoint transfer (weights + KV arena, i.e. the tenant's
``memory_bytes`` grant) from the source pod's HBM through the datacenter
switch into the destination pod.  The model follows the FireSim switch
shape (``target-design/switch/switch.cc``): each directed pod pair is a
link with

* a fixed **latency** (propagation + switch pipeline),
* a finite **bandwidth** (serialization: concurrent transfers on one link
  queue behind each other — the link has one free-at clock),
* a finite **output buffer** — backlog beyond it is counted as pressure
  (``buffer_overflows``); the transfer still completes (lossless PFC-style
  backpressure, not drops), it just waits for the queue.

All times are seconds, sizes bytes.  The switch is driven only at fleet
barriers by the router, so its state is tiny (one clock + backlog per
touched link) and its arithmetic is plain float adds — deterministic and
identical between the serial and process-parallel executors (it lives in
the fleet driver process either way).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

Link = Tuple[int, int]              # (src pod id, dst pod id), directed


@dataclasses.dataclass
class SwitchConfig:
    """Inter-pod link parameters.

    Defaults model a 400G-class datacenter fabric: 2 us one-way latency
    (ToR + pipeline), 50 GB/s effective per-link bandwidth, 256 MiB of
    output buffering per link.
    """
    latency_s: float = 2e-6
    bandwidth_bytes_per_s: float = 50e9
    buffer_bytes: int = 256 << 20


@dataclasses.dataclass
class SwitchStats:
    """Cumulative transfer telemetry (one fleet run)."""
    n_transfers: int = 0
    bytes_total: int = 0
    busy_s: float = 0.0               # summed serialization time
    queued_s: float = 0.0             # summed head-of-line waiting time
    buffer_overflows: int = 0         # enqueues that found a full buffer
    max_backlog_bytes: int = 0
    n_brownouts: int = 0              # degradations applied (chaos plane)

    def as_dict(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        d["busy_s"] = round(self.busy_s, 6)
        d["queued_s"] = round(self.queued_s, 6)
        return d


class PodSwitch:
    """Per-directed-link serializing switch between pods.

    :meth:`transfer` charges one checkpoint transfer and returns its
    completion time; O(1) per call.
    """

    def __init__(self, config: SwitchConfig = SwitchConfig()):
        self.config = config
        self._free_at: Dict[Link, float] = {}
        self._backlog: Dict[Link, Tuple[float, int]] = {}  # (asof, bytes)
        self._degrade = 1.0               # brownout factor (>= 1)
        self.stats = SwitchStats()

    def set_degradation(self, factor: float) -> None:
        """Switch brownout (chaos plane): every link's effective bandwidth
        becomes ``bandwidth / factor`` until reset to 1.0.  Driven only at
        fleet barriers, so the serialized-transfer arithmetic stays
        deterministic across executors."""
        f = float(factor)
        if f < 1.0:
            raise ValueError(f"brownout factor must be >= 1, got {f}")
        if f > 1.0:
            self.stats.n_brownouts += 1
        self._degrade = f

    def _bandwidth(self) -> float:
        return self.config.bandwidth_bytes_per_s / self._degrade

    def _drain_backlog(self, link: Link, now: float) -> int:
        """Bytes still queued on ``link`` at ``now`` (the serialized bytes
        whose transmission has not finished yet)."""
        asof, backlog = self._backlog.get(link, (0.0, 0))
        drained = int((now - asof) * self._bandwidth())
        return max(backlog - max(drained, 0), 0)

    def transfer(self, src_pod: int, dst_pod: int, n_bytes: int,
                 now: float) -> float:
        """Charge a ``n_bytes`` checkpoint transfer from ``src_pod`` to
        ``dst_pod`` starting no earlier than ``now``; returns the
        completion time (seconds).  Serializes behind earlier transfers on
        the same directed link and books buffering pressure."""
        cfg = self.config
        link = (int(src_pod), int(dst_pod))
        n_bytes = int(n_bytes)
        start = max(now, self._free_at.get(link, 0.0))
        serialize = n_bytes / max(self._bandwidth(), 1e-9)
        done = start + cfg.latency_s + serialize
        backlog = self._drain_backlog(link, now)
        if backlog > cfg.buffer_bytes:
            self.stats.buffer_overflows += 1
        backlog += n_bytes
        self._backlog[link] = (now, backlog)
        self._free_at[link] = start + serialize

        st = self.stats
        st.n_transfers += 1
        st.bytes_total += n_bytes
        st.busy_s += serialize
        st.queued_s += start - now
        if backlog > st.max_backlog_bytes:
            st.max_backlog_bytes = backlog
        return done
