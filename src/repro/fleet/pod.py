"""One pod of the fleet: a mesh + placement policy + cluster scheduler
(+ serving plane), driven incrementally through the pod protocol.

A :class:`PodHost` wraps exactly the stack one standalone
``ClusterScheduler`` run uses — the pod's own :class:`~repro.core.topology.
Topology` (possibly a different mesh size or ``mem_interface`` layout per
pod), a fresh :class:`~repro.sched.policy.PlacementPolicy`, and an optional
:class:`~repro.sched.cluster.ServingConfig` whose request-stream seed is
*derived* from the fleet seed and the pod id — and exposes the barrier
protocol the executors drive: ``snapshot`` / ``feed`` / ``advance_to`` /
``drain`` / ``undrain`` / ``fail`` / ``evacuate`` / ``finish``.

Everything a host is built from (:class:`PodSpec`, :class:`FleetPodParams`)
is picklable, so the process-parallel executor constructs identical hosts
inside its workers from the identical inputs — the share-nothing half of
the serial/parallel bit-identity argument.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.topology import mesh_2d
from ..obs.trace import Tracer
from ..sched.cluster import ClusterMetrics, ClusterScheduler, ServingConfig
from ..sched.events import TenantSpec
from ..sched.policy import make_policy
from .router import PodView


def derive_pod_seed(fleet_seed: int, pod_id: int) -> int:
    """The pod's request-stream seed, derived from the fleet seed.

    Uses :class:`numpy.random.SeedSequence` spawn keys — a pure function
    of ``(fleet_seed, pod_id)``, so the same fleet seed yields the same
    per-pod streams however the pods are distributed over workers, and
    distinct pods get decorrelated streams (not ``seed + pod_id``, which
    would overlap neighboring pods' Philox counters).
    """
    ss = np.random.SeedSequence(entropy=int(fleet_seed),
                                spawn_key=(int(pod_id),))
    return int(ss.generate_state(1, dtype=np.uint32)[0])


@dataclasses.dataclass
class PodSpec:
    """One pod's hardware + scheduler shape (picklable construction
    recipe).  ``mem_interface_cols=None`` keeps the mesh default (column
    0); heterogeneous fleets mix sizes and interface layouts freely."""
    pod_id: int
    rows: int = 16
    cols: int = 16
    mem_interface_cols: Optional[Tuple[int, ...]] = None
    policy: str = "vnpu"
    policy_kwargs: Dict = dataclasses.field(default_factory=dict)
    epoch_s: float = 2.0
    admission: str = "sla"
    rescore: str = "ledger"


@dataclasses.dataclass
class FleetPodParams:
    """Fleet-wide knobs every pod shares (picklable; crosses the fork).

    ``serving=False`` runs plain admission/defrag pods with no request
    plane (the classic cluster traces at fleet scale)."""
    fleet_seed: int = 0
    trace_name: str = ""
    serving: bool = True
    engine: str = "vector"
    record_requests: bool = False
    rate_scale: float = 1.0
    request_mix: str = "default"
    #: per-pod span ring-buffer capacity; 0 disables tracing entirely
    trace_capacity: int = 0


class PodHost:
    """The in-process pod: builds the stack from its spec and adapts the
    scheduler's incremental-drive protocol for an executor."""

    def __init__(self, spec: PodSpec, params: FleetPodParams):
        self.spec = spec
        kwargs = {}
        if spec.mem_interface_cols is not None:
            kwargs["mem_interface_cols"] = tuple(spec.mem_interface_cols)
        self.topo = mesh_2d(spec.rows, spec.cols,
                            name=f"pod{spec.pod_id}", **kwargs)
        self.policy = make_policy(spec.policy, self.topo,
                                  **dict(spec.policy_kwargs))
        serving = None
        if params.serving:
            serving = ServingConfig(
                seed=derive_pod_seed(params.fleet_seed, spec.pod_id),
                engine=params.engine,
                record_requests=params.record_requests,
                rate_scale=params.rate_scale,
                request_mix=params.request_mix)
        if params.trace_capacity > 0:
            self.tracer = Tracer(capacity=params.trace_capacity,
                                 pid=spec.pod_id)
            self.tracer.process_name(
                f"pod{spec.pod_id} {spec.rows}x{spec.cols} {spec.policy}")
        else:
            self.tracer = Tracer.NULL
        self.sched = ClusterScheduler(self.policy, epoch_s=spec.epoch_s,
                                      rescore=spec.rescore, serving=serving,
                                      admission=spec.admission,
                                      tracer=self.tracer)
        self.sched.begin(trace_name=params.trace_name, driven=True)
        self.failed = False

    # -- barrier protocol --------------------------------------------------
    def snapshot(self) -> PodView:
        """The router-facing state at the current barrier."""
        sched = self.sched
        residents = sched.resident_specs()
        models: Dict[str, int] = {}
        for s in residents.values():
            models[s.model] = models.get(s.model, 0) + 1
        waiting = [w_spec for w_spec, _enq in sched._waiting]
        total = self.spec.rows * self.spec.cols
        return PodView(
            pod_id=self.spec.pod_id,
            total_cores=total,
            healthy_cores=total - len(sched._failed_cores),
            free_cores=len(self.policy.free_cores()),
            n_resident=len(residents),
            n_queued=len(waiting),
            resident_cores=sum(s.n_cores for s in residents.values()),
            queued_cores=sum(s.n_cores for s in waiting),
            utilization=self.policy.utilization(),
            models=models,
            draining=sched.draining,
            failed=self.failed)

    def feed(self, specs: List[TenantSpec]) -> None:
        self.sched.feed(specs)

    def advance_to(self, t: float) -> None:
        self.sched.advance_to(t)

    def drain(self) -> None:
        self.sched.drain()

    def undrain(self) -> None:
        self.sched.undrain()

    def fail(self) -> None:
        """Whole-pod failure: permanently out of routing rotation (the
        driver evacuates the tenants through the router)."""
        self.failed = True
        self.sched.drain()

    def evacuate(self, now: float) -> Tuple[List[TenantSpec],
                                            List[TenantSpec]]:
        """Hand back ``(residents, queued)``: residents re-admit with their
        remaining duration (they pay a checkpoint transfer to move);
        queued tenants re-route verbatim with their SLA clock running."""
        n_res = len(self.sched._residents)
        out = self.sched.evacuate(now)
        return out[:n_res], out[n_res:]

    def drain_trace(self) -> dict:
        """Hand the buffered trace events to the driver (clears the pod's
        ring buffer).  Cheap no-op payload when tracing is off."""
        return self.tracer.drain()

    def finish(self) -> ClusterMetrics:
        return self.sched.finish()
