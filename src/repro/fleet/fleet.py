"""The fleet driver: N pods, one router, one switch, bounded-lag windows.

Execution model (the perf core of the fleet layer):

* Time is cut into ``window_s`` **bounded-lag windows**.  At each window
  barrier the driver collects one :class:`~repro.fleet.router.PodView`
  snapshot per pod (pod-id order), lets the
  :class:`~repro.fleet.router.FleetRouter` admit every tenant arriving in
  the window to a pod, applies any due scenario (rolling upgrade, pod
  failure) — evacuating through the router and charging cross-pod moves
  as checkpoint transfers on the :class:`~repro.fleet.switch.PodSwitch` —
  and then commands every pod to advance to the next barrier.
* Between barriers pods are **share-nothing**: router decisions at
  barrier *k* read snapshots from barrier *k* (one-window lag by
  construction), and all cross-pod state lives in the driver process.
  That is why the serial and process-parallel executors produce
  bit-identical per-pod trajectories and fleet summaries — the pods see
  the same feeds at the same barriers in the same order either way, and
  :class:`~repro.fleet.executor.ParallelExecutor` only changes *which OS
  process* runs a pod's deterministic event loop.

Scenario semantics:

* ``upgrade`` (rolling upgrade): at the first barrier >= ``t_s`` the pod
  is drained and its tenants evacuated — residents re-admit elsewhere
  with their remaining duration after a checkpoint transfer
  (``memory_bytes`` over the switch), queued tenants re-route with their
  SLA clock still running from the original arrival.  At the first
  barrier >= ``t_s + duration_s`` the pod is un-drained and re-enters
  the routing rotation.
* ``pod-failure``: same evacuation, but the pod never comes back.
* ``switch-brownout``: the inter-pod switch's effective bandwidth drops
  by ``factor`` for ``duration_s`` — checkpoint transfers serialize
  proportionally slower until the first barrier past the restore time.

Tenants the router cannot place anywhere eligible are *retried*, not
lost: each unroutable tenant enters a bounded exponential-backoff queue
(``retry_base_s * 2**attempts``, up to ``retry_max`` re-route attempts)
and re-routes at a later barrier against fresh snapshots.  Exhausted
retries — and retries still waiting when the run ends — are dropped and
counted (``FleetMetrics.n_dropped``); every deferral is counted too
(``FleetMetrics.n_retried``).  The queue lives in the driver process, so
serial and parallel executors stay bit-identical.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

from ..obs.trace import FLEET_PID, Tracer
from ..sched.cluster import ClusterMetrics
from ..sched.events import TenantSpec
from ..sched.traces import TRACES, poisson_trace
from ..serve.stats import LatencyStats
from .executor import make_executor
from .pod import FleetPodParams, PodSpec
from .router import (FleetRouter, PodView, RouterStats, RoutingPolicy,
                     make_routing_policy)
from .switch import PodSwitch, SwitchConfig, SwitchStats

#: per-pod tenant arrival rate the ``fleet-serving`` trace is tuned to
#: (1.6/s per 16x16 pod = the pod-serving overload scaled by core count)
FLEET_PER_POD_RATE = 1.6


@dataclasses.dataclass
class Scenario:
    """One fleet-wide event: ``kind`` is ``"upgrade"`` (drain for
    ``duration_s``, then return to service), ``"pod-failure"``
    (permanent), or ``"switch-brownout"`` (inter-pod bandwidth divided by
    ``factor`` for ``duration_s``; ``pod_id`` is ignored).  Applied at
    the first window barrier >= ``t_s``."""
    kind: str
    t_s: float
    pod_id: int
    duration_s: float = 0.0
    factor: float = 1.0


@dataclasses.dataclass
class FleetConfig:
    """Fleet-wide knobs: the window length, routing policy, switch
    parameters, and the serving-plane settings every pod shares."""
    seed: int = 0
    window_s: float = 5.0
    routing: str = "least-loaded"
    switch: SwitchConfig = dataclasses.field(default_factory=SwitchConfig)
    trace_name: str = ""
    serving: bool = True
    engine: str = "vector"
    record_requests: bool = False
    rate_scale: float = 1.0
    request_mix: str = "default"
    #: how long past the last arrival the fleet keeps running so admitted
    #: tenants drain out (the serving catalog's clipped service ceiling)
    drain_tail_s: float = 150.0
    #: unroutable tenants re-route after this backoff, doubled per failed
    #: attempt; after ``retry_max`` re-route failures the tenant is dropped
    retry_base_s: float = 2.0
    retry_max: int = 4
    #: per-pod (and driver) span ring-buffer capacity; 0 disables tracing.
    #: Tracing is a pure observer — trajectories and summaries are
    #: bit-identical with it on or off, serial or parallel.
    trace_capacity: int = 0


@dataclasses.dataclass
class FleetMetrics:
    """Everything one fleet run reports: the per-pod metrics in pod-id
    order plus the fleet-global router/switch telemetry."""
    pods: List[ClusterMetrics]
    pod_ids: List[int]
    router: RouterStats
    switch: SwitchStats
    horizon_s: float
    window_s: float
    n_windows: int
    workers: int
    wall_s: float
    n_retried: int = 0      # unroutable deferrals through the retry queue
    n_dropped: int = 0      # retry budget exhausted or run ended waiting

    @property
    def requests_arrived(self) -> int:
        return sum(p.requests_arrived for p in self.pods)

    @property
    def requests_completed(self) -> int:
        return sum(p.requests_completed for p in self.pods)

    def serving_summary(self) -> Dict[str, object]:
        """Fleet-level digest in the shape of
        :meth:`~repro.sched.cluster.ClusterMetrics.serving_summary`:
        exact counters summed over pods, latency percentiles from the
        merged per-pod streaming sketches (:meth:`LatencyStats.merge`,
        pod-id order).  Contains no wall-clock quantities, so the
        serial-vs-parallel gate compares it for equality directly."""
        ttft = LatencyStats.merge([p.ttft_stats for p in self.pods])
        tpot = LatencyStats.merge([p.tpot_stats for p in self.pods])
        sla_good = sum(p.requests_sla_good for p in self.pods)
        return {
            "pods": len(self.pods),
            "requests": self.requests_arrived,
            "completed": self.requests_completed,
            "sla_good": sla_good,
            "sla_goodput_rps": round(
                sla_good / self.horizon_s if self.horizon_s else 0.0, 4),
            "tokens_generated": sum(p.tokens_generated for p in self.pods),
            "ttft_p50_s": round(ttft.percentile(50), 4),
            "ttft_p95_s": round(ttft.percentile(95), 4),
            "ttft_p99_s": round(ttft.percentile(99), 4),
            "tpot_p50_s": round(tpot.percentile(50), 5),
            "tpot_p95_s": round(tpot.percentile(95), 5),
            "tpot_p99_s": round(tpot.percentile(99), 5),
            "kv_preemptions": sum(p.kv_preemptions for p in self.pods),
            "kv_admit_oom": sum(p.kv_admit_oom for p in self.pods),
            "requests_dropped": sum(p.requests_dropped for p in self.pods),
            "admitted": sum(p.n_admitted for p in self.pods),
            "rejected": sum(p.n_rejected for p in self.pods),
            "evacuated": sum(p.n_evacuated for p in self.pods),
            "migrations": sum(p.n_migrations for p in self.pods),
            "resizes": sum(p.n_resizes for p in self.pods),
            "n_retried": self.n_retried,
            "n_dropped": self.n_dropped,
            "router": self.router.as_dict(),
            "switch": self.switch.as_dict(),
        }

    def summary(self) -> Dict[str, object]:
        """The digest plus run-shape and wall-clock facts (NOT compared
        across executors — ``wall_s`` is machine time)."""
        out = self.serving_summary()
        out.update({
            "horizon_s": self.horizon_s,
            "windows": self.n_windows,
            "window_s": self.window_s,
            "workers": self.workers,
            "wall_s": round(self.wall_s, 2),
            "agg_req_per_s": round(
                self.requests_arrived / self.wall_s if self.wall_s else 0.0,
                1),
        })
        return out

    def pod_digests(self) -> List[Tuple]:
        """Per-pod trajectory digests for the bit-identity gate: every
        deterministic counter and the epoch trajectory, no wall-clock
        fields (``scoring_pass_s`` is machine time and excluded)."""
        out = []
        for pid, p in zip(self.pod_ids, self.pods):
            out.append((
                pid, p.n_arrived, p.n_admitted, p.n_rejected,
                p.n_migrations, p.n_evacuated, p.n_events,
                p.requests_arrived, p.requests_completed,
                p.requests_sla_good, p.tokens_generated,
                p.kv_preemptions, p.n_resizes,
                round(p.util_integral, 9),
                tuple((s.t, s.n_resident, s.n_queued,
                       round(s.utilization, 12), round(s.agg_fps, 9))
                      for s in p.samples),
                tuple(p.request_log),
            ))
        return out


def fleet_trace(n_pods: int, seed: Optional[int] = None,
                horizon_s: Optional[float] = None) -> List[TenantSpec]:
    """The ``fleet-serving`` arrival stream scaled to ``n_pods`` pods: the
    registered config carries the 8-pod rate, so a smaller test fleet gets
    a proportionally thinner stream at the same per-pod overload."""
    cfg = TRACES["fleet-serving"]
    cfg = dataclasses.replace(
        cfg,
        seed=cfg.seed if seed is None else seed,
        horizon_s=cfg.horizon_s if horizon_s is None else horizon_s,
        rate_per_s=FLEET_PER_POD_RATE * n_pods)
    return poisson_trace(cfg)


class Fleet:
    """N pods + a router + a switch, run over bounded-lag windows."""

    def __init__(self, pods: Sequence[PodSpec],
                 config: Optional[FleetConfig] = None,
                 routing_policy: Optional[RoutingPolicy] = None):
        if not pods:
            raise ValueError("a fleet needs at least one pod")
        ids = [ps.pod_id for ps in pods]
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate pod ids: {ids}")
        self.pods = list(pods)
        self.config = config or FleetConfig()
        self.router = FleetRouter(
            routing_policy or make_routing_policy(self.config.routing))
        self.switch = PodSwitch(self.config.switch)
        # the merged fleet trace: per-pod ring buffers drain into this one
        # at every window barrier (pod-id order, so serial == parallel);
        # driver-scope events (routing, transfers, scenarios) land under
        # FLEET_PID.  Pure observer — never feeds back into the run.
        if self.config.trace_capacity > 0:
            self.tracer = Tracer(
                capacity=self.config.trace_capacity * (len(self.pods) + 1),
                pid=FLEET_PID)
            self.tracer.process_name("fleet driver")
        else:
            self.tracer = Tracer.NULL

    def _params(self) -> FleetPodParams:
        cfg = self.config
        return FleetPodParams(
            fleet_seed=cfg.seed, trace_name=cfg.trace_name,
            serving=cfg.serving, engine=cfg.engine,
            record_requests=cfg.record_requests, rate_scale=cfg.rate_scale,
            request_mix=cfg.request_mix,
            trace_capacity=cfg.trace_capacity)

    def run(self, trace: Sequence[TenantSpec],
            scenarios: Sequence[Scenario] = (),
            workers: int = 1,
            end_s: Optional[float] = None) -> FleetMetrics:
        """Replay ``trace`` (global arrival stream) to completion.

        ``workers=1`` is the serial reference; ``workers>1`` forks the
        process-parallel executor — same trajectories, less wall-clock.
        ``end_s`` overrides the run end (default: last arrival +
        ``drain_tail_s``, so admitted tenants drain out).
        """
        cfg = self.config
        arrivals = sorted(trace, key=lambda s: (s.arrival_s, s.tid))
        if end_s is None:
            last = arrivals[-1].arrival_s if arrivals else 0.0
            end_s = last + cfg.drain_tail_s
        pending = sorted(scenarios, key=lambda s: (s.t_s, s.pod_id, s.kind))
        for sc in pending:
            if sc.kind not in ("upgrade", "pod-failure", "switch-brownout"):
                raise ValueError(f"unknown scenario kind {sc.kind!r}")

        t0 = time.perf_counter()
        ex = make_executor(self.pods, self._params(), workers)
        try:
            metrics = self._drive(ex, arrivals, pending, end_s)
        finally:
            ex.close()
        wall = time.perf_counter() - t0
        return FleetMetrics(
            pods=metrics[0], pod_ids=[ps.pod_id for ps in self.pods],
            router=self.router.stats, switch=self.switch.stats,
            horizon_s=end_s, window_s=cfg.window_s, n_windows=metrics[1],
            workers=getattr(ex, "workers", workers), wall_s=wall,
            n_retried=metrics[2], n_dropped=metrics[3])

    # -- the window loop ---------------------------------------------------
    def _drive(self, ex, arrivals: List[TenantSpec],
               pending: List[Scenario],
               end_s: float) -> Tuple[List[ClusterMetrics], int, int, int]:
        cfg = self.config
        tr = self.tracer
        undrain_at: List[Tuple[float, int]] = []
        restore_at: List[float] = []     # brownout ends (switch back to 1.0)
        # unroutable tenants awaiting re-route: (ready_s, attempts,
        # src pod id for evacuees — their checkpoint still has to cross the
        # switch on success — or None, spec)
        retry: List[Tuple[float, int, Optional[int], TenantSpec]] = []
        n_retried = 0
        n_dropped = 0
        idx = 0
        t = 0.0
        n_windows = 0
        while True:
            t_next = min(t + cfg.window_s, end_s)
            views = {v.pod_id: v for v in ex.snapshots()}
            self.router.new_window()

            # pods whose upgrade drain completed re-enter the rotation
            still = []
            for when, pid in undrain_at:
                if when <= t:
                    ex.undrain(pid)
                    views[pid].draining = False
                else:
                    still.append((when, pid))
            undrain_at = still

            # brownouts whose duration elapsed restore full bandwidth
            if restore_at and restore_at[0] <= t:
                restore_at = [when for when in restore_at if when > t]
                if not restore_at:
                    self.switch.set_degradation(1.0)

            # due scenarios: drain/fail, evacuate, re-route via the router
            batches: Dict[int, List[TenantSpec]] = {}
            while pending and pending[0].t_s <= t:
                sc = pending.pop(0)
                tr.instant(f"scenario:{sc.kind}", "fleet", t,
                           args={"pod": sc.pod_id,
                                 "duration_s": sc.duration_s,
                                 "factor": sc.factor})
                if sc.kind == "switch-brownout":
                    self.switch.set_degradation(sc.factor)
                    restore_at.append(sc.t_s + sc.duration_s)
                    restore_at.sort()
                    continue
                if sc.kind == "upgrade":
                    ex.drain(sc.pod_id)
                    views[sc.pod_id].draining = True
                    undrain_at.append((sc.t_s + sc.duration_s, sc.pod_id))
                    undrain_at.sort()
                else:
                    ex.fail(sc.pod_id)
                    views[sc.pod_id].failed = True
                residents, queued = ex.evacuate(sc.pod_id, t)
                view_list = [views[ps.pod_id] for ps in self.pods]
                for spec in residents:
                    dst = self.router.route(spec, view_list, migration=True)
                    if dst is None:
                        # counted unroutable; the tenant waits in the retry
                        # queue instead of being lost
                        n_retried += 1
                        retry.append((t + cfg.retry_base_s, 1,
                                      sc.pod_id, spec))
                        continue
                    # the checkpoint (weights + KV arena = memory_bytes)
                    # crosses the switch; the tenant re-arrives when the
                    # transfer completes
                    done = self.switch.transfer(sc.pod_id, dst,
                                                spec.memory_bytes, t)
                    tr.span("transfer", "fleet", t, done - t,
                            args={"tid": spec.tid, "src": sc.pod_id,
                                  "dst": dst, "bytes": spec.memory_bytes})
                    batches.setdefault(dst, []).append(
                        dataclasses.replace(spec, arrival_s=done))
                for spec in queued:
                    # never admitted: nothing to transfer, SLA clock keeps
                    # running from the original arrival
                    dst = self.router.route(spec, view_list, migration=True)
                    if dst is not None:
                        batches.setdefault(dst, []).append(spec)
                    else:
                        n_retried += 1
                        retry.append((t + cfg.retry_base_s, 1, None, spec))

            view_list = [views[ps.pod_id] for ps in self.pods]

            # due retries re-route first — they predate this window's
            # arrivals; backoff doubles per failed attempt, a bounded
            # number of attempts, then the tenant is dropped for real
            if retry:
                due = sorted((r for r in retry if r[0] <= t),
                             key=lambda r: (r[0], r[3].tid))
                retry = [r for r in retry if r[0] > t]
                for ready, attempts, src, spec in due:
                    dst = self.router.route(spec, view_list,
                                            migration=src is not None)
                    if dst is None:
                        if attempts >= cfg.retry_max:
                            n_dropped += 1
                            tr.instant("retry_drop", "fleet", t,
                                       args={"tid": spec.tid,
                                             "attempts": attempts})
                        else:
                            n_retried += 1
                            backoff = cfg.retry_base_s * (2.0 ** attempts)
                            retry.append((t + backoff, attempts + 1,
                                          src, spec))
                        continue
                    if src is not None:
                        done = self.switch.transfer(src, dst,
                                                    spec.memory_bytes, t)
                        tr.span("transfer", "fleet", t, done - t,
                                args={"tid": spec.tid, "src": src,
                                      "dst": dst,
                                      "bytes": spec.memory_bytes})
                        spec = dataclasses.replace(spec, arrival_s=done)
                    batches.setdefault(dst, []).append(spec)

            # this window's arrivals, routed against the barrier snapshots
            while idx < len(arrivals) and arrivals[idx].arrival_s < t_next:
                spec = arrivals[idx]
                idx += 1
                dst = self.router.route(spec, view_list)
                if tr.enabled:
                    tr.instant("route", "fleet", spec.arrival_s,
                               args={"tid": spec.tid,
                                     "dst": -1 if dst is None else dst})
                if dst is not None:
                    batches.setdefault(dst, []).append(spec)
                else:
                    n_retried += 1
                    retry.append((t + cfg.retry_base_s, 1, None, spec))

            if batches:
                ex.feed_many(batches)
            ex.advance_all(t_next)     # the parallel section
            if tr.enabled:
                # pod ring buffers drain into the merged fleet trace at the
                # barrier, in pod-id order — the same merged stream whether
                # pods ran serially or across worker processes
                for _pid, payload in ex.drain_traces():
                    tr.absorb(payload)
            n_windows += 1
            t = t_next
            if t >= end_s:
                break
        n_dropped += len(retry)        # still waiting when the run ended
        pod_metrics = ex.finish_all()
        if tr.enabled:
            # finish() closes still-open spans (down cores at the horizon)
            for _pid, payload in ex.drain_traces():
                tr.absorb(payload)
        return pod_metrics, n_windows, n_retried, n_dropped
