from .pipeline import DataConfig, make_batch, data_iterator
