"""Deterministic data pipeline: synthetic LM token streams (and the stub
modality frontends) with per-host sharding, reproducible order, and
background prefetch.

Determinism contract: batch ``i`` of shard ``(host, n_hosts)`` is a pure
function of ``(seed, i)`` — a restarted/elastically-remapped job regenerates
the exact same stream from any step (the checkpoint stores the step).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1
    family: str = "dense"          # adds frontend arrays for vlm/encdec
    frontend_seq: int = 0
    frontend_dim: int = 0


def _rng_for(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_index]))


def make_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Synthetic-but-learnable stream: Zipfian unigrams + a short repeated
    motif so the loss visibly decreases during the example runs."""
    rng = _rng_for(cfg, step)
    b = cfg.global_batch // cfg.host_count
    s = cfg.seq_len
    text_len = s - (cfg.frontend_seq if cfg.family == "vlm" else 0)
    ranks = np.arange(1, cfg.vocab_size + 1)
    probs = 1.0 / ranks ** 1.1
    probs /= probs.sum()
    toks = rng.choice(cfg.vocab_size, size=(b, text_len), p=probs)
    # motif: every 16th position starts a fixed 4-gram (learnable structure)
    motif = (np.arange(4) * 7 + 13) % cfg.vocab_size
    toks[:, ::16] = motif[0]
    for k in range(1, 4):
        toks[:, k::16] = motif[k]
    batch: Dict[str, np.ndarray] = {"tokens": toks.astype(np.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = rng.standard_normal(
            (b, cfg.frontend_seq, cfg.frontend_dim)).astype(np.float32)
    if cfg.family == "encdec":
        batch["frames"] = rng.standard_normal(
            (b, cfg.frontend_seq, cfg.frontend_dim)).astype(np.float32)
    return batch


def data_iterator(cfg: DataConfig, start_step: int = 0,
                  prefetch: int = 2) -> Iterator[Dict[str, np.ndarray]]:
    """Background-thread prefetching iterator."""
    q: "queue.Queue" = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def producer():
        step = start_step
        while not stop.is_set():
            try:
                q.put(make_batch(cfg, step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()
