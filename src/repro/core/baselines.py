"""Comparison allocators used throughout §6: the MIG-NPU and UVM baselines.

``MIGPartitioner`` (fixed sub-topologies, TDM when oversubscribed — the
MIG-NPU baseline) and ``UVMAllocator`` (no topology: arbitrary cores, data
exchanged through global memory — the Aurora/V10-style baseline).

Both expose the same lifecycle surface the scheduler's ``PlacementPolicy``
adapters need — allocate / release / utilization — so the cluster layer
(:mod:`repro.sched`) can drive vNPU, MIG and UVM through one interface.
Historically these lived in :mod:`repro.core.hypervisor`; they are
re-exported there (and from :mod:`repro.core`) for backward compatibility.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence, Set, Tuple

from .topology import Topology


class AllocationError(RuntimeError):
    pass


# ---------------------------------------------------------------------------
# MIG baseline (§6.3.2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MIGPartition:
    pid: int
    cores: FrozenSet[int]
    topology: Topology
    occupied_by: Optional[int] = None
    failed: bool = False          # a dead core poisons the whole partition


class MIGPartitioner:
    """Fixed-partition virtualization à la NVIDIA MIG / TPU-v6e slices.

    The physical mesh is split into a predetermined set of rectangular
    sub-topologies.  Requests get the smallest free partition with at least
    the requested core count; if none is large enough, multiple virtual cores
    time-share one physical core (TDM), modeled by ``time_share`` < 1.
    """

    def __init__(self, phys_topo: Topology, partition_shapes: Sequence[Tuple[int, int]]):
        self.topo = phys_topo
        shape = phys_topo.is_rect_mesh()
        if shape is None:
            raise ValueError("MIG baseline requires a rectangular mesh")
        self.mesh_shape = shape
        self.partitions: List[MIGPartition] = []
        self._carve(partition_shapes)
        self._next_vmid = 1
        # vmid -> (partition id, requested virtual core count)
        self._tenants: Dict[int, Tuple[int, int]] = {}
        # individual dead cores (a partition stays poisoned until every one
        # of its dead cores is repaired)
        self.failed_cores: Set[int] = set()

    def _carve(self, shapes: Sequence[Tuple[int, int]]) -> None:
        """Tile the mesh left-to-right, top-to-bottom with the given shapes."""
        R, C = self.mesh_shape
        by_coord = {v: k for k, v in self.topo.coords.items()}
        used: Set[Tuple[int, int]] = set()
        pid = 0
        for (r, c) in shapes:
            placed = False
            for r0 in range(R - r + 1):
                for c0 in range(C - c + 1):
                    cells = {(r0 + i, c0 + j) for i in range(r) for j in range(c)}
                    if cells & used:
                        continue
                    used |= cells
                    cores = frozenset(by_coord[x] for x in cells)
                    self.partitions.append(
                        MIGPartition(pid, cores, self.topo.subgraph(cores)))
                    pid += 1
                    placed = True
                    break
                if placed:
                    break
            if not placed:
                raise ValueError(f"cannot carve partition {r}x{c}")

    def allocate(self, n_cores: int) -> Tuple[MIGPartition, float]:
        """Returns (partition, time_share).  time_share < 1 when the request
        exceeds every free partition and physical cores must be TDM-shared.
        Failed partitions are never handed out.
        """
        free = [p for p in self.partitions
                if p.occupied_by is None and not p.failed]
        if not free:
            raise AllocationError("no free MIG partition")
        fitting = [p for p in free if len(p.cores) >= n_cores]
        if fitting:
            part = min(fitting, key=lambda p: len(p.cores))
            share = 1.0
        else:
            part = max(free, key=lambda p: len(p.cores))
            share = len(part.cores) / n_cores  # TDM factor (<1)
        part.occupied_by = self._next_vmid
        self._tenants[self._next_vmid] = (part.pid, n_cores)
        self._next_vmid += 1
        return part, share

    def release(self, pid: int) -> None:
        part = self.partitions[pid]
        if part.occupied_by is not None:
            self._tenants.pop(part.occupied_by, None)
        part.occupied_by = None

    def utilization_for(self, n_cores: int, part: MIGPartition) -> float:
        """Fraction of the partition the tenant actually uses."""
        return min(1.0, n_cores / len(part.cores))

    def mark_failed(self, cores: Iterable[int]) -> None:
        """Dead hardware: the MIG model has no sub-partition granularity,
        so a dead core poisons its whole partition — it is never handed
        out again (a resident, if any, keeps its placement until the
        caller migrates it off via a fresh ``allocate``)."""
        dead = set(cores) & set(self.topo.node_attrs)
        self.failed_cores |= dead
        for p in self.partitions:
            if dead & p.cores:
                p.failed = True

    def mark_repaired(self, cores: Iterable[int]) -> None:
        """Repaired hardware: a partition is handed out again only once
        *every* dead core inside it is back (partition-granular recovery —
        the MIG model cannot serve around a single bad core)."""
        self.failed_cores -= set(cores)
        for p in self.partitions:
            if p.failed and not (self.failed_cores & p.cores):
                p.failed = False

    def utilization(self) -> float:
        """Useful cores / healthy cores: an occupied partition contributes
        only the cores its tenant asked for — the rest is internal
        fragmentation (and TDM-shared partitions contribute at most the
        whole partition).  Failed partitions leave both sides: their cores
        are not capacity, and a tenant stranded on one contributes no
        useful work.
        """
        healthy = self.topo.num_nodes - sum(
            len(p.cores) for p in self.partitions if p.failed)
        if healthy <= 0:
            return 0.0
        useful = sum(min(req, len(self.partitions[pid].cores))
                     for pid, req in self._tenants.values()
                     if not self.partitions[pid].failed)
        return useful / healthy

    def allocated_cores(self) -> Set[int]:
        return {c for p in self.partitions if p.occupied_by is not None
                for c in p.cores}

    def free_cores(self) -> Set[int]:
        """Cores of unoccupied, healthy partitions."""
        failed = {c for p in self.partitions if p.failed for c in p.cores}
        return set(self.topo.node_attrs) - self.allocated_cores() - failed


# ---------------------------------------------------------------------------
# UVM baseline (Aurora / V10-style; §6.3.1)
# ---------------------------------------------------------------------------

class UVMAllocator:
    """Cores are symmetric and interchangeable; no topology is exposed, all
    inter-core data exchange goes through global memory.  Allocation is just
    "any N free cores".
    """

    def __init__(self, phys_topo: Topology):
        self.topo = phys_topo
        self.allocated: Set[int] = set()
        self.quarantined: Set[int] = set()

    def allocate(self, n_cores: int) -> FrozenSet[int]:
        """Lowest-id ``n_cores`` free healthy cores (O(cores))."""
        free = sorted(set(self.topo.node_attrs) - self.allocated
                      - self.quarantined)
        if len(free) < n_cores:
            raise AllocationError("not enough free cores")
        pick = frozenset(free[:n_cores])
        self.allocated |= pick
        return pick

    def release(self, cores: Iterable[int]) -> None:
        self.allocated -= set(cores)

    def mark_failed(self, cores: Iterable[int]) -> None:
        """Dead hardware: the cores stay quarantined until repaired (an
        owner, if any, keeps them until released — migrate it off first)."""
        self.quarantined |= set(cores)

    def mark_repaired(self, cores: Iterable[int]) -> None:
        """Repaired hardware: lift the quarantine.  A repaired core that is
        still owned simply keeps serving its owner; an unowned one is free
        again immediately."""
        self.quarantined -= set(cores)

    def utilization(self) -> float:
        """Allocated healthy cores / healthy cores, in [0, 1] (quarantined
        cores leave both sides, mirroring the hypervisor's accounting)."""
        healthy = self.topo.num_nodes - len(self.quarantined)
        if healthy <= 0:
            return 0.0
        return len(self.allocated - self.quarantined) / healthy

    def free_cores(self) -> Set[int]:
        return set(self.topo.node_attrs) - self.allocated - self.quarantined
