"""ML workload graphs for the NPU simulator (§6's benchmark set).

Each workload is a DAG of layers.  A layer carries the quantities the
simulator needs: MACs (multiply-accumulates), weight bytes, output-activation
bytes.  Edges carry the activation bytes that flow between layers — crossing
a core boundary turns them into NoC (or global-memory) traffic.

The set follows the paper: ResNet-18/34/50 [33], GPT2 small/medium/large,
BERT [15], MobileNet [34], AlexNet [42], GoogLeNet [66], YOLO-lite [35],
plus a generic "Transformer" used in Figs. 15/16.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

DTYPE_BYTES = 2  # bf16 weights/activations


@dataclasses.dataclass
class Layer:
    name: str
    macs: int               # multiply-accumulates (flops = 2*macs)
    weight_bytes: int
    out_bytes: int
    kind: str = "conv"      # conv | matmul | dwconv | norm | pool
    reduce_out: bool = False  # tensor-parallel: output needs an all-reduce


@dataclasses.dataclass
class WorkloadGraph:
    name: str
    layers: List[Layer]
    edges: List[Tuple[int, int]]   # (src layer idx, dst layer idx)

    @property
    def total_macs(self) -> int:
        return sum(l.macs for l in self.layers)

    @property
    def total_weight_bytes(self) -> int:
        return sum(l.weight_bytes for l in self.layers)

    def successors(self, i: int) -> List[int]:
        return [b for a, b in self.edges if a == i]


# ---------------------------------------------------------------------------
# layer constructors
# ---------------------------------------------------------------------------

def conv(name: str, h: int, w: int, cin: int, cout: int, k: int,
         stride: int = 1, dw: bool = False) -> Layer:
    ho, wo = h // stride, w // stride
    if dw:
        macs = ho * wo * cin * k * k
        wbytes = cin * k * k * DTYPE_BYTES
        cout = cin
    else:
        macs = ho * wo * cout * cin * k * k
        wbytes = cin * k * k * cout * DTYPE_BYTES
    return Layer(name, macs, wbytes, ho * wo * cout * DTYPE_BYTES,
                 kind="dwconv" if dw else "conv")


def fc(name: str, din: int, dout: int, tokens: int = 1) -> Layer:
    return Layer(name, tokens * din * dout, din * dout * DTYPE_BYTES,
                 tokens * dout * DTYPE_BYTES, kind="matmul")


def _chain_edges(n: int) -> List[Tuple[int, int]]:
    return [(i, i + 1) for i in range(n - 1)]


# ---------------------------------------------------------------------------
# CNNs
# ---------------------------------------------------------------------------

def _resnet(name: str, block_counts: Sequence[int], bottleneck: bool) -> WorkloadGraph:
    layers: List[Layer] = [conv("stem", 224, 224, 3, 64, 7, stride=2)]
    edges: List[Tuple[int, int]] = []
    h = w = 56
    cin = 64
    widths = [64, 128, 256, 512]
    prev = 0
    for stage, (blocks, width) in enumerate(zip(block_counts, widths)):
        for b in range(blocks):
            stride = 2 if (b == 0 and stage > 0) else 1
            h2, w2 = h // stride, w // stride
            block_start = len(layers)
            if bottleneck:
                cout = width * 4
                layers.append(conv(f"s{stage}b{b}c1", h, w, cin, width, 1, stride))
                layers.append(conv(f"s{stage}b{b}c2", h2, w2, width, width, 3))
                layers.append(conv(f"s{stage}b{b}c3", h2, w2, width, cout, 1))
                edges += [(prev, block_start), (block_start, block_start + 1),
                          (block_start + 1, block_start + 2)]
                # skip connection: prev -> block output
                edges.append((prev, block_start + 2))
                prev = block_start + 2
            else:
                cout = width
                layers.append(conv(f"s{stage}b{b}c1", h, w, cin, width, 3, stride))
                layers.append(conv(f"s{stage}b{b}c2", h2, w2, width, width, 3))
                edges += [(prev, block_start), (block_start, block_start + 1)]
                edges.append((prev, block_start + 1))  # skip
                prev = block_start + 1
            cin = cout
            h, w = h2, w2
    head = len(layers)
    layers.append(fc("fc", cin, 1000))
    edges.append((prev, head))
    return WorkloadGraph(name, layers, edges)


def resnet18() -> WorkloadGraph:
    return _resnet("resnet18", [2, 2, 2, 2], bottleneck=False)


def resnet34() -> WorkloadGraph:
    return _resnet("resnet34", [3, 4, 6, 3], bottleneck=False)


def resnet50() -> WorkloadGraph:
    return _resnet("resnet50", [3, 4, 6, 3], bottleneck=True)


def mobilenet() -> WorkloadGraph:
    cfg = [(32, 64, 1), (64, 128, 2), (128, 128, 1), (128, 256, 2),
           (256, 256, 1), (256, 512, 2)] + [(512, 512, 1)] * 5 + \
          [(512, 1024, 2), (1024, 1024, 1)]
    layers = [conv("stem", 224, 224, 3, 32, 3, stride=2)]
    h = w = 112
    for i, (cin, cout, s) in enumerate(cfg):
        layers.append(conv(f"dw{i}", h, w, cin, cin, 3, stride=s, dw=True))
        h, w = h // s, w // s
        layers.append(conv(f"pw{i}", h, w, cin, cout, 1))
    layers.append(fc("fc", 1024, 1000))
    return WorkloadGraph("mobilenet", layers, _chain_edges(len(layers)))


def alexnet() -> WorkloadGraph:
    layers = [
        conv("c1", 224, 224, 3, 96, 11, stride=4),
        conv("c2", 27, 27, 96, 256, 5),
        conv("c3", 13, 13, 256, 384, 3),
        conv("c4", 13, 13, 384, 384, 3),
        conv("c5", 13, 13, 384, 256, 3),
        fc("f6", 256 * 6 * 6, 4096),
        fc("f7", 4096, 4096),
        fc("f8", 4096, 1000),
    ]
    return WorkloadGraph("alexnet", layers, _chain_edges(len(layers)))


def googlenet() -> WorkloadGraph:
    """Inception modules — branches expose graph-structure sensitivity."""
    layers: List[Layer] = [conv("stem1", 224, 224, 3, 64, 7, stride=2),
                           conv("stem2", 56, 56, 64, 192, 3)]
    edges: List[Tuple[int, int]] = [(0, 1)]
    prev = 1
    incep = [  # (h, cin, b1, b3r, b3, b5r, b5, pp)
        (28, 192, 64, 96, 128, 16, 32, 32),
        (28, 256, 128, 128, 192, 32, 96, 64),
        (14, 480, 192, 96, 208, 16, 48, 64),
        (14, 512, 160, 112, 224, 24, 64, 64),
        (14, 512, 128, 128, 256, 24, 64, 64),
        (14, 512, 112, 144, 288, 32, 64, 64),
        (14, 528, 256, 160, 320, 32, 128, 128),
        (7, 832, 256, 160, 320, 32, 128, 128),
        (7, 832, 384, 192, 384, 48, 128, 128),
    ]
    for m, (h, cin, b1, b3r, b3, b5r, b5, pp) in enumerate(incep):
        branch_outs = []
        i0 = len(layers)
        layers.append(conv(f"i{m}b1", h, h, cin, b1, 1)); edges.append((prev, i0))
        branch_outs.append(i0)
        i1 = len(layers)
        layers.append(conv(f"i{m}b3r", h, h, cin, b3r, 1)); edges.append((prev, i1))
        layers.append(conv(f"i{m}b3", h, h, b3r, b3, 3)); edges.append((i1, i1 + 1))
        branch_outs.append(i1 + 1)
        i2 = len(layers)
        layers.append(conv(f"i{m}b5r", h, h, cin, b5r, 1)); edges.append((prev, i2))
        layers.append(conv(f"i{m}b5", h, h, b5r, b5, 5)); edges.append((i2, i2 + 1))
        branch_outs.append(i2 + 1)
        i3 = len(layers)
        layers.append(conv(f"i{m}pp", h, h, cin, pp, 1)); edges.append((prev, i3))
        branch_outs.append(i3)
        # concat node: model as a cheap norm layer gathering the branches
        cat = len(layers)
        cout = b1 + b3 + b5 + pp
        layers.append(Layer(f"i{m}cat", 0, 0, h * h * cout * DTYPE_BYTES, kind="norm"))
        for b in branch_outs:
            edges.append((b, cat))
        prev = cat
    head = len(layers)
    layers.append(fc("fc", 1024, 1000))
    edges.append((prev, head))
    return WorkloadGraph("googlenet", layers, edges)


def yolo_lite() -> WorkloadGraph:
    layers = [
        conv("c1", 224, 224, 3, 16, 3, stride=2),
        conv("c2", 112, 112, 16, 32, 3, stride=2),
        conv("c3", 56, 56, 32, 64, 3, stride=2),
        conv("c4", 28, 28, 64, 128, 3, stride=2),
        conv("c5", 14, 14, 128, 128, 3),
        conv("c6", 14, 14, 128, 256, 3),
        conv("c7", 14, 14, 256, 125, 1),
    ]
    return WorkloadGraph("yolo_lite", layers, _chain_edges(len(layers)))


# ---------------------------------------------------------------------------
# transformers
# ---------------------------------------------------------------------------

def _transformer(name: str, n_layers: int, d: int, seq: int,
                 d_ff_mult: int = 4, vocab: int = 50257) -> WorkloadGraph:
    layers: List[Layer] = [Layer("embed", seq * d, vocab * d * DTYPE_BYTES,
                                 seq * d * DTYPE_BYTES, kind="matmul")]
    for i in range(n_layers):
        qkv = Layer(f"l{i}.qkv", seq * d * 3 * d, 3 * d * d * DTYPE_BYTES,
                    seq * 3 * d * DTYPE_BYTES, kind="matmul")
        attn = Layer(f"l{i}.attn", 2 * seq * seq * d, 0,
                     seq * d * DTYPE_BYTES, kind="matmul")
        # tensor parallelism reduces at the two residual-add boundaries:
        # attention output projection and MLP down projection
        proj = Layer(f"l{i}.proj", seq * d * d, d * d * DTYPE_BYTES,
                     seq * d * DTYPE_BYTES, kind="matmul", reduce_out=True)
        up = Layer(f"l{i}.up", seq * d * d_ff_mult * d,
                   d_ff_mult * d * d * DTYPE_BYTES,
                   seq * d_ff_mult * d * DTYPE_BYTES, kind="matmul")
        down = Layer(f"l{i}.down", seq * d_ff_mult * d * d,
                     d_ff_mult * d * d * DTYPE_BYTES,
                     seq * d * DTYPE_BYTES, kind="matmul", reduce_out=True)
        layers += [qkv, attn, proj, up, down]
    head = fc("lm_head", d, vocab, tokens=seq)
    head.reduce_out = True
    layers.append(head)
    return WorkloadGraph(name, layers, _chain_edges(len(layers)))


def gpt2_small(seq: int = 1024) -> WorkloadGraph:
    return _transformer("gpt2_small", 12, 768, seq)


def gpt2_medium(seq: int = 1024) -> WorkloadGraph:
    return _transformer("gpt2_medium", 24, 1024, seq)


def gpt2_large(seq: int = 1024) -> WorkloadGraph:
    return _transformer("gpt2_large", 36, 1280, seq)


def bert_base(seq: int = 384) -> WorkloadGraph:
    return _transformer("bert_base", 12, 768, seq, vocab=30522)


def transformer_generic(seq: int = 512) -> WorkloadGraph:
    return _transformer("transformer", 6, 512, seq, vocab=32000)


REGISTRY = {
    "resnet18": resnet18,
    "resnet34": resnet34,
    "resnet50": resnet50,
    "mobilenet": mobilenet,
    "alexnet": alexnet,
    "googlenet": googlenet,
    "yolo_lite": yolo_lite,
    "gpt2_small": gpt2_small,
    "gpt2_medium": gpt2_medium,
    "gpt2_large": gpt2_large,
    "bert_base": bert_base,
    "transformer": transformer_generic,
}


def get_workload(name: str) -> WorkloadGraph:
    try:
        return REGISTRY[name]()
    except KeyError:
        raise KeyError(f"unknown workload {name!r}; have {sorted(REGISTRY)}")


# ---------------------------------------------------------------------------
# layer -> core partitioning (pipeline mapping)
# ---------------------------------------------------------------------------

def partition_layers(graph: WorkloadGraph, n_cores: int,
                     cost: Optional[callable] = None) -> List[int]:
    """Contiguous pipeline partition balanced by ``cost`` (default: MACs):
    returns core index per layer (topological order == layer order by
    construction).
    """
    if n_cores <= 0:
        raise ValueError("n_cores must be positive")
    cost = cost or (lambda l: l.macs)
    costs = [cost(l) for l in graph.layers]
    total = sum(costs)
    target = total / n_cores
    out: List[int] = []
    core, acc = 0, 0
    remaining = total
    for i, layer in enumerate(graph.layers):
        out.append(core)
        acc += costs[i]
        remaining -= costs[i]
        cores_left = n_cores - core - 1
        if acc >= target and cores_left > 0 and remaining > 0:
            core += 1
            acc = 0
            target = remaining / max(cores_left, 1)
    return out
