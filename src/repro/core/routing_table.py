"""Routing tables: virtual NPU core id -> physical NPU core id.

Mirrors §4.1.1 / Fig. 4 of the paper.  Two encodings:

* ``DenseRoutingTable`` — one entry per virtual core (the "standard" table).
* ``CompactRoutingTable`` — for regular rectangular virtual topologies it
  stores only the initial virtual/physical core id and the shape, saving
  on-chip SRAM (the paper's optimized structure).

Both are owned by the hypervisor (meta-zone; §5.1) — guests get lookup only.
Entry bit-widths follow the paper's RTT sizing style and feed the hardware
cost model used by benchmarks/fig19_hwcost.py.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

# Bit widths for the HW cost model (physical core id, direction field, etc.)
CORE_ID_BITS = 16
VMID_BITS = 12
DIR_BITS = 3  # N/E/S/W/local + "use default DOR"


class RoutingError(KeyError):
    pass


@dataclasses.dataclass(frozen=True)
class RTKey:
    vmid: int
    v_core: int


class RoutingTable:
    """Base interface: translate virtual core id -> physical core id."""

    vmid: int

    def lookup(self, v_core: int) -> int:
        raise NotImplementedError

    def v_cores(self) -> List[int]:
        raise NotImplementedError

    def p_cores(self) -> List[int]:
        return [self.lookup(v) for v in self.v_cores()]

    def entry_count(self) -> int:
        raise NotImplementedError

    def storage_bits(self) -> int:
        raise NotImplementedError

    def as_dict(self) -> Dict[int, int]:
        return {v: self.lookup(v) for v in self.v_cores()}


class DenseRoutingTable(RoutingTable):
    """One (v_core -> p_core) entry per virtual core; supports irregular
    virtual topologies and per-hop direction overrides (NoC vRouter, §4.1.2).
    """

    def __init__(self, vmid: int, mapping: Dict[int, int]):
        if len(set(mapping.values())) != len(mapping):
            raise ValueError("physical cores must be unique within one vNPU")
        self.vmid = int(vmid)
        self._map = {int(k): int(v) for k, v in mapping.items()}
        # directions[(v_src, v_dst)] = list of hop directions predefined by the
        # hypervisor so packets stay confined to the virtual topology.
        self.directions: Dict[Tuple[int, int], List[str]] = {}

    def lookup(self, v_core: int) -> int:
        try:
            return self._map[v_core]
        except KeyError:
            raise RoutingError(
                f"vmid={self.vmid}: virtual core {v_core} not mapped"
            ) from None

    def v_cores(self) -> List[int]:
        return sorted(self._map)

    def entry_count(self) -> int:
        return len(self._map)

    def storage_bits(self) -> int:
        per_entry = CORE_ID_BITS * 2  # v_core, p_core
        dir_bits = sum(DIR_BITS * len(p) for p in self.directions.values())
        return VMID_BITS + per_entry * len(self._map) + dir_bits

    def set_route(self, v_src: int, v_dst: int, hop_dirs: Sequence[str]) -> None:
        self.lookup(v_src), self.lookup(v_dst)  # validate
        self.directions[(v_src, v_dst)] = list(hop_dirs)


class CompactRoutingTable(RoutingTable):
    """Regular-shape encoding: (v_start, p_start, shape) only.

    Virtual core ids are row-major over ``shape`` starting at ``v_start``;
    physical ids are row-major over the physical mesh of width
    ``phys_cols`` starting at ``p_start`` (the paper's Fig. 4 "specific
    routing table structure ... records the initial ID ... and the shape").
    """

    def __init__(self, vmid: int, v_start: int, p_start: int,
                 shape: Tuple[int, int], phys_cols: int):
        self.vmid = int(vmid)
        self.v_start = int(v_start)
        self.p_start = int(p_start)
        self.shape = (int(shape[0]), int(shape[1]))
        self.phys_cols = int(phys_cols)
        if self.shape[1] > self.phys_cols:
            raise ValueError("virtual mesh wider than physical mesh")

    def lookup(self, v_core: int) -> int:
        idx = v_core - self.v_start
        r, c = divmod(idx, self.shape[1])
        if not (0 <= r < self.shape[0] and 0 <= c < self.shape[1]) or idx < 0:
            raise RoutingError(
                f"vmid={self.vmid}: virtual core {v_core} outside shape {self.shape}"
            )
        return self.p_start + r * self.phys_cols + c

    def v_cores(self) -> List[int]:
        n = self.shape[0] * self.shape[1]
        return list(range(self.v_start, self.v_start + n))

    def entry_count(self) -> int:
        return 1

    def storage_bits(self) -> int:
        # v_start, p_start, 2 shape fields (8b each is plenty for 2^8 rows)
        return VMID_BITS + CORE_ID_BITS * 2 + 16


def make_routing_table(vmid: int, v_to_p: Dict[int, int],
                       phys_cols: Optional[int] = None,
                       phys_coords: Optional[Dict[int, Tuple[int, int]]] = None
                       ) -> RoutingTable:
    """Pick the cheapest encoding: compact when the mapping is a contiguous
    row-major rectangle on the physical mesh, dense otherwise.
    """
    if phys_cols is not None and phys_coords is not None and v_to_p:
        v_sorted = sorted(v_to_p)
        v0 = v_sorted[0]
        if v_sorted == list(range(v0, v0 + len(v_sorted))):
            coords = [phys_coords[v_to_p[v]] for v in v_sorted]
            rows = sorted({r for r, _ in coords})
            cols = sorted({c for _, c in coords})
            nr, nc = rows[-1] - rows[0] + 1, cols[-1] - cols[0] + 1
            if nr * nc == len(v_sorted):
                want = [
                    (rows[0] + i, cols[0] + j)
                    for i in range(nr)
                    for j in range(nc)
                ]
                if coords == want:
                    p_start = v_to_p[v0]
                    cand = CompactRoutingTable(vmid, v0, p_start, (nr, nc), phys_cols)
                    if cand.as_dict() == {int(k): int(v) for k, v in v_to_p.items()}:
                        return cand
    return DenseRoutingTable(vmid, v_to_p)


class RoutingTableDirectory:
    """All routing tables, indexed by VMID — the NPU controller's SRAM-resident
    directory (§4.1.1: "the NPU controller stores all routing tables in SRAM").
    """

    def __init__(self):
        self._tables: Dict[int, RoutingTable] = {}

    def install(self, table: RoutingTable) -> None:
        self._tables[table.vmid] = table

    def remove(self, vmid: int) -> None:
        self._tables.pop(vmid, None)

    def get(self, vmid: int) -> RoutingTable:
        try:
            return self._tables[vmid]
        except KeyError:
            raise RoutingError(f"no routing table for vmid={vmid}") from None

    def translate(self, vmid: int, v_core: int) -> int:
        return self.get(vmid).lookup(v_core)

    def vmids(self) -> List[int]:
        return sorted(self._tables)

    def storage_bits(self) -> int:
        return sum(t.storage_bits() for t in self._tables.values())
