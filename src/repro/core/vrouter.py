"""vRouter: virtualization of the NPU instruction router and the NoC (§4.1).

* ``InstructionRouter`` — the NPU-controller-side vRouter.  Translates the
  virtual core id carried by every NPU instruction into a physical core id
  via the routing-table directory.  Models the paper's "consecutive
  instructions to the same core skip the lookup" optimization and both
  dispatch transports (shared instruction BUS vs. dedicated instruction NoC,
  Fig. 12).
* ``NoCRouter`` — per-core vRouter for data packets.  Send/receive rewrite
  the virtual destination id to a physical id; relay hops either follow
  dimension-order routing (DOR) on the *physical* mesh (may interfere with
  other tenants) or hypervisor-predefined directions that confine the path to
  the tenant's own cores (§4.1.2, Fig. 5).

Latency constants are in cycles and calibrated so the micro-benchmarks land
in the ranges the paper reports (Fig. 11/12, Table 3).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .routing_table import RoutingTable, RoutingTableDirectory, RoutingError
from .topology import Topology

# --- calibrated cycle constants (FPGA column of Table 2, 1 GHz) -----------
RT_LOOKUP_CYCLES = 2          # SRAM-resident routing table read
IBUS_DISPATCH_CYCLES = 4      # shared instruction bus, distance-independent
INOC_HOP_CYCLES = 3           # per-hop latency of the dedicated instr NoC
NOC_HOP_CYCLES = 3            # data NoC per-hop router latency
NOC_FLIT_BYTES = 32           # link width
PACKET_BYTES = 2048           # "routing packet" size used in Table 3
SEND_SETUP_CYCLES = 20        # send engine setup per packet
RECV_SETUP_CYCLES = 22
VROUTER_REWRITE_CYCLES = 1    # dst-id rewrite in the send/receive engine
AVAIL_QUERY_CYCLES_PER_CORE = 2   # Fig. 11: query core availability
RT_CONFIG_CYCLES_PER_ENTRY = 3    # Fig. 11: write one RT entry

Coord = Tuple[int, int]
DIRS = {"E": (0, 1), "W": (0, -1), "S": (1, 0), "N": (-1, 0)}


def dor_path(src: Coord, dst: Coord) -> List[Coord]:
    """Dimension-order (X-then-Y) route on a 2D mesh; includes endpoints."""
    path = [src]
    r, c = src
    while c != dst[1]:
        c += 1 if dst[1] > c else -1
        path.append((r, c))
    while r != dst[0]:
        r += 1 if dst[0] > r else -1
        path.append((r, c))
    return path


def path_directions(path: Sequence[Coord]) -> List[str]:
    out = []
    for (r0, c0), (r1, c1) in zip(path, path[1:]):
        for name, (dr, dc) in DIRS.items():
            if (r1 - r0, c1 - c0) == (dr, dc):
                out.append(name)
                break
        else:
            raise ValueError("non-adjacent hop in path")
    return out


def confined_path(topo: Topology, src: int, dst: int, owned: Iterable[int]) -> Optional[List[int]]:
    """Shortest path src->dst using only ``owned`` nodes (BFS).  Returns node
    ids (incl. endpoints) or None if the tenant's subgraph disconnects them.
    """
    owned_set = set(owned) | {src, dst}
    from collections import deque
    adj = topo._adj()
    prev = {src: None}
    q = deque([src])
    while q:
        cur = q.popleft()
        if cur == dst:
            path = [cur]
            while prev[cur] is not None:
                cur = prev[cur]
                path.append(cur)
            return path[::-1]
        for nb in adj[cur]:
            if nb in owned_set and nb not in prev:
                prev[nb] = cur
                q.append(nb)
    return None


@dataclasses.dataclass
class DispatchResult:
    p_core: int
    cycles: int
    rt_lookup: bool


class InstructionRouter:
    """NPU-controller vRouter for instruction dispatch (§4.1.1, Fig. 4/12)."""

    def __init__(self, directory: RoutingTableDirectory, phys_topo: Topology,
                 controller_coord: Coord = (0, 0), transport: str = "inoc"):
        if transport not in ("inoc", "ibus"):
            raise ValueError("transport must be 'inoc' or 'ibus'")
        self.directory = directory
        self.topo = phys_topo
        self.controller = controller_coord
        self.transport = transport
        self._last: Optional[Tuple[int, int]] = None  # (vmid, v_core) cache

    def dispatch(self, vmid: int, v_core: int) -> DispatchResult:
        cycles = 0
        rt_lookup = self._last != (vmid, v_core)
        if rt_lookup:
            cycles += RT_LOOKUP_CYCLES
            self._last = (vmid, v_core)
        p_core = self.directory.translate(vmid, v_core)
        if self.transport == "ibus":
            cycles += IBUS_DISPATCH_CYCLES
        else:
            dst = self.topo.coords[p_core]
            hops = abs(dst[0] - self.controller[0]) + abs(dst[1] - self.controller[1])
            cycles += INOC_HOP_CYCLES * max(hops, 1)
        return DispatchResult(p_core=p_core, cycles=cycles, rt_lookup=rt_lookup)


@dataclasses.dataclass
class NoCTransfer:
    """Result of one virtualized send/receive pair."""
    path: List[int]                 # physical node ids, incl. endpoints
    send_cycles: int
    recv_cycles: int
    interference_nodes: Set[int]    # relay nodes owned by *other* tenants


class NoCRouter:
    """Per-core NoC vRouter (§4.1.2, Fig. 5)."""

    def __init__(self, phys_topo: Topology):
        self.topo = phys_topo
        self._coord_to_node = {v: k for k, v in phys_topo.coords.items()}

    def _nodes_of(self, coords: Sequence[Coord]) -> List[int]:
        return [self._coord_to_node[c] for c in coords]

    def route(self, rt: RoutingTable, v_src: int, v_dst: int,
              owned_p_cores: Iterable[int], *, confined: bool,
              payload_bytes: int = PACKET_BYTES,
              virtualized: bool = True) -> NoCTransfer:
        """Compute the physical path and cycle cost of sending one packet.

        ``virtualized=False`` models the bare-metal NoC (no dst-id rewrite) —
        Table 3's non-virtualization columns.
        """
        p_src = rt.lookup(v_src) if virtualized else v_src
        p_dst = rt.lookup(v_dst) if virtualized else v_dst
        owned = set(owned_p_cores)

        if confined and virtualized:
            nodes = confined_path(self.topo, p_src, p_dst, owned)
            if nodes is None:
                raise RoutingError(
                    f"vNPU subgraph disconnects {p_src}->{p_dst}; cannot confine")
        else:
            coords = dor_path(self.topo.coords[p_src], self.topo.coords[p_dst])
            nodes = self._nodes_of(coords)

        hops = max(len(nodes) - 1, 1)
        flits = max(1, -(-payload_bytes // NOC_FLIT_BYTES))
        rewrite = VROUTER_REWRITE_CYCLES if virtualized else 0
        # wormhole: head latency = hops * per-hop + serialization of the body
        send = SEND_SETUP_CYCLES + rewrite + hops * NOC_HOP_CYCLES + flits
        recv = RECV_SETUP_CYCLES + rewrite + hops * NOC_HOP_CYCLES + flits
        interference = {n for n in nodes[1:-1] if n not in owned}
        return NoCTransfer(path=nodes, send_cycles=send, recv_cycles=recv,
                           interference_nodes=interference)

    def link_loads(self, paths: Iterable[Sequence[int]]) -> Dict[Tuple[int, int], int]:
        """Count how many flows use each physical link — the contention input
        for the simulator's congestion model.
        """
        loads: Dict[Tuple[int, int], int] = {}
        for path in paths:
            for a, b in zip(path, path[1:]):
                e = (a, b) if a <= b else (b, a)
                loads[e] = loads.get(e, 0) + 1
        return loads


def rt_config_cost(n_cores: int) -> Dict[str, int]:
    """Fig. 11: cycles to (a) query availability of candidate cores and
    (b) write the routing-table entries during vNPU creation."""
    return {
        "query_cycles": AVAIL_QUERY_CYCLES_PER_CORE * n_cores,
        "config_cycles": RT_CONFIG_CYCLES_PER_ENTRY * n_cores,
        "total_cycles": (AVAIL_QUERY_CYCLES_PER_CORE + RT_CONFIG_CYCLES_PER_ENTRY) * n_cores,
    }
