"""Topology graphs for inter-core connected NPUs.

The paper (vNPU, ISCA'25) models an NPU as a set of cores at fixed
topological positions joined by NoC links.  This module provides the graph
substrate used by every other layer: routing (vrouter), allocation
(mapping/hypervisor) and the JAX mesh integration (vmesh).

Nodes are integer core ids.  Node attributes carry heterogeneity info
(``abbr`` — core type, ``mem_dist`` — hops to the nearest memory interface).
Edge attributes carry a ``cost`` used by the customized edge-match functions
of the topology-mapping algorithm (Algorithm 1 in the paper).
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Dict, FrozenSet, Iterable, Iterator, List, Optional, Sequence, Tuple

Edge = Tuple[int, int]


def _norm_edge(a: int, b: int) -> Edge:
    return (a, b) if a <= b else (b, a)


@dataclasses.dataclass
class Topology:
    """An undirected graph of NPU cores.

    ``coords`` optionally maps node id -> (row, col) for mesh-like physical
    topologies; virtual topologies produced by the mapper may have no
    coordinates (irregular shapes).
    """

    node_attrs: Dict[int, Dict]
    edge_attrs: Dict[Edge, Dict]
    coords: Dict[int, Tuple[int, int]] = dataclasses.field(default_factory=dict)
    name: str = ""

    # -- construction -----------------------------------------------------
    @staticmethod
    def from_edges(nodes: Iterable[int], edges: Iterable[Edge], name: str = "") -> "Topology":
        na = {int(n): {} for n in nodes}
        ea = {}
        for a, b in edges:
            e = _norm_edge(int(a), int(b))
            if e[0] == e[1]:
                raise ValueError(f"self loop on node {e[0]}")
            if e[0] not in na or e[1] not in na:
                raise ValueError(f"edge {e} references unknown node")
            ea[e] = {}
        return Topology(na, ea, name=name)

    def copy(self) -> "Topology":
        return Topology(
            {n: dict(a) for n, a in self.node_attrs.items()},
            {e: dict(a) for e, a in self.edge_attrs.items()},
            dict(self.coords),
            self.name,
        )

    # -- basic accessors ---------------------------------------------------
    def nodes(self) -> List[int]:
        return sorted(self.node_attrs)

    def edges(self) -> List[Edge]:
        return sorted(self.edge_attrs)

    @property
    def num_nodes(self) -> int:
        return len(self.node_attrs)

    @property
    def num_edges(self) -> int:
        return len(self.edge_attrs)

    def has_edge(self, a: int, b: int) -> bool:
        return _norm_edge(a, b) in self.edge_attrs

    def neighbors(self, n: int) -> List[int]:
        out = []
        for (a, b) in self.edge_attrs:
            if a == n:
                out.append(b)
            elif b == n:
                out.append(a)
        return sorted(out)

    def degree(self, n: int) -> int:
        return len(self.neighbors(n))

    def degree_sequence(self) -> Tuple[int, ...]:
        return tuple(sorted(self.degree(n) for n in self.node_attrs))

    # -- structure ----------------------------------------------------------
    def subgraph(self, nodes: Iterable[int]) -> "Topology":
        keep = set(int(n) for n in nodes)
        missing = keep - set(self.node_attrs)
        if missing:
            raise ValueError(f"subgraph nodes not in topology: {sorted(missing)}")
        na = {n: dict(self.node_attrs[n]) for n in keep}
        ea = {e: dict(a) for e, a in self.edge_attrs.items() if e[0] in keep and e[1] in keep}
        co = {n: self.coords[n] for n in keep if n in self.coords}
        return Topology(na, ea, co, name=f"{self.name}.sub")

    def is_connected(self, nodes: Optional[Iterable[int]] = None) -> bool:
        if nodes is None:
            node_set = set(self.node_attrs)
            adj = self._adj()
        else:
            node_set = set(int(n) for n in nodes)
            adj = {n: [m for m in self._adj().get(n, ()) if m in node_set] for n in node_set}
        if not node_set:
            return True
        start = next(iter(node_set))
        seen = {start}
        q = deque([start])
        while q:
            cur = q.popleft()
            for nb in adj[cur]:
                if nb not in seen:
                    seen.add(nb)
                    q.append(nb)
        return seen == node_set

    def _adj(self) -> Dict[int, List[int]]:
        adj: Dict[int, List[int]] = {n: [] for n in self.node_attrs}
        for a, b in self.edge_attrs:
            adj[a].append(b)
            adj[b].append(a)
        return adj

    def bfs_hops(self, src: int, dst: int, allowed: Optional[Iterable[int]] = None) -> int:
        """Shortest hop count src->dst, optionally restricted to ``allowed`` nodes.

        Returns -1 if unreachable.
        """
        allow = set(self.node_attrs) if allowed is None else set(allowed) | {src, dst}
        adj = self._adj()
        seen = {src: 0}
        q = deque([src])
        while q:
            cur = q.popleft()
            if cur == dst:
                return seen[cur]
            for nb in adj[cur]:
                if nb in allow and nb not in seen:
                    seen[nb] = seen[cur] + 1
                    q.append(nb)
        return -1

    # -- isomorphism-dedup support ------------------------------------------
    def canonical_key(self, rounds: int = 3) -> Tuple:
        """Weisfeiler-Lehman style hash used to deduplicate candidate
        topologies that are isomorphic (pruning rule 2 of Algorithm 1).

        Not a perfect canonical form (WL cannot distinguish all graphs) but a
        sound *grouping* key: isomorphic graphs always collide.  We refine
        with the node-attribute ``abbr`` so heterogeneous cores separate.
        """
        labels = {
            n: (self.degree(n), self.node_attrs[n].get("abbr", ""))
            for n in self.node_attrs
        }
        adj = self._adj()
        for _ in range(rounds):
            new = {}
            for n in self.node_attrs:
                neigh = tuple(sorted(labels[m] for m in adj[n]))
                new[n] = (labels[n], neigh)
            # compress
            uniq = {lab: i for i, lab in enumerate(sorted(set(new.values())))}
            labels = {n: (uniq[new[n]],) for n in new}
        return (self.num_nodes, self.num_edges, tuple(sorted(labels.values())))

    def is_rect_mesh(self) -> Optional[Tuple[int, int]]:
        """If this topology is exactly an r x c 2D mesh (by coords), return
        (r, c); else None.  Used to pick the compact routing-table encoding.
        """
        if not self.coords or len(self.coords) != self.num_nodes:
            return None
        rows = sorted({r for r, _ in self.coords.values()})
        cols = sorted({c for _, c in self.coords.values()})
        r0, c0 = rows[0], cols[0]
        nr, nc = rows[-1] - r0 + 1, cols[-1] - c0 + 1
        if nr * nc != self.num_nodes:
            return None
        want = {(r0 + i, c0 + j) for i in range(nr) for j in range(nc)}
        if set(self.coords.values()) != want:
            return None
        # every lattice-adjacent pair must be an edge and nothing else
        by_coord = {v: k for k, v in self.coords.items()}
        expect_edges = set()
        for (r, c), n in by_coord.items():
            for dr, dc in ((0, 1), (1, 0)):
                m = by_coord.get((r + dr, c + dc))
                if m is not None:
                    expect_edges.add(_norm_edge(n, m))
        if expect_edges != set(self.edge_attrs):
            return None
        return (nr, nc)


# ---------------------------------------------------------------------------
# standard constructions
# ---------------------------------------------------------------------------

def mesh_2d(rows: int, cols: int, *, base_id: int = 0, torus: bool = False,
            mem_interface_cols: Sequence[int] = (0,), name: str = "") -> Topology:
    """Build an ``rows x cols`` 2D mesh (optionally torus) of cores.

    Core ids are row-major starting at ``base_id`` — matching the paper's
    figures (Fig. 5: 4x4 mesh ids 0..15).  ``mem_interface_cols`` marks which
    columns host HBM memory interfaces; the node attribute ``mem_dist`` is the
    hop distance to the nearest interface column, used by the heterogeneous
    node-match penalty of the mapping algorithm (§4.3).
    """
    if rows <= 0 or cols <= 0:
        raise ValueError("mesh dims must be positive")
    nid = lambda r, c: base_id + r * cols + c
    nodes = [nid(r, c) for r in range(rows) for c in range(cols)]
    edges: List[Edge] = []
    for r in range(rows):
        for c in range(cols):
            if c + 1 < cols:
                edges.append((nid(r, c), nid(r, c + 1)))
            elif torus and cols > 2:
                edges.append((nid(r, c), nid(r, 0)))
            if r + 1 < rows:
                edges.append((nid(r, c), nid(r + 1, c)))
            elif torus and rows > 2:
                edges.append((nid(r, c), nid(0, c)))
    topo = Topology.from_edges(nodes, edges, name=name or f"mesh{rows}x{cols}")
    for r in range(rows):
        for c in range(cols):
            n = nid(r, c)
            topo.coords[n] = (r, c)
            topo.node_attrs[n]["abbr"] = "npu"
            topo.node_attrs[n]["mem_dist"] = min(abs(c - mc) for mc in mem_interface_cols)
    return topo


def line(n: int, base_id: int = 0) -> Topology:
    return mesh_2d(1, n, base_id=base_id, name=f"line{n}")


def ring(n: int, base_id: int = 0) -> Topology:
    nodes = list(range(base_id, base_id + n))
    edges = [(nodes[i], nodes[(i + 1) % n]) for i in range(n)]
    t = Topology.from_edges(nodes, edges, name=f"ring{n}")
    for i, nd in enumerate(nodes):
        t.node_attrs[nd]["abbr"] = "npu"
    return t


def enumerate_connected_subsets(
    topo: Topology,
    size: int,
    *,
    within: Optional[Iterable[int]] = None,
    max_results: Optional[int] = None,
) -> Iterator[FrozenSet[int]]:
    """Enumerate connected induced node subsets of ``size`` nodes.

    Classic recursive enumeration (each subset emitted exactly once): grow
    from every start node, only adding neighbours greater than the start and
    not in the per-branch exclusion set.  ``within`` restricts to the free
    (unallocated) nodes — the ``remainN`` of Algorithm 1.
    """
    allow = set(topo.node_attrs) if within is None else set(within)
    adj = {n: [m for m in topo._adj()[n] if m in allow] for n in allow}
    count = 0

    def grow(cur: FrozenSet[int], frontier: List[int], excluded: FrozenSet[int], start: int):
        nonlocal count
        if max_results is not None and count >= max_results:
            return
        if len(cur) == size:
            count += 1
            yield cur
            return
        # candidate extensions: neighbours of cur not excluded, > start
        cand = sorted(
            {m for n in cur for m in adj[n] if m not in cur and m not in excluded and m > start}
        )
        ex = set(excluded)
        for m in cand:
            yield from grow(cur | {m}, [], frozenset(ex), start)
            ex.add(m)  # subsequent branches must not use m (avoids dupes)
            if max_results is not None and count >= max_results:
                return

    for s in sorted(allow):
        yield from grow(frozenset([s]), [], frozenset(), s)
        if max_results is not None and count >= max_results:
            return
