"""The vNPU hypervisor (§5.2): virtual-NPU lifecycle and meta-table owner.

Manages, per virtual NPU:
  * core allocation through the :class:`~repro.core.engine.MappingEngine`
    (incremental free regions, cached minTopologyEditDistance, vectorized
    candidate scoring; exact -> similar -> optional fragmented fallback),
  * the routing table (compact encoding when the allocation is a contiguous
    rectangle, dense otherwise) + confined-routing directions,
  * global-memory allocation through the buddy system, recorded as RTT
    ranges sorted by virtual address,
  * the per-tenant Access Counter bandwidth cap.

The hypervisor is the engine's single writer: every lifecycle transition
(create / destroy / remap / migrate) drives the engine's
``notify_allocate`` / ``notify_release`` invalidation hooks, so the
engine's incremental free-region view is always exactly the complement of
the resident vNPUs' cores.

The two comparison allocators used throughout §6 (``MIGPartitioner``,
``UVMAllocator``) live in :mod:`repro.core.baselines` and are re-exported
here for backward compatibility.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence, Set, Tuple

from .baselines import (AllocationError, MIGPartition, MIGPartitioner,
                        UVMAllocator)
from .buddy import BuddyAllocator, OutOfMemory
from .engine import MappingEngine
from .mapping import (MappingResult, straightforward_mapping,
                      mem_dist_node_match, NodeMatch, EdgeMatch)
from .routing_table import (DenseRoutingTable, RoutingTable,
                            RoutingTableDirectory, make_routing_table)
from .topology import Topology, mesh_2d
from .vchunk import AccessCounter, RangeTranslationTable, RTTEntry
from .vrouter import NoCRouter, confined_path, path_directions


@dataclasses.dataclass
class VNPURequest:
    """What a VM asks for at creation (§5.2): cores+topology, memory, QoS."""
    topology: Topology
    memory_bytes: int = 0
    bandwidth_cap: Optional[int] = None   # bytes per window, None = unlimited
    require_connected: bool = True
    confined_routing: bool = False
    strategy: str = "similar"             # similar | straightforward
    mapper: Optional[str] = None          # engine strategy override
                                          # (exact|hybrid|bipartite|rect)


@dataclasses.dataclass
class VirtualNPU:
    vmid: int
    request: VNPURequest
    p_cores: FrozenSet[int]
    assignment: Dict[int, int]            # virtual core id -> physical core id
    routing_table: RoutingTable
    rtt: RangeTranslationTable
    access_counter: AccessCounter
    ted: float
    exact: bool
    mem_blocks: List[int] = dataclasses.field(default_factory=list)
    time_share: float = 1.0               # <1.0 when TDM-shared (MIG baseline)

    @property
    def n_cores(self) -> int:
        return len(self.p_cores)

    def virtual_topology(self) -> Topology:
        return self.request.topology


class Hypervisor:
    """CPU-side hypervisor + hyper-mode NPU controller state (§5)."""

    def __init__(self, phys_topo: Topology, hbm_bytes: int = 1 << 36,
                 min_block: int = 1 << 20,
                 engine: Optional[MappingEngine] = None,
                 mapper: Optional[str] = None):
        self.topo = phys_topo
        self.directory = RoutingTableDirectory()
        self.noc = NoCRouter(phys_topo)
        self.buddy = BuddyAllocator(hbm_bytes, min_block=min_block)
        if engine is not None:
            # an injected engine (e.g. with a pre-warmed TED cache) must
            # describe this mesh and agree that nothing is allocated yet —
            # the hypervisor is the engine's single writer from here on
            if engine.topo is not phys_topo:
                raise ValueError("injected MappingEngine is bound to a "
                                 "different topology")
            if engine.regions.free != set(phys_topo.node_attrs):
                raise ValueError("injected MappingEngine already has cores "
                                 "allocated; pass a fresh (or reset) engine")
            if mapper is not None:       # don't silently drop the request
                if mapper not in engine.mappers:
                    raise KeyError(f"unknown mapper {mapper!r}; "
                                   f"have {sorted(engine.mappers)}")
                engine.default_mapper = mapper
            self.engine = engine
        else:
            self.engine = MappingEngine(phys_topo, mapper=mapper or "hybrid")
        self.vnpus: Dict[int, VirtualNPU] = {}
        self.quarantined: Set[int] = set()     # failed cores, never realloc'd
        self._next_vmid = 1

    # -- introspection -----------------------------------------------------
    def allocated_cores(self) -> Set[int]:
        return {p for v in self.vnpus.values() for p in v.p_cores}

    def free_cores(self) -> Set[int]:
        # the engine's incrementally-maintained view IS the free set: every
        # lifecycle transition drives its notify hooks, and the integration
        # tests reconstruct the expected set from vnpus+quarantine to pin it
        return set(self.engine.regions.free)

    def utilization(self) -> float:
        # fraction of *healthy* capacity doing useful work: quarantined
        # (dead) cores leave both sides — a dead core still held by a
        # not-yet-migrated tenant is not useful work, and counting it would
        # push utilization past 1.0
        total = self.topo.num_nodes - len(self.quarantined)
        useful = len(self.allocated_cores() - self.quarantined)
        return useful / total if total else 0.0

    # -- fault handling ------------------------------------------------------
    def mark_failed(self, cores: Iterable[int]) -> None:
        """Quarantine dead cores: they leave the allocatable pool for good.
        A quarantined core that is currently owned by a vNPU stays out of
        the pool when that tenant remaps away or is destroyed."""
        new = (set(int(c) for c in cores) & set(self.topo.node_attrs)) \
            - self.quarantined
        if not new:
            return
        self.quarantined |= new
        # pull currently-free dead cores out of the engine's free regions;
        # allocated ones are withheld at release time instead
        self.engine.notify_allocate(new & self.engine.regions.free)

    def mark_repaired(self, cores: Iterable[int]) -> None:
        """Lift the quarantine on repaired cores.  Unowned ones rejoin the
        engine's free regions immediately; a repaired core still owned by a
        vNPU just keeps serving it and rejoins the pool through the normal
        release path (which only withholds *still-quarantined* cores)."""
        back = set(int(c) for c in cores) & self.quarantined
        if not back:
            return
        self.quarantined -= back
        unowned = back - self.allocated_cores()
        if unowned:
            self.engine.notify_release(unowned)

    # -- placement ----------------------------------------------------------
    def _map_request(self, request: VNPURequest,
                     node_match: Optional[NodeMatch],
                     edge_match: Optional[EdgeMatch]
                     ) -> Optional[MappingResult]:
        if request.strategy == "straightforward":
            return straightforward_mapping(
                self.topo, self.allocated_cores() | self.quarantined,
                request.topology)
        # relaxed requests never need a straightforward fallback here: the
        # engine's zig-zag relaxed path already covers every free>=k case
        return self.engine.map_request(
            request.topology, node_match=node_match, edge_match=edge_match,
            require_connected=request.require_connected,
            mapper=request.mapper)

    def can_allocate(self, request: VNPURequest,
                     node_match: Optional[NodeMatch] = None,
                     edge_match: Optional[EdgeMatch] = None) -> bool:
        """Side-effect-free feasibility probe.  The mapping computed here is
        cached by the engine, so probe-then-allocate costs one solve."""
        k = request.topology.num_nodes
        if k > len(self.free_cores()):
            return False
        if request.strategy == "straightforward":
            return True
        return self._map_request(request, node_match, edge_match) is not None

    # -- lifecycle ----------------------------------------------------------
    def create_vnpu(self, request: VNPURequest,
                    node_match: Optional[NodeMatch] = None,
                    edge_match: Optional[EdgeMatch] = None) -> VirtualNPU:
        k = request.topology.num_nodes
        free = self.free_cores()
        if k > len(free):
            raise AllocationError(
                f"requested {k} cores, only {len(free)} free")

        result = self._map_request(request, node_match, edge_match)
        if result is None:
            raise AllocationError(
                f"no candidate sub-topology of {k} cores "
                f"(topology lock-in; free={len(free)})")

        vmid = self._next_vmid
        self._next_vmid += 1

        # routing table: virtual ids are the request topology's node ids
        v_to_p = dict(result.assignment)
        rt = make_routing_table(
            vmid, v_to_p,
            phys_cols=self._phys_cols(),
            phys_coords=self.topo.coords or None)

        # confined routing: pre-program per-hop directions for every pair
        if request.confined_routing and isinstance(rt, DenseRoutingTable):
            self._program_confined_routes(rt, result.nodes)

        # memory: buddy blocks -> RTT ranges sorted by vaddr (§5.2)
        rtt = RangeTranslationTable()
        blocks: List[int] = []
        if request.memory_bytes > 0:
            vaddr = 0
            remaining = request.memory_bytes
            while remaining > 0:
                chunk = min(remaining, self.buddy.total // 4)
                try:
                    paddr, size = self.buddy.alloc(chunk)
                except OutOfMemory:
                    for b in blocks:
                        self.buddy.free_block(b)
                    raise AllocationError("insufficient NPU global memory")
                blocks.append(paddr)
                rtt.insert(RTTEntry(vaddr=vaddr, paddr=paddr, size=size))
                vaddr += size
                remaining -= size

        vnpu = VirtualNPU(
            vmid=vmid, request=request, p_cores=result.nodes,
            assignment=v_to_p, routing_table=rt, rtt=rtt,
            access_counter=AccessCounter(request.bandwidth_cap),
            ted=result.ted, exact=result.exact, mem_blocks=blocks)
        self.vnpus[vmid] = vnpu
        self.directory.install(rt)
        self.engine.notify_allocate(result.nodes)
        return vnpu

    def destroy_vnpu(self, vmid: int) -> None:
        vnpu = self.vnpus.pop(vmid, None)
        if vnpu is None:
            raise AllocationError(f"unknown vmid {vmid}")
        self.directory.remove(vmid)
        for b in vnpu.mem_blocks:
            self.buddy.free_block(b)
        self.engine.notify_release(set(vnpu.p_cores) - self.quarantined)

    def _phys_cols(self) -> Optional[int]:
        shape = self.topo.is_rect_mesh()
        return shape[1] if shape else None

    def _program_confined_routes(self, rt: DenseRoutingTable,
                                 owned: FrozenSet[int]) -> None:
        v_cores = rt.v_cores()
        for v_src, v_dst in itertools.permutations(v_cores, 2):
            p_src, p_dst = rt.lookup(v_src), rt.lookup(v_dst)
            path = confined_path(self.topo, p_src, p_dst, owned)
            if path is None:
                raise AllocationError(
                    "confined routing requested but allocation disconnects "
                    f"{p_src}->{p_dst}")
            if self.topo.coords:
                coords = [self.topo.coords[n] for n in path]
                rt.set_route(v_src, v_dst, path_directions(coords))

    # -- elastic remap (fault tolerance; used by vmesh/elastic) -------------
    def remap_vnpu(self, vmid: int, failed_cores: Iterable[int],
                   node_match: Optional[NodeMatch] = None, *,
                   quarantine: bool = True) -> VirtualNPU:
        """Device failure path: re-run similar-topology mapping over the
        surviving free cores and re-install the routing table.  Memory (RTT)
        is preserved — HBM contents are re-loaded from checkpoint by the
        training runtime.

        ``failed_cores`` are quarantined by default — they never rejoin the
        allocatable pool (``mark_failed``).  The defragmentation path
        (``migrate_vnpu``) passes ``quarantine=False``: its ``avoid`` set is
        advisory, not dead hardware.

        The tenant's own surviving cores count as free for the re-solve (it
        vacates them) — expressed to the engine as a ``free_override``; the
        canonical TED cache still applies, so a migration back into a
        previously-seen region shape is a cache hit.
        """
        vnpu = self.vnpus[vmid]
        failed = set(failed_cores)
        if quarantine:
            self.mark_failed(failed)
        old_cores = set(vnpu.p_cores)
        free_for_remap = ((self.free_cores() | old_cores) - failed
                          - self.quarantined)
        result = self.engine.map_request(
            vnpu.request.topology, node_match=node_match,
            require_connected=vnpu.request.require_connected,
            mapper=vnpu.request.mapper, free_override=free_for_remap)
        if result is None:
            raise AllocationError(
                f"cannot remap vmid={vmid}: no surviving sub-topology")
        if result.nodes == vnpu.p_cores:
            # same core set: the installed routing table still maps the
            # request onto exactly these cores, so an assignment-only
            # re-shuffle buys nothing — skip the rebuild/reinstall/region
            # churn entirely and keep ``migrate_vnpu``'s moved=False honest
            return vnpu
        return self._commit_mapping(vnpu, result)

    # -- shared solve-commit (remap / resize) --------------------------------
    def _commit_mapping(self, vnpu: VirtualNPU,
                        result: MappingResult) -> VirtualNPU:
        """Install a re-solve onto a live vNPU: rebuild and reinstall the
        routing table under the same vmid, swap the core set and the
        engine's free-region view.  Memory (RTT) is untouched.  The one
        commit sequence both :meth:`remap_vnpu` and :meth:`resize_vnpu`
        use — any ordering or quarantine fix lands in both paths."""
        old_cores = set(vnpu.p_cores)
        rt = make_routing_table(vnpu.vmid, dict(result.assignment),
                                phys_cols=self._phys_cols(),
                                phys_coords=self.topo.coords or None)
        vnpu.p_cores = result.nodes
        vnpu.assignment = dict(result.assignment)
        vnpu.routing_table = rt
        vnpu.ted = result.ted
        vnpu.exact = result.exact
        self.directory.install(rt)
        self.engine.notify_release(old_cores - self.quarantined)
        self.engine.notify_allocate(result.nodes)
        return vnpu

    # -- planned remap (the scheduler's ILP defrag planner) ------------------
    def apply_mapping(self, vmid: int, result: MappingResult) -> VirtualNPU:
        """Install an externally-planned mapping onto a live vNPU (the
        scheduler's defrag planner computed it through the engine's
        side-effect-free ``free_override`` path).  The destination must be
        available *now* — free cores plus the vNPU's own, never
        quarantined — so a stale plan fails loudly instead of corrupting
        the region tracker.  Same-core-set plans are no-ops (planners drop
        them, but the check keeps the call idempotent)."""
        vnpu = self.vnpus[vmid]
        avail = ((self.free_cores() | set(vnpu.p_cores))
                 - self.quarantined)
        if not set(result.nodes) <= avail:
            raise AllocationError(
                f"planned mapping for vmid={vmid} uses unavailable cores "
                f"{sorted(set(result.nodes) - avail)}")
        if result.nodes == vnpu.p_cores:
            return vnpu
        return self._commit_mapping(vnpu, result)

    # -- elastic resize (serving plane; used by sched/cluster) --------------
    def resize_vnpu(self, vmid: int, new_topology: Topology,
                    node_match: Optional[NodeMatch] = None) -> VirtualNPU:
        """Grow or shrink a live vNPU to ``new_topology`` cores.

        Reuses the remap machinery: the tenant's own cores count as free
        for the re-solve (``free_override``), so a grow prefers extending
        in place and a shrink keeps a subset of the current footprint when
        the mapper scores it best; the canonical TED cache applies as for
        any other solve.  The routing table is rebuilt and reinstalled
        under the same vmid; global memory (RTT) is untouched — KV/weight
        contents survive, and the scheduler charges the scratchpad re-warm
        pause exactly like a migration.

        Raises :class:`AllocationError` when no sub-topology of the new
        size exists (the vNPU is left unchanged — resize is transactional).
        """
        vnpu = self.vnpus[vmid]
        free_for = ((self.free_cores() | set(vnpu.p_cores))
                    - self.quarantined)
        result = self.engine.map_request(
            new_topology, node_match=node_match,
            require_connected=vnpu.request.require_connected,
            mapper=vnpu.request.mapper, free_override=free_for)
        if result is None:
            raise AllocationError(
                f"cannot resize vmid={vmid} to {new_topology.num_nodes} "
                f"cores: no candidate sub-topology")
        vnpu.request = dataclasses.replace(vnpu.request,
                                           topology=new_topology)
        return self._commit_mapping(vnpu, result)

    # -- live migration (defragmentation; used by sched/cluster) ------------
    def migrate_vnpu(self, vmid: int,
                     node_match: Optional[NodeMatch] = None,
                     avoid: Iterable[int] = ()) -> Tuple[VirtualNPU, bool]:
        """Best-effort defragmenting migration: re-run the similar-topology
        mapping for a *healthy* tenant with a compaction objective (default:
        pull allocations toward the memory-interface column via
        ``mem_dist_node_match``) and reinstall the routing table if a better
        spot exists.

        Returns ``(vnpu, moved)``.  The RTT (global-memory contents) is
        preserved; the scheduler charges the pause — scratchpad re-warm from
        HBM plus routing-table reconfiguration — through the simulator's
        warmup/RTT cost model.
        """
        old_cores = set(self.vnpus[vmid].p_cores)
        vnpu = self.remap_vnpu(
            vmid, failed_cores=avoid,
            node_match=node_match or mem_dist_node_match(0.5),
            quarantine=False)
        return vnpu, set(vnpu.p_cores) != old_cores


def make_standard_hypervisor(rows: int = 6, cols: int = 6,
                             hbm_bytes: int = 1 << 36) -> Hypervisor:
    """The SIM configuration of Table 2: 36 tiles, 2D mesh."""
    return Hypervisor(mesh_2d(rows, cols), hbm_bytes=hbm_bytes)
