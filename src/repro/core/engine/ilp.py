"""Exact TED-minimizing placement as a MILP (HiGHS via scipy.optimize.milp).

The topology-edit-distance objective the whole engine optimizes —

    sum_i  nm(req_i, phys(i))                      node substitutions
  + sum_{(i,j) in E_req} W_miss[i,j] * [no edge between phys(i), phys(j)]
  + sum_{(p,q) in E_cand} Wsp[p,q]   * [both occupied, no req edge mapped]

— is a quadratic assignment problem.  This module linearizes it with
*directed* edge-realization variables (the Frieze–Yadegar-style
formulation, whose LP relaxation is far tighter than the naive
``y <= x + x`` linking) and hands it to HiGHS:

* ``x[i,p]`` (binary)     request node ``i`` placed on physical node ``p``;
* ``z[e,(p,q)]`` (continuous) request edge ``e = (i,j)`` realized with
  ``i`` on ``p`` and ``j`` on ``q``, one variable per *directed* physical
  arc — degree-capped by ``x`` on both endpoints, so it is 0/1 at any
  integral ``x``;
* ``s[f]``  (continuous)  physical edge ``f`` is *spurious*: both
  endpoints occupied but no request edge realized on it.

Solved over **all** nodes of a free component (not a truncated candidate
pool), the optimum is a true lower bound on every heuristic mapper's TED
for that component — the optimality-gap harness and the conformance
suite's differential checks rest on exactly that property.  HiGHS is
deterministic for a fixed input, so results are bit-identical across runs;
``time_limit`` bounds the solve, and the returned ``proven`` flag is True
only when HiGHS reports status 0 (optimal), never on an incumbent.

The chosen node set is *not* constrained to be connected: TED already
prices fragmentation (every unrealized request edge costs ``W_miss``), and
the engine's relaxed fallback has always admitted disconnected placements.
Connectivity-requiring callers get connected results in practice because a
connected optimum dominates whenever one exists at equal cost — and the
conformance invariants (placement inside the free set, injectivity, cost
== ``induced_edit_cost``) hold either way.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

try:  # scipy >= 1.9 ships milp (HiGHS); absent -> the ILP mapper disables
    from scipy.optimize import Bounds, LinearConstraint, milp
    from scipy.sparse import csc_matrix
    HAVE_MILP = True
except Exception:  # pragma: no cover - the baked image has scipy 1.14
    HAVE_MILP = False


@dataclasses.dataclass
class MilpSolution:
    """One placement MILP outcome.

    ``slots[i]`` is the index (into the candidate node sequence) hosting
    request slot ``i``; ``proven`` is the optimality certificate (HiGHS
    status 0).  ``objective`` is the solver's objective value — callers
    re-derive the exact edit cost from ``slots`` through the same batched
    arithmetic every other mapper uses, so solver tolerances can never
    leak into a TED comparison.
    """
    slots: np.ndarray
    objective: float
    proven: bool
    status: int


def _edges_of(adj: np.ndarray) -> List[Tuple[int, int]]:
    """Upper-triangle edge list of a boolean adjacency matrix."""
    a, b = np.nonzero(np.triu(adj, 1))
    return list(zip(a.tolist(), b.tolist()))


def placement_milp_size(k: int, m: int, n_req_edges: int,
                        n_cand_edges: int) -> int:
    """Variable count of the MILP ``solve_placement_milp`` would build —
    the tractability gate the ILP mapper checks before committing."""
    return k * m + 2 * n_req_edges * n_cand_edges + n_cand_edges


def solve_placement_milp(req_A: np.ndarray, req_W: np.ndarray,
                         C: np.ndarray, cand_A: np.ndarray,
                         cand_W: np.ndarray, *,
                         time_limit: Optional[float] = None
                         ) -> Optional[MilpSolution]:
    """Minimize induced edit cost of placing the request into a node set.

    ``req_A``/``req_W`` are the request adjacency and per-edge deletion
    costs (k x k, symmetric); ``C`` is the (k x m) node substitution cost
    matrix; ``cand_A``/``cand_W`` the candidate-side adjacency and per-edge
    insertion costs (m x m).  ``m == k`` is the square per-candidate case;
    ``m > k`` additionally optimizes *which* k of the m nodes are used.

    Returns None when no solution was found inside ``time_limit`` (or the
    milp backend is unavailable).
    """
    if not HAVE_MILP:  # pragma: no cover
        return None
    k, m = C.shape
    req_edges = _edges_of(req_A)
    cand_edges = _edges_of(cand_A)
    arcs = [(p, q) for p, q in cand_edges] + [(q, p) for p, q in cand_edges]
    nre, nce = len(req_edges), len(cand_edges)
    na = len(arcs)
    nx = k * m
    nz = nre * na
    nvar = nx + nz + nce
    # arcs touching each node, by direction (for the degree caps)
    out_arcs: List[List[int]] = [[] for _ in range(m)]
    in_arcs: List[List[int]] = [[] for _ in range(m)]
    for a, (p, q) in enumerate(arcs):
        out_arcs[p].append(a)
        in_arcs[q].append(a)

    def xv(i: int, p: int) -> int:
        return i * m + p

    def zv(e: int, a: int) -> int:
        return nx + e * na + a

    def sv(f: int) -> int:
        return nx + nz + f

    # objective: node costs + (base missing cost - W_miss per realized
    # edge) + Wsp per spurious edge.  The W_miss base constant is implicit
    # — callers re-derive the exact edit cost from ``slots``.
    c = np.zeros(nvar)
    c[:nx] = C.reshape(-1)
    for e, (i, j) in enumerate(req_edges):
        w = float(req_W[i, j])
        for a in range(na):
            c[zv(e, a)] = -w
    for f, (p, q) in enumerate(cand_edges):
        c[sv(f)] = float(cand_W[p, q])

    rows: List[int] = []
    cols: List[int] = []
    vals: List[float] = []
    lb: List[float] = []
    ub: List[float] = []
    r = 0

    def add(coeffs: Sequence[Tuple[int, float]], lo: float, hi: float):
        nonlocal r
        for col, v in coeffs:
            rows.append(r)
            cols.append(col)
            vals.append(v)
        lb.append(lo)
        ub.append(hi)
        r += 1

    # each request node on exactly one physical node
    for i in range(k):
        add([(xv(i, p), 1.0) for p in range(m)], 1.0, 1.0)
    # each physical node hosts at most one request node
    for p in range(m):
        add([(xv(i, p), 1.0) for i in range(k)], 0.0, 1.0)
    # degree caps: realizations of e=(i,j) with i at p (arcs out of p) are
    # bounded by x[i,p]; with j at q (arcs into q) by x[j,q].  z = 1 then
    # *implies* both endpoint placements — the tight directed linking
    for e, (i, j) in enumerate(req_edges):
        for p in range(m):
            if out_arcs[p]:
                add([(zv(e, a), 1.0) for a in out_arcs[p]]
                    + [(xv(i, p), -1.0)], -np.inf, 0.0)
            if in_arcs[p]:
                add([(zv(e, a), 1.0) for a in in_arcs[p]]
                    + [(xv(j, p), -1.0)], -np.inf, 0.0)
    # spurious: s[f] >= occ(p) + occ(q) - 1 - realized(f)
    for f, (p, q) in enumerate(cand_edges):
        coeffs = [(xv(i, p), 1.0) for i in range(k)]
        coeffs += [(xv(i, q), 1.0) for i in range(k)]
        coeffs += [(zv(e, f), -1.0) for e in range(nre)]        # arc p->q
        coeffs += [(zv(e, f + nce), -1.0) for e in range(nre)]  # arc q->p
        coeffs.append((sv(f), -1.0))
        add(coeffs, -np.inf, 1.0)

    A = csc_matrix((vals, (rows, cols)), shape=(r, nvar))
    integrality = np.zeros(nvar)
    integrality[:nx] = 1
    options = {}
    if time_limit is not None:
        options["time_limit"] = float(time_limit)
    res = milp(c=c, constraints=LinearConstraint(A, lb, ub),
               integrality=integrality,
               bounds=Bounds(np.zeros(nvar), np.ones(nvar)),
               options=options)
    if res.x is None:
        return None
    X = res.x[:nx].reshape(k, m)
    slots = np.argmax(X, axis=1).astype(np.int64)
    if len(set(slots.tolist())) != k:  # pragma: no cover - defensive
        return None
    return MilpSolution(slots=slots, objective=float(res.fun),
                        proven=(res.status == 0), status=int(res.status))
