"""Bounded candidate generation per free component.

Same candidate families as the legacy ``repro.core.mapping.propose_candidates``
(exact rectangles, clipped rectangles, BFS-compact blobs, the zig-zag set,
full enumeration for small regions), restructured for the engine:

* generation is **per component** — a candidate can never straddle free
  components (it must be connected), so the engine proposes within each
  component and the TED cache keys per-component results independently;
* rectangle windows are found with one summed-area table per component and
  fully-vectorized window sums (the legacy path recomputed the prefix sums
  per shape and scanned positions in Python);
* every candidate is connected **by construction** (rectangles, clipped
  rectangles and blobs are grown inside one component), so no per-candidate
  BFS connectivity filter is needed.
"""
from __future__ import annotations

from typing import (Dict, FrozenSet, Iterable, Iterator, List, Optional,
                    Sequence, Set, Tuple)

import numpy as np

from ..topology import Topology, enumerate_connected_subsets

FULL_ENUM_COMPONENT_LIMIT = 18   # full enumeration below this component size
FULL_ENUM_MAX_RESULTS = 20_000


def rect_windows(topo: Topology, nodes: Set[int], k: int,
                 shapes: Optional[List[Tuple[int, int, int]]] = None
                 ) -> Iterator[Tuple[int, ...]]:
    """All r x c windows (r*c == k) fully inside ``nodes``, plus clipped
    rectangles (r*c > k, excess removed from the end of the last row).
    Yields node tuples in row-major window order (the natural assignment
    order for rectangular requests).  ``shapes`` (a list of
    ``(rows, cols, clip)``) restricts generation — e.g. the rect-greedy
    mapper asks only for the request's exact shape.

    A generator: consumers that stop at ``max_candidates`` (the engine's
    candidate pool) never materialize the tail — on a mostly-free pod mesh
    one shape can have hundreds of positions, and the enumeration order
    (shape, then row-major position) is unchanged, so truncation picks the
    same prefix the eager list did.
    """
    coords = topo.coords
    if not coords or any(n not in coords for n in nodes):
        return
    r0 = min(coords[n][0] for n in nodes)
    c0 = min(coords[n][1] for n in nodes)
    R = 1 + max(coords[n][0] for n in nodes) - r0
    C = 1 + max(coords[n][1] for n in nodes) - c0
    grid = np.full((R, C), -1, dtype=np.int64)
    for n in nodes:
        r, c = coords[n]
        grid[r - r0, c - c0] = n
    mask = grid >= 0
    pad = np.zeros((R + 1, C + 1), dtype=np.int64)
    pad[1:, 1:] = np.cumsum(np.cumsum(mask.astype(np.int64), 0), 1)

    if shapes is None:
        shapes = []
        for r in range(1, min(k, R) + 1):
            c_exact, rem = divmod(k, r)
            if rem == 0 and c_exact <= C:
                shapes.append((r, c_exact, 0))
            c_clip = -(-k // r)
            if r * c_clip > k and c_clip <= C:
                shapes.append((r, c_clip, r * c_clip - k))

    for (r, c, clip) in shapes:
        # vectorized window sums over every (r0, c0) position at once
        s = (pad[r:, c:] - pad[:-r, c:] - pad[r:, :-c] + pad[:-r, :-c])
        for i, j in np.argwhere(s == r * c):
            block = grid[i:i + r, j:j + c].ravel()
            cand = tuple((block[:-clip] if clip else block).tolist())
            yield cand[:k] if len(cand) > k else cand


def bfs_blobs(adj: Dict[int, Sequence[int]], nodes: Set[int], k: int,
              max_seeds: int) -> List[Tuple[int, ...]]:
    """Compact connected blobs: from each seed, greedily absorb the free
    neighbour maximizing internal edges (keeps the blob mesh-like)."""
    seeds = sorted(nodes)
    if len(seeds) > max_seeds:
        step = len(seeds) // max_seeds
        seeds = seeds[::step][:max_seeds]
    out: List[Tuple[int, ...]] = []
    for s in seeds:
        blob = {s}
        grown = [s]
        frontier = {n for n in adj[s] if n in nodes}
        while len(blob) < k and frontier:
            best = max(frontier,
                       key=lambda n: (sum(1 for m in adj[n] if m in blob), -n))
            blob.add(best)
            grown.append(best)
            frontier.discard(best)
            frontier |= {n for n in adj[best] if n in nodes and n not in blob}
        if len(blob) == k:
            out.append(tuple(grown))
    return out


def zigzag_order(topo: Topology, nodes: Iterable[int]) -> List[int]:
    """Row-major (coords) or id order — the straightforward baseline order."""
    return sorted(nodes, key=lambda n: topo.coords.get(n, (0, n)))


def component_candidates(topo: Topology, adj: Dict[int, Sequence[int]],
                         comp: FrozenSet[int], k: int, *,
                         max_candidates: int = 512) -> List[Tuple[int, ...]]:
    """Candidate node tuples of size ``k`` within one free component.

    The tuple order is the proposal order (row-major for rectangles, growth
    order for blobs) — scoring is order-independent, but a deterministic
    order keeps cached results bit-stable.
    """
    n = len(comp)
    if n < k:
        return []
    if n == k:
        return [tuple(sorted(comp))]
    seen: Set[FrozenSet[int]] = set()
    out: List[Tuple[int, ...]] = []

    def add(cand: Tuple[int, ...]) -> bool:
        key = frozenset(cand)
        if len(key) == k and key not in seen:
            seen.add(key)
            out.append(cand)
        return len(out) >= max_candidates

    if n <= FULL_ENUM_COMPONENT_LIMIT:
        for c in enumerate_connected_subsets(
                topo, k, within=comp, max_results=FULL_ENUM_MAX_RESULTS):
            if add(tuple(sorted(c))):
                return out
        if out:
            return out

    for cand in rect_windows(topo, set(comp), k):
        if add(cand):
            return out
    for cand in bfs_blobs(adj, set(comp), k,
                          max_seeds=max(8, max_candidates // 4)):
        if add(cand):
            return out
    # the zig-zag prefix of this component is always a legal candidate
    zz = tuple(zigzag_order(topo, comp)[:k])
    if topo.is_connected(zz):
        add(zz)
    return out
