"""Incremental free-region tracking + canonical region signatures.

The pre-engine mapper re-derived the free set and its connected components
from scratch on every allocation (``set(topo.node_attrs) - allocated`` plus
a BFS per candidate).  :class:`FreeRegions` maintains the free-core
connected components *incrementally* across allocate/release:

* ``allocate(nodes)`` removes cores and re-scans only the components they
  belonged to (a removal can split a component);
* ``release(nodes)`` adds cores and merges only the components adjacent to
  them (an addition can only merge, never split).

Components are immutable frozensets with a fresh id on every change, which
makes them safe keys for lazy per-component *canonical signatures*
(:func:`component_signature`).  A signature is a translation-normalized,
attribute- and edge-exact description of a node set: two regions get the
same key iff a coordinate translation maps one onto the other preserving
node attributes (``abbr``, ``mem_dist`` — everything a match function may
read) and edge attributes.  That key is what the TED cache is addressed
by — see DESIGN.md "MappingEngine".
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence, Tuple

from ..topology import Topology


def _attr_key(attrs: Dict) -> Tuple:
    """Hashable, order-independent digest of a node/edge attribute dict."""
    return tuple(sorted((k, v) for k, v in attrs.items()
                        if isinstance(v, (str, int, float, bool))))


@dataclasses.dataclass(frozen=True)
class RegionSignature:
    """Canonical form of a node set: a cache key plus the node order that
    maps canonical indices back to concrete node ids."""
    key: Tuple
    order: Tuple[int, ...]

    def index_of(self) -> Dict[int, int]:
        return {n: i for i, n in enumerate(self.order)}


def component_signature(topo: Topology, nodes: Iterable[int],
                        adj: Dict[int, Sequence[int]]) -> RegionSignature:
    """Canonical signature of ``nodes`` within ``topo``.

    With coordinates, nodes are ordered by translation-normalized (row, col)
    — so a region shifted anywhere on the mesh canonicalizes identically.
    Without coordinates, node *id deltas* against the smallest id are used
    (shift-by-base-id invariance, e.g. two rings at different base ids).
    Edges are recorded in canonical-index space with their attribute digest,
    so tori/rings cannot collide with open meshes of the same footprint.
    """
    node_list = sorted(int(n) for n in nodes)
    coords = topo.coords
    if coords and all(n in coords for n in node_list):
        r0 = min(coords[n][0] for n in node_list)
        c0 = min(coords[n][1] for n in node_list)
        keyed = sorted(((coords[n][0] - r0, coords[n][1] - c0), n)
                       for n in node_list)
        order = tuple(n for _, n in keyed)
        offsets = tuple(o for o, _ in keyed)
        tag = "xy"
    else:
        base = node_list[0] if node_list else 0
        order = tuple(node_list)
        offsets = tuple(n - base for n in node_list)
        tag = "raw"
    index = {n: i for i, n in enumerate(order)}
    attr_sig = tuple(_attr_key(topo.node_attrs[n]) for n in order)
    node_set = set(node_list)
    edges = []
    for n in order:
        for m in adj[n]:
            if m in node_set and m > n:
                a, b = index[n], index[m]
                e = (a, b) if a <= b else (b, a)
                edges.append((e, _attr_key(
                    topo.edge_attrs[(n, m) if n <= m else (m, n)])))
    key = (tag, len(order), offsets, attr_sig, tuple(sorted(edges)))
    return RegionSignature(key=key, order=order)


def scan_components(nodes: Iterable[int],
                    adj: Dict[int, Sequence[int]]) -> List[FrozenSet[int]]:
    """Connected components of ``nodes`` under ``adj``, smallest-id first."""
    pending = set(nodes)
    out: List[FrozenSet[int]] = []
    while pending:
        start = min(pending)
        seen = {start}
        q = deque([start])
        while q:
            cur = q.popleft()
            for nb in adj[cur]:
                if nb in pending and nb not in seen:
                    seen.add(nb)
                    q.append(nb)
        pending -= seen
        out.append(frozenset(seen))
    return sorted(out, key=min)


class FreeRegions:
    """Free set + connected components, maintained incrementally."""

    def __init__(self, topo: Topology, free: Optional[Iterable[int]] = None,
                 adj: Optional[Dict[int, Tuple[int, ...]]] = None):
        self.topo = topo
        if adj is None:
            adj = {n: tuple(sorted(ms)) for n, ms in topo._adj().items()}
        self.adj = adj
        self.ops = 0
        self.reset(free)

    # -- state -------------------------------------------------------------
    def reset(self, free: Optional[Iterable[int]] = None) -> None:
        self.free = (set(self.topo.node_attrs) if free is None
                     else set(int(n) for n in free))
        self._comps: Dict[int, FrozenSet[int]] = {}
        self._comp_of: Dict[int, int] = {}
        self._sigs: Dict[int, RegionSignature] = {}
        self._next_id = 0
        for comp in scan_components(self.free, self.adj):
            self._install(comp)

    def _install(self, nodes: FrozenSet[int]) -> int:
        cid = self._next_id
        self._next_id += 1
        self._comps[cid] = nodes
        for n in nodes:
            self._comp_of[n] = cid
        return cid

    def _drop(self, cid: int) -> FrozenSet[int]:
        nodes = self._comps.pop(cid)
        for n in nodes:
            if self._comp_of.get(n) == cid:
                del self._comp_of[n]
        self._sigs.pop(cid, None)
        return nodes

    # -- mutation ----------------------------------------------------------
    def allocate(self, nodes: Iterable[int]) -> None:
        """Cores leave the free set; affected components re-scan (split)."""
        taken = set(int(n) for n in nodes) & self.free
        if not taken:
            return
        affected = {self._comp_of[n] for n in taken}
        self.free -= taken
        for cid in affected:
            remaining = self._drop(cid) - taken
            for comp in scan_components(remaining, self.adj):
                self._install(comp)
        self.ops += 1

    def release(self, nodes: Iterable[int]) -> None:
        """Cores rejoin the free set; adjacent components merge."""
        added = set(int(n) for n in nodes) - self.free
        if not added:
            return
        self.free |= added
        merged = set(added)
        touch = {self._comp_of[m] for n in added for m in self.adj[n]
                 if m in self._comp_of}
        for cid in touch:
            merged |= self._drop(cid)
        for comp in scan_components(merged, self.adj):
            self._install(comp)
        self.ops += 1

    # -- queries -----------------------------------------------------------
    def components(self, min_size: int = 1) -> List[Tuple[int, FrozenSet[int]]]:
        """(component id, nodes) pairs with at least ``min_size`` nodes,
        ordered by smallest member (deterministic iteration order)."""
        out = [(cid, c) for cid, c in self._comps.items()
               if len(c) >= min_size]
        out.sort(key=lambda item: min(item[1]))
        return out

    def component_of(self, node: int) -> Optional[FrozenSet[int]]:
        cid = self._comp_of.get(node)
        return self._comps.get(cid) if cid is not None else None

    def signature(self, cid: int) -> RegionSignature:
        sig = self._sigs.get(cid)
        if sig is None:
            sig = component_signature(self.topo, self._comps[cid], self.adj)
            self._sigs[cid] = sig
        return sig

    def check_invariants(self) -> None:
        """Test hook: components partition the free set and are connected."""
        union = set()
        for cid, comp in self._comps.items():
            assert comp, f"empty component {cid}"
            assert not (union & comp), "components overlap"
            union |= comp
            assert self.topo.is_connected(comp), f"component {cid} split"
            for n in comp:
                assert self._comp_of[n] == cid
        assert union == self.free, "components != free set"
