"""Incremental free-region tracking + canonical region signatures.

The pre-engine mapper re-derived the free set and its connected components
from scratch on every allocation (``set(topo.node_attrs) - allocated`` plus
a BFS per candidate).  :class:`FreeRegions` maintains the free-core
connected components *incrementally* across allocate/release:

* ``allocate(nodes)`` removes cores and re-scans only the components they
  belonged to (a removal can split a component);
* ``release(nodes)`` adds cores and merges only the components adjacent to
  them (an addition can only merge, never split).

Components are immutable frozensets with a fresh id on every change, which
makes them safe keys for lazy per-component *canonical signatures*
(:func:`component_signature`).  A signature is a symmetry- and
translation-normalized, attribute- and edge-exact description of a node
set: two regions get the same key iff a translation composed with one of
the eight D4 transforms (rotations/reflections of the coordinate lattice)
maps one onto the other preserving node attributes (``abbr``, ``mem_dist``
— everything a match function may read) and edge attributes.  Because the
attribute pattern travels with the nodes and is part of every candidate
key, a transform that would *change* an attribute a match function reads
(e.g. a horizontal mirror changing ``mem_dist`` on the default
``mem_interface_cols=(0,)`` layout) simply produces a different key — such
regions never collide, so no per-layout symmetry whitelist is needed.
The winning group element is recorded on the signature
(``RegionSignature.transform``); the canonical node ``order`` bakes it in,
so cache decode both translates *and* transforms back to concrete core
ids.  That key is what the TED cache is addressed by — see DESIGN.md
"MappingEngine" and "Pod-scale fast path".
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence, Set, Tuple

from ..topology import Topology


def _attr_key(attrs: Dict) -> Tuple:
    """Hashable, order-independent digest of a node/edge attribute dict."""
    return tuple(sorted((k, v) for k, v in attrs.items()
                        if isinstance(v, (str, int, float, bool))))


@dataclasses.dataclass(frozen=True)
class RegionSignature:
    """Canonical form of a node set: a cache key plus the node order that
    maps canonical indices back to concrete node ids.  ``transform`` names
    the D4 group element whose coordinate frame won the canonicalization
    (``"identity"`` when symmetry normalization is off or the untransformed
    frame is already minimal); ``order`` is sorted by the *transformed*
    coordinates, so decoding through it applies the inverse transform."""
    key: Tuple
    order: Tuple[int, ...]
    transform: str = "identity"

    def index_of(self) -> Dict[int, int]:
        return {n: i for i, n in enumerate(self.order)}


#: The eight elements of the dihedral group D4 acting on (row, col):
#: rotations by 0/90/180/270 degrees and the four reflections.  Applied to
#: translation-normalized offsets; the lexicographically-smallest resulting
#: signature is the canonical one.
D4_TRANSFORMS: Tuple[Tuple[str, "object"], ...] = (
    ("identity", lambda r, c: (r, c)),
    ("rot90", lambda r, c: (c, -r)),
    ("rot180", lambda r, c: (-r, -c)),
    ("rot270", lambda r, c: (-c, r)),
    ("flip_rows", lambda r, c: (-r, c)),     # vertical mirror
    ("flip_cols", lambda r, c: (r, -c)),     # horizontal mirror
    ("transpose", lambda r, c: (c, r)),
    ("anti_transpose", lambda r, c: (-c, -r)),
)


def _order_signature(topo: Topology, order: Tuple[int, ...],
                     adj: Dict[int, Sequence[int]], node_set: Set[int]
                     ) -> Tuple[Tuple, Tuple]:
    """(attr_sig, edges) of a node set in a given canonical order: node
    attribute digests plus intra-set edges in canonical-index space with
    their attribute digests — the shared tail of every signature frame."""
    index = {n: i for i, n in enumerate(order)}
    attr_sig = tuple(_attr_key(topo.node_attrs[n]) for n in order)
    edges = []
    for n in order:
        for m in adj[n]:
            if m in node_set and m > n:
                a, b = index[n], index[m]
                e = (a, b) if a <= b else (b, a)
                edges.append((e, _attr_key(
                    topo.edge_attrs[(n, m) if n <= m else (m, n)])))
    return attr_sig, tuple(sorted(edges))


def _frame_signature(topo: Topology, pts: List[Tuple[int, int, int]],
                     adj: Dict[int, Sequence[int]], node_set: Set[int]
                     ) -> Tuple[Tuple, Tuple[int, ...]]:
    """(key, order) of one transformed coordinate frame: nodes ordered by
    normalized transformed (row, col), attrs and edges in that order."""
    r0 = min(r for r, _, _ in pts)
    c0 = min(c for _, c, _ in pts)
    keyed = sorted(((r - r0, c - c0), n) for r, c, n in pts)
    order = tuple(n for _, n in keyed)
    offsets = tuple(o for o, _ in keyed)
    attr_sig, edges = _order_signature(topo, order, adj, node_set)
    key = ("xy", len(order), offsets, attr_sig, edges)
    return key, order


def component_signature(topo: Topology, nodes: Iterable[int],
                        adj: Dict[int, Sequence[int]],
                        symmetry: bool = True) -> RegionSignature:
    """Canonical signature of ``nodes`` within ``topo``.

    With coordinates, nodes are ordered by translation-normalized (row,
    col), minimized over the eight D4 rotations/reflections when
    ``symmetry`` is on — so a region shifted, rotated or mirrored anywhere
    on the mesh canonicalizes identically *provided the transform also
    preserves the attribute pattern* (attrs are part of each candidate
    key, so an attr-changing transform can never cause a collision — the
    ``mem_dist`` asymmetry guard is structural, not a special case).
    Without coordinates, node *id deltas* against the smallest id are used
    (shift-by-base-id invariance, e.g. two rings at different base ids).
    Edges are recorded in canonical-index space with their attribute
    digest, so tori/rings cannot collide with open meshes of the same
    footprint.

    The offsets tuple dominates the lexicographic key comparison, so the
    full attr/edge signature is only materialized for the frames whose
    normalized offsets tie at the minimum (one frame for asymmetric
    shapes, up to eight for fully-symmetric ones).
    """
    node_list = sorted(int(n) for n in nodes)
    coords = topo.coords
    if not (coords and all(n in coords for n in node_list)):
        base = node_list[0] if node_list else 0
        order = tuple(node_list)
        offsets = tuple(n - base for n in node_list)
        attr_sig, edges = _order_signature(topo, order, adj, set(node_list))
        key = ("raw", len(order), offsets, attr_sig, edges)
        return RegionSignature(key=key, order=order)

    node_set = set(node_list)
    base_pts = [(coords[n][0], coords[n][1], n) for n in node_list]
    transforms = D4_TRANSFORMS if symmetry else D4_TRANSFORMS[:1]

    # stage 1: normalized offsets per frame (cheap); they dominate the key
    frames = []
    for name, fn in transforms:
        pts = [fn(r, c) + (n,) for r, c, n in base_pts]
        r0 = min(r for r, _, _ in pts)
        c0 = min(c for _, c, _ in pts)
        offsets = tuple(sorted((r - r0, c - c0) for r, c, _ in pts))
        frames.append((offsets, name, pts))
    min_offsets = min(f[0] for f in frames)

    # stage 2: full signature only for the offset-minimal frames
    best = None
    for offsets, name, pts in frames:
        if offsets != min_offsets:
            continue
        key, order = _frame_signature(topo, pts, adj, node_set)
        if best is None or key < best[0]:
            best = (key, order, name)
    return RegionSignature(key=best[0], order=best[1], transform=best[2])


def scan_components(nodes: Iterable[int],
                    adj: Dict[int, Sequence[int]]) -> List[FrozenSet[int]]:
    """Connected components of ``nodes`` under ``adj``, smallest-id first."""
    pending = set(nodes)
    out: List[FrozenSet[int]] = []
    while pending:
        start = min(pending)
        seen = {start}
        q = deque([start])
        while q:
            cur = q.popleft()
            for nb in adj[cur]:
                if nb in pending and nb not in seen:
                    seen.add(nb)
                    q.append(nb)
        pending -= seen
        out.append(frozenset(seen))
    return sorted(out, key=min)


class FreeRegions:
    """Free set + connected components, maintained incrementally.

    ``symmetry`` selects D4-normalized canonical signatures (the default;
    pass False for translation-only keys — the pre-fast-path behaviour,
    kept for A/B measurement and the asymmetry tests)."""

    def __init__(self, topo: Topology, free: Optional[Iterable[int]] = None,
                 adj: Optional[Dict[int, Tuple[int, ...]]] = None,
                 symmetry: bool = True):
        self.topo = topo
        if adj is None:
            adj = {n: tuple(sorted(ms)) for n, ms in topo._adj().items()}
        self.adj = adj
        self.symmetry = symmetry
        self.ops = 0
        self.reset(free)

    # -- state -------------------------------------------------------------
    def reset(self, free: Optional[Iterable[int]] = None) -> None:
        self.free = (set(self.topo.node_attrs) if free is None
                     else set(int(n) for n in free))
        self._comps: Dict[int, FrozenSet[int]] = {}
        self._comp_of: Dict[int, int] = {}
        self._sigs: Dict[int, RegionSignature] = {}
        self._free_key: Optional[Tuple[int, Tuple]] = None
        self._next_id = 0
        for comp in scan_components(self.free, self.adj):
            self._install(comp)

    def _install(self, nodes: FrozenSet[int]) -> int:
        cid = self._next_id
        self._next_id += 1
        self._comps[cid] = nodes
        for n in nodes:
            self._comp_of[n] = cid
        return cid

    def _drop(self, cid: int) -> FrozenSet[int]:
        nodes = self._comps.pop(cid)
        for n in nodes:
            if self._comp_of.get(n) == cid:
                del self._comp_of[n]
        self._sigs.pop(cid, None)
        return nodes

    # -- mutation ----------------------------------------------------------
    def allocate(self, nodes: Iterable[int]) -> None:
        """Cores leave the free set; affected components re-scan (split)."""
        taken = set(int(n) for n in nodes) & self.free
        if not taken:
            return
        affected = {self._comp_of[n] for n in taken}
        self.free -= taken
        for cid in affected:
            remaining = self._drop(cid) - taken
            for comp in scan_components(remaining, self.adj):
                self._install(comp)
        self.ops += 1

    def release(self, nodes: Iterable[int]) -> None:
        """Cores rejoin the free set; adjacent components merge."""
        added = set(int(n) for n in nodes) - self.free
        if not added:
            return
        self.free |= added
        merged = set(added)
        touch = {self._comp_of[m] for n in added for m in self.adj[n]
                 if m in self._comp_of}
        for cid in touch:
            merged |= self._drop(cid)
        for comp in scan_components(merged, self.adj):
            self._install(comp)
        self.ops += 1

    # -- queries -----------------------------------------------------------
    def components(self, min_size: int = 1) -> List[Tuple[int, FrozenSet[int]]]:
        """(component id, nodes) pairs with at least ``min_size`` nodes,
        ordered by smallest member (deterministic iteration order)."""
        out = [(cid, c) for cid, c in self._comps.items()
               if len(c) >= min_size]
        out.sort(key=lambda item: min(item[1]))
        return out

    def component_of(self, node: int) -> Optional[FrozenSet[int]]:
        cid = self._comp_of.get(node)
        return self._comps.get(cid) if cid is not None else None

    def signature(self, cid: int) -> RegionSignature:
        sig = self._sigs.get(cid)
        if sig is None:
            sig = component_signature(self.topo, self._comps[cid], self.adj,
                                      symmetry=self.symmetry)
            self._sigs[cid] = sig
        return sig

    def free_key(self) -> Tuple:
        """Canonical key of the *whole* free set: the sorted multiset of
        component canonical keys.  Two free pools with equal keys are
        indistinguishable to any shape-based feasibility question (can a
        k-core connected/fragmented request be placed?) — the drain-queue
        probe memo compares these.  Cached until the next mutation;
        recomputation reuses the per-component signature cache."""
        if self._free_key is not None and self._free_key[0] == self.ops:
            return self._free_key[1]
        key = tuple(sorted(self.signature(cid).key for cid in self._comps))
        self._free_key = (self.ops, key)
        return key

    def check_invariants(self) -> None:
        """Test hook: components partition the free set and are connected."""
        union = set()
        for cid, comp in self._comps.items():
            assert comp, f"empty component {cid}"
            assert not (union & comp), "components overlap"
            union |= comp
            assert self.topo.is_connected(comp), f"component {cid} split"
            for n in comp:
                assert self._comp_of[n] == cid
        assert union == self.free, "components != free set"
