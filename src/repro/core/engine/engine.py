"""The MappingEngine: incremental, cached, vectorized topology mapping.

This is the placement service every layer above consumes (hypervisor,
scheduler policies, benchmarks).  It wraps Algorithm 1 (§4.3) behind three
optimizations that the per-request batch solve of ``repro.core.mapping``
lacks — see DESIGN.md "MappingEngine" for the protocol details:

1. **Incremental free regions** — connected components of the free set are
   maintained across allocate/release/migrate notifications instead of
   being re-derived per request (:class:`FreeRegions`).
2. **Memoized minTopologyEditDistance** — results are cached per
   (canonical free-region hash, request shape, match-fn id, mapper) in
   canonical index space, so a hit serves any *translated* recurrence of
   the same region/request pair.  Invalidation is content-addressed:
   mutated components mint new canonical keys and stale entries age out.
3. **Vectorized candidate scoring** — batched Riesen–Bunke assignment over
   the stacked candidate pool, with exact branch & bound only as a
   budget-seeded escalation on the best-ranked candidates
   (:mod:`~repro.core.engine.mappers`).

The legacy functions in :mod:`repro.core.mapping` remain as the reference
implementation; ``benchmarks/mapping_engine.py`` measures the engine
against them for both latency and TED quality.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..mapping import (EXACT_TED_MAX_NODES, EdgeMatch, MappingResult,
                       NodeMatch, default_edge_match, default_node_match)
from ..topology import Topology
from . import batch
from .cache import TEDCache, decode_result, encode_result
from .candidates import component_candidates, zigzag_order
from .mappers import MapContext, Mapper, make_mappers
from .regions import (FreeRegions, RegionSignature, component_signature,
                      scan_components)


def match_key(fn) -> Optional[str]:
    """Stable identity of a match function for cache addressing.

    The factory-made functions in :mod:`repro.core.mapping` carry a
    ``match_id`` attribute.  Ad-hoc callables have no stable identity, so
    results computed with them are never cached (``None`` disables the
    cache for the call — correctness over speed).
    """
    return getattr(fn, "match_id", None)


@dataclasses.dataclass
class EngineStats:
    map_calls: int = 0
    hits: int = 0
    misses: int = 0
    uncacheable: int = 0
    exact_escalations: int = 0
    candidates_evaluated: int = 0
    #: cache hits whose region canonicalizes through a different D4 frame
    #: than the entry's encoder did — i.e. the stored and looked-up regions
    #: are rotated/reflected (not translated) copies, exactly the lookups
    #: the translation-only canonicalization would have missed
    sym_decoded_hits: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def as_dict(self) -> Dict[str, float]:
        """Flat JSON-safe export (the raw dataclass counters plus the
        derived hit rate) — what the observability registry and the
        epoch-boundary ``engine_cache`` counter track consume."""
        d = dataclasses.asdict(self)
        d["hit_rate"] = round(self.hit_rate, 4)
        return d


class MappingEngine:
    """Incremental, cached, vectorized topology mapping over one NPU mesh."""

    def __init__(self, topo: Topology, *, mapper: str = "hybrid",
                 cache_entries: int = 4096, max_candidates: int = 512,
                 exact_max: int = EXACT_TED_MAX_NODES,
                 symmetry: bool = True):
        self.topo = topo
        self.adj: Dict[int, Tuple[int, ...]] = {
            n: tuple(sorted(ms)) for n, ms in topo._adj().items()}
        self.pool = batch.make_pool_arrays(topo)
        self.symmetry = symmetry
        self.regions = FreeRegions(topo, adj=self.adj, symmetry=symmetry)
        self.cache = TEDCache(cache_entries, pinned=self._live_region_keys)
        self.stats = EngineStats()
        self.mappers: Dict[str, Mapper] = make_mappers()
        if mapper not in self.mappers:
            raise KeyError(f"unknown mapper {mapper!r}; "
                           f"have {sorted(self.mappers)}")
        self.default_mapper = mapper
        self.max_candidates = max_candidates
        self.exact_max = exact_max
        # link-heat-aware admission (opt in): a callable returning the
        # current per-directed-link occupancy (the scheduler binds the
        # InterferenceLedger's ``link_loads``).  When set, equal-TED
        # candidates are tie-broken toward the one whose *boundary* links
        # are coldest — placements snuggle into quiet neighborhoods.  When
        # None (the default) selection is exactly the historical
        # first-strictly-better scan, bit for bit.
        self.heat_fn = None
        self._wspur: Dict[str, np.ndarray] = {}
        # interned whole-pool canonical keys -> small-int ids.  Bounded
        # LRU (keys are multi-KB nested tuples at 1024 cores); ids come
        # from a monotonic counter and are never reused, so eviction can
        # only cost a memo hit, never alias two different pool shapes.
        self._freekey_ids: "OrderedDict[Tuple, int]" = OrderedDict()
        self._freekey_next = 0

    # -- hypervisor-driven invalidation hooks --------------------------------
    def notify_allocate(self, nodes: Iterable[int]) -> None:
        """Cores left the free set (vNPU created / migrated in)."""
        self.regions.allocate(nodes)

    def notify_release(self, nodes: Iterable[int]) -> None:
        """Cores rejoined the free set (vNPU destroyed / migrated out)."""
        self.regions.release(nodes)

    def reset(self, free: Optional[Iterable[int]] = None) -> None:
        """Re-derive regions from scratch (and drop the cache)."""
        self.regions.reset(free)
        self.cache.clear()

    @property
    def free_cores(self) -> FrozenSet[int]:
        return frozenset(self.regions.free)

    FREEKEY_INTERN_MAX = 1024

    def free_state_id(self) -> int:
        """Small-int id of the canonical free-set *shape* (interned
        :meth:`FreeRegions.free_key`).  Equal ids mean the free pools are
        indistinguishable to any placement-feasibility question, so a
        negative probe memoized under one id is valid under the other —
        the scheduler's drain-queue memo compares these in O(1)."""
        key = self.regions.free_key()
        fid = self._freekey_ids.get(key)
        if fid is None:
            fid = self._freekey_next
            self._freekey_next += 1
            self._freekey_ids[key] = fid
            while len(self._freekey_ids) > self.FREEKEY_INTERN_MAX:
                self._freekey_ids.popitem(last=False)
        else:
            self._freekey_ids.move_to_end(key)
        return fid

    # -- queries -------------------------------------------------------------
    def propose_candidates(self, k: int,
                           free_override: Optional[Iterable[int]] = None
                           ) -> List[Tuple[int, ...]]:
        """Bounded candidate pool of size-``k`` core sets over the current
        free components (Algorithm 1's ``totalSubTopo`` after R-1/R-3)."""
        comps = self._components(k, free_override)
        out: List[Tuple[int, ...]] = []
        for _, comp in comps:
            out.extend(component_candidates(
                self.topo, self.adj, comp, k,
                max_candidates=self.max_candidates))
        return out

    def map_request(self, t_req: Topology, *,
                    node_match: Optional[NodeMatch] = None,
                    edge_match: Optional[EdgeMatch] = None,
                    require_connected: bool = True,
                    mapper: Optional[str] = None,
                    max_candidates: Optional[int] = None,
                    free_override: Optional[Iterable[int]] = None
                    ) -> Optional[MappingResult]:
        """Algorithm 1 (minTopologyEditDistance) over the tracked free set.

        ``free_override`` maps against an explicit free set instead of the
        tracker (the remap/migrate path, where the tenant's own cores count
        as free and failed cores do not); the canonical cache still applies.
        Returns None when no candidate of the right size exists — with
        ``require_connected=False`` a fragmented zig-zag fallback is scored
        before giving up (§4.3's topology-fragmentation trade-off).
        """
        self.stats.map_calls += 1
        nm = node_match or default_node_match
        em = edge_match or default_edge_match
        nm_id, em_id = match_key(nm), match_key(em)
        strategy = self.mappers[mapper or self.default_mapper]
        maxc = max_candidates or self.max_candidates
        k = t_req.num_nodes

        free = (self.regions.free if free_override is None
                else set(int(n) for n in free_override))
        if k == 0 or k > len(free):
            return None

        # the request keeps a translation-only canonical form: its node
        # order feeds the batched scorer and the returned assignment, and
        # requests recur with a fixed orientation (best_rect meshes), so
        # region-side D4 normalization is where the symmetry hits live
        req_sig = component_signature(t_req, t_req.node_attrs, t_req._adj(),
                                      symmetry=False)
        cacheable = nm_id is not None and em_id is not None
        ctx = MapContext(
            topo=self.topo, adj=self.adj, pool=self.pool, t_req=t_req,
            req=batch.make_request_spec(self.pool, t_req, req_sig.order, em),
            nm=nm, em=em, nm_id=nm_id, em_id=em_id,
            Wspur=self._wspur_for(em, em_id), exact_max=self.exact_max,
            max_candidates=maxc, stats=self.stats)

        # one heat snapshot per call: the tie-break must compare every
        # candidate against the same occupancy picture (and never leak
        # into cache keys — heat varies per instant, placements recur)
        loads = self.heat_fn() if self.heat_fn is not None else None

        def better(candidate: MappingResult,
                   incumbent: Optional[MappingResult]) -> bool:
            if incumbent is None or candidate.ted < incumbent.ted:
                return True
            if loads is None or candidate.ted > incumbent.ted:
                return False
            # equal TED: prefer the colder boundary (strictly — ties keep
            # the incumbent, preserving the first-wins scan order)
            return (self._boundary_heat(candidate.nodes, loads)
                    < self._boundary_heat(incumbent.nodes, loads))

        best: Optional[MappingResult] = None
        evaluated = 0
        for cid, comp, sig in self._component_sigs(k, free_override):
            key = ((sig.key, req_sig.key, nm_id, em_id, strategy.name, maxc)
                   if cacheable else None)
            result: Optional[MappingResult] = None
            if key is not None:
                # A cross-orientation entry is only served when provably
                # orientation-independent: a negative (feasibility is
                # structural), a perfect result (TED 0 is a global lower
                # bound), or an ILP-certified component optimum (the
                # minimum over all placements is a D4-invariant quantity,
                # and decode preserves validity and cost).  Heuristic
                # quality is NOT D4-invariant (first-fit privileges an
                # orientation; pool scoring does too once max_candidates
                # truncates), so a suboptimal twin falls through to the
                # frame-exact key, then to a fresh solve — a lucky
                # orientation can never poison its rotations.
                found, entry = self.cache.get(key)
                servable = found and (entry is None or entry.ted == 0.0
                                      or entry.optimal
                                      or entry.transform == sig.transform)
                if not servable:
                    # frame-exact fallback: covers both a cross-frame
                    # suboptimal primary and an LRU-evicted primary slot
                    found, entry = self.cache.get(key + (sig.transform,))
                if found:
                    self.stats.hits += 1
                    if entry is not None:
                        # a hit whose frame differs from the encoder's is
                        # one the translation-only keys would have missed
                        if entry.transform != sig.transform:
                            self.stats.sym_decoded_hits += 1
                        result = decode_result(entry, sig.order, req_sig.order)
                    evaluated += (entry.candidates_evaluated
                                  if entry is not None else 0)
                    if result is not None and better(result, best):
                        best = result
                    # a TED-0 hit ends the scan — except under heat, where
                    # another component may host an equally-perfect but
                    # colder placement
                    if loads is None and best is not None \
                            and best.ted == 0.0:
                        break
                    continue
            result = strategy.map_component(ctx, comp)
            if key is not None:
                self.stats.misses += 1
                enc = (None if result is None else
                       encode_result(result, sig.order, req_sig.order,
                                     transform=sig.transform))
                if enc is None or enc.ted == 0.0 or enc.optimal:
                    # serves every orientation — claim the frame-free key
                    self.cache.put(key, enc)
                else:
                    # frame-bound quality: store under the frame-exact key;
                    # also seed the frame-free slot if vacant so translated
                    # (same-frame) twins hit in one lookup
                    self.cache.put(key + (sig.transform,), enc)
                    if not self.cache.get(key)[0]:
                        self.cache.put(key, enc)
            else:
                self.stats.uncacheable += 1
            if result is not None:
                evaluated += result.candidates_evaluated
                if better(result, best):
                    best = result
                if loads is None and best.ted == 0.0:
                    break

        if not require_connected:
            best = self._relaxed_fallback(ctx, free, k, best, req_sig,
                                          cacheable)
        if best is not None:
            best = dataclasses.replace(best, candidates_evaluated=max(
                evaluated, best.candidates_evaluated))
            self.stats.candidates_evaluated += best.candidates_evaluated
        return best

    def counters(self) -> Dict[str, float]:
        """Telemetry snapshot.  ``hits``/``misses``/``uncacheable`` count
        per-component cache lookups — a single ``map_request`` over a
        fragmented free set performs one lookup per eligible component.
        ``hit_rate`` is hits / (hits + misses), i.e. the rate over
        *cacheable* lookups; ``component_lookups`` is the total including
        the uncacheable ones (ad-hoc match functions without a match_id)."""
        s = self.stats
        return {
            "map_calls": s.map_calls,
            "component_lookups": s.hits + s.misses + s.uncacheable,
            "cache_hits": s.hits,
            "cache_misses": s.misses,
            "uncacheable": s.uncacheable,
            "hit_rate": round(s.hit_rate, 4),
            "sym_decoded_hits": s.sym_decoded_hits,
            "exact_escalations": s.exact_escalations,
            "candidates_evaluated": s.candidates_evaluated,
            "cache_entries": len(self.cache),
            "cache_evictions": self.cache.evictions,
            "region_ops": self.regions.ops,
        }

    # -- internals -----------------------------------------------------------
    def _live_region_keys(self) -> FrozenSet:
        """Canonical keys of the free-set shapes currently instantiated on
        the mesh (every tracked component, plus the whole free set that
        addresses the relaxed zig-zag memo) — the entries
        :class:`TEDCache` eviction must not drop, so that a live shape's
        hit/miss pattern is independent of unrelated churn (see the
        cache's docstring for the determinism argument)."""
        keys = {self.regions.signature(cid).key
                for cid, _ in self.regions.components()}
        keys.add(tuple(sorted(self.regions.free)))
        return frozenset(keys)

    @staticmethod
    def _better(candidate: MappingResult,
                incumbent: Optional[MappingResult]) -> bool:
        return incumbent is None or candidate.ted < incumbent.ted

    def _boundary_heat(self, nodes: FrozenSet[int], loads) -> float:
        """Summed occupancy of the directed links crossing the candidate's
        boundary (both directions) — the interference this placement would
        trade with its neighbors.  O(|nodes| x degree)."""
        heat = 0.0
        for n in nodes:
            for m in self.adj[n]:
                if m not in nodes:
                    heat += loads.get((n, m), 0.0) + loads.get((m, n), 0.0)
        return heat

    def _components(self, k: int, free_override: Optional[Iterable[int]]
                    ) -> List[Tuple[Optional[int], FrozenSet[int]]]:
        if free_override is None:
            return [(cid, comp)
                    for cid, comp in self.regions.components(min_size=k)]
        comps = scan_components(set(int(n) for n in free_override), self.adj)
        return [(None, c) for c in comps if len(c) >= k]

    def _component_sigs(self, k: int, free_override: Optional[Iterable[int]]
                        ) -> List[Tuple[Optional[int], FrozenSet[int],
                                        RegionSignature]]:
        out = []
        for cid, comp in self._components(k, free_override):
            sig = (self.regions.signature(cid) if cid is not None
                   else component_signature(self.topo, comp, self.adj,
                                            symmetry=self.symmetry))
            out.append((cid, comp, sig))
        return out

    def _wspur_for(self, em: EdgeMatch, em_id: Optional[str]) -> np.ndarray:
        if em_id is None:
            return batch.spur_matrix(self.pool, em)
        w = self._wspur.get(em_id)
        if w is None:
            w = batch.spur_matrix(self.pool, em)
            self._wspur[em_id] = w
        return w

    def _relaxed_fallback(self, ctx: MapContext, free: Iterable[int], k: int,
                          best: Optional[MappingResult],
                          req_sig: RegionSignature,
                          cacheable: bool) -> Optional[MappingResult]:
        """Score the global zig-zag prefix too (it is always a legal
        candidate under relaxed connectivity, so the similar mapping can
        never do worse than the straightforward baseline).  The solve is
        memoized against the exact free set — the zig-zag depends on all of
        it, not one component — so repeated relaxed probes over an
        unchanged mesh (defrag loops, probe-then-allocate) are hits."""
        if best is not None and best.ted == 0.0:
            return best          # match costs are non-negative: unbeatable
        zz = tuple(zigzag_order(self.topo, free)[:k])
        if len(zz) < k or (best is not None and frozenset(zz) == best.nodes):
            return best
        from .mappers import _bnb_perm, _result_from

        key = (("zz", tuple(sorted(free)), req_sig.key, ctx.nm_id, ctx.em_id)
               if cacheable else None)
        zres: Optional[MappingResult] = None
        if key is not None:
            found, entry = self.cache.get(key)
            if found and entry is not None:
                self.stats.hits += 1
                zres = decode_result(entry, zz, req_sig.order)
        if zres is None:
            idx = np.array([[self.pool.index[n] for n in zz]],
                           dtype=np.int64)
            score = batch.score_pool(self.pool, ctx.req, idx, ctx.Wspur,
                                     ctx.nm, ctx.nm_id)
            cost, perm = float(score.costs[0]), score.perms[0]
            c2, p2 = batch.hungarian_crosscheck(ctx.req, score, 0)
            if c2 < cost:
                cost, perm = c2, p2
                score.costs[0], score.perms[0] = c2, p2
            c3, p3 = batch.refine_assignment(ctx.req, score, 0)
            if c3 < cost:
                cost, perm = c3, p3
            # the fragmented zig-zag is often the ONLY candidate, so its
            # assignment quality matters as much as a connected one's:
            # escalate exactly like the hybrid mapper would (legacy parity)
            if cost > 0.0 and k <= self.exact_max:
                c4, p4 = _bnb_perm(ctx, zz, budget=cost + 1e-9)
                if c4 is not None and c4 < cost:
                    cost, perm = c4, p4
            zres = _result_from(ctx, zz, perm, cost, 1)
            if key is not None:
                self.stats.misses += 1
                self.cache.put(key, encode_result(zres, zz, req_sig.order))
            else:
                self.stats.uncacheable += 1
        if best is not None and best.ted <= zres.ted:
            return best
        return dataclasses.replace(
            zres, candidates_evaluated=(
                best.candidates_evaluated if best else 0) + 1)
