"""Incremental, cached, vectorized topology-mapping engine (§4.3, Alg. 1).

The placement subsystem behind :class:`repro.core.hypervisor.Hypervisor`
and every scheduler policy:

* :mod:`repro.core.engine.regions`    — incremental free-core connected
  components + canonical region signatures;
* :mod:`repro.core.engine.candidates` — bounded per-component candidate
  generation (rectangles / blobs / enumeration);
* :mod:`repro.core.engine.batch`      — batched numpy Riesen–Bunke scoring;
* :mod:`repro.core.engine.cache`      — content-addressed LRU over
  canonicalized minTopologyEditDistance results;
* :mod:`repro.core.engine.mappers`    — pluggable speed/accuracy strategies
  (exact / hybrid / bipartite / rectangle-greedy / ilp / partition);
* :mod:`repro.core.engine.ilp`        — the MILP formulation behind the
  ``ilp`` placement-quality oracle (HiGHS via scipy);
* :mod:`repro.core.engine.engine`     — the :class:`MappingEngine` facade.
"""
from .engine import EngineStats, MappingEngine, match_key
from .mappers import (BipartiteMapper, ExactMapper, HybridMapper, ILPMapper,
                      MAPPERS, Mapper, PartitionMapper, RectangleGreedyMapper)
from .regions import FreeRegions, RegionSignature, component_signature
from .cache import TEDCache

__all__ = [
    "MappingEngine", "EngineStats", "match_key",
    "Mapper", "MAPPERS", "HybridMapper", "BipartiteMapper", "ExactMapper",
    "RectangleGreedyMapper", "ILPMapper", "PartitionMapper",
    "FreeRegions", "RegionSignature", "component_signature", "TEDCache",
]
