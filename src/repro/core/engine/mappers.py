"""Pluggable mapping strategies over one free component.

Every :class:`Mapper` turns (request, free component) into the best
:class:`~repro.core.mapping.MappingResult` it is willing to pay for:

* ``rect``      — rectangle-greedy: first exact-shape rectangle window
  (identity row-major assignment), else the single best-effort blob.  No
  assignment optimization; the cheapest speed point.
* ``bipartite`` — batched Riesen–Bunke over the full candidate pool; the
  vectorized equivalent of the legacy large-request path.
* ``hybrid``    — bipartite ranking plus escalation on the best-ranked
  candidates: exact branch & bound (budget-seeded) for small requests,
  Hungarian cross-check + 2-opt descent above the exact threshold.  The
  engine default.
* ``exact``     — branch & bound on *every* candidate (exponential in the
  request size; ground truth for tests and small configs).

Escalation order is ascending bipartite cost with a running global budget,
with an edge-count lower-bound skip under the default edge-match — most
candidates are eliminated without entering the B&B at all.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

import numpy as np

from ..mapping import (EdgeMatch, MappingResult, NodeMatch,
                       _exact_ged_same_size)
from ..topology import Topology
from . import batch
from .candidates import component_candidates

EXACT_ESCALATION_LIMIT = 64     # max B&B escalations per component (hybrid)
REFINE_TOP_K = 16               # 2-opt / cross-check pool above exact sizes


@dataclasses.dataclass
class MapContext:
    """Everything a mapper needs for one request, prepared by the engine."""
    topo: Topology
    adj: Dict[int, Tuple[int, ...]]
    pool: batch.PoolArrays
    t_req: Topology
    req: batch.RequestSpec
    nm: NodeMatch
    em: EdgeMatch
    nm_id: Optional[str]
    em_id: Optional[str]
    Wspur: np.ndarray
    exact_max: int
    max_candidates: int
    stats: "object" = None       # EngineStats, duck-typed


def _result_from(ctx: MapContext, cand: Sequence[int], perm: np.ndarray,
                 ted: float, evaluated: int) -> MappingResult:
    assignment = {ctx.req.order[i]: int(cand[perm[i]])
                  for i in range(len(ctx.req.order))}
    return MappingResult(nodes=frozenset(int(n) for n in cand), ted=float(ted),
                         assignment=assignment, exact=(ted == 0.0),
                         candidates_evaluated=evaluated)


def _bnb(ctx: MapContext, cand: Sequence[int], budget: float
         ) -> Tuple[Optional[float], Optional[Dict[int, int]]]:
    """Budgeted exact branch & bound on one candidate subgraph."""
    sub = ctx.topo.subgraph(cand)
    cost, mapping = _exact_ged_same_size(ctx.t_req, sub, ctx.nm, ctx.em,
                                         budget=budget)
    if not mapping:
        return None, None
    return cost, mapping


def _bnb_perm(ctx: MapContext, cand: Sequence[int], budget: float
              ) -> Tuple[Optional[float], Optional[np.ndarray]]:
    """Budgeted B&B returning the assignment as a canonical-order perm."""
    cost, mapping = _bnb(ctx, cand, budget)
    if cost is None:
        return None, None
    slot = {node: i for i, node in enumerate(ctx.req.order)}
    pos = {node: i for i, node in enumerate(cand)}
    perm = np.empty(len(ctx.req.order), dtype=np.int64)
    for v, p in mapping.items():
        perm[slot[v]] = pos[p]
    return cost, perm


def _edge_count_lb(ctx: MapContext, score: batch.PoolScore, c: int) -> float:
    """Sound lower bound on the edit cost of candidate ``c``: any bijection
    must edit at least |E_req - E_cand| edges, each costing at least the
    cheapest edge involved (request-edge deletion costs when the request has
    more edges, candidate-edge insertion costs when the candidate does)."""
    d = ctx.req.n_edges - int(score.n_edges[c])
    if d > 0:
        miss = ctx.req.W_miss[ctx.req.A]
        return d * float(miss.min()) if miss.size else 0.0
    if d < 0:
        spur = score.Wsp[c][score.A[c]]
        return -d * float(spur.min()) if spur.size else 0.0
    return 0.0


class Mapper:
    """Strategy protocol: best mapping of the request into one component.

    No strategy's *result quality* is guaranteed invariant under
    rotations/reflections of the component (first-fit privileges an
    orientation outright; pool scoring does too once ``max_candidates``
    truncates the pool), which is why the engine's D4 cache unification
    only serves cross-orientation entries that are provably
    orientation-independent — negatives and perfect (TED 0) results; see
    ``MappingEngine.map_request``."""

    name = "abstract"

    def map_component(self, ctx: MapContext,
                      comp: FrozenSet[int]) -> Optional[MappingResult]:
        raise NotImplementedError

    # -- shared plumbing ----------------------------------------------------
    def _candidates(self, ctx: MapContext,
                    comp: FrozenSet[int]) -> List[Tuple[int, ...]]:
        """Bounded candidate pool (size-k node tuples) within ``comp``."""
        return component_candidates(ctx.topo, ctx.adj, comp,
                                    len(ctx.req.order),
                                    max_candidates=ctx.max_candidates)

    def _score(self, ctx: MapContext,
               cands: List[Tuple[int, ...]]) -> batch.PoolScore:
        """Batch-score ``cands`` (see :func:`batch.score_pool`)."""
        idx = np.array([[ctx.pool.index[n] for n in cand] for cand in cands],
                       dtype=np.int64)
        return batch.score_pool(ctx.pool, ctx.req, idx, ctx.Wspur,
                                ctx.nm, ctx.nm_id)


class BipartiteMapper(Mapper):
    """Batched bipartite approximation, no escalation.  O(pool x k^3)."""

    name = "bipartite"
    refine_top_k = 0
    escalate = False
    escalate_limit: Optional[int] = EXACT_ESCALATION_LIMIT
    escalate_any_size = False      # else only requests <= ctx.exact_max

    def map_component(self, ctx: MapContext,
                      comp: FrozenSet[int]) -> Optional[MappingResult]:
        """Best mapping of the request into ``comp`` (None when the
        component cannot host it); TED in edit-cost units."""
        cands = self._candidates(ctx, comp)
        if not cands:
            return None
        score = self._score(ctx, cands)
        order = np.argsort(score.costs, kind="stable")
        best_c = int(order[0])
        best_cost = float(score.costs[best_c])
        best_perm = score.perms[best_c]
        best_nodes = cands[best_c]

        if best_cost > 0.0 and self.refine_top_k > 0:
            for c in order[:self.refine_top_k]:
                c = int(c)
                cost, perm = batch.hungarian_crosscheck(ctx.req, score, c)
                if cost < float(score.costs[c]):
                    score.costs[c] = cost
                    score.perms[c] = perm
                cost2, perm2 = batch.refine_assignment(ctx.req, score, c)
                if cost2 < float(score.costs[c]):
                    score.costs[c] = cost2
                    score.perms[c] = perm2
                if score.costs[c] < best_cost:
                    best_cost = float(score.costs[c])
                    best_perm = score.perms[c]
                    best_c, best_nodes = c, cands[c]
                if best_cost == 0.0:
                    break

        if best_cost > 0.0 and self.escalate and \
                (self.escalate_any_size
                 or len(ctx.req.order) <= ctx.exact_max):
            best_cost, best_perm, best_nodes = self._escalate(
                ctx, cands, score, order, best_cost, best_perm, best_nodes)

        return _result_from(ctx, best_nodes, np.asarray(best_perm),
                            best_cost, len(cands))

    def _escalate(self, ctx, cands, score, order, best_cost, best_perm,
                  best_nodes):
        """Exact B&B over the best-ranked candidates with a running budget."""
        n = 0
        for c in order:
            if best_cost == 0.0 or (self.escalate_limit is not None
                                    and n >= self.escalate_limit):
                break
            c = int(c)
            if _edge_count_lb(ctx, score, c) >= best_cost:
                continue
            n += 1
            if ctx.stats is not None:
                ctx.stats.exact_escalations += 1
            cost, perm = _bnb_perm(ctx, cands[c], budget=best_cost + 1e-9)
            if cost is not None and cost < best_cost:
                best_cost, best_perm, best_nodes = cost, perm, cands[c]
        return best_cost, best_perm, best_nodes


class HybridMapper(BipartiteMapper):
    """Bipartite ranking + exact/2-opt escalation — the engine default."""

    name = "hybrid"
    refine_top_k = REFINE_TOP_K
    escalate = True


class ExactMapper(BipartiteMapper):
    """Branch & bound on every candidate, whatever the request size (the
    sound ``_edge_count_lb`` skip and the shrinking global budget still
    prune, so exactness over the pool is preserved).  Exponential in the
    request size — ground truth for tests and small paper configs only."""

    name = "exact"
    escalate = True
    escalate_limit = None
    escalate_any_size = True


class RectangleGreedyMapper(Mapper):
    """First-fit: an exact-shape rectangle window if one exists, else the
    *first proposed* candidate scored by one bipartite solve — no pool-wide
    scoring, by design the cheapest (and least accurate) strategy.

    Quality is sharply orientation-dependent (an exact-shape window exists
    in one orientation of a strip but not its rotation) — the canonical
    example of why the engine never serves a cross-orientation cache entry
    whose TED is non-zero."""

    name = "rect"

    def map_component(self, ctx: MapContext,
                      comp: FrozenSet[int]) -> Optional[MappingResult]:
        from .candidates import rect_windows

        shape = ctx.t_req.is_rect_mesh()
        if shape is not None:
            k = len(ctx.req.order)
            # only windows of the request's exact shape — each is an
            # unclipped full rectangle, so no per-window shape re-check
            cand = next(rect_windows(ctx.topo, set(comp), k,
                                     shapes=[(shape[0], shape[1], 0)]), None)
            if cand is not None:
                # request canonical order and window order are both
                # row-major: the identity permutation aligns them
                score = self._score(ctx, [cand])
                ident = np.arange(k, dtype=np.int64)
                cost = float(batch.induced_batch(
                    ctx.req.A, ctx.req.W_miss, score.A, score.Wsp,
                    score.Cnode, ident[None])[0])
                return _result_from(ctx, cand, ident, cost, 1)
        cands = self._candidates(ctx, comp)
        if not cands:
            return None
        score = self._score(ctx, cands[:1])
        return _result_from(ctx, cands[0], score.perms[0],
                            float(score.costs[0]), 1)


MAPPERS = {
    cls.name: cls
    for cls in (HybridMapper, BipartiteMapper, ExactMapper,
                RectangleGreedyMapper)
}


def make_mappers() -> Dict[str, Mapper]:
    """Fresh strategy instances per engine (mappers are stateless today,
    but per-engine instances keep any future state from leaking)."""
    return {name: cls() for name, cls in MAPPERS.items()}
