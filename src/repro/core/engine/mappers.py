"""Pluggable mapping strategies over one free component.

Every :class:`Mapper` turns (request, free component) into the best
:class:`~repro.core.mapping.MappingResult` it is willing to pay for:

* ``rect``      — rectangle-greedy: first exact-shape rectangle window
  (identity row-major assignment), else the single best-effort blob.  No
  assignment optimization; the cheapest speed point.
* ``bipartite`` — batched Riesen–Bunke over the full candidate pool; the
  vectorized equivalent of the legacy large-request path.
* ``hybrid``    — bipartite ranking plus escalation on the best-ranked
  candidates: exact branch & bound (budget-seeded) for small requests,
  Hungarian cross-check + 2-opt descent above the exact threshold.  The
  engine default.
* ``exact``     — branch & bound on *every* candidate (exponential in the
  request size; ground truth for tests and small configs).
* ``ilp``       — one MILP over the whole free component (HiGHS via
  :mod:`~repro.core.engine.ilp`): provably minimal TED over *all*
  injective placements when the component fits the variable budget
  (``MappingResult.optimal`` is the certificate), a deterministic
  sub-domain restriction above it.  The placement-quality oracle.
* ``partition`` — METIS-style recursive bisection: the virtual topology
  is min-cut bisected while the free tile is geometrically bisected in
  proportion, then the leaf assignment is 2-opt polished.  No candidate
  pool at all — the cheapest topology-aware strategy.

Escalation order is ascending bipartite cost with a running global budget,
with an edge-count lower-bound skip under the default edge-match — most
candidates are eliminated without entering the B&B at all.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

import numpy as np

from ..mapping import (EdgeMatch, MappingResult, NodeMatch,
                       _exact_ged_same_size)
from ..topology import Topology
from . import batch
from .candidates import component_candidates

EXACT_ESCALATION_LIMIT = 64     # max B&B escalations per component (hybrid)
REFINE_TOP_K = 16               # 2-opt / cross-check pool above exact sizes


@dataclasses.dataclass
class MapContext:
    """Everything a mapper needs for one request, prepared by the engine."""
    topo: Topology
    adj: Dict[int, Tuple[int, ...]]
    pool: batch.PoolArrays
    t_req: Topology
    req: batch.RequestSpec
    nm: NodeMatch
    em: EdgeMatch
    nm_id: Optional[str]
    em_id: Optional[str]
    Wspur: np.ndarray
    exact_max: int
    max_candidates: int
    stats: "object" = None       # EngineStats, duck-typed


def _result_from(ctx: MapContext, cand: Sequence[int], perm: np.ndarray,
                 ted: float, evaluated: int,
                 optimal: bool = False) -> MappingResult:
    assignment = {ctx.req.order[i]: int(cand[perm[i]])
                  for i in range(len(ctx.req.order))}
    return MappingResult(nodes=frozenset(int(n) for n in cand), ted=float(ted),
                         assignment=assignment, exact=(ted == 0.0),
                         candidates_evaluated=evaluated, optimal=optimal)


def _bnb(ctx: MapContext, cand: Sequence[int], budget: float
         ) -> Tuple[Optional[float], Optional[Dict[int, int]]]:
    """Budgeted exact branch & bound on one candidate subgraph."""
    sub = ctx.topo.subgraph(cand)
    cost, mapping = _exact_ged_same_size(ctx.t_req, sub, ctx.nm, ctx.em,
                                         budget=budget)
    if not mapping:
        return None, None
    return cost, mapping


def _bnb_perm(ctx: MapContext, cand: Sequence[int], budget: float
              ) -> Tuple[Optional[float], Optional[np.ndarray]]:
    """Budgeted B&B returning the assignment as a canonical-order perm."""
    cost, mapping = _bnb(ctx, cand, budget)
    if cost is None:
        return None, None
    slot = {node: i for i, node in enumerate(ctx.req.order)}
    pos = {node: i for i, node in enumerate(cand)}
    perm = np.empty(len(ctx.req.order), dtype=np.int64)
    for v, p in mapping.items():
        perm[slot[v]] = pos[p]
    return cost, perm


def _edge_count_lb(ctx: MapContext, score: batch.PoolScore, c: int) -> float:
    """Sound lower bound on the edit cost of candidate ``c``: any bijection
    must edit at least |E_req - E_cand| edges, each costing at least the
    cheapest edge involved (request-edge deletion costs when the request has
    more edges, candidate-edge insertion costs when the candidate does)."""
    d = ctx.req.n_edges - int(score.n_edges[c])
    if d > 0:
        miss = ctx.req.W_miss[ctx.req.A]
        return d * float(miss.min()) if miss.size else 0.0
    if d < 0:
        spur = score.Wsp[c][score.A[c]]
        return -d * float(spur.min()) if spur.size else 0.0
    return 0.0


class Mapper:
    """Strategy protocol: best mapping of the request into one component.

    No strategy's *result quality* is guaranteed invariant under
    rotations/reflections of the component (first-fit privileges an
    orientation outright; pool scoring does too once ``max_candidates``
    truncates the pool), which is why the engine's D4 cache unification
    only serves cross-orientation entries that are provably
    orientation-independent — negatives and perfect (TED 0) results; see
    ``MappingEngine.map_request``."""

    name = "abstract"

    def map_component(self, ctx: MapContext,
                      comp: FrozenSet[int]) -> Optional[MappingResult]:
        raise NotImplementedError

    # -- shared plumbing ----------------------------------------------------
    def _candidates(self, ctx: MapContext,
                    comp: FrozenSet[int]) -> List[Tuple[int, ...]]:
        """Bounded candidate pool (size-k node tuples) within ``comp``."""
        return component_candidates(ctx.topo, ctx.adj, comp,
                                    len(ctx.req.order),
                                    max_candidates=ctx.max_candidates)

    def _score(self, ctx: MapContext,
               cands: List[Tuple[int, ...]]) -> batch.PoolScore:
        """Batch-score ``cands`` (see :func:`batch.score_pool`)."""
        idx = np.array([[ctx.pool.index[n] for n in cand] for cand in cands],
                       dtype=np.int64)
        return batch.score_pool(ctx.pool, ctx.req, idx, ctx.Wspur,
                                ctx.nm, ctx.nm_id)


class BipartiteMapper(Mapper):
    """Batched bipartite approximation, no escalation.  O(pool x k^3)."""

    name = "bipartite"
    refine_top_k = 0
    escalate = False
    escalate_limit: Optional[int] = EXACT_ESCALATION_LIMIT
    escalate_any_size = False      # else only requests <= ctx.exact_max

    def map_component(self, ctx: MapContext,
                      comp: FrozenSet[int]) -> Optional[MappingResult]:
        """Best mapping of the request into ``comp`` (None when the
        component cannot host it); TED in edit-cost units."""
        cands = self._candidates(ctx, comp)
        if not cands:
            return None
        score = self._score(ctx, cands)
        order = np.argsort(score.costs, kind="stable")
        best_c = int(order[0])
        best_cost = float(score.costs[best_c])
        best_perm = score.perms[best_c]
        best_nodes = cands[best_c]

        if best_cost > 0.0 and self.refine_top_k > 0:
            for c in order[:self.refine_top_k]:
                c = int(c)
                cost, perm = batch.hungarian_crosscheck(ctx.req, score, c)
                if cost < float(score.costs[c]):
                    score.costs[c] = cost
                    score.perms[c] = perm
                cost2, perm2 = batch.refine_assignment(ctx.req, score, c)
                if cost2 < float(score.costs[c]):
                    score.costs[c] = cost2
                    score.perms[c] = perm2
                if score.costs[c] < best_cost:
                    best_cost = float(score.costs[c])
                    best_perm = score.perms[c]
                    best_c, best_nodes = c, cands[c]
                if best_cost == 0.0:
                    break

        if best_cost > 0.0 and self.escalate and \
                (self.escalate_any_size
                 or len(ctx.req.order) <= ctx.exact_max):
            best_cost, best_perm, best_nodes = self._escalate(
                ctx, cands, score, order, best_cost, best_perm, best_nodes)

        return _result_from(ctx, best_nodes, np.asarray(best_perm),
                            best_cost, len(cands))

    def _escalate(self, ctx, cands, score, order, best_cost, best_perm,
                  best_nodes):
        """Exact B&B over the best-ranked candidates with a running budget."""
        n = 0
        for c in order:
            if best_cost == 0.0 or (self.escalate_limit is not None
                                    and n >= self.escalate_limit):
                break
            c = int(c)
            if _edge_count_lb(ctx, score, c) >= best_cost:
                continue
            n += 1
            if ctx.stats is not None:
                ctx.stats.exact_escalations += 1
            cost, perm = _bnb_perm(ctx, cands[c], budget=best_cost + 1e-9)
            if cost is not None and cost < best_cost:
                best_cost, best_perm, best_nodes = cost, perm, cands[c]
        return best_cost, best_perm, best_nodes


class HybridMapper(BipartiteMapper):
    """Bipartite ranking + exact/2-opt escalation — the engine default."""

    name = "hybrid"
    refine_top_k = REFINE_TOP_K
    escalate = True


class ExactMapper(BipartiteMapper):
    """Branch & bound on every candidate, whatever the request size (the
    sound ``_edge_count_lb`` skip and the shrinking global budget still
    prune, so exactness over the pool is preserved).  Exponential in the
    request size — ground truth for tests and small paper configs only."""

    name = "exact"
    escalate = True
    escalate_limit = None
    escalate_any_size = True


class RectangleGreedyMapper(Mapper):
    """First-fit: an exact-shape rectangle window if one exists, else the
    *first proposed* candidate scored by one bipartite solve — no pool-wide
    scoring, by design the cheapest (and least accurate) strategy.

    Quality is sharply orientation-dependent (an exact-shape window exists
    in one orientation of a strip but not its rotation) — the canonical
    example of why the engine never serves a cross-orientation cache entry
    whose TED is non-zero."""

    name = "rect"

    def map_component(self, ctx: MapContext,
                      comp: FrozenSet[int]) -> Optional[MappingResult]:
        from .candidates import rect_windows

        shape = ctx.t_req.is_rect_mesh()
        if shape is not None:
            k = len(ctx.req.order)
            # only windows of the request's exact shape — each is an
            # unclipped full rectangle, so no per-window shape re-check
            cand = next(rect_windows(ctx.topo, set(comp), k,
                                     shapes=[(shape[0], shape[1], 0)]), None)
            if cand is not None:
                # request canonical order and window order are both
                # row-major: the identity permutation aligns them
                score = self._score(ctx, [cand])
                ident = np.arange(k, dtype=np.int64)
                cost = float(batch.induced_batch(
                    ctx.req.A, ctx.req.W_miss, score.A, score.Wsp,
                    score.Cnode, ident[None])[0])
                return _result_from(ctx, cand, ident, cost, 1)
        cands = self._candidates(ctx, comp)
        if not cands:
            return None
        score = self._score(ctx, cands[:1])
        return _result_from(ctx, cands[0], score.perms[0],
                            float(score.costs[0]), 1)


class ILPMapper(Mapper):
    """Placement-quality oracle: one MILP over the free component.

    The TED objective is a quadratic assignment problem; this strategy
    linearizes it (:func:`repro.core.engine.ilp.solve_placement_milp`) and
    lets HiGHS prove the minimum over *all* injective placements of the
    request into the component — not just the truncated candidate pool the
    heuristic mappers rank.  ``MappingResult.optimal`` certifies it: True
    only when the MILP domain was the whole component and HiGHS returned
    status 0 (proven optimal) inside the time limit.

    Components whose MILP would exceed ``var_limit`` variables get a
    deterministic sub-domain instead — the union of the best
    bipartite-ranked candidates' nodes — so the strategy stays usable at
    pod scale, just without the certificate.  A perfect (TED 0) pool hit
    short-circuits the MILP entirely: zero is a global lower bound, so the
    certificate is free.

    Determinism: the domain construction is ordered, and HiGHS is
    deterministic for a fixed model; ``time_budget_s`` only caps runaway
    solves (a capped solve returns the incumbent un-certified).
    """

    name = "ilp"
    time_budget_s: float = 20.0      # HiGHS wall cap per component solve
    var_limit: int = 9000            # full-component MILP eligibility

    def map_component(self, ctx: MapContext,
                      comp: FrozenSet[int]) -> Optional[MappingResult]:
        from . import ilp as _ilp

        k = len(ctx.req.order)
        if len(comp) < k:
            return None
        cands = self._candidates(ctx, comp)
        if not _ilp.HAVE_MILP:  # pragma: no cover - scipy always ships milp
            return HybridMapper().map_component(ctx, comp)

        # cheap incumbent (and TED-0 short-circuit) from the pool
        best_cost = None
        best_perm = best_nodes = None
        if cands:
            score = self._score(ctx, cands)
            c = int(np.argmin(score.costs))
            best_cost = float(score.costs[c])
            best_perm, best_nodes = score.perms[c], cands[c]
            if best_cost == 0.0:
                return _result_from(ctx, best_nodes, best_perm, 0.0,
                                    len(cands), optimal=True)

        domain = self._domain(ctx, comp, cands, k)
        if domain is None:
            if best_cost is None:
                return None
            return _result_from(ctx, best_nodes, best_perm, best_cost,
                                len(cands))
        full = len(domain) == len(comp)
        idx = np.array([ctx.pool.index[n] for n in domain], dtype=np.int64)
        sol = _ilp.solve_placement_milp(
            ctx.req.A, ctx.req.W_miss, self._node_costs(ctx, idx),
            ctx.pool.adj[np.ix_(idx, idx)], ctx.Wspur[np.ix_(idx, idx)],
            time_limit=self.time_budget_s)
        evaluated = len(cands) + 1
        if sol is None:
            if best_cost is None:
                return None
            return _result_from(ctx, best_nodes, best_perm, best_cost,
                                evaluated)
        nodes = tuple(domain[s] for s in sol.slots)
        # exact edit cost of the MILP assignment through the same batched
        # arithmetic as every other mapper — solver tolerances never leak
        cost = self._induced(ctx, nodes)
        ident = np.arange(k, dtype=np.int64)
        if sol.proven and full:
            return _result_from(ctx, nodes, ident, cost, evaluated,
                                optimal=True)
        if best_cost is not None and best_cost <= cost:
            return _result_from(ctx, best_nodes, best_perm, best_cost,
                                evaluated)
        return _result_from(ctx, nodes, ident, cost, evaluated)

    # -- helpers ------------------------------------------------------------
    def _domain(self, ctx: MapContext, comp: FrozenSet[int],
                cands: List[Tuple[int, ...]], k: int
                ) -> Optional[Tuple[int, ...]]:
        """MILP node domain: the whole component when its model fits
        ``var_limit``, else the union of the best-ranked candidates' nodes
        (ascending bipartite cost — the order ``self._score`` ranked them
        in is not retained here, so plain pool order keeps it
        deterministic) up to the largest m the budget allows."""
        from . import ilp as _ilp

        nre = ctx.req.n_edges
        m = len(comp)
        n_edges = int(ctx.pool.adj[np.ix_(
            [ctx.pool.index[n] for n in comp],
            [ctx.pool.index[n] for n in comp])].sum()) // 2
        if _ilp.placement_milp_size(k, m, nre, n_edges) <= self.var_limit:
            return tuple(sorted(comp))
        if not cands:
            return None
        # mesh degree <= 4 bounds edges by 2m: m_max from the size formula
        m_max = max(k, self.var_limit // (k + 2 * nre + 2))
        domain: List[int] = []
        seen = set()
        for cand in cands:
            new = [n for n in cand if n not in seen]
            if domain and len(domain) + len(new) > m_max:
                break
            domain.extend(new)
            seen.update(new)
        return tuple(sorted(domain))

    def _node_costs(self, ctx: MapContext, idx: np.ndarray) -> np.ndarray:
        """(k x m) node substitution costs req slot x domain node — the
        rectangular analogue of :func:`batch.node_cost_tensor` (which is
        square, per-candidate)."""
        pool, req = ctx.pool, ctx.req
        base = (req.abbr[:, None] != pool.abbr[idx][None, :]).astype(
            np.float64) * batch.DEFAULT_NODE_COST
        if ctx.nm_id == "node:default":
            return base
        w = getattr(ctx.nm, "mem_dist_weight", None)
        if w is not None:
            return base + float(w) * np.abs(
                req.mem_dist[:, None] - pool.mem_dist[idx][None, :])
        node_attrs = pool.topo.node_attrs
        cattrs = [node_attrs[pool.ids[j]] for j in idx]
        out = np.empty((len(req.order), len(idx)), dtype=np.float64)
        for i, ra in enumerate(req.attrs):
            out[i, :] = [ctx.nm(ra, ca) for ca in cattrs]
        return out

    def _induced(self, ctx: MapContext, nodes: Sequence[int]) -> float:
        """Exact induced edit cost of the identity assignment onto
        ``nodes`` (slot i -> nodes[i])."""
        score = self._score(ctx, [tuple(nodes)])
        ident = np.arange(len(nodes), dtype=np.int64)
        return float(batch.induced_batch(ctx.req.A, ctx.req.W_miss, score.A,
                                         score.Wsp, score.Cnode,
                                         ident[None])[0])


class PartitionMapper(Mapper):
    """METIS-style recursive bisection — no candidate pool at all.

    The free component is first trimmed to a compact connected k-node
    blob (greedy nearest-to-seed growth from a corner node — without this
    a proportional geometric split of an m >> k component scatters the
    tile across the whole region).  The request graph is then recursively
    bisected (by its longer coordinate axis when it has coordinates — the
    min-cut split for a mesh — else by BFS order), the blob geometrically
    bisected along its longer bounding-box axis into matching halves.
    Leaves assign one request node to the first node of its tile; the
    resulting assignment is polished by one Hungarian cross-check and a
    2-opt descent on the selected node set.  O(m log m) selection +
    O(k^3) polish — cheaper than any pool-scoring strategy, and
    topology-aware where ``rect`` is not.
    """

    name = "partition"

    def map_component(self, ctx: MapContext,
                      comp: FrozenSet[int]) -> Optional[MappingResult]:
        k = len(ctx.req.order)
        if len(comp) < k:
            return None
        slots = self._bisect(ctx, list(range(k)),
                             self._trim(ctx, sorted(comp), k))
        cand = tuple(slots[i] for i in range(k))
        score = self._score(ctx, [cand])
        ident = np.arange(k, dtype=np.int64)
        part_cost = float(batch.induced_batch(
            ctx.req.A, ctx.req.W_miss, score.A, score.Wsp, score.Cnode,
            ident[None])[0])
        # keep the cheaper of (bisection order, Riesen-Bunke assignment)
        # on the selected tile, then 2-opt to a fixed point
        if part_cost <= float(score.costs[0]):
            score.costs[0], score.perms[0] = part_cost, ident
        best_cost = float(score.costs[0])
        best_perm = score.perms[0]
        if best_cost > 0.0:
            c2, p2 = batch.refine_assignment(ctx.req, score, 0)
            if c2 < best_cost:
                best_cost, best_perm = c2, p2
        return _result_from(ctx, cand, np.asarray(best_perm), best_cost, 1)

    # -- compact-blob pre-trim -----------------------------------------------
    def _trim(self, ctx: MapContext, region: List[int], k: int) -> List[int]:
        """Connected k-node blob grown greedily from a corner seed,
        preferring nodes nearest the seed (Manhattan; ties by id) — the
        compact tile the bisection then carves up."""
        if len(region) <= k:
            return region
        pcoords = ctx.topo.coords or {}
        seed = self._leaf_node(ctx, region, pcoords)
        sxy = pcoords.get(seed)

        def dist(n: int) -> int:
            p = pcoords.get(n)
            if sxy is None or p is None:
                return 0
            return abs(p[0] - sxy[0]) + abs(p[1] - sxy[1])

        in_region = set(region)
        chosen = {seed}
        frontier = {nb for nb in ctx.adj.get(seed, ())
                    if nb in in_region}
        while frontier and len(chosen) < k:
            # most-connected-first keeps the blob square-ish: a node with
            # two chosen neighbours closes a unit cell, one with a single
            # neighbour starts a strip
            n = min(frontier,
                    key=lambda x: (-sum(nb in chosen
                                        for nb in ctx.adj.get(x, ())),
                                   dist(x), x))
            frontier.discard(n)
            chosen.add(n)
            for nb in ctx.adj.get(n, ()):
                if nb in in_region and nb not in chosen:
                    frontier.add(nb)
        if len(chosen) < k:  # pragma: no cover - comp is connected
            chosen |= set(n for n in region if n not in chosen)
            return sorted(chosen)[:k]
        return sorted(chosen)

    # -- recursive bisection -------------------------------------------------
    def _bisect(self, ctx: MapContext, req_slots: List[int],
                region: List[int]) -> Dict[int, int]:
        """slot -> physical node by simultaneous recursive bisection."""
        rcoords = ctx.t_req.coords or {}
        pcoords = ctx.topo.coords or {}

        def rxy(slot: int):
            return rcoords.get(ctx.req.order[slot])

        def split(slots: List[int], nodes: List[int]) -> Dict[int, int]:
            if len(slots) == 1:
                return {slots[0]: self._leaf_node(ctx, nodes, pcoords)}
            n1 = len(slots) - len(slots) // 2
            n2 = len(slots) - n1
            slots = self._order(slots, rxy)
            m = len(nodes)
            m1 = max(n1, min(m - n2, round(m * n1 / len(slots))))
            nodes = self._order(nodes, pcoords.get)
            out = split(slots[:n1], nodes[:m1])
            out.update(split(slots[n1:], nodes[m1:]))
            return out

        return split(req_slots, region)

    @staticmethod
    def _order(items: List, xy) -> List:
        """Sort by the longer bounding-box axis (ties: the other axis,
        then identity) — the geometric bisection order.  Items without
        coordinates keep their given (sorted) order."""
        pts = [(it, xy(it)) for it in items]
        if any(p is None for _, p in pts):
            return list(items)
        rows = [p[0] for _, p in pts]
        cols = [p[1] for _, p in pts]
        if max(rows) - min(rows) >= max(cols) - min(cols):
            key = lambda t: (t[1][0], t[1][1], t[0])
        else:
            key = lambda t: (t[1][1], t[1][0], t[0])
        return [it for it, _ in sorted(pts, key=key)]

    @staticmethod
    def _leaf_node(ctx: MapContext, nodes: List[int], pcoords) -> int:
        if len(nodes) == 1 or not pcoords:
            return nodes[0]
        return PartitionMapper._order(nodes, pcoords.get)[0]


MAPPERS = {
    cls.name: cls
    for cls in (HybridMapper, BipartiteMapper, ExactMapper,
                RectangleGreedyMapper, ILPMapper, PartitionMapper)
}


def make_mappers() -> Dict[str, Mapper]:
    """Fresh strategy instances per engine (mappers are stateless today,
    but per-engine instances keep any future state from leaking)."""
    return {name: cls() for name, cls in MAPPERS.items()}
