"""Vectorized TED scoring over a stacked candidate pool.

The legacy path scored candidates one at a time: a Python-loop cost matrix,
a Python Hungarian solve, and a Python induced-edit-cost walk per candidate
(~1 ms each, hundreds per allocation).  Here the whole pool is scored as
batched numpy:

* one ``(n_cand, k, k)`` adjacency gather from the topology's dense
  adjacency matrix;
* one broadcasted Riesen–Bunke substitution-cost tensor (node match +
  degree-mismatch edge estimate) for the registered match functions
  (``match_id``-tagged); arbitrary callables fall back to a Python loop;
* per-candidate linear-sum-assignment (scipy when available, the local
  O(n^3) Hungarian otherwise);
* one batched induced-edit-cost evaluation (missing/spurious edge masks
  via permuted adjacency gathers).

The induced cost computed here is definitionally identical to
``repro.core.mapping.induced_edit_cost`` — the engine's property tests pin
that equivalence.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..mapping import (DEFAULT_EDGE_COST, DEFAULT_NODE_COST, EdgeMatch,
                       NodeMatch, hungarian)
from ..topology import Topology

try:  # scipy is optional — the pure-python Hungarian is the fallback
    from scipy.optimize import linear_sum_assignment as _lsa
except Exception:  # pragma: no cover
    _lsa = None


# ---------------------------------------------------------------------------
# per-topology precomputed arrays
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PoolArrays:
    """Dense per-topology arrays shared by every scoring call."""
    topo: Topology
    ids: Tuple[int, ...]
    index: Dict[int, int]
    adj: np.ndarray          # (N, N) bool
    abbr: np.ndarray         # (N,) int32 codes into ``vocab``
    mem_dist: np.ndarray     # (N,) float64
    vocab: Dict[str, int]

    def abbr_code(self, s: str) -> int:
        """Intern a node ``abbr`` string into the shared integer vocab."""
        return self.vocab.setdefault(s, len(self.vocab))


def make_pool_arrays(topo: Topology) -> PoolArrays:
    """Precompute the dense per-topology arrays (O(N^2) memory, built once
    per engine) that every batched scoring call gathers from."""
    ids = tuple(sorted(topo.node_attrs))
    index = {n: i for i, n in enumerate(ids)}
    n = len(ids)
    adj = np.zeros((n, n), dtype=bool)
    for (a, b) in topo.edge_attrs:
        ia, ib = index[a], index[b]
        adj[ia, ib] = adj[ib, ia] = True
    vocab: Dict[str, int] = {}
    abbr = np.zeros(n, dtype=np.int32)
    mem_dist = np.zeros(n, dtype=np.float64)
    for i, node in enumerate(ids):
        attrs = topo.node_attrs[node]
        s = attrs.get("abbr", "")
        abbr[i] = vocab.setdefault(s, len(vocab))
        mem_dist[i] = float(attrs.get("mem_dist", 0))
    return PoolArrays(topo=topo, ids=ids, index=index, adj=adj,
                      abbr=abbr, mem_dist=mem_dist, vocab=vocab)


def spur_matrix(pool: PoolArrays, em: EdgeMatch) -> np.ndarray:
    """(N, N) insertion cost of each physical edge under ``em`` — the cost a
    candidate pays for an edge the request does not have."""
    n = len(pool.ids)
    w = np.zeros((n, n), dtype=np.float64)
    for (a, b), attrs in pool.topo.edge_attrs.items():
        c = float(em(None, attrs))
        ia, ib = pool.index[a], pool.index[b]
        w[ia, ib] = w[ib, ia] = c
    return w


# ---------------------------------------------------------------------------
# request-side arrays
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RequestSpec:
    """The request topology in canonical order, as arrays."""
    order: Tuple[int, ...]     # request node ids, canonical order
    attrs: List[Dict]
    A: np.ndarray              # (k, k) bool adjacency
    W_miss: np.ndarray         # (k, k) deletion cost of each request edge
    abbr: np.ndarray           # (k,) codes into the pool vocab
    mem_dist: np.ndarray       # (k,) float64
    deg: np.ndarray            # (k,) float64
    n_edges: int = 0


def make_request_spec(pool: PoolArrays, t_req: Topology,
                      order: Sequence[int], em: EdgeMatch) -> RequestSpec:
    """Lift the request topology into canonical-order arrays (O(k^2), once
    per ``map_request``): adjacency, per-edge deletion costs under ``em``,
    attribute codes shared with the pool vocab."""
    order = tuple(order)
    k = len(order)
    idx = {n: i for i, n in enumerate(order)}
    attrs = [t_req.node_attrs[n] for n in order]
    A = np.zeros((k, k), dtype=bool)
    W = np.zeros((k, k), dtype=np.float64)
    for (a, b), eattrs in t_req.edge_attrs.items():
        ia, ib = idx[a], idx[b]
        A[ia, ib] = A[ib, ia] = True
        c = float(em(eattrs, None))
        W[ia, ib] = W[ib, ia] = c
    abbr = np.array([pool.abbr_code(d.get("abbr", "")) for d in attrs],
                    dtype=np.int32)
    mem = np.array([float(d.get("mem_dist", 0)) for d in attrs])
    return RequestSpec(order=order, attrs=attrs, A=A, W_miss=W, abbr=abbr,
                       mem_dist=mem, deg=A.sum(1).astype(np.float64),
                       n_edges=t_req.num_edges)


# ---------------------------------------------------------------------------
# batched scoring
# ---------------------------------------------------------------------------

def node_cost_tensor(pool: PoolArrays, req: RequestSpec,
                     cand_idx: np.ndarray, nm: NodeMatch,
                     nm_id: Optional[str]) -> np.ndarray:
    """(nc, k, k) substitution costs: C[c, i, j] = nm(req node i, cand slot j)."""
    cand_abbr = pool.abbr[cand_idx]          # (nc, k)
    base = (req.abbr[None, :, None] != cand_abbr[:, None, :]).astype(
        np.float64) * DEFAULT_NODE_COST
    if nm_id == "node:default":
        return base
    w = getattr(nm, "mem_dist_weight", None)   # mem_dist_node_match(w)
    if w is not None:
        cand_md = pool.mem_dist[cand_idx]
        return base + float(w) * np.abs(req.mem_dist[None, :, None]
                                        - cand_md[:, None, :])
    # arbitrary callable: exact but per-pair Python
    nc, k = cand_idx.shape
    out = np.empty((nc, k, k), dtype=np.float64)
    node_attrs = pool.topo.node_attrs
    for c in range(nc):
        cattrs = [node_attrs[pool.ids[j]] for j in cand_idx[c]]
        for i, ra in enumerate(req.attrs):
            out[c, i, :] = [nm(ra, ca) for ca in cattrs]
    return out


def assign_batch(C: np.ndarray) -> np.ndarray:
    """Optimal assignment per candidate: perms[c, i] = slot for req node i."""
    nc, k, _ = C.shape
    perms = np.empty((nc, k), dtype=np.int64)
    if _lsa is not None:
        for c in range(nc):
            _, cols = _lsa(C[c])
            perms[c] = cols
    else:
        for c in range(nc):
            perms[c] = hungarian(C[c])
    return perms


def induced_batch(req_A: np.ndarray, req_W: np.ndarray, A: np.ndarray,
                  Wsp: np.ndarray, Cnode: np.ndarray,
                  perms: np.ndarray) -> np.ndarray:
    """Batched ``induced_edit_cost``: node substitutions + request edges
    missing under the mapping + spurious candidate edges."""
    nc, k = perms.shape
    ar = np.arange(nc)[:, None, None]
    node_cost = np.take_along_axis(
        Cnode, perms[:, :, None], axis=2)[:, :, 0].sum(1)
    B = A[ar, perms[:, :, None], perms[:, None, :]]           # (nc, k, k)
    Wm = Wsp[ar, perms[:, :, None], perms[:, None, :]]
    missing = req_A[None] & ~B
    spur = B & ~req_A[None]
    # symmetric matrices count each edge twice -> 0.5
    edge_cost = 0.5 * ((req_W[None] * missing).sum((1, 2))
                       + (Wm * spur).sum((1, 2)))
    return node_cost + edge_cost


@dataclasses.dataclass
class PoolScore:
    """One batch-scoring result: per-candidate costs/assignments plus the
    gathered tensors the refinement passes reuse (costs are edit-distance
    units — the same scale as ``MappingResult.ted``)."""
    cand_idx: np.ndarray       # (nc, k) indices into pool.ids
    costs: np.ndarray          # (nc,) induced edit cost of the LSA assignment
    perms: np.ndarray          # (nc, k)
    A: np.ndarray              # (nc, k, k) candidate adjacency
    Wsp: np.ndarray            # (nc, k, k) spurious-edge costs
    Cnode: np.ndarray          # (nc, k, k) node substitution costs
    n_edges: np.ndarray        # (nc,) candidate internal edge count


def score_pool(pool: PoolArrays, req: RequestSpec, cand_idx: np.ndarray,
               Wspur: np.ndarray, nm: NodeMatch,
               nm_id: Optional[str]) -> PoolScore:
    """Score the whole candidate pool in one batched pass: Riesen–Bunke
    bipartite assignment per candidate, then the exact induced edit cost
    of each assignment.  O(nc x k^3) for the assignments + O(nc x k^2)
    vectorized arithmetic — the hot path of every mapper."""
    A = pool.adj[cand_idx[:, :, None], cand_idx[:, None, :]]
    degc = A.sum(-1).astype(np.float64)
    Cnode = node_cost_tensor(pool, req, cand_idx, nm, nm_id)
    Cbip = Cnode + 0.5 * DEFAULT_EDGE_COST * np.abs(
        req.deg[None, :, None] - degc[:, None, :])
    perms = assign_batch(Cbip)
    Wsp = Wspur[cand_idx[:, :, None], cand_idx[:, None, :]]
    costs = induced_batch(req.A, req.W_miss, A, Wsp, Cnode, perms)
    return PoolScore(cand_idx=cand_idx, costs=costs, perms=perms, A=A,
                     Wsp=Wsp, Cnode=Cnode,
                     n_edges=(A.sum((1, 2)) // 2).astype(np.int64))


# ---------------------------------------------------------------------------
# refinement
# ---------------------------------------------------------------------------

def refine_assignment(req: RequestSpec, score: PoolScore, c: int,
                      max_rounds: Optional[int] = None
                      ) -> Tuple[float, np.ndarray]:
    """2-opt descent on candidate ``c``: evaluate all pairwise slot swaps
    of the current assignment, take the best, repeat to a fixed point.
    Monotone non-increasing, so the result is never worse than the input.

    Swap deltas are computed in closed form — a slot swap (i, j) only
    relabels rows/columns i and j of the permuted adjacency, so the edit
    cost changes by row-local terms assembled from two k x k matmuls:
    O(k^3) per round instead of the O(k^4) full re-evaluation of every
    variant.  With the shipped match functions every cost is a dyadic
    rational, so the delta arithmetic is float-exact and the descent
    (including tie-breaks, which follow the (i, j) lexicographic pair
    order) is identical to the full re-evaluation's.
    """
    k = score.perms.shape[1]
    perm = score.perms[c].copy()
    cost = float(score.costs[c])
    if k < 2:
        return cost, perm
    A = score.A[c].astype(np.float64)
    Wsp = score.Wsp[c]
    Cn = score.Cnode[c]
    reqA = req.A.astype(np.float64)
    M1 = reqA * req.W_miss                 # request-edge deletion costs
    notreqA = 1.0 - reqA
    iu = np.triu_indices(k, 1)
    rounds = max_rounds if max_rounds is not None else 2 * k
    for _ in range(rounds):
        B = A[np.ix_(perm, perm)]          # candidate adjacency, slot space
        S = Wsp[np.ix_(perm, perm)]
        notB = 1.0 - B
        BS = B * S                         # spurious-edge costs actually paid
        E = M1 * notB + notreqA * BS       # per-pair edit cost, current perm
        Erow = E.sum(1)
        # R[i, j] = row cost of request node i re-homed onto slot perm[j]
        R = M1 @ notB.T + notreqA @ BS.T
        CnP = Cn[:, perm]
        diag = np.diagonal(CnP).copy()
        dnode = CnP + CnP.T - diag[:, None] - diag[None, :]
        delta = (dnode + R + R.T - 2.0 * BS - 2.0 * M1
                 - Erow[:, None] - Erow[None, :] + 2.0 * E)
        flat = delta[iu]
        best = int(np.argmin(flat))
        if flat[best] < -1e-12:
            cost = float(cost + flat[best])
            i, j = int(iu[0][best]), int(iu[1][best])
            perm[i], perm[j] = perm[j], perm[i]
        else:
            break
    return cost, perm


def hungarian_crosscheck(req: RequestSpec, score: PoolScore,
                         c: int) -> Tuple[float, np.ndarray]:
    """Score candidate ``c`` with the pure-python Hungarian (the legacy
    solver).  LSA ties can pick assignments whose *induced* cost differs;
    evaluating both and keeping the cheaper makes the batched path
    equal-or-better than the legacy per-candidate path on every candidate
    it refines."""
    k = score.perms.shape[1]
    degc = score.A[c].sum(1).astype(np.float64)
    Cbip = score.Cnode[c] + 0.5 * DEFAULT_EDGE_COST * np.abs(
        req.deg[:, None] - degc[None, :])
    perm = np.asarray(hungarian(Cbip), dtype=np.int64)
    cost = float(induced_batch(req.A, req.W_miss, score.A[c:c + 1],
                               score.Wsp[c:c + 1], score.Cnode[c:c + 1],
                               perm[None])[0])
    return cost, perm
