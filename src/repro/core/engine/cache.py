"""Content-addressed LRU cache for minTopologyEditDistance results.

Keys are ``(free-region canonical key, request canonical key, node-match id,
edge-match id, mapper name, max_candidates)``.  Values are stored in
*canonical index space* (positions within the region's and request's
canonical node orders), so one entry serves every translated placement of
the same region shape — the hit is translated back to concrete core ids
through the current :class:`~repro.core.engine.regions.RegionSignature`.

Invalidation is structural rather than explicit: the hypervisor's
allocate/release notifications update the :class:`FreeRegions` tracker,
every component mutation mints a fresh canonical key, and entries for
shapes that no longer occur simply age out of the LRU.  A stale entry is
unreachable by construction — there is no epoch/version protocol to get
wrong.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, FrozenSet, Hashable, Optional, Sequence, Tuple

from ..mapping import MappingResult


@dataclasses.dataclass(frozen=True)
class CachedMapping:
    """A MappingResult lifted into canonical index space.  ``transform``
    records the D4 group element of the *encoding* region's canonical
    frame — a later hit whose region canonicalizes through a different
    element is a genuinely symmetry-decoded result (one a
    translation-only key could not have served)."""
    ted: float
    nodes_idx: Tuple[int, ...]                 # indices into the region order
    assign_idx: Tuple[Tuple[int, int], ...]    # (request idx, region idx)
    exact: bool
    candidates_evaluated: int
    transform: str = "identity"


def encode_result(result: MappingResult, region_order: Sequence[int],
                  request_order: Sequence[int],
                  transform: str = "identity") -> CachedMapping:
    rpos = {n: i for i, n in enumerate(region_order)}
    qpos = {n: i for i, n in enumerate(request_order)}
    return CachedMapping(
        ted=result.ted,
        nodes_idx=tuple(sorted(rpos[n] for n in result.nodes)),
        assign_idx=tuple(sorted((qpos[v], rpos[p])
                                for v, p in result.assignment.items())),
        exact=result.exact,
        candidates_evaluated=result.candidates_evaluated,
        transform=transform)


def decode_result(entry: CachedMapping, region_order: Sequence[int],
                  request_order: Sequence[int]) -> MappingResult:
    return MappingResult(
        nodes=frozenset(region_order[i] for i in entry.nodes_idx),
        ted=entry.ted,
        assignment={request_order[qi]: region_order[ri]
                    for qi, ri in entry.assign_idx},
        exact=entry.exact,
        candidates_evaluated=entry.candidates_evaluated)


class TEDCache:
    """Bounded LRU over canonical mapping results."""

    def __init__(self, max_entries: int = 4096):
        self.max_entries = max_entries
        self._data: "OrderedDict[Hashable, Optional[CachedMapping]]" = \
            OrderedDict()

    def get(self, key: Hashable) -> Tuple[bool, Optional[CachedMapping]]:
        """(found, entry) — ``entry`` may be None (a cached negative:
        the region provably has no candidate for that request)."""
        if key not in self._data:
            return False, None
        self._data.move_to_end(key)
        return True, self._data[key]

    def put(self, key: Hashable, entry: Optional[CachedMapping]) -> None:
        self._data[key] = entry
        self._data.move_to_end(key)
        while len(self._data) > self.max_entries:
            self._data.popitem(last=False)

    def clear(self) -> None:
        self._data.clear()

    def __len__(self) -> int:
        return len(self._data)
