"""Content-addressed LRU cache for minTopologyEditDistance results.

Keys are ``(free-region canonical key, request canonical key, node-match id,
edge-match id, mapper name, max_candidates)``.  Values are stored in
*canonical index space* (positions within the region's and request's
canonical node orders), so one entry serves every translated placement of
the same region shape — the hit is translated back to concrete core ids
through the current :class:`~repro.core.engine.regions.RegionSignature`.

Invalidation is structural rather than explicit: the hypervisor's
allocate/release notifications update the :class:`FreeRegions` tracker,
every component mutation mints a fresh canonical key, and entries for
shapes that no longer occur simply age out of the LRU.  A stale entry is
unreachable by construction — there is no epoch/version protocol to get
wrong.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import (AbstractSet, Callable, Dict, FrozenSet, Hashable,
                    Optional, Sequence, Tuple)

from ..mapping import MappingResult


@dataclasses.dataclass(frozen=True)
class CachedMapping:
    """A MappingResult lifted into canonical index space.  ``transform``
    records the D4 group element of the *encoding* region's canonical
    frame — a later hit whose region canonicalizes through a different
    element is a genuinely symmetry-decoded result (one a
    translation-only key could not have served)."""
    ted: float
    nodes_idx: Tuple[int, ...]                 # indices into the region order
    assign_idx: Tuple[Tuple[int, int], ...]    # (request idx, region idx)
    exact: bool
    candidates_evaluated: int
    transform: str = "identity"
    #: the ILP mapper's optimality certificate (see MappingResult.optimal);
    #: like TED 0, a proven component optimum is a D4-invariant quantity,
    #: so optimal entries are servable across orientations
    optimal: bool = False


def encode_result(result: MappingResult, region_order: Sequence[int],
                  request_order: Sequence[int],
                  transform: str = "identity") -> CachedMapping:
    rpos = {n: i for i, n in enumerate(region_order)}
    qpos = {n: i for i, n in enumerate(request_order)}
    return CachedMapping(
        ted=result.ted,
        nodes_idx=tuple(sorted(rpos[n] for n in result.nodes)),
        assign_idx=tuple(sorted((qpos[v], rpos[p])
                                for v, p in result.assignment.items())),
        exact=result.exact,
        candidates_evaluated=result.candidates_evaluated,
        transform=transform,
        optimal=result.optimal)


def decode_result(entry: CachedMapping, region_order: Sequence[int],
                  request_order: Sequence[int]) -> MappingResult:
    return MappingResult(
        nodes=frozenset(region_order[i] for i in entry.nodes_idx),
        ted=entry.ted,
        assignment={request_order[qi]: region_order[ri]
                    for qi, ri in entry.assign_idx},
        exact=entry.exact,
        candidates_evaluated=entry.candidates_evaluated,
        optimal=entry.optimal)


def region_part(key: Tuple) -> Hashable:
    """The free-region component of a cache key.  Normal keys lead with
    the region's canonical ``RegionSignature.key``; the relaxed zig-zag
    keys lead with the ``"zz"`` tag and carry the sorted free set second
    (see ``MappingEngine.map_request`` / ``_relaxed_fallback``)."""
    return key[1] if key[0] == "zz" else key[0]


class TEDCache:
    """Bounded LRU over canonical mapping results, with live-shape pinning.

    Plain LRU makes placement results *history-dependent* at scale: once
    churn evicts the entry for a region shape that is still instantiated
    on the mesh, the next query re-solves on concrete core ids, and a
    re-solve is only guaranteed to reproduce the evicted entry up to
    equal-cost ties (heuristic tie-breaks are translation-covariant but
    the D4 frame-exact protocol exists precisely because they are not
    orientation-invariant).  ``pinned`` closes that hole: a callback
    returning the region keys currently *live* on the mesh — eviction
    gives their entries a second chance (re-appended, never dropped), so
    for live shapes the hit/miss pattern is a function of the query
    sequence alone, not of how much unrelated churn the cache absorbed.
    Dead shapes become evictable the moment the tracker mutates them
    away; if every resident entry is pinned the capacity bound goes soft
    (the pin set is O(live components), so the overshoot is too).
    """

    def __init__(self, max_entries: int = 4096,
                 pinned: Optional[Callable[[], AbstractSet]] = None):
        self.max_entries = max_entries
        self._pinned = pinned
        self.evictions = 0
        self._data: "OrderedDict[Hashable, Optional[CachedMapping]]" = \
            OrderedDict()

    def get(self, key: Hashable) -> Tuple[bool, Optional[CachedMapping]]:
        """(found, entry) — ``entry`` may be None (a cached negative:
        the region provably has no candidate for that request)."""
        if key not in self._data:
            return False, None
        self._data.move_to_end(key)
        return True, self._data[key]

    def put(self, key: Hashable, entry: Optional[CachedMapping]) -> None:
        self._data[key] = entry
        self._data.move_to_end(key)
        if len(self._data) <= self.max_entries:
            return
        live: Optional[AbstractSet] = None
        scanned, n = 0, len(self._data)
        while len(self._data) > self.max_entries and scanned < n:
            k, v = self._data.popitem(last=False)
            scanned += 1
            if live is None:    # snapshot once per overflowing put
                live = (frozenset(self._pinned())
                        if self._pinned is not None else frozenset())
            if region_part(k) in live:
                self._data[k] = v        # second chance: stays resident
            else:
                self.evictions += 1

    def clear(self) -> None:
        self._data.clear()

    def __len__(self) -> int:
        return len(self._data)
