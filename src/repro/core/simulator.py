"""Analytical performance simulator for inter-core connected NPUs.

Replaces the paper's FireSim/DCRA stack (this container has no FPGA): a
deterministic, mechanistic model of

  * systolic-array compute per tile (Table 2 geometry),
  * DMA between HBM and per-tile scratchpad with pluggable address
    translation (physical / page-TLB / range-TLB).  Two modes: an analytic
    model of the burst-pipelined walker (used by benchmarks — calibrated to
    NeuMMU-style behaviour), and a trace-driven mode that drives the *real*
    TLB structures from ``vchunk.py`` with synthetic traces exhibiting the
    paper's Patterns 1–3 (used by unit tests),
  * NoC transfers with dimension-order routing, per-link contention and
    tenant interference,
  * two execution styles per workload:
      - ``pipeline``  — layers partitioned across cores (CNNs; Fig 16/18),
      - ``tensor``    — every layer split across all cores, with a per-layer
        activation all-reduce (transformers under tensor partitioning; the
        paper notes SOTA data-flow NPUs hold all weights in SRAM via tensor
        partition, §6.3),
    each under ``dataflow`` (inter-core NoC) or ``uvm`` (global-memory
    synchronization) communication.

Outputs are cycles (and FPS at the configured frequency).  Benchmarks for
Figs. 11–18 / Table 3 are thin drivers over this module.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence, Set, Tuple

import numpy as np

from .topology import Topology, mesh_2d
from .vchunk import (PageTable, PageTLB, RangeTLB, RangeTranslationTable,
                     RTTEntry, TLBStats)
from .vrouter import NOC_HOP_CYCLES, dor_path
from .workloads import Layer, WorkloadGraph, partition_layers


@dataclasses.dataclass
class HWConfig:
    """Table 2 — SIM column by default."""
    sa_dim: int = 128
    n_tiles: int = 36
    mesh_shape: Tuple[int, int] = (6, 6)
    scratchpad_per_tile: int = 30 << 20
    freq_hz: float = 500e6
    hbm_bw_bytes_per_s: float = 360e9
    noc_link_bytes_per_cycle: int = 256   # dedicated per-link on-chip bw
    noc_hop_cycles: int = NOC_HOP_CYCLES
    dma_burst_bytes: int = 512
    page_size: int = 4096
    # pipelined page-walker: stall cycles *exposed* per miss once the walk
    # queue saturates during DMA bursts (NeuMMU burst phenomenon)
    exposed_page_walk_cycles: int = 16
    dma_streams: int = 8                  # concurrent DMA queues per core
    tlb_thrash_alpha: float = 0.8         # inter-stream TLB thrash factor
    rtt_entry_read_cycles: int = 6        # read one RTT entry from meta-zone
    uvm_sync_cycles: int = 600            # semaphore round-trip via L2/HBM
    vector_macs_per_cycle: int = 128      # VU rate for depthwise/norm layers
    tdm_switch_cycles: int = 5_000      # scratchpad context swap (§7)
    mem_interface_cols: Tuple[int, ...] = (0,)

    @property
    def hbm_bytes_per_cycle(self) -> float:
        return self.hbm_bw_bytes_per_s / self.freq_hz

    @property
    def macs_per_cycle(self) -> int:
        return self.sa_dim * self.sa_dim

    def topo(self) -> Topology:
        return mesh_2d(*self.mesh_shape, mem_interface_cols=self.mem_interface_cols)


FPGA_CONFIG = HWConfig(sa_dim=16, n_tiles=8, mesh_shape=(2, 4),
                       scratchpad_per_tile=512 << 10, freq_hz=1e9,
                       hbm_bw_bytes_per_s=16e9, noc_link_bytes_per_cycle=32)
SIM_CONFIG = HWConfig()


# ---------------------------------------------------------------------------
# compute model
# ---------------------------------------------------------------------------

def layer_compute_cycles(layer: Layer, hw: HWConfig, cores: int = 1) -> int:
    """Cycles to run one layer on ``cores`` tiles (weight-stationary SA).

    Utilization drops when the reduction dim underfills the array — the
    structural reason small CNN layers can't saturate big NPUs (§2.2).
    """
    if layer.macs == 0:
        return 0
    if layer.kind in ("dwconv", "norm", "pool"):
        rate = hw.vector_macs_per_cycle * cores
        return max(1, math.ceil(layer.macs / rate))
    sa = hw.sa_dim
    if layer.weight_bytes > 0:
        # reduction depth = weights / (2 bytes * out_features); recover
        # out_features from out_bytes per spatial position is fiddly — use a
        # robust proxy: depth = sqrt-scaled weights footprint
        n_weights = layer.weight_bytes // 2
        # conv: weights = cin*k*k*cout; reduction = cin*k*k
        # we stored enough to get reduction via macs/out_elems:
        out_elems = max(layer.out_bytes // 2, 1)
        reduction = max(1, layer.macs // out_elems)
        util_r = min(1.0, reduction / sa)
        util_c = min(1.0, (n_weights / max(reduction, 1)) / sa)
        util = max(util_r * max(util_c, 1.0 / sa), 1.0 / sa)
    else:
        util = 0.5  # attention score/value matmuls — activation-stationary
    eff = hw.macs_per_cycle * util * cores
    return max(1, math.ceil(layer.macs / eff))


# ---------------------------------------------------------------------------
# DMA + translation model
# ---------------------------------------------------------------------------

def make_rtt_for_blob(total_bytes: int, base_paddr: int = 0,
                      max_block: int = 256 << 20,
                      min_block: int = 1 << 20) -> RangeTranslationTable:
    """Buddy-style decomposition of a weight blob into power-of-two ranges."""
    rtt = RangeTranslationTable()
    va = pa = 0
    pa = base_paddr
    remaining = max(total_bytes, min_block)
    while remaining > 0:
        blk = 1 << (remaining.bit_length() - 1)
        blk = max(min(blk, max_block), min_block)
        rtt.insert(RTTEntry(vaddr=va, paddr=pa, size=blk))
        va += blk
        pa += blk
        remaining -= blk
    return rtt


@dataclasses.dataclass
class DMAResult:
    transfer_cycles: int
    stall_cycles: int
    misses: int = 0
    stats: Optional[TLBStats] = None

    @property
    def total_cycles(self) -> int:
        return self.transfer_cycles + self.stall_cycles

    @property
    def overhead(self) -> float:
        return self.stall_cycles / max(self.transfer_cycles, 1)


def page_misses_analytic(total_bytes: int, hw: HWConfig, tlb_entries: int,
                         n_iterations: int = 1) -> int:
    """Streaming weight DMA touches bytes/page_size distinct pages per
    iteration; with fewer TLB entries than concurrent DMA streams, the
    sequential locality inside a page is destroyed by thrash (calibrated to
    the paper's Fig 14: ~20% overhead @4 entries, ~9.2% @32)."""
    pages = max(1, total_bytes // hw.page_size)
    thrash = 1.0 + hw.tlb_thrash_alpha * (hw.dma_streams / max(tlb_entries, 1))
    return int(pages * thrash) * n_iterations


def simulate_weight_dma(total_bytes: int, hw: HWConfig, *,
                        translation: str = "physical",
                        tlb_entries: int = 4,
                        n_iterations: int = 1,
                        bw_share: float = 1.0,
                        n_ranges: Optional[int] = None,
                        trace_driven: bool = False) -> DMAResult:
    """Stream ``total_bytes`` of weights HBM->SRAM, ``n_iterations`` times.

    Analytic by default; ``trace_driven=True`` drives the real vchunk TLB
    structures with a monotonic, iteration-periodic burst trace (Patterns
    2/3) — used by the unit tests and small Fig-14 points.
    """
    if translation not in ("physical", "page", "range"):
        raise ValueError(translation)
    bw = hw.hbm_bytes_per_cycle * bw_share
    xfer = math.ceil(total_bytes * n_iterations / bw)
    if translation == "physical" or total_bytes == 0:
        return DMAResult(xfer, 0)

    if trace_driven:
        burst = hw.dma_burst_bytes
        n_bursts = max(1, total_bytes // burst)
        if translation == "page":
            pt = PageTable(hw.page_size)
            pt.map_range(0, 0, _round_up(total_bytes, hw.page_size))
            tlb = PageTLB(pt, n_entries=tlb_entries)
            for _ in range(n_iterations):
                for b in range(n_bursts):
                    tlb.translate(b * burst)
            stall = tlb.stats.misses * hw.exposed_page_walk_cycles
            return DMAResult(xfer, stall, tlb.stats.misses, tlb.stats)
        rtt = make_rtt_for_blob(total_bytes)
        rtlb = RangeTLB(rtt, n_entries=tlb_entries)
        for _ in range(n_iterations):
            for b in range(n_bursts):
                rtlb.translate(b * burst)
        stall = rtlb.stats.walk_steps * hw.rtt_entry_read_cycles
        return DMAResult(xfer, stall, rtlb.stats.misses, rtlb.stats)

    if translation == "page":
        misses = page_misses_analytic(total_bytes, hw, tlb_entries, n_iterations)
        stall = misses * hw.exposed_page_walk_cycles
        return DMAResult(xfer, stall, misses)
    # range: misses per iteration ~= number of RTT ranges; the RTT_CUR cursor
    # makes each miss a 1-entry walk (Pattern-2) and last_v removes the
    # wrap-around scan from iteration 2 onwards (Pattern-3).
    nr = n_ranges if n_ranges is not None else len(make_rtt_for_blob(total_bytes).entries)
    misses = nr * n_iterations
    walk_steps = nr + (n_iterations - 1) * nr  # 1 step per miss with cursor
    stall = walk_steps * hw.rtt_entry_read_cycles
    return DMAResult(xfer, stall, misses)


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


# ---------------------------------------------------------------------------
# NoC model
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Flow:
    src: int            # physical core id
    dst: int
    bytes_per_iter: int
    owner: int = 0      # vmid


def flow_paths(topo: Topology, flows: Sequence[Flow]) -> List[List[int]]:
    coord = topo.coords
    inv = {v: k for k, v in coord.items()}
    return [[inv[c] for c in dor_path(coord[f.src], coord[f.dst])]
            for f in flows]


def flow_link_loads(topo: Topology, flows: Sequence[Flow]
                    ) -> Dict[Tuple[int, int], float]:
    """Aggregate per-directed-link byte loads (bytes/iteration) of a flow
    set — the unit the scheduler's
    :class:`~repro.sched.ledger.InterferenceLedger` adds and subtracts per
    tenant.  O(flows x path length).

    Loads are float-typed but always integer-valued (``Flow.bytes_per_iter``
    is an int and sums stay far below 2**53), so aggregation is exact and
    order-independent: summing per-tenant footprints and summing a flat
    flow list produce bit-identical link loads.
    """
    loads: Dict[Tuple[int, int], float] = {}
    for path, f in zip(flow_paths(topo, flows), flows):
        for e in zip(path, path[1:]):
            loads[e] = loads.get(e, 0.0) + f.bytes_per_iter
    # a zero load is indistinguishable from an absent link in every
    # consumer (max-over-path, add, subtract) — prune for clean bookkeeping
    return {e: v for e, v in loads.items() if v}


def link_contention(paths: Sequence[Sequence[int]],
                    flows: Sequence[Flow],
                    external_loads: Optional[Dict[Tuple[int, int], float]]
                    = None) -> List[float]:
    """Per-flow slowdown: bytes on its busiest link / its own bytes (>=1).

    Links are full-duplex: the (a, b) and (b, a) directions carry
    independent bandwidth, so opposing flows do not contend — loads are
    keyed by *directed* edge.  ``external_loads`` seeds the link loads with
    pre-aggregated co-tenant traffic (see :func:`flow_link_loads`) — exactly
    equivalent to, and cheaper than, listing every external flow in
    ``flows``.  O(flows x path length).
    """
    loads: Dict[Tuple[int, int], float] = (
        dict(external_loads) if external_loads else {})
    for path, f in zip(paths, flows):
        for e in zip(path, path[1:]):
            loads[e] = loads.get(e, 0.0) + f.bytes_per_iter
    out = []
    for path, f in zip(paths, flows):
        if len(path) < 2 or f.bytes_per_iter == 0:
            out.append(1.0)
            continue
        worst = max(loads[e] for e in zip(path, path[1:]))
        out.append(max(1.0, worst / f.bytes_per_iter))
    return out


def noc_transfer_cycles(topo: Topology, flow: Flow, hw: HWConfig,
                        contention: float = 1.0) -> int:
    coord = topo.coords
    hops = abs(coord[flow.src][0] - coord[flow.dst][0]) + \
        abs(coord[flow.src][1] - coord[flow.dst][1])
    if flow.bytes_per_iter == 0:
        return 0
    # longer paths occupy more links: wormhole body trails the head across
    # `hops` links, so effective serialization grows with path length
    occupancy = 1.0 + 0.3 * max(hops - 1, 0)
    ser = flow.bytes_per_iter / hw.noc_link_bytes_per_cycle * \
        contention * occupancy
    return int(hops * hw.noc_hop_cycles + ser)


def avg_pairwise_hops(topo: Topology, cores: Sequence[int]) -> float:
    """Mean NoC distance inside an allocation — compactness of the mapping.

    Vectorized (all-pairs |Δrow| + |Δcol| as one numpy reduction): the sums
    are integer-exact, so the value is identical to the reference double
    loop at any scale.  O(k^2) arithmetic without the Python-loop constant.
    """
    cs = list(cores)
    k = len(cs)
    if k < 2:
        return 0.0
    coord = topo.coords
    pts = np.array([coord[c] for c in cs], dtype=np.int64)
    tot = int(np.abs(pts[:, None, :] - pts[None, :, :]).sum()) // 2
    return tot / (k * (k - 1) // 2)


# ---------------------------------------------------------------------------
# execution models
# ---------------------------------------------------------------------------

def tdm_pack(times: Sequence[int], n_physical: int) -> List[int]:
    """Greedy longest-processing-time packing of virtual-core stage times
    onto physical cores (the MIG baseline's time-division multiplexing,
    §6.3.2: 'binding a high-load virtual core with a low-load virtual
    core').  Returns per-physical-core total loads.
    """
    bins = [0] * max(n_physical, 1)
    for t in sorted(times, reverse=True):
        i = min(range(len(bins)), key=lambda j: bins[j])
        bins[i] += t
    return bins


@dataclasses.dataclass
class StageReport:
    core: int
    compute_cycles: int
    comm_cycles: int
    dma_cycles: int


@dataclasses.dataclass
class RunReport:
    workload: str
    mode: str                  # pipeline-dataflow | pipeline-uvm | tensor-*
    interval_cycles: int       # pipeline initiation interval (1/throughput)
    latency_cycles: int
    warmup_cycles: int
    stages: List[StageReport]
    fps: float
    bubble_fraction: float


def _stage_flows(graph: WorkloadGraph, layer_core: Sequence[int],
                 core_of_stage: Sequence[int], owner: int) -> List[Flow]:
    agg: Dict[Tuple[int, int], int] = {}
    for (a, b) in graph.edges:
        sa, sb = layer_core[a], layer_core[b]
        if sa != sb:
            key = (core_of_stage[sa], core_of_stage[sb])
            agg[key] = agg.get(key, 0) + graph.layers[a].out_bytes
    return [Flow(src=s, dst=d, bytes_per_iter=v, owner=owner)
            for (s, d), v in agg.items()]


def _reduce_layers(graph: WorkloadGraph) -> List[Layer]:
    out = [l for l in graph.layers if l.reduce_out and l.out_bytes]
    if not out:  # untagged graph: reduce everything (conservative)
        out = [l for l in graph.layers if l.out_bytes]
    return out


def _ring_flows(graph: WorkloadGraph, cores: Sequence[int],
                owner: int) -> List[Flow]:
    """Tensor-parallel ring all-reduce as per-iteration NoC flows between
    consecutive ring members (the per-link ring volume of every reduced
    layer)."""
    n = len(cores)
    if n < 2:
        return []
    per_link = sum(2 * l.out_bytes * (n - 1) // max(n, 1)
                   for l in _reduce_layers(graph))
    ring = sorted(cores)
    return [Flow(src=a, dst=b, bytes_per_iter=per_link, owner=owner)
            for a, b in zip(ring, ring[1:] + ring[:1])]


def is_tensor_parallel(graph: "WorkloadGraph") -> bool:
    """One predicate for the transformer/tensor-parallel execution model —
    both the flow wiring (``tenant_flows``) and the dispatcher
    (``simulate``) must agree on it, or the scheduler would inject ring
    all-reduce flows for a tenant scored as a pipeline (or vice versa)."""
    return graph.name.startswith(("gpt", "bert", "transformer"))


def tenant_flows(graph: WorkloadGraph, cores: Sequence[int], topo: Topology,
                 hw: HWConfig, owner: int = 1) -> List[Flow]:
    """The NoC flows one tenant injects per iteration — what its co-residents
    see as ``external_flows``.

    Pipeline workloads (CNNs): the stage-boundary activation transfers.
    Tensor-parallel workloads (transformers): the ring all-reduce flows.
    """
    n = len(cores)
    if n == 0:
        return []
    if is_tensor_parallel(graph):
        return _ring_flows(graph, cores, owner)
    layer_core = partition_layers(graph, n,
                                  cost=lambda l: layer_compute_cycles(l, hw))
    return _stage_flows(graph, layer_core, list(cores), owner)


@dataclasses.dataclass
class PipelineSkeleton:
    """The placement-dependent half of :func:`simulate_pipeline`.

    Everything here is a function of (graph, cores, topo, hw, comm) only —
    layer partition, per-stage compute/weight totals, the tenant's own NoC
    flows and their DOR paths.  None of it depends on co-tenant traffic
    (``external_link_loads``/``external_flows``) or ``hbm_concurrency``, so
    the scheduler computes it once per *placement* and recombines only the
    contention/HBM terms per scoring pass (:func:`rescore_contention`).
    """
    graph: WorkloadGraph
    topo: Topology
    hw: HWConfig
    comm: str
    owner: int
    translation: str
    tlb_entries: int
    weight_streaming: bool
    tdm_physical: Optional[int]
    virtualization_overhead: float
    n: int
    core_of_stage: List[int]
    comp: List[int]                     # per-stage compute cycles
    wbytes: List[int]                   # per-stage weight bytes
    flows: List[Flow]                   # own NoC flows (stage boundaries)
    paths: List[List[int]]              # DOR path of each own flow

    @property
    def noc_flows(self) -> List[Flow]:
        """The flows this tenant injects (what co-residents see)."""
        return self.flows


def pipeline_skeleton(
    graph: WorkloadGraph,
    cores: Sequence[int],
    topo: Topology,
    hw: HWConfig,
    *,
    comm: str = "dataflow",
    owner: int = 1,
    translation: str = "range",
    tlb_entries: int = 4,
    weight_streaming: bool = False,
    tdm_physical: Optional[int] = None,
    virtualization_overhead: float = 0.0,
) -> PipelineSkeleton:
    """Build the contention-independent skeleton of a pipeline run:
    O(layers + flows x path length), paid once per placement."""
    n = len(cores)
    layer_core = partition_layers(graph, n,
                                  cost=lambda l: layer_compute_cycles(l, hw))
    core_of_stage = list(cores)
    comp = [0] * n
    wbytes = [0] * n
    for i, layer in enumerate(graph.layers):
        comp[layer_core[i]] += layer_compute_cycles(layer, hw)
        wbytes[layer_core[i]] += layer.weight_bytes
    flows = _stage_flows(graph, layer_core, core_of_stage, owner)
    return PipelineSkeleton(
        graph=graph, topo=topo, hw=hw, comm=comm, owner=owner,
        translation=translation, tlb_entries=tlb_entries,
        weight_streaming=weight_streaming, tdm_physical=tdm_physical,
        virtualization_overhead=virtualization_overhead, n=n,
        core_of_stage=core_of_stage, comp=comp, wbytes=wbytes, flows=flows,
        paths=flow_paths(topo, flows))


def finish_pipeline(
    sk: PipelineSkeleton,
    *,
    external_flows: Sequence[Flow] = (),
    external_link_loads: Optional[Dict[Tuple[int, int], float]] = None,
    hbm_concurrency: int = 1,
) -> RunReport:
    """Recombine a pipeline skeleton with the contention/HBM context.

    O(own flows x path length + stages).  ``simulate_pipeline`` is exactly
    ``finish_pipeline(pipeline_skeleton(...))``, so a rescore through a
    cached skeleton is bit-identical to a full re-simulation by
    construction — there is one arithmetic path, not two.
    """
    graph, topo, hw, comm = sk.graph, sk.topo, sk.hw, sk.comm
    n, core_of_stage, flows = sk.n, sk.core_of_stage, sk.flows
    if external_link_loads is not None:
        factors = link_contention(sk.paths, flows,
                                  external_loads=external_link_loads)
    else:
        all_flows = list(flows) + list(external_flows)
        paths = flow_paths(topo, all_flows)
        factors = link_contention(paths, all_flows)

    comm_in: Dict[int, int] = {c: 0 for c in core_of_stage}
    comm_out: Dict[int, int] = {c: 0 for c in core_of_stage}
    for f, fac in zip(flows, factors[: len(flows)]):
        if comm == "uvm":
            bw = hw.hbm_bytes_per_cycle / max(hbm_concurrency, 1)
            cyc = int(2 * f.bytes_per_iter / bw) + hw.uvm_sync_cycles
        else:
            cyc = noc_transfer_cycles(topo, f, hw, contention=fac)
        comm_out[f.src] = comm_out.get(f.src, 0) + cyc
        comm_in[f.dst] = comm_in.get(f.dst, 0) + cyc

    stages: List[StageReport] = []
    for s in range(n):
        c = core_of_stage[s]
        dma = 0
        if sk.weight_streaming and sk.wbytes[s] > 0:
            r = simulate_weight_dma(sk.wbytes[s], hw,
                                    translation=sk.translation,
                                    tlb_entries=sk.tlb_entries,
                                    bw_share=1.0 / (n * hbm_concurrency))
            dma = r.total_cycles
        stages.append(StageReport(core=c, compute_cycles=sk.comp[s],
                                  comm_cycles=comm_in[c] + comm_out[c],
                                  dma_cycles=dma))

    if comm == "uvm":
        per_stage = [st.compute_cycles + st.comm_cycles + st.dma_cycles
                     for st in stages]
    else:
        # dataflow comm overlaps with compute (§6.2.3)
        per_stage = [max(st.compute_cycles, st.comm_cycles) + st.dma_cycles
                     for st in stages]
    if sk.tdm_physical is not None and sk.tdm_physical < n:
        loads = tdm_pack(per_stage, sk.tdm_physical)
        interval = max(loads) + hw.tdm_switch_cycles
    else:
        interval = max(per_stage) if per_stage else 1
    interval = int(interval * (1.0 + sk.virtualization_overhead))
    latency = sum(per_stage)

    warmup = math.ceil(graph.total_weight_bytes /
                       (hw.hbm_bytes_per_cycle / max(hbm_concurrency, 1)))
    ideal = sum(sk.comp) / max(n, 1)
    bubble = 1.0 - (ideal / interval) if interval else 0.0
    return RunReport(workload=graph.name, mode=f"pipeline-{comm}",
                     interval_cycles=max(interval, 1), latency_cycles=latency,
                     warmup_cycles=warmup, stages=stages,
                     fps=hw.freq_hz / max(interval, 1),
                     bubble_fraction=max(0.0, min(1.0, bubble)))


def simulate_pipeline(
    graph: WorkloadGraph,
    cores: Sequence[int],                # physical core ids, pipeline order
    topo: Topology,
    hw: HWConfig,
    *,
    comm: str = "dataflow",              # dataflow | uvm
    owner: int = 1,
    translation: str = "range",
    tlb_entries: int = 4,
    weight_streaming: bool = False,
    external_flows: Sequence[Flow] = (),
    external_link_loads: Optional[Dict[Tuple[int, int], float]] = None,
    hbm_concurrency: int = 1,            # concurrent HBM clients (UVM contention)
    tdm_physical: Optional[int] = None,  # MIG: physical cores < virtual cores
    virtualization_overhead: float = 0.0,
) -> RunReport:
    """Layer-pipelined execution (CNN style; Figs. 16/18).

    Cross-tenant NoC interference enters either as ``external_flows`` (the
    co-residents' flow list, re-pathed here: O(total flows)) or as
    ``external_link_loads`` (their pre-aggregated per-directed-link loads
    from :func:`flow_link_loads`: O(own flows) — the scheduler's ledger
    path).  The two are bit-identical because link loads are exact integer
    sums; external flows only ever influence the result through the loads
    on this tenant's own links.

    Implemented as :func:`pipeline_skeleton` + :func:`finish_pipeline`, so
    the scheduler's split-RunReport rescoring (skeleton cached per
    placement) shares this exact arithmetic path.
    """
    sk = pipeline_skeleton(
        graph, cores, topo, hw, comm=comm, owner=owner,
        translation=translation, tlb_entries=tlb_entries,
        weight_streaming=weight_streaming, tdm_physical=tdm_physical,
        virtualization_overhead=virtualization_overhead)
    return finish_pipeline(sk, external_flows=external_flows,
                           external_link_loads=external_link_loads,
                           hbm_concurrency=hbm_concurrency)


@dataclasses.dataclass
class TensorSkeleton:
    """The placement-dependent half of :func:`simulate_tensor_parallel`:
    total compute, ring geometry (flows + paths + mean hops) and the
    reduced layers' output sizes.  Independent of co-tenant loads and
    ``hbm_concurrency`` — see :class:`PipelineSkeleton`."""
    graph: WorkloadGraph
    topo: Topology
    hw: HWConfig
    comm: str
    owner: int
    tdm_physical: Optional[int]
    virtualization_overhead: float
    overlap: float
    n: int
    comp: int                           # total compute cycles, all layers
    hops: float                         # avg pairwise hops of the placement
    ring: List[Flow]                    # ring all-reduce flows
    ring_paths: List[List[int]]         # DOR path of each ring flow
    reduce_out_bytes: List[int]         # out_bytes of each reduced layer

    @property
    def noc_flows(self) -> List[Flow]:
        """The flows this tenant injects (what co-residents see)."""
        return self.ring


def tensor_skeleton(
    graph: WorkloadGraph,
    cores: Sequence[int],
    topo: Topology,
    hw: HWConfig,
    *,
    comm: str = "dataflow",
    owner: int = 1,
    tdm_physical: Optional[int] = None,
    virtualization_overhead: float = 0.0,
    overlap: float = 0.7,
) -> TensorSkeleton:
    """Build the contention-independent skeleton of a tensor-parallel run:
    O(layers + k^2), paid once per placement (the per-layer compute sum and
    the all-pairs hop count are the expensive terms a rescore skips)."""
    n = len(cores)
    comp = sum(layer_compute_cycles(l, hw, cores=n) for l in graph.layers)
    ring = _ring_flows(graph, cores, owner)
    return TensorSkeleton(
        graph=graph, topo=topo, hw=hw, comm=comm, owner=owner,
        tdm_physical=tdm_physical,
        virtualization_overhead=virtualization_overhead, overlap=overlap,
        n=n, comp=comp, hops=avg_pairwise_hops(topo, cores), ring=ring,
        ring_paths=flow_paths(topo, ring),
        reduce_out_bytes=[l.out_bytes for l in _reduce_layers(graph)])


def finish_tensor(
    sk: TensorSkeleton,
    *,
    external_flows: Sequence[Flow] = (),
    external_link_loads: Optional[Dict[Tuple[int, int], float]] = None,
    hbm_concurrency: int = 1,
) -> RunReport:
    """Recombine a tensor skeleton with the contention/HBM context:
    O(ring flows x path length + reduced layers).  One arithmetic path
    with :func:`simulate_tensor_parallel` — see :func:`finish_pipeline`.
    """
    graph, topo, hw, comm = sk.graph, sk.topo, sk.hw, sk.comm
    n, comp, hops = sk.n, sk.comp, sk.hops

    # cross-tenant contention on the ring links
    contention = 1.0
    if comm != "uvm" and (external_flows or external_link_loads is not None):
        ring = sk.ring
        if ring:
            if external_link_loads is not None:
                factors = link_contention(
                    sk.ring_paths, ring,
                    external_loads=external_link_loads)
            else:
                all_flows = ring + list(external_flows)
                factors = link_contention(flow_paths(topo, all_flows),
                                          all_flows)
            contention = sum(factors[: len(ring)]) / len(ring)

    ar_cycles = 0
    for out_bytes in sk.reduce_out_bytes:
        vol = 2 * out_bytes * (n - 1) / max(n, 1)  # ring all-reduce volume
        if comm == "uvm":
            bw = hw.hbm_bytes_per_cycle / max(hbm_concurrency, 1)
            # every core writes its partial and reads the sum: n writes + n
            # reads of the shard, serialized on shared HBM + sync barrier
            ar_cycles += int(2 * out_bytes * n / bw) + hw.uvm_sync_cycles
        else:
            # ring steps between logically-adjacent, physically-distant cores
            # occupy `hops` links each -> serialization scales with avg hops
            ser = vol / hw.noc_link_bytes_per_cycle * max(hops, 1.0) * \
                contention
            ar_cycles += int(ser + 2 * (n - 1) * hops * hw.noc_hop_cycles)

    if sk.tdm_physical is not None and sk.tdm_physical < n:
        # ceil(n/P) tensor slices run serially on the busiest physical core,
        # and co-located slices also serialize their NoC injections
        slices = -(-n // sk.tdm_physical)
        comp = comp * slices + hw.tdm_switch_cycles
        ar_cycles *= slices
    if comm == "uvm":
        interval = comp + ar_cycles
    else:
        exposed = int(ar_cycles * (1.0 - sk.overlap))
        interval = comp + exposed
    interval = int(interval * (1.0 + sk.virtualization_overhead))

    warmup = math.ceil(graph.total_weight_bytes /
                       (hw.hbm_bytes_per_cycle / max(hbm_concurrency, 1)))
    bubble = 1.0 - comp / max(interval, 1)
    return RunReport(workload=graph.name, mode=f"tensor-{comm}",
                     interval_cycles=max(interval, 1),
                     latency_cycles=max(interval, 1),
                     warmup_cycles=warmup, stages=[],
                     fps=hw.freq_hz / max(interval, 1),
                     bubble_fraction=max(0.0, min(1.0, bubble)))


def simulate_tensor_parallel(
    graph: WorkloadGraph,
    cores: Sequence[int],
    topo: Topology,
    hw: HWConfig,
    *,
    comm: str = "dataflow",
    owner: int = 1,
    hbm_concurrency: int = 1,
    tdm_physical: Optional[int] = None,
    virtualization_overhead: float = 0.0,
    overlap: float = 0.7,          # fraction of NoC all-reduce hidden by compute
    external_flows: Sequence[Flow] = (),
    external_link_loads: Optional[Dict[Tuple[int, int], float]] = None,
) -> RunReport:
    """Tensor-partitioned execution (transformers; §6.3's LLM workloads).

    Every layer's weights are split across all cores; each layer ends with an
    all-reduce of its output activation.  Under ``dataflow`` the all-reduce
    runs ring-style on the NoC and mostly overlaps with compute; under
    ``uvm`` each reduction bounces through shared global memory and
    serializes (§6.3.1's contention argument).  ``external_flows`` — other
    tenants' NoC traffic — slow the ring by the contention on its links;
    ``external_link_loads`` is the pre-aggregated equivalent (see
    :func:`flow_link_loads`).  Callers must pass ``external_link_loads``
    (even an empty dict) exactly when they would have passed a non-empty
    ``external_flows`` list: the contention term — which includes the
    ring's *self*-contention — is only computed when co-tenant traffic
    exists, so the two paths stay bit-identical.

    Implemented as :func:`tensor_skeleton` + :func:`finish_tensor` — the
    scheduler's split-RunReport rescoring shares this arithmetic path.
    """
    sk = tensor_skeleton(
        graph, cores, topo, hw, comm=comm, owner=owner,
        tdm_physical=tdm_physical,
        virtualization_overhead=virtualization_overhead, overlap=overlap)
    return finish_tensor(sk, external_flows=external_flows,
                         external_link_loads=external_link_loads,
                         hbm_concurrency=hbm_concurrency)


def simulate(graph: WorkloadGraph, cores: Sequence[int], topo: Topology,
             hw: HWConfig, **kw) -> RunReport:
    """Dispatch on workload style: transformers -> tensor-parallel, CNNs ->
    pipeline (how the paper's DCRA setup runs them)."""
    if is_tensor_parallel(graph):
        kw.pop("weight_streaming", None)
        kw.pop("translation", None)
        kw.pop("tlb_entries", None)
        return simulate_tensor_parallel(graph, cores, topo, hw, **kw)
    return simulate_pipeline(graph, cores, topo, hw, **kw)


def make_skeleton(graph: WorkloadGraph, cores: Sequence[int], topo: Topology,
                  hw: HWConfig, **kw):
    """Placement-dependent half of :func:`simulate`, dispatched like it
    (transformers -> :func:`tensor_skeleton`, CNNs ->
    :func:`pipeline_skeleton`).  Pair with :func:`rescore_contention`."""
    if is_tensor_parallel(graph):
        kw.pop("weight_streaming", None)
        kw.pop("translation", None)
        kw.pop("tlb_entries", None)
        return tensor_skeleton(graph, cores, topo, hw, **kw)
    return pipeline_skeleton(graph, cores, topo, hw, **kw)


def rescore_contention(sk, *, external_flows: Sequence[Flow] = (),
                       external_link_loads: Optional[
                           Dict[Tuple[int, int], float]] = None,
                       hbm_concurrency: int = 1) -> RunReport:
    """Recombine a cached skeleton with fresh contention/HBM context.

    ``rescore_contention(make_skeleton(g, c, t, hw, **pkw), **ckw)`` is
    bit-identical to ``simulate(g, c, t, hw, **pkw, **ckw)`` — both are the
    same two function calls.  The split exists so the scheduler can keep
    the skeleton across scoring passes whose placement didn't change and
    pay only the O(own flows + reduced layers) recombination.
    """
    finish = (finish_tensor if isinstance(sk, TensorSkeleton)
              else finish_pipeline)
    return finish(sk, external_flows=external_flows,
                  external_link_loads=external_link_loads,
                  hbm_concurrency=hbm_concurrency)


# ---------------------------------------------------------------------------
# serving phase model (prefill / decode) over the tensor skeleton
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PhaseModel:
    """Phase-aware serving throughput for one LLM tenant.

    Derived from the tenant's cached :class:`TensorSkeleton` and its
    current contention-aware :class:`RunReport` (the scheduler's epoch
    score), so cross-tenant NoC interference and HBM concurrency reach the
    request level through the same ledger-maintained context that scores
    epochs — nothing is hand-set:

    * **prefill** is a compute-bound full forward pass: the proxy graph is
      one iteration over ``proxy_seq`` tokens, so prefill throughput is
      ``report.fps x proxy_seq`` tokens/s (contention, TDM slicing and UVM
      serialization all arrive via the report's interval);
    * **decode** is bandwidth-bound: one batched step streams the weight
      shards that don't fit in aggregate scratchpad plus every active
      request's KV from HBM (shared across ``decode_hbm_clients``
      streamers), pays the per-token ring all-reduce scaled by the
      tenant's current NoC contention ratio, and the KV RTT re-walk
      stall (``n_ranges x rtt_entry_read_cycles``, Pattern 2).
    """
    prefill_tokens_per_s: float
    # weights stream + slice-serialized all-reduce + TDM swap; the HBM
    # streaming terms are charged once per step (a TDM slice streams only
    # its own shard set and the batch KV is read once per token), only
    # the per-slice all-reduce serializes — folded in at derive time
    step_base_cycles: float
    hbm_bytes_per_cycle: float         # this tenant's decode-phase HBM share
    stall_cycles_per_range: int
    freq_hz: float
    slices: int = 1                    # TDM: virtual slices run serially
    weights_resident: bool = True

    def decode_step_s(self, active_kv_bytes: float, n_ranges: int) -> float:
        """Seconds for one continuous-batching decode step (one token for
        every active request) given the batch's live KV bytes and total
        RTT range count."""
        cyc = (self.step_base_cycles
               + active_kv_bytes / self.hbm_bytes_per_cycle
               + n_ranges * self.stall_cycles_per_range)
        return cyc / self.freq_hz


#: fraction of per-tile scratchpad available to hold resident weight
#: shards during decode (the rest stages activations and KV tiles) — when
#: the tensor-partitioned shards fit, decode stops streaming weights from
#: HBM, which is the structural reason growing a vNPU speeds decode.
WEIGHTS_SRAM_FRACTION = 0.5


def weights_resident(weight_bytes: int, physical_tiles: int,
                     hw: HWConfig) -> bool:
    """Do tensor-partitioned weight shards fit in the aggregate scratchpad
    of ``physical_tiles`` tiles?  The one formula both the phase model and
    the scheduler's HBM-streamer census use — they must agree on who is
    streaming or decode bandwidth shares are computed against the wrong
    client count."""
    return weight_bytes <= \
        hw.scratchpad_per_tile * physical_tiles * WEIGHTS_SRAM_FRACTION


def derive_phase_model(sk: TensorSkeleton, report: RunReport, *,
                       proxy_seq: int,
                       decode_hbm_clients: int = 1,
                       hbm_share: Optional[float] = None,
                       isolated_interval: Optional[int] = None) -> PhaseModel:
    """Build the serving :class:`PhaseModel` from one tenant's skeleton and
    its current (contention-aware) report.  O(reduced layers).

    The decode HBM port is shared across actively-streaming residents.
    ``hbm_share`` is this tenant's fraction of the port bandwidth — the
    scheduler weights it by each resident's actual decode traffic
    (streamed weight bytes + KV arena bytes), which is how a saturated
    FR-FCFS memory controller actually divides service: a 7B shard set
    issues proportionally more requests than an embedding-sized
    co-resident and gets proportionally more bandwidth (the legacy
    equal-split census throttled it as if both drew the same).  The
    weighted share is charged to the sustained decode streams (weight
    shards and batch KV reads); the UVM activation bounce — short,
    latency-bound synchronization round-trips that cannot batch into
    long row hits — stays at the equal-split ``1/decode_hbm_clients``
    service a fair controller gives short transfers.  The scheduler
    passes a *conserving* share (a convex blend of the equal split and
    the pure demand fraction — ``sched.cluster.HBM_BYTE_WEIGHT``):
    shares sum to one over the busy clients, so byte-weighting
    redistributes port bandwidth toward heavy streamers instead of
    minting extra service, and a small co-resident keeps a guaranteed
    round-robin slot rather than starving behind a 7B shard stream.
    ``decode_hbm_clients`` is the legacy equal-split (share = 1/clients
    applied to every term) when ``hbm_share`` is None.

    The NoC contention ratio is ``report.interval / isolated interval`` —
    both recombinations of the same cached skeleton, so the ratio is
    exactly the slowdown the ledger's aggregated co-tenant loads induce.
    ``isolated_interval`` is that denominator; it is a pure function of
    the skeleton, so callers that rebuild phase models per scoring pass
    (the scheduler) cache it per placement and pass it in.
    """
    if not isinstance(sk, TensorSkeleton):
        raise TypeError("serving phase model requires a tensor-parallel "
                        f"skeleton, got {type(sk).__name__}")
    hw, graph, n = sk.hw, sk.graph, sk.n
    physical = sk.tdm_physical if (sk.tdm_physical and sk.tdm_physical < n) \
        else n
    slices = -(-n // physical)
    eq_bw = hw.hbm_bytes_per_cycle / max(decode_hbm_clients, 1)
    if hbm_share is not None:
        bw = hw.hbm_bytes_per_cycle * min(max(hbm_share, 1e-9), 1.0)
    else:
        bw = eq_bw
    kv_bw = bw

    resident = weights_resident(graph.total_weight_bytes, physical, hw)
    # weights stream once per step whatever the slicing (each TDM slice
    # streams only its own shard set, serialized back to the whole set)
    base = 0.0 if resident else graph.total_weight_bytes / bw

    iso = (isolated_interval if isolated_interval is not None
           else finish_tensor(sk).interval_cycles)
    contention = max(1.0, report.interval_cycles / max(iso, 1))
    hops = max(sk.hops, 1.0)
    comm = 0.0
    for out_bytes in sk.reduce_out_bytes:
        tok_bytes = out_bytes / max(proxy_seq, 1)   # one token's activation
        if sk.comm == "uvm":
            # bounce through global memory: n writes + n reads + barrier
            # (fair-share service — too short to batch into row hits)
            comm += 2 * tok_bytes * n / eq_bw + hw.uvm_sync_cycles
        else:
            vol = 2 * tok_bytes * (n - 1) / max(n, 1)
            comm += (vol / hw.noc_link_bytes_per_cycle * hops * contention
                     + 2 * (n - 1) * hops * hw.noc_hop_cycles)
    # only the all-reduce serializes per TDM slice (finish_tensor's
    # ``ar_cycles *= slices`` convention), plus one context swap per step
    base += comm * slices
    if slices > 1:
        base += hw.tdm_switch_cycles

    return PhaseModel(
        prefill_tokens_per_s=max(report.fps * proxy_seq, 1e-9),
        step_base_cycles=base,
        hbm_bytes_per_cycle=kv_bw,
        stall_cycles_per_range=hw.rtt_entry_read_cycles,
        freq_hz=hw.freq_hz,
        slices=slices,
        weights_resident=resident)


# ---------------------------------------------------------------------------
# broadcast micro-model (Fig. 13)
# ---------------------------------------------------------------------------

NOC_PORTS = 4  # a 2D-mesh router drives 4 outgoing links in parallel


def broadcast_cycles_vrouter(bytes_out: int, n_receivers: int, avg_hops: float,
                             hw: HWConfig) -> int:
    """Multicast over the NoC: the sender's router replicates the stream on
    up to NOC_PORTS outgoing links in parallel; NoC handshake for sync."""
    ser = bytes_out / hw.noc_link_bytes_per_cycle
    waves = -(-n_receivers // NOC_PORTS)
    return int(waves * ser + avg_hops * hw.noc_hop_cycles + 64)


def broadcast_cycles_memsync(bytes_out: int, n_receivers: int,
                             hw: HWConfig, hbm_concurrency: int = 1) -> int:
    """Write once to HBM, each receiver polls a flag then reads its copy —
    all serialized on the shared HBM port (bandwidth split across tenants)."""
    bw = hw.hbm_bytes_per_cycle / max(hbm_concurrency, 1)
    write = bytes_out / bw
    reads = n_receivers * bytes_out / bw
    return int(write + reads + (1 + n_receivers) * hw.uvm_sync_cycles)
