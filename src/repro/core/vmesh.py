"""JAX integration: virtual NPUs as `jax.sharding.Mesh` submeshes.

This is where the paper's routing table becomes executable: the assignment
``virtual core id -> physical core id`` chosen by the topology mapper is
materialized as the *device array layout* of a JAX Mesh.  Logical mesh
coordinates (what pjit/shard_map see) are the virtual topology; the physical
devices behind them are whatever the hypervisor allocated — exactly the
vRouter redirect of §4.1, realized at the SPMD-partitioner level.

Elastic remap (device failure) re-runs the similar-topology mapping over the
survivors and returns a new Mesh; the training runtime then re-shards its
checkpoint onto it (see train/loop.py and examples/elastic_failover.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

try:  # jax is required at runtime but keep import errors readable
    import jax
    from jax.sharding import Mesh
except Exception as e:  # pragma: no cover
    raise ImportError("repro.core.vmesh requires jax") from e

from .hypervisor import AllocationError, Hypervisor, VirtualNPU, VNPURequest
from .topology import Topology, mesh_2d


@dataclasses.dataclass
class DeviceTopology:
    """Binding between an NPU topology and a set of JAX devices.

    ``node_to_device[i]`` is the JAX device sitting at physical core id
    ``i``.  For a TPU pod this is the ICI coordinate grid; on the CPU
    host-platform backend it's simply an enumeration.
    """

    topo: Topology
    node_to_device: Dict[int, "jax.Device"]

    @staticmethod
    def from_devices(devices: Sequence["jax.Device"],
                     mesh_shape: Optional[Tuple[int, int]] = None,
                     torus: bool = False) -> "DeviceTopology":
        n = len(devices)
        if mesh_shape is None:
            r = int(np.floor(np.sqrt(n)))
            while n % r:
                r -= 1
            mesh_shape = (r, n // r)
        if mesh_shape[0] * mesh_shape[1] != n:
            raise ValueError(f"mesh {mesh_shape} != {n} devices")
        topo = mesh_2d(*mesh_shape, torus=torus, name="pod")
        return DeviceTopology(topo, {i: d for i, d in enumerate(devices)})

    def device_for(self, node: int) -> "jax.Device":
        return self.node_to_device[node]


class VirtualMeshError(RuntimeError):
    pass


def virtual_mesh(vnpu: VirtualNPU, dt: DeviceTopology,
                 axis_names: Tuple[str, ...] = ("data", "model")) -> Mesh:
    """Materialize a virtual NPU as a JAX Mesh.

    The virtual topology must be a rectangular mesh (the common case for
    SPMD programs); its row-major node order defines the logical coordinate
    grid, and the routing-table assignment places physical devices.
    """
    vt = vnpu.virtual_topology()
    shape = vt.is_rect_mesh()
    if shape is None:
        # 1-D virtual topologies (lines/rings) are still usable as a flat mesh
        if len(axis_names) != 1:
            raise VirtualMeshError(
                "non-rectangular virtual topology needs a single axis")
        order = vt.nodes()
        devs = np.array([dt.device_for(vnpu.assignment[v]) for v in order])
        return Mesh(devs, axis_names)
    r, c = shape
    if len(axis_names) != 2:
        raise VirtualMeshError(f"2D virtual topology needs 2 axis names")
    # row-major over virtual coords
    by_coord = {vt.coords[n]: n for n in vt.nodes()}
    rows = sorted({rc[0] for rc in by_coord})
    cols = sorted({rc[1] for rc in by_coord})
    grid = np.empty((r, c), dtype=object)
    for i, rr in enumerate(rows):
        for j, cc in enumerate(cols):
            vnode = by_coord[(rr, cc)]
            grid[i, j] = dt.device_for(vnpu.assignment[vnode])
    return Mesh(grid, axis_names)


@dataclasses.dataclass
class TenantMesh:
    """A tenant's full handle: hypervisor object + JAX mesh."""
    vnpu: VirtualNPU
    mesh: Mesh
    dt: DeviceTopology


def allocate_tenant(hyp: Hypervisor, dt: DeviceTopology,
                    topology: Topology,
                    axis_names: Tuple[str, ...] = ("data", "model"),
                    node_match=None, edge_match=None,
                    **req_kwargs) -> TenantMesh:
    """One-call tenant setup: topology mapping -> routing table -> JAX mesh.

    The mapping runs through the hypervisor's MappingEngine; pass
    ``mapper="exact"|"hybrid"|"bipartite"|"rect"`` (a ``VNPURequest`` field)
    to pick a speed/accuracy point, and ``node_match``/``edge_match`` for
    heterogeneous or critical-edge-aware placement.
    """
    req = VNPURequest(topology=topology, **req_kwargs)
    vnpu = hyp.create_vnpu(req, node_match=node_match, edge_match=edge_match)
    mesh = virtual_mesh(vnpu, dt, axis_names)
    return TenantMesh(vnpu=vnpu, mesh=mesh, dt=dt)


def elastic_remap(hyp: Hypervisor, dt: DeviceTopology, tenant: TenantMesh,
                  failed_nodes: Iterable[int],
                  axis_names: Optional[Tuple[str, ...]] = None) -> TenantMesh:
    """Failure path: re-run the similar-topology mapping excluding the failed
    cores (which the hypervisor quarantines — they never rejoin the
    allocatable pool); returns a fresh TenantMesh on the surviving devices.

    This is the paper's allocator doing double duty as the fault-tolerance
    mechanism — the 'closest legal submesh' is exactly what a 1000-node
    deployment needs when a tray drops.
    """
    names = axis_names or tenant.mesh.axis_names
    vnpu = hyp.remap_vnpu(tenant.vnpu.vmid, failed_nodes)
    mesh = virtual_mesh(vnpu, dt, tuple(names))
    return TenantMesh(vnpu=vnpu, mesh=mesh, dt=dt)


def device_permutation(old: TenantMesh, new: TenantMesh) -> Dict[int, int]:
    """old physical node -> new physical node per virtual coordinate; used by
    the checkpoint layer to compute the resharding plan after a remap."""
    out = {}
    for v, p_old in old.vnpu.assignment.items():
        out[p_old] = new.vnpu.assignment[v]
    return out
