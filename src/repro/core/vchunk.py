"""vChunk: range-based NPU memory virtualization (§4.2).

Components faithful to the paper:

* ``RTTEntry`` — (vaddr 48b, paddr 48b, size 32b, perms, last_v). 144 bits
  per hardware range-TLB entry (the paper's figure for 4-entry range TLBs).
* ``RangeTranslationTable`` — hypervisor-managed, sorted by virtual address
  (§5.2), one entry per buddy block.
* ``RangeTLB`` — per-core 4-entry TLB with the two pattern optimizations:
  - **Pattern-2** (monotonic within an iteration): ``RTT_CUR`` cursor; on a
    miss the walker scans forward from the cursor, wrapping at RTT_END.
  - **Pattern-3** (iteration-periodic): ``last_v`` per entry records the
    index of the *next* entry used in the previous iteration, letting the
    walker jump straight back to the iteration start instead of scanning.
* ``PageTable``/``PageTLB`` — classical fixed-page baseline (Fig. 14).
* ``AccessCounter`` — per-vNPU HBM bandwidth QoS (end of §4.2).

All structures count their translation work (hits / misses / walk steps) so
the simulator can convert them into stall cycles.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, List, Optional, Tuple

RTT_ENTRY_BITS = 144  # 48 + 48 + 32 + perms/last_v packing — paper §6.2.4
PAGE_ENTRY_BITS = 64


class TranslationFault(Exception):
    pass


@dataclasses.dataclass
class RTTEntry:
    vaddr: int
    paddr: int
    size: int
    perms: str = "rw"
    last_v: Optional[int] = None  # index of next entry used in prev iteration

    def contains(self, va: int) -> bool:
        return self.vaddr <= va < self.vaddr + self.size

    def translate(self, va: int) -> int:
        return self.paddr + (va - self.vaddr)


class RangeTranslationTable:
    """Sorted-by-vaddr table of ranges for one virtual NPU."""

    def __init__(self, entries: Optional[List[RTTEntry]] = None):
        self.entries: List[RTTEntry] = []
        for e in entries or []:
            self.insert(e)

    def insert(self, entry: RTTEntry) -> None:
        if entry.size <= 0:
            raise ValueError("range size must be positive")
        keys = [e.vaddr for e in self.entries]
        i = bisect.bisect_left(keys, entry.vaddr)
        # reject overlap with neighbours
        if i > 0:
            prev = self.entries[i - 1]
            if prev.vaddr + prev.size > entry.vaddr:
                raise ValueError("overlapping virtual ranges")
        if i < len(self.entries):
            nxt = self.entries[i]
            if entry.vaddr + entry.size > nxt.vaddr:
                raise ValueError("overlapping virtual ranges")
        self.entries.insert(i, entry)

    def __len__(self) -> int:
        return len(self.entries)

    def find_index(self, va: int) -> int:
        keys = [e.vaddr for e in self.entries]
        i = bisect.bisect_right(keys, va) - 1
        if i >= 0 and self.entries[i].contains(va):
            return i
        raise TranslationFault(f"no range maps {va:#x}")

    def translate(self, va: int) -> int:
        return self.entries[self.find_index(va)].translate(va)

    def storage_bits(self) -> int:
        return RTT_ENTRY_BITS * len(self.entries)


@dataclasses.dataclass
class TLBStats:
    hits: int = 0
    misses: int = 0
    walk_steps: int = 0  # RTT entries touched during misses
    last_v_hits: int = 0  # misses resolved directly via last_v

    def reset(self) -> None:
        self.hits = self.misses = self.walk_steps = self.last_v_hits = 0


class RangeTLB:
    """Per-core hardware range TLB (default 4 entries, 144b each).

    Miss flow (paper §4.2): check ``last_v`` of the entry that missed the
    cursor position; if absent/wrong, scan forward from ``RTT_CUR`` wrapping
    at RTT_END back to RTT_BASE; finally update ``last_v`` and ``RTT_CUR``.
    """

    def __init__(self, rtt: RangeTranslationTable, n_entries: int = 4):
        self.rtt = rtt
        self.n = n_entries
        self.slots: List[int] = []  # indices into rtt.entries, LRU order (front = LRU)
        self.cur: int = 0  # RTT_CUR
        self.stats = TLBStats()

    def _fill(self, idx: int) -> None:
        if idx in self.slots:
            self.slots.remove(idx)
        self.slots.append(idx)
        if len(self.slots) > self.n:
            self.slots.pop(0)

    def translate(self, va: int) -> int:
        # TLB hit?
        for idx in reversed(self.slots):
            e = self.rtt.entries[idx]
            if e.contains(va):
                self.stats.hits += 1
                self._fill(idx)  # refresh LRU
                return e.translate(va)
        # miss -> walk
        self.stats.misses += 1
        n = len(self.rtt.entries)
        if n == 0:
            raise TranslationFault("empty RTT")
        # 1) try last_v recorded on the current entry (Pattern-3 jump-back)
        cur_entry = self.rtt.entries[self.cur] if self.cur < n else None
        if cur_entry is not None and cur_entry.last_v is not None:
            cand = self.rtt.entries[cur_entry.last_v % n]
            self.stats.walk_steps += 1
            if cand.contains(va):
                self.stats.last_v_hits += 1
                idx = cur_entry.last_v % n
                cur_entry.last_v = idx
                self.cur = idx
                self._fill(idx)
                return cand.translate(va)
        # 2) scan forward from RTT_CUR, wrap at RTT_END -> RTT_BASE (Pattern-2)
        found = None
        for step in range(n):
            idx = (self.cur + step) % n
            self.stats.walk_steps += 1
            if self.rtt.entries[idx].contains(va):
                found = idx
                break
        if found is None:
            raise TranslationFault(f"no range maps {va:#x}")
        if cur_entry is not None:
            cur_entry.last_v = found  # learn the jump for the next iteration
        self.cur = found
        self._fill(found)
        return self.rtt.entries[found].translate(va)


# ---------------------------------------------------------------------------
# Page-based baseline (what CPUs/GPUs do; Fig. 14's comparison points)
# ---------------------------------------------------------------------------

class PageTable:
    def __init__(self, page_size: int = 4096):
        if page_size & (page_size - 1):
            raise ValueError("page size must be power of two")
        self.page_size = page_size
        self.map: Dict[int, int] = {}  # vpn -> ppn

    def map_range(self, vaddr: int, paddr: int, size: int) -> None:
        ps = self.page_size
        if vaddr % ps or paddr % ps:
            raise ValueError("unaligned mapping")
        for off in range(0, size, ps):
            self.map[(vaddr + off) // ps] = (paddr + off) // ps

    def translate(self, va: int) -> int:
        vpn, off = divmod(va, self.page_size)
        try:
            return self.map[vpn] * self.page_size + off
        except KeyError:
            raise TranslationFault(f"unmapped page for {va:#x}") from None

    def storage_bits(self) -> int:
        return PAGE_ENTRY_BITS * len(self.map)


class PageTLB:
    def __init__(self, table: PageTable, n_entries: int = 4):
        self.table = table
        self.n = n_entries
        self.slots: List[int] = []  # vpns, LRU order
        self.stats = TLBStats()

    def translate(self, va: int) -> int:
        vpn = va // self.table.page_size
        if vpn in self.slots:
            self.stats.hits += 1
            self.slots.remove(vpn)
            self.slots.append(vpn)
        else:
            self.stats.misses += 1
            # page walk cost is modeled by the simulator per miss
            self.table.translate(va)  # may fault
            self.slots.append(vpn)
            if len(self.slots) > self.n:
                self.slots.pop(0)
        return self.table.translate(va)


# ---------------------------------------------------------------------------
# Bandwidth QoS
# ---------------------------------------------------------------------------

class AccessCounter:
    """Track per-vNPU HBM bytes within a time window; the NPU controller caps
    bandwidth per tenant (§4.2 last paragraph).
    """

    def __init__(self, max_bytes_per_window: Optional[int], window_cycles: int = 10_000):
        self.max = max_bytes_per_window
        self.window = window_cycles
        self.window_start = 0
        self.count = 0
        self.throttled = 0

    def record(self, now_cycle: int, nbytes: int) -> bool:
        """Record an access; returns True if allowed, False if throttled."""
        if now_cycle - self.window_start >= self.window:
            self.window_start = now_cycle - (now_cycle - self.window_start) % self.window
            self.count = 0
        if self.max is not None and self.count + nbytes > self.max:
            self.throttled += 1
            return False
        self.count += nbytes
        return True
