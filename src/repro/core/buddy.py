"""Buddy allocator for NPU global memory (HBM/DRAM).

§5.2: "the hypervisor utilizes the traditional buddy system for memory
allocation, and records address mappings in the range translation table.
Unlike the page table which needs to partition blocks ... into fixed-size
pages, vNPU maps an entire block directly into the RTT entry with the block
size."  Hence allocations here are whole power-of-two blocks that become
single RTT ranges.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple


class OutOfMemory(MemoryError):
    pass


def _next_pow2(n: int) -> int:
    if n <= 0:
        raise ValueError("allocation size must be positive")
    return 1 << (n - 1).bit_length()


class BuddyAllocator:
    def __init__(self, total_bytes: int, min_block: int = 1 << 20):
        if total_bytes & (total_bytes - 1):
            raise ValueError("total_bytes must be a power of two")
        if min_block & (min_block - 1):
            raise ValueError("min_block must be a power of two")
        self.total = total_bytes
        self.min_block = min_block
        # free lists per order; order 0 == min_block
        self.max_order = (total_bytes // min_block - 1).bit_length()
        self.free: Dict[int, List[int]] = {o: [] for o in range(self.max_order + 1)}
        self.free[self.max_order].append(0)
        self.allocated: Dict[int, int] = {}  # addr -> order

    def _order_for(self, size: int) -> int:
        size = max(_next_pow2(size), self.min_block)
        order = (size // self.min_block - 1).bit_length()
        if order > self.max_order:
            raise OutOfMemory(f"request {size} exceeds arena {self.total}")
        return order

    def block_size(self, order: int) -> int:
        return self.min_block << order

    def alloc(self, size: int) -> Tuple[int, int]:
        """Allocate >= size bytes; returns (addr, actual_block_size)."""
        order = self._order_for(size)
        o = order
        while o <= self.max_order and not self.free[o]:
            o += 1
        if o > self.max_order:
            raise OutOfMemory(f"no free block for {size} bytes")
        addr = self.free[o].pop()
        while o > order:  # split down
            o -= 1
            buddy = addr + self.block_size(o)
            self.free[o].append(buddy)
        self.allocated[addr] = order
        return addr, self.block_size(order)

    def free_block(self, addr: int) -> None:
        if addr not in self.allocated:
            raise ValueError(f"free of unallocated addr {addr:#x}")
        order = self.allocated.pop(addr)
        # coalesce with buddy while possible
        while order < self.max_order:
            buddy = addr ^ self.block_size(order)
            if buddy in self.free[order]:
                self.free[order].remove(buddy)
                addr = min(addr, buddy)
                order += 1
            else:
                break
        self.free[order].append(addr)

    def used_bytes(self) -> int:
        return sum(self.block_size(o) for o in self.allocated.values())

    def free_bytes(self) -> int:
        return self.total - self.used_bytes()

    def state_key(self) -> Tuple[Tuple[int, int], ...]:
        """Digest of the free-block *size multiset*: ((order, count), ...).

        Whether any sequence of ``alloc`` sizes can succeed is a function
        of this multiset alone (splitting is deterministic in sizes, and
        addresses never gate success), so two states with equal keys give
        identical success/failure for identical request sequences — what
        the scheduler's negative-probe memo compares.  Deliberately *not*
        an operation counter: a rolled-back allocation (the OOM path
        restores every block) returns to the same key, so repeated
        memory-infeasible probes memoize instead of thrashing.
        """
        return tuple((o, len(blocks)) for o, blocks in sorted(self.free.items())
                     if blocks)

    def check_invariants(self) -> None:
        """No overlaps, full coverage. Used by hypothesis property tests."""
        spans = []
        for addr, order in self.allocated.items():
            spans.append((addr, addr + self.block_size(order), "A"))
        for order, addrs in self.free.items():
            for addr in addrs:
                spans.append((addr, addr + self.block_size(order), "F"))
        spans.sort()
        pos = 0
        for lo, hi, _ in spans:
            if lo != pos:
                raise AssertionError(f"gap/overlap at {pos:#x} vs {lo:#x}")
            pos = hi
        if pos != self.total:
            raise AssertionError(f"arena not covered: {pos:#x} != {self.total:#x}")
