"""vNPU core: topology-aware virtualization for inter-core connected NPUs.

The paper's three techniques, plus the JAX-mesh integration:

* :mod:`repro.core.vrouter` / :mod:`repro.core.routing_table` — NPU route
  virtualization (instruction dispatch + NoC).
* :mod:`repro.core.vchunk` — range-based memory virtualization.
* :mod:`repro.core.mapping` — best-effort topology mapping (Algorithm 1,
  reference implementation).
* :mod:`repro.core.engine` — the MappingEngine: incremental free regions,
  cached minTopologyEditDistance, vectorized candidate scoring, pluggable
  mapper strategies.
* :mod:`repro.core.hypervisor` — vNPU lifecycle + MIG/UVM baselines.
* :mod:`repro.core.simulator` / :mod:`repro.core.workloads` — the DCRA-style
  performance model behind the paper-figure benchmarks.
* :mod:`repro.core.vmesh` — virtual NPUs as `jax.sharding.Mesh` submeshes.
"""
from .topology import Topology, mesh_2d, line, ring, enumerate_connected_subsets
from .routing_table import (DenseRoutingTable, CompactRoutingTable,
                            RoutingTableDirectory, make_routing_table,
                            RoutingError)
from .vrouter import (InstructionRouter, NoCRouter, dor_path, confined_path,
                      rt_config_cost)
from .vchunk import (RangeTranslationTable, RTTEntry, RangeTLB, PageTable,
                     PageTLB, AccessCounter, TranslationFault)
from .buddy import BuddyAllocator, OutOfMemory
from .mapping import (topology_edit_distance, min_topology_edit_distance,
                      straightforward_mapping, MappingResult,
                      default_node_match, default_edge_match,
                      mem_dist_node_match, critical_edge_match)
from .engine import (EngineStats, FreeRegions, MappingEngine,
                     component_signature)
from .baselines import (AllocationError, MIGPartition, MIGPartitioner,
                        UVMAllocator)
from .hypervisor import (Hypervisor, VNPURequest, VirtualNPU,
                         make_standard_hypervisor)
from .vmesh import (DeviceTopology, TenantMesh, virtual_mesh, allocate_tenant,
                    elastic_remap, device_permutation)

__all__ = [
    "Topology", "mesh_2d", "line", "ring", "enumerate_connected_subsets",
    "DenseRoutingTable", "CompactRoutingTable", "RoutingTableDirectory",
    "make_routing_table", "RoutingError",
    "InstructionRouter", "NoCRouter", "dor_path", "confined_path",
    "rt_config_cost",
    "RangeTranslationTable", "RTTEntry", "RangeTLB", "PageTable", "PageTLB",
    "AccessCounter", "TranslationFault",
    "BuddyAllocator", "OutOfMemory",
    "topology_edit_distance", "min_topology_edit_distance",
    "straightforward_mapping", "MappingResult",
    "MappingEngine", "EngineStats", "FreeRegions", "component_signature",
    "default_node_match", "default_edge_match", "mem_dist_node_match",
    "critical_edge_match",
    "Hypervisor", "VNPURequest", "VirtualNPU", "AllocationError",
    "MIGPartition", "MIGPartitioner", "UVMAllocator",
    "make_standard_hypervisor",
    "DeviceTopology", "TenantMesh", "virtual_mesh", "allocate_tenant",
    "elastic_remap", "device_permutation",
]
