"""Topology-mapping strategies for virtual-NPU core allocation (§4.3, Alg. 1).

Faithful pieces:

* topology edit distance (TED) with customizable ``node_match`` /
  ``edge_match`` penalty functions (heterogeneous nodes, critical edges);
* candidate enumeration over the free cores with the paper's three prunes —
  connectivity (R-3), isomorphism dedup, exact-match early exit (R-1 is
  enforced by construction: candidates have exactly the requested node
  count);
* ``minTopologyEditDistance`` — Algorithm 1, returning both the chosen
  physical node set *and* the virtual->physical node assignment (which is
  precisely the routing table the hypervisor must install).

Scale adaptation (documented in DESIGN.md): the paper enumerates
``COMB(remainN, k)`` on 36–48-core chips.  At pod scale (256–1024 cores)
exhaustive enumeration is astronomically large, so ``propose_candidates``
generates a bounded, high-quality candidate pool — exact rectangles, clipped
rectangles, and BFS-compact blobs — and falls back to full enumeration only
for small free regions.  TED computation is exact (branch & bound) for small
requests and the Riesen–Bunke bipartite approximation (paper's ref [60])
above that.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, FrozenSet, Iterable, List, Optional, Sequence, Set, Tuple

import numpy as np

from .topology import Topology, enumerate_connected_subsets

NodeMatch = Callable[[Dict, Dict], float]
EdgeMatch = Callable[[Optional[Dict], Optional[Dict]], float]

DEFAULT_NODE_COST = 1.0
DEFAULT_EDGE_COST = 1.0


def default_node_match(a: Dict, b: Dict) -> float:
    """Paper's NodeMatch: penalty if the node types (abbr) differ."""
    return DEFAULT_NODE_COST if a.get("abbr", "") != b.get("abbr", "") else 0.0


def default_edge_match(e_req: Optional[Dict], e_cand: Optional[Dict]) -> float:
    """Paper's EdgeMatch: an edge present in the request but absent in the
    candidate costs its importance (``cost`` attr, default 1); a spurious
    candidate edge costs the default insertion penalty.
    """
    if e_req is not None and e_cand is None:
        return float(e_req.get("cost", DEFAULT_EDGE_COST))
    if e_req is None and e_cand is not None:
        return float(e_cand.get("cost", DEFAULT_EDGE_COST))
    return 0.0


# ``match_id`` gives a match function a stable identity the MappingEngine's
# TED cache can key on (and a vectorizable form where one exists); ad-hoc
# callables without one are computed fresh on every request.
default_node_match.match_id = "node:default"
default_edge_match.match_id = "edge:default"


def mem_dist_node_match(weight: float = 0.5) -> NodeMatch:
    """Heterogeneous node matching: extra penalty proportional to the
    difference in distance-to-memory-interface (§4.3 'Heterogeneous topology
    mapping').
    """

    def match(a: Dict, b: Dict) -> float:
        c = default_node_match(a, b)
        c += weight * abs(a.get("mem_dist", 0) - b.get("mem_dist", 0))
        return c

    match.match_id = f"node:mem_dist:{float(weight)!r}"
    # vectorizable form for the engine's batched scorer: the weight travels
    # as an attribute, not by re-parsing the match_id string
    match.mem_dist_weight = float(weight)
    return match


def critical_edge_match(critical_cost: float = 4.0) -> EdgeMatch:
    """Edges tagged ``critical`` (e.g. all-reduce paths) cost more to lose."""

    def match(e_req: Optional[Dict], e_cand: Optional[Dict]) -> float:
        if e_req is not None and e_cand is None:
            return critical_cost if e_req.get("critical") else float(
                e_req.get("cost", DEFAULT_EDGE_COST))
        return default_edge_match(e_req, e_cand)

    match.match_id = f"edge:critical:{float(critical_cost)!r}"
    return match


# ---------------------------------------------------------------------------
# assignment machinery
# ---------------------------------------------------------------------------

def hungarian(cost: np.ndarray) -> List[int]:
    """O(n^3) Hungarian algorithm (potentials / shortest augmenting path).

    Returns ``assign`` with assign[row] = col minimizing total cost.  Square
    matrices only — pad rectangular inputs before calling.
    """
    cost = np.asarray(cost, dtype=np.float64)
    n = cost.shape[0]
    assert cost.shape == (n, n)
    INF = float("inf")
    u = np.zeros(n + 1)
    v = np.zeros(n + 1)
    p = np.zeros(n + 1, dtype=int)  # p[col] = row matched to col (1-indexed)
    way = np.zeros(n + 1, dtype=int)
    for i in range(1, n + 1):
        p[0] = i
        j0 = 0
        minv = np.full(n + 1, INF)
        used = np.zeros(n + 1, dtype=bool)
        while True:
            # one Dijkstra relaxation step, vectorized over the columns
            # (same arithmetic and same first-minimum tie-break as the
            # scalar loop — np.argmin returns the lowest index)
            used[j0] = True
            i0 = p[j0]
            cur = cost[i0 - 1, :] - u[i0] - v[1:]
            free = ~used[1:]
            improve = free & (cur < minv[1:])
            minv[1:][improve] = cur[improve]
            way[1:][improve] = j0
            masked = np.where(free, minv[1:], INF)
            j1 = int(np.argmin(masked)) + 1
            delta = float(masked[j1 - 1])
            np.add.at(u, p[used], delta)
            v[used] -= delta
            minv[~used] -= delta
            j0 = j1
            if p[j0] == 0:
                break
        while True:
            j1 = way[j0]
            p[j0] = p[j1]
            j0 = j1
            if j0 == 0:
                break
    assign = [0] * n
    for j in range(1, n + 1):
        if p[j] > 0:
            assign[p[j] - 1] = j - 1
    return assign


def induced_edit_cost(t_req: Topology, t_cand: Topology,
                      mapping: Dict[int, int],
                      node_match: NodeMatch, edge_match: EdgeMatch) -> float:
    """Edit cost induced by a concrete node bijection (upper bound on GED)."""
    cost = 0.0
    for rq, cd in mapping.items():
        cost += node_match(t_req.node_attrs[rq], t_cand.node_attrs[cd])
    # edges of request vs image edges in candidate
    for (a, b), attrs in t_req.edge_attrs.items():
        ma, mb = mapping[a], mapping[b]
        if t_cand.has_edge(ma, mb):
            cost += edge_match(attrs, t_cand.edge_attrs[(min(ma, mb), max(ma, mb))]) * 0.0
        else:
            cost += edge_match(attrs, None)
    inv = {v: k for k, v in mapping.items()}
    for (a, b), attrs in t_cand.edge_attrs.items():
        ra, rb = inv.get(a), inv.get(b)
        if ra is None or rb is None or not t_req.has_edge(ra, rb):
            cost += edge_match(None, attrs)
    return cost


def _exact_ged_same_size(t_req: Topology, t_cand: Topology,
                         node_match: NodeMatch, edge_match: EdgeMatch,
                         budget: float = float("inf")
                         ) -> Tuple[float, Dict[int, int]]:
    """Branch & bound over bijections (both graphs have equal node count).

    Suitable for requests up to ~8 nodes; above that use the bipartite
    approximation.
    """
    req_nodes = t_req.nodes()
    cand_nodes = t_cand.nodes()
    n = len(req_nodes)
    assert n == len(cand_nodes)
    # order request nodes by degree (high first) for tighter pruning
    req_nodes = sorted(req_nodes, key=lambda x: -t_req.degree(x))
    best = [budget, None]

    def rec(i: int, used: Set[int], mapping: Dict[int, int], acc: float):
        if acc >= best[0]:
            return
        if i == n:
            # add insertion cost for candidate edges not covered
            total = acc
            inv = {v: k for k, v in mapping.items()}
            for (a, b), attrs in t_cand.edge_attrs.items():
                ra, rb = inv[a], inv[b]
                if not t_req.has_edge(ra, rb):
                    total += edge_match(None, attrs)
            if total < best[0]:
                best[0] = total
                best[1] = dict(mapping)
            return
        rq = req_nodes[i]
        for cd in cand_nodes:
            if cd in used:
                continue
            delta = node_match(t_req.node_attrs[rq], t_cand.node_attrs[cd])
            # edges back to already-assigned request nodes
            for prev_rq, prev_cd in mapping.items():
                req_has = t_req.has_edge(rq, prev_rq)
                cand_has = t_cand.has_edge(cd, prev_cd)
                if req_has and not cand_has:
                    e = t_req.edge_attrs[(min(rq, prev_rq), max(rq, prev_rq))]
                    delta += edge_match(e, None)
                elif cand_has and not req_has:
                    e = t_cand.edge_attrs[(min(cd, prev_cd), max(cd, prev_cd))]
                    delta += edge_match(None, e)
            mapping[rq] = cd
            rec(i + 1, used | {cd}, mapping, acc + delta)
            del mapping[rq]

    rec(0, set(), {}, 0.0)
    if best[1] is None:
        return budget, {}
    return best[0], best[1]


def _bipartite_ged_same_size(t_req: Topology, t_cand: Topology,
                             node_match: NodeMatch, edge_match: EdgeMatch
                             ) -> Tuple[float, Dict[int, int]]:
    """Riesen–Bunke bipartite approximation specialized to equal-size graphs:
    Hungarian over per-node substitution costs (node cost + incident-edge
    neighbourhood mismatch estimate), then the *induced* edit cost of that
    assignment is returned (a valid upper bound, consistent ranking).
    """
    req_nodes = t_req.nodes()
    cand_nodes = t_cand.nodes()
    n = len(req_nodes)
    C = np.zeros((n, n))
    req_deg = {x: t_req.degree(x) for x in req_nodes}
    cand_deg = {x: t_cand.degree(x) for x in cand_nodes}
    for i, rq in enumerate(req_nodes):
        for j, cd in enumerate(cand_nodes):
            c = node_match(t_req.node_attrs[rq], t_cand.node_attrs[cd])
            # local edge structure estimate: degree mismatch costs ~1 edit per
            # missing/extra incident edge (each edge shared by 2 nodes -> /2)
            c += 0.5 * abs(req_deg[rq] - cand_deg[cd]) * DEFAULT_EDGE_COST
            C[i, j] = c
    assign = hungarian(C)
    mapping = {req_nodes[i]: cand_nodes[assign[i]] for i in range(n)}
    return induced_edit_cost(t_req, t_cand, mapping, node_match, edge_match), mapping


EXACT_TED_MAX_NODES = 8


def topology_edit_distance(t_req: Topology, t_cand: Topology,
                           node_match: Optional[NodeMatch] = None,
                           edge_match: Optional[EdgeMatch] = None,
                           method: str = "auto"
                           ) -> Tuple[float, Dict[int, int]]:
    """TED between the requested and candidate topologies (equal node count),
    plus the realizing virtual->physical node assignment.
    """
    if t_req.num_nodes != t_cand.num_nodes:
        raise ValueError("R-1 violated: node counts differ")
    nm = node_match or default_node_match
    em = edge_match or default_edge_match
    if method == "exact" or (method == "auto" and t_req.num_nodes <= EXACT_TED_MAX_NODES):
        # seed branch & bound with the bipartite bound for fast pruning
        ub, ub_map = _bipartite_ged_same_size(t_req, t_cand, nm, em)
        cost, mapping = _exact_ged_same_size(t_req, t_cand, nm, em, budget=ub + 1e-9)
        if not mapping:
            return ub, ub_map
        return cost, mapping
    return _bipartite_ged_same_size(t_req, t_cand, nm, em)


# ---------------------------------------------------------------------------
# candidate proposal
# ---------------------------------------------------------------------------

def _rect_windows(topo: Topology, free: Set[int], k: int) -> List[FrozenSet[int]]:
    """All r x c windows (r*c == k) fully inside the free mask, plus clipped
    rectangles (r*c > k, removing the excess from the last row) — vectorized
    on the coordinate grid.
    """
    if not topo.coords:
        return []
    coords = topo.coords
    by_coord = {v: n for n, v in coords.items()}
    R = 1 + max(r for r, _ in coords.values())
    C = 1 + max(c for _, c in coords.values())
    mask = np.zeros((R, C), dtype=bool)
    for n in free:
        r, c = coords[n]
        mask[r, c] = True
    out: List[FrozenSet[int]] = []
    shapes = []
    for r in range(1, k + 1):
        c_exact, rem = divmod(k, r)
        if rem == 0:
            shapes.append((r, c_exact, 0))
        # clipped: smallest c with r*c >= k
        c_clip = -(-k // r)
        if r * c_clip > k and c_clip <= C:
            shapes.append((r, c_clip, r * c_clip - k))
    for (r, c, clip) in shapes:
        if r > R or c > C:
            continue
        # sliding window sum of mask
        ii = np.cumsum(np.cumsum(mask.astype(np.int32), 0), 1)
        pad = np.zeros((R + 1, C + 1), dtype=np.int64)
        pad[1:, 1:] = ii
        for r0 in range(R - r + 1):
            for c0 in range(C - c + 1):
                s = pad[r0 + r, c0 + c] - pad[r0, c0 + c] - pad[r0 + r, c0] + pad[r0, c0]
                if s == r * c:
                    nodes = [by_coord[(r0 + i, c0 + j)]
                             for i in range(r) for j in range(c)]
                    if clip:
                        nodes = nodes[:-clip] if clip < c else nodes[:k]
                    out.append(frozenset(nodes[:k]) if not clip else frozenset(nodes))
    return out


def _bfs_blobs(topo: Topology, free: Set[int], k: int,
               max_seeds: Optional[int] = None) -> List[FrozenSet[int]]:
    """Compact connected blobs: from each free seed, greedily absorb the free
    neighbour that maximizes internal edges (keeps the blob mesh-like)."""
    adj = topo._adj()
    seeds = sorted(free)
    if max_seeds is not None and len(seeds) > max_seeds:
        step = len(seeds) // max_seeds
        seeds = seeds[::step][:max_seeds]
    out = []
    for s in seeds:
        blob = {s}
        frontier = {n for n in adj[s] if n in free}
        while len(blob) < k and frontier:
            best = max(frontier, key=lambda n: (sum(1 for m in adj[n] if m in blob), -n))
            blob.add(best)
            frontier.discard(best)
            frontier |= {n for n in adj[best] if n in free and n not in blob}
        if len(blob) == k:
            out.append(frozenset(blob))
    return out


FULL_ENUM_FREE_LIMIT = 18   # full COMB enumeration only below this many free cores
FULL_ENUM_MAX_RESULTS = 20_000


def propose_candidates(topo: Topology, free: Iterable[int], k: int,
                       *, require_connected: bool = True,
                       max_candidates: int = 512) -> List[FrozenSet[int]]:
    """Candidate physical node sets of size k (Algorithm 1's ``totalSubTopo``
    after R-1/R-3 filtering), bounded for pod-scale meshes.
    """
    free_set = set(free)
    if k > len(free_set):
        return []
    cands: List[FrozenSet[int]] = []
    seen: Set[FrozenSet[int]] = set()

    def add(c: FrozenSet[int]) -> None:
        if c not in seen and len(c) == k:
            if not require_connected or topo.is_connected(c):
                seen.add(c)
                cands.append(c)

    if len(free_set) <= FULL_ENUM_FREE_LIMIT:
        for c in enumerate_connected_subsets(topo, k, within=free_set,
                                             max_results=FULL_ENUM_MAX_RESULTS):
            add(c)
            if len(cands) >= max_candidates:
                return cands
        if cands or require_connected:
            return cands
    for c in _rect_windows(topo, free_set, k):
        add(c)
    for c in _bfs_blobs(topo, free_set, k, max_seeds=max(8, max_candidates // 4)):
        add(c)
        if len(cands) >= max_candidates:
            break
    # always consider the straightforward (zig-zag) node set too — it is a
    # legal candidate, so similar-mapping can never do worse than it
    ordered = sorted(free_set, key=lambda n: topo.coords.get(n, (0, n)))
    add(frozenset(ordered[:k]))
    if not cands and not require_connected:
        # fragmented fallback (§4.3 'Topology fragmentation' trade-off)
        cands.append(frozenset(ordered[:k]))
    return cands[:max_candidates]


# ---------------------------------------------------------------------------
# Algorithm 1
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MappingResult:
    nodes: FrozenSet[int]             # chosen physical cores
    ted: float                        # topology edit distance achieved
    assignment: Dict[int, int]        # request node id -> physical node id
    exact: bool                       # early-exited with an exact match
    candidates_evaluated: int = 0
    #: provably minimal TED over *all* injective placements of the request
    #: into the free component that produced this result (the ILP mapper's
    #: optimality certificate; heuristic mappers always leave it False)
    optimal: bool = False


def min_topology_edit_distance(
    topo: Topology,
    allocated: Iterable[int],
    t_req: Topology,
    *,
    node_match: Optional[NodeMatch] = None,
    edge_match: Optional[EdgeMatch] = None,
    require_connected: bool = True,
    max_candidates: int = 512,
) -> Optional[MappingResult]:
    """Algorithm 1 (minTopologyEditDistance).  Returns None when not even a
    candidate of the right size exists (caller may retry with
    ``require_connected=False`` — the fragmentation trade-off).
    """
    nm = node_match or default_node_match
    em = edge_match or default_edge_match
    free = set(topo.node_attrs) - set(allocated)
    k = t_req.num_nodes
    req_key = t_req.canonical_key()

    cands = propose_candidates(topo, free, k, require_connected=require_connected,
                               max_candidates=max_candidates)
    if not cands:
        return None

    # prune 2: isomorphism dedup — keep one instance per canonical key...
    # except when heterogeneous matching is in play the position matters, so
    # the canonical key already folds in node attrs (see Topology.canonical_key).
    by_key: Dict[Tuple, FrozenSet[int]] = {}
    uniq: List[Tuple[FrozenSet[int], Topology, Tuple]] = []
    for c in cands:
        sub = topo.subgraph(c)
        key = sub.canonical_key()
        if key in by_key:
            continue
        by_key[key] = c
        uniq.append((c, sub, key))

    # prune 3: exact-match early exit
    for c, sub, key in uniq:
        if key == req_key:
            ted, mapping = topology_edit_distance(t_req, sub, nm, em)
            if ted == 0.0:
                return MappingResult(nodes=c, ted=0.0, assignment=mapping,
                                     exact=True, candidates_evaluated=len(uniq))

    best: Optional[MappingResult] = None
    for c, sub, _ in uniq:
        ted, mapping = topology_edit_distance(t_req, sub, nm, em)
        if best is None or ted < best.ted:
            best = MappingResult(nodes=c, ted=ted, assignment=mapping, exact=False)
        if best.ted == 0.0:
            break
    if best is not None:
        best.candidates_evaluated = len(uniq)
    return best


def straightforward_mapping(topo: Topology, allocated: Iterable[int],
                            t_req: Topology) -> Optional[MappingResult]:
    """Fig. 18's baseline: allocate by core id (zig-zag), ignoring topology."""
    free = sorted(set(topo.node_attrs) - set(allocated))
    k = t_req.num_nodes
    if len(free) < k:
        return None
    nodes = frozenset(free[:k])
    sub = topo.subgraph(nodes)
    # identity-ish assignment: request nodes in sorted order -> chosen cores
    req_sorted = t_req.nodes()
    mapping = dict(zip(req_sorted, sorted(nodes)))
    ted = induced_edit_cost(t_req, sub, mapping,
                            default_node_match, default_edge_match)
    return MappingResult(nodes=nodes, ted=ted, assignment=mapping, exact=False)
