"""Epoch-boundary occupancy and NoC link-heat timelines.

The scheduler already touches everything needed at every EPOCH event:
the policy's free/failed core sets and — in ledger mode — the
:class:`~repro.sched.ledger.InterferenceLedger`'s per-directed-link
occupancy (``link_loads``, the very aggregate the link-heatmap-aware
admission objective reads).  A :class:`TimelineSampler` turns those into
Perfetto counter tracks:

- ``cores`` — busy / free / failed core counts (stacked);
- ``link_heat`` — total and max bytes/iteration over all directed NoC
  links, plus the count of loaded links.

Aggregates (not 2·links individual tracks) keep a 32x32 trace openable;
``keep_links=True`` additionally retains the full per-link dict per
sample for offline tooling.  Sampling is a pure read of values the sim
computed anyway — no feedback into the trajectory.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.obs.trace import Tracer


class TimelineSampler:
    """Emits core-occupancy and link-heat counter tracks to a tracer."""

    def __init__(self, tracer: Tracer, pid: Optional[int] = None,
                 keep_links: bool = False) -> None:
        self.tracer = tracer
        self.pid = pid
        self.keep_links = keep_links
        #: retained (t_s, {directed link: bytes/iter}) samples
        #: (``keep_links=True`` only)
        self.link_samples: List[Tuple[float, Dict]] = []

    def sample(self, t: float, n_total: int, n_free: int, n_failed: int,
               link_loads: Optional[Dict] = None) -> None:
        """Record one epoch boundary.  ``link_loads`` is the ledger's
        per-directed-link aggregate (None in oracle mode: the core track
        still samples)."""
        tr = self.tracer
        if not tr.enabled:
            return
        tr.counter("cores", t,
                   {"busy": n_total - n_free - n_failed,
                    "free": n_free, "failed": n_failed},
                   pid=self.pid)
        if link_loads is not None:
            loads = link_loads.values()
            tr.counter("link_heat", t,
                       {"total": float(sum(loads)),
                        "max": float(max(loads, default=0.0)),
                        "active_links": len(link_loads)},
                       pid=self.pid)
            if self.keep_links:
                self.link_samples.append((t, dict(link_loads)))
