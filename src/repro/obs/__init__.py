"""Sim-time observability plane.

Three parts, all pure observers of the simulation:

- :mod:`repro.obs.trace` — span/instant/counter flight recorder in
  *simulated* time, exportable as Chrome trace-event JSON (Perfetto).
- :mod:`repro.obs.registry` — unified counter/gauge/histogram registry
  with Prometheus text exposition and a JSON snapshot for BENCH records.
- :mod:`repro.obs.timeline` — per-core occupancy and NoC link-heat
  timelines sampled at epoch boundaries, rendered as counter tracks.

Tracing must never perturb a trajectory: a disabled tracer
(``Tracer.NULL``) is a no-op, and an enabled one only records values it
is handed — no RNG draws, no time arithmetic feeding back into the sim.
"""
from repro.obs.trace import Tracer, FLEET_PID
from repro.obs.registry import MetricsRegistry
from repro.obs.timeline import TimelineSampler

__all__ = ["Tracer", "FLEET_PID", "MetricsRegistry", "TimelineSampler"]
