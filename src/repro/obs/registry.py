"""Unified metrics registry: counters, gauges and histogram snapshots.

The repo's telemetry is scattered across ad-hoc dataclasses
(``ClusterMetrics``, ``FleetMetrics``, ``EngineStats``, ``SwitchStats``,
ledger counters, P² latency sketches).  A :class:`MetricsRegistry` gives
them one export surface: Prometheus text exposition for eyeballs and a
JSON ``snapshot()`` — a *list* of metric objects, so downstream linting
can reject duplicate names — that BENCH records embed.

Naming conventions (see ``docs/observability.md``):

- ``<subsystem>_<noun>`` with Prometheus-legal characters only
  (``[a-zA-Z_:][a-zA-Z0-9_:]*``);
- monotone event counts end in ``_total``; point-in-time values are
  gauges with a unit suffix (``_s``, ``_bytes``, ``_ratio``);
- latency sketches register as histograms via
  ``LatencyStats.snapshot()``.

Registration is collection-time (the sim finishes, then a collector
walks the metrics objects) — the registry never sits on a hot path and
never perturbs a trajectory.
"""
from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Any, Dict, List, Optional

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")


class MetricsRegistry:
    """Insertion-ordered metric store with duplicate-name rejection."""

    def __init__(self) -> None:
        self._metrics: Dict[str, Dict[str, Any]] = {}

    def _add(self, name: str, entry: Dict[str, Any]) -> None:
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        if name in self._metrics:
            raise ValueError(f"duplicate metric name {name!r}")
        self._metrics[name] = entry

    def counter(self, name: str, value: float, help: str = "") -> None:
        """Monotone event count (convention: name ends in ``_total``)."""
        self._add(name, {"name": name, "kind": "counter",
                         "value": float(value), "help": help})

    def gauge(self, name: str, value: float, help: str = "") -> None:
        """Point-in-time value."""
        self._add(name, {"name": name, "kind": "gauge",
                         "value": float(value), "help": help})

    def histogram(self, name: str, snap: Dict[str, Any],
                  help: str = "") -> None:
        """Distribution summary from ``LatencyStats.snapshot()`` (or any
        dict with ``count``/``total`` and a ``quantiles`` mapping)."""
        self._add(name, {
            "name": name, "kind": "histogram", "help": help,
            "count": int(snap.get("count", 0)),
            "sum": float(snap.get("total", 0.0)),
            "min": float(snap.get("min", 0.0)),
            "max": float(snap.get("max", 0.0)),
            "quantiles": {str(q): float(v) for q, v in
                          snap.get("quantiles", {}).items()},
        })

    def __len__(self) -> int:
        return len(self._metrics)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    # ------------------------------------------------------------------
    # export
    # ------------------------------------------------------------------
    def snapshot(self) -> List[Dict[str, Any]]:
        """JSON-safe list of metric objects, in registration order.  A
        list (not a name-keyed dict) so ``tools/check_bench.py`` can lint
        hand-edited records for duplicate names."""
        out = []
        for m in self._metrics.values():
            m = dict(m)
            if not m.get("help"):
                m.pop("help", None)
            out.append(m)
        return out

    def prometheus_text(self) -> str:
        """Prometheus text exposition format (histograms as summaries)."""
        lines: List[str] = []
        for name, m in self._metrics.items():
            if m.get("help"):
                lines.append(f"# HELP {name} {m['help']}")
            if m["kind"] == "histogram":
                lines.append(f"# TYPE {name} summary")
                for q, v in m["quantiles"].items():
                    lines.append(f'{name}{{quantile="{q}"}} {v:.9g}')
                lines.append(f"{name}_sum {m['sum']:.9g}")
                lines.append(f"{name}_count {m['count']}")
            else:
                lines.append(f"# TYPE {name} {m['kind']}")
                lines.append(f"{name} {m['value']:.9g}")
        return "\n".join(lines) + "\n"

    def write_json(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump({"metrics": self.snapshot()}, fh, indent=1,
                      sort_keys=False)
            fh.write("\n")


def _num(v: Any) -> Optional[float]:
    """The value as a finite float, or None when it isn't scalar."""
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        return None
    return float(v) if math.isfinite(v) else None


def _register_flat(reg: MetricsRegistry, prefix: str,
                   d: Dict[str, Any], kind: str = "counter") -> None:
    """Register every finite scalar of ``d`` under ``prefix_``; rate-like
    keys become gauges regardless of ``kind``."""
    for k, v in d.items():
        val = _num(v)
        if val is None:
            continue
        name = f"{prefix}_{k}"
        gaugey = kind == "gauge" or k.endswith(("_rate", "_ratio", "_s",
                                                "_ms", "_frac", "_rps",
                                                "_bytes"))
        if gaugey:
            reg.gauge(name, val)
        else:
            reg.counter(name + ("" if k.endswith("_total") else "_total"),
                        val)


def collect_cluster(reg: MetricsRegistry, metrics: Any,
                    prefix: str = "cluster") -> MetricsRegistry:
    """Register one :class:`~repro.sched.cluster.ClusterMetrics` run.

    Every ``n_*`` dataclass counter is surfaced mechanically — the whole
    point of the registry path is that a counter added to the metrics
    can never again be silently dropped from the export (the
    ``summary()`` table once omitted ``n_evacuated``/``n_probe_skips``).
    """
    for f in dataclasses.fields(metrics):
        if not f.name.startswith("n_"):
            continue
        v = _num(getattr(metrics, f.name))
        if v is not None:
            reg.counter(f"{prefix}_{f.name[2:]}_total", v)
    for name, v in (
            ("requests_arrived_total", metrics.requests_arrived),
            ("requests_completed_total", metrics.requests_completed),
            ("requests_sla_good_total", metrics.requests_sla_good),
            ("tokens_generated_total", metrics.tokens_generated),
            ("kv_preemptions_total", metrics.kv_preemptions),
            ("kv_admit_oom_total", metrics.kv_admit_oom),
            ("requests_dropped_total", metrics.requests_dropped),
            ("requests_fault_lost_total", metrics.requests_fault_lost),
            ("rework_s", metrics.rework_s),
            ("rewarm_cost_s", metrics.rewarm_cost_s),
            ("core_downtime_s", metrics.core_downtime_s),
            ("mttr_s", metrics.mttr_s),
            ("horizon_s", metrics.horizon_s),
            ("mean_utilization_ratio", metrics.mean_utilization),
            ("capacity_availability_ratio", metrics.capacity_availability),
            ("service_availability_ratio", metrics.service_availability),
            ("p50_wait_s", metrics.p50_wait_s),
            ("p95_wait_s", metrics.p95_wait_s),
            ("p99_wait_s", metrics.p99_wait_s),
            ("median_scoring_ms", metrics.median_scoring_ms),
            ("peak_live_records", metrics.peak_live_records)):
        v = _num(v)
        if v is None:
            continue
        full = f"{prefix}_{name}"
        if name.endswith("_total"):
            reg.counter(full, v)
        else:
            reg.gauge(full, v)
    if metrics.engine_counters:
        _register_flat(reg, f"{prefix}_engine", metrics.engine_counters)
    if metrics.ledger_counters:
        _register_flat(reg, f"{prefix}_ledger", metrics.ledger_counters)
    for label, stats in (("ttft", metrics.ttft_stats),
                         ("tpot", metrics.tpot_stats)):
        if stats.count:
            reg.histogram(f"{prefix}_{label}_seconds", stats.snapshot())
    return reg


def collect_serving(reg: MetricsRegistry, summary: Dict[str, Any],
                    prefix: str = "serving") -> MetricsRegistry:
    """Register a flat serving digest (``serving_summary()`` output)."""
    _register_flat(reg, prefix, summary)
    return reg


def collect_fleet(reg: MetricsRegistry, metrics: Any,
                  prefix: str = "fleet") -> MetricsRegistry:
    """Register one :class:`~repro.fleet.fleet.FleetMetrics` run: router
    and switch counters, pod census, and the merged serving digest."""
    reg.gauge(f"{prefix}_pods", len(metrics.pod_ids))
    _register_flat(reg, f"{prefix}_router",
                   {k: v for k, v in metrics.router.as_dict().items()
                    if _num(v) is not None})
    _register_flat(reg, f"{prefix}_switch", metrics.switch.as_dict())
    collect_serving(reg, metrics.serving_summary(), prefix=f"{prefix}_serving")
    return reg
