"""Sim-time span tracer with a bounded ring-buffer flight recorder.

Records structured spans ("X"), instants ("i") and counter samples ("C")
stamped in *simulated* time and exports them as Chrome trace-event JSON
that opens directly in Perfetto (https://ui.perfetto.dev) or
``chrome://tracing``.  Conventions:

- ``pid`` is the pod (0 for a single-pod run, :data:`FLEET_PID` for
  fleet-driver-scope events such as routing decisions);
- ``tid`` is the tenant id (0 for scheduler-scope events);
- timestamps and durations are microseconds of sim time.

Determinism contract
--------------------
The tracer is a **pure observer**: it only stores values handed to it by
the simulation — it never draws randomness, reads clocks, or computes
anything the sim reads back.  Eviction from the ring buffer is strictly
count-based (oldest event first), never wall-time-based, so the set of
retained events is a deterministic function of the emission sequence.
``Tracer.NULL`` is a shared disabled instance; call sites guard hot
paths with ``if tracer.enabled:`` so tracing-off costs one attribute
load.
"""
from __future__ import annotations

import json
from collections import deque
from typing import Any, Deque, Dict, List, Optional

#: ``pid`` used for fleet-driver-scope events (routing, switch transfers,
#: scenario injections) so they land on their own Perfetto track group.
FLEET_PID = 9999

#: Default flight-recorder size.  A 32x32 pod-gate run emits a few
#: hundred thousand events; the default keeps the newest of those.
DEFAULT_CAPACITY = 500_000


def _us(t_s: float) -> float:
    """Sim seconds -> trace microseconds (3 decimal places = ns grain)."""
    return round(t_s * 1e6, 3)


class Tracer:
    """Bounded flight recorder for sim-time trace events.

    ``capacity`` bounds the ring buffer (``None`` = unbounded); when it
    overflows the *oldest* events are evicted (count-based, deterministic).
    ``pid`` is the default process id stamped on events, overridable per
    call so a fleet driver can file events under individual pods.
    """

    __slots__ = ("enabled", "capacity", "pid", "n_emitted", "_buf", "_meta")

    NULL: "Tracer"  # shared disabled instance, assigned below

    def __init__(self, capacity: Optional[int] = DEFAULT_CAPACITY,
                 pid: int = 0, enabled: bool = True) -> None:
        self.enabled = enabled
        self.capacity = capacity
        self.pid = pid
        self.n_emitted = 0
        self._buf: Deque[Dict[str, Any]] = deque(maxlen=capacity)
        # (pid,) -> process name; (pid, tid) -> thread name.  Kept out of
        # the ring buffer so names survive eviction.
        self._meta: Dict[tuple, str] = {}

    # ------------------------------------------------------------------
    # emission
    # ------------------------------------------------------------------
    def _push(self, ev: Dict[str, Any]) -> None:
        self.n_emitted += 1
        self._buf.append(ev)

    def span(self, name: str, cat: str, ts: float, dur: float,
             tid: int = 0, args: Optional[Dict[str, Any]] = None,
             pid: Optional[int] = None) -> None:
        """Complete span: ``[ts, ts+dur]`` in sim seconds."""
        if not self.enabled:
            return
        ev: Dict[str, Any] = {
            "name": name, "cat": cat, "ph": "X",
            "ts": _us(ts), "dur": _us(max(dur, 0.0)),
            "pid": self.pid if pid is None else pid, "tid": tid,
        }
        if args:
            ev["args"] = args
        self._push(ev)

    def instant(self, name: str, cat: str, ts: float,
                tid: int = 0, args: Optional[Dict[str, Any]] = None,
                pid: Optional[int] = None) -> None:
        """Zero-duration marker (thread-scoped)."""
        if not self.enabled:
            return
        ev: Dict[str, Any] = {
            "name": name, "cat": cat, "ph": "i", "s": "t",
            "ts": _us(ts),
            "pid": self.pid if pid is None else pid, "tid": tid,
        }
        if args:
            ev["args"] = args
        self._push(ev)

    def counter(self, name: str, ts: float, values: Dict[str, float],
                pid: Optional[int] = None) -> None:
        """Counter-track sample; each key renders as a stacked series."""
        if not self.enabled:
            return
        self._push({
            "name": name, "cat": "counter", "ph": "C",
            "ts": _us(ts),
            "pid": self.pid if pid is None else pid, "tid": 0,
            "args": values,
        })

    def process_name(self, name: str, pid: Optional[int] = None) -> None:
        if self.enabled:
            self._meta[(self.pid if pid is None else pid,)] = name

    def thread_name(self, tid: int, name: str,
                    pid: Optional[int] = None) -> None:
        if self.enabled:
            self._meta[(self.pid if pid is None else pid, tid)] = name

    # ------------------------------------------------------------------
    # merging (fleet barrier drains)
    # ------------------------------------------------------------------
    @property
    def dropped(self) -> int:
        """Events evicted from the ring so far."""
        return self.n_emitted - len(self._buf)

    def __len__(self) -> int:
        return len(self._buf)

    def drain(self) -> Dict[str, Any]:
        """Detach and return buffered events + names (pipe-safe payload).

        Used by fleet pods at window barriers; the driver feeds the
        payload to :meth:`absorb` on its merged tracer.  The payload's
        ``dropped`` counts this window's ring evictions only — the
        emitted/dropped counters restart after every drain, so absorbing
        tracers can sum payload counts without double counting.
        """
        events = list(self._buf)
        dropped = self.dropped          # before the clear detaches the buf
        self._buf.clear()
        self.n_emitted = 0              # restart the window's drop counter
        meta = {"|".join(map(str, k)): v for k, v in self._meta.items()}
        return {"events": events, "meta": meta, "dropped": dropped}

    def absorb(self, payload: Dict[str, Any]) -> None:
        """Merge a :meth:`drain` payload into this tracer's buffer."""
        if not self.enabled:
            return
        for ev in payload.get("events", ()):
            self._push(ev)
        for k, v in payload.get("meta", {}).items():
            self._meta[tuple(int(p) for p in k.split("|"))] = v

    # ------------------------------------------------------------------
    # export
    # ------------------------------------------------------------------
    def export(self) -> Dict[str, Any]:
        """Chrome trace-event JSON object (metadata first, then events)."""
        meta_events: List[Dict[str, Any]] = []
        for key in sorted(self._meta):
            if len(key) == 1:
                meta_events.append({
                    "name": "process_name", "ph": "M", "pid": key[0],
                    "tid": 0, "args": {"name": self._meta[key]},
                })
            else:
                meta_events.append({
                    "name": "thread_name", "ph": "M", "pid": key[0],
                    "tid": key[1], "args": {"name": self._meta[key]},
                })
        return {
            "traceEvents": meta_events + list(self._buf),
            "displayTimeUnit": "ms",
            "otherData": {
                "clock": "sim",
                "emitted": self.n_emitted,
                "dropped": self.dropped,
            },
        }

    def write(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.export(), fh, separators=(",", ":"))
            fh.write("\n")


Tracer.NULL = Tracer(capacity=0, enabled=False)
